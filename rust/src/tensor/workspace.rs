//! Reusable scratch buffers for the allocation-free numeric hot path.
//!
//! A [`Workspace`] is a pool of [`Tensor`]s keyed by shape plus raw `f32`
//! buffers keyed by length. The `_into` kernels and the `nn` forward paths
//! draw their intermediates from one of these instead of the global
//! allocator, so a solver loop that reuses a workspace performs **zero
//! steady-state heap allocations**: every `take_*` after warmup pops a
//! previously returned buffer, and every `give_*` pushes it back into a
//! pool whose backing `Vec` capacity is already established.
//!
//! Contract: buffers come back with **stale contents** — callers must fully
//! overwrite them (every `_into` kernel in this crate does). Not returning
//! a buffer (e.g. on an error path) is safe; the pool simply re-allocates
//! on the next miss.

use std::collections::HashMap;

use crate::tensor::Tensor;

/// Shape-keyed scratch-buffer pool. See the module docs for the contract.
#[derive(Debug, Default)]
pub struct Workspace {
    tensors: HashMap<Vec<usize>, Vec<Tensor>>,
    bufs: HashMap<usize, Vec<Vec<f32>>>,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace {
            tensors: HashMap::new(),
            bufs: HashMap::new(),
        }
    }

    /// Pop a tensor of exactly `shape` from the pool, or allocate one on a
    /// miss. Contents are arbitrary (zeroed only on the first allocation).
    pub fn take_tensor(&mut self, shape: &[usize]) -> Tensor {
        if let Some(pool) = self.tensors.get_mut(shape) {
            if let Some(t) = pool.pop() {
                return t;
            }
        }
        Tensor::zeros(shape)
    }

    /// Return a tensor to the pool for its shape.
    // contains_key + get_mut instead of entry(): entry() would force a
    // `shape.to_vec()` key allocation on EVERY give, not just first insert.
    #[allow(clippy::map_entry)]
    pub fn give_tensor(&mut self, t: Tensor) {
        if self.tensors.contains_key(t.shape()) {
            self.tensors.get_mut(t.shape()).unwrap().push(t);
        } else {
            self.tensors.insert(t.shape().to_vec(), vec![t]);
        }
    }

    /// Pop a raw buffer of exactly `len` elements (contents arbitrary).
    pub fn take_buf(&mut self, len: usize) -> Vec<f32> {
        if let Some(pool) = self.bufs.get_mut(&len) {
            if let Some(b) = pool.pop() {
                debug_assert_eq!(b.len(), len);
                return b;
            }
        }
        vec![0.0; len]
    }

    /// Return a raw buffer to the pool for its length.
    pub fn give_buf(&mut self, b: Vec<f32>) {
        let len = b.len();
        self.bufs.entry(len).or_default().push(b);
    }

    /// Number of tensors currently parked in the pool (test introspection).
    pub fn pooled_tensors(&self) -> usize {
        self.tensors.values().map(Vec::len).sum()
    }

    /// Number of raw buffers currently parked in the pool.
    pub fn pooled_bufs(&self) -> usize {
        self.bufs.values().map(Vec::len).sum()
    }

    /// Drop every pooled buffer (frees the memory; the pool stays usable).
    pub fn clear(&mut self) {
        self.tensors.clear();
        self.bufs.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_give_roundtrip_reuses_storage() {
        let mut ws = Workspace::new();
        let mut t = ws.take_tensor(&[2, 3]);
        t.data_mut()[0] = 7.0;
        let ptr = t.data().as_ptr();
        ws.give_tensor(t);
        assert_eq!(ws.pooled_tensors(), 1);
        let t2 = ws.take_tensor(&[2, 3]);
        assert_eq!(t2.data().as_ptr(), ptr, "same backing storage reused");
        assert_eq!(t2.data()[0], 7.0, "contents are stale by contract");
        assert_eq!(ws.pooled_tensors(), 0);
    }

    #[test]
    fn distinct_shapes_pool_separately() {
        let mut ws = Workspace::new();
        let a = ws.take_tensor(&[4]);
        let b = ws.take_tensor(&[2, 2]);
        ws.give_tensor(a);
        ws.give_tensor(b);
        // same numel, different shape: each take must match its own shape
        assert_eq!(ws.take_tensor(&[4]).shape(), &[4]);
        assert_eq!(ws.take_tensor(&[2, 2]).shape(), &[2, 2]);
    }

    #[test]
    fn raw_bufs_pool_by_len() {
        let mut ws = Workspace::new();
        let b = ws.take_buf(16);
        assert_eq!(b.len(), 16);
        let ptr = b.as_ptr();
        ws.give_buf(b);
        assert_eq!(ws.pooled_bufs(), 1);
        assert_eq!(ws.take_buf(16).as_ptr(), ptr);
        assert_ne!(ws.take_buf(8).len(), 16);
    }

    #[test]
    fn clear_empties_pools() {
        let mut ws = Workspace::new();
        let t = ws.take_tensor(&[3]);
        ws.give_tensor(t);
        let b = ws.take_buf(5);
        ws.give_buf(b);
        ws.clear();
        assert_eq!(ws.pooled_tensors(), 0);
        assert_eq!(ws.pooled_bufs(), 0);
    }
}
