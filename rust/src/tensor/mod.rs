//! Minimal owned f32 tensor.
//!
//! Just enough n-d array to run the exported networks natively (dense
//! matmul, SAME-padding 3×3 conv, elementwise ops) — the native path backs
//! the benches' dense parameter sweeps so they don't pay a PJRT compile per
//! (solver, K) point. Row-major, contiguous, f32 only.
//!
//! Every allocating kernel has an `_into` / `_inplace` twin that writes
//! into caller-provided storage (usually drawn from a [`Workspace`]); the
//! pure APIs are thin wrappers over those twins, so the two paths are
//! bit-identical by construction. The solver hot loop runs entirely on the
//! `_into` layer — see `solvers::RkWorkspace`.

use std::sync::{Arc, Mutex};

use crate::util::threadpool::ThreadPool;
use crate::{Error, Result};

pub mod workspace;

pub use workspace::Workspace;

/// Pool used by [`gemm_into`] for row-block parallel matmuls, when
/// registered. Kept behind a mutex so registration can happen at runtime
/// (daemon startup, benches); the per-matmul cost is one uncontended
/// lock + `Arc` clone, only paid above the size threshold.
static MATMUL_POOL: Mutex<Option<Arc<ThreadPool>>> = Mutex::new(None);

/// Mul-adds below which a matmul never tries the pool: at ~64K FLOPs the
/// dispatch overhead (boxed closures + channel) is already amortized ~100×.
const PAR_MIN_MACS: usize = 1 << 16;

/// Register a thread pool for large matmuls. Row-block parallelism keeps
/// each output row's accumulation order unchanged, so results are
/// **bit-identical** to the serial path.
///
/// Pass a *dedicated* pool: a pool whose own jobs perform matmuls would
/// deadlock waiting for itself. Small products (< ~64K mul-adds) never use
/// the pool; note that parallel dispatch itself allocates, so hot loops
/// that must stay allocation-free should keep their products small or
/// leave this unset.
pub fn set_matmul_pool(pool: Arc<ThreadPool>) {
    *MATMUL_POOL.lock().unwrap() = Some(pool);
}

/// Undo [`set_matmul_pool`]; in-flight matmuls keep their `Arc` and finish.
pub fn clear_matmul_pool() {
    *MATMUL_POOL.lock().unwrap() = None;
}

/// `out = a @ b` for row-major `a` (m, k), `b` (k, n). Fully overwrites
/// `out` (stale contents are fine). The single gemm entry point: `matmul`,
/// `matmul_into`, and the im2col conv all funnel here, so their numerics
/// are identical by construction.
pub(crate) fn gemm_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    if m >= 2 && m.saturating_mul(k).saturating_mul(n) >= PAR_MIN_MACS {
        let pool = MATMUL_POOL.lock().unwrap().clone();
        if let Some(pool) = pool {
            if pool.workers() > 1 {
                gemm_parallel(a, b, m, k, n, out, &pool);
                return;
            }
        }
    }
    gemm_rows(a, b, m, k, n, out);
}

/// Serial gemm over `m` rows: ikj loop order with the N axis tiled so the
/// output strip stays L1-resident across the K loop — matters for the
/// wide-N products the im2col conv path generates (see EXPERIMENTS.md
/// §Perf).
fn gemm_rows(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    const N_BLK: usize = 1024; // 4 KiB output strip per row
    out.fill(0.0);
    for jb in (0..n).step_by(N_BLK) {
        let je = (jb + N_BLK).min(n);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n + jb..i * n + je];
            // Zero-skip is hoisted to a per-row density check: a branch per
            // element in the hottest loop pessimizes dense weights, but
            // genuinely sparse rows (pruned exports, one-hot probes) still
            // skip. The O(k) scan is noise next to the O(k·blk) inner loop.
            let zeros = arow.iter().filter(|&&x| x == 0.0).count();
            if zeros * 4 >= k {
                for (kk, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n + jb..kk * n + je];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            } else {
                for (kk, &av) in arow.iter().enumerate() {
                    let brow = &b[kk * n + jb..kk * n + je];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
        }
    }
}

/// Raw-pointer handoff for the row-block jobs. Each job owns a disjoint
/// range of `out` rows and only reads `a`/`b`.
struct SendConst(*const f32);
unsafe impl Send for SendConst {}
struct SendMut(*mut f32);
unsafe impl Send for SendMut {}

/// Parallel gemm over row blocks. Each job computes rows [i0, i0+rows)
/// exactly as the serial path would, so results are bit-identical.
fn gemm_parallel(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
    pool: &ThreadPool,
) {
    let chunks = pool.workers().min(m);
    let rows_per = m.div_ceil(chunks);
    let (tx, rx) = std::sync::mpsc::channel::<()>();
    // One base pointer per slice, taken ONCE: deriving every block pointer
    // from the same provenance root (rather than re-borrowing `out` per
    // block) keeps the already-dispatched pointers valid under Stacked
    // Borrows.
    let a_base = a.as_ptr();
    let b_base = b.as_ptr();
    let out_base = out.as_mut_ptr();
    let mut jobs = 0usize;
    let mut i0 = 0usize;
    while i0 < m {
        let rows = rows_per.min(m - i0);
        let ap = SendConst(unsafe { a_base.add(i0 * k) });
        let bp = SendConst(b_base);
        let op = SendMut(unsafe { out_base.add(i0 * n) });
        let tx = tx.clone();
        pool.execute(move || {
            // SAFETY: the caller blocks on `rx` below until every job has
            // signalled, so `a`, `b`, and `out` outlive this closure; the
            // out row blocks are disjoint by construction, and the gemm
            // body cannot panic (pure in-bounds arithmetic).
            let a = unsafe { std::slice::from_raw_parts(ap.0, rows * k) };
            let b = unsafe { std::slice::from_raw_parts(bp.0, k * n) };
            let o = unsafe { std::slice::from_raw_parts_mut(op.0, rows * n) };
            gemm_rows(a, b, rows, k, n, o);
            let _ = tx.send(());
        });
        jobs += 1;
        i0 += rows;
    }
    drop(tx);
    for _ in 0..jobs {
        rx.recv().expect("gemm worker died");
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            return Err(Error::Shape(format!(
                "shape {shape:?} needs {numel} elements, got {}",
                data.len()
            )));
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> f32) -> Self {
        let numel = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: (0..numel).map(|i| f(i)).collect(),
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor> {
        Tensor::new(shape, self.data.clone())
    }

    /// (rows, cols) view of a 2-D tensor.
    fn dims2(&self) -> Result<(usize, usize)> {
        match self.shape.as_slice() {
            [m, n] => Ok((*m, *n)),
            s => Err(Error::Shape(format!("expected 2-d, got {s:?}"))),
        }
    }

    // -- elementwise -------------------------------------------------------

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// In-place [`map`](Self::map).
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for v in &mut self.data {
            *v = f(*v);
        }
    }

    /// Overwrite with `src`'s contents. Panics on shape mismatch (the
    /// workspace layer guarantees matching shapes by construction).
    pub fn copy_from(&mut self, src: &Tensor) {
        assert_eq!(
            self.shape, src.shape,
            "copy_from shape mismatch {:?} vs {:?}",
            self.shape, src.shape
        );
        self.data.copy_from_slice(&src.data);
    }

    /// Set every element to `v`.
    pub fn fill(&mut self, v: f32) {
        self.data.fill(v);
    }

    fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Tensor> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!(
                "shape mismatch {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        Ok(Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        })
    }

    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a - b)
    }

    pub fn scale(&self, k: f32) -> Tensor {
        self.map(|x| k * x)
    }

    /// self += k * other, in place — the solver hot loop's axpy.
    pub fn axpy(&mut self, k: f32, other: &Tensor) -> Result<()> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!(
                "axpy shape mismatch {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += k * b;
        }
        Ok(())
    }

    // -- linear algebra ----------------------------------------------------

    /// Dense matmul (m,k) x (k,n) -> (m,n). Wrapper over
    /// [`matmul_into`](Self::matmul_into) (see [`gemm_into`] for the loop
    /// structure and the optional row-block parallelism).
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        let (m, _) = self.dims2()?;
        let (_, n) = other.dims2()?;
        let mut out = Tensor::zeros(&[m, n]);
        self.matmul_into(other, &mut out)?;
        Ok(out)
    }

    /// `out = self @ other`, fully overwriting `out` (stale contents are
    /// fine). `out` must already have shape (m, n).
    pub fn matmul_into(&self, other: &Tensor, out: &mut Tensor) -> Result<()> {
        let (m, k) = self.dims2()?;
        let (k2, n) = other.dims2()?;
        if k != k2 {
            return Err(Error::Shape(format!("matmul inner dim {k} vs {k2}")));
        }
        if out.shape != [m, n] {
            return Err(Error::Shape(format!(
                "matmul_into out shape {:?}, want [{m}, {n}]",
                out.shape
            )));
        }
        gemm_into(&self.data, &other.data, m, k, n, &mut out.data);
        Ok(())
    }

    /// `out = selfᵀ @ other` for `self` (m, k), `other` (m, n) → out (k, n)
    /// — the dW term of the dense-layer backward pass (xᵀ · dy). The
    /// transpose is materialized into a workspace buffer so the product
    /// runs through [`gemm_into`], inheriting the same numerics (and the
    /// optional row-block parallelism) as every other matmul in the crate.
    pub fn matmul_tn_into(
        &self,
        other: &Tensor,
        out: &mut Tensor,
        ws: &mut Workspace,
    ) -> Result<()> {
        let (m, k) = self.dims2()?;
        let (m2, n) = other.dims2()?;
        if m != m2 {
            return Err(Error::Shape(format!("matmul_tn rows {m} vs {m2}")));
        }
        if out.shape != [k, n] {
            return Err(Error::Shape(format!(
                "matmul_tn_into out shape {:?}, want [{k}, {n}]",
                out.shape
            )));
        }
        let mut at = ws.take_buf(k * m);
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = self.data[i * k + j];
            }
        }
        gemm_into(&at, &other.data, k, m, n, &mut out.data);
        ws.give_buf(at);
        Ok(())
    }

    /// Pure wrapper over [`matmul_tn_into`](Self::matmul_tn_into).
    pub fn matmul_tn(&self, other: &Tensor) -> Result<Tensor> {
        let (_, k) = self.dims2()?;
        let (_, n) = other.dims2()?;
        let mut out = Tensor::zeros(&[k, n]);
        let mut ws = Workspace::new();
        self.matmul_tn_into(other, &mut out, &mut ws)?;
        Ok(out)
    }

    /// `out = self @ otherᵀ` for `self` (m, k), `other` (n, k) → out (m, n)
    /// — the dX term of the dense-layer backward pass (dy · Wᵀ). Like
    /// [`matmul_tn_into`](Self::matmul_tn_into), funnels through
    /// [`gemm_into`] via a materialized transpose.
    pub fn matmul_nt_into(
        &self,
        other: &Tensor,
        out: &mut Tensor,
        ws: &mut Workspace,
    ) -> Result<()> {
        let (m, k) = self.dims2()?;
        let (n, k2) = other.dims2()?;
        if k != k2 {
            return Err(Error::Shape(format!("matmul_nt inner dim {k} vs {k2}")));
        }
        if out.shape != [m, n] {
            return Err(Error::Shape(format!(
                "matmul_nt_into out shape {:?}, want [{m}, {n}]",
                out.shape
            )));
        }
        let mut bt = ws.take_buf(k * n);
        for j in 0..n {
            for i in 0..k {
                bt[i * n + j] = other.data[j * k + i];
            }
        }
        gemm_into(&self.data, &bt, m, k, n, &mut out.data);
        ws.give_buf(bt);
        Ok(())
    }

    /// Pure wrapper over [`matmul_nt_into`](Self::matmul_nt_into).
    pub fn matmul_nt(&self, other: &Tensor) -> Result<Tensor> {
        let (m, _) = self.dims2()?;
        let (n, _) = other.dims2()?;
        let mut out = Tensor::zeros(&[m, n]);
        let mut ws = Workspace::new();
        self.matmul_nt_into(other, &mut out, &mut ws)?;
        Ok(out)
    }

    /// Column sums of an (m, n) tensor into `out` (length n, fully
    /// overwritten) — the bias gradient of the dense layer.
    pub fn col_sums_into(&self, out: &mut [f32]) -> Result<()> {
        let (m, n) = self.dims2()?;
        if out.len() != n {
            return Err(Error::Shape(format!(
                "col_sums_into out len {} vs cols {n}",
                out.len()
            )));
        }
        out.fill(0.0);
        for i in 0..m {
            let row = &self.data[i * n..(i + 1) * n];
            for (o, &v) in out.iter_mut().zip(row) {
                *o += v;
            }
        }
        Ok(())
    }

    /// Add a length-n bias row to every row of an (m, n) tensor.
    pub fn add_bias_rows(&self, bias: &[f32]) -> Result<Tensor> {
        let mut out = self.clone();
        out.add_bias_rows_inplace(bias)?;
        Ok(out)
    }

    /// In-place [`add_bias_rows`](Self::add_bias_rows).
    pub fn add_bias_rows_inplace(&mut self, bias: &[f32]) -> Result<()> {
        let (m, n) = self.dims2()?;
        if bias.len() != n {
            return Err(Error::Shape(format!(
                "bias len {} vs cols {n}",
                bias.len()
            )));
        }
        for i in 0..m {
            let row = &mut self.data[i * n..(i + 1) * n];
            for (v, &bv) in row.iter_mut().zip(bias) {
                *v += bv;
            }
        }
        Ok(())
    }

    /// Horizontally concatenate 2-D tensors (same row count).
    pub fn hcat(parts: &[&Tensor]) -> Result<Tensor> {
        let m = parts
            .first()
            .ok_or_else(|| Error::Shape("hcat of nothing".into()))?
            .dims2()?
            .0;
        let mut widths = Vec::with_capacity(parts.len());
        for p in parts {
            let (pm, pn) = p.dims2()?;
            if pm != m {
                return Err(Error::Shape("hcat row mismatch".into()));
            }
            widths.push(pn);
        }
        let n: usize = widths.iter().sum();
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let mut col = 0;
            for (p, &w) in parts.iter().zip(&widths) {
                out[i * n + col..i * n + col + w]
                    .copy_from_slice(&p.data[i * w..(i + 1) * w]);
                col += w;
            }
        }
        Tensor::new(&[m, n], out)
    }

    // -- conv (NCHW, OIHW, stride 1, SAME padding) --------------------------

    /// 2-D convolution matching `jax.lax.conv_general_dilated` with NCHW
    /// input, OIHW weights, stride 1, SAME padding — the only conv the
    /// exported models use.
    ///
    /// im2col + matmul: the patch matrix (B·H·W, Cin·kh·kw) is built once
    /// and contracted against the reshaped weights, putting the whole
    /// convolution on the (vectorised) matmul path. ~4× over the direct
    /// loop nest on the image-task shapes (see EXPERIMENTS.md §Perf);
    /// `conv2d_same_naive` keeps the reference implementation for the
    /// property tests. Wrapper over
    /// [`conv2d_same_into`](Self::conv2d_same_into) with a throwaway
    /// workspace.
    pub fn conv2d_same(&self, w: &Tensor, bias: &[f32]) -> Result<Tensor> {
        let (b, h, wd) = match self.shape.as_slice() {
            [b, _, h, w] => (*b, *h, *w),
            s => return Err(Error::Shape(format!("conv input {s:?}"))),
        };
        let cout = match w.shape.as_slice() {
            [o, _, _, _] => *o,
            s => return Err(Error::Shape(format!("conv weight {s:?}"))),
        };
        let mut out = Tensor::zeros(&[b, cout, h, wd]);
        let mut ws = Workspace::new();
        self.conv2d_same_into(w, bias, &mut out, &mut ws)?;
        Ok(out)
    }

    /// [`conv2d_same`](Self::conv2d_same) writing into `out` (shape
    /// (B, Cout, H, W), fully overwritten), with the im2col patch matrix
    /// and the product drawn from `ws` — the conv path's only heap traffic,
    /// gone once the workspace is warm.
    pub fn conv2d_same_into(
        &self,
        w: &Tensor,
        bias: &[f32],
        out: &mut Tensor,
        ws: &mut Workspace,
    ) -> Result<()> {
        let (b, cin, h, wd) = match self.shape.as_slice() {
            [b, c, h, w] => (*b, *c, *h, *w),
            s => return Err(Error::Shape(format!("conv input {s:?}"))),
        };
        let (cout, cin2, kh, kw) = match w.shape.as_slice() {
            [o, i, kh, kw] => (*o, *i, *kh, *kw),
            s => return Err(Error::Shape(format!("conv weight {s:?}"))),
        };
        if cin != cin2 {
            return Err(Error::Shape(format!("conv channels {cin} vs {cin2}")));
        }
        if bias.len() != cout {
            return Err(Error::Shape("conv bias length".into()));
        }
        if out.shape != [b, cout, h, wd] {
            return Err(Error::Shape(format!(
                "conv2d_same_into out shape {:?}, want {:?}",
                out.shape,
                [b, cout, h, wd]
            )));
        }
        let (ph, pw) = ((kh - 1) / 2, (kw - 1) / 2);
        let patch = cin * kh * kw;
        let plane = h * wd;

        // im2col, PATCH-MAJOR: row p of `cols` holds patch entry p for every
        // output pixel (b-major). Writes are contiguous x-runs and the
        // subsequent matmul (cout, patch) @ (patch, B·plane) streams the
        // wide N axis through the vector units. The buffer is pooled, so it
        // must be re-zeroed: padding cells are never written below.
        let n_pix = b * plane;
        let mut cols = ws.take_buf(patch * n_pix);
        cols.fill(0.0);
        for ic in 0..cin {
            for ky in 0..kh {
                for kx in 0..kw {
                    let p = (ic * kh + ky) * kw + kx;
                    let prow = p * n_pix;
                    for bi in 0..b {
                        let ibase = (bi * cin + ic) * plane;
                        let obase = prow + bi * plane;
                        // y such that iy = y + ky - ph stays in [0, h)
                        let y0 = ph.saturating_sub(ky);
                        let y1 = (h + ph - ky).min(h);
                        for y in y0..y1 {
                            let iy = y + ky - ph;
                            let x0 = pw.saturating_sub(kx);
                            let x1 = (wd + pw - kx).min(wd);
                            let src = ibase + iy * wd + (x0 + kx) - pw;
                            let dst = obase + y * wd + x0;
                            let len = x1 - x0;
                            let (s, d) = (src, dst);
                            cols[d..d + len]
                                .copy_from_slice(&self.data[s..s + len]);
                        }
                    }
                }
            }
        }

        // (cout, patch) @ (patch, B·plane): OIHW weights flatten directly
        // into the LHS.
        let mut prod = ws.take_buf(cout * n_pix);
        gemm_into(&w.data, &cols, cout, patch, n_pix, &mut prod);

        // (cout, B·plane) → NCHW + bias (plane rows stay contiguous)
        for oc in 0..cout {
            for bi in 0..b {
                let src = oc * n_pix + bi * plane;
                let dst = (bi * cout + oc) * plane;
                let bias_v = bias[oc];
                for i in 0..plane {
                    out.data[dst + i] = prod[src + i] + bias_v;
                }
            }
        }
        ws.give_buf(cols);
        ws.give_buf(prod);
        Ok(())
    }

    /// Reference direct-loop convolution (kept for property-testing the
    /// im2col path).
    pub fn conv2d_same_naive(&self, w: &Tensor, bias: &[f32]) -> Result<Tensor> {
        let (b, cin, h, wd) = match self.shape.as_slice() {
            [b, c, h, w] => (*b, *c, *h, *w),
            s => return Err(Error::Shape(format!("conv input {s:?}"))),
        };
        let (cout, cin2, kh, kw) = match w.shape.as_slice() {
            [o, i, kh, kw] => (*o, *i, *kh, *kw),
            s => return Err(Error::Shape(format!("conv weight {s:?}"))),
        };
        if cin != cin2 {
            return Err(Error::Shape(format!(
                "conv channels {cin} vs {cin2}"
            )));
        }
        if bias.len() != cout {
            return Err(Error::Shape("conv bias length".into()));
        }
        let (ph, pw) = ((kh - 1) / 2, (kw - 1) / 2);
        let mut out = vec![0.0f32; b * cout * h * wd];
        for bi in 0..b {
            for oc in 0..cout {
                let obase = ((bi * cout) + oc) * h * wd;
                for ic in 0..cin {
                    let ibase = ((bi * cin) + ic) * h * wd;
                    let wbase = ((oc * cin) + ic) * kh * kw;
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let wv = w.data[wbase + ky * kw + kx];
                            if wv == 0.0 {
                                continue;
                            }
                            // input row range that keeps (y+ky-ph) in bounds
                            let y0 = ph.saturating_sub(ky);
                            let y1 = (h + ph - ky).min(h);
                            for y in y0..y1 {
                                let iy = y + ky - ph;
                                let x0 = pw.saturating_sub(kx);
                                let x1 = (wd + pw - kx).min(wd);
                                let irow = ibase + iy * wd;
                                let orow = obase + y * wd;
                                for x in x0..x1 {
                                    let ix = x + kx - pw;
                                    out[orow + x] += wv * self.data[irow + ix];
                                }
                            }
                        }
                    }
                }
                let obase = ((bi * cout) + oc) * h * wd;
                for v in &mut out[obase..obase + h * wd] {
                    *v += bias[oc];
                }
            }
        }
        Tensor::new(&[b, cout, h, wd], out)
    }

    /// Append a constant-valued channel (the DepthCat op).
    pub fn depth_cat(&self, value: f32) -> Result<Tensor> {
        let (b, c, h, w) = match self.shape.as_slice() {
            [b, c, h, w] => (*b, *c, *h, *w),
            s => return Err(Error::Shape(format!("depth_cat input {s:?}"))),
        };
        let mut out = Tensor::zeros(&[b, c + 1, h, w]);
        self.depth_cat_into(value, &mut out)?;
        Ok(out)
    }

    /// [`depth_cat`](Self::depth_cat) writing into `out` (shape
    /// (B, C+1, H, W), fully overwritten).
    pub fn depth_cat_into(&self, value: f32, out: &mut Tensor) -> Result<()> {
        let (b, c, h, w) = match self.shape.as_slice() {
            [b, c, h, w] => (*b, *c, *h, *w),
            s => return Err(Error::Shape(format!("depth_cat input {s:?}"))),
        };
        if out.shape != [b, c + 1, h, w] {
            return Err(Error::Shape(format!(
                "depth_cat_into out shape {:?}, want {:?}",
                out.shape,
                [b, c + 1, h, w]
            )));
        }
        let plane = h * w;
        for bi in 0..b {
            let src = bi * c * plane;
            let dst = bi * (c + 1) * plane;
            out.data[dst..dst + c * plane]
                .copy_from_slice(&self.data[src..src + c * plane]);
            out.data[dst + c * plane..dst + (c + 1) * plane].fill(value);
        }
        Ok(())
    }

    // -- reductions ---------------------------------------------------------

    pub fn frobenius_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Row-wise argmax of a 2-D tensor.
    pub fn argmax_rows(&self) -> Result<Vec<usize>> {
        let (m, n) = self.dims2()?;
        Ok((0..m)
            .map(|i| {
                let row = &self.data[i * n..(i + 1) * n];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j)
                    .unwrap()
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propkit::{check, gen_range, gen_vec, prop_assert_close};

    #[test]
    fn construct_and_shape_check() {
        assert!(Tensor::new(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(&[2, 3], vec![0.0; 5]).is_err());
        assert_eq!(Tensor::zeros(&[4]).numel(), 4);
    }

    #[test]
    fn matmul_identity_property() {
        check("A @ I == A", 50, |rng| {
            let m = gen_range(rng, 1, 8);
            let n = gen_range(rng, 1, 8);
            let a = Tensor::new(&[m, n], gen_vec(rng, m * n, 1.0)).unwrap();
            let prod = a.matmul(&Tensor::eye(n)).unwrap();
            prop_assert_close(prod.data(), a.data(), 1e-6)
        });
    }

    #[test]
    fn matmul_known_values() {
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::new(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_associativity_property() {
        check("(AB)C == A(BC)", 30, |rng| {
            let (m, k, n, p) = (
                gen_range(rng, 1, 5),
                gen_range(rng, 1, 5),
                gen_range(rng, 1, 5),
                gen_range(rng, 1, 5),
            );
            let a = Tensor::new(&[m, k], gen_vec(rng, m * k, 1.0)).unwrap();
            let b = Tensor::new(&[k, n], gen_vec(rng, k * n, 1.0)).unwrap();
            let c = Tensor::new(&[n, p], gen_vec(rng, n * p, 1.0)).unwrap();
            let left = a.matmul(&b).unwrap().matmul(&c).unwrap();
            let right = a.matmul(&b.matmul(&c).unwrap()).unwrap();
            prop_assert_close(left.data(), right.data(), 1e-4)
        });
    }

    #[test]
    fn axpy_matches_scale_add() {
        check("axpy == add(scale)", 40, |rng| {
            let n = gen_range(rng, 1, 32);
            let a = Tensor::new(&[n], gen_vec(rng, n, 1.0)).unwrap();
            let b = Tensor::new(&[n], gen_vec(rng, n, 1.0)).unwrap();
            let k = rng.normal_f32();
            let mut via_axpy = a.clone();
            via_axpy.axpy(k, &b).unwrap();
            let via_ops = a.add(&b.scale(k)).unwrap();
            prop_assert_close(via_axpy.data(), via_ops.data(), 1e-6)
        });
    }

    #[test]
    fn hcat_widths() {
        let a = Tensor::new(&[2, 1], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(&[2, 2], vec![3.0, 4.0, 5.0, 6.0]).unwrap();
        let c = Tensor::hcat(&[&a, &b]).unwrap();
        assert_eq!(c.shape(), &[2, 3]);
        assert_eq!(c.data(), &[1.0, 3.0, 4.0, 2.0, 5.0, 6.0]);
        assert!(Tensor::hcat(&[]).is_err());
    }

    #[test]
    fn conv2d_identity_kernel() {
        // 1x1 kernel with weight 1 == identity
        let x = Tensor::new(&[1, 1, 3, 3], (1..=9).map(|v| v as f32).collect()).unwrap();
        let w = Tensor::new(&[1, 1, 1, 1], vec![1.0]).unwrap();
        let y = x.conv2d_same(&w, &[0.0]).unwrap();
        assert_eq!(y.data(), x.data());
    }

    #[test]
    fn conv2d_averaging_kernel_known() {
        // 3x3 ones kernel on a constant image: interior = 9, corners = 4
        let x = Tensor::full(&[1, 1, 4, 4], 1.0);
        let w = Tensor::full(&[1, 1, 3, 3], 1.0);
        let y = x.conv2d_same(&w, &[0.0]).unwrap();
        assert_eq!(y.shape(), &[1, 1, 4, 4]);
        assert_eq!(y.data()[0], 4.0); // corner
        assert_eq!(y.data()[5], 9.0); // interior
    }

    #[test]
    fn conv2d_matches_naive_property() {
        fn naive(x: &Tensor, w: &Tensor, bias: &[f32]) -> Tensor {
            let (b, cin, h, wd) = (
                x.shape()[0],
                x.shape()[1],
                x.shape()[2],
                x.shape()[3],
            );
            let (cout, _, kh, kw) = (
                w.shape()[0],
                w.shape()[1],
                w.shape()[2],
                w.shape()[3],
            );
            let (ph, pw) = ((kh - 1) / 2, (kw - 1) / 2);
            let mut out = Tensor::zeros(&[b, cout, h, wd]);
            for bi in 0..b {
                for oc in 0..cout {
                    for y in 0..h {
                        for xx in 0..wd {
                            let mut acc = bias[oc];
                            for ic in 0..cin {
                                for ky in 0..kh {
                                    for kx in 0..kw {
                                        let iy = y as isize + ky as isize - ph as isize;
                                        let ix = xx as isize + kx as isize - pw as isize;
                                        if iy < 0
                                            || ix < 0
                                            || iy >= h as isize
                                            || ix >= wd as isize
                                        {
                                            continue;
                                        }
                                        let xi = ((bi * cin + ic) * h
                                            + iy as usize)
                                            * wd
                                            + ix as usize;
                                        let wi = ((oc * cin + ic) * kh + ky) * kw
                                            + kx;
                                        acc += x.data()[xi] * w.data()[wi];
                                    }
                                }
                            }
                            out.data_mut()
                                [((bi * cout + oc) * h + y) * wd + xx] = acc;
                        }
                    }
                }
            }
            out
        }
        check("conv2d == naive", 20, |rng| {
            let b = gen_range(rng, 1, 2);
            let cin = gen_range(rng, 1, 3);
            let cout = gen_range(rng, 1, 3);
            let h = gen_range(rng, 3, 6);
            let wd = gen_range(rng, 3, 6);
            let x = Tensor::new(&[b, cin, h, wd], gen_vec(rng, b * cin * h * wd, 1.0))
                .unwrap();
            let w = Tensor::new(&[cout, cin, 3, 3], gen_vec(rng, cout * cin * 9, 1.0))
                .unwrap();
            let bias = gen_vec(rng, cout, 1.0);
            let fast = x.conv2d_same(&w, &bias).unwrap();
            let slow = naive(&x, &w, &bias);
            prop_assert_close(fast.data(), slow.data(), 1e-4)?;
            // the shipped direct-loop reference must agree too
            let direct = x.conv2d_same_naive(&w, &bias).unwrap();
            prop_assert_close(direct.data(), slow.data(), 1e-4)
        });
    }

    #[test]
    fn depth_cat_appends_channel() {
        let x = Tensor::zeros(&[2, 3, 4, 4]);
        let y = x.depth_cat(0.5).unwrap();
        assert_eq!(y.shape(), &[2, 4, 4, 4]);
        // last channel of each batch element is the constant
        for bi in 0..2 {
            let base = (bi * 4 + 3) * 16;
            assert!(y.data()[base..base + 16].iter().all(|&v| v == 0.5));
        }
    }

    #[test]
    fn argmax_rows() {
        let t = Tensor::new(&[2, 3], vec![0.1, 0.9, 0.2, 5.0, -1.0, 3.0]).unwrap();
        assert_eq!(t.argmax_rows().unwrap(), vec![1, 0]);
    }

    #[test]
    fn shape_errors() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[3, 3]);
        assert!(a.add(&b).is_err());
        assert!(a.matmul(&a).is_err());
        assert!(Tensor::zeros(&[4]).argmax_rows().is_err());
    }

    #[test]
    fn matmul_into_matches_pure_and_overwrites_stale() {
        check("matmul_into == matmul", 40, |rng| {
            let (m, k, n) = (
                gen_range(rng, 1, 7),
                gen_range(rng, 1, 7),
                gen_range(rng, 1, 7),
            );
            let a = Tensor::new(&[m, k], gen_vec(rng, m * k, 1.0)).unwrap();
            let b = Tensor::new(&[k, n], gen_vec(rng, k * n, 1.0)).unwrap();
            let pure = a.matmul(&b).unwrap();
            // stale garbage in out must not leak through
            let mut out = Tensor::full(&[m, n], f32::NAN);
            a.matmul_into(&b, &mut out).unwrap();
            if out.data() != pure.data() {
                return Err("matmul_into diverged from matmul".into());
            }
            Ok(())
        });
    }

    #[test]
    fn matmul_into_shape_checked() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[3, 4]);
        let mut bad = Tensor::zeros(&[2, 5]);
        assert!(a.matmul_into(&b, &mut bad).is_err());
    }

    #[test]
    fn sparse_rows_still_skip_dense_rows_exact() {
        // a row that's mostly zeros and a dense row must both agree with a
        // plain triple loop
        let a = Tensor::new(
            &[2, 4],
            vec![0.0, 0.0, 0.0, 2.0, 1.0, -1.0, 0.5, 0.25],
        )
        .unwrap();
        let b = Tensor::new(
            &[4, 2],
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0],
        )
        .unwrap();
        let c = a.matmul(&b).unwrap();
        let mut want = vec![0.0f32; 4];
        for i in 0..2 {
            for j in 0..2 {
                for kk in 0..4 {
                    want[i * 2 + j] += a.data()[i * 4 + kk] * b.data()[kk * 2 + j];
                }
            }
        }
        assert_eq!(c.data(), &want[..]);
    }

    #[test]
    fn parallel_matmul_bit_identical() {
        use crate::util::threadpool::ThreadPool;
        use std::sync::Arc;
        // big enough to clear PAR_MIN_MACS: 64*64*64 = 262144 mul-adds
        let mut rng = crate::util::prng::Rng::new(11);
        let a = Tensor::new(&[64, 64], gen_vec(&mut rng, 64 * 64, 1.0)).unwrap();
        let b = Tensor::new(&[64, 64], gen_vec(&mut rng, 64 * 64, 1.0)).unwrap();
        let serial = a.matmul(&b).unwrap();
        set_matmul_pool(Arc::new(ThreadPool::new(4)));
        let parallel = a.matmul(&b).unwrap();
        clear_matmul_pool();
        assert_eq!(serial.data(), parallel.data());
    }

    #[test]
    fn transposed_matmuls_match_explicit_transpose() {
        fn transpose(t: &Tensor) -> Tensor {
            let (m, n) = (t.shape()[0], t.shape()[1]);
            Tensor::from_fn(&[n, m], |i| {
                let (r, c) = (i / m, i % m);
                t.data()[c * n + r]
            })
        }
        check("tn/nt == transpose + matmul", 40, |rng| {
            let (m, k, n) = (
                gen_range(rng, 1, 7),
                gen_range(rng, 1, 7),
                gen_range(rng, 1, 7),
            );
            let a = Tensor::new(&[m, k], gen_vec(rng, m * k, 1.0)).unwrap();
            let b = Tensor::new(&[m, n], gen_vec(rng, m * n, 1.0)).unwrap();
            let c = Tensor::new(&[n, k], gen_vec(rng, n * k, 1.0)).unwrap();
            // tn: aᵀ b == transpose(a) @ b, bit-identical (same gemm)
            let tn = a.matmul_tn(&b).unwrap();
            let tn_ref = transpose(&a).matmul(&b).unwrap();
            if tn.data() != tn_ref.data() {
                return Err("matmul_tn diverged from transpose+matmul".into());
            }
            // nt: a cᵀ == a @ transpose(c)
            let nt = a.matmul_nt(&c).unwrap();
            let nt_ref = a.matmul(&transpose(&c)).unwrap();
            if nt.data() != nt_ref.data() {
                return Err("matmul_nt diverged from transpose+matmul".into());
            }
            Ok(())
        });
    }

    #[test]
    fn transposed_matmuls_shape_checked_and_overwrite_stale() {
        let a = Tensor::new(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let b = Tensor::new(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let mut ws = Workspace::new();
        let mut out = Tensor::full(&[3, 2], f32::NAN);
        a.matmul_tn_into(&b, &mut out, &mut ws).unwrap();
        assert!(out.data().iter().all(|v| v.is_finite()));
        let mut bad = Tensor::zeros(&[2, 2]);
        assert!(a.matmul_tn_into(&b, &mut bad, &mut ws).is_err());
        assert!(b.matmul_nt_into(&a, &mut bad, &mut ws).is_err()); // inner 2 vs 3
    }

    #[test]
    fn col_sums_known_values() {
        let t = Tensor::new(&[2, 3], vec![1.0, 2.0, 3.0, 10.0, 20.0, 30.0]).unwrap();
        let mut out = vec![f32::NAN; 3];
        t.col_sums_into(&mut out).unwrap();
        assert_eq!(out, vec![11.0, 22.0, 33.0]);
        let mut short = vec![0.0; 2];
        assert!(t.col_sums_into(&mut short).is_err());
    }

    #[test]
    fn inplace_twins_match_pure() {
        check("inplace == pure", 40, |rng| {
            let (m, n) = (gen_range(rng, 1, 6), gen_range(rng, 1, 6));
            let t = Tensor::new(&[m, n], gen_vec(rng, m * n, 1.0)).unwrap();
            let bias = gen_vec(rng, n, 1.0);

            let mut ip = t.clone();
            ip.add_bias_rows_inplace(&bias).unwrap();
            if ip.data() != t.add_bias_rows(&bias).unwrap().data() {
                return Err("add_bias_rows_inplace diverged".into());
            }

            let mut mp = t.clone();
            mp.map_inplace(|x| x.tanh());
            if mp.data() != t.map(|x| x.tanh()).data() {
                return Err("map_inplace diverged".into());
            }
            Ok(())
        });
    }

    #[test]
    fn copy_from_and_fill() {
        let src = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let mut dst = Tensor::zeros(&[2, 2]);
        dst.copy_from(&src);
        assert_eq!(dst, src);
        dst.fill(-1.5);
        assert!(dst.data().iter().all(|&v| v == -1.5));
    }

    #[test]
    #[should_panic(expected = "copy_from shape mismatch")]
    fn copy_from_panics_on_shape_mismatch() {
        let src = Tensor::zeros(&[2, 2]);
        let mut dst = Tensor::zeros(&[4]);
        dst.copy_from(&src);
    }

    #[test]
    fn conv_and_depth_cat_into_match_pure_with_reused_workspace() {
        // one workspace across varied shapes: catches stale-buffer bugs
        let mut ws = Workspace::new();
        check("conv2d_same_into == conv2d_same", 15, |rng| {
            let b = gen_range(rng, 1, 2);
            let cin = gen_range(rng, 1, 3);
            let cout = gen_range(rng, 1, 3);
            let h = gen_range(rng, 3, 6);
            let wd = gen_range(rng, 3, 6);
            let x = Tensor::new(&[b, cin, h, wd], gen_vec(rng, b * cin * h * wd, 1.0))
                .unwrap();
            let w = Tensor::new(&[cout, cin, 3, 3], gen_vec(rng, cout * cin * 9, 1.0))
                .unwrap();
            let bias = gen_vec(rng, cout, 1.0);
            let pure = x.conv2d_same(&w, &bias).unwrap();
            let mut out = Tensor::full(&[b, cout, h, wd], f32::NAN);
            x.conv2d_same_into(&w, &bias, &mut out, &mut ws).unwrap();
            if out.data() != pure.data() {
                return Err("conv2d_same_into diverged".into());
            }

            let cat = x.depth_cat(0.75).unwrap();
            let mut cat_out = Tensor::full(&[b, cin + 1, h, wd], f32::NAN);
            x.depth_cat_into(0.75, &mut cat_out).unwrap();
            if cat_out.data() != cat.data() {
                return Err("depth_cat_into diverged".into());
            }
            Ok(())
        });
        assert!(ws.pooled_bufs() > 0, "conv returned its scratch to the pool");
    }
}
