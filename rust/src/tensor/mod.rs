//! Minimal owned f32 tensor.
//!
//! Just enough n-d array to run the exported networks natively (dense
//! matmul, SAME-padding 3×3 conv, elementwise ops) — the native path backs
//! the benches' dense parameter sweeps so they don't pay a PJRT compile per
//! (solver, K) point. Row-major, contiguous, f32 only.

use crate::{Error, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            return Err(Error::Shape(format!(
                "shape {shape:?} needs {numel} elements, got {}",
                data.len()
            )));
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    pub fn eye(n: usize) -> Self {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> f32) -> Self {
        let numel = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: (0..numel).map(|i| f(i)).collect(),
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn reshape(&self, shape: &[usize]) -> Result<Tensor> {
        Tensor::new(shape, self.data.clone())
    }

    /// (rows, cols) view of a 2-D tensor.
    fn dims2(&self) -> Result<(usize, usize)> {
        match self.shape.as_slice() {
            [m, n] => Ok((*m, *n)),
            s => Err(Error::Shape(format!("expected 2-d, got {s:?}"))),
        }
    }

    // -- elementwise -------------------------------------------------------

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Tensor> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!(
                "shape mismatch {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        Ok(Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        })
    }

    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a - b)
    }

    pub fn scale(&self, k: f32) -> Tensor {
        self.map(|x| k * x)
    }

    /// self += k * other, in place — the solver hot loop's axpy.
    pub fn axpy(&mut self, k: f32, other: &Tensor) -> Result<()> {
        if self.shape != other.shape {
            return Err(Error::Shape(format!(
                "axpy shape mismatch {:?} vs {:?}",
                self.shape, other.shape
            )));
        }
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += k * b;
        }
        Ok(())
    }

    // -- linear algebra ----------------------------------------------------

    /// Dense matmul (m,k) x (k,n) -> (m,n).
    ///
    /// ikj loop order (row-major friendly) with the N axis tiled so the
    /// output strip stays L1-resident across the K loop — matters for the
    /// wide-N products the im2col conv path generates (see EXPERIMENTS.md
    /// §Perf).
    pub fn matmul(&self, other: &Tensor) -> Result<Tensor> {
        let (m, k) = self.dims2()?;
        let (k2, n) = other.dims2()?;
        if k != k2 {
            return Err(Error::Shape(format!(
                "matmul inner dim {k} vs {k2}"
            )));
        }
        const N_BLK: usize = 1024; // 4 KiB output strip per row
        let mut out = vec![0.0f32; m * n];
        for jb in (0..n).step_by(N_BLK) {
            let je = (jb + N_BLK).min(n);
            for i in 0..m {
                let arow = &self.data[i * k..(i + 1) * k];
                let orow = &mut out[i * n + jb..i * n + je];
                for (kk, &a) in arow.iter().enumerate() {
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &other.data[kk * n + jb..kk * n + je];
                    for (o, &b) in orow.iter_mut().zip(brow) {
                        *o += a * b;
                    }
                }
            }
        }
        Tensor::new(&[m, n], out)
    }

    /// Add a length-n bias row to every row of an (m, n) tensor.
    pub fn add_bias_rows(&self, bias: &[f32]) -> Result<Tensor> {
        let (m, n) = self.dims2()?;
        if bias.len() != n {
            return Err(Error::Shape(format!(
                "bias len {} vs cols {n}",
                bias.len()
            )));
        }
        let mut out = self.data.clone();
        for i in 0..m {
            for j in 0..n {
                out[i * n + j] += bias[j];
            }
        }
        Tensor::new(&[m, n], out)
    }

    /// Horizontally concatenate 2-D tensors (same row count).
    pub fn hcat(parts: &[&Tensor]) -> Result<Tensor> {
        let m = parts
            .first()
            .ok_or_else(|| Error::Shape("hcat of nothing".into()))?
            .dims2()?
            .0;
        let mut widths = Vec::with_capacity(parts.len());
        for p in parts {
            let (pm, pn) = p.dims2()?;
            if pm != m {
                return Err(Error::Shape("hcat row mismatch".into()));
            }
            widths.push(pn);
        }
        let n: usize = widths.iter().sum();
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            let mut col = 0;
            for (p, &w) in parts.iter().zip(&widths) {
                out[i * n + col..i * n + col + w]
                    .copy_from_slice(&p.data[i * w..(i + 1) * w]);
                col += w;
            }
        }
        Tensor::new(&[m, n], out)
    }

    // -- conv (NCHW, OIHW, stride 1, SAME padding) --------------------------

    /// 2-D convolution matching `jax.lax.conv_general_dilated` with NCHW
    /// input, OIHW weights, stride 1, SAME padding — the only conv the
    /// exported models use.
    ///
    /// im2col + matmul: the patch matrix (B·H·W, Cin·kh·kw) is built once
    /// and contracted against the reshaped weights, putting the whole
    /// convolution on the (vectorised) matmul path. ~4× over the direct
    /// loop nest on the image-task shapes (see EXPERIMENTS.md §Perf);
    /// `conv2d_same_naive` keeps the reference implementation for the
    /// property tests.
    pub fn conv2d_same(&self, w: &Tensor, bias: &[f32]) -> Result<Tensor> {
        let (b, cin, h, wd) = match self.shape.as_slice() {
            [b, c, h, w] => (*b, *c, *h, *w),
            s => return Err(Error::Shape(format!("conv input {s:?}"))),
        };
        let (cout, cin2, kh, kw) = match w.shape.as_slice() {
            [o, i, kh, kw] => (*o, *i, *kh, *kw),
            s => return Err(Error::Shape(format!("conv weight {s:?}"))),
        };
        if cin != cin2 {
            return Err(Error::Shape(format!("conv channels {cin} vs {cin2}")));
        }
        if bias.len() != cout {
            return Err(Error::Shape("conv bias length".into()));
        }
        let (ph, pw) = ((kh - 1) / 2, (kw - 1) / 2);
        let patch = cin * kh * kw;
        let plane = h * wd;

        // im2col, PATCH-MAJOR: row p of `cols` holds patch entry p for every
        // output pixel (b-major). Writes are contiguous x-runs and the
        // subsequent matmul (cout, patch) @ (patch, B·plane) streams the
        // wide N axis through the vector units.
        let n_pix = b * plane;
        let mut cols = vec![0.0f32; patch * n_pix];
        for ic in 0..cin {
            for ky in 0..kh {
                for kx in 0..kw {
                    let p = (ic * kh + ky) * kw + kx;
                    let prow = p * n_pix;
                    for bi in 0..b {
                        let ibase = (bi * cin + ic) * plane;
                        let obase = prow + bi * plane;
                        // y such that iy = y + ky - ph stays in [0, h)
                        let y0 = ph.saturating_sub(ky);
                        let y1 = (h + ph - ky).min(h);
                        for y in y0..y1 {
                            let iy = y + ky - ph;
                            let x0 = pw.saturating_sub(kx);
                            let x1 = (wd + pw - kx).min(wd);
                            let src = ibase + iy * wd + (x0 + kx) - pw;
                            let dst = obase + y * wd + x0;
                            let len = x1 - x0;
                            let (s, d) = (src, dst);
                            cols[d..d + len]
                                .copy_from_slice(&self.data[s..s + len]);
                        }
                    }
                }
            }
        }

        // (cout, patch) @ (patch, B·plane): OIHW weights flatten directly
        // into the LHS.
        let wt = Tensor::new(&[cout, patch], w.data.clone())?;
        let cols_t = Tensor::new(&[patch, n_pix], cols)?;
        let prod = wt.matmul(&cols_t)?; // (cout, B·plane)

        // (cout, B·plane) → NCHW + bias (plane rows stay contiguous)
        let mut out = vec![0.0f32; b * cout * plane];
        for oc in 0..cout {
            for bi in 0..b {
                let src = oc * n_pix + bi * plane;
                let dst = (bi * cout + oc) * plane;
                let bias_v = bias[oc];
                for i in 0..plane {
                    out[dst + i] = prod.data[src + i] + bias_v;
                }
            }
        }
        Tensor::new(&[b, cout, h, wd], out)
    }

    /// Reference direct-loop convolution (kept for property-testing the
    /// im2col path).
    pub fn conv2d_same_naive(&self, w: &Tensor, bias: &[f32]) -> Result<Tensor> {
        let (b, cin, h, wd) = match self.shape.as_slice() {
            [b, c, h, w] => (*b, *c, *h, *w),
            s => return Err(Error::Shape(format!("conv input {s:?}"))),
        };
        let (cout, cin2, kh, kw) = match w.shape.as_slice() {
            [o, i, kh, kw] => (*o, *i, *kh, *kw),
            s => return Err(Error::Shape(format!("conv weight {s:?}"))),
        };
        if cin != cin2 {
            return Err(Error::Shape(format!(
                "conv channels {cin} vs {cin2}"
            )));
        }
        if bias.len() != cout {
            return Err(Error::Shape("conv bias length".into()));
        }
        let (ph, pw) = ((kh - 1) / 2, (kw - 1) / 2);
        let mut out = vec![0.0f32; b * cout * h * wd];
        for bi in 0..b {
            for oc in 0..cout {
                let obase = ((bi * cout) + oc) * h * wd;
                for ic in 0..cin {
                    let ibase = ((bi * cin) + ic) * h * wd;
                    let wbase = ((oc * cin) + ic) * kh * kw;
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let wv = w.data[wbase + ky * kw + kx];
                            if wv == 0.0 {
                                continue;
                            }
                            // input row range that keeps (y+ky-ph) in bounds
                            let y0 = ph.saturating_sub(ky);
                            let y1 = (h + ph - ky).min(h);
                            for y in y0..y1 {
                                let iy = y + ky - ph;
                                let x0 = pw.saturating_sub(kx);
                                let x1 = (wd + pw - kx).min(wd);
                                let irow = ibase + iy * wd;
                                let orow = obase + y * wd;
                                for x in x0..x1 {
                                    let ix = x + kx - pw;
                                    out[orow + x] += wv * self.data[irow + ix];
                                }
                            }
                        }
                    }
                }
                let obase = ((bi * cout) + oc) * h * wd;
                for v in &mut out[obase..obase + h * wd] {
                    *v += bias[oc];
                }
            }
        }
        Tensor::new(&[b, cout, h, wd], out)
    }

    /// Append a constant-valued channel (the DepthCat op).
    pub fn depth_cat(&self, value: f32) -> Result<Tensor> {
        let (b, c, h, w) = match self.shape.as_slice() {
            [b, c, h, w] => (*b, *c, *h, *w),
            s => return Err(Error::Shape(format!("depth_cat input {s:?}"))),
        };
        let plane = h * w;
        let mut out = Vec::with_capacity(b * (c + 1) * plane);
        for bi in 0..b {
            let base = bi * c * plane;
            out.extend_from_slice(&self.data[base..base + c * plane]);
            out.extend(std::iter::repeat(value).take(plane));
        }
        Tensor::new(&[b, c + 1, h, w], out)
    }

    // -- reductions ---------------------------------------------------------

    pub fn frobenius_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Row-wise argmax of a 2-D tensor.
    pub fn argmax_rows(&self) -> Result<Vec<usize>> {
        let (m, n) = self.dims2()?;
        Ok((0..m)
            .map(|i| {
                let row = &self.data[i * n..(i + 1) * n];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j)
                    .unwrap()
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propkit::{check, gen_range, gen_vec, prop_assert_close};

    #[test]
    fn construct_and_shape_check() {
        assert!(Tensor::new(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(&[2, 3], vec![0.0; 5]).is_err());
        assert_eq!(Tensor::zeros(&[4]).numel(), 4);
    }

    #[test]
    fn matmul_identity_property() {
        check("A @ I == A", 50, |rng| {
            let m = gen_range(rng, 1, 8);
            let n = gen_range(rng, 1, 8);
            let a = Tensor::new(&[m, n], gen_vec(rng, m * n, 1.0)).unwrap();
            let prod = a.matmul(&Tensor::eye(n)).unwrap();
            prop_assert_close(prod.data(), a.data(), 1e-6)
        });
    }

    #[test]
    fn matmul_known_values() {
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::new(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_associativity_property() {
        check("(AB)C == A(BC)", 30, |rng| {
            let (m, k, n, p) = (
                gen_range(rng, 1, 5),
                gen_range(rng, 1, 5),
                gen_range(rng, 1, 5),
                gen_range(rng, 1, 5),
            );
            let a = Tensor::new(&[m, k], gen_vec(rng, m * k, 1.0)).unwrap();
            let b = Tensor::new(&[k, n], gen_vec(rng, k * n, 1.0)).unwrap();
            let c = Tensor::new(&[n, p], gen_vec(rng, n * p, 1.0)).unwrap();
            let left = a.matmul(&b).unwrap().matmul(&c).unwrap();
            let right = a.matmul(&b.matmul(&c).unwrap()).unwrap();
            prop_assert_close(left.data(), right.data(), 1e-4)
        });
    }

    #[test]
    fn axpy_matches_scale_add() {
        check("axpy == add(scale)", 40, |rng| {
            let n = gen_range(rng, 1, 32);
            let a = Tensor::new(&[n], gen_vec(rng, n, 1.0)).unwrap();
            let b = Tensor::new(&[n], gen_vec(rng, n, 1.0)).unwrap();
            let k = rng.normal_f32();
            let mut via_axpy = a.clone();
            via_axpy.axpy(k, &b).unwrap();
            let via_ops = a.add(&b.scale(k)).unwrap();
            prop_assert_close(via_axpy.data(), via_ops.data(), 1e-6)
        });
    }

    #[test]
    fn hcat_widths() {
        let a = Tensor::new(&[2, 1], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(&[2, 2], vec![3.0, 4.0, 5.0, 6.0]).unwrap();
        let c = Tensor::hcat(&[&a, &b]).unwrap();
        assert_eq!(c.shape(), &[2, 3]);
        assert_eq!(c.data(), &[1.0, 3.0, 4.0, 2.0, 5.0, 6.0]);
        assert!(Tensor::hcat(&[]).is_err());
    }

    #[test]
    fn conv2d_identity_kernel() {
        // 1x1 kernel with weight 1 == identity
        let x = Tensor::new(&[1, 1, 3, 3], (1..=9).map(|v| v as f32).collect()).unwrap();
        let w = Tensor::new(&[1, 1, 1, 1], vec![1.0]).unwrap();
        let y = x.conv2d_same(&w, &[0.0]).unwrap();
        assert_eq!(y.data(), x.data());
    }

    #[test]
    fn conv2d_averaging_kernel_known() {
        // 3x3 ones kernel on a constant image: interior = 9, corners = 4
        let x = Tensor::full(&[1, 1, 4, 4], 1.0);
        let w = Tensor::full(&[1, 1, 3, 3], 1.0);
        let y = x.conv2d_same(&w, &[0.0]).unwrap();
        assert_eq!(y.shape(), &[1, 1, 4, 4]);
        assert_eq!(y.data()[0], 4.0); // corner
        assert_eq!(y.data()[5], 9.0); // interior
    }

    #[test]
    fn conv2d_matches_naive_property() {
        fn naive(x: &Tensor, w: &Tensor, bias: &[f32]) -> Tensor {
            let (b, cin, h, wd) = (
                x.shape()[0],
                x.shape()[1],
                x.shape()[2],
                x.shape()[3],
            );
            let (cout, _, kh, kw) = (
                w.shape()[0],
                w.shape()[1],
                w.shape()[2],
                w.shape()[3],
            );
            let (ph, pw) = ((kh - 1) / 2, (kw - 1) / 2);
            let mut out = Tensor::zeros(&[b, cout, h, wd]);
            for bi in 0..b {
                for oc in 0..cout {
                    for y in 0..h {
                        for xx in 0..wd {
                            let mut acc = bias[oc];
                            for ic in 0..cin {
                                for ky in 0..kh {
                                    for kx in 0..kw {
                                        let iy = y as isize + ky as isize - ph as isize;
                                        let ix = xx as isize + kx as isize - pw as isize;
                                        if iy < 0
                                            || ix < 0
                                            || iy >= h as isize
                                            || ix >= wd as isize
                                        {
                                            continue;
                                        }
                                        let xi = ((bi * cin + ic) * h
                                            + iy as usize)
                                            * wd
                                            + ix as usize;
                                        let wi = ((oc * cin + ic) * kh + ky) * kw
                                            + kx;
                                        acc += x.data()[xi] * w.data()[wi];
                                    }
                                }
                            }
                            out.data_mut()
                                [((bi * cout + oc) * h + y) * wd + xx] = acc;
                        }
                    }
                }
            }
            out
        }
        check("conv2d == naive", 20, |rng| {
            let b = gen_range(rng, 1, 2);
            let cin = gen_range(rng, 1, 3);
            let cout = gen_range(rng, 1, 3);
            let h = gen_range(rng, 3, 6);
            let wd = gen_range(rng, 3, 6);
            let x = Tensor::new(&[b, cin, h, wd], gen_vec(rng, b * cin * h * wd, 1.0))
                .unwrap();
            let w = Tensor::new(&[cout, cin, 3, 3], gen_vec(rng, cout * cin * 9, 1.0))
                .unwrap();
            let bias = gen_vec(rng, cout, 1.0);
            let fast = x.conv2d_same(&w, &bias).unwrap();
            let slow = naive(&x, &w, &bias);
            prop_assert_close(fast.data(), slow.data(), 1e-4)?;
            // the shipped direct-loop reference must agree too
            let direct = x.conv2d_same_naive(&w, &bias).unwrap();
            prop_assert_close(direct.data(), slow.data(), 1e-4)
        });
    }

    #[test]
    fn depth_cat_appends_channel() {
        let x = Tensor::zeros(&[2, 3, 4, 4]);
        let y = x.depth_cat(0.5).unwrap();
        assert_eq!(y.shape(), &[2, 4, 4, 4]);
        // last channel of each batch element is the constant
        for bi in 0..2 {
            let base = (bi * 4 + 3) * 16;
            assert!(y.data()[base..base + 16].iter().all(|&v| v == 0.5));
        }
    }

    #[test]
    fn argmax_rows() {
        let t = Tensor::new(&[2, 3], vec![0.1, 0.9, 0.2, 5.0, -1.0, 3.0]).unwrap();
        assert_eq!(t.argmax_rows().unwrap(), vec![1, 0]);
    }

    #[test]
    fn shape_errors() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[3, 3]);
        assert!(a.add(&b).is_err());
        assert!(a.matmul(&a).is_err());
        assert!(Tensor::zeros(&[4]).argmax_rows().is_err());
    }
}
