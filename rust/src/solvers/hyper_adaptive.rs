//! Adaptive hypersolver stepping (paper §6, "Beyond fixed-step explicit
//! hypersolvers").
//!
//! The hypersolver's own correction term is (by Thm 1) an estimate of the
//! base solver's local truncation error: ‖ε^{p+1} g_ω‖ ≈ e_k. That gives a
//! *free* error estimate — no embedded second solution — so the standard
//! accept/reject + PI controller machinery applies to the hypersolved
//! scheme directly. The accepted update still ADDS the correction, so the
//! scheme keeps the O(δ ε^{p+1}) local error while adapting ε to the
//! dynamics.

use crate::ode::VectorField;
use crate::solvers::adaptive::{scaled_err_rms, AdaptiveOpts, AdaptiveResult};
use crate::solvers::butcher::Tableau;
use crate::solvers::fixed::{combine_into, rk_stages_core};
use crate::solvers::hyper::HyperNet;
use crate::solvers::workspace::RkWorkspace;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Adaptive integration of the hypersolved scheme: the ε^{p+1}·g_ω term is
/// both the error estimate (step control) and the applied correction.
/// Wrapper over [`odeint_hyper_adaptive_ws`] with a throwaway workspace.
pub fn odeint_hyper_adaptive<F: VectorField + ?Sized, G: HyperNet + ?Sized>(
    f: &F,
    g: &G,
    z0: &Tensor,
    s_span: (f32, f32),
    tab: &Tableau,
    opts: &AdaptiveOpts,
) -> Result<AdaptiveResult> {
    let mut ws = RkWorkspace::new();
    odeint_hyper_adaptive_ws(f, g, z0, s_span, tab, opts, &mut ws)
}

/// [`odeint_hyper_adaptive`] on a caller-held workspace (allocation-free
/// per step once warm).
pub fn odeint_hyper_adaptive_ws<F: VectorField + ?Sized, G: HyperNet + ?Sized>(
    f: &F,
    g: &G,
    z0: &Tensor,
    s_span: (f32, f32),
    tab: &Tableau,
    opts: &AdaptiveOpts,
    ws: &mut RkWorkspace,
) -> Result<AdaptiveResult> {
    let (s0, s1) = s_span;
    let direction = if s1 >= s0 { 1.0f32 } else { -1.0 };
    let span = (s1 - s0).abs();
    if span == 0.0 {
        return Ok(AdaptiveResult {
            z: z0.clone(),
            nfe: 0,
            accepted: 0,
            rejected: 0,
        });
    }
    let exponent = -1.0 / (tab.order + 1) as f32;

    ws.ensure(z0.shape(), tab.stages());
    ws.ensure_corr();
    ws.z_cur.copy_from(z0);
    let mut progress = 0.0f32;
    let mut eps = span * opts.first_step_frac;
    let (mut nfe, mut accepted, mut rejected) = (0u64, 0u64, 0u64);

    for _ in 0..opts.max_steps {
        if progress >= span * (1.0 - 1e-6) {
            return Ok(AdaptiveResult {
                z: ws.state().clone(),
                nfe,
                accepted,
                rejected,
            });
        }
        let eps_c = eps.min(span - progress);
        let s_abs = s0 + direction * progress;
        let h = direction * eps_c;
        rk_stages_core(f, tab, s_abs, h, ws)?;
        nfe += tab.stages() as u64;
        let p = tab.stages();
        combine_into(&ws.stages[..p], &tab.b, &mut ws.acc)?;
        g.eval_into(h, s_abs, &ws.z_cur, &ws.stages[0], &mut ws.corr, &mut ws.scratch);
        let corr_scale = h.abs().powi(tab.order as i32 + 1);

        // error estimate: the correction magnitude, in the mixed abs/rel norm
        ws.z_next.copy_from(&ws.z_cur);
        ws.z_next.axpy(h, &ws.acc)?;
        let err = {
            let corr = ws.corr.data();
            scaled_err_rms(&ws.z_next, &ws.z_cur, opts.rtol, opts.atol, |i| {
                corr_scale * corr[i]
            })
        };

        let accept = err <= 1.0;
        let factor = (opts.safety * err.max(1e-10).powf(exponent))
            .clamp(opts.min_factor, opts.max_factor);
        eps = (eps_c * factor).clamp(1e-6 * span, span);
        if accept {
            // apply the correction on acceptance: hypersolved update (eq. 5)
            ws.z_next
                .axpy(direction.powi(tab.order as i32 + 1) * corr_scale, &ws.corr)?;
            ws.swap();
            progress += eps_c;
            accepted += 1;
        } else {
            rejected += 1;
        }
    }
    Err(Error::Other(format!(
        "hyper_adaptive: max_steps={} exhausted",
        opts.max_steps
    )))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::Rotation;
    use crate::solvers::adaptive::dopri5;

    #[test]
    fn exact_taylor_g_integrates_accurately() {
        let omega = 1.0f32;
        let f = Rotation { omega };
        // g = ½A²z: exact Euler residual leading term
        let g = move |_e: f32, _s: f32, z: &Tensor, _dz: &Tensor| {
            z.scale(-0.5 * omega * omega)
        };
        let z0 = Tensor::new(&[1, 2], vec![1.0, 0.0]).unwrap();
        let r = odeint_hyper_adaptive(
            &f,
            &g,
            &z0,
            (0.0, 1.0),
            &Tableau::euler(),
            &AdaptiveOpts::with_tol(1e-4),
        )
        .unwrap();
        let exact = f.exact(&z0, 1.0);
        let err = r.z.sub(&exact).unwrap().frobenius_norm();
        assert!(err < 5e-3, "err {err}");
        assert!(r.accepted > 0);
        // the estimator costs nothing: exactly 1 NFE per attempted step,
        // vs dopri5's 7 (a 2nd-order scheme takes more steps on a smooth
        // field, but each is 7x cheaper in f evaluations)
        assert_eq!(r.nfe, r.accepted + r.rejected);
        let d5 = dopri5(&f, &z0, (0.0, 1.0), &AdaptiveOpts::with_tol(1e-4)).unwrap();
        let nfe_per_step_d5 = d5.nfe as f64 / (d5.accepted + d5.rejected) as f64;
        assert_eq!(nfe_per_step_d5, 7.0);
    }

    #[test]
    fn zero_g_accepts_everything() {
        // with g ≡ 0 the error estimate is 0: every step accepted, max size
        let f = Rotation { omega: 1.0 };
        let g = |_e: f32, _s: f32, z: &Tensor, _dz: &Tensor| Tensor::zeros(z.shape());
        let z0 = Tensor::new(&[1, 2], vec![1.0, 0.0]).unwrap();
        let r = odeint_hyper_adaptive(
            &f,
            &g,
            &z0,
            (0.0, 1.0),
            &Tableau::euler(),
            &AdaptiveOpts::with_tol(1e-6),
        )
        .unwrap();
        assert_eq!(r.rejected, 0);
    }

    #[test]
    fn backward_span() {
        let omega = 1.0f32;
        let f = Rotation { omega };
        let g = move |_e: f32, _s: f32, z: &Tensor, _dz: &Tensor| {
            z.scale(-0.5 * omega * omega)
        };
        let z0 = Tensor::new(&[1, 2], vec![0.2, -0.9]).unwrap();
        let fwd = odeint_hyper_adaptive(
            &f, &g, &z0, (0.0, 1.0), &Tableau::euler(),
            &AdaptiveOpts::with_tol(1e-5),
        )
        .unwrap();
        let back = odeint_hyper_adaptive(
            &f, &g, &fwd.z, (1.0, 0.0), &Tableau::euler(),
            &AdaptiveOpts::with_tol(1e-5),
        )
        .unwrap();
        let err = back.z.sub(&z0).unwrap().frobenius_norm();
        assert!(err < 2e-2, "round trip {err}");
    }
}
