//! Adaptive hypersolver stepping (paper §6, "Beyond fixed-step explicit
//! hypersolvers").
//!
//! The hypersolver's own correction term is (by Thm 1) an estimate of the
//! base solver's local truncation error: ‖ε^{p+1} g_ω‖ ≈ e_k. That gives a
//! *free* error estimate — no embedded second solution — so the standard
//! accept/reject + PI controller machinery applies to the hypersolved
//! scheme directly. The accepted update still ADDS the correction, so the
//! scheme keeps the O(δ ε^{p+1}) local error while adapting ε to the
//! dynamics.

use crate::ode::VectorField;
use crate::solvers::adaptive::{AdaptiveOpts, AdaptiveResult};
use crate::solvers::butcher::Tableau;
use crate::solvers::fixed::{combine, rk_stages};
use crate::solvers::hyper::HyperNet;
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Adaptive integration of the hypersolved scheme: the ε^{p+1}·g_ω term is
/// both the error estimate (step control) and the applied correction.
pub fn odeint_hyper_adaptive<F: VectorField + ?Sized, G: HyperNet + ?Sized>(
    f: &F,
    g: &G,
    z0: &Tensor,
    s_span: (f32, f32),
    tab: &Tableau,
    opts: &AdaptiveOpts,
) -> Result<AdaptiveResult> {
    let (s0, s1) = s_span;
    let direction = if s1 >= s0 { 1.0f32 } else { -1.0 };
    let span = (s1 - s0).abs();
    if span == 0.0 {
        return Ok(AdaptiveResult {
            z: z0.clone(),
            nfe: 0,
            accepted: 0,
            rejected: 0,
        });
    }
    let exponent = -1.0 / (tab.order + 1) as f32;

    let mut progress = 0.0f32;
    let mut z = z0.clone();
    let mut eps = span * opts.first_step_frac;
    let (mut nfe, mut accepted, mut rejected) = (0u64, 0u64, 0u64);

    for _ in 0..opts.max_steps {
        if progress >= span * (1.0 - 1e-6) {
            return Ok(AdaptiveResult {
                z,
                nfe,
                accepted,
                rejected,
            });
        }
        let eps_c = eps.min(span - progress);
        let s_abs = s0 + direction * progress;
        let h = direction * eps_c;
        let stages = rk_stages(f, tab, s_abs, &z, h)?;
        nfe += tab.stages() as u64;
        let psi = combine(z.shape(), &stages, &tab.b)?;
        let corr = g.eval(h, s_abs, &z, &stages[0]);
        let corr_scale = h.abs().powi(tab.order as i32 + 1);

        // error estimate: the correction magnitude, in the mixed abs/rel norm
        let mut z_new = z.clone();
        z_new.axpy(h, &psi)?;
        let err = {
            let n = z_new.numel() as f32;
            let mut acc = 0.0f64;
            for i in 0..z_new.numel() {
                let scale = opts.atol
                    + opts.rtol * z_new.data()[i].abs().max(z.data()[i].abs());
                let e = corr_scale * corr.data()[i] / scale;
                acc += (e * e) as f64;
            }
            ((acc / n as f64) as f32).sqrt()
        };

        let accept = err <= 1.0;
        let factor = (opts.safety * err.max(1e-10).powf(exponent))
            .clamp(opts.min_factor, opts.max_factor);
        eps = (eps_c * factor).clamp(1e-6 * span, span);
        if accept {
            // apply the correction on acceptance: hypersolved update (eq. 5)
            z_new.axpy(direction.powi(tab.order as i32 + 1) * corr_scale, &corr)?;
            z = z_new;
            progress += eps_c;
            accepted += 1;
        } else {
            rejected += 1;
        }
    }
    Err(Error::Other(format!(
        "hyper_adaptive: max_steps={} exhausted",
        opts.max_steps
    )))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::Rotation;
    use crate::solvers::adaptive::dopri5;

    #[test]
    fn exact_taylor_g_integrates_accurately() {
        let omega = 1.0f32;
        let f = Rotation { omega };
        // g = ½A²z: exact Euler residual leading term
        let g = move |_e: f32, _s: f32, z: &Tensor, _dz: &Tensor| {
            z.scale(-0.5 * omega * omega)
        };
        let z0 = Tensor::new(&[1, 2], vec![1.0, 0.0]).unwrap();
        let r = odeint_hyper_adaptive(
            &f,
            &g,
            &z0,
            (0.0, 1.0),
            &Tableau::euler(),
            &AdaptiveOpts::with_tol(1e-4),
        )
        .unwrap();
        let exact = f.exact(&z0, 1.0);
        let err = r.z.sub(&exact).unwrap().frobenius_norm();
        assert!(err < 5e-3, "err {err}");
        assert!(r.accepted > 0);
        // the estimator costs nothing: exactly 1 NFE per attempted step,
        // vs dopri5's 7 (a 2nd-order scheme takes more steps on a smooth
        // field, but each is 7x cheaper in f evaluations)
        assert_eq!(r.nfe, r.accepted + r.rejected);
        let d5 = dopri5(&f, &z0, (0.0, 1.0), &AdaptiveOpts::with_tol(1e-4)).unwrap();
        let nfe_per_step_d5 = d5.nfe as f64 / (d5.accepted + d5.rejected) as f64;
        assert_eq!(nfe_per_step_d5, 7.0);
    }

    #[test]
    fn zero_g_accepts_everything() {
        // with g ≡ 0 the error estimate is 0: every step accepted, max size
        let f = Rotation { omega: 1.0 };
        let g = |_e: f32, _s: f32, z: &Tensor, _dz: &Tensor| Tensor::zeros(z.shape());
        let z0 = Tensor::new(&[1, 2], vec![1.0, 0.0]).unwrap();
        let r = odeint_hyper_adaptive(
            &f,
            &g,
            &z0,
            (0.0, 1.0),
            &Tableau::euler(),
            &AdaptiveOpts::with_tol(1e-6),
        )
        .unwrap();
        assert_eq!(r.rejected, 0);
    }

    #[test]
    fn backward_span() {
        let omega = 1.0f32;
        let f = Rotation { omega };
        let g = move |_e: f32, _s: f32, z: &Tensor, _dz: &Tensor| {
            z.scale(-0.5 * omega * omega)
        };
        let z0 = Tensor::new(&[1, 2], vec![0.2, -0.9]).unwrap();
        let fwd = odeint_hyper_adaptive(
            &f, &g, &z0, (0.0, 1.0), &Tableau::euler(),
            &AdaptiveOpts::with_tol(1e-5),
        )
        .unwrap();
        let back = odeint_hyper_adaptive(
            &f, &g, &fwd.z, (1.0, 0.0), &Tableau::euler(),
            &AdaptiveOpts::with_tol(1e-5),
        )
        .unwrap();
        let err = back.z.sub(&z0).unwrap().frobenius_norm();
        assert!(err < 2e-2, "round trip {err}");
    }
}
