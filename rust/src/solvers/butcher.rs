//! Explicit Butcher tableaus (paper eq. 3 / Fig. 5).

use crate::{Error, Result};

/// An explicit Runge-Kutta tableau. `a[i]` holds the i entries of stage i's
/// row (strictly lower triangular).
#[derive(Clone, Debug, PartialEq)]
pub struct Tableau {
    pub name: String,
    pub a: Vec<Vec<f32>>,
    pub b: Vec<f32>,
    pub c: Vec<f32>,
    pub order: u32,
    /// Embedded lower-order weights (adaptive pairs only).
    pub b_err: Option<Vec<f32>>,
}

impl Tableau {
    pub fn stages(&self) -> usize {
        self.b.len()
    }

    /// Internal consistency: matching lengths, c_i = Σ_j a_ij, Σ b_i = 1.
    pub fn validate(&self) -> Result<()> {
        let p = self.stages();
        if self.a.len() != p || self.c.len() != p {
            return Err(Error::Other(format!(
                "tableau {}: inconsistent stage counts",
                self.name
            )));
        }
        for (i, row) in self.a.iter().enumerate() {
            if row.len() != i {
                return Err(Error::Other(format!(
                    "tableau {}: row {i} has {} entries",
                    self.name,
                    row.len()
                )));
            }
            let rowsum: f32 = row.iter().sum();
            if (rowsum - self.c[i]).abs() > 1e-5 {
                return Err(Error::Other(format!(
                    "tableau {}: c[{i}] != row sum",
                    self.name
                )));
            }
        }
        let bsum: f32 = self.b.iter().sum();
        if (bsum - 1.0).abs() > 1e-5 {
            return Err(Error::Other(format!(
                "tableau {}: b does not sum to 1",
                self.name
            )));
        }
        if let Some(be) = &self.b_err {
            if be.len() != p {
                return Err(Error::Other(format!(
                    "tableau {}: b_err length",
                    self.name
                )));
            }
        }
        Ok(())
    }

    pub fn euler() -> Tableau {
        Tableau {
            name: "euler".into(),
            a: vec![vec![]],
            b: vec![1.0],
            c: vec![0.0],
            order: 1,
            b_err: None,
        }
    }

    pub fn midpoint() -> Tableau {
        Tableau {
            name: "midpoint".into(),
            a: vec![vec![], vec![0.5]],
            b: vec![0.0, 1.0],
            c: vec![0.0, 0.5],
            order: 2,
            b_err: None,
        }
    }

    pub fn heun() -> Tableau {
        Tableau {
            name: "heun".into(),
            a: vec![vec![], vec![1.0]],
            b: vec![0.5, 0.5],
            c: vec![0.0, 1.0],
            order: 2,
            b_err: None,
        }
    }

    pub fn rk4() -> Tableau {
        Tableau {
            name: "rk4".into(),
            a: vec![vec![], vec![0.5], vec![0.0, 0.5], vec![0.0, 0.0, 1.0]],
            b: vec![1.0 / 6.0, 1.0 / 3.0, 1.0 / 3.0, 1.0 / 6.0],
            c: vec![0.0, 0.5, 0.5, 1.0],
            order: 4,
            b_err: None,
        }
    }

    /// Second-order α family (Fig. 5 right): α = 0.5 is midpoint, α = 1 is
    /// Heun.
    pub fn alpha(alpha: f32) -> Result<Tableau> {
        if alpha <= 0.0 {
            return Err(Error::Other("alpha must be positive".into()));
        }
        Ok(Tableau {
            name: format!("alpha{alpha}"),
            a: vec![vec![], vec![alpha]],
            b: vec![1.0 - 1.0 / (2.0 * alpha), 1.0 / (2.0 * alpha)],
            c: vec![0.0, alpha],
            order: 2,
            b_err: None,
        })
    }

    /// Ralston's second-order method (minimal error bound among 2-stage
    /// explicit RK; equals the α family at α = 2/3).
    pub fn ralston() -> Tableau {
        Tableau {
            name: "ralston".into(),
            a: vec![vec![], vec![2.0 / 3.0]],
            b: vec![0.25, 0.75],
            c: vec![0.0, 2.0 / 3.0],
            order: 2,
            b_err: None,
        }
    }

    /// Kutta's 3/8 rule (4th order, the other classic 4-stage tableau).
    pub fn rk38() -> Tableau {
        Tableau {
            name: "rk38".into(),
            a: vec![
                vec![],
                vec![1.0 / 3.0],
                vec![-1.0 / 3.0, 1.0],
                vec![1.0, -1.0, 1.0],
            ],
            b: vec![1.0 / 8.0, 3.0 / 8.0, 3.0 / 8.0, 1.0 / 8.0],
            c: vec![0.0, 1.0 / 3.0, 2.0 / 3.0, 1.0],
            order: 4,
            b_err: None,
        }
    }

    /// Bogacki–Shampine 3(2) embedded pair (the `ode23` workhorse) — a
    /// second adaptive method beside dopri5, used by the ablation benches.
    pub fn bs32() -> Tableau {
        Tableau {
            name: "bs32".into(),
            a: vec![
                vec![],
                vec![0.5],
                vec![0.0, 0.75],
                vec![2.0 / 9.0, 1.0 / 3.0, 4.0 / 9.0],
            ],
            b: vec![2.0 / 9.0, 1.0 / 3.0, 4.0 / 9.0, 0.0],
            c: vec![0.0, 0.5, 0.75, 1.0],
            order: 3,
            b_err: Some(vec![7.0 / 24.0, 0.25, 1.0 / 3.0, 0.125]),
        }
    }

    /// Dormand-Prince 5(4) pair.
    pub fn dopri5() -> Tableau {
        Tableau {
            name: "dopri5".into(),
            a: vec![
                vec![],
                vec![1.0 / 5.0],
                vec![3.0 / 40.0, 9.0 / 40.0],
                vec![44.0 / 45.0, -56.0 / 15.0, 32.0 / 9.0],
                vec![
                    19372.0 / 6561.0,
                    -25360.0 / 2187.0,
                    64448.0 / 6561.0,
                    -212.0 / 729.0,
                ],
                vec![
                    9017.0 / 3168.0,
                    -355.0 / 33.0,
                    46732.0 / 5247.0,
                    49.0 / 176.0,
                    -5103.0 / 18656.0,
                ],
                vec![
                    35.0 / 384.0,
                    0.0,
                    500.0 / 1113.0,
                    125.0 / 192.0,
                    -2187.0 / 6784.0,
                    11.0 / 84.0,
                ],
            ],
            b: vec![
                35.0 / 384.0,
                0.0,
                500.0 / 1113.0,
                125.0 / 192.0,
                -2187.0 / 6784.0,
                11.0 / 84.0,
                0.0,
            ],
            c: vec![0.0, 0.2, 0.3, 0.8, 8.0 / 9.0, 1.0, 1.0],
            order: 5,
            b_err: Some(vec![
                5179.0 / 57600.0,
                0.0,
                7571.0 / 16695.0,
                393.0 / 640.0,
                -92097.0 / 339200.0,
                187.0 / 2100.0,
                1.0 / 40.0,
            ]),
        }
    }

    /// Resolve by name; `alphaX.Y` builds the α family.
    pub fn by_name(name: &str) -> Result<Tableau> {
        match name {
            "euler" => Ok(Self::euler()),
            "midpoint" => Ok(Self::midpoint()),
            "heun" => Ok(Self::heun()),
            "ralston" => Ok(Self::ralston()),
            "rk4" => Ok(Self::rk4()),
            "rk38" => Ok(Self::rk38()),
            "bs32" => Ok(Self::bs32()),
            "dopri5" => Ok(Self::dopri5()),
            _ => {
                if let Some(rest) = name.strip_prefix("alpha") {
                    let a: f32 = rest
                        .parse()
                        .map_err(|_| Error::Other(format!("bad solver {name}")))?;
                    Self::alpha(a)
                } else {
                    Err(Error::Other(format!("unknown solver {name:?}")))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn all() -> Vec<Tableau> {
        vec![
            Tableau::euler(),
            Tableau::midpoint(),
            Tableau::heun(),
            Tableau::ralston(),
            Tableau::rk4(),
            Tableau::rk38(),
            Tableau::bs32(),
            Tableau::alpha(0.3).unwrap(),
            Tableau::dopri5(),
        ]
    }

    #[test]
    fn ralston_is_alpha_two_thirds() {
        let r = Tableau::ralston();
        let a = Tableau::alpha(2.0 / 3.0).unwrap();
        for (x, y) in r.b.iter().zip(&a.b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn bs32_embedded_sums_to_one() {
        let s: f32 = Tableau::bs32().b_err.unwrap().iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
    }

    #[test]
    fn fourth_order_condition_rk38() {
        // Σ b_i c_i = 1/2 and Σ b_i c_i² = 1/3 for order ≥ 3
        let t = Tableau::rk38();
        let s1: f32 = t.b.iter().zip(&t.c).map(|(b, c)| b * c).sum();
        let s2: f32 = t.b.iter().zip(&t.c).map(|(b, c)| b * c * c).sum();
        assert!((s1 - 0.5).abs() < 1e-6);
        assert!((s2 - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn all_tableaus_validate() {
        for t in all() {
            t.validate().unwrap_or_else(|e| panic!("{}: {e}", t.name));
        }
    }

    #[test]
    fn second_order_condition() {
        for t in [Tableau::midpoint(), Tableau::heun(), Tableau::alpha(0.7).unwrap()] {
            let s: f32 = t.b.iter().zip(&t.c).map(|(b, c)| b * c).sum();
            assert!((s - 0.5).abs() < 1e-6, "{}", t.name);
        }
    }

    #[test]
    fn alpha_recovers_midpoint_and_heun() {
        let mid = Tableau::alpha(0.5).unwrap();
        assert_eq!(mid.b, Tableau::midpoint().b);
        let heun = Tableau::alpha(1.0).unwrap();
        assert_eq!(heun.b, Tableau::heun().b);
    }

    #[test]
    fn dopri5_embedded_sums_to_one() {
        let t = Tableau::dopri5();
        let s: f32 = t.b_err.unwrap().iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
    }

    #[test]
    fn by_name_roundtrip() {
        for t in all() {
            if !t.name.starts_with("alpha") {
                assert_eq!(Tableau::by_name(&t.name).unwrap().b, t.b);
            }
        }
        assert!((Tableau::by_name("alpha0.25").unwrap().c[1] - 0.25).abs() < 1e-6);
        assert!(Tableau::by_name("adams").is_err());
        assert!(Tableau::by_name("alpha0").is_err());
    }

    #[test]
    fn validate_catches_corruption() {
        let mut t = Tableau::rk4();
        t.b[0] = 0.9;
        assert!(t.validate().is_err());
        let mut t2 = Tableau::rk4();
        t2.c[1] = 0.7;
        assert!(t2.validate().is_err());
    }
}
