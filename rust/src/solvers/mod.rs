//! Native Rust ODE solvers: Butcher tableaus, fixed-step integration,
//! hypersolved stepping, and adaptive Dormand-Prince 5(4).
//!
//! These mirror `python/compile/solvers.py` exactly (same tableaus, same
//! controller) — the cross-language agreement is itself under test — and
//! serve three roles: (a) cross-validation of the JAX solvers, (b) the
//! engine behind the dense parameter sweeps in the benches, and (c) the
//! control loop for adaptive integration over PJRT-loaded fields
//! (`runtime::field_exec`), where rust owns the stepping decisions and XLA
//! only evaluates f.
//!
//! All stepping runs on reusable [`RkWorkspace`] buffers; the `*_ws` entry
//! points expose that to callers who hold a workspace across solves (the
//! serving runtime keeps one per queue), while the original pure APIs wrap
//! them with a throwaway workspace — same signatures, bit-identical
//! results, zero steady-state allocation on the `_ws` path.

pub mod adaptive;
pub mod butcher;
pub mod fixed;
pub mod hyper;
pub mod hyper_adaptive;
pub mod multistep;
pub mod workspace;

pub use adaptive::{adaptive, adaptive_ws, dopri5, dopri5_ws, AdaptiveOpts, AdaptiveResult};
pub use butcher::Tableau;
pub use fixed::{
    combine_into, odeint_fixed, odeint_fixed_traj, odeint_fixed_ws, psi, rk_stages, rk_step,
};
pub use hyper::{
    hyper_step, odeint_hyper, odeint_hyper_traj, odeint_hyper_ws, residual, HyperNet,
};
pub use hyper_adaptive::{odeint_hyper_adaptive, odeint_hyper_adaptive_ws};
pub use multistep::{
    odeint_ab, odeint_ab_ws, odeint_abm, odeint_abm_plain, odeint_abm_ws, AbOrder,
};
pub use workspace::RkWorkspace;
