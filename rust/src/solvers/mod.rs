//! Native Rust ODE solvers: Butcher tableaus, fixed-step integration,
//! hypersolved stepping, and adaptive Dormand-Prince 5(4).
//!
//! These mirror `python/compile/solvers.py` exactly (same tableaus, same
//! controller) — the cross-language agreement is itself under test — and
//! serve three roles: (a) cross-validation of the JAX solvers, (b) the
//! engine behind the dense parameter sweeps in the benches, and (c) the
//! control loop for adaptive integration over PJRT-loaded fields
//! (`runtime::field_exec`), where rust owns the stepping decisions and XLA
//! only evaluates f.

pub mod adaptive;
pub mod butcher;
pub mod fixed;
pub mod hyper;
pub mod hyper_adaptive;
pub mod multistep;

pub use adaptive::{adaptive, dopri5, AdaptiveOpts, AdaptiveResult};
pub use butcher::Tableau;
pub use fixed::{odeint_fixed, odeint_fixed_traj, psi, rk_step};
pub use hyper::{hyper_step, odeint_hyper, odeint_hyper_traj, residual, HyperNet};
pub use hyper_adaptive::odeint_hyper_adaptive;
pub use multistep::{odeint_ab, odeint_abm, odeint_abm_plain, AbOrder};
