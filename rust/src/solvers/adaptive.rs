//! Adaptive Dormand-Prince 5(4) with step-size control.
//!
//! Mirrors `python/compile/solvers.py::odeint_dopri5` (same error norm,
//! same controller constants) so the native and JAX dopri5 agree — and the
//! control loop lives in *rust*, which lets the runtime drive adaptive
//! integration over a PJRT-loaded field executable while XLA only
//! evaluates f.

use crate::ode::VectorField;
use crate::solvers::butcher::Tableau;
use crate::solvers::fixed::{combine_into, rk_stages_core};
use crate::solvers::workspace::RkWorkspace;
use crate::tensor::Tensor;
use crate::{Error, Result};

#[derive(Clone, Debug)]
pub struct AdaptiveOpts {
    pub rtol: f32,
    pub atol: f32,
    pub max_steps: usize,
    pub safety: f32,
    pub min_factor: f32,
    pub max_factor: f32,
    /// initial step as a fraction of the span
    pub first_step_frac: f32,
}

impl Default for AdaptiveOpts {
    fn default() -> Self {
        AdaptiveOpts {
            rtol: 1e-4,
            atol: 1e-4,
            max_steps: 10_000,
            safety: 0.9,
            min_factor: 0.2,
            max_factor: 10.0,
            first_step_frac: 0.1,
        }
    }
}

impl AdaptiveOpts {
    pub fn with_tol(tol: f32) -> Self {
        AdaptiveOpts {
            rtol: tol,
            atol: tol,
            ..Default::default()
        }
    }
}

#[derive(Clone, Debug)]
pub struct AdaptiveResult {
    pub z: Tensor,
    /// vector-field evaluations (7 per attempted step, matching the python
    /// counter)
    pub nfe: u64,
    pub accepted: u64,
    pub rejected: u64,
}

/// RMS of the mixed abs/rel scaled error (max-free batch norm identical to
/// the python implementation); `err_term(i)` supplies element i's raw
/// error. Shared by the embedded-pair controller here and the
/// hypersolver-correction controller in `hyper_adaptive`.
pub(crate) fn scaled_err_rms(
    z_new: &Tensor,
    z_old: &Tensor,
    rtol: f32,
    atol: f32,
    err_term: impl Fn(usize) -> f32,
) -> f32 {
    let n = z_new.numel() as f32;
    let (znew, zold) = (z_new.data(), z_old.data());
    let mut acc = 0.0f64;
    for i in 0..znew.len() {
        let scale = atol + rtol * znew[i].abs().max(zold[i].abs());
        let e = err_term(i) / scale;
        acc += (e * e) as f64;
    }
    ((acc / n as f64) as f32).sqrt()
}

/// Integrate ż = f(s, z) over `s_span` with dopri5.
pub fn dopri5<F: VectorField + ?Sized>(
    f: &F,
    z0: &Tensor,
    s_span: (f32, f32),
    opts: &AdaptiveOpts,
) -> Result<AdaptiveResult> {
    adaptive(f, z0, s_span, &Tableau::dopri5(), opts)
}

/// [`dopri5`] on a caller-held workspace. Allocation-free per *step* once
/// warm; per *solve* it still pays the `Tableau::dopri5()` construction
/// (a dozen small vecs) plus the `AdaptiveResult.z` clone — callers who
/// care should hold the tableau too and use [`adaptive_ws`].
pub fn dopri5_ws<F: VectorField + ?Sized>(
    f: &F,
    z0: &Tensor,
    s_span: (f32, f32),
    opts: &AdaptiveOpts,
    ws: &mut RkWorkspace,
) -> Result<AdaptiveResult> {
    adaptive_ws(f, z0, s_span, &Tableau::dopri5(), opts, ws)
}

/// Adaptive integration with any embedded Runge-Kutta pair (`tab.b_err`
/// must be present — dopri5, bs32, ...). Controller exponent adapts to the
/// pair's order. Wrapper over [`adaptive_ws`] with a throwaway workspace.
pub fn adaptive<F: VectorField + ?Sized>(
    f: &F,
    z0: &Tensor,
    s_span: (f32, f32),
    tab: &Tableau,
    opts: &AdaptiveOpts,
) -> Result<AdaptiveResult> {
    let mut ws = RkWorkspace::new();
    adaptive_ws(f, z0, s_span, tab, opts, &mut ws)
}

/// [`adaptive`] on a caller-held [`RkWorkspace`]. The accepted (5th-order)
/// combination lives in `ws.acc`, the embedded one in `ws.acc2`, and the
/// scaled error norm is folded in-flight — no error tensor is
/// materialized, and the numerics match the historical allocating
/// implementation bit-for-bit (same op order: (Σb − Σb̂), ×h, ÷scale).
pub fn adaptive_ws<F: VectorField + ?Sized>(
    f: &F,
    z0: &Tensor,
    s_span: (f32, f32),
    tab: &Tableau,
    opts: &AdaptiveOpts,
    ws: &mut RkWorkspace,
) -> Result<AdaptiveResult> {
    let b_err = tab
        .b_err
        .as_ref()
        .ok_or_else(|| Error::Other(format!("{} has no embedded pair", tab.name)))?;
    let exponent = -1.0 / tab.order as f32;
    let (s0, s1) = s_span;
    let direction = if s1 >= s0 { 1.0f32 } else { -1.0 };
    let span = (s1 - s0).abs();
    if span == 0.0 {
        return Ok(AdaptiveResult {
            z: z0.clone(),
            nfe: 0,
            accepted: 0,
            rejected: 0,
        });
    }

    ws.ensure(z0.shape(), tab.stages());
    ws.ensure_acc2();
    ws.z_cur.copy_from(z0);
    let mut progress = 0.0f32; // in [0, span]
    let mut eps = span * opts.first_step_frac;
    let (mut nfe, mut accepted, mut rejected) = (0u64, 0u64, 0u64);

    for _ in 0..opts.max_steps {
        if progress >= span * (1.0 - 1e-6) {
            return Ok(AdaptiveResult {
                z: ws.state().clone(),
                nfe,
                accepted,
                rejected,
            });
        }
        let eps_c = eps.min(span - progress);
        let s_abs = s0 + direction * progress;
        let h = direction * eps_c;
        rk_stages_core(f, tab, s_abs, h, ws)?;
        nfe += tab.stages() as u64;

        let p = tab.stages();
        combine_into(&ws.stages[..p], &tab.b, &mut ws.acc)?;
        combine_into(&ws.stages[..p], b_err, &mut ws.acc2)?;
        ws.z_next.copy_from(&ws.z_cur);
        ws.z_next.axpy(h, &ws.acc)?;

        let err = {
            let (a5, a4) = (ws.acc.data(), ws.acc2.data());
            scaled_err_rms(&ws.z_next, &ws.z_cur, opts.rtol, opts.atol, |i| {
                (a5[i] - a4[i]) * h
            })
        };
        let accept = err <= 1.0;
        let factor = (opts.safety * err.max(1e-10).powf(exponent))
            .clamp(opts.min_factor, opts.max_factor);
        eps = (eps_c * factor).clamp(1e-6 * span, span);
        if accept {
            progress += eps_c;
            ws.swap();
            accepted += 1;
        } else {
            rejected += 1;
        }
    }
    Err(Error::Other(format!(
        "dopri5: max_steps={} exhausted at progress {progress}/{span}",
        opts.max_steps
    )))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::{Decay, Rotation};

    #[test]
    fn matches_closed_form_rotation() {
        let f = Rotation { omega: 1.0 };
        let z0 = Tensor::new(&[1, 2], vec![1.0, 0.0]).unwrap();
        let r = dopri5(&f, &z0, (0.0, 1.0), &AdaptiveOpts::with_tol(1e-7)).unwrap();
        let exact = f.exact(&z0, 1.0);
        let err = r.z.sub(&exact).unwrap().frobenius_norm();
        assert!(err < 1e-5, "err {err}");
        assert_eq!(r.nfe % 7, 0);
        assert!(r.accepted > 0);
    }

    #[test]
    fn nfe_grows_with_tightening_tolerance() {
        let f = Rotation { omega: 4.0 };
        let z0 = Tensor::new(&[1, 2], vec![1.0, 0.0]).unwrap();
        let loose = dopri5(&f, &z0, (0.0, 1.0), &AdaptiveOpts::with_tol(1e-2)).unwrap();
        let tight = dopri5(&f, &z0, (0.0, 1.0), &AdaptiveOpts::with_tol(1e-8)).unwrap();
        assert!(tight.nfe > loose.nfe, "{} vs {}", tight.nfe, loose.nfe);
    }

    #[test]
    fn stiff_decay_is_resolved() {
        let f = Decay { lambda: -50.0 };
        let z0 = Tensor::full(&[1, 2], 1.0);
        let r = dopri5(&f, &z0, (0.0, 1.0), &AdaptiveOpts::with_tol(1e-6)).unwrap();
        let exact = f.exact(&z0, 1.0);
        let err = r.z.sub(&exact).unwrap().frobenius_norm();
        assert!(err < 1e-6, "err {err}");
        assert!(r.rejected > 0 || r.accepted > 10); // stiffness forced work
    }

    #[test]
    fn backward_direction() {
        let f = Rotation { omega: 1.0 };
        let z0 = Tensor::new(&[1, 2], vec![0.3, -0.7]).unwrap();
        let fwd = dopri5(&f, &z0, (0.0, 1.0), &AdaptiveOpts::with_tol(1e-7)).unwrap();
        let back = dopri5(&f, &fwd.z, (1.0, 0.0), &AdaptiveOpts::with_tol(1e-7)).unwrap();
        let err = back.z.sub(&z0).unwrap().frobenius_norm();
        assert!(err < 1e-4, "err {err}");
    }

    #[test]
    fn zero_span_is_identity() {
        let f = Rotation { omega: 1.0 };
        let z0 = Tensor::new(&[1, 2], vec![1.0, 2.0]).unwrap();
        let r = dopri5(&f, &z0, (0.5, 0.5), &AdaptiveOpts::default()).unwrap();
        assert_eq!(r.z, z0);
        assert_eq!(r.nfe, 0);
    }

    #[test]
    fn bs32_adaptive_pair_works() {
        let f = Rotation { omega: 2.0 };
        let z0 = Tensor::new(&[1, 2], vec![1.0, 0.0]).unwrap();
        let r = adaptive(
            &f,
            &z0,
            (0.0, 1.0),
            &Tableau::bs32(),
            &AdaptiveOpts::with_tol(1e-6),
        )
        .unwrap();
        let exact = f.exact(&z0, 1.0);
        let err = r.z.sub(&exact).unwrap().frobenius_norm();
        assert!(err < 1e-4, "bs32 err {err}");
        // 3rd-order pair needs more NFE than dopri5 at equal tolerance
        let d5 = dopri5(&f, &z0, (0.0, 1.0), &AdaptiveOpts::with_tol(1e-6)).unwrap();
        assert!(r.nfe >= d5.nfe / 2, "bs32 {} vs dopri5 {}", r.nfe, d5.nfe);
    }

    #[test]
    fn non_embedded_tableau_rejected() {
        let f = Rotation { omega: 1.0 };
        let z0 = Tensor::new(&[1, 2], vec![1.0, 0.0]).unwrap();
        assert!(adaptive(
            &f,
            &z0,
            (0.0, 1.0),
            &Tableau::rk4(),
            &AdaptiveOpts::default()
        )
        .is_err());
    }

    #[test]
    fn max_steps_errors_out() {
        let f = Decay { lambda: -50_000.0 };
        let z0 = Tensor::full(&[1, 1], 1.0);
        let opts = AdaptiveOpts {
            max_steps: 3,
            ..AdaptiveOpts::with_tol(1e-10)
        };
        assert!(dopri5(&f, &z0, (0.0, 1.0), &opts).is_err());
    }
}
