//! Hypersolved stepping (paper eq. 5): z' = z + ε ψ + ε^{p+1} g_ω(ε, s, z, ż).

use crate::ode::VectorField;
use crate::solvers::butcher::Tableau;
use crate::solvers::fixed::{combine, rk_stages};
use crate::tensor::Tensor;
use crate::Result;

/// The hypersolver correction network g_ω. `dz` is the first RK stage
/// f(s, z) (free for every explicit method since c_1 = 0), mirroring the
/// appendix B.1 template input `cat(z, dz, ds)`.
pub trait HyperNet {
    fn eval(&self, eps: f32, s: f32, z: &Tensor, dz: &Tensor) -> Tensor;

    /// Analytic MACs per sample per evaluation.
    fn macs(&self) -> u64 {
        0
    }
}

impl<G: Fn(f32, f32, &Tensor, &Tensor) -> Tensor> HyperNet for G {
    fn eval(&self, eps: f32, s: f32, z: &Tensor, dz: &Tensor) -> Tensor {
        self(eps, s, z, dz)
    }
}

/// One hypersolved step.
pub fn hyper_step<F: VectorField + ?Sized, G: HyperNet + ?Sized>(
    f: &F,
    g: &G,
    tab: &Tableau,
    s: f32,
    z: &Tensor,
    eps: f32,
) -> Result<Tensor> {
    let stages = rk_stages(f, tab, s, z, eps)?;
    let direction = combine(z.shape(), &stages, &tab.b)?;
    let corr = g.eval(eps, s, z, &stages[0]);
    let mut out = z.clone();
    out.axpy(eps, &direction)?;
    out.axpy(eps.powi(tab.order as i32 + 1), &corr)?;
    Ok(out)
}

/// Hypersolved fixed-step integration; terminal state.
pub fn odeint_hyper<F: VectorField + ?Sized, G: HyperNet + ?Sized>(
    f: &F,
    g: &G,
    z0: &Tensor,
    s_span: (f32, f32),
    steps: usize,
    tab: &Tableau,
) -> Result<Tensor> {
    assert!(steps > 0);
    let eps = (s_span.1 - s_span.0) / steps as f32;
    let mut z = z0.clone();
    for k in 0..steps {
        let s = s_span.0 + k as f32 * eps;
        z = hyper_step(f, g, tab, s, &z, eps)?;
    }
    Ok(z)
}

/// As [`odeint_hyper`] but returns the (K+1)-point trajectory.
pub fn odeint_hyper_traj<F: VectorField + ?Sized, G: HyperNet + ?Sized>(
    f: &F,
    g: &G,
    z0: &Tensor,
    s_span: (f32, f32),
    steps: usize,
    tab: &Tableau,
) -> Result<Vec<Tensor>> {
    let eps = (s_span.1 - s_span.0) / steps as f32;
    let mut traj = Vec::with_capacity(steps + 1);
    traj.push(z0.clone());
    for k in 0..steps {
        let s = s_span.0 + k as f32 * eps;
        let next = hyper_step(f, g, tab, s, traj.last().unwrap(), eps)?;
        traj.push(next);
    }
    Ok(traj)
}

/// The residual of eq. (6): R = (z_{k+1} − z_k − ε ψ) / ε^{p+1}, computed
/// from ground-truth checkpoints. Used by tests and the fig2 bench to relate
/// a hypersolver's fit quality δ to its local error.
pub fn residual<F: VectorField + ?Sized>(
    f: &F,
    tab: &Tableau,
    s: f32,
    zk: &Tensor,
    zk1: &Tensor,
    eps: f32,
) -> Result<Tensor> {
    let direction = crate::solvers::fixed::psi(f, tab, s, zk, eps)?;
    let mut r = zk1.sub(zk)?;
    r.axpy(-eps, &direction)?;
    Ok(r.scale(1.0 / eps.powi(tab.order as i32 + 1)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::Rotation;
    use crate::solvers::fixed::odeint_fixed;

    fn zero_g() -> impl HyperNet {
        |_e: f32, _s: f32, z: &Tensor, _dz: &Tensor| Tensor::zeros(z.shape())
    }

    #[test]
    fn zero_correction_equals_base() {
        let f = Rotation { omega: 1.0 };
        let z0 = Tensor::new(&[1, 2], vec![1.0, 0.0]).unwrap();
        for tab in [Tableau::euler(), Tableau::heun()] {
            let zh = odeint_hyper(&f, &zero_g(), &z0, (0.0, 1.0), 7, &tab).unwrap();
            let zb = odeint_fixed(&f, &z0, (0.0, 1.0), 7, &tab).unwrap();
            let err = zh.sub(&zb).unwrap().frobenius_norm();
            assert!(err < 1e-6, "{}: {err}", tab.name);
        }
    }

    #[test]
    fn taylor_g_raises_euler_to_second_order() {
        // For ż = Az, the ε² Taylor term is ½A²z; A² = -ω² I for rotation.
        let omega = 1.0f32;
        let f = Rotation { omega };
        let g = move |_e: f32, _s: f32, z: &Tensor, _dz: &Tensor| {
            z.scale(-0.5 * omega * omega)
        };
        let z0 = Tensor::new(&[1, 2], vec![1.0, 0.0]).unwrap();
        let exact = f.exact(&z0, 1.0);
        let err = |k: usize| {
            odeint_hyper(&f, &g, &z0, (0.0, 1.0), k, &Tableau::euler())
                .unwrap()
                .sub(&exact)
                .unwrap()
                .frobenius_norm()
        };
        let (e8, e16) = (err(8), err(16));
        let order = (e8 / e16).log2();
        assert!(order > 1.6, "order {order} e8={e8} e16={e16}");
        // and beats plain euler outright
        let e_euler = odeint_fixed(&f, &z0, (0.0, 1.0), 8, &Tableau::euler())
            .unwrap()
            .sub(&exact)
            .unwrap()
            .frobenius_norm();
        assert!(e8 < e_euler / 4.0);
    }

    #[test]
    fn residual_of_exact_taylor_term() {
        // residual of euler on rotation ≈ ½A²z + O(ε): check leading term
        let f = Rotation { omega: 1.0 };
        let z0 = Tensor::new(&[1, 2], vec![1.0, 0.0]).unwrap();
        let eps = 0.01f32;
        let z1 = f.exact(&z0, eps);
        let r = residual(&f, &Tableau::euler(), 0.0, &z0, &z1, eps).unwrap();
        // expected: ½ A² z = -½ z for ω=1
        let expected = z0.scale(-0.5);
        let err = r.sub(&expected).unwrap().frobenius_norm();
        assert!(err < 0.05, "residual {:?} vs {:?}", r.data(), expected.data());
    }

    #[test]
    fn trajectory_matches_terminal() {
        let f = Rotation { omega: 1.0 };
        let z0 = Tensor::new(&[1, 2], vec![0.0, 1.0]).unwrap();
        let g = zero_g();
        let traj =
            odeint_hyper_traj(&f, &g, &z0, (0.0, 1.0), 5, &Tableau::heun()).unwrap();
        let term = odeint_hyper(&f, &g, &z0, (0.0, 1.0), 5, &Tableau::heun()).unwrap();
        assert_eq!(traj.len(), 6);
        assert_eq!(*traj.last().unwrap(), term);
    }
}
