//! Hypersolved stepping (paper eq. 5): z' = z + ε ψ + ε^{p+1} g_ω(ε, s, z, ż).
//!
//! Like `fixed`, the stepping core runs on [`RkWorkspace`] buffers (the
//! correction g_ω writes into `ws.corr` through `HyperNet::eval_into`);
//! the pure APIs wrap it with a throwaway workspace.

use crate::ode::VectorField;
use crate::solvers::butcher::Tableau;
use crate::solvers::fixed::{combine_into, rk_stages_core};
use crate::solvers::workspace::RkWorkspace;
use crate::tensor::{Tensor, Workspace};
use crate::Result;

/// The hypersolver correction network g_ω. `dz` is the first RK stage
/// f(s, z) (free for every explicit method since c_1 = 0), mirroring the
/// appendix B.1 template input `cat(z, dz, ds)`.
pub trait HyperNet {
    fn eval(&self, eps: f32, s: f32, z: &Tensor, dz: &Tensor) -> Tensor;

    /// Write g_ω(ε, s, z, ż) into `out` (same shape as `z`, fully
    /// overwritten), drawing scratch from `ws`. Default falls back to
    /// [`eval`](Self::eval) so external impls keep compiling; overrides
    /// must be bit-identical to `eval`.
    fn eval_into(
        &self,
        eps: f32,
        s: f32,
        z: &Tensor,
        dz: &Tensor,
        out: &mut Tensor,
        ws: &mut Workspace,
    ) {
        let _ = ws;
        let r = self.eval(eps, s, z, dz);
        if r.shape() == out.shape() {
            out.copy_from(&r);
        } else {
            // wrong-shaped correction: pass it through so the solver's
            // axpy shape check reports Err instead of panicking here
            *out = r;
        }
    }

    /// Analytic MACs per sample per evaluation.
    fn macs(&self) -> u64 {
        0
    }
}

impl<G: Fn(f32, f32, &Tensor, &Tensor) -> Tensor> HyperNet for G {
    fn eval(&self, eps: f32, s: f32, z: &Tensor, dz: &Tensor) -> Tensor {
        self(eps, s, z, dz)
    }
}

/// One hypersolved step on the workspace: advances `ws.z_cur` by
/// ε·ψ + ε^{p+1}·g_ω.
pub(crate) fn hyper_step_core<F: VectorField + ?Sized, G: HyperNet + ?Sized>(
    f: &F,
    g: &G,
    tab: &Tableau,
    s: f32,
    eps: f32,
    ws: &mut RkWorkspace,
) -> Result<()> {
    ws.ensure_corr();
    rk_stages_core(f, tab, s, eps, ws)?;
    let p = tab.stages();
    combine_into(&ws.stages[..p], &tab.b, &mut ws.acc)?;
    g.eval_into(eps, s, &ws.z_cur, &ws.stages[0], &mut ws.corr, &mut ws.scratch);
    ws.z_next.copy_from(&ws.z_cur);
    ws.z_next.axpy(eps, &ws.acc)?;
    ws.z_next.axpy(eps.powi(tab.order as i32 + 1), &ws.corr)?;
    ws.swap();
    Ok(())
}

/// One hypersolved step.
pub fn hyper_step<F: VectorField + ?Sized, G: HyperNet + ?Sized>(
    f: &F,
    g: &G,
    tab: &Tableau,
    s: f32,
    z: &Tensor,
    eps: f32,
) -> Result<Tensor> {
    let mut ws = RkWorkspace::new();
    ws.ensure(z.shape(), tab.stages());
    ws.z_cur.copy_from(z);
    hyper_step_core(f, g, tab, s, eps, &mut ws)?;
    Ok(ws.state().clone())
}

/// [`odeint_hyper`] on a caller-held workspace (allocation-free once warm).
/// Returns a borrow of the terminal state inside `ws`.
pub fn odeint_hyper_ws<'a, F: VectorField + ?Sized, G: HyperNet + ?Sized>(
    f: &F,
    g: &G,
    z0: &Tensor,
    s_span: (f32, f32),
    steps: usize,
    tab: &Tableau,
    ws: &'a mut RkWorkspace,
) -> Result<&'a Tensor> {
    assert!(steps > 0);
    let eps = (s_span.1 - s_span.0) / steps as f32;
    ws.ensure(z0.shape(), tab.stages());
    ws.z_cur.copy_from(z0);
    for k in 0..steps {
        let s = s_span.0 + k as f32 * eps;
        hyper_step_core(f, g, tab, s, eps, ws)?;
    }
    Ok(ws.state())
}

/// Hypersolved fixed-step integration; terminal state.
pub fn odeint_hyper<F: VectorField + ?Sized, G: HyperNet + ?Sized>(
    f: &F,
    g: &G,
    z0: &Tensor,
    s_span: (f32, f32),
    steps: usize,
    tab: &Tableau,
) -> Result<Tensor> {
    let mut ws = RkWorkspace::new();
    Ok(odeint_hyper_ws(f, g, z0, s_span, steps, tab, &mut ws)?.clone())
}

/// As [`odeint_hyper`] but returns the (K+1)-point trajectory.
pub fn odeint_hyper_traj<F: VectorField + ?Sized, G: HyperNet + ?Sized>(
    f: &F,
    g: &G,
    z0: &Tensor,
    s_span: (f32, f32),
    steps: usize,
    tab: &Tableau,
) -> Result<Vec<Tensor>> {
    let eps = (s_span.1 - s_span.0) / steps as f32;
    let mut ws = RkWorkspace::new();
    ws.ensure(z0.shape(), tab.stages());
    ws.z_cur.copy_from(z0);
    let mut traj = Vec::with_capacity(steps + 1);
    traj.push(z0.clone());
    for k in 0..steps {
        let s = s_span.0 + k as f32 * eps;
        hyper_step_core(f, g, tab, s, eps, &mut ws)?;
        traj.push(ws.state().clone());
    }
    Ok(traj)
}

/// The residual of eq. (6): R = (z_{k+1} − z_k − ε ψ) / ε^{p+1}, computed
/// from ground-truth checkpoints. Used by tests and the fig2 bench to relate
/// a hypersolver's fit quality δ to its local error.
pub fn residual<F: VectorField + ?Sized>(
    f: &F,
    tab: &Tableau,
    s: f32,
    zk: &Tensor,
    zk1: &Tensor,
    eps: f32,
) -> Result<Tensor> {
    let direction = crate::solvers::fixed::psi(f, tab, s, zk, eps)?;
    let mut r = zk1.sub(zk)?;
    r.axpy(-eps, &direction)?;
    Ok(r.scale(1.0 / eps.powi(tab.order as i32 + 1)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::Rotation;
    use crate::solvers::fixed::odeint_fixed;

    fn zero_g() -> impl HyperNet {
        |_e: f32, _s: f32, z: &Tensor, _dz: &Tensor| Tensor::zeros(z.shape())
    }

    #[test]
    fn zero_correction_equals_base() {
        let f = Rotation { omega: 1.0 };
        let z0 = Tensor::new(&[1, 2], vec![1.0, 0.0]).unwrap();
        for tab in [Tableau::euler(), Tableau::heun()] {
            let zh = odeint_hyper(&f, &zero_g(), &z0, (0.0, 1.0), 7, &tab).unwrap();
            let zb = odeint_fixed(&f, &z0, (0.0, 1.0), 7, &tab).unwrap();
            let err = zh.sub(&zb).unwrap().frobenius_norm();
            assert!(err < 1e-6, "{}: {err}", tab.name);
        }
    }

    #[test]
    fn taylor_g_raises_euler_to_second_order() {
        // For ż = Az, the ε² Taylor term is ½A²z; A² = -ω² I for rotation.
        let omega = 1.0f32;
        let f = Rotation { omega };
        let g = move |_e: f32, _s: f32, z: &Tensor, _dz: &Tensor| {
            z.scale(-0.5 * omega * omega)
        };
        let z0 = Tensor::new(&[1, 2], vec![1.0, 0.0]).unwrap();
        let exact = f.exact(&z0, 1.0);
        let err = |k: usize| {
            odeint_hyper(&f, &g, &z0, (0.0, 1.0), k, &Tableau::euler())
                .unwrap()
                .sub(&exact)
                .unwrap()
                .frobenius_norm()
        };
        let (e8, e16) = (err(8), err(16));
        let order = (e8 / e16).log2();
        assert!(order > 1.6, "order {order} e8={e8} e16={e16}");
        // and beats plain euler outright
        let e_euler = odeint_fixed(&f, &z0, (0.0, 1.0), 8, &Tableau::euler())
            .unwrap()
            .sub(&exact)
            .unwrap()
            .frobenius_norm();
        assert!(e8 < e_euler / 4.0);
    }

    #[test]
    fn residual_of_exact_taylor_term() {
        // residual of euler on rotation ≈ ½A²z + O(ε): check leading term
        let f = Rotation { omega: 1.0 };
        let z0 = Tensor::new(&[1, 2], vec![1.0, 0.0]).unwrap();
        let eps = 0.01f32;
        let z1 = f.exact(&z0, eps);
        let r = residual(&f, &Tableau::euler(), 0.0, &z0, &z1, eps).unwrap();
        // expected: ½ A² z = -½ z for ω=1
        let expected = z0.scale(-0.5);
        let err = r.sub(&expected).unwrap().frobenius_norm();
        assert!(err < 0.05, "residual {:?} vs {:?}", r.data(), expected.data());
    }

    #[test]
    fn trajectory_matches_terminal() {
        let f = Rotation { omega: 1.0 };
        let z0 = Tensor::new(&[1, 2], vec![0.0, 1.0]).unwrap();
        let g = zero_g();
        let traj =
            odeint_hyper_traj(&f, &g, &z0, (0.0, 1.0), 5, &Tableau::heun()).unwrap();
        let term = odeint_hyper(&f, &g, &z0, (0.0, 1.0), 5, &Tableau::heun()).unwrap();
        assert_eq!(traj.len(), 6);
        assert_eq!(*traj.last().unwrap(), term);
    }
}
