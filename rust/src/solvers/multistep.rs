//! Linear multistep methods: Adams-Bashforth and the Adams-Bashforth-Moulton
//! predictor-corrector — the "beyond fixed-step explicit" direction of the
//! paper's §6, where hypersolver corrections slot into either the predictor
//! or the corrector.
//!
//! These reuse past derivative evaluations, so per-step NFE is 1 (AB) or 2
//! (ABM) regardless of order — a different point on the NFE/accuracy plane
//! than the RK family, which the ablation bench contrasts against the
//! hypersolved variants.
//!
//! Like the RK family, the stepping cores run on [`RkWorkspace`] buffers:
//! the derivative history lives in a ring over the workspace's stage slots
//! (slots 0..4 stay reserved for the RK4 bootstrap, the ring sits above
//! them), so the stepping loop itself is allocation-free on a warm
//! workspace. Each `_ws` call still constructs the RK4 bootstrap tableau
//! (a dozen tiny vecs) — per *solve*, not per step. The original pure APIs
//! wrap the `_ws` entry points with a throwaway workspace — same
//! signatures, bit-identical results.

use crate::ode::VectorField;
use crate::solvers::butcher::Tableau;
use crate::solvers::fixed::rk_step_core;
use crate::solvers::hyper::HyperNet;
use crate::solvers::workspace::RkWorkspace;
use crate::tensor::Tensor;
use crate::Result;

/// Stage slots used by the RK4 bootstrap; the multistep history ring
/// occupies the slots above this.
const BOOT_SLOTS: usize = 4;

/// Adams-Bashforth order (2 or 3 supported).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AbOrder {
    Two,
    Three,
}

impl AbOrder {
    fn steps(self) -> usize {
        match self {
            AbOrder::Two => 2,
            AbOrder::Three => 3,
        }
    }

    /// AB coefficients for f_{k}, f_{k-1}, (f_{k-2}).
    fn coeffs(self) -> &'static [f32] {
        match self {
            AbOrder::Two => &[1.5, -0.5],
            AbOrder::Three => &[23.0 / 12.0, -16.0 / 12.0, 5.0 / 12.0],
        }
    }
}

/// [`odeint_ab`] on a caller-held workspace: stepping is allocation-free
/// once `ws` is warm (the per-solve `Tableau::rk4()` bootstrap
/// construction is the remaining heap traffic). The derivative history is
/// a ring over `ws.stages[4..4+p]`, rotated by index — no buffer
/// shuffling, no reallocation. Returns a borrow of the terminal state
/// inside `ws`.
pub fn odeint_ab_ws<'a, F: VectorField + ?Sized>(
    f: &F,
    z0: &Tensor,
    s_span: (f32, f32),
    steps: usize,
    order: AbOrder,
    ws: &'a mut RkWorkspace,
) -> Result<&'a Tensor> {
    let p = order.steps();
    assert!(steps >= p, "need at least {p} steps");
    let eps = (s_span.1 - s_span.0) / steps as f32;
    let rk4 = Tableau::rk4();
    let coeffs = order.coeffs();

    ws.ensure(z0.shape(), BOOT_SLOTS + p);
    ws.z_cur.copy_from(z0);
    // ring position of the newest derivative; slot(j) holds the j-th newest
    let mut head = 0usize;
    let slot = |head: usize, j: usize| BOOT_SLOTS + (head + p - j) % p;
    f.eval_into(s_span.0, &ws.z_cur, &mut ws.stages[BOOT_SLOTS], &mut ws.scratch);
    let mut filled = 1usize;

    for k in 0..steps {
        let s = s_span.0 + k as f32 * eps;
        let last = k + 1 == steps;
        if filled < p {
            // bootstrap with RK4 (standard practice); record the
            // derivative at the new point into the next ring slot
            rk_step_core(f, &rk4, s, eps, ws)?;
            head = (head + 1) % p;
            if !last {
                f.eval_into(s + eps, &ws.z_cur, &mut ws.stages[slot(head, 0)], &mut ws.scratch);
            }
            filled += 1;
            continue;
        }
        // AB step: z ← z + ε Σ_j c_j f_{newest−j}
        ws.z_next.copy_from(&ws.z_cur);
        for (j, c) in coeffs.iter().enumerate() {
            ws.z_next.axpy(eps * c, &ws.stages[slot(head, j)])?;
        }
        ws.swap();
        head = (head + 1) % p;
        // the derivative at the terminal point is never consumed — skip it
        if !last {
            f.eval_into(s + eps, &ws.z_cur, &mut ws.stages[slot(head, 0)], &mut ws.scratch);
        }
    }
    Ok(ws.state())
}

/// Fixed-step Adams-Bashforth integration. Bootstraps the multistep history
/// with RK4 steps, then runs at 1 NFE/step. Thin wrapper over
/// [`odeint_ab_ws`] with a throwaway workspace — bit-identical results.
pub fn odeint_ab<F: VectorField + ?Sized>(
    f: &F,
    z0: &Tensor,
    s_span: (f32, f32),
    steps: usize,
    order: AbOrder,
) -> Result<Tensor> {
    let mut ws = RkWorkspace::new();
    Ok(odeint_ab_ws(f, z0, s_span, steps, order, &mut ws)?.clone())
}

// ABM history slots above the bootstrap range.
const FP: usize = BOOT_SLOTS; // f at the previous point
const FC: usize = BOOT_SLOTS + 1; // f at the current point
const FPRED: usize = BOOT_SLOTS + 2; // f at the predicted point

/// [`odeint_abm`] on a caller-held workspace (stepping allocation-free
/// once warm; the per-solve bootstrap tableau construction is the
/// remaining heap traffic). The predictor state lives in `ws.zi` (free
/// outside `rk_stages_core`), the f history in dedicated stage slots
/// swapped by index, and the optional hypersolver correction in
/// `ws.corr`. Returns a borrow of the terminal state.
pub fn odeint_abm_ws<'a, F: VectorField + ?Sized, G: HyperNet + ?Sized>(
    f: &F,
    z0: &Tensor,
    s_span: (f32, f32),
    steps: usize,
    hyper: Option<&G>,
    ws: &'a mut RkWorkspace,
) -> Result<&'a Tensor> {
    assert!(steps >= 2);
    let eps = (s_span.1 - s_span.0) / steps as f32;
    let rk4 = Tableau::rk4();

    ws.ensure(z0.shape(), BOOT_SLOTS + 3);
    if hyper.is_some() {
        ws.ensure_corr();
    }
    ws.z_cur.copy_from(z0);
    f.eval_into(s_span.0, &ws.z_cur, &mut ws.stages[FC], &mut ws.scratch);
    let mut booted = false;

    for k in 0..steps {
        let s = s_span.0 + k as f32 * eps;
        if !booted {
            // bootstrap one RK4 step; shift the history
            rk_step_core(f, &rk4, s, eps, ws)?;
            ws.stages.swap(FP, FC);
            f.eval_into(s + eps, &ws.z_cur, &mut ws.stages[FC], &mut ws.scratch);
            booted = true;
            continue;
        }
        // predict: AB2 (+ optional hypersolver correction, order 2)
        ws.zi.copy_from(&ws.z_cur);
        ws.zi.axpy(eps * 1.5, &ws.stages[FC])?;
        ws.zi.axpy(-eps * 0.5, &ws.stages[FP])?;
        if let Some(g) = hyper {
            g.eval_into(eps, s, &ws.z_cur, &ws.stages[FC], &mut ws.corr, &mut ws.scratch);
            ws.zi.axpy(eps.powi(3), &ws.corr)?;
        }
        // evaluate at the predicted point, correct with AM2 (trapezoid)
        f.eval_into(s + eps, &ws.zi, &mut ws.stages[FPRED], &mut ws.scratch);
        ws.z_next.copy_from(&ws.z_cur);
        ws.z_next.axpy(eps * 0.5, &ws.stages[FC])?;
        ws.z_next.axpy(eps * 0.5, &ws.stages[FPRED])?;
        ws.swap();
        ws.stages.swap(FP, FC);
        // the derivative at the terminal point is never consumed — skip it
        if k + 1 < steps {
            f.eval_into(s + eps, &ws.z_cur, &mut ws.stages[FC], &mut ws.scratch);
        }
    }
    Ok(ws.state())
}

/// Adams-Bashforth-Moulton predictor-corrector (PECE): AB2 predicts, the
/// trapezoidal AM2 corrects. 2 NFE/step after bootstrap.
///
/// When `hyper` is given, its output corrects the *predictor* with the
/// ε^{p+1}-scaled term of eq. (5) — the §6 predictor-corrector hypersolver.
/// Thin wrapper over [`odeint_abm_ws`] — bit-identical results.
pub fn odeint_abm<F: VectorField + ?Sized, G: HyperNet + ?Sized>(
    f: &F,
    z0: &Tensor,
    s_span: (f32, f32),
    steps: usize,
    hyper: Option<&G>,
) -> Result<Tensor> {
    let mut ws = RkWorkspace::new();
    Ok(odeint_abm_ws(f, z0, s_span, steps, hyper, &mut ws)?.clone())
}

/// Convenience: ABM without a hypersolver.
pub fn odeint_abm_plain<F: VectorField + ?Sized>(
    f: &F,
    z0: &Tensor,
    s_span: (f32, f32),
    steps: usize,
) -> Result<Tensor> {
    odeint_abm(
        f,
        z0,
        s_span,
        steps,
        None::<&fn(f32, f32, &Tensor, &Tensor) -> Tensor>,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::Rotation;

    fn setup() -> (Rotation, Tensor, Tensor) {
        let f = Rotation { omega: 1.0 };
        let z0 = Tensor::new(&[1, 2], vec![1.0, 0.0]).unwrap();
        let exact = f.exact(&z0, 1.0);
        (f, z0, exact)
    }

    fn err(a: &Tensor, b: &Tensor) -> f32 {
        a.sub(b).unwrap().frobenius_norm()
    }

    #[test]
    fn ab2_second_order() {
        let (f, z0, exact) = setup();
        let e1 = err(&odeint_ab(&f, &z0, (0.0, 1.0), 16, AbOrder::Two).unwrap(), &exact);
        let e2 = err(&odeint_ab(&f, &z0, (0.0, 1.0), 32, AbOrder::Two).unwrap(), &exact);
        let order = (e1 / e2).log2();
        assert!(order > 1.5, "AB2 order {order} ({e1} -> {e2})");
    }

    #[test]
    fn ab3_beats_ab2() {
        let (f, z0, exact) = setup();
        let e2 = err(&odeint_ab(&f, &z0, (0.0, 1.0), 32, AbOrder::Two).unwrap(), &exact);
        let e3 = err(&odeint_ab(&f, &z0, (0.0, 1.0), 32, AbOrder::Three).unwrap(), &exact);
        assert!(e3 < e2, "AB3 {e3} vs AB2 {e2}");
    }

    #[test]
    fn abm_beats_ab2() {
        let (f, z0, exact) = setup();
        let e_ab = err(&odeint_ab(&f, &z0, (0.0, 1.0), 16, AbOrder::Two).unwrap(), &exact);
        let e_abm = err(&odeint_abm_plain(&f, &z0, (0.0, 1.0), 16).unwrap(), &exact);
        assert!(e_abm < e_ab, "ABM {e_abm} vs AB2 {e_ab}");
    }

    #[test]
    fn hyper_predictor_stays_consistent() {
        // Correcting the AB2 predictor with the exact Euler-residual Taylor
        // term perturbs only the O(ε³) predictor error, so the corrected
        // PECE result must stay within a small factor of the plain one (the
        // corrector dominates) and converge to the same answer as K grows.
        let (f, z0, exact) = setup();
        let omega = 1.0f32;
        let g = move |_e: f32, _s: f32, z: &Tensor, _dz: &Tensor| {
            z.scale(-0.5 * omega * omega)
        };
        for k in [6usize, 24] {
            let plain = odeint_abm_plain(&f, &z0, (0.0, 1.0), k).unwrap();
            let hyp = odeint_abm(&f, &z0, (0.0, 1.0), k, Some(&g)).unwrap();
            let (e_h, e_p) = (err(&hyp, &exact), err(&plain, &exact));
            assert!(
                e_h <= e_p * 2.0 + 1e-5,
                "K={k}: hyper {e_h} vs plain {e_p}"
            );
        }
        // and the hypersolved variant still converges at 2nd order overall
        let e1 = err(&odeint_abm(&f, &z0, (0.0, 1.0), 16, Some(&g)).unwrap(), &exact);
        let e2 = err(&odeint_abm(&f, &z0, (0.0, 1.0), 32, Some(&g)).unwrap(), &exact);
        assert!((e1 / e2).log2() > 1.5, "order {}", (e1 / e2).log2());
    }

    #[test]
    fn warm_workspace_reuse_is_bit_identical_to_pure() {
        // one workspace across solvers, orders, and step counts: results
        // must match the pure wrappers bit for bit, with buffers reused
        let (f, z0, _) = setup();
        let g = |_e: f32, _s: f32, z: &Tensor, _dz: &Tensor| z.scale(-0.5);
        let mut ws = RkWorkspace::new();
        for steps in [4usize, 9, 16] {
            for order in [AbOrder::Two, AbOrder::Three] {
                let pure = odeint_ab(&f, &z0, (0.0, 1.0), steps, order).unwrap();
                let w = odeint_ab_ws(&f, &z0, (0.0, 1.0), steps, order, &mut ws)
                    .unwrap()
                    .clone();
                assert_eq!(pure.data(), w.data(), "ab {order:?} K={steps}");
            }
            let pure = odeint_abm_plain(&f, &z0, (0.0, 1.0), steps).unwrap();
            let w = odeint_abm_ws(
                &f,
                &z0,
                (0.0, 1.0),
                steps,
                None::<&fn(f32, f32, &Tensor, &Tensor) -> Tensor>,
                &mut ws,
            )
            .unwrap()
            .clone();
            assert_eq!(pure.data(), w.data(), "abm K={steps}");
            let pure_h = odeint_abm(&f, &z0, (0.0, 1.0), steps, Some(&g)).unwrap();
            let w_h = odeint_abm_ws(&f, &z0, (0.0, 1.0), steps, Some(&g), &mut ws)
                .unwrap()
                .clone();
            assert_eq!(pure_h.data(), w_h.data(), "hyper abm K={steps}");
        }
    }

    #[test]
    #[should_panic]
    fn too_few_steps_panics() {
        let (f, z0, _) = setup();
        let _ = odeint_ab(&f, &z0, (0.0, 1.0), 2, AbOrder::Three);
    }
}
