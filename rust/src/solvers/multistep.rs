//! Linear multistep methods: Adams-Bashforth and the Adams-Bashforth-Moulton
//! predictor-corrector — the "beyond fixed-step explicit" direction of the
//! paper's §6, where hypersolver corrections slot into either the predictor
//! or the corrector.
//!
//! These reuse past derivative evaluations, so per-step NFE is 1 (AB) or 2
//! (ABM) regardless of order — a different point on the NFE/accuracy plane
//! than the RK family, which the ablation bench contrasts against the
//! hypersolved variants.

use crate::ode::VectorField;
use crate::solvers::butcher::Tableau;
use crate::solvers::fixed::rk_step;
use crate::solvers::hyper::HyperNet;
use crate::tensor::Tensor;
use crate::Result;

/// Adams-Bashforth order (2 or 3 supported).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AbOrder {
    Two,
    Three,
}

impl AbOrder {
    fn steps(self) -> usize {
        match self {
            AbOrder::Two => 2,
            AbOrder::Three => 3,
        }
    }

    /// AB coefficients for f_{k}, f_{k-1}, (f_{k-2}).
    fn coeffs(self) -> &'static [f32] {
        match self {
            AbOrder::Two => &[1.5, -0.5],
            AbOrder::Three => &[23.0 / 12.0, -16.0 / 12.0, 5.0 / 12.0],
        }
    }
}

/// Fixed-step Adams-Bashforth integration. Bootstraps the multistep history
/// with RK4 steps (standard practice), then runs at 1 NFE/step.
pub fn odeint_ab<F: VectorField + ?Sized>(
    f: &F,
    z0: &Tensor,
    s_span: (f32, f32),
    steps: usize,
    order: AbOrder,
) -> Result<Tensor> {
    assert!(steps >= order.steps(), "need at least {} steps", order.steps());
    let eps = (s_span.1 - s_span.0) / steps as f32;
    let rk4 = Tableau::rk4();
    let coeffs = order.coeffs();
    let p = order.steps();

    // history[0] = f at current step, history[1] = one step back, ...
    let mut z = z0.clone();
    let mut history: Vec<Tensor> = vec![f.eval(s_span.0, &z)];
    for k in 0..steps {
        let s = s_span.0 + k as f32 * eps;
        if history.len() < p {
            // bootstrap with RK4; record the derivative at the new point.
            // rk_step spins up a throwaway RkWorkspace, but this runs at
            // most (p-1) times per solve — the steady-state AB loop below
            // is plain axpy. Porting the history ring to a caller-held
            // workspace is a ROADMAP open item.
            z = rk_step(f, &rk4, s, &z, eps)?;
            history.insert(0, f.eval(s + eps, &z));
            continue;
        }
        let mut step = z.clone();
        for (c, fk) in coeffs.iter().zip(history.iter()) {
            step.axpy(eps * c, fk)?;
        }
        z = step;
        history.insert(0, f.eval(s + eps, &z));
        history.truncate(p);
    }
    Ok(z)
}

/// Adams-Bashforth-Moulton predictor-corrector (PECE): AB2 predicts, the
/// trapezoidal AM2 corrects. 2 NFE/step after bootstrap.
///
/// When `hyper` is given, its output corrects the *predictor* with the
/// ε^{p+1}-scaled term of eq. (5) — the §6 predictor-corrector hypersolver.
pub fn odeint_abm<F: VectorField + ?Sized, G: HyperNet + ?Sized>(
    f: &F,
    z0: &Tensor,
    s_span: (f32, f32),
    steps: usize,
    hyper: Option<&G>,
) -> Result<Tensor> {
    assert!(steps >= 2);
    let eps = (s_span.1 - s_span.0) / steps as f32;
    let rk4 = Tableau::rk4();

    let mut z = z0.clone();
    let mut f_prev: Option<Tensor> = None;
    let mut f_curr = f.eval(s_span.0, &z);
    for k in 0..steps {
        let s = s_span.0 + k as f32 * eps;
        match &f_prev {
            None => {
                // bootstrap one RK4 step
                let z_next = rk_step(f, &rk4, s, &z, eps)?;
                f_prev = Some(f_curr);
                f_curr = f.eval(s + eps, &z_next);
                z = z_next;
            }
            Some(fp) => {
                // predict: AB2 (+ optional hypersolver correction, order 2)
                let mut pred = z.clone();
                pred.axpy(eps * 1.5, &f_curr)?;
                pred.axpy(-eps * 0.5, fp)?;
                if let Some(g) = hyper {
                    let corr = g.eval(eps, s, &z, &f_curr);
                    pred.axpy(eps.powi(3), &corr)?;
                }
                // evaluate at the predicted point, correct with AM2
                let f_pred = f.eval(s + eps, &pred);
                let mut corr = z.clone();
                corr.axpy(eps * 0.5, &f_curr)?;
                corr.axpy(eps * 0.5, &f_pred)?;
                f_prev = Some(std::mem::replace(&mut f_curr, f.eval(s + eps, &corr)));
                z = corr;
            }
        }
    }
    Ok(z)
}

/// Convenience: ABM without a hypersolver.
pub fn odeint_abm_plain<F: VectorField + ?Sized>(
    f: &F,
    z0: &Tensor,
    s_span: (f32, f32),
    steps: usize,
) -> Result<Tensor> {
    odeint_abm(
        f,
        z0,
        s_span,
        steps,
        None::<&fn(f32, f32, &Tensor, &Tensor) -> Tensor>,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::Rotation;

    fn setup() -> (Rotation, Tensor, Tensor) {
        let f = Rotation { omega: 1.0 };
        let z0 = Tensor::new(&[1, 2], vec![1.0, 0.0]).unwrap();
        let exact = f.exact(&z0, 1.0);
        (f, z0, exact)
    }

    fn err(a: &Tensor, b: &Tensor) -> f32 {
        a.sub(b).unwrap().frobenius_norm()
    }

    #[test]
    fn ab2_second_order() {
        let (f, z0, exact) = setup();
        let e1 = err(&odeint_ab(&f, &z0, (0.0, 1.0), 16, AbOrder::Two).unwrap(), &exact);
        let e2 = err(&odeint_ab(&f, &z0, (0.0, 1.0), 32, AbOrder::Two).unwrap(), &exact);
        let order = (e1 / e2).log2();
        assert!(order > 1.5, "AB2 order {order} ({e1} -> {e2})");
    }

    #[test]
    fn ab3_beats_ab2() {
        let (f, z0, exact) = setup();
        let e2 = err(&odeint_ab(&f, &z0, (0.0, 1.0), 32, AbOrder::Two).unwrap(), &exact);
        let e3 = err(&odeint_ab(&f, &z0, (0.0, 1.0), 32, AbOrder::Three).unwrap(), &exact);
        assert!(e3 < e2, "AB3 {e3} vs AB2 {e2}");
    }

    #[test]
    fn abm_beats_ab2() {
        let (f, z0, exact) = setup();
        let e_ab = err(&odeint_ab(&f, &z0, (0.0, 1.0), 16, AbOrder::Two).unwrap(), &exact);
        let e_abm = err(&odeint_abm_plain(&f, &z0, (0.0, 1.0), 16).unwrap(), &exact);
        assert!(e_abm < e_ab, "ABM {e_abm} vs AB2 {e_ab}");
    }

    #[test]
    fn hyper_predictor_stays_consistent() {
        // Correcting the AB2 predictor with the exact Euler-residual Taylor
        // term perturbs only the O(ε³) predictor error, so the corrected
        // PECE result must stay within a small factor of the plain one (the
        // corrector dominates) and converge to the same answer as K grows.
        let (f, z0, exact) = setup();
        let omega = 1.0f32;
        let g = move |_e: f32, _s: f32, z: &Tensor, _dz: &Tensor| {
            z.scale(-0.5 * omega * omega)
        };
        for k in [6usize, 24] {
            let plain = odeint_abm_plain(&f, &z0, (0.0, 1.0), k).unwrap();
            let hyp = odeint_abm(&f, &z0, (0.0, 1.0), k, Some(&g)).unwrap();
            let (e_h, e_p) = (err(&hyp, &exact), err(&plain, &exact));
            assert!(
                e_h <= e_p * 2.0 + 1e-5,
                "K={k}: hyper {e_h} vs plain {e_p}"
            );
        }
        // and the hypersolved variant still converges at 2nd order overall
        let e1 = err(&odeint_abm(&f, &z0, (0.0, 1.0), 16, Some(&g)).unwrap(), &exact);
        let e2 = err(&odeint_abm(&f, &z0, (0.0, 1.0), 32, Some(&g)).unwrap(), &exact);
        assert!((e1 / e2).log2() > 1.5, "order {}", (e1 / e2).log2());
    }

    #[test]
    #[should_panic]
    fn too_few_steps_panics() {
        let (f, z0, _) = setup();
        let _ = odeint_ab(&f, &z0, (0.0, 1.0), 2, AbOrder::Three);
    }
}
