//! [`RkWorkspace`] — the solver loop's reusable state.
//!
//! One workspace holds everything an explicit RK / hypersolved / adaptive
//! integration needs per step: the stage-derivative buffers, the
//! stage-input state, the ψ accumulators, the hypersolver correction, a
//! double-buffered (current, next) state pair, and a nested
//! [`Workspace`](crate::tensor::Workspace) the vector field and hyper net
//! draw their layer activations from. Allocation happens only in
//! [`ensure`](RkWorkspace::ensure) when the state shape or stage count
//! changes; a warm workspace makes the whole solver loop allocation-free
//! (asserted by `tests/alloc_free.rs` with a counting global allocator).
//!
//! The runtime keeps one of these per (task, variant) queue and reuses it
//! across batches; the pure solver APIs spin up a throwaway one per call.

use crate::tensor::{Tensor, Workspace};

/// Reusable buffers for the RK-family solver loops. See the module docs.
#[derive(Debug)]
pub struct RkWorkspace {
    /// Stage derivatives r_1..r_p.
    pub(crate) stages: Vec<Tensor>,
    /// Stage input z + ε Σ a_ij r_j.
    pub(crate) zi: Tensor,
    /// ψ accumulator (Σ b_i r_i).
    pub(crate) acc: Tensor,
    /// Second accumulator (embedded-pair Σ b̂_i r_i in adaptive solvers).
    pub(crate) acc2: Tensor,
    /// Hypersolver correction g_ω output.
    pub(crate) corr: Tensor,
    /// Current state (the integration result lives here between steps).
    pub(crate) z_cur: Tensor,
    /// Next state (swapped with `z_cur` after each accepted step).
    pub(crate) z_next: Tensor,
    /// Scratch pool for `eval_into` / `forward_into` intermediates.
    pub(crate) scratch: Workspace,
    shape: Vec<usize>,
    n_stages: usize,
    ready: bool,
}

impl Default for RkWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

impl RkWorkspace {
    /// An empty workspace; buffers are sized lazily by
    /// [`ensure`](Self::ensure) on first use.
    pub fn new() -> RkWorkspace {
        let empty = || Tensor::zeros(&[0]);
        RkWorkspace {
            stages: Vec::new(),
            zi: empty(),
            acc: empty(),
            acc2: empty(),
            corr: empty(),
            z_cur: empty(),
            z_next: empty(),
            scratch: Workspace::new(),
            shape: Vec::new(),
            n_stages: 0,
            ready: false,
        }
    }

    /// Size every core buffer for states of `shape` and `n_stages` RK
    /// stages. No-op (and allocation-free) when already sized — the
    /// steady-state path. Buffer contents after a resize are zeros; after
    /// a no-op they are whatever the last solve left, which every user
    /// overwrites. `acc2`/`corr` are lazy (see [`ensure_acc2`](Self::ensure_acc2)
    /// / [`ensure_corr`](Self::ensure_corr)) so fixed-step non-hyper queues
    /// don't carry two dead state-sized buffers each.
    pub fn ensure(&mut self, shape: &[usize], n_stages: usize) {
        if self.ready
            && self.shape == shape
            && self.n_stages == n_stages
            // a failed solve over a misbehaving external field (wrong-shape
            // eval) can leave a stage buffer off-shape; heal it here
            && self.stages.iter().all(|st| st.shape() == shape)
        {
            return;
        }
        self.stages = (0..n_stages).map(|_| Tensor::zeros(shape)).collect();
        self.zi = Tensor::zeros(shape);
        self.acc = Tensor::zeros(shape);
        self.acc2 = Tensor::zeros(&[0]);
        self.corr = Tensor::zeros(&[0]);
        self.z_cur = Tensor::zeros(shape);
        self.z_next = Tensor::zeros(shape);
        self.shape = shape.to_vec();
        self.n_stages = n_stages;
        self.ready = true;
    }

    /// Size the embedded-pair accumulator (adaptive solvers only). No-op
    /// slice compare once sized — safe to call per solve.
    pub(crate) fn ensure_acc2(&mut self) {
        if self.acc2.shape() != self.shape.as_slice() {
            self.acc2 = Tensor::zeros(&self.shape);
        }
    }

    /// Size the hypersolver-correction buffer (hyper solvers only). No-op
    /// slice compare once sized — safe to call per step.
    pub(crate) fn ensure_corr(&mut self) {
        if self.corr.shape() != self.shape.as_slice() {
            self.corr = Tensor::zeros(&self.shape);
        }
    }

    /// The current integration state (the result after a `_ws` solve).
    pub fn state(&self) -> &Tensor {
        &self.z_cur
    }

    /// Promote `z_next` to the current state (post-step / on acceptance).
    pub(crate) fn swap(&mut self) {
        std::mem::swap(&mut self.z_cur, &mut self.z_next);
    }

    /// The nested tensor scratch pool (exposed for tests/introspection).
    pub fn scratch(&mut self) -> &mut Workspace {
        &mut self.scratch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ensure_is_idempotent_and_resizes() {
        let mut ws = RkWorkspace::new();
        ws.ensure(&[2, 3], 4);
        assert_eq!(ws.stages.len(), 4);
        assert_eq!(ws.z_cur.shape(), &[2, 3]);
        let ptr = ws.z_cur.data().as_ptr();
        ws.ensure(&[2, 3], 4); // no-op
        assert_eq!(ws.z_cur.data().as_ptr(), ptr, "no reallocation");
        ws.ensure(&[5], 2); // resize
        assert_eq!(ws.stages.len(), 2);
        assert_eq!(ws.z_cur.shape(), &[5]);
    }

    #[test]
    fn swap_exchanges_state_buffers() {
        let mut ws = RkWorkspace::new();
        ws.ensure(&[2], 1);
        ws.z_cur.fill(1.0);
        ws.z_next.fill(2.0);
        ws.swap();
        assert_eq!(ws.state().data(), &[2.0, 2.0]);
    }
}
