//! Fixed-step explicit RK integration over any [`VectorField`].

use crate::ode::VectorField;
use crate::solvers::butcher::Tableau;
use crate::tensor::Tensor;
use crate::Result;

/// Compute the stage derivatives r_1..r_p at (s, z).
pub fn rk_stages<F: VectorField + ?Sized>(
    f: &F,
    tab: &Tableau,
    s: f32,
    z: &Tensor,
    eps: f32,
) -> Result<Vec<Tensor>> {
    let mut stages: Vec<Tensor> = Vec::with_capacity(tab.stages());
    for i in 0..tab.stages() {
        let mut zi = z.clone();
        for (j, &aij) in tab.a[i].iter().enumerate() {
            if aij != 0.0 {
                zi.axpy(eps * aij, &stages[j])?;
            }
        }
        stages.push(f.eval(s + tab.c[i] * eps, &zi));
    }
    Ok(stages)
}

/// The update direction ψ = Σ b_i r_i (eq. 2).
pub fn psi<F: VectorField + ?Sized>(
    f: &F,
    tab: &Tableau,
    s: f32,
    z: &Tensor,
    eps: f32,
) -> Result<Tensor> {
    let stages = rk_stages(f, tab, s, z, eps)?;
    combine(z.shape(), &stages, &tab.b)
}

/// Σ b_i r_i without the state added (helper shared with adaptive).
pub(crate) fn combine(shape: &[usize], stages: &[Tensor], b: &[f32]) -> Result<Tensor> {
    let mut acc = Tensor::zeros(shape);
    for (bi, ri) in b.iter().zip(stages) {
        if *bi != 0.0 {
            acc.axpy(*bi, ri)?;
        }
    }
    Ok(acc)
}

/// One explicit RK step.
pub fn rk_step<F: VectorField + ?Sized>(
    f: &F,
    tab: &Tableau,
    s: f32,
    z: &Tensor,
    eps: f32,
) -> Result<Tensor> {
    let mut out = z.clone();
    out.axpy(eps, &psi(f, tab, s, z, eps)?)?;
    Ok(out)
}

/// Integrate over `s_span` with K equal steps; returns the terminal state.
/// NFE = stages × K.
pub fn odeint_fixed<F: VectorField + ?Sized>(
    f: &F,
    z0: &Tensor,
    s_span: (f32, f32),
    steps: usize,
    tab: &Tableau,
) -> Result<Tensor> {
    assert!(steps > 0, "need at least one step");
    let eps = (s_span.1 - s_span.0) / steps as f32;
    let mut z = z0.clone();
    for k in 0..steps {
        let s = s_span.0 + k as f32 * eps;
        z = rk_step(f, tab, s, &z, eps)?;
    }
    Ok(z)
}

/// As [`odeint_fixed`] but returns the full (K+1)-point trajectory.
pub fn odeint_fixed_traj<F: VectorField + ?Sized>(
    f: &F,
    z0: &Tensor,
    s_span: (f32, f32),
    steps: usize,
    tab: &Tableau,
) -> Result<Vec<Tensor>> {
    let eps = (s_span.1 - s_span.0) / steps as f32;
    let mut traj = Vec::with_capacity(steps + 1);
    traj.push(z0.clone());
    for k in 0..steps {
        let s = s_span.0 + k as f32 * eps;
        let next = rk_step(f, tab, s, traj.last().unwrap(), eps)?;
        traj.push(next);
    }
    Ok(traj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::{Decay, Rotation, TimeCosine};
    use crate::util::propkit::{check, gen_vec, prop_assert};

    #[test]
    fn euler_one_step_decay() {
        let f = Decay { lambda: -1.0 };
        let z0 = Tensor::full(&[1, 1], 1.0);
        let z1 = odeint_fixed(&f, &z0, (0.0, 0.1), 1, &Tableau::euler()).unwrap();
        assert!((z1.data()[0] - 0.9).abs() < 1e-6);
    }

    #[test]
    fn convergence_orders_on_rotation() {
        let f = Rotation { omega: 1.0 };
        let z0 = Tensor::new(&[1, 2], vec![1.0, 0.0]).unwrap();
        let exact = f.exact(&z0, 1.0);
        for (tab, expected) in [
            (Tableau::euler(), 1.0),
            (Tableau::midpoint(), 2.0),
            (Tableau::heun(), 2.0),
            (Tableau::alpha(0.4).unwrap(), 2.0),
            (Tableau::rk4(), 4.0),
        ] {
            let err_k =
                |k: usize| -> f32 {
                    odeint_fixed(&f, &z0, (0.0, 1.0), k, &tab)
                        .unwrap()
                        .sub(&exact)
                        .unwrap()
                        .frobenius_norm()
                };
            let (e8, e16) = (err_k(8), err_k(16));
            if e16 > 5e-6 {
                let order = (e8 / e16).log2();
                assert!(
                    order > expected - 0.6,
                    "{}: order {order} (e8={e8}, e16={e16})",
                    tab.name
                );
            }
        }
    }

    #[test]
    fn stage_times_respected() {
        // TimeCosine is state-independent: only correct c_i give 2nd order.
        // NB: integrate over a PARTIAL period — over the full period both
        // left-Riemann and midpoint quadratures are spectrally exact.
        let f = TimeCosine;
        let z0 = Tensor::zeros(&[1, 1]);
        let exact = f.exact(&z0, 0.25);
        let e_mid = odeint_fixed(&f, &z0, (0.0, 0.25), 8, &Tableau::midpoint())
            .unwrap()
            .sub(&exact)
            .unwrap()
            .frobenius_norm();
        let e_eul = odeint_fixed(&f, &z0, (0.0, 0.25), 8, &Tableau::euler())
            .unwrap()
            .sub(&exact)
            .unwrap()
            .frobenius_norm();
        assert!(e_mid < e_eul * 0.51, "midpoint {e_mid} vs euler {e_eul}");
    }

    #[test]
    fn trajectory_endpoints_match() {
        let f = Rotation { omega: 2.0 };
        let z0 = Tensor::new(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let traj = odeint_fixed_traj(&f, &z0, (0.0, 1.0), 10, &Tableau::rk4()).unwrap();
        assert_eq!(traj.len(), 11);
        let direct = odeint_fixed(&f, &z0, (0.0, 1.0), 10, &Tableau::rk4()).unwrap();
        assert_eq!(traj[10], direct);
        assert_eq!(traj[0], z0);
    }

    #[test]
    fn backward_integration_property() {
        check("forward then backward returns to start", 20, |rng| {
            let z0 = Tensor::new(&[1, 2], gen_vec(rng, 2, 1.0)).unwrap();
            let f = Rotation { omega: 1.0 };
            let z1 = odeint_fixed(&f, &z0, (0.0, 1.0), 32, &Tableau::rk4()).unwrap();
            let back = odeint_fixed(&f, &z1, (1.0, 0.0), 32, &Tableau::rk4()).unwrap();
            let err = back.sub(&z0).unwrap().frobenius_norm();
            prop_assert(err < 1e-4, format!("round trip error {err}"))
        });
    }

    #[test]
    fn psi_times_eps_is_step() {
        let f = Rotation { omega: 1.0 };
        let z0 = Tensor::new(&[1, 2], vec![0.5, -0.5]).unwrap();
        for tab in [Tableau::euler(), Tableau::heun(), Tableau::rk4()] {
            let p = psi(&f, &tab, 0.0, &z0, 0.2).unwrap();
            let mut manual = z0.clone();
            manual.axpy(0.2, &p).unwrap();
            let step = rk_step(&f, &tab, 0.0, &z0, 0.2).unwrap();
            assert_eq!(manual, step);
        }
    }
}
