//! Fixed-step explicit RK integration over any [`VectorField`].
//!
//! The stepping machinery is written against [`RkWorkspace`]: stage
//! derivatives, stage inputs, and the (current, next) state pair all live
//! in reusable buffers and the field is evaluated through
//! `VectorField::eval_into`, so the loop performs zero steady-state heap
//! allocations. The original pure APIs (`rk_stages`, `psi`, `rk_step`,
//! `odeint_fixed`) remain as thin wrappers that spin up a throwaway
//! workspace — same signatures, bit-identical results.

use crate::ode::VectorField;
use crate::solvers::butcher::Tableau;
use crate::solvers::workspace::RkWorkspace;
use crate::tensor::Tensor;
use crate::Result;

/// Fill `ws.stages[..p]` with the stage derivatives r_1..r_p at
/// (s, ws.z_cur). `ws` must be `ensure`d for the state shape and
/// `tab.stages()`.
pub(crate) fn rk_stages_core<F: VectorField + ?Sized>(
    f: &F,
    tab: &Tableau,
    s: f32,
    eps: f32,
    ws: &mut RkWorkspace,
) -> Result<()> {
    for i in 0..tab.stages() {
        ws.zi.copy_from(&ws.z_cur);
        for (j, &aij) in tab.a[i].iter().enumerate() {
            if aij != 0.0 {
                ws.zi.axpy(eps * aij, &ws.stages[j])?;
            }
        }
        f.eval_into(s + tab.c[i] * eps, &ws.zi, &mut ws.stages[i], &mut ws.scratch);
    }
    Ok(())
}

/// Σ b_i r_i into `out` (fully overwritten) — the workspace form of
/// [`combine`], shared with the adaptive and hypersolved steppers.
pub fn combine_into(stages: &[Tensor], b: &[f32], out: &mut Tensor) -> Result<()> {
    out.fill(0.0);
    for (bi, ri) in b.iter().zip(stages) {
        if *bi != 0.0 {
            out.axpy(*bi, ri)?;
        }
    }
    Ok(())
}

/// Σ b_i r_i without the state added (allocating helper).
pub(crate) fn combine(shape: &[usize], stages: &[Tensor], b: &[f32]) -> Result<Tensor> {
    let mut acc = Tensor::zeros(shape);
    combine_into(stages, b, &mut acc)?;
    Ok(acc)
}

/// One explicit RK step on the workspace: advances `ws.z_cur` by ε·ψ.
pub(crate) fn rk_step_core<F: VectorField + ?Sized>(
    f: &F,
    tab: &Tableau,
    s: f32,
    eps: f32,
    ws: &mut RkWorkspace,
) -> Result<()> {
    rk_stages_core(f, tab, s, eps, ws)?;
    let p = tab.stages();
    combine_into(&ws.stages[..p], &tab.b, &mut ws.acc)?;
    ws.z_next.copy_from(&ws.z_cur);
    ws.z_next.axpy(eps, &ws.acc)?;
    ws.swap();
    Ok(())
}

/// Compute the stage derivatives r_1..r_p at (s, z).
pub fn rk_stages<F: VectorField + ?Sized>(
    f: &F,
    tab: &Tableau,
    s: f32,
    z: &Tensor,
    eps: f32,
) -> Result<Vec<Tensor>> {
    let mut ws = RkWorkspace::new();
    ws.ensure(z.shape(), tab.stages());
    ws.z_cur.copy_from(z);
    rk_stages_core(f, tab, s, eps, &mut ws)?;
    Ok(std::mem::take(&mut ws.stages))
}

/// The update direction ψ = Σ b_i r_i (eq. 2).
pub fn psi<F: VectorField + ?Sized>(
    f: &F,
    tab: &Tableau,
    s: f32,
    z: &Tensor,
    eps: f32,
) -> Result<Tensor> {
    let stages = rk_stages(f, tab, s, z, eps)?;
    combine(z.shape(), &stages, &tab.b)
}

/// One explicit RK step.
pub fn rk_step<F: VectorField + ?Sized>(
    f: &F,
    tab: &Tableau,
    s: f32,
    z: &Tensor,
    eps: f32,
) -> Result<Tensor> {
    let mut ws = RkWorkspace::new();
    ws.ensure(z.shape(), tab.stages());
    ws.z_cur.copy_from(z);
    rk_step_core(f, tab, s, eps, &mut ws)?;
    Ok(ws.state().clone())
}

/// [`odeint_fixed`] on a caller-held workspace: the allocation-free entry
/// point the runtime uses. Returns a borrow of the terminal state inside
/// `ws` (clone it to keep it past the next solve).
pub fn odeint_fixed_ws<'a, F: VectorField + ?Sized>(
    f: &F,
    z0: &Tensor,
    s_span: (f32, f32),
    steps: usize,
    tab: &Tableau,
    ws: &'a mut RkWorkspace,
) -> Result<&'a Tensor> {
    assert!(steps > 0, "need at least one step");
    let eps = (s_span.1 - s_span.0) / steps as f32;
    ws.ensure(z0.shape(), tab.stages());
    ws.z_cur.copy_from(z0);
    for k in 0..steps {
        let s = s_span.0 + k as f32 * eps;
        rk_step_core(f, tab, s, eps, ws)?;
    }
    Ok(ws.state())
}

/// Integrate over `s_span` with K equal steps; returns the terminal state.
/// NFE = stages × K.
pub fn odeint_fixed<F: VectorField + ?Sized>(
    f: &F,
    z0: &Tensor,
    s_span: (f32, f32),
    steps: usize,
    tab: &Tableau,
) -> Result<Tensor> {
    let mut ws = RkWorkspace::new();
    Ok(odeint_fixed_ws(f, z0, s_span, steps, tab, &mut ws)?.clone())
}

/// As [`odeint_fixed`] but returns the full (K+1)-point trajectory.
pub fn odeint_fixed_traj<F: VectorField + ?Sized>(
    f: &F,
    z0: &Tensor,
    s_span: (f32, f32),
    steps: usize,
    tab: &Tableau,
) -> Result<Vec<Tensor>> {
    let eps = (s_span.1 - s_span.0) / steps as f32;
    let mut ws = RkWorkspace::new();
    ws.ensure(z0.shape(), tab.stages());
    ws.z_cur.copy_from(z0);
    let mut traj = Vec::with_capacity(steps + 1);
    traj.push(z0.clone());
    for k in 0..steps {
        let s = s_span.0 + k as f32 * eps;
        rk_step_core(f, tab, s, eps, &mut ws)?;
        traj.push(ws.state().clone());
    }
    Ok(traj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::{Decay, Rotation, TimeCosine};
    use crate::util::propkit::{check, gen_vec, prop_assert};

    #[test]
    fn euler_one_step_decay() {
        let f = Decay { lambda: -1.0 };
        let z0 = Tensor::full(&[1, 1], 1.0);
        let z1 = odeint_fixed(&f, &z0, (0.0, 0.1), 1, &Tableau::euler()).unwrap();
        assert!((z1.data()[0] - 0.9).abs() < 1e-6);
    }

    #[test]
    fn convergence_orders_on_rotation() {
        let f = Rotation { omega: 1.0 };
        let z0 = Tensor::new(&[1, 2], vec![1.0, 0.0]).unwrap();
        let exact = f.exact(&z0, 1.0);
        for (tab, expected) in [
            (Tableau::euler(), 1.0),
            (Tableau::midpoint(), 2.0),
            (Tableau::heun(), 2.0),
            (Tableau::alpha(0.4).unwrap(), 2.0),
            (Tableau::rk4(), 4.0),
        ] {
            let err_k =
                |k: usize| -> f32 {
                    odeint_fixed(&f, &z0, (0.0, 1.0), k, &tab)
                        .unwrap()
                        .sub(&exact)
                        .unwrap()
                        .frobenius_norm()
                };
            let (e8, e16) = (err_k(8), err_k(16));
            if e16 > 5e-6 {
                let order = (e8 / e16).log2();
                assert!(
                    order > expected - 0.6,
                    "{}: order {order} (e8={e8}, e16={e16})",
                    tab.name
                );
            }
        }
    }

    #[test]
    fn stage_times_respected() {
        // TimeCosine is state-independent: only correct c_i give 2nd order.
        // NB: integrate over a PARTIAL period — over the full period both
        // left-Riemann and midpoint quadratures are spectrally exact.
        let f = TimeCosine;
        let z0 = Tensor::zeros(&[1, 1]);
        let exact = f.exact(&z0, 0.25);
        let e_mid = odeint_fixed(&f, &z0, (0.0, 0.25), 8, &Tableau::midpoint())
            .unwrap()
            .sub(&exact)
            .unwrap()
            .frobenius_norm();
        let e_eul = odeint_fixed(&f, &z0, (0.0, 0.25), 8, &Tableau::euler())
            .unwrap()
            .sub(&exact)
            .unwrap()
            .frobenius_norm();
        assert!(e_mid < e_eul * 0.51, "midpoint {e_mid} vs euler {e_eul}");
    }

    #[test]
    fn trajectory_endpoints_match() {
        let f = Rotation { omega: 2.0 };
        let z0 = Tensor::new(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let traj = odeint_fixed_traj(&f, &z0, (0.0, 1.0), 10, &Tableau::rk4()).unwrap();
        assert_eq!(traj.len(), 11);
        let direct = odeint_fixed(&f, &z0, (0.0, 1.0), 10, &Tableau::rk4()).unwrap();
        assert_eq!(traj[10], direct);
        assert_eq!(traj[0], z0);
    }

    #[test]
    fn backward_integration_property() {
        check("forward then backward returns to start", 20, |rng| {
            let z0 = Tensor::new(&[1, 2], gen_vec(rng, 2, 1.0)).unwrap();
            let f = Rotation { omega: 1.0 };
            let z1 = odeint_fixed(&f, &z0, (0.0, 1.0), 32, &Tableau::rk4()).unwrap();
            let back = odeint_fixed(&f, &z1, (1.0, 0.0), 32, &Tableau::rk4()).unwrap();
            let err = back.sub(&z0).unwrap().frobenius_norm();
            prop_assert(err < 1e-4, format!("round trip error {err}"))
        });
    }

    #[test]
    fn psi_times_eps_is_step() {
        let f = Rotation { omega: 1.0 };
        let z0 = Tensor::new(&[1, 2], vec![0.5, -0.5]).unwrap();
        for tab in [Tableau::euler(), Tableau::heun(), Tableau::rk4()] {
            let p = psi(&f, &tab, 0.0, &z0, 0.2).unwrap();
            let mut manual = z0.clone();
            manual.axpy(0.2, &p).unwrap();
            let step = rk_step(&f, &tab, 0.0, &z0, 0.2).unwrap();
            assert_eq!(manual, step);
        }
    }
}
