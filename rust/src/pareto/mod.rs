//! The Pareto evaluation subsystem — the repo's measurement backbone for
//! the paper's headline claim (accuracy-vs-NFE/wall-clock pareto
//! efficiency, §4 figs. 3/9).
//!
//! A [`GridConfig`] names a (solver × step-count/tolerance × task × state
//! distribution) grid; the pipeline trains the hypersolver point by
//! residual fitting ([`crate::train`]), sweeps every cell through the
//! allocation-free `_ws` solver kernels *and* through the full serving
//! coordinator (a native-backend [`Engine`] via `Engine::submit` with the
//! variant pinned — batching/queueing included), computes
//! terminal/trajectory error
//! against a tight-tolerance dopri5 reference, extracts dominance-correct
//! Pareto fronts, and emits one `BENCH_pareto.json` in the shared
//! [`benchkit`](crate::util::benchkit) schema (plus a rolling
//! `BENCH_trajectory.json` entry, so successive PRs accumulate a bench
//! trajectory). The `hyperbench` binary drives it; `--smoke` runs a
//! CI-sized grid and asserts the trained HyperEuler lands on the NFE
//! front ahead of Euler.
//!
//! * [`grid`] — the grid config, task specs (analytic + synthetic MLP
//!   fields), and the shared state samplers.
//! * [`sweep`] — kernel and serve sweeps plus the grid-wide artifact
//!   exporter ([`sweep::write_sweep_artifacts`]).
//! * [`front`] — exact non-dominated-set extraction.
//! * [`report`] — the pipeline, the JSON document, dominance checks, and
//!   table rendering.
//!
//! [`Engine`]: crate::coordinator::Engine
//! [`GridConfig`]: grid::GridConfig

pub mod front;
pub mod grid;
pub mod report;
pub mod sweep;

pub use front::{dominates, front_of, non_dominated};
pub use grid::{GridConfig, TaskSpec};
pub use report::{
    check_same_nfe_dominance, pareto_doc, render_plane, run_pipeline,
    serve_speedup_vs_tightest_dopri5, trajectory_entry, DominanceCheck, TaskReport,
    TrainSummary,
};
pub use sweep::{
    kernel_sweep, method_label, serve_sweep, write_sweep_artifacts, SweepPoint,
};
