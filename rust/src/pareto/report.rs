//! The pipeline (train → sweep → export → serve-sweep), the unified
//! `BENCH_pareto.json` document, human-readable tables, and the dominance
//! checks the smoke mode and the end-to-end test assert.

use std::path::Path;

use crate::pareto::front::front_of;
use crate::pareto::grid::{GridConfig, TaskSpec};
use crate::pareto::sweep::{
    kernel_sweep, method_label, serve_sweep, write_sweep_artifacts, SweepPoint,
};
use crate::runtime::Manifest;
use crate::train::{train_hypersolver, FineRef, TrainConfig};
use crate::util::benchkit::{self, Table};
use crate::util::json::{self, Value};
use crate::util::prng::Rng;
use crate::{Error, Result};

/// What training the task's hypersolver point produced.
#[derive(Clone, Debug)]
pub struct TrainSummary {
    pub steps_run: usize,
    /// Held-out one-step improvement factor over the base solver.
    pub improvement: f32,
    pub err_base: f32,
    pub err_hyper: f32,
    /// Best validation loss δ (exported as the manifest `delta`).
    pub delta: f32,
    pub wall_secs: f64,
}

/// Everything the pipeline measured for one task.
#[derive(Clone, Debug)]
pub struct TaskReport {
    pub task: String,
    /// Kernel sweep on box-uniform states.
    pub kernel_box: Vec<SweepPoint>,
    /// Kernel sweep on trajectory-sampled states (the serving
    /// distribution g was trained for).
    pub kernel_traj: Vec<SweepPoint>,
    /// Full serve-path sweep through the coordinator (`Engine::submit`,
    /// native backend).
    pub serve: Vec<SweepPoint>,
    pub train: TrainSummary,
}

/// Train the hypersolver point and run every sweep for every task,
/// exporting the servable grid artifacts into `artifacts_dir` (tasks
/// merge into one manifest — `hypersolverd serve --backend native
/// --artifacts <dir>` works on the result).
pub fn run_pipeline(
    grid: &GridConfig,
    tasks: &[TaskSpec],
    artifacts_dir: &Path,
) -> Result<Vec<TaskReport>> {
    grid.validate()?;
    if tasks.is_empty() {
        return Err(Error::Other("pareto pipeline: no tasks".into()));
    }
    let mut reports = Vec::with_capacity(tasks.len());
    for (ti, spec) in tasks.iter().enumerate() {
        let d = spec.field.state_dim();
        let traj_sampler = grid.traj_sampler(d);
        let cfg = TrainConfig {
            solver: grid.hyper_base.clone(),
            hidden: grid.train_hidden.clone(),
            steps: grid.train_steps,
            seed: grid.seed.wrapping_add(ti as u64 * 7919),
            s_span: grid.span,
            k: grid.hyper_k,
            fine: FineRef::Rk4Substeps(8),
            sampler: traj_sampler.clone(),
            stop_at_improvement: grid.train_stop_at,
            log: grid.log,
            ..TrainConfig::default()
        };
        if grid.log {
            println!(
                "[{}] training hyper{} at k={} ({} max steps, hidden {:?})",
                spec.name, grid.hyper_base, grid.hyper_k, grid.train_steps, grid.train_hidden
            );
        }
        let (g, treport) = train_hypersolver(&spec.field, &cfg)?;
        if grid.log {
            println!(
                "[{}] trained in {:.1}s: one-step improvement {:.1}× \
                 (base {:.3e} → hyper {:.3e})",
                spec.name,
                treport.wall_secs,
                treport.improvement,
                treport.err_base,
                treport.err_hyper
            );
        }

        // sweep batches: one box draw, one trajectory draw, same stream
        let mut rng = Rng::new(grid.seed ^ 0xA11C_E5ED).fold_in(ti as u64);
        let z_box = grid.box_sampler(d).sample_for(&spec.field, grid.batch, &mut rng)?;
        let z_traj = traj_sampler.sample_for(&spec.field, grid.batch, &mut rng)?;
        let kernel_box = kernel_sweep(&spec.name, &spec.field, &g, grid, &z_box, "box")?;
        let kernel_traj =
            kernel_sweep(&spec.name, &spec.field, &g, grid, &z_traj, "trajectory")?;

        write_sweep_artifacts(
            artifacts_dir,
            &spec.name,
            &spec.field,
            &g,
            grid,
            treport.best_val_loss,
            &kernel_box,
        )?;
        let manifest = Manifest::load(artifacts_dir)?;
        let serve = serve_sweep(&manifest, &spec.name, grid)?;
        if grid.log {
            println!("[{}] swept {} kernel cells × 2 state sets + {} serve variants",
                spec.name, kernel_box.len(), serve.len());
        }

        reports.push(TaskReport {
            task: spec.name.clone(),
            kernel_box,
            kernel_traj,
            serve,
            train: TrainSummary {
                steps_run: treport.steps_run,
                improvement: treport.improvement,
                err_base: treport.err_base,
                err_hyper: treport.err_hyper,
                delta: treport.best_val_loss,
                wall_secs: treport.wall_secs,
            },
        });
    }
    Ok(reports)
}

// ---------------------------------------------------------------------------
// JSON document (shared benchkit schema)
// ---------------------------------------------------------------------------

fn point_json(p: &SweepPoint) -> Value {
    let mut fields = vec![
        ("label", json::s(&p.label)),
        ("solver", json::s(&p.solver)),
        ("k", json::num(p.k as f64)),
        ("hyper", Value::Bool(p.hyper)),
        ("nfe", json::num(p.nfe)),
        ("g_evals", json::num(p.g_evals as f64)),
        ("err", json::num(p.err)),
        ("mape", json::num(p.mape)),
        ("wall_us", json::num(p.wall_us)),
    ];
    if let Some(t) = p.tol {
        fields.push(("tol", json::num(t as f64)));
    }
    if let Some(e) = p.err_traj {
        fields.push(("err_traj", json::num(e)));
    }
    json::obj(fields)
}

fn labels_json(points: &[SweepPoint], idx: &[usize]) -> Value {
    Value::Arr(idx.iter().map(|&i| json::s(&points[i].label)).collect())
}

/// One Pareto plane: its points plus the extracted fronts on both cost
/// axes (field NFE, measured wall-clock).
fn plane_json(points: &[SweepPoint], states: &str) -> Value {
    let nfe_front = front_of(points, |p| (p.nfe, p.err));
    let wall_front = front_of(points, |p| (p.wall_us, p.err));
    json::obj(vec![
        ("states", json::s(states)),
        ("points", Value::Arr(points.iter().map(point_json).collect())),
        ("front_nfe", labels_json(points, &nfe_front)),
        ("front_wall", labels_json(points, &wall_front)),
    ])
}

fn task_json(r: &TaskReport) -> Value {
    json::obj(vec![
        ("task", json::s(&r.task)),
        (
            "train",
            json::obj(vec![
                ("steps_run", json::num(r.train.steps_run as f64)),
                ("improvement", json::num(r.train.improvement as f64)),
                ("err_base", json::num(r.train.err_base as f64)),
                ("err_hyper", json::num(r.train.err_hyper as f64)),
                ("delta", json::num(r.train.delta as f64)),
                ("wall_secs", json::num(r.train.wall_secs)),
            ]),
        ),
        ("kernel_box", plane_json(&r.kernel_box, "box")),
        ("kernel_trajectory", plane_json(&r.kernel_traj, "trajectory")),
        ("serve", plane_json(&r.serve, "box")),
    ])
}

fn grid_json(grid: &GridConfig) -> Value {
    json::obj(vec![
        (
            "solvers",
            Value::Arr(grid.solvers.iter().map(|s| json::s(s)).collect()),
        ),
        (
            "ks",
            Value::Arr(grid.ks.iter().map(|&k| json::num(k as f64)).collect()),
        ),
        (
            "tols",
            Value::Arr(grid.tols.iter().map(|&t| json::num(t as f64)).collect()),
        ),
        ("hyper_base", json::s(&grid.hyper_base)),
        ("hyper_k", json::num(grid.hyper_k as f64)),
        ("batch", json::num(grid.batch as f64)),
        ("seed", json::num(grid.seed as f64)),
        (
            "span",
            Value::Arr(vec![
                json::num(grid.span.0 as f64),
                json::num(grid.span.1 as f64),
            ]),
        ),
        ("sample_box", json::num(grid.sample_box as f64)),
        ("traj_mesh_k", json::num(grid.traj_mesh_k as f64)),
        ("traj_checkpoints", json::num(grid.traj_checkpoints as f64)),
        ("ref_tol", json::num(grid.ref_tol as f64)),
        ("train_steps", json::num(grid.train_steps as f64)),
        (
            "train_hidden",
            Value::Arr(
                grid.train_hidden
                    .iter()
                    .map(|&h| json::num(h as f64))
                    .collect(),
            ),
        ),
        ("train_stop_at", json::num(grid.train_stop_at as f64)),
    ])
}

/// The complete `BENCH_pareto.json` document in the shared bench schema.
pub fn pareto_doc(grid: &GridConfig, reports: &[TaskReport]) -> Value {
    benchkit::bench_doc(
        "hyperbench_pareto",
        vec![
            ("grid", grid_json(grid)),
            ("tasks", Value::Arr(reports.iter().map(task_json).collect())),
        ],
    )
}

/// Headline numbers for the rolling bench trajectory: per task, where the
/// trained hypersolver landed relative to its same-NFE rivals and how its
/// serve-path wall-clock compares to the tightest dopri5 variant.
pub fn trajectory_entry(grid: &GridConfig, reports: &[TaskReport]) -> Value {
    let tasks: Vec<Value> = reports
        .iter()
        .map(|r| {
            let chk = check_same_nfe_dominance(&r.kernel_traj, grid).ok();
            let mut fields = vec![
                ("task", json::s(&r.task)),
                ("improvement", json::num(r.train.improvement as f64)),
            ];
            if let Some(c) = chk {
                fields.push(("err_hyper", json::num(c.err_hyper)));
                if let Some(e) = c.err_euler {
                    fields.push(("err_euler_same_nfe", json::num(e)));
                }
                if let Some(e) = c.err_midpoint {
                    fields.push(("err_midpoint_same_nfe", json::num(e)));
                }
                fields.push(("hyper_on_nfe_front", Value::Bool(c.on_nfe_front)));
            }
            if let Some(sp) = serve_speedup_vs_tightest_dopri5(&r.serve, grid) {
                fields.push(("serve_speedup_vs_dopri5", json::num(sp)));
            }
            json::obj(fields)
        })
        .collect();
    benchkit::bench_doc("hyperbench_pareto", vec![("tasks", Value::Arr(tasks))])
}

// ---------------------------------------------------------------------------
// Dominance checks (smoke mode + e2e test)
// ---------------------------------------------------------------------------

/// Where the trained hypersolver point stands against its same-field-NFE
/// classical rivals on one Pareto plane.
#[derive(Clone, Debug)]
pub struct DominanceCheck {
    pub hyper_label: String,
    pub err_hyper: f64,
    /// Error of euler at the same field NFE, when that cell is on the grid.
    pub err_euler: Option<f64>,
    /// Error of midpoint at the same field NFE, when on the grid.
    pub err_midpoint: Option<f64>,
    /// Is the hyper point a member of the NFE-vs-error Pareto front?
    pub on_nfe_front: bool,
}

impl DominanceCheck {
    /// Strictly better than euler at equal field NFE (same cost axis
    /// value → strictly lower error = dominance).
    pub fn dominates_same_nfe_euler(&self) -> bool {
        self.err_euler.map(|e| self.err_hyper < e).unwrap_or(false)
    }

    pub fn dominates_same_nfe_midpoint(&self) -> bool {
        self.err_midpoint.map(|e| self.err_hyper < e).unwrap_or(false)
    }
}

/// Locate the trained hyper point in `points` and compare it to the
/// classical cells at the same field NFE.
pub fn check_same_nfe_dominance(
    points: &[SweepPoint],
    grid: &GridConfig,
) -> Result<DominanceCheck> {
    let hyper_label = method_label(&grid.hyper_base, grid.hyper_k, true, None);
    let hp = points
        .iter()
        .find(|p| p.label == hyper_label)
        .ok_or_else(|| Error::Other(format!("no {hyper_label} point in the sweep")))?;
    let same_nfe = |p: &&SweepPoint| !p.hyper && p.tol.is_none() && p.nfe == hp.nfe;
    let err_euler = points
        .iter()
        .find(|p| same_nfe(p) && p.solver == "euler")
        .map(|p| p.err);
    let err_midpoint = points
        .iter()
        .find(|p| same_nfe(p) && p.solver == "midpoint")
        .map(|p| p.err);
    let front = front_of(points, |p| (p.nfe, p.err));
    let on_nfe_front = front.iter().any(|&i| points[i].label == hyper_label);
    Ok(DominanceCheck {
        hyper_label,
        err_hyper: hp.err,
        err_euler,
        err_midpoint,
        on_nfe_front,
    })
}

/// Serve-path wall-clock of the tightest dopri5 variant divided by the
/// hyper variant's — the paper's end-to-end speedup headline.
pub fn serve_speedup_vs_tightest_dopri5(
    serve: &[SweepPoint],
    grid: &GridConfig,
) -> Option<f64> {
    let hyper_label = method_label(&grid.hyper_base, grid.hyper_k, true, None);
    let hp = serve.iter().find(|p| p.label == hyper_label)?;
    let d5 = serve
        .iter()
        .filter(|p| p.tol.is_some())
        .min_by(|a, b| a.tol.unwrap().partial_cmp(&b.tol.unwrap()).unwrap())?;
    Some(d5.wall_us / hp.wall_us.max(1e-9))
}

// ---------------------------------------------------------------------------
// Human-readable rendering
// ---------------------------------------------------------------------------

/// Aligned table of one Pareto plane, front membership marked per axis.
pub fn render_plane(title: &str, points: &[SweepPoint]) -> String {
    let nfe_front = front_of(points, |p| (p.nfe, p.err));
    let wall_front = front_of(points, |p| (p.wall_us, p.err));
    let mut t = Table::new(&[
        "method", "NFE", "g", "err", "err_traj", "wall µs", "front(NFE)", "front(wall)",
    ]);
    for (i, p) in points.iter().enumerate() {
        t.row(&[
            p.label.clone(),
            format!("{:.0}", p.nfe),
            p.g_evals.to_string(),
            benchkit::fmt_sci(p.err),
            p.err_traj.map(benchkit::fmt_sci).unwrap_or_else(|| "-".into()),
            format!("{:.1}", p.wall_us),
            if nfe_front.contains(&i) { "*".into() } else { String::new() },
            if wall_front.contains(&i) { "*".into() } else { String::new() },
        ]);
    }
    format!("{title}\n{}", t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(label: &str, solver: &str, k: usize, hyper: bool, nfe: f64, err: f64) -> SweepPoint {
        SweepPoint {
            task: "t".into(),
            states: "box".into(),
            label: label.into(),
            solver: solver.into(),
            k,
            tol: None,
            hyper,
            nfe,
            g_evals: if hyper { k as u64 } else { 0 },
            err,
            mape: err,
            err_traj: None,
            wall_us: 1.0,
        }
    }

    fn smoke_grid() -> GridConfig {
        GridConfig::smoke()
    }

    #[test]
    fn dominance_check_reads_same_nfe_rivals() {
        let grid = smoke_grid(); // hyper_k = 2, base euler
        let points = vec![
            pt("euler_k1", "euler", 1, false, 1.0, 0.9),
            pt("euler_k2", "euler", 2, false, 2.0, 0.5),
            pt("midpoint_k1", "midpoint", 1, false, 2.0, 0.4),
            pt("hypereuler_k2", "euler", 2, true, 2.0, 0.05),
        ];
        let c = check_same_nfe_dominance(&points, &grid).unwrap();
        assert_eq!(c.hyper_label, "hypereuler_k2");
        assert_eq!(c.err_euler, Some(0.5));
        assert_eq!(c.err_midpoint, Some(0.4));
        assert!(c.dominates_same_nfe_euler());
        assert!(c.dominates_same_nfe_midpoint());
        assert!(c.on_nfe_front);
        // a worse hyper point loses front membership and dominance
        let mut worse = points.clone();
        worse[3].err = 0.95;
        let c = check_same_nfe_dominance(&worse, &grid).unwrap();
        assert!(!c.dominates_same_nfe_euler());
        assert!(!c.on_nfe_front);
        // a missing hyper point is an error, not a silent pass
        assert!(check_same_nfe_dominance(&points[..3], &grid).is_err());
    }

    #[test]
    fn serve_speedup_picks_tightest_tolerance() {
        let grid = smoke_grid();
        let mut d5a = pt("dopri5_1e-3", "dopri5", 0, false, 30.0, 1e-3);
        d5a.tol = Some(1e-3);
        d5a.wall_us = 50.0;
        let mut d5b = pt("dopri5_1e-5", "dopri5", 0, false, 80.0, 1e-5);
        d5b.tol = Some(1e-5);
        d5b.wall_us = 200.0;
        let mut hp = pt("hypereuler_k2", "euler", 2, true, 2.0, 0.05);
        hp.wall_us = 10.0;
        let serve = vec![d5a, hp, d5b];
        let sp = serve_speedup_vs_tightest_dopri5(&serve, &grid).unwrap();
        assert!((sp - 20.0).abs() < 1e-9, "tightest is 1e-5 at 200µs: {sp}");
    }

    #[test]
    fn doc_round_trips_and_carries_fronts() {
        let grid = smoke_grid();
        let report = TaskReport {
            task: "vdp".into(),
            kernel_box: vec![
                pt("euler_k2", "euler", 2, false, 2.0, 0.5),
                pt("hypereuler_k2", "euler", 2, true, 2.0, 0.05),
            ],
            kernel_traj: vec![pt("euler_k2", "euler", 2, false, 2.0, 0.6)],
            serve: vec![pt("euler_k2", "euler", 2, false, 2.0, 0.5)],
            train: TrainSummary {
                steps_run: 10,
                improvement: 5.0,
                err_base: 0.5,
                err_hyper: 0.1,
                delta: 0.01,
                wall_secs: 1.0,
            },
        };
        let doc = pareto_doc(&grid, &[report]);
        let back = json::parse(&json::to_string(&doc)).unwrap();
        assert_eq!(back.get("bench").unwrap().as_str(), Some("hyperbench_pareto"));
        let tasks = back.get("tasks").unwrap().as_arr().unwrap();
        let plane = tasks[0].get("kernel_box").unwrap();
        let front = plane.get("front_nfe").unwrap().as_arr().unwrap();
        // hyper dominates euler at equal NFE → it alone is the front
        assert_eq!(front.len(), 1);
        assert_eq!(front[0].as_str(), Some("hypereuler_k2"));
        assert!(tasks[0].get("train").unwrap().get("improvement").is_some());
        // the grid block makes the run reproducible
        assert!(back.get("grid").unwrap().get("seed").is_some());
    }

    #[test]
    fn plane_renders_with_front_markers() {
        let points = vec![
            pt("euler_k2", "euler", 2, false, 2.0, 0.5),
            pt("hypereuler_k2", "euler", 2, true, 2.0, 0.05),
        ];
        let s = render_plane("kernel (box)", &points);
        assert!(s.contains("hypereuler_k2"));
        assert!(s.contains("front(NFE)"));
    }
}
