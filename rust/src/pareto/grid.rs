//! The sweep grid: which (solver × step-count/tolerance × task × state
//! distribution) cells the Pareto evaluation visits, plus the training
//! budget of the hypersolver point.
//!
//! One [`GridConfig`] drives the whole pipeline — kernel sweeps, the
//! serve-path artifact export, the serve sweep, and the residual-fitting
//! run that produces the trained HyperEuler point — so a `BENCH_pareto.json`
//! is reproducible from its embedded grid block plus the seed.

use crate::nn::{Act, AnalyticField, FieldNet, Linear, Mlp, MlpField, TimeMode};
use crate::runtime::native::DEFAULT_DOPRI5_TOL;
use crate::solvers::Tableau;
use crate::tensor::Tensor;
use crate::train::StateSampler;
use crate::util::prng::Rng;
use crate::{Error, Result};

/// One task of the sweep: a named vector field. All tasks are CNF-shaped
/// (planar states), matching the serving stack's `cnf` task kind.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub name: String,
    pub field: FieldNet,
}

impl TaskSpec {
    /// The paper's analytic reference fields: `vdp` | `rotation` | `decay`.
    pub fn analytic(name: &str) -> Result<TaskSpec> {
        let (name, field) = match name {
            "vdp" | "vanderpol" => ("vdp", AnalyticField::VanDerPol { mu: 1.0 }),
            "rotation" => ("rotation", AnalyticField::Rotation { omega: 1.0 }),
            "decay" => ("decay", AnalyticField::Decay { lambda: -1.0 }),
            other => {
                return Err(Error::Other(format!(
                    "unknown analytic task {other:?} (vdp | rotation | decay)"
                )))
            }
        };
        Ok(TaskSpec {
            name: name.to_string(),
            field: FieldNet::Analytic(field),
        })
    }

    /// A seeded synthetic MLP field: tanh hidden layers bound the field
    /// magnitude (so every solver stays finite over the span) and the
    /// last layer's weights are scaled down to keep |f| ≈ O(1). Its cost
    /// profile — thousands of MACs per evaluation — is the regime where
    /// hypersolvers win *wall-clock*, complementing the ~free analytic
    /// fields where only the NFE axis is interesting (paper §6's relative
    /// overhead argument).
    pub fn synthetic_mlp(name: &str, hidden: &[usize], seed: u64) -> TaskSpec {
        let mut rng = Rng::new(seed ^ 0x517E_F1E1D);
        let state_dim = 2usize;
        let mut dims = Vec::with_capacity(hidden.len() + 2);
        dims.push(state_dim + TimeMode::Concat.dim());
        dims.extend_from_slice(hidden);
        dims.push(state_dim);
        let mut layers = Vec::with_capacity(dims.len() - 1);
        for li in 0..dims.len() - 1 {
            let (din, dout) = (dims[li], dims[li + 1]);
            let last = li == dims.len() - 2;
            let scale = if last {
                0.5 / (din as f32).sqrt()
            } else {
                1.0 / (din as f32).sqrt()
            };
            let w = Tensor::new(
                &[din, dout],
                (0..din * dout).map(|_| rng.normal_f32() * scale).collect(),
            )
            .expect("synthetic field weight shape");
            layers.push(Linear {
                w,
                b: vec![0.0; dout],
                act: if last { Act::Id } else { Act::Tanh },
            });
        }
        TaskSpec {
            name: name.to_string(),
            field: FieldNet::Mlp(MlpField {
                mlp: Mlp { layers },
                time_mode: TimeMode::Concat,
            }),
        }
    }
}

/// The full sweep grid + hypersolver training budget.
#[derive(Clone, Debug)]
pub struct GridConfig {
    /// Classical fixed-step tableaus swept at every k in `ks`.
    pub solvers: Vec<String>,
    pub ks: Vec<usize>,
    /// dopri5 tolerances — the adaptive axis of the grid.
    pub tols: Vec<f32>,
    /// Base tableau of the trained hypersolver point.
    pub hyper_base: String,
    /// Step count the hypersolver is trained at and swept at.
    pub hyper_k: usize,
    /// States per sweep batch (also the exported serve batch).
    pub batch: usize,
    pub seed: u64,
    pub span: (f32, f32),
    /// Initial-state box half-width for both samplers.
    pub sample_box: f32,
    /// Mesh resolution of the trajectory state sampler.
    pub traj_mesh_k: usize,
    /// Checkpoints of the trajectory-error metric; a fixed-step method
    /// reports it only when `traj_checkpoints` divides its k.
    pub traj_checkpoints: usize,
    /// Tolerance of the tight dopri5 error reference.
    pub ref_tol: f32,
    /// benchkit measurement budget per grid cell (ms).
    pub measure_ms: u64,
    /// Residual-fitting budget of the hypersolver point.
    pub train_steps: usize,
    pub train_hidden: Vec<usize>,
    /// Early-stop once the held-out one-step improvement reaches this.
    pub train_stop_at: f32,
    /// Print training/sweep progress lines.
    pub log: bool,
}

impl GridConfig {
    /// The full paper-scale grid (minutes of wall time per task).
    pub fn standard() -> GridConfig {
        GridConfig {
            solvers: vec!["euler".into(), "midpoint".into(), "rk4".into()],
            ks: vec![1, 2, 4, 8, 16, 32],
            tols: vec![1e-2, 1e-3, DEFAULT_DOPRI5_TOL],
            hyper_base: "euler".into(),
            hyper_k: 8,
            batch: 256,
            seed: 7,
            span: (0.0, 1.0),
            sample_box: 2.0,
            traj_mesh_k: 16,
            traj_checkpoints: 4,
            ref_tol: 1e-7,
            measure_ms: 150,
            train_steps: 4000,
            train_hidden: vec![16, 16],
            train_stop_at: 8.0,
            log: true,
        }
    }

    /// A CI-sized grid (seconds): tiny k axis, short training, quick
    /// timing budgets. The hypersolver trains at k=2, where both same-NFE
    /// rivals (euler k=2, midpoint k=1) are far off — the smoke
    /// assertions hold with wide margins.
    pub fn smoke() -> GridConfig {
        GridConfig {
            solvers: vec!["euler".into(), "midpoint".into()],
            ks: vec![1, 2, 4],
            tols: vec![1e-3, DEFAULT_DOPRI5_TOL],
            hyper_k: 2,
            batch: 64,
            traj_mesh_k: 8,
            traj_checkpoints: 2,
            measure_ms: 40,
            train_steps: 1500,
            train_hidden: vec![8],
            train_stop_at: 4.0,
            ..GridConfig::standard()
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.solvers.is_empty() || self.ks.is_empty() {
            return Err(Error::Other("grid: solvers and ks must be non-empty".into()));
        }
        if self.ks.contains(&0) || self.hyper_k == 0 {
            return Err(Error::Other("grid: step counts must be > 0".into()));
        }
        if self.batch == 0 || self.traj_checkpoints == 0 || self.traj_mesh_k == 0 {
            return Err(Error::Other(
                "grid: batch, traj_checkpoints, traj_mesh_k must be > 0".into(),
            ));
        }
        if self.span.1 <= self.span.0 {
            return Err(Error::Other("grid: span must be increasing".into()));
        }
        let bad_tol = |t: f32| t <= 0.0 || t.is_nan();
        if bad_tol(self.ref_tol) || self.tols.iter().any(|t| bad_tol(*t)) {
            return Err(Error::Other("grid: tolerances must be > 0".into()));
        }
        for name in self.solvers.iter().chain(std::iter::once(&self.hyper_base)) {
            let tab = Tableau::by_name(name)?;
            if tab.b_err.is_some() {
                return Err(Error::Other(format!(
                    "grid: {name} is an adaptive pair; the fixed-step axis \
                     takes fixed-step tableaus (the tolerance axis covers \
                     adaptive solvers)"
                )));
            }
        }
        // duplicate axis values would export manifest variants with
        // identical names, which every later lookup silently aliases —
        // reject here instead of producing a corrupted BENCH_pareto.json.
        // (Distinct literals like 1e-3 and 0.001 collide as the same f32
        // and therefore the same variant label; value equality catches
        // exactly that.)
        fn has_dup<T: PartialEq>(xs: &[T]) -> bool {
            xs.iter()
                .enumerate()
                .any(|(i, x)| xs[..i].contains(x))
        }
        if has_dup(&self.solvers) || has_dup(&self.ks) || has_dup(&self.tols) {
            return Err(Error::Other(
                "grid: duplicate solver, k, or tolerance values".into(),
            ));
        }
        Ok(())
    }

    /// Uniform-box state sampler over `[-sample_box, sample_box]^dim`.
    pub fn box_sampler(&self, dim: usize) -> StateSampler {
        StateSampler::UniformBox {
            lo: -self.sample_box,
            hi: self.sample_box,
            dim,
        }
    }

    /// Trajectory state sampler: states along `hyper_base` trajectories of
    /// the field (the paper's CNF serving distribution) — shared between
    /// the sweep and `train::residual`.
    pub fn traj_sampler(&self, dim: usize) -> StateSampler {
        StateSampler::Trajectory {
            lo: -self.sample_box,
            hi: self.sample_box,
            dim,
            solver: self.hyper_base.clone(),
            k: self.traj_mesh_k,
            span: self.span,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::VectorField;

    #[test]
    fn analytic_tasks_resolve_and_unknown_rejected() {
        for name in ["vdp", "rotation", "decay"] {
            let t = TaskSpec::analytic(name).unwrap();
            assert_eq!(t.name, name);
            assert_eq!(t.field.state_dim(), 2);
        }
        assert_eq!(TaskSpec::analytic("vanderpol").unwrap().name, "vdp");
        assert!(TaskSpec::analytic("nope").is_err());
    }

    #[test]
    fn synthetic_mlp_field_is_bounded_and_seeded() {
        let t = TaskSpec::synthetic_mlp("mlp16", &[16, 16], 7);
        assert_eq!(t.field.state_dim(), 2);
        // seeded determinism
        let t2 = TaskSpec::synthetic_mlp("mlp16", &[16, 16], 7);
        let z = Tensor::new(&[3, 2], vec![0.5, -1.0, 2.0, 0.0, -1.5, 1.5]).unwrap();
        assert_eq!(t.field.eval(0.3, &z).data(), t2.field.eval(0.3, &z).data());
        // tanh hidden + scaled-down output layer keep |f| O(1): the bound
        // is Σ|w_out| per coordinate, comfortably below 16
        let dz = t.field.eval(0.0, &z);
        assert!(dz.data().iter().all(|v| v.is_finite() && v.abs() < 16.0));
        // a different seed gives a different field
        let t3 = TaskSpec::synthetic_mlp("mlp16", &[16, 16], 8);
        assert_ne!(t.field.eval(0.3, &z).data(), t3.field.eval(0.3, &z).data());
    }

    #[test]
    fn grid_validation() {
        assert!(GridConfig::standard().validate().is_ok());
        assert!(GridConfig::smoke().validate().is_ok());
        let mut g = GridConfig::smoke();
        g.ks = vec![];
        assert!(g.validate().is_err());
        let mut g = GridConfig::smoke();
        g.solvers = vec!["dopri5".into()];
        assert!(g.validate().is_err(), "adaptive pair on the fixed-step axis");
        let mut g = GridConfig::smoke();
        g.span = (1.0, 0.0);
        assert!(g.validate().is_err());
        let mut g = GridConfig::smoke();
        g.tols = vec![0.0];
        assert!(g.validate().is_err());
        let mut g = GridConfig::smoke();
        g.ks = vec![1, 2, 2];
        assert!(g.validate().is_err(), "duplicate k would alias variant names");
        let mut g = GridConfig::smoke();
        g.tols = vec![1e-3, 0.001];
        assert!(g.validate().is_err(), "tolerances colliding as f32 rejected");
    }

    #[test]
    fn samplers_share_the_grid_geometry() {
        let g = GridConfig::smoke();
        match g.box_sampler(2) {
            StateSampler::UniformBox { lo, hi, dim } => {
                assert_eq!((lo, hi, dim), (-g.sample_box, g.sample_box, 2));
            }
            other => panic!("unexpected sampler {other:?}"),
        }
        match g.traj_sampler(2) {
            StateSampler::Trajectory { solver, k, span, .. } => {
                assert_eq!(solver, g.hyper_base);
                assert_eq!(k, g.traj_mesh_k);
                assert_eq!(span, g.span);
            }
            other => panic!("unexpected sampler {other:?}"),
        }
    }
}
