//! Dominance-correct Pareto-front extraction.
//!
//! The front is the *exact* non-dominated subset: a point survives iff no
//! other point is at-most-equal on both axes and strictly better on at
//! least one. This is stricter bookkeeping than a plain best-so-far scan —
//! equal-(cost, error) duplicates are mutually non-dominating and all
//! belong on the front, while an equal-error point at strictly higher
//! cost is dominated and must go. `tests/pareto_front.rs` pins this
//! definition against a brute-force O(n²) reference.

/// Does `a` dominate `b` on (cost, error)? No worse on both axes, strictly
/// better on at least one.
pub fn dominates(a: (f64, f64), b: (f64, f64)) -> bool {
    a.0 <= b.0 && a.1 <= b.1 && (a.0 < b.0 || a.1 < b.1)
}

/// Indices of the exact non-dominated subset of `points`, in the stable
/// order (cost asc, error asc, original index asc). Points with a
/// non-finite coordinate are never on the front (and never dominate —
/// they are skipped entirely).
pub fn non_dominated(points: &[(f64, f64)]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len())
        .filter(|&i| points[i].0.is_finite() && points[i].1.is_finite())
        .collect();
    idx.sort_by(|&a, &b| {
        points[a]
            .0
            .partial_cmp(&points[b].0)
            .unwrap()
            .then(points[a].1.partial_cmp(&points[b].1).unwrap())
            .then(a.cmp(&b))
    });
    // one scan over cost groups: a point survives iff it ties the minimum
    // error within its own cost group AND that minimum is strictly below
    // every strictly-cheaper point's error
    let mut out = Vec::new();
    let mut best_cheaper = f64::INFINITY;
    let mut i = 0;
    while i < idx.len() {
        let cost = points[idx[i]].0;
        let mut j = i;
        while j < idx.len() && points[idx[j]].0 == cost {
            j += 1;
        }
        // sorted by error within the group, so the group minimum is first
        let group_min = points[idx[i]].1;
        if group_min < best_cheaper {
            for &p in &idx[i..j] {
                if points[p].1 == group_min {
                    out.push(p);
                }
            }
            best_cheaper = group_min;
        }
        i = j;
    }
    out
}

/// [`non_dominated`] over arbitrary items via a (cost, error) projection.
pub fn front_of<T>(items: &[T], key: impl Fn(&T) -> (f64, f64)) -> Vec<usize> {
    let pts: Vec<(f64, f64)> = items.iter().map(&key).collect();
    non_dominated(&pts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_definition() {
        assert!(dominates((1.0, 1.0), (2.0, 1.0)));
        assert!(dominates((1.0, 1.0), (1.0, 2.0)));
        assert!(dominates((1.0, 1.0), (2.0, 2.0)));
        assert!(!dominates((1.0, 1.0), (1.0, 1.0)), "ties do not dominate");
        assert!(!dominates((1.0, 2.0), (2.0, 1.0)), "trade-offs do not dominate");
    }

    #[test]
    fn keeps_exactly_the_non_dominated_set() {
        let pts = vec![
            (1.0, 0.5),
            (2.0, 0.6), // dominated by (2.0, 0.2)
            (2.0, 0.2),
            (4.0, 0.1),
            (3.0, 0.5), // dominated by (1.0, 0.5): equal error, higher cost
        ];
        assert_eq!(non_dominated(&pts), vec![0, 2, 3]);
    }

    #[test]
    fn equal_points_are_mutually_non_dominating() {
        let pts = vec![(1.0, 0.5), (1.0, 0.5), (0.5, 0.9)];
        assert_eq!(non_dominated(&pts), vec![2, 0, 1]);
    }

    #[test]
    fn non_finite_points_are_ignored() {
        let pts = vec![(f64::NAN, 0.0), (1.0, f64::INFINITY), (2.0, 0.3)];
        assert_eq!(non_dominated(&pts), vec![2]);
    }

    #[test]
    fn front_of_projects() {
        struct P {
            c: f64,
            e: f64,
        }
        let items = vec![P { c: 1.0, e: 1.0 }, P { c: 2.0, e: 2.0 }];
        assert_eq!(front_of(&items, |p| (p.c, p.e)), vec![0]);
    }
}
