//! The sweep engine: run every grid cell through the `_ws` solver kernels
//! (NFE-vs-error, kernel wall-clock) and through the full serving
//! coordinator — `Engine::submit` with the variant pinned, so the
//! wall-clock plane includes the engine's batching/queueing/dispatch
//! (true end-to-end wall-clock) — against a tight-tolerance dopri5
//! reference.
//!
//! Cost-axis semantics, pinned here once: `nfe` counts **field**
//! evaluations (the paper's cost model — hypersolvers spend the same field
//! NFE as their base solver and pay `g_evals` extra hypernet calls, which
//! are recorded separately), `wall_us` is measured mean wall-clock per
//! batch. At equal NFE a hypersolver necessarily pays g on the wall-clock
//! axis; its wall-clock wins show up against the *higher-NFE classical
//! configurations that reach its accuracy* — most visibly on expensive
//! (MLP) fields, exactly the paper's §6 overhead argument.

use std::path::Path;
use std::time::Duration;

use crate::coordinator::{Engine, EngineConfig, Policy, SubmitOptions};
use crate::metrics::{mape, mean_l2};
use crate::nn::{CnfModel, FieldNet, HyperMlp};
use crate::obs::drift::TrainStats;
use crate::ode::VectorField;
use crate::pareto::grid::GridConfig;
use crate::runtime::{BackendKind, Manifest};
use crate::solvers::{
    adaptive_ws, odeint_fixed_traj, odeint_fixed_ws, odeint_hyper_traj, odeint_hyper_ws,
    AdaptiveOpts, HyperNet, RkWorkspace, Tableau,
};
use crate::tensor::Tensor;
use crate::util::benchkit::Bench;
use crate::util::json::{self, Value};
use crate::util::prng::Rng;
use crate::{Error, Result};

/// One measured grid cell — a single point of a Pareto plane.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub task: String,
    /// State distribution the batch was drawn from: "box" | "trajectory".
    pub states: String,
    /// Canonical cell label (also the serve-path variant name).
    pub label: String,
    pub solver: String,
    /// Step count (0 for adaptive cells).
    pub k: usize,
    /// Tolerance of an adaptive cell.
    pub tol: Option<f32>,
    pub hyper: bool,
    /// Field evaluations per sample (measured for adaptive cells).
    pub nfe: f64,
    /// Hypernet evaluations per sample (0 for classical cells).
    pub g_evals: u64,
    /// Terminal mean per-sample L2 error vs the tight reference.
    pub err: f64,
    /// Terminal MAPE vs the tight reference (the manifest metric).
    pub mape: f64,
    /// Mean checkpoint error along the trajectory, when the cell's mesh
    /// contains the checkpoints.
    pub err_traj: Option<f64>,
    /// Mean wall-clock per batch solve (µs).
    pub wall_us: f64,
}

/// The canonical label of a grid cell; doubles as the exported serve-path
/// variant name, so kernel and serve points join on it.
pub fn method_label(solver: &str, k: usize, hyper: bool, tol: Option<f32>) -> String {
    if let Some(t) = tol {
        format!("dopri5_{t:e}")
    } else if hyper {
        format!("hyper{solver}_k{k}")
    } else {
        format!("{solver}_k{k}")
    }
}

/// Tight reference states at the `c` trajectory checkpoints (the last one
/// is the terminal state), integrated segment-to-segment so every
/// checkpoint is itself reference-accurate.
fn reference_checkpoints<F: VectorField + ?Sized>(
    f: &F,
    z0: &Tensor,
    grid: &GridConfig,
    ws: &mut RkWorkspace,
) -> Result<Vec<Tensor>> {
    let c = grid.traj_checkpoints;
    let d5 = Tableau::dopri5();
    let opts = AdaptiveOpts::with_tol(grid.ref_tol);
    let (s0, s1) = grid.span;
    let mut out = Vec::with_capacity(c);
    let mut cur = z0.clone();
    for j in 1..=c {
        let t0 = s0 + (s1 - s0) * (j - 1) as f32 / c as f32;
        let t1 = s0 + (s1 - s0) * j as f32 / c as f32;
        cur = adaptive_ws(f, &cur, (t0, t1), &d5, &opts, ws)?.z;
        out.push(cur.clone());
    }
    Ok(out)
}

/// Mean checkpoint error of a (k+1)-point fixed-step trajectory against
/// the reference checkpoints; `None` when the mesh misses the checkpoints.
fn traj_error(traj: &[Tensor], ref_ckpts: &[Tensor]) -> Result<Option<f64>> {
    let c = ref_ckpts.len();
    let k = traj.len() - 1;
    if k == 0 || k % c != 0 {
        return Ok(None);
    }
    let mut acc = 0.0;
    for j in 1..=c {
        acc += mean_l2(&traj[j * k / c], &ref_ckpts[j - 1])?;
    }
    Ok(Some(acc / c as f64))
}

/// Sweep every grid cell at the solver-kernel level on the batch `z0`
/// (drawn from the `states` distribution): classical fixed-step methods ×
/// ks, the trained hypersolver at its k, and dopri5 across the tolerance
/// axis. Errors are against a dopri5(`ref_tol`) reference; wall-clock is
/// benchkit-measured on the allocation-free `_ws` kernels with a warm
/// workspace.
pub fn kernel_sweep<F, G>(
    task: &str,
    f: &F,
    g: &G,
    grid: &GridConfig,
    z0: &Tensor,
    states: &str,
) -> Result<Vec<SweepPoint>>
where
    F: VectorField + ?Sized,
    G: HyperNet + ?Sized,
{
    grid.validate()?;
    let mut ws = RkWorkspace::new();
    let ref_ckpts = reference_checkpoints(f, z0, grid, &mut ws)?;
    let zref = ref_ckpts.last().expect("at least one checkpoint").clone();
    let bench = Bench::with_budget(grid.measure_ms);
    let span = grid.span;
    let mut out = Vec::new();

    // classical fixed-step axis
    for solver in &grid.solvers {
        let tab = Tableau::by_name(solver)?;
        for &k in &grid.ks {
            let label = method_label(solver, k, false, None);
            let traj = odeint_fixed_traj(f, z0, span, k, &tab)?;
            let zt = traj.last().expect("terminal state");
            let err_traj = traj_error(&traj, &ref_ckpts)?;
            let m = bench.run(&label, || {
                odeint_fixed_ws(f, z0, span, k, &tab, &mut ws).unwrap();
            });
            out.push(SweepPoint {
                task: task.to_string(),
                states: states.to_string(),
                label,
                solver: solver.clone(),
                k,
                tol: None,
                hyper: false,
                nfe: (tab.stages() * k) as f64,
                g_evals: 0,
                err: mean_l2(zt, &zref)?,
                mape: mape(zt, &zref)?,
                err_traj,
                wall_us: m.mean_us(),
            });
        }
    }

    // the trained hypersolver point
    {
        let tab = Tableau::by_name(&grid.hyper_base)?;
        let k = grid.hyper_k;
        let label = method_label(&grid.hyper_base, k, true, None);
        let traj = odeint_hyper_traj(f, g, z0, span, k, &tab)?;
        let zt = traj.last().expect("terminal state");
        let err_traj = traj_error(&traj, &ref_ckpts)?;
        let m = bench.run(&label, || {
            odeint_hyper_ws(f, g, z0, span, k, &tab, &mut ws).unwrap();
        });
        out.push(SweepPoint {
            task: task.to_string(),
            states: states.to_string(),
            label,
            solver: grid.hyper_base.clone(),
            k,
            tol: None,
            hyper: true,
            nfe: (tab.stages() * k) as f64,
            g_evals: k as u64,
            err: mean_l2(zt, &zref)?,
            mape: mape(zt, &zref)?,
            err_traj,
            wall_us: m.mean_us(),
        });
    }

    // adaptive tolerance axis
    let d5 = Tableau::dopri5();
    for &tol in &grid.tols {
        let label = method_label("dopri5", 0, false, Some(tol));
        let opts = AdaptiveOpts::with_tol(tol);
        let r = adaptive_ws(f, z0, span, &d5, &opts, &mut ws)?;
        let m = bench.run(&label, || {
            adaptive_ws(f, z0, span, &d5, &opts, &mut ws).unwrap();
        });
        out.push(SweepPoint {
            task: task.to_string(),
            states: states.to_string(),
            label,
            solver: "dopri5".into(),
            k: 0,
            tol: Some(tol),
            hyper: false,
            nfe: r.nfe as f64,
            g_evals: 0,
            err: mean_l2(&r.z, &zref)?,
            mape: mape(&r.z, &zref)?,
            err_traj: None,
            wall_us: m.mean_us(),
        });
    }
    Ok(out)
}

/// Write a servable artifact set covering the *whole* grid for `task`:
/// `weights/<task>.json` (field + trained hypersolver, the exact schema
/// `CnfModel::load` parses) plus a manifest whose variants are every grid
/// cell — classical solvers × ks, the hypersolved point, and one dopri5
/// variant per tolerance (pinned via the manifest `tol` field). Variant
/// `mape`/`nfe` are stamped from the box-states kernel sweep, so the
/// manifest carries measured numbers, not placeholders. Merges into an
/// existing manifest the way `train::export_trained` does.
pub fn write_sweep_artifacts(
    dir: &Path,
    task: &str,
    field: &FieldNet,
    g: &HyperMlp,
    grid: &GridConfig,
    delta: f32,
    kernel_box: &[SweepPoint],
) -> Result<()> {
    let model = CnfModel {
        field: field.clone(),
        hyper: g.clone(),
    };
    std::fs::create_dir_all(dir.join("weights"))?;
    let weights_rel = format!("weights/{task}.json");
    std::fs::write(dir.join(&weights_rel), json::to_string(&model.to_json()))?;

    let d = field.state_dim();
    let batch = grid.batch;
    let shape = || {
        Value::Arr(vec![json::num(batch as f64), json::num(d as f64)])
    };
    let mac_f = VectorField::macs(field);
    let mac_g = HyperNet::macs(g);
    let find = |label: &str| -> Result<&SweepPoint> {
        kernel_box
            .iter()
            .find(|p| p.label == label)
            .ok_or_else(|| Error::Other(format!("no kernel measurement for {label}")))
    };

    let variant = |label: &str,
                   solver: &str,
                   k: usize,
                   hyper: bool,
                   nfe: u64,
                   macs: u64,
                   mape: f64,
                   tol: Option<f32>| {
        let mut fields = vec![
            ("name", json::s(label)),
            ("solver", json::s(solver)),
            ("k", json::num(k as f64)),
            ("hyper", Value::Bool(hyper)),
            // no HLO exists for sweep exports; only the pjrt backend reads
            // it, and it fails loudly on the missing file
            ("hlo", json::s(&format!("{task}_{label}.hlo.txt"))),
            ("nfe", json::num(nfe as f64)),
            ("macs", json::num(macs as f64)),
            ("mape", json::num(mape)),
            ("in_shape", shape()),
            ("out_shape", shape()),
        ];
        if let Some(t) = tol {
            fields.push(("tol", json::num(t as f64)));
            fields.push(("outputs", Value::Arr(vec![json::s("z"), json::s("nfe")])));
        }
        json::obj(fields)
    };

    let mut variants = Vec::new();
    for solver in &grid.solvers {
        let tab = Tableau::by_name(solver)?;
        for &k in &grid.ks {
            let label = method_label(solver, k, false, None);
            let p = find(&label)?;
            let nfe = (tab.stages() * k) as u64;
            variants.push(variant(&label, solver, k, false, nfe, nfe * mac_f, p.mape, None));
        }
    }
    {
        let tab = Tableau::by_name(&grid.hyper_base)?;
        let k = grid.hyper_k;
        let label = method_label(&grid.hyper_base, k, true, None);
        let p = find(&label)?;
        let nfe = (tab.stages() * k) as u64;
        let macs = k as u64 * (tab.stages() as u64 * mac_f + mac_g);
        variants.push(variant(&label, &grid.hyper_base, k, true, nfe, macs, p.mape, None));
    }
    for &tol in &grid.tols {
        let label = method_label("dopri5", 0, false, Some(tol));
        let p = find(&label)?;
        let nfe = p.nfe as u64;
        variants.push(variant(&label, "dopri5", 0, false, nfe, nfe * mac_f, p.mape, Some(tol)));
    }

    let task_obj = json::obj(vec![
        ("kind", json::s("cnf")),
        ("state", json::obj(vec![("shape", shape())])),
        (
            "s_span",
            Value::Arr(vec![
                json::num(grid.span.0 as f64),
                json::num(grid.span.1 as f64),
            ]),
        ),
        ("weights", json::s(&weights_rel)),
        ("field_hlo", json::s(&format!("{task}_field.hlo.txt"))),
        (
            "macs",
            json::obj(vec![
                ("field", json::num(mac_f as f64)),
                ("hyper", json::num(mac_g as f64)),
            ]),
        ),
        ("delta", json::num(delta as f64)),
        ("hyper_base", json::s(&grid.hyper_base)),
        // training-distribution stamp for the serving audit plane's drift
        // detection: the sweep's hypersolver trains on grid box states, so
        // that is what drift is measured against (obs::drift)
        ("train_stats", {
            let mut srng = Rng::new(grid.seed ^ 0x7A57_57A7);
            let rows = batch.max(512);
            let states = grid.box_sampler(d).sample_for(field, rows, &mut srng)?;
            TrainStats::from_rows(states.data(), d)?.to_json()
        }),
        ("variants", Value::Arr(variants)),
    ]);

    // merge into an existing manifest (multiple tasks share one sweep
    // artifacts dir) — the shared exporter semantics live in
    // runtime::manifest
    crate::runtime::manifest::merge_task_into_manifest(
        dir,
        task,
        task_obj,
        "hyperbench-sweep",
        grid.seed,
    )?;
    Ok(())
}

/// Sweep every exported variant of `task` through the **full serve
/// path**: a native-backend [`Engine`] is brought up over the exported
/// artifacts and each variant is measured via `Engine::submit` with the
/// variant pinned — one full-batch multi-sample request per solve, so the
/// wall-clock includes everything a served request pays: submission,
/// queueing, the dispatch worker hand-off, batching, backend execution,
/// and completion delivery (the coordinator's real batching/queueing
/// effects, not just `NativeBackend::execute`). Errors are measured
/// against a dopri5(`ref_tol`) reference on the same inputs; inputs are
/// drawn box-uniform from the grid seed, so kernel and serve sweeps are
/// reproducible from the same config.
pub fn serve_sweep(
    manifest: &Manifest,
    task: &str,
    grid: &GridConfig,
) -> Result<Vec<SweepPoint>> {
    grid.validate()?;
    let entry = manifest.task(task)?;
    let model = CnfModel::load(&manifest.weights_path(entry))?;
    let batch = entry.batch();
    let d: usize = entry.state_shape[1..].iter().product();

    let mut rng = Rng::new(grid.seed ^ 0x5E12_BEAC);
    let z0 = grid.box_sampler(d).sample_for(&model.field, batch, &mut rng)?;
    let mut ws = RkWorkspace::new();
    let zref = adaptive_ws(
        &model.field,
        &z0,
        entry.s_span,
        &Tableau::dopri5(),
        &AdaptiveOpts::with_tol(grid.ref_tol),
        &mut ws,
    )?
    .z;

    // the measured serve plane: the coordinator route, not a bare backend.
    // A full-batch request fills its queue instantly (rows == cap), so the
    // per-solve wall-clock is submit → dispatch → execute → complete.
    let engine = Engine::new(EngineConfig {
        artifacts_dir: manifest.dir.clone(),
        max_wait: Duration::from_millis(2),
        policy: Policy::MinMacs,
        backend: BackendKind::Native,
        workers: 2,
        ..Default::default()
    })?;
    engine.warmup(task)?;

    let input = z0.into_data();
    let bench = Bench::with_budget(grid.measure_ms);
    let mut out = Vec::new();
    for v in &entry.variants {
        let opts = SubmitOptions {
            variant: Some(v.name.clone()),
            ..SubmitOptions::default()
        };
        let submit_once = || -> Result<crate::coordinator::Response> {
            engine
                .submit_opts(task, f32::INFINITY, input.clone(), batch, &opts)
                .map_err(Error::from)?
                .wait()
                .map_err(Error::from)
        };
        let first = submit_once()?;
        let zt = Tensor::new(&[batch, d], first.output.clone())?;
        let m = bench.run(&v.name, || {
            submit_once().expect("serve sweep submission failed");
        });
        out.push(SweepPoint {
            task: task.to_string(),
            states: "box".into(),
            label: v.name.clone(),
            solver: v.solver.clone(),
            k: v.k,
            tol: v.tol.map(|t| t as f32),
            hyper: v.hyper,
            nfe: first.nfe as f64,
            g_evals: if v.hyper { v.k as u64 } else { 0 },
            err: mean_l2(&zt, &zref)?,
            mape: mape(&zt, &zref)?,
            err_traj: None,
            wall_us: m.mean_us(),
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_join_kernel_and_serve() {
        assert_eq!(method_label("euler", 8, false, None), "euler_k8");
        assert_eq!(method_label("euler", 8, true, None), "hypereuler_k8");
        assert_eq!(method_label("dopri5", 0, false, Some(1e-3)), "dopri5_1e-3");
        assert_eq!(method_label("dopri5", 0, false, Some(1e-5)), "dopri5_1e-5");
        // and the hyper label matches the trainer's variant naming
        let cfg = crate::train::TrainConfig {
            solver: "euler".into(),
            k: 8,
            ..crate::train::TrainConfig::default()
        };
        assert_eq!(method_label("euler", 8, true, None), crate::train::hyper_variant_name(&cfg));
    }

    #[test]
    fn traj_error_requires_matching_mesh() {
        let t = |v: f32| Tensor::full(&[1, 2], v);
        let ref_ckpts = vec![t(1.0), t(2.0)];
        // k=4, c=2: checkpoints at mesh indices 2 and 4
        let traj = vec![t(0.0), t(0.5), t(1.0), t(1.5), t(2.0)];
        let e = traj_error(&traj, &ref_ckpts).unwrap().unwrap();
        assert!(e.abs() < 1e-12);
        // k=3 misses the checkpoints
        let traj3 = vec![t(0.0), t(1.0), t(1.5), t(2.0)];
        assert!(traj_error(&traj3, &ref_ckpts).unwrap().is_none());
    }
}
