//! Evaluation metrics: MAPE/MSE/accuracy, global truncation error, Pareto
//! front extraction, and the MAC cost model (mirrors `compile/macs.py`).

use crate::tensor::Tensor;
use crate::{Error, Result};

/// Mean absolute percentage error with the paper's small-denominator guard
/// (identical to `compile/aot.py::mape` so rust and python report the same
/// numbers on the same blobs).
pub fn mape(pred: &Tensor, truth: &Tensor) -> Result<f64> {
    if pred.shape() != truth.shape() {
        return Err(Error::Shape(format!(
            "mape shapes {:?} vs {:?}",
            pred.shape(),
            truth.shape()
        )));
    }
    let mut acc = 0.0f64;
    for (p, t) in pred.data().iter().zip(truth.data()) {
        acc += ((p - t).abs() / (t.abs() + 1e-2)) as f64;
    }
    Ok(acc / pred.numel() as f64)
}

pub fn mse(pred: &Tensor, truth: &Tensor) -> Result<f64> {
    if pred.shape() != truth.shape() {
        return Err(Error::Shape("mse shape mismatch".into()));
    }
    let mut acc = 0.0f64;
    for (p, t) in pred.data().iter().zip(truth.data()) {
        let d = (p - t) as f64;
        acc += d * d;
    }
    Ok(acc / pred.numel() as f64)
}

/// Mean per-sample L2 distance — the global truncation error E_k of the
/// paper when applied at a mesh point.
pub fn mean_l2(pred: &Tensor, truth: &Tensor) -> Result<f64> {
    if pred.shape() != truth.shape() {
        return Err(Error::Shape("mean_l2 shape mismatch".into()));
    }
    let b = pred.shape()[0];
    let d = pred.numel() / b;
    let mut acc = 0.0f64;
    for i in 0..b {
        let mut s = 0.0f64;
        for j in 0..d {
            let diff = (pred.data()[i * d + j] - truth.data()[i * d + j]) as f64;
            s += diff * diff;
        }
        acc += s.sqrt();
    }
    Ok(acc / b as f64)
}

/// Classification accuracy of logits (B, C) against labels.
pub fn accuracy(logits: &Tensor, labels: &[i32]) -> Result<f64> {
    let preds = logits.argmax_rows()?;
    if preds.len() != labels.len() {
        return Err(Error::Shape("accuracy label count".into()));
    }
    let correct = preds
        .iter()
        .zip(labels)
        .filter(|(p, l)| **p as i32 == **l)
        .count();
    Ok(correct as f64 / labels.len() as f64)
}

// ---------------------------------------------------------------------------
// Pareto fronts
// ---------------------------------------------------------------------------

/// A (cost, error) point with a label — one solver variant.
#[derive(Clone, Debug, PartialEq)]
pub struct ParetoPoint {
    pub label: String,
    pub cost: f64,
    pub error: f64,
}

/// Extract the Pareto-efficient subset, sorted by cost. Delegates to
/// [`crate::pareto::front`] so the whole crate — these labeled
/// convenience points, the fig benches, and the sweep subsystem — shares
/// ONE dominance rule (the exact non-dominated set: equal-(cost, error)
/// ties kept, equal-error-higher-cost dropped).
pub fn pareto_front(points: &[ParetoPoint]) -> Vec<ParetoPoint> {
    crate::pareto::front::front_of(points, |p| (p.cost, p.error))
        .into_iter()
        .map(|i| points[i].clone())
        .collect()
}

/// Does `a` dominate `b` (cheaper-or-equal AND more-accurate-or-equal, with
/// at least one strict)? Same rule as [`crate::pareto::front::dominates`].
pub fn dominates(a: &ParetoPoint, b: &ParetoPoint) -> bool {
    crate::pareto::front::dominates((a.cost, a.error), (b.cost, b.error))
}

// ---------------------------------------------------------------------------
// MAC cost model (mirror of compile/macs.py)
// ---------------------------------------------------------------------------

/// Total MACs per sample of one fixed-step solve.
pub fn solve_macs(mac_f: u64, mac_g: u64, stages: u64, steps: u64, hyper: bool) -> u64 {
    let mut total = stages * steps * mac_f;
    if hyper {
        total += steps * mac_g;
    }
    total
}

/// Relative overhead O_r = 1 + MAC_g / (p · MAC_f) (paper §6).
pub fn relative_overhead(mac_f: u64, mac_g: u64, order: u64) -> f64 {
    1.0 + mac_g as f64 / (order as f64 * mac_f as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propkit::{check, gen_vec, prop_assert};

    #[test]
    fn mape_zero_for_identical() {
        let t = Tensor::new(&[2, 2], vec![1.0, -2.0, 3.0, 0.5]).unwrap();
        assert_eq!(mape(&t, &t).unwrap(), 0.0);
    }

    #[test]
    fn mape_known_value() {
        let p = Tensor::new(&[1, 1], vec![1.1]).unwrap();
        let t = Tensor::new(&[1, 1], vec![1.0]).unwrap();
        let m = mape(&p, &t).unwrap();
        assert!((m - 0.1 / 1.01).abs() < 1e-6);
    }

    #[test]
    fn accuracy_counts() {
        let logits = Tensor::new(&[3, 2], vec![2.0, 1.0, 0.0, 5.0, 1.0, 0.0]).unwrap();
        let acc = accuracy(&logits, &[0, 1, 1]).unwrap();
        assert!((acc - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn mean_l2_is_rowwise() {
        let p = Tensor::new(&[2, 2], vec![1.0, 0.0, 0.0, 0.0]).unwrap();
        let t = Tensor::new(&[2, 2], vec![0.0, 0.0, 0.0, 3.0]).unwrap();
        assert!((mean_l2(&p, &t).unwrap() - 2.0).abs() < 1e-9); // (1+3)/2
    }

    #[test]
    fn pareto_front_filters_dominated() {
        let pts = vec![
            ParetoPoint { label: "a".into(), cost: 1.0, error: 0.5 },
            ParetoPoint { label: "b".into(), cost: 2.0, error: 0.6 }, // dominated
            ParetoPoint { label: "c".into(), cost: 2.0, error: 0.2 },
            ParetoPoint { label: "d".into(), cost: 4.0, error: 0.1 },
        ];
        let front = pareto_front(&pts);
        let labels: Vec<&str> = front.iter().map(|p| p.label.as_str()).collect();
        assert_eq!(labels, vec!["a", "c", "d"]);
    }

    #[test]
    fn pareto_front_property() {
        check("front members are mutually non-dominating", 30, |rng| {
            let n = 20;
            let costs = gen_vec(rng, n, 1.0);
            let errs = gen_vec(rng, n, 1.0);
            let pts: Vec<ParetoPoint> = (0..n)
                .map(|i| ParetoPoint {
                    label: format!("p{i}"),
                    cost: costs[i].abs() as f64,
                    error: errs[i].abs() as f64,
                })
                .collect();
            let front = pareto_front(&pts);
            for a in &front {
                for b in &front {
                    if a.label != b.label && dominates(a, b) {
                        return Err(format!("{} dominates {}", a.label, b.label));
                    }
                }
            }
            // every excluded point is dominated by some front member
            for p in &pts {
                if !front.iter().any(|f| f.label == p.label)
                    && !front.iter().any(|f| dominates(f, p))
                {
                    return Err(format!("{} excluded but undominated", p.label));
                }
            }
            prop_assert(!front.is_empty(), "empty front")
        });
    }

    #[test]
    fn overhead_shrinks_with_order() {
        let o1 = relative_overhead(100, 50, 1);
        let o4 = relative_overhead(100, 50, 4);
        assert!((o1 - 1.5).abs() < 1e-12);
        assert!(o4 < o1);
        assert_eq!(solve_macs(100, 50, 2, 10, true), 2 * 10 * 100 + 10 * 50);
    }
}
