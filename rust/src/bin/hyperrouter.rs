//! `hyperrouter` — the cluster routing daemon.
//!
//! Fronts N `hypersolverd` engine nodes with one v0/v1/v2-speaking
//! endpoint: consistent-hash placement by `(task, variant)`, periodic
//! health polls with eject/readmit, and health-aware retries bounded by
//! a budget and each request's own `deadline_us`. See rust/README.md
//! §"Cluster serving".
//!
//! ```text
//! hyperrouter --listen 0.0.0.0:7171 --nodes 127.0.0.1:7070,127.0.0.1:7071
//! ```

use std::time::Duration;

use hypersolvers::router::{Router, RouterConfig};
use hypersolvers::util::cli::{self, Cli};

fn main() {
    let args = Cli::new("hyperrouter — consistent-hash router over hypersolverd nodes")
        .opt("listen", "127.0.0.1:7171", "address to listen on")
        .opt(
            "nodes",
            "127.0.0.1:7070",
            "comma-separated engine node addresses (ring order)",
        )
        .opt("vnodes", "64", "virtual nodes per engine on the placement ring")
        .opt(
            "eject-after",
            "3",
            "consecutive failed health polls before a node is ejected",
        )
        .opt("poll-ms", "500", "health poll cadence in milliseconds")
        .opt(
            "retries",
            "2",
            "max failover re-sends per request (total sends = retries + 1)",
        )
        .opt(
            "connect-timeout-ms",
            "1000",
            "upstream TCP connect bound in milliseconds",
        )
        .opt(
            "probe-timeout-ms",
            "2000",
            "read bound for health polls and forwarded commands, in milliseconds",
        )
        .parse_env();

    let nodes = cli::parse_list(&args.get("nodes"));
    if nodes.is_empty() {
        eprintln!("hyperrouter: --nodes needs at least one engine address");
        std::process::exit(2);
    }
    let cfg = RouterConfig {
        nodes,
        vnodes: args.get_usize("vnodes"),
        eject_after: args.get_usize("eject-after") as u32,
        poll_interval: Duration::from_millis(args.get_usize("poll-ms") as u64),
        retries: args.get_usize("retries"),
        connect_timeout: Duration::from_millis(args.get_usize("connect-timeout-ms") as u64),
        probe_read_timeout: Duration::from_millis(args.get_usize("probe-timeout-ms") as u64),
    };
    if cfg.eject_after == 0 {
        eprintln!("hyperrouter: --eject-after must be at least 1");
        std::process::exit(2);
    }
    let listen = args.get("listen");
    let router = Router::new(cfg);
    if let Err(e) = router.serve(&listen) {
        eprintln!("hyperrouter: {e}");
        std::process::exit(1);
    }
}
