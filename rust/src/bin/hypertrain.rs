//! `hypertrain` — in-repo hypersolver training by residual fitting.
//!
//! Trains g_ω for a vector field (analytic, or the MLP field of an
//! existing weights export), then writes a servable artifact set
//! (`manifest.json` + `weights/<task>.json`) and self-verifies the
//! train→serialize→serve loop by executing the trained variant through
//! the native backend.
//!
//! Examples:
//!   hypertrain --field vdp --mu 1.0 --solver euler --k 8 --out artifacts-vdp
//!   hypertrain --weights artifacts/weights/cnf_rings.json --solver heun \
//!       --density rings --steps 4000
//!   hypertrain --field vdp --steps 400 --batch 64 --hidden 16,16 --bench
//!
//! After training:
//!   hypersolverd serve --backend native --artifacts artifacts-vdp

use std::path::Path;
use std::sync::Arc;

use hypersolvers::nn::{AnalyticField, CnfModel, FieldNet, HyperMlp};
use hypersolvers::solvers::Tableau;
use hypersolvers::tensor::{self, Tensor, Workspace};
use hypersolvers::train::{
    export_trained, hyper_input_into, mlp_backward, mlp_forward_cached, mse_loss_grad,
    serve_check, train_hypersolver, FineRef, MlpCache, MlpGrads, ResidualBatch, ResidualGen,
    StateSampler, TrainConfig,
};
use hypersolvers::util::benchkit::{self, Bench};
use hypersolvers::util::cli::{self, Cli};
use hypersolvers::util::json::{self, Value};
use hypersolvers::util::prng::Rng;
use hypersolvers::util::threadpool::ThreadPool;
use hypersolvers::Result;

fn main() {
    let parsed = Cli::new("hypertrain — residual-fitting trainer for hypersolver nets")
        .opt("field", "vdp", "analytic field: vdp | rotation | decay")
        .opt("mu", "1.0", "Van der Pol stiffness (with --field vdp)")
        .opt("omega", "1.0", "rotation rate (with --field rotation)")
        .opt("lambda", "-1.0", "decay rate (with --field decay)")
        .opt("weights", "", "train for an existing weights JSON's field instead")
        .opt("solver", "euler", "base tableau: euler | heun | midpoint | rk4 | alpha<x>")
        .opt("k", "8", "serving step count (ε = span / k)")
        .opt("span", "0,1", "integration span s0,s1")
        .opt("steps", "2000", "max optimizer steps")
        .opt("batch", "128", "minibatch size")
        .opt("lr", "0.003", "peak learning rate (cosine decay, linear warmup)")
        .opt("warmup", "50", "warmup steps")
        .opt("hidden", "32,32", "hidden widths of g_ω (comma-separated)")
        .opt("seed", "7", "PRNG seed")
        .opt("substeps", "8", "RK4 substeps of the fine one-step reference")
        .opt("fine-tol", "0", "use dopri5(tol) as the fine reference when > 0")
        .opt("box", "2", "sample states uniform in [-box, box]^dim")
        .opt("density", "", "sample states from a data density (rings, pinwheel, ...)")
        .flag(
            "sample-traj",
            "draw training states along base-solver trajectories of the field \
             (the paper's CNF setup)",
        )
        .opt("eval-every", "100", "validation cadence (steps)")
        .opt("patience", "6", "early stop after this many flat evaluations")
        .opt("stop-at", "0", "stop once the one-step improvement factor reaches this")
        .opt("out", "artifacts-trained", "artifact directory to write")
        .opt("task", "", "exported task name (default: the field name)")
        .opt("export-batch", "16", "batch size stamped into the exported manifest")
        .opt("matmul-threads", "0", "dedicated row-block matmul pool size (0 = off)")
        .flag("bench", "write BENCH_train.json (path override: BENCH_JSON env)")
        .flag("quiet", "suppress per-evaluation loss lines")
        .parse_env();

    let mm = parsed.get_usize("matmul-threads");
    if mm > 0 {
        tensor::set_matmul_pool(Arc::new(ThreadPool::new(mm)));
        println!("matmul pool: {mm} workers");
    }

    let field_name = parsed.get("field");
    let field = match load_field(&parsed.get("weights"), &field_name, &parsed) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };

    let span = match cli::parse_span("--span", &parsed.get("span")) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let hidden = match cli::parse_usize_list("--hidden", &parsed.get("hidden")) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let fine_tol = parsed.get_f64("fine-tol") as f32;
    let density = parsed.get("density");
    let boxr = parsed.get_f64("box") as f32;
    let sample_traj = parsed.get_flag("sample-traj");
    if sample_traj && !density.is_empty() {
        eprintln!("error: --sample-traj and --density are mutually exclusive");
        std::process::exit(2);
    }
    let cfg = TrainConfig {
        solver: parsed.get("solver"),
        hidden,
        steps: parsed.get_usize("steps"),
        batch: parsed.get_usize("batch"),
        lr: parsed.get_f64("lr") as f32,
        warmup: parsed.get_usize("warmup"),
        seed: parsed.get_usize("seed") as u64,
        s_span: span,
        k: parsed.get_usize("k"),
        fine: if fine_tol > 0.0 {
            FineRef::Dopri5Tol(fine_tol)
        } else {
            FineRef::Rk4Substeps(parsed.get_usize("substeps"))
        },
        sampler: if sample_traj {
            StateSampler::Trajectory {
                lo: -boxr,
                hi: boxr,
                dim: field.state_dim(),
                solver: parsed.get("solver"),
                k: parsed.get_usize("k").max(1),
                span,
            }
        } else if density.is_empty() {
            StateSampler::UniformBox {
                lo: -boxr,
                hi: boxr,
                dim: field.state_dim(),
            }
        } else {
            StateSampler::Density(density)
        },
        eval_every: parsed.get_usize("eval-every"),
        patience: parsed.get_usize("patience"),
        stop_at_improvement: parsed.get_f64("stop-at") as f32,
        log: !parsed.get_flag("quiet"),
        ..TrainConfig::default()
    };
    // default task name: the analytic field's name, or for --weights the
    // source file's stem + "_retrained" (NOT the unrelated --field default,
    // and never the original task name — merging into the source artifacts
    // dir must not silently replace the original entry)
    let task = if !parsed.get("task").is_empty() {
        parsed.get("task")
    } else if !parsed.get("weights").is_empty() {
        let stem = Path::new(&parsed.get("weights"))
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("weights")
            .to_string();
        format!("{stem}_retrained")
    } else {
        field_name
    };

    if let Err(e) = run(
        &field,
        &cfg,
        &task,
        Path::new(&parsed.get("out")),
        parsed.get_usize("export-batch"),
        parsed.get_flag("bench"),
        mm,
    ) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(
    field: &FieldNet,
    cfg: &TrainConfig,
    task: &str,
    out: &Path,
    export_batch: usize,
    bench: bool,
    matmul_threads: usize,
) -> Result<()> {
    println!(
        "training g_ω: base {} K={} over [{}, {}], {} max steps, batch {}",
        cfg.solver, cfg.k, cfg.s_span.0, cfg.s_span.1, cfg.steps, cfg.batch
    );
    let (g, report) = train_hypersolver(field, cfg)?;
    println!(
        "\ntrained in {:.2}s ({:.0} steps/s, {} steps): val loss {:.6}",
        report.wall_secs, report.steps_per_sec, report.steps_run, report.best_val_loss
    );
    println!(
        "held-out one-step residual: base {:.3e} → hyper {:.3e} ({:.1}× better)",
        report.err_base, report.err_hyper, report.improvement
    );

    let weights_path = export_trained(out, task, field, &g, cfg, &report, export_batch)?;
    println!("wrote {} + {}/manifest.json", weights_path.display(), out.display());

    // self-verify the loop: reload through the manifest, execute every
    // variant through the native backend, and require the hypersolved
    // variant to beat the plain base solver against the served dopri5
    // reference — the same criterion tests/train_e2e.rs pins
    let (d_hyper, d_plain) = serve_check(out, task, cfg, export_batch)?;
    println!(
        "serve check: ‖hyper − dopri5‖ = {d_hyper:.4}, ‖plain − dopri5‖ = {d_plain:.4}"
    );

    if bench {
        // paired matmul-pool measurement: the gemm-heavy training step
        // core timed with the row-block pool off and on, so BENCH JSON
        // records what --matmul-threads actually buys on this config
        let mut fields: Vec<(&str, Value)> = vec![
            ("task", json::s(task)),
            ("solver", json::s(&cfg.solver)),
            ("k", json::num(cfg.k as f64)),
            ("steps_run", json::num(report.steps_run as f64)),
            ("final_loss", json::num(report.final_loss as f64)),
            ("best_val_loss", json::num(report.best_val_loss as f64)),
            ("err_base", json::num(report.err_base as f64)),
            ("err_hyper", json::num(report.err_hyper as f64)),
            (
                "residual_improvement_vs_base",
                json::num(report.improvement as f64),
            ),
            ("wall_secs", json::num(report.wall_secs)),
            ("steps_per_sec", json::num(report.steps_per_sec)),
            ("serve_dist_hyper", json::num(d_hyper as f64)),
            ("serve_dist_plain", json::num(d_plain as f64)),
            (
                "history",
                Value::Arr(
                    report
                        .history
                        .iter()
                        .map(|(s, l)| {
                            Value::Arr(vec![json::num(*s as f64), json::num(*l as f64)])
                        })
                        .collect(),
                ),
            ),
            ("matmul_threads", json::num(matmul_threads as f64)),
        ];
        let matmul_pair = if matmul_threads > 0 {
            tensor::clear_matmul_pool();
            let off = time_train_step(field, &g, cfg)?;
            tensor::set_matmul_pool(Arc::new(ThreadPool::new(matmul_threads)));
            let on = time_train_step(field, &g, cfg)?;
            println!(
                "matmul pool on the training-step core: off {off:.3} ms, \
                 on({matmul_threads}) {on:.3} ms ({:.2}× speedup)",
                off / on.max(1e-9)
            );
            Some(json::obj(vec![
                ("threads", json::num(matmul_threads as f64)),
                ("step_ms_pool_off", json::num(off)),
                ("step_ms_pool_on", json::num(on)),
                ("speedup", json::num(off / on.max(1e-9))),
            ]))
        } else {
            None
        };
        if let Some(pair) = matmul_pair {
            fields.push(("matmul", pair));
        }
        let doc = benchkit::bench_doc("hypertrain", fields);
        let path = benchkit::write_bench_json("BENCH_train.json", &doc)?;
        println!("wrote {}", path.display());
        let traj = benchkit::bench_doc(
            "hypertrain",
            vec![
                ("task", json::s(task)),
                ("improvement", json::num(report.improvement as f64)),
                ("err_hyper", json::num(report.err_hyper as f64)),
                ("steps_per_sec", json::num(report.steps_per_sec)),
            ],
        );
        let tpath = benchkit::append_trajectory(traj)?;
        println!("appended to {}", tpath.display());
    }
    Ok(())
}

/// Mean ms of one gemm-heavy training-step core (cached forward + loss
/// grad + reverse pass) on the trained net — the paired measurement behind
/// the `matmul` rows in `BENCH_train.json`. Target generation happens once
/// outside the timed loop, so the measurement isolates the matmul stack.
fn time_train_step(field: &FieldNet, g: &HyperMlp, cfg: &TrainConfig) -> Result<f64> {
    let tab = Tableau::by_name(&cfg.solver)?;
    let d = cfg.sampler.dim();
    let span = cfg.s_span.1 - cfg.s_span.0;
    let eps = span / cfg.k.max(1) as f32;
    let mut gen = ResidualGen::new(field, tab, cfg.fine);
    let mut rng = Rng::new(cfg.seed ^ 0x00B4_1C00);
    let mut batch = ResidualBatch::new();
    let s_range = (cfg.s_span.0, (cfg.s_span.1 - eps).max(cfg.s_span.0));
    gen.fill(&cfg.sampler, cfg.batch, s_range, eps, &mut rng, &mut batch)?;
    let mut x = Tensor::zeros(&[cfg.batch, 2 * d + 2]);
    hyper_input_into(batch.eps, batch.s, &batch.z, &batch.dz, &mut x)?;
    let mut dy = Tensor::zeros(&[cfg.batch, d]);
    let mut cache = MlpCache::new();
    let mut grads = MlpGrads::new();
    let mut ws = Workspace::new();
    let m = Bench::quick().run("train_step", || {
        mlp_forward_cached(&g.mlp, &x, &mut cache).unwrap();
        mse_loss_grad(cache.output(), &batch.target, &mut dy).unwrap();
        mlp_backward(&g.mlp, &cache, &dy, &mut grads, None, &mut ws).unwrap();
    });
    Ok(m.mean_ms())
}

fn load_field(weights: &str, field: &str, parsed: &hypersolvers::util::cli::Parsed) -> Result<FieldNet> {
    if !weights.is_empty() {
        let model = CnfModel::load(Path::new(weights))?;
        return Ok(model.field);
    }
    let f = match field {
        "vdp" | "vanderpol" => AnalyticField::VanDerPol {
            mu: parsed.get_f64("mu") as f32,
        },
        "rotation" => AnalyticField::Rotation {
            omega: parsed.get_f64("omega") as f32,
        },
        "decay" => AnalyticField::Decay {
            lambda: parsed.get_f64("lambda") as f32,
        },
        other => {
            return Err(hypersolvers::Error::Other(format!(
                "unknown field {other:?} (vdp | rotation | decay, or --weights)"
            )))
        }
    };
    Ok(FieldNet::Analytic(f))
}

