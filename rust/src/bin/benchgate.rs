//! `benchgate` — the bench-trajectory regression gate.
//!
//! Reads the rolling `BENCH_trajectory.json` (oldest → newest, one entry
//! per bench run; see `util::benchkit::append_trajectory`), diffs the
//! newest entry of every bench stream against the previous one, and exits
//! non-zero on a regression:
//!
//! * serving p50 (`serving_throughput.mixed_p50_ms`) growing past
//!   `--p50-slack ×` the previous run;
//! * the trained hypersolver dropping off the NFE Pareto front
//!   (`hyperbench_pareto.tasks[*].hyper_on_nfe_front` true → false);
//! * the serve-path speedup vs the tightest dopri5 collapsing below 1×;
//! * overload goodput (`serving_throughput.overload_goodput`): shedding-on
//!   must strictly beat the shedding-off baseline within a run, and must
//!   not drop more than `--goodput-drop` (absolute) run-over-run.
//!
//! CI restores the previous run's trajectory via actions/cache before the
//! benches run, so the file genuinely accumulates and this diff is
//! commit-over-commit. A missing file (first run / cold cache) passes with
//! a note — there is nothing to regress against yet.
//!
//! A second mode, `--expo-check FILE`, validates a scraped Prometheus
//! exposition instead of the trajectory: the file must parse as exposition
//! text and carry the serving metric families the dashboards key on. CI
//! runs it against the text scraped from the serving bench's
//! `--metrics-addr` listener. `--expo-check-health FILE` is the same check
//! plus the numerical-health families (`hypersolvers_audit_*`,
//! `hypersolvers_drift_score`) — for expositions rendered with the shadow
//! audit plane enabled.
//!
//! ```bash
//! benchgate                                   # ./BENCH_trajectory.json
//! benchgate --trajectory path.json --p50-slack 1.75
//! benchgate --expo-check metrics.prom         # gate a scraped exposition
//! benchgate --expo-check-health health.prom   # + audit/drift families
//! ```

use hypersolvers::obs::expo;
use hypersolvers::util::benchkit;
use hypersolvers::util::cli::Cli;
use hypersolvers::util::json;

fn main() {
    let args = Cli::new("benchgate — diff the bench trajectory and fail on regressions")
        .opt(
            "trajectory",
            "BENCH_trajectory.json",
            "rolling trajectory file (BENCH_TRAJECTORY env also honored)",
        )
        .opt(
            "expo-check",
            "",
            "validate a scraped Prometheus exposition file instead of \
             gating the trajectory",
        )
        .opt(
            "expo-check-health",
            "",
            "like --expo-check, but additionally require the shadow-audit \
             and drift metric families (audit-enabled expositions)",
        )
        .opt(
            "p50-slack",
            "1.75",
            "allowed serving-p50 growth factor run-over-run (wall clock on \
             shared runners is noisy; keep this generous)",
        )
        .opt(
            "goodput-drop",
            "0.15",
            "allowed absolute drop of overload goodput run-over-run \
             (goodput is in [0, 1])",
        )
        .parse_env();

    let expo_path = args.get("expo-check");
    if !expo_path.is_empty() {
        expo_check(&expo_path, false);
        return;
    }
    let health_path = args.get("expo-check-health");
    if !health_path.is_empty() {
        expo_check(&health_path, true);
        return;
    }

    let path = std::env::var("BENCH_TRAJECTORY")
        .unwrap_or_else(|_| args.get("trajectory"));
    let slack = args.get_f64("p50-slack");
    if !(slack.is_finite() && slack >= 1.0) {
        eprintln!("error: --p50-slack must be a finite factor ≥ 1, got {slack}");
        std::process::exit(2);
    }
    let goodput_drop = args.get_f64("goodput-drop");
    if !(goodput_drop.is_finite() && (0.0..=1.0).contains(&goodput_drop)) {
        eprintln!("error: --goodput-drop must be in [0, 1], got {goodput_drop}");
        std::process::exit(2);
    }

    let path = std::path::Path::new(&path);
    if !path.exists() {
        println!(
            "benchgate: {} does not exist — first run, nothing to gate",
            path.display()
        );
        return;
    }
    let entries = match json::parse_file(path) {
        Ok(v) => match v.as_arr() {
            Some(a) => a.to_vec(),
            None => {
                eprintln!(
                    "error: {} is not a JSON array of trajectory entries",
                    path.display()
                );
                std::process::exit(2);
            }
        },
        Err(e) => {
            eprintln!("error: parse {}: {e}", path.display());
            std::process::exit(2);
        }
    };

    println!(
        "benchgate: {} entries in {}, p50 slack {slack}×, goodput drop ≤ {goodput_drop}",
        entries.len(),
        path.display()
    );
    let report = benchkit::trajectory_gate(&entries, slack, goodput_drop);
    for line in &report.checks {
        println!("  ok  {line}");
    }
    for line in &report.regressions {
        println!("  FAIL {line}");
    }
    if !report.passed() {
        eprintln!(
            "benchgate: {} regression(s) against the previous run",
            report.regressions.len()
        );
        std::process::exit(1);
    }
    println!("benchgate: no regressions");
}

/// `--expo-check` / `--expo-check-health`: the scraped exposition must
/// parse line-for-line and carry the families the serving dashboards key
/// on — plus, in health mode, the shadow-audit and drift families an
/// audit-enabled engine renders. A scrape that raced the bench's first
/// engine (`hypersolvers_up` only) fails here — CI's retry loop is
/// supposed to have waited that out.
fn expo_check(path: &str, health: bool) {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: read {path}: {e}");
            std::process::exit(2);
        }
    };
    let mut required = vec![
        "hypersolvers_requests_total",
        "hypersolvers_responses_total",
        "hypersolvers_batch_fill_ratio",
        "hypersolvers_goodput",
        "hypersolvers_latency_us",
    ];
    if health {
        required.extend([
            "hypersolvers_audit_samples_total",
            "hypersolvers_audit_drops_total",
            "hypersolvers_audit_budget_breach_total",
            "hypersolvers_audit_error",
            "hypersolvers_drift_score",
        ]);
    }
    match expo::self_check(&text, &required) {
        Ok(samples) => {
            println!(
                "benchgate: exposition ok — {samples} samples, all {} required \
                 families present",
                required.len()
            );
        }
        Err(e) => {
            eprintln!("benchgate: bad exposition in {path}: {e}");
            std::process::exit(1);
        }
    }
}
