//! `hyperbench` — the Pareto evaluation pipeline as a CLI.
//!
//! Grid config → hypersolver training → kernel sweeps (box + trajectory
//! states) → grid-wide artifact export → serve-path sweep → Pareto fronts
//! → `BENCH_pareto.json` (shared bench schema) + a `BENCH_trajectory.json`
//! entry + human-readable tables.
//!
//! Examples:
//!   hyperbench                                   # full grid, vdp/rotation/mlp64
//!   hyperbench --tasks vdp --ks 1,2,4,8 --hyper-k 4
//!   hyperbench --smoke                           # CI grid + assertions
//!
//! `--smoke` runs the CI-sized grid on VanDerPol and **asserts** that the
//! trained HyperEuler point (a) lands on the NFE-vs-error Pareto front
//! ahead of same-NFE Euler (and Midpoint when on the grid) in the kernel
//! sweep over trajectory states, and (b) beats the same-NFE classical
//! variants through the full serve path while costing less wall-clock
//! than the tightest served dopri5. Exit code 1 when any claim fails.

use std::path::PathBuf;
use std::sync::Arc;

use hypersolvers::pareto::{
    check_same_nfe_dominance, pareto_doc, render_plane, run_pipeline,
    serve_speedup_vs_tightest_dopri5, trajectory_entry, GridConfig, TaskSpec,
};
use hypersolvers::tensor;
use hypersolvers::util::benchkit;
use hypersolvers::util::cli::{self, Cli};
use hypersolvers::util::threadpool::ThreadPool;
use hypersolvers::Result;

fn main() {
    let parsed = Cli::new(
        "hyperbench — solver×step×tolerance×task Pareto sweeps over the \
         kernel and serve paths",
    )
    .opt(
        "tasks",
        "vdp,rotation,mlp64",
        "comma list: vdp | rotation | decay | mlp64 (synthetic MLP field)",
    )
    .opt("solvers", "euler,midpoint,rk4", "classical fixed-step tableaus")
    .opt("ks", "1,2,4,8,16,32", "step counts of the fixed-step axis")
    .opt("tols", "1e-2,1e-3,1e-5", "dopri5 tolerances of the adaptive axis")
    .opt("hyper-base", "euler", "base tableau of the trained hypersolver")
    .opt("hyper-k", "8", "step count the hypersolver is trained and swept at")
    .opt("batch", "256", "states per sweep batch (also the serve batch)")
    .opt("seed", "7", "PRNG seed")
    .opt("span", "0,1", "integration span s0,s1")
    .opt("box", "2", "initial-state box half-width")
    .opt("ref-tol", "1e-7", "dopri5 tolerance of the error reference")
    .opt("measure-ms", "150", "benchkit budget per grid cell (ms)")
    .opt("train-steps", "4000", "max residual-fitting steps per task")
    .opt("hidden", "16,16", "hidden widths of g_ω")
    .opt("stop-at", "8", "early-stop at this one-step improvement factor")
    .opt(
        "artifacts-out",
        "",
        "serve-path artifact dir (default: a fresh temp dir; the export is \
         directly servable by hypersolverd --backend native)",
    )
    .opt("matmul-threads", "0", "row-block matmul pool size (0 = off)")
    .flag(
        "smoke",
        "CI grid on VanDerPol + hard assertions (ignores the grid-shape flags)",
    )
    .flag("quiet", "suppress per-task progress lines")
    .parse_env();

    let mm = parsed.get_usize("matmul-threads");
    if mm > 0 {
        tensor::set_matmul_pool(Arc::new(ThreadPool::new(mm)));
        println!("matmul pool: {mm} workers");
    }

    if let Err(e) = run(&parsed) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(parsed: &hypersolvers::util::cli::Parsed) -> Result<()> {
    let smoke = parsed.get_flag("smoke");
    let quiet = parsed.get_flag("quiet");
    let grid = if smoke {
        GridConfig {
            seed: parsed.get_usize("seed") as u64,
            log: !quiet,
            ..GridConfig::smoke()
        }
    } else {
        GridConfig {
            solvers: cli::parse_list(&parsed.get("solvers")),
            ks: cli::parse_usize_list("--ks", &parsed.get("ks"))?,
            tols: cli::parse_f32_list("--tols", &parsed.get("tols"))?,
            hyper_base: parsed.get("hyper-base"),
            hyper_k: parsed.get_usize("hyper-k"),
            batch: parsed.get_usize("batch"),
            seed: parsed.get_usize("seed") as u64,
            span: cli::parse_span("--span", &parsed.get("span"))?,
            sample_box: parsed.get_f64("box") as f32,
            ref_tol: parsed.get_f64("ref-tol") as f32,
            measure_ms: parsed.get_usize("measure-ms") as u64,
            train_steps: parsed.get_usize("train-steps"),
            train_hidden: cli::parse_usize_list("--hidden", &parsed.get("hidden"))?,
            train_stop_at: parsed.get_f64("stop-at") as f32,
            log: !quiet,
            ..GridConfig::standard()
        }
    };
    grid.validate()?;

    let task_names = if smoke {
        vec!["vdp".to_string()]
    } else {
        cli::parse_list(&parsed.get("tasks"))
    };
    let mut tasks = Vec::with_capacity(task_names.len());
    for name in &task_names {
        tasks.push(resolve_task(name, grid.seed)?);
    }

    let artifacts_dir = {
        let out = parsed.get("artifacts-out");
        if out.is_empty() {
            temp_artifacts_dir()?
        } else {
            PathBuf::from(out)
        }
    };
    println!(
        "hyperbench: {} task(s), {} solvers × {} ks + hyper{}_k{} + {} tols → {}",
        tasks.len(),
        grid.solvers.len(),
        grid.ks.len(),
        grid.hyper_base,
        grid.hyper_k,
        grid.tols.len(),
        artifacts_dir.display()
    );

    let reports = run_pipeline(&grid, &tasks, &artifacts_dir)?;

    for r in &reports {
        println!();
        println!("{}", render_plane(&format!("[{}] kernel, box states", r.task), &r.kernel_box));
        println!(
            "{}",
            render_plane(&format!("[{}] kernel, trajectory states", r.task), &r.kernel_traj)
        );
        println!("{}", render_plane(&format!("[{}] serve path (native)", r.task), &r.serve));
        if let Some(sp) = serve_speedup_vs_tightest_dopri5(&r.serve, &grid) {
            println!(
                "[{}] served hyper{}_k{} runs {sp:.1}× faster than the tightest \
                 served dopri5",
                r.task, grid.hyper_base, grid.hyper_k
            );
        }
    }

    let doc = pareto_doc(&grid, &reports);
    let path = benchkit::write_bench_json("BENCH_pareto.json", &doc)?;
    println!("\nwrote {}", path.display());
    let tpath = benchkit::append_trajectory(trajectory_entry(&grid, &reports))?;
    println!("appended to {}", tpath.display());
    println!("serve artifacts kept at {}", artifacts_dir.display());

    if smoke {
        assert_smoke(&grid, &reports)?;
        println!("SMOKE OK: HyperEuler on the NFE front ahead of Euler, and ahead through the serve path");
    }
    Ok(())
}

/// The CI assertions: the paper's claim on the tiny grid, checked hard.
fn assert_smoke(
    grid: &GridConfig,
    reports: &[hypersolvers::pareto::TaskReport],
) -> Result<()> {
    use hypersolvers::Error;
    for r in reports {
        // kernel plane, trajectory states (the distribution g trained on)
        let chk = check_same_nfe_dominance(&r.kernel_traj, grid)?;
        if !chk.dominates_same_nfe_euler() {
            return Err(Error::Other(format!(
                "[{}] smoke: {} (err {:.3e}) does not beat same-NFE euler ({:?})",
                r.task, chk.hyper_label, chk.err_hyper, chk.err_euler
            )));
        }
        if chk.err_midpoint.is_some() && !chk.dominates_same_nfe_midpoint() {
            return Err(Error::Other(format!(
                "[{}] smoke: {} (err {:.3e}) does not beat same-NFE midpoint ({:?})",
                r.task, chk.hyper_label, chk.err_hyper, chk.err_midpoint
            )));
        }
        if !chk.on_nfe_front {
            return Err(Error::Other(format!(
                "[{}] smoke: {} is not on the NFE-vs-error front",
                r.task, chk.hyper_label
            )));
        }
        // serve plane: same-NFE error ranking survives the full serve
        // path, and the hyper variant undercuts the tightest dopri5 wall
        let schk = check_same_nfe_dominance(&r.serve, grid)?;
        if !schk.dominates_same_nfe_euler() {
            return Err(Error::Other(format!(
                "[{}] smoke: served {} (err {:.3e}) does not beat same-NFE euler ({:?})",
                r.task, schk.hyper_label, schk.err_hyper, schk.err_euler
            )));
        }
        match serve_speedup_vs_tightest_dopri5(&r.serve, grid) {
            Some(sp) if sp > 1.0 => {}
            other => {
                return Err(Error::Other(format!(
                    "[{}] smoke: served hyper point is not faster than the \
                     tightest dopri5 (speedup {other:?})",
                    r.task
                )))
            }
        }
    }
    Ok(())
}

fn resolve_task(name: &str, seed: u64) -> Result<TaskSpec> {
    match name {
        "mlp64" => Ok(TaskSpec::synthetic_mlp("mlp64", &[64, 64], seed)),
        "mlp16" => Ok(TaskSpec::synthetic_mlp("mlp16", &[16, 16], seed)),
        other => TaskSpec::analytic(other),
    }
}

fn temp_artifacts_dir() -> Result<PathBuf> {
    use std::sync::atomic::{AtomicU64, Ordering};
    static UNIQ: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "hyperbench_artifacts_{}_{}",
        std::process::id(),
        UNIQ.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}

