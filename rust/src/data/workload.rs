//! Serving workload generation: Poisson arrivals of inference requests with
//! heterogeneous accuracy budgets — the trace the coordinator benches replay.

use crate::util::prng::Rng;

/// One request in a trace.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// arrival offset from trace start, seconds
    pub at_s: f64,
    /// task name (e.g. "cnf_rings")
    pub task: String,
    /// MAPE budget the response must satisfy
    pub budget: f32,
}

#[derive(Clone, Debug)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
}

/// Workload shape: arrival rate and the budget mixture.
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    /// mean requests/second (Poisson)
    pub rate: f64,
    /// total requests
    pub count: usize,
    /// tasks to draw from (uniform)
    pub tasks: Vec<String>,
    /// (budget, weight) mixture, e.g. tight real-time vs loose batch jobs
    pub budgets: Vec<(f32, f64)>,
}

impl WorkloadSpec {
    pub fn generate(&self, rng: &mut Rng) -> Trace {
        assert!(!self.tasks.is_empty() && !self.budgets.is_empty());
        let total_w: f64 = self.budgets.iter().map(|(_, w)| w).sum();
        let mut t = 0.0f64;
        let mut events = Vec::with_capacity(self.count);
        for _ in 0..self.count {
            t += rng.exponential(self.rate);
            let task = rng.choose(&self.tasks).clone();
            let mut pick = rng.uniform() * total_w;
            let mut budget = self.budgets[0].0;
            for (b, w) in &self.budgets {
                if pick < *w {
                    budget = *b;
                    break;
                }
                pick -= w;
            }
            events.push(TraceEvent {
                at_s: t,
                task,
                budget,
            });
        }
        Trace { events }
    }
}

impl Trace {
    pub fn duration_s(&self) -> f64 {
        self.events.last().map(|e| e.at_s).unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> WorkloadSpec {
        WorkloadSpec {
            rate: 100.0,
            count: 1000,
            tasks: vec!["a".into(), "b".into()],
            budgets: vec![(0.05, 0.7), (0.2, 0.3)],
        }
    }

    #[test]
    fn arrivals_are_monotone_and_rate_matches() {
        let mut rng = Rng::new(0);
        let trace = spec().generate(&mut rng);
        assert_eq!(trace.events.len(), 1000);
        for w in trace.events.windows(2) {
            assert!(w[1].at_s >= w[0].at_s);
        }
        // mean inter-arrival ≈ 1/rate
        let mean = trace.duration_s() / 1000.0;
        assert!((mean - 0.01).abs() < 0.002, "mean={mean}");
    }

    #[test]
    fn budget_mixture_respected() {
        let mut rng = Rng::new(1);
        let trace = spec().generate(&mut rng);
        let tight = trace.events.iter().filter(|e| e.budget == 0.05).count();
        let frac = tight as f64 / 1000.0;
        assert!((frac - 0.7).abs() < 0.05, "tight fraction {frac}");
    }

    #[test]
    fn deterministic_under_seed() {
        let a = spec().generate(&mut Rng::new(9));
        let b = spec().generate(&mut Rng::new(9));
        assert_eq!(a.events.len(), b.events.len());
        assert_eq!(a.events[5].task, b.events[5].task);
        assert_eq!(a.events[5].at_s, b.events[5].at_s);
    }
}
