//! Native port of the synthetic image dataset (`compile/tasks/images.py`).
//!
//! Same generative family — class identity = (start angle, curvature,
//! lobes) of a parametric stroke, gaussian bumps splatted along it,
//! class-coded color + texture for the 3-channel variant. Streams are not
//! bit-identical to numpy's (different PRNG); class structure is, which is
//! what the tests check. Used by benches that want fresh evaluation data
//! beyond the exported blobs.

use crate::tensor::Tensor;
use crate::util::prng::Rng;
use crate::{Error, Result};

pub const HW: usize = 16;
pub const N_CLASSES: usize = 10;

/// Render one grayscale stroke image of class `c`.
pub fn render_stroke(c: usize, rng: &mut Rng) -> [f32; HW * HW] {
    let n_pts = 24;
    let ang0 = 2.0 * std::f64::consts::PI * c as f64 / N_CLASSES as f64
        + rng.normal() * 0.1;
    let curv = 2.0 + 1.5 * ((c * 7) % N_CLASSES) as f64 / N_CLASSES as f64;
    let lobes = 1 + (c % 3);
    let cx = 0.5 + 0.06 * rng.normal();
    let cy = 0.5 + 0.06 * rng.normal();

    let mut img = [0.0f32; HW * HW];
    let sig2 = 2.0 * 0.045f64 * 0.045;
    for i in 0..n_pts {
        let t = i as f64 / (n_pts - 1) as f64;
        let r = 0.25 + 0.18 * (lobes as f64 * 2.0 * std::f64::consts::PI * t).sin();
        let ang = ang0 + curv * t;
        let px = cx + r * ang.cos();
        let py = cy + r * ang.sin();
        for y in 0..HW {
            let fy = y as f64 / (HW - 1) as f64;
            for x in 0..HW {
                let fx = x as f64 / (HW - 1) as f64;
                let d2 = (fx - px) * (fx - px) + (fy - py) * (fy - py);
                img[y * HW + x] += (-d2 / sig2).exp() as f32;
            }
        }
    }
    let max = img.iter().cloned().fold(0.0f32, f32::max) + 1e-6;
    for v in &mut img {
        *v = *v / max + 0.05 * rng.normal() as f32;
    }
    img
}

/// Generate `n` samples: (images NCHW, labels). `channels` 1 (smnist-like)
/// or 3 (scifar-like).
pub fn make_dataset(
    channels: usize,
    n: usize,
    rng: &mut Rng,
) -> Result<(Tensor, Vec<i32>)> {
    if channels != 1 && channels != 3 {
        return Err(Error::Other("channels must be 1 or 3".into()));
    }
    let plane = HW * HW;
    let mut data = vec![0.0f32; n * channels * plane];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let c = rng.below(N_CLASSES as u64) as usize;
        labels.push(c as i32);
        let g = render_stroke(c, rng);
        if channels == 1 {
            data[i * plane..(i + 1) * plane].copy_from_slice(&g);
        } else {
            let mix = [
                0.3 + 0.7 * ((c * 3) % 10) as f32 / 10.0,
                0.3 + 0.7 * ((c * 7 + 2) % 10) as f32 / 10.0,
                0.3 + 0.7 * ((c * 5 + 5) % 10) as f32 / 10.0,
            ];
            for k in 0..3 {
                let base = (i * 3 + k) * plane;
                for y in 0..HW {
                    for x in 0..HW {
                        let fx = x as f32 / (HW - 1) as f32 * 2.0 * std::f32::consts::PI;
                        let fy = y as f32 / (HW - 1) as f32 * 2.0 * std::f32::consts::PI;
                        let tex =
                            0.15 * (fx * (1 + c % 4) as f32 + fy * (1 + c / 4) as f32).sin();
                        data[base + y * HW + x] = mix[k] * g[y * HW + x]
                            + tex
                            + 0.05 * rng.normal() as f32;
                    }
                }
            }
        }
    }
    Ok((Tensor::new(&[n, channels, HW, HW], data)?, labels))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let mut rng = Rng::new(0);
        let (x, y) = make_dataset(1, 24, &mut rng).unwrap();
        assert_eq!(x.shape(), &[24, 1, HW, HW]);
        assert_eq!(y.len(), 24);
        assert!(y.iter().all(|&l| (0..N_CLASSES as i32).contains(&l)));
        assert!(x.data().iter().all(|v| v.is_finite()));
        let (x3, _) = make_dataset(3, 4, &mut rng).unwrap();
        assert_eq!(x3.shape(), &[4, 3, HW, HW]);
        assert!(make_dataset(2, 4, &mut rng).is_err());
    }

    #[test]
    fn classes_are_distinguishable() {
        // intra-class mean distance < inter-class template distance
        let mut rng = Rng::new(1);
        let mut means: Vec<Vec<f32>> = Vec::new();
        let mut intra = 0.0f64;
        for c in 0..3 {
            let imgs: Vec<[f32; HW * HW]> =
                (0..8).map(|_| render_stroke(c, &mut rng)).collect();
            let mut mean = vec![0.0f32; HW * HW];
            for img in &imgs {
                for (m, v) in mean.iter_mut().zip(img.iter()) {
                    *m += v / 8.0;
                }
            }
            for img in &imgs {
                let d: f32 = img
                    .iter()
                    .zip(&mean)
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f32>()
                    .sqrt();
                intra += d as f64 / 24.0;
            }
            means.push(mean);
        }
        let mut inter = 0.0f64;
        let mut pairs = 0;
        for i in 0..3 {
            for j in (i + 1)..3 {
                let d: f32 = means[i]
                    .iter()
                    .zip(&means[j])
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f32>()
                    .sqrt();
                inter += d as f64;
                pairs += 1;
            }
        }
        inter /= pairs as f64;
        assert!(inter > intra, "inter {inter} vs intra {intra}");
    }

    #[test]
    fn deterministic_under_seed() {
        let (a, la) = make_dataset(1, 8, &mut Rng::new(42)).unwrap();
        let (b, lb) = make_dataset(1, 8, &mut Rng::new(42)).unwrap();
        assert_eq!(a, b);
        assert_eq!(la, lb);
    }
}
