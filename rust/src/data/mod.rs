//! Data substrates: 2-D toy densities, artifact blob loading, and the
//! Poisson request-trace generator for the serving benches.

pub mod blobs;
pub mod densities;
pub mod synthimg;
pub mod workload;

pub use blobs::{load_f32, load_i32, Blob};
pub use densities::sample_density;
pub use workload::{Trace, TraceEvent, WorkloadSpec};
