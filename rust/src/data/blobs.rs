//! Raw f32/i32 artifact blob loading (`artifacts/data/*.bin` + the shapes
//! recorded in the manifest).

use std::path::Path;

use crate::tensor::Tensor;
use crate::{Error, Result};

/// A loaded blob: data + shape.
#[derive(Clone, Debug)]
pub struct Blob {
    pub shape: Vec<usize>,
    pub f32_data: Vec<f32>,
}

impl Blob {
    pub fn to_tensor(&self) -> Result<Tensor> {
        Tensor::new(&self.shape, self.f32_data.clone())
    }
}

/// Load little-endian f32s and validate against the expected shape.
pub fn load_f32(path: &Path, shape: &[usize]) -> Result<Tensor> {
    let bytes = std::fs::read(path)
        .map_err(|e| Error::Other(format!("read {}: {e}", path.display())))?;
    if bytes.len() % 4 != 0 {
        return Err(Error::Other(format!(
            "{}: length {} not a multiple of 4",
            path.display(),
            bytes.len()
        )));
    }
    let numel: usize = shape.iter().product();
    if bytes.len() / 4 != numel {
        return Err(Error::Shape(format!(
            "{}: {} f32s on disk, shape {:?} wants {}",
            path.display(),
            bytes.len() / 4,
            shape,
            numel
        )));
    }
    let data: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Tensor::new(shape, data)
}

/// Load little-endian i32s.
pub fn load_i32(path: &Path, len: usize) -> Result<Vec<i32>> {
    let bytes = std::fs::read(path)
        .map_err(|e| Error::Other(format!("read {}: {e}", path.display())))?;
    if bytes.len() / 4 != len {
        return Err(Error::Shape(format!(
            "{}: {} i32s on disk, expected {len}",
            path.display(),
            bytes.len() / 4
        )));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let dir = std::env::temp_dir().join("hsolve_blob_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.bin");
        let vals = [1.5f32, -2.25, 0.0, 1e-7];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        let t = load_f32(&path, &[2, 2]).unwrap();
        assert_eq!(t.data(), &vals);
        assert!(load_f32(&path, &[3, 3]).is_err());
    }

    #[test]
    fn i32_roundtrip() {
        let dir = std::env::temp_dir().join("hsolve_blob_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("y.bin");
        let vals = [7i32, -9, 0];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        assert_eq!(load_i32(&path, 3).unwrap(), vals);
        assert!(load_i32(&path, 4).is_err());
    }

    #[test]
    fn missing_file_errors() {
        assert!(load_f32(Path::new("/nonexistent/x.bin"), &[1]).is_err());
    }
}
