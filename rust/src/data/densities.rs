//! 2-D toy densities (pinwheel, rings, checkerboard, circles).
//!
//! Native ports of `compile/tasks/cnf.py::sample_density` — the CNF bench
//! uses these to draw fresh evaluation sets without touching python. The
//! PRNG differs from numpy's, so streams are not bit-identical to the
//! python sampler; distributional equality is what the tests check.

use crate::tensor::Tensor;
use crate::util::prng::Rng;
use crate::{Error, Result};

pub const DENSITIES: [&str; 4] = ["pinwheel", "rings", "checkerboard", "circles"];

/// Draw `n` samples from a named density as an (n, 2) tensor.
pub fn sample_density(name: &str, n: usize, rng: &mut Rng) -> Result<Tensor> {
    let mut out = Vec::with_capacity(n * 2);
    match name {
        "pinwheel" => {
            let (radial_std, tangential_std, num_classes, rate) = (0.3, 0.1, 5u64, 0.25);
            for _ in 0..n {
                let label = rng.below(num_classes) as f64;
                let f0 = rng.normal() * radial_std + 1.0;
                let f1 = rng.normal() * tangential_std;
                let ang = 2.0 * std::f64::consts::PI * label / num_classes as f64
                    + rate * f0.exp();
                let (c, s) = (ang.cos(), ang.sin());
                // rotate (f0, f1) by ang, scale 2 (matches the python einsum)
                out.push((2.0 * (f0 * c + f1 * s)) as f32);
                out.push((2.0 * (-f0 * s + f1 * c)) as f32);
            }
        }
        "rings" => {
            let radii = [1.0, 2.0, 3.0];
            for _ in 0..n {
                let r = radii[rng.below(3) as usize] + rng.normal() * 0.08;
                let ang = rng.uniform_in(0.0, 2.0 * std::f64::consts::PI);
                out.push((r * ang.cos()) as f32);
                out.push((r * ang.sin()) as f32);
            }
        }
        "checkerboard" => {
            for _ in 0..n {
                let x1 = rng.uniform_in(-3.0, 3.0);
                let x2_ = rng.uniform_in(0.0, 1.5);
                let offs = ((x1 / 1.5).floor().rem_euclid(2.0)) * 1.5;
                let x2 = x2_ + offs - 1.5 * (rng.below(2) as f64) * 2.0;
                out.push(x1 as f32);
                out.push(x2 as f32);
            }
        }
        "circles" => {
            for _ in 0..n {
                let kind = rng.uniform();
                let ang = rng.uniform_in(0.0, 2.0 * std::f64::consts::PI);
                let (x, y) = if kind < 0.4 {
                    let r = 1.0 + rng.normal() * 0.06;
                    (r * ang.cos(), r * ang.sin())
                } else if kind < 0.8 {
                    let r = 2.5 + rng.normal() * 0.06;
                    (r * ang.cos(), r * ang.sin())
                } else {
                    let ci = rng.below(3) as f64;
                    let base = 2.0 * std::f64::consts::PI * ci / 3.0
                        + rng.normal() * 0.05;
                    let rr = rng.uniform_in(1.0, 2.5);
                    (rr * base.cos(), rr * base.sin())
                };
                out.push(x as f32);
                out.push(y as f32);
            }
        }
        _ => return Err(Error::Other(format!("unknown density {name:?}"))),
    }
    Tensor::new(&[n, 2], out)
}

/// 2-D histogram over [-lim, lim]² — sample-quality scoring for the CNF
/// figures (normalised counts; L1 distance between histograms is the
/// reported sample-quality metric).
pub fn histogram2d(samples: &Tensor, bins: usize, lim: f32) -> Vec<f64> {
    let n = samples.shape()[0];
    let mut h = vec![0.0f64; bins * bins];
    let width = 2.0 * lim / bins as f32;
    for i in 0..n {
        let x = samples.data()[i * 2];
        let y = samples.data()[i * 2 + 1];
        let bx = ((x + lim) / width).floor();
        let by = ((y + lim) / width).floor();
        if bx >= 0.0 && by >= 0.0 && (bx as usize) < bins && (by as usize) < bins {
            h[by as usize * bins + bx as usize] += 1.0;
        }
    }
    let total: f64 = h.iter().sum();
    if total > 0.0 {
        for v in &mut h {
            *v /= total;
        }
    }
    h
}

/// L1 distance between two normalised histograms (in [0, 2]).
pub fn hist_l1(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

/// Render a normalised 2-D histogram as ascii shades — the Fig. 1
/// qualitative view of CNF sample quality, terminal-friendly.
pub fn density_ascii(hist: &[f64], bins: usize) -> String {
    const SHADES: &[u8] = b" .:-=+*#%@";
    let max = hist.iter().cloned().fold(0.0f64, f64::max).max(1e-12);
    let mut out = String::with_capacity(bins * (bins + 1));
    for row in (0..bins).rev() {
        for col in 0..bins {
            let v = hist[row * bins + col] / max;
            let idx = ((v.sqrt()) * (SHADES.len() - 1) as f64).round() as usize;
            out.push(SHADES[idx.min(SHADES.len() - 1)] as char);
            out.push(SHADES[idx.min(SHADES.len() - 1)] as char);
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_densities_sample() {
        let mut rng = Rng::new(0);
        for name in DENSITIES {
            let t = sample_density(name, 500, &mut rng).unwrap();
            assert_eq!(t.shape(), &[500, 2]);
            assert!(t.data().iter().all(|x| x.is_finite()));
            assert!(t.data().iter().all(|x| x.abs() < 12.0), "{name}");
        }
        assert!(sample_density("moons", 10, &mut rng).is_err());
    }

    #[test]
    fn rings_radii_cluster() {
        let mut rng = Rng::new(1);
        let t = sample_density("rings", 2000, &mut rng).unwrap();
        let mut near = 0;
        for i in 0..2000 {
            let r = (t.data()[2 * i].powi(2) + t.data()[2 * i + 1].powi(2)).sqrt();
            let d = [1.0f32, 2.0, 3.0]
                .iter()
                .map(|c| (r - c).abs())
                .fold(f32::INFINITY, f32::min);
            if d < 0.3 {
                near += 1;
            }
        }
        assert!(near > 1900, "only {near}/2000 near a ring");
    }

    #[test]
    fn density_ascii_renders() {
        let mut rng = Rng::new(3);
        let s = sample_density("rings", 1000, &mut rng).unwrap();
        let art = density_ascii(&histogram2d(&s, 10, 4.0), 10);
        assert_eq!(art.lines().count(), 10);
        assert!(art.lines().all(|l| l.chars().count() == 20));
        assert!(art.contains('@') || art.contains('%')); // has a hot bin
    }

    #[test]
    fn histogram_normalised_and_sensitive() {
        let mut rng = Rng::new(2);
        let a = sample_density("rings", 3000, &mut rng).unwrap();
        let b = sample_density("checkerboard", 3000, &mut rng).unwrap();
        let ha = histogram2d(&a, 16, 4.0);
        let hb = histogram2d(&b, 16, 4.0);
        assert!((ha.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        let same = hist_l1(&ha, &histogram2d(&sample_density("rings", 3000, &mut rng).unwrap(), 16, 4.0));
        let diff = hist_l1(&ha, &hb);
        assert!(diff > 3.0 * same, "same={same} diff={diff}");
    }
}
