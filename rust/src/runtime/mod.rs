//! Execution runtime: manifest loading plus the pluggable backends the
//! coordinator dispatches to.
//!
//! * [`backend`] defines the [`ExecBackend`] trait — "how does a (task,
//!   variant) batch execute" — and the [`PjrtBackend`] implementation over
//!   the AOT HLO artifacts.
//! * [`native`] serves the same manifest variants with the in-repo
//!   tensor/solver stack (no XLA, no artifacts beyond weights JSON).
//! * The `xla` crate's handles wrap raw PJRT pointers and are `!Send`, so
//!   all PJRT state lives on one dedicated **executor thread**
//!   ([`exec::Executor`]); the rest of the system talks to it through
//!   channels.

pub mod backend;
pub mod exec;
pub mod field_exec;
pub mod manifest;
pub mod native;

pub use backend::{pjrt_available, BackendKind, ExecBackend, ExecOutput, PjrtBackend};
pub use exec::{Executor, ExecutorHandle};
pub use manifest::{BlobRef, Manifest, TaskEntry, Variant};
pub use native::NativeBackend;
