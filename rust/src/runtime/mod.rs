//! PJRT runtime: load AOT artifacts (HLO text), compile, execute.
//!
//! The `xla` crate's handles wrap raw PJRT pointers and are `!Send`, so all
//! PJRT state lives on one dedicated **executor thread** ([`exec::Executor`]);
//! the rest of the system talks to it through channels. On this testbed
//! (single-core CPU PJRT) that costs nothing and it keeps the coordinator's
//! threading model independent of backend thread-safety.

pub mod exec;
pub mod field_exec;
pub mod manifest;

pub use exec::{Executor, ExecutorHandle};
pub use manifest::{BlobRef, Manifest, TaskEntry, Variant};
