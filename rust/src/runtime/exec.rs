//! The PJRT executor thread: owns the `!Send` XLA handles, serves execution
//! requests over channels, compiles HLO lazily and caches executables.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

use crate::{Error, Result};

/// A request to the executor thread.
enum Msg {
    /// Ensure the HLO at `path` is compiled under `key`.
    Load {
        key: String,
        path: PathBuf,
        reply: mpsc::Sender<Result<()>>,
    },
    /// Execute `key` with f32 inputs (data, shape) pairs; reply with all f32
    /// outputs flattened (tuple outputs decomposed in order).
    Run {
        key: String,
        inputs: Vec<(Vec<f32>, Vec<usize>)>,
        reply: mpsc::Sender<Result<Vec<Vec<f32>>>>,
    },
    Shutdown,
}

/// Handle to the executor thread (cheaply cloneable, `Send + Sync`: the
/// sender sits behind a mutex so one handle can be shared by the engine's
/// dispatch worker pool; requests still serialise on the executor thread).
#[derive(Clone)]
pub struct ExecutorHandle {
    tx: Arc<Mutex<mpsc::Sender<Msg>>>,
}

impl ExecutorHandle {
    fn send(&self, msg: Msg) -> Result<()> {
        self.tx
            .lock()
            .map_err(|_| Error::Other("executor handle poisoned".into()))?
            .send(msg)
            .map_err(|_| Error::Other("executor gone".into()))
    }
}

/// The executor: spawn once, share the handle.
pub struct Executor {
    handle: ExecutorHandle,
    join: Option<thread::JoinHandle<()>>,
}

impl Executor {
    /// Spawn the executor thread and bring up the PJRT CPU client on it.
    pub fn spawn() -> Result<Executor> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || executor_main(rx, ready_tx))
            .map_err(|e| Error::Other(format!("spawn executor: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Other("executor died at startup".into()))??;
        Ok(Executor {
            handle: ExecutorHandle {
                tx: Arc::new(Mutex::new(tx)),
            },
            join: Some(join),
        })
    }

    pub fn handle(&self) -> ExecutorHandle {
        self.handle.clone()
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        let _ = self.handle.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl ExecutorHandle {
    /// Compile (or confirm cached) the HLO text file under `key`.
    pub fn load(&self, key: &str, path: PathBuf) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.send(Msg::Load {
            key: key.to_string(),
            path,
            reply,
        })?;
        rx.recv().map_err(|_| Error::Other("executor gone".into()))?
    }

    /// Execute `key` on a single flattened f32 input. Returns every output
    /// leaf as a flat f32 vector (tuple outputs in declaration order).
    /// Takes ownership of the buffer — no copy on the hot path.
    pub fn run(&self, key: &str, input: Vec<f32>, in_shape: &[usize]) -> Result<Vec<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        self.send(Msg::Run {
            key: key.to_string(),
            inputs: vec![(input, in_shape.to_vec())],
            reply,
        })?;
        rx.recv().map_err(|_| Error::Other("executor gone".into()))?
    }

    /// Execute `key` with several (data, shape) f32 arguments.
    pub fn run_multi(&self, key: &str, inputs: &[(&[f32], &[usize])]) -> Result<Vec<Vec<f32>>> {
        let (reply, rx) = mpsc::channel();
        self.send(Msg::Run {
            key: key.to_string(),
            inputs: inputs
                .iter()
                .map(|(d, s)| (d.to_vec(), s.to_vec()))
                .collect(),
            reply,
        })?;
        rx.recv().map_err(|_| Error::Other("executor gone".into()))?
    }
}

fn executor_main(rx: mpsc::Receiver<Msg>, ready: mpsc::Sender<Result<()>>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(e.into()));
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();

    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Shutdown => break,
            Msg::Load { key, path, reply } => {
                let result = if cache.contains_key(&key) {
                    Ok(())
                } else {
                    load_exe(&client, &path).map(|exe| {
                        cache.insert(key, exe);
                    })
                };
                let _ = reply.send(result);
            }
            Msg::Run { key, inputs, reply } => {
                let result = match cache.get(&key) {
                    None => Err(Error::Other(format!(
                        "executable {key:?} not loaded"
                    ))),
                    Some(exe) => run_exe(exe, &inputs),
                };
                let _ = reply.send(result);
            }
        }
    }
}

fn load_exe(
    client: &xla::PjRtClient,
    path: &std::path::Path,
) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str()
            .ok_or_else(|| Error::Other("non-utf8 path".into()))?,
    )?;
    let comp = xla::XlaComputation::from_proto(&proto);
    Ok(client.compile(&comp)?)
}

fn run_exe(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[(Vec<f32>, Vec<usize>)],
) -> Result<Vec<Vec<f32>>> {
    let mut lits = Vec::with_capacity(inputs.len());
    for (data, shape) in inputs {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        lits.push(xla::Literal::vec1(data).reshape(&dims)?);
    }
    let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
    // exports are tuple-rooted (return_tuple=True); decompose every leaf
    let leaves = result.to_tuple()?;
    let mut out = Vec::with_capacity(leaves.len());
    for leaf in leaves {
        // nfe counters come back as i32/i64; normalise everything to f32
        let ty = leaf.ty()?;
        let v: Vec<f32> = match ty {
            xla::ElementType::F32 => leaf.to_vec::<f32>()?,
            xla::ElementType::S32 => leaf
                .to_vec::<i32>()?
                .into_iter()
                .map(|x| x as f32)
                .collect(),
            xla::ElementType::S64 => leaf
                .to_vec::<i64>()?
                .into_iter()
                .map(|x| x as f32)
                .collect(),
            other => {
                let conv = leaf.convert(xla::PrimitiveType::F32)?;
                let _ = other;
                conv.to_vec::<f32>()?
            }
        };
        out.push(v);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    // Executor tests that need artifacts live in rust/tests/ (integration);
    // here we only verify error paths that don't require a PJRT client.

    #[test]
    fn handle_is_clone() {
        fn assert_clone<T: Clone>() {}
        assert_clone::<super::ExecutorHandle>();
    }

    #[test]
    fn handle_is_send_sync() {
        // the dispatch worker pool shares one handle across threads
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<super::ExecutorHandle>();
    }
}
