//! The native execution backend: serves manifest variants with the in-repo
//! tensor/solver stack — no XLA runtime, no HLO artifacts, just
//! `manifest.json` plus the exported weight JSON.
//!
//! For each task it loads the weights once (`nn::{CnfModel, TrackingModel,
//! ImageModel}`), then instantiates the solver a variant names from its
//! `(solver, k, hyper)` manifest fields and integrates with
//! `odeint_fixed` / `odeint_hyper` / `dopri5` on the native [`Tensor`]
//! path. This is what makes the full submit→batch→execute→respond pipeline
//! exercisable in plain `cargo test` on any machine.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::nn::{CnfModel, ImageModel, TrackingModel};
use crate::ode::VectorField;
use crate::runtime::backend::{ExecBackend, ExecOutput};
use crate::runtime::manifest::{Manifest, TaskEntry, Variant};
use crate::solvers::{
    adaptive_ws, odeint_fixed_ws, odeint_hyper_ws, AdaptiveOpts, HyperNet, RkWorkspace, Tableau,
};
use crate::tensor::Tensor;
use crate::{Error, Result};

/// Tolerance used for `dopri5` variants whose manifest pins no `tol`.
///
/// Historically this was a silent `unwrap_or(1e-5)` buried in the execute
/// path; it is a named constant so the pareto sweep's default tolerance
/// grids ([`crate::pareto::grid::GridConfig`]) and the serving path agree
/// on — and document — the same default.
pub const DEFAULT_DOPRI5_TOL: f32 = 1e-5;

/// A task's weights, loaded once and shared across dispatch workers.
/// `pub(crate)` so the audit plane ([`crate::obs::audit`]) can load the
/// same weights for its tight-tolerance reference solves.
pub(crate) enum NativeModel {
    Cnf(CnfModel),
    Tracking(TrackingModel),
    Image(ImageModel),
}

impl NativeModel {
    pub(crate) fn load(manifest: &Manifest, task: &TaskEntry) -> Result<NativeModel> {
        let path = manifest.weights_path(task);
        match task.kind.as_str() {
            "cnf" => Ok(NativeModel::Cnf(CnfModel::load(&path)?)),
            "tracking" => Ok(NativeModel::Tracking(TrackingModel::load(&path)?)),
            "image" => Ok(NativeModel::Image(ImageModel::load(&path)?)),
            other => Err(Error::Manifest(format!(
                "native backend: unknown task kind {other:?} for {}",
                task.name
            ))),
        }
    }

    pub(crate) fn field(&self) -> &dyn VectorField {
        match self {
            NativeModel::Cnf(m) => &m.field,
            NativeModel::Tracking(m) => &m.field,
            NativeModel::Image(m) => &m.field,
        }
    }

    fn hyper(&self) -> &dyn HyperNet {
        match self {
            NativeModel::Cnf(m) => &m.hyper,
            NativeModel::Tracking(m) => &m.hyper,
            NativeModel::Image(m) => &m.hyper,
        }
    }
}

/// [`ExecBackend`] over the native solver stack. Model loading is cached
/// per task; execution takes no global lock, so batches for distinct
/// queues run genuinely in parallel on the engine's worker pool.
///
/// Each (task, variant) queue owns one [`RkWorkspace`] that persists
/// across batches: after the first batch warms it, the solver loop runs
/// with **zero steady-state heap allocation** (the engine's per-queue
/// affinity means a queue's workspace mutex is uncontended — at most one
/// worker executes a given queue at a time).
/// Everything a (task, variant) queue holds across batches: its solver
/// workspace and the (immutable) tableau, so steady-state batches rebuild
/// neither.
struct QueueState {
    tab: Tableau,
    ws: Mutex<RkWorkspace>,
    /// Persistent input staging tensor, shaped `variant.in_shape` — the
    /// borrowed batch slice is copied into it, so steady-state execution
    /// allocates nothing for the input either.
    z0: Mutex<Tensor>,
}

pub struct NativeBackend {
    models: Mutex<HashMap<String, Arc<NativeModel>>>,
    /// task name → variant name → the queue's persistent state. Nested so
    /// the steady-state lookup borrows `&str`s instead of building a
    /// `(String, String)` key per batch.
    queues: Mutex<HashMap<String, HashMap<String, Arc<QueueState>>>>,
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend {
            models: Mutex::new(HashMap::new()),
            queues: Mutex::new(HashMap::new()),
        }
    }

    fn model(&self, manifest: &Manifest, task: &TaskEntry) -> Result<Arc<NativeModel>> {
        if let Some(m) = self.models.lock().unwrap().get(&task.name) {
            return Ok(Arc::clone(m));
        }
        // load outside the lock: weight files can be large, and another
        // worker may be serving a different task meanwhile
        let loaded = Arc::new(NativeModel::load(manifest, task)?);
        let mut cache = self.models.lock().unwrap();
        Ok(Arc::clone(
            cache.entry(task.name.clone()).or_insert(loaded),
        ))
    }

    /// The (task, variant) queue's persistent state (workspace + tableau).
    /// The outer map lock is held only for the lookup (allocation-free
    /// once the entry exists); the solve itself holds the per-queue mutex.
    fn queue_state(&self, task: &TaskEntry, variant: &Variant) -> Result<Arc<QueueState>> {
        let mut map = self.queues.lock().unwrap();
        if let Some(qs) = map
            .get(task.name.as_str())
            .and_then(|m| m.get(variant.name.as_str()))
        {
            return Ok(Arc::clone(qs));
        }
        let tab = if variant.solver == "dopri5" {
            Tableau::dopri5()
        } else if variant.hyper {
            Tableau::by_name(&task.hyper_base)?
        } else {
            Tableau::by_name(&variant.solver)?
        };
        Ok(Arc::clone(
            map.entry(task.name.clone())
                .or_default()
                .entry(variant.name.clone())
                .or_insert_with(|| {
                    Arc::new(QueueState {
                        tab,
                        ws: Mutex::new(RkWorkspace::new()),
                        z0: Mutex::new(Tensor::zeros(&variant.in_shape)),
                    })
                }),
        ))
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl ExecBackend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn prepare(&self, manifest: &Manifest, task: &TaskEntry, _variant: &Variant) -> Result<()> {
        self.model(manifest, task).map(|_| ())
    }

    fn execute(
        &self,
        manifest: &Manifest,
        task: &TaskEntry,
        variant: &Variant,
        input: &[f32],
    ) -> Result<ExecOutput> {
        let model = self.model(manifest, task)?;
        let qs = self.queue_state(task, variant)?;

        // stage the borrowed batch into the queue's persistent input
        // tensor — the shape check `Tensor::new` used to perform, without
        // its per-batch allocation
        let mut staged = qs.z0.lock().unwrap();
        if input.len() != staged.numel() {
            return Err(Error::Shape(format!(
                "native batch for {}/{} carries {} values, in_shape {:?} wants {}",
                task.name,
                variant.name,
                input.len(),
                variant.in_shape,
                staged.numel()
            )));
        }
        staged.data_mut().copy_from_slice(input);

        // image tasks may export image→logits executables: the manifest's
        // state shape is the ODE-state shape, so an in_shape that differs
        // from it means the batch arrives in image space and needs the
        // learned h_x augmenter first
        let hx_t;
        let z0: &Tensor = match &*model {
            NativeModel::Image(im) if variant.in_shape != task.state_shape => {
                hx_t = im.hx(&staged)?;
                &hx_t
            }
            _ => &staged,
        };

        let field = model.field();
        let mut ws = qs.ws.lock().unwrap();
        let (zt, nfe) = if variant.solver == "dopri5" {
            // the manifest may pin a per-variant tolerance (the pareto
            // sweep's adaptive axis); otherwise fall back loudly to the
            // shared default instead of a silent magic number
            let tol = match variant.tol {
                Some(t) => t as f32,
                None => {
                    crate::log_debug!(
                        "variant {} pins no dopri5 tol; using default {DEFAULT_DOPRI5_TOL}",
                        variant.name
                    );
                    DEFAULT_DOPRI5_TOL
                }
            };
            let r = adaptive_ws(
                field,
                z0,
                task.s_span,
                &qs.tab,
                &AdaptiveOpts::with_tol(tol),
                &mut ws,
            )?;
            // solver-internal span counters: the engine reads this
            // thread-local right after execute() returns (same worker
            // thread on the native path), so the `_ws` solver signatures
            // stay untouched and the hot loop stays allocation-free
            crate::obs::solver_stamp(r.nfe, r.accepted, r.rejected);
            (r.z, Some(r.nfe))
        } else if variant.hyper {
            if variant.k == 0 {
                return Err(Error::Manifest(format!(
                    "variant {} has k=0 but is not adaptive",
                    variant.name
                )));
            }
            // honest field-eval count for the span: k steps × RK stages
            // (the hypersolver residual g is not a field eval)
            crate::obs::solver_stamp((variant.k * qs.tab.stages()) as u64, 0, 0);
            (
                odeint_hyper_ws(
                    field,
                    model.hyper(),
                    z0,
                    task.s_span,
                    variant.k,
                    &qs.tab,
                    &mut ws,
                )?
                .clone(),
                None,
            )
        } else {
            if variant.k == 0 {
                return Err(Error::Manifest(format!(
                    "variant {} has k=0 but is not adaptive",
                    variant.name
                )));
            }
            crate::obs::solver_stamp((variant.k * qs.tab.stages()) as u64, 0, 0);
            (
                odeint_fixed_ws(field, z0, task.s_span, variant.k, &qs.tab, &mut ws)?.clone(),
                None,
            )
        };
        drop(ws);
        drop(staged);

        // image readout when the export's output is logits, not state
        let out = match &*model {
            NativeModel::Image(im)
                if variant.out_shape.len() == 2 && zt.shape().len() == 4 =>
            {
                im.hy(&zt)?
            }
            _ => zt,
        };

        let want: usize = variant.out_shape.iter().product();
        if out.numel() != want {
            return Err(Error::Shape(format!(
                "native solve of {}/{} produced {} values, manifest out_shape {:?} wants {want}",
                task.name,
                variant.name,
                out.numel(),
                variant.out_shape
            )));
        }
        Ok(ExecOutput {
            z: out.into_data(),
            nfe,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::fixtures;

    fn synth() -> (Manifest, NativeBackend) {
        let dir = fixtures::temp_native_artifacts("native_unit", &[("cnf_t", 4)]).unwrap();
        (Manifest::load(&dir).unwrap(), NativeBackend::new())
    }

    #[test]
    fn serves_fixed_hyper_and_adaptive_variants() {
        let (m, backend) = synth();
        let task = m.task("cnf_t").unwrap();
        let input: Vec<f32> = (0..8).map(|i| 0.1 * i as f32).collect();
        for v in &task.variants {
            let out = backend
                .execute(&m, task, v, &input)
                .unwrap_or_else(|e| panic!("{}: {e}", v.name));
            assert_eq!(out.z.len(), 8, "{}", v.name);
            assert!(out.z.iter().all(|x| x.is_finite()), "{}", v.name);
            if v.solver == "dopri5" {
                assert!(out.nfe.unwrap() >= 7, "{}", v.name);
            } else {
                assert!(out.nfe.is_none(), "{}", v.name);
            }
        }
    }

    #[test]
    fn distinct_variants_distinct_outputs() {
        // euler K=2 and dopri5 must disagree on a rotation-flavoured field
        let (m, backend) = synth();
        let task = m.task("cnf_t").unwrap();
        let input: Vec<f32> = (0..8).map(|i| 0.3 + 0.2 * i as f32).collect();
        let euler = backend
            .execute(&m, task, task.variant("euler_k2").unwrap(), &input)
            .unwrap();
        let d5 = backend
            .execute(&m, task, task.variant("dopri5").unwrap(), &input)
            .unwrap();
        let diff: f32 = euler
            .z
            .iter()
            .zip(&d5.z)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1e-4, "euler and dopri5 agreed suspiciously: {diff}");
    }

    #[test]
    fn prepare_is_idempotent_and_caches() {
        let (m, backend) = synth();
        let task = m.task("cnf_t").unwrap();
        let v = &task.variants[0];
        backend.prepare(&m, task, v).unwrap();
        backend.prepare(&m, task, v).unwrap();
        assert_eq!(backend.models.lock().unwrap().len(), 1);
    }

    #[test]
    fn variant_tolerance_drives_adaptive_effort() {
        // the same dopri5 variant at a looser manifest tol must spend
        // fewer NFE; distinct names keep the per-queue workspaces apart
        let (m, backend) = synth();
        let task = m.task("cnf_t").unwrap();
        let input: Vec<f32> = (0..8).map(|i| 0.1 * i as f32 - 0.3).collect();
        let base = task.variant("dopri5").unwrap();
        let mut tight = base.clone();
        tight.name = "dopri5_tight".into();
        tight.tol = Some(1e-7);
        let mut loose = base.clone();
        loose.name = "dopri5_loose".into();
        loose.tol = Some(1e-2);
        let nfe_tight = backend
            .execute(&m, task, &tight, &input)
            .unwrap()
            .nfe
            .unwrap();
        let nfe_loose = backend.execute(&m, task, &loose, &input).unwrap().nfe.unwrap();
        assert!(
            nfe_tight > nfe_loose,
            "tol 1e-7 spent {nfe_tight} NFE vs 1e-2's {nfe_loose}"
        );
    }

    #[test]
    fn wrong_input_size_is_an_error() {
        let (m, backend) = synth();
        let task = m.task("cnf_t").unwrap();
        let v = &task.variants[0];
        assert!(backend.execute(&m, task, v, &[0.0; 3]).is_err());
    }

    #[test]
    fn workspaces_persist_per_queue_and_results_stay_deterministic() {
        let (m, backend) = synth();
        let task = m.task("cnf_t").unwrap();
        let input: Vec<f32> = (0..8).map(|i| 0.2 * i as f32 - 0.7).collect();
        // repeat batches on every variant: one workspace per (task, variant),
        // reused, and outputs identical batch over batch
        for v in &task.variants {
            let first = backend.execute(&m, task, v, &input).unwrap();
            for _ in 0..3 {
                let again = backend.execute(&m, task, v, &input).unwrap();
                assert_eq!(again.z, first.z, "{} drifted across batches", v.name);
            }
        }
        let ws_count: usize = backend
            .queues
            .lock()
            .unwrap()
            .values()
            .map(HashMap::len)
            .sum();
        assert_eq!(
            ws_count,
            task.variants.len(),
            "one workspace per (task, variant) queue"
        );
    }
}
