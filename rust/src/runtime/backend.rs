//! Pluggable execution backends: "how a (task, variant) batch executes" as
//! a trait, so the coordinator is independent of whether batches run on the
//! PJRT executor thread ([`PjrtBackend`]) or on the in-repo tensor/solver
//! stack ([`crate::runtime::native::NativeBackend`]).
//!
//! The engine's dispatch workers share one backend behind an `Arc`, so
//! implementations must be `Send + Sync`; the native backend executes
//! concurrently, the PJRT backend serialises on its executor thread (the
//! `!Send` XLA handles live there).

use std::collections::HashSet;
use std::sync::Mutex;

use crate::runtime::exec::Executor;
use crate::runtime::manifest::{Manifest, TaskEntry, Variant};
use crate::{Error, Result};

/// Output of one batched execution.
#[derive(Debug, Clone)]
pub struct ExecOutput {
    /// Flattened terminal output, batch-major (`cap * out_dim` values; the
    /// engine slices out the real samples).
    pub z: Vec<f32>,
    /// Measured NFE when the solve reports one (adaptive solvers); `None`
    /// means "use the variant's static manifest count".
    pub nfe: Option<u64>,
}

/// How one (task, variant) batch executes.
pub trait ExecBackend: Send + Sync {
    /// Short stable name ("pjrt" | "native") for logs/CLI/metrics.
    fn name(&self) -> &'static str;

    /// Prepare the executable (compile HLO / load weights). Idempotent;
    /// called by `Engine::warmup` and implicitly by `execute`.
    fn prepare(&self, manifest: &Manifest, task: &TaskEntry, variant: &Variant) -> Result<()>;

    /// Execute one padded batch: `input` is the row-major flattening of
    /// `variant.in_shape` (padding rows zeroed). Borrowed so the engine
    /// can reuse one padding buffer across batches; backends stage their
    /// own device/tensor copy.
    fn execute(
        &self,
        manifest: &Manifest,
        task: &TaskEntry,
        variant: &Variant,
        input: &[f32],
    ) -> Result<ExecOutput>;
}

/// Backend selector, threaded through `EngineConfig`, the `hypersolverd`
/// CLI and the serving benches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// In-repo tensor/solver stack; needs only `manifest.json` + weights.
    Native,
    /// AOT HLO executables on the PJRT executor thread; needs the full
    /// artifacts directory and an XLA runtime.
    Pjrt,
}

impl BackendKind {
    pub fn from_name(name: &str) -> Result<BackendKind> {
        match name {
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            other => Err(Error::Other(format!(
                "unknown backend {other:?} (native | pjrt)"
            ))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }

    /// Instantiate the backend (spawns the executor thread for PJRT).
    pub fn create(self) -> Result<Box<dyn ExecBackend>> {
        match self {
            BackendKind::Native => Ok(Box::new(crate::runtime::native::NativeBackend::new())),
            BackendKind::Pjrt => Ok(Box::new(PjrtBackend::spawn()?)),
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// True when a PJRT client can actually be brought up — the runtime check
/// that gates XLA-dependent tests and benches.
pub fn pjrt_available() -> bool {
    Executor::spawn().is_ok()
}

/// The PJRT path: the original executor-thread design behind the trait.
/// Compilation state (which keys are loaded) is tracked here so `execute`
/// can lazily prepare on first sight, exactly like the old dispatcher.
pub struct PjrtBackend {
    executor: Executor,
    loaded: Mutex<HashSet<String>>,
}

impl PjrtBackend {
    /// Spawn the executor thread; fails fast when no PJRT runtime exists.
    pub fn spawn() -> Result<PjrtBackend> {
        Ok(PjrtBackend {
            executor: Executor::spawn()?,
            loaded: Mutex::new(HashSet::new()),
        })
    }
}

fn exe_key(task: &TaskEntry, variant: &Variant) -> String {
    format!("{}/{}", task.name, variant.name)
}

impl ExecBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn prepare(&self, manifest: &Manifest, task: &TaskEntry, variant: &Variant) -> Result<()> {
        let key = exe_key(task, variant);
        if self.loaded.lock().unwrap().contains(&key) {
            return Ok(());
        }
        self.executor
            .handle()
            .load(&key, manifest.hlo_path(&variant.hlo))?;
        self.loaded.lock().unwrap().insert(key);
        Ok(())
    }

    fn execute(
        &self,
        manifest: &Manifest,
        task: &TaskEntry,
        variant: &Variant,
        input: &[f32],
    ) -> Result<ExecOutput> {
        self.prepare(manifest, task, variant)?;
        let key = exe_key(task, variant);
        // the executor consumes an owned host buffer (it crosses to the
        // executor thread; PJRT copies host→device regardless)
        let outputs = self
            .executor
            .handle()
            .run(&key, input.to_vec(), &variant.in_shape)?;
        let mut leaves = outputs.into_iter();
        let z = leaves
            .next()
            .ok_or_else(|| Error::Xla(format!("{key}: executable returned no outputs")))?;
        let nfe = if variant.returns_nfe {
            leaves
                .next()
                .and_then(|leaf| leaf.first().copied())
                .map(|x| x as u64)
        } else {
            None
        };
        Ok(ExecOutput { z, nfe })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_round_trips_names() {
        for kind in [BackendKind::Native, BackendKind::Pjrt] {
            assert_eq!(BackendKind::from_name(kind.name()).unwrap(), kind);
            assert_eq!(format!("{kind}"), kind.name());
        }
        assert!(BackendKind::from_name("tpu").is_err());
    }

    #[test]
    fn backends_are_object_safe_and_shareable() {
        fn assert_send_sync<T: Send + Sync + ?Sized>() {}
        assert_send_sync::<dyn ExecBackend>();
    }

    #[test]
    fn native_kind_always_creates() {
        let b = BackendKind::Native.create().unwrap();
        assert_eq!(b.name(), "native");
    }
}
