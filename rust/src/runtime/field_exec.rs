//! A PJRT-backed [`VectorField`]: rust drives the (adaptive) stepping loop,
//! XLA evaluates f.
//!
//! This is the hybrid mode of the architecture: the exported
//! `<task>_field.hlo.txt` computes one f(s, z) evaluation for the task's
//! batched state; [`crate::solvers::dopri5`] supplies the step-size control
//! from the rust side. Slower per-eval than the fused full-solve
//! executables (one host↔PJRT round trip per stage) but fully flexible —
//! used for tolerance sweeps no fused export covers.

use crate::ode::VectorField;
use crate::runtime::exec::ExecutorHandle;
use crate::tensor::Tensor;

/// f(s, z) backed by a compiled field executable.
pub struct PjrtField {
    exec: ExecutorHandle,
    key: String,
    state_shape: Vec<usize>,
    mac_f: u64,
}

impl PjrtField {
    /// `key` must already be loaded in the executor. `state_shape` is the
    /// exported batched state shape (leading batch dim).
    pub fn new(exec: ExecutorHandle, key: &str, state_shape: &[usize], mac_f: u64) -> Self {
        PjrtField {
            exec,
            key: key.to_string(),
            state_shape: state_shape.to_vec(),
            mac_f,
        }
    }
}

impl VectorField for PjrtField {
    fn eval(&self, s: f32, z: &Tensor) -> Tensor {
        // export signature: f(s: f32[1], z: state_shape) -> state_shape,
        // fed as one flat buffer per argument
        let outs = self
            .exec
            .run_two(&self.key, &[s], z.data(), &self.state_shape)
            .expect("pjrt field eval");
        Tensor::new(z.shape(), outs.into_iter().next().expect("one output"))
            .expect("field output shape")
    }

    fn macs(&self) -> u64 {
        self.mac_f
    }
}

impl ExecutorHandle {
    /// Execute a two-argument executable (scalar s + state z). Kept here so
    /// `exec.rs` stays a generic single-input engine.
    pub fn run_two(
        &self,
        key: &str,
        s: &[f32],
        z: &[f32],
        z_shape: &[usize],
    ) -> crate::Result<Vec<Vec<f32>>> {
        self.run_multi(key, &[(s, &[1]), (z, z_shape)])
    }
}
