//! `artifacts/manifest.json` — the registry of everything python exported.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::obs::drift::TrainStats;
use crate::util::json::{self, Value};
use crate::{Error, Result};

/// Reference to a raw data blob (shape + relative path).
#[derive(Clone, Debug)]
pub struct BlobRef {
    pub path: String,
    pub shape: Vec<usize>,
}

impl BlobRef {
    fn from_json(v: &Value) -> Result<BlobRef> {
        Ok(BlobRef {
            path: v
                .req("path")?
                .as_str()
                .ok_or_else(|| Error::Manifest("blob path".into()))?
                .to_string(),
            shape: v.req("shape")?.as_usize_vec()?,
        })
    }
}

/// One exported (solver, K) full-solve executable + its measured metrics.
#[derive(Clone, Debug)]
pub struct Variant {
    pub name: String,
    pub solver: String,
    pub k: usize,
    pub hyper: bool,
    pub hlo: String,
    pub nfe: u64,
    /// analytic MACs per sample
    pub macs: u64,
    /// measured terminal MAPE vs dopri5(1e-6) on the eval batch
    pub mape: f64,
    /// adaptive tolerance of a dopri5 variant; `None` means the backend's
    /// default (1e-5). Lets one manifest expose a whole tolerance axis
    /// (the pareto sweep's adaptive grid) as distinct variants.
    pub tol: Option<f64>,
    /// accuracy drop vs dopri5 (image tasks only)
    pub acc_drop: Option<f64>,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    /// true when the executable returns (z, nfe) (the dopri5 export)
    pub returns_nfe: bool,
}

impl Variant {
    // loud over lossy, like `tol` below: `k`/`nfe`/`macs` used to silently
    // default to 0 on malformed values (the json accessors saturate-cast,
    // so even `-1` or `1.5` slipped through), `hyper` to false and `mape`
    // to NaN — a typo'd manifest would mis-route policy decisions and
    // mis-seed admission control with no diagnostic anywhere
    fn from_json(v: &Value) -> Result<Variant> {
        Ok(Variant {
            name: req_str(v, "name")?,
            solver: req_str(v, "solver")?,
            k: uint_field(v, "k", "variant k")? as usize,
            hyper: v.req("hyper")?.as_bool().ok_or_else(|| {
                Error::Manifest("variant hyper must be a boolean".into())
            })?,
            hlo: req_str(v, "hlo")?,
            nfe: uint_field(v, "nfe", "variant nfe")?,
            macs: uint_field(v, "macs", "variant macs")?,
            mape: v.req("mape")?.as_f64().ok_or_else(|| {
                Error::Manifest("variant mape must be a number".into())
            })?,
            // a present-but-non-numeric tol must fail loudly: silently
            // falling back to the backend default would serve (and
            // measure) the wrong tolerance with no diagnostic
            tol: match v.get("tol") {
                None => None,
                Some(t) => Some(t.as_f64().ok_or_else(|| {
                    Error::Manifest("variant tol must be a number".into())
                })?),
            },
            acc_drop: v.get("acc_drop").and_then(Value::as_f64),
            in_shape: v.req("in_shape")?.as_usize_vec()?,
            out_shape: v.req("out_shape")?.as_usize_vec()?,
            returns_nfe: v.get("outputs").is_some(),
        })
    }
}

/// One task (cnf_<density>, img_<ds>, tracking).
#[derive(Clone, Debug)]
pub struct TaskEntry {
    pub name: String,
    pub kind: String,
    pub state_shape: Vec<usize>,
    pub s_span: (f32, f32),
    pub weights: String,
    pub field_hlo: String,
    pub mac_f: u64,
    pub mac_g: u64,
    /// final residual-fitting loss δ of the hypersolver
    pub delta: f64,
    pub hyper_base: String,
    pub truth_acc: Option<f64>,
    /// training-distribution stamp for drift detection
    /// ([`crate::obs::drift`]); exporters embed it, older manifests lack
    /// it — absent means drift reporting is disabled for the task (the
    /// audit plane says so loudly), while a *present but malformed* stamp
    /// is a hard load error like every other manifest field
    pub train_stats: Option<TrainStats>,
    pub variants: Vec<Variant>,
    pub data: BTreeMap<String, BlobRef>,
}

impl TaskEntry {
    pub fn variant(&self, name: &str) -> Option<&Variant> {
        self.variants.iter().find(|v| v.name == name)
    }

    /// The batch size every full-solve executable was exported at.
    pub fn batch(&self) -> usize {
        self.state_shape.first().copied().unwrap_or(1)
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub stamp: String,
    pub quick: bool,
    pub tasks: BTreeMap<String, TaskEntry>,
}

fn req_str(v: &Value, key: &str) -> Result<String> {
    Ok(v.req(key)?
        .as_str()
        .ok_or_else(|| Error::Manifest(format!("{key} must be a string")))?
        .to_string())
}

/// Strict non-negative integer field. The generic json accessors
/// (`as_usize`/`as_i64`) saturate-cast through f64 — `-1` becomes 0 and
/// `1.5` becomes 1 — so manifest counters must validate the raw number.
fn uint_field(v: &Value, key: &str, label: &str) -> Result<u64> {
    let n = v
        .req(key)?
        .as_f64()
        .ok_or_else(|| Error::Manifest(format!("{label} must be a number")))?;
    if !(n.is_finite() && n >= 0.0 && n.fract() == 0.0 && n <= 2f64.powi(53)) {
        return Err(Error::Manifest(format!(
            "{label} must be a non-negative integer, got {n}"
        )));
    }
    Ok(n as u64)
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        if !path.exists() {
            return Err(Error::Manifest(format!(
                "{} not found — run `make artifacts` first",
                path.display()
            )));
        }
        let root = json::parse_file(&path)?;
        let mut tasks = BTreeMap::new();
        let tobj = root
            .req("tasks")?
            .as_obj()
            .ok_or_else(|| Error::Manifest("tasks must be an object".into()))?;
        for (name, tv) in tobj {
            let span = tv.req("s_span")?;
            let span = span
                .as_arr()
                .ok_or_else(|| Error::Manifest("s_span".into()))?;
            let variants = tv
                .req("variants")?
                .as_arr()
                .ok_or_else(|| Error::Manifest("variants".into()))?
                .iter()
                .map(Variant::from_json)
                .collect::<Result<Vec<_>>>()?;
            let mut data = BTreeMap::new();
            if let Some(Value::Obj(dm)) = tv.get("data") {
                for (k, v) in dm {
                    data.insert(k.clone(), BlobRef::from_json(v)?);
                }
            }
            let macs = tv.req("macs")?;
            let state_shape = tv.req("state")?.req("shape")?.as_usize_vec()?;
            if state_shape.is_empty() {
                // an empty shape used to silently mean batch() == 1 — any
                // mismatch then surfaced as shape errors far from the cause
                return Err(Error::Manifest(format!(
                    "task {name}: state shape is empty — the exported batch \
                     dimension must be explicit"
                )));
            }
            tasks.insert(
                name.clone(),
                TaskEntry {
                    name: name.clone(),
                    kind: req_str(tv, "kind")?,
                    state_shape,
                    s_span: (
                        span[0].as_f32().unwrap_or(0.0),
                        span[1].as_f32().unwrap_or(1.0),
                    ),
                    weights: req_str(tv, "weights")?,
                    field_hlo: req_str(tv, "field_hlo")?,
                    mac_f: uint_field(macs, "field", "task macs.field")?,
                    mac_g: uint_field(macs, "hyper", "task macs.hyper")?,
                    delta: tv.req("delta")?.as_f64().unwrap_or(f64::NAN),
                    hyper_base: req_str(tv, "hyper_base")?,
                    truth_acc: tv.get("truth_acc").and_then(Value::as_f64),
                    train_stats: match tv.get("train_stats") {
                        None => None,
                        Some(ts) => Some(TrainStats::from_json(ts).map_err(|e| {
                            Error::Manifest(format!("task {name}: {e}"))
                        })?),
                    },
                    variants,
                    data,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            stamp: req_str(&root, "stamp").unwrap_or_default(),
            quick: root.get("quick").and_then(Value::as_bool).unwrap_or(false),
            tasks,
        })
    }

    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<Manifest> {
        Self::load(&crate::artifacts_dir())
    }

    pub fn task(&self, name: &str) -> Result<&TaskEntry> {
        self.tasks
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("unknown task {name:?}")))
    }

    pub fn hlo_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    pub fn blob_path(&self, b: &BlobRef) -> PathBuf {
        self.dir.join(&b.path)
    }

    pub fn weights_path(&self, task: &TaskEntry) -> PathBuf {
        self.dir.join(&task.weights)
    }
}

/// Merge one task entry into `<dir>/manifest.json`, creating the file
/// with the given defaults when absent. The same-name task is replaced
/// while other tasks AND any top-level metadata a previous exporter wrote
/// (stamp, seed, ...) are preserved; a present-but-unparsable manifest is
/// an error, not a silent restart — overwriting it would drop every other
/// task it listed. This is the single definition of exporter merge
/// semantics, shared by `train::export_trained` and
/// `pareto::write_sweep_artifacts` so they cannot drift from the schema
/// [`Manifest::load`] parses.
pub fn merge_task_into_manifest(
    dir: &Path,
    task: &str,
    task_obj: Value,
    default_stamp: &str,
    default_seed: u64,
) -> Result<()> {
    let manifest_path = dir.join("manifest.json");
    let mut root: BTreeMap<String, Value> = if manifest_path.exists() {
        json::parse_file(&manifest_path)?
            .as_obj()
            .cloned()
            .ok_or_else(|| {
                Error::Manifest(format!(
                    "existing {} is not a JSON object; refusing to overwrite it",
                    manifest_path.display()
                ))
            })?
    } else {
        Default::default()
    };
    // a `tasks` key that exists but is not an object is the same silent
    // data loss the root-level check guards against — refuse, don't
    // restart the task map
    let mut tasks = match root.get("tasks") {
        None => BTreeMap::new(),
        Some(t) => t.as_obj().cloned().ok_or_else(|| {
            Error::Manifest(format!(
                "existing {} has a non-object `tasks` value; refusing to \
                 overwrite it",
                manifest_path.display()
            ))
        })?,
    };
    tasks.insert(task.to_string(), task_obj);
    root.insert("tasks".into(), Value::Obj(tasks));
    root.entry("version".into()).or_insert(json::num(1.0));
    root.entry("stamp".into()).or_insert(json::s(default_stamp));
    root.entry("seed".into())
        .or_insert(json::num(default_seed as f64));
    root.entry("quick".into()).or_insert(Value::Bool(false));
    std::fs::write(manifest_path, json::to_string(&Value::Obj(root)))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "stamp": "abc", "seed": 0, "quick": false,
      "tasks": {
        "cnf_rings": {
          "kind": "cnf",
          "state": {"shape": [256, 2]},
          "s_span": [0.0, 1.0],
          "weights": "weights/cnf_rings.json",
          "field_hlo": "cnf_rings_field.hlo.txt",
          "macs": {"field": 8512, "hyper": 4608},
          "delta": 0.03,
          "hyper_base": "heun",
          "variants": [
            {"name": "heun_k1", "solver": "heun", "k": 1, "hyper": false,
             "hlo": "cnf_rings_heun_k1.hlo.txt", "nfe": 2, "macs": 17024,
             "mape": 0.119, "in_shape": [256, 2], "out_shape": [256, 2]},
            {"name": "dopri5", "solver": "dopri5", "k": 0, "hyper": false,
             "hlo": "cnf_rings_dopri5.hlo.txt", "nfe": 28, "macs": 238336,
             "mape": 0.0, "tol": 0.001, "in_shape": [256, 2], "out_shape": [256, 2],
             "outputs": ["z", "nfe"]}
          ],
          "data": {"z0": {"path": "data/cnf_rings_z0.bin", "shape": [256, 2]}}
        }
      }
    }"#;

    fn write_sample() -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "hsolve_manifest_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        dir
    }

    #[test]
    fn parses_sample() {
        let dir = write_sample();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.stamp, "abc");
        let t = m.task("cnf_rings").unwrap();
        assert_eq!(t.kind, "cnf");
        assert_eq!(t.batch(), 256);
        assert_eq!(t.mac_f, 8512);
        assert_eq!(t.variants.len(), 2);
        let v = t.variant("heun_k1").unwrap();
        assert_eq!(v.nfe, 2);
        assert!(!v.returns_nfe);
        assert_eq!(v.tol, None);
        let d5 = t.variant("dopri5").unwrap();
        assert!(d5.returns_nfe);
        assert_eq!(d5.tol, Some(0.001));
        assert!(m.task("nope").is_err());
        assert!(t.data.contains_key("z0"));
    }

    #[test]
    fn non_numeric_tol_is_rejected() {
        let dir = std::env::temp_dir().join(format!(
            "hsolve_manifest_badtol_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let bad = SAMPLE.replace("\"tol\": 0.001", "\"tol\": \"0.001\"");
        assert!(bad.contains("\"tol\": \"0.001\""), "replacement applied");
        std::fs::write(dir.join("manifest.json"), bad).unwrap();
        let err = Manifest::load(&dir).unwrap_err();
        assert!(err.to_string().contains("tol"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn malformed_variant_fields_are_rejected_loudly() {
        // each case breaks exactly one field the loader used to silently
        // default (k→0, hyper→false, nfe/macs→0, mape→NaN, shape→batch 1)
        let cases = [
            ("\"k\": 1,", "\"k\": \"1\",", "variant k"),
            ("\"nfe\": 2,", "\"nfe\": -2,", "variant nfe"),
            ("\"macs\": 17024,", "\"macs\": 1.5,", "variant macs"),
            ("\"hyper\": false,", "\"hyper\": \"no\",", "variant hyper"),
            ("\"mape\": 0.119,", "\"mape\": \"high\",", "variant mape"),
            (
                "\"state\": {\"shape\": [256, 2]}",
                "\"state\": {\"shape\": []}",
                "state shape is empty",
            ),
        ];
        for (i, (from, to, needle)) in cases.iter().enumerate() {
            let dir = std::env::temp_dir().join(format!(
                "hsolve_manifest_bad{}_{}",
                i,
                std::process::id()
            ));
            std::fs::create_dir_all(&dir).unwrap();
            let bad = SAMPLE.replace(from, to);
            assert_ne!(bad, SAMPLE, "replacement {from:?} applied");
            std::fs::write(dir.join("manifest.json"), bad).unwrap();
            let err = Manifest::load(&dir).unwrap_err();
            assert!(err.to_string().contains(needle), "{from}: {err}");
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn train_stats_is_optional_but_strict() {
        // absent: loads fine, drift disabled
        let dir = write_sample();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.task("cnf_rings").unwrap().train_stats.is_none());

        let with_stats = |stats: &str| {
            SAMPLE.replace(
                "\"delta\": 0.03,",
                &format!("\"delta\": 0.03, \"train_stats\": {stats},"),
            )
        };
        let mag: Vec<String> = (0..32).map(|_| "0".to_string()).collect();
        let good = with_stats(&format!(
            "{{\"count\": 4, \"mean\": [0.1, -0.2], \"var\": [1.0, 2.0], \
             \"mag\": [{}]}}",
            mag.join(", ")
        ));
        let cases: Vec<(String, &str)> = vec![
            (good.clone(), ""),
            (
                good.replace("\"count\": 4", "\"count\": 0"),
                "count must be > 0",
            ),
            (
                good.replace("\"var\": [1.0, 2.0]", "\"var\": [1.0]"),
                "same-length",
            ),
            (
                good.replace("\"mean\": [0.1, -0.2]", "\"mean\": \"wide\""),
                "must be an array",
            ),
            (with_stats("{\"count\": 4}"), "missing"),
        ];
        for (i, (text, needle)) in cases.iter().enumerate() {
            let dir = std::env::temp_dir().join(format!(
                "hsolve_manifest_ts{}_{}",
                i,
                std::process::id()
            ));
            std::fs::create_dir_all(&dir).unwrap();
            assert_ne!(text.as_str(), SAMPLE, "case {i} replacement applied");
            std::fs::write(dir.join("manifest.json"), text).unwrap();
            let loaded = Manifest::load(&dir);
            if needle.is_empty() {
                let m = loaded.unwrap();
                let ts = m.task("cnf_rings").unwrap().train_stats.clone().unwrap();
                assert_eq!(ts.count, 4);
                assert_eq!(ts.mean, vec![0.1, -0.2]);
            } else {
                let err = loaded.unwrap_err().to_string();
                assert!(err.contains(needle), "case {i}: want {needle:?} in {err:?}");
                assert!(err.contains("cnf_rings"), "case {i}: error names the task");
            }
            std::fs::remove_dir_all(&dir).ok();
        }
    }

    #[test]
    fn merge_preserves_other_tasks_and_metadata() {
        let dir = std::env::temp_dir().join(format!(
            "hsolve_manifest_merge_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        let task_obj = json::parse(
            r#"{"kind": "cnf", "state": {"shape": [4, 2]}, "s_span": [0, 1],
                "weights": "weights/extra.json", "field_hlo": "x.hlo.txt",
                "macs": {"field": 1, "hyper": 1}, "delta": 0.5,
                "hyper_base": "euler", "variants": []}"#,
        )
        .unwrap();
        merge_task_into_manifest(&dir, "extra", task_obj, "new-stamp", 99).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.tasks.len(), 2, "existing task preserved");
        assert!(m.task("cnf_rings").is_ok());
        assert!(m.task("extra").is_ok());
        // pre-existing top-level metadata wins over the defaults
        assert_eq!(m.stamp, "abc");
        // corrupt manifest refuses instead of clobbering
        std::fs::write(dir.join("manifest.json"), "[1, 2]").unwrap();
        let obj = json::parse(r#"{"kind": "cnf"}"#).unwrap();
        assert!(merge_task_into_manifest(&dir, "t", obj, "s", 0).is_err());
        // ... and so does a corrupt `tasks` value inside a valid root
        std::fs::write(dir.join("manifest.json"), r#"{"tasks": [1]}"#).unwrap();
        let obj = json::parse(r#"{"kind": "cnf"}"#).unwrap();
        assert!(merge_task_into_manifest(&dir, "t", obj, "s", 0).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
