//! `artifacts/manifest.json` — the registry of everything python exported.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::{self, Value};
use crate::{Error, Result};

/// Reference to a raw data blob (shape + relative path).
#[derive(Clone, Debug)]
pub struct BlobRef {
    pub path: String,
    pub shape: Vec<usize>,
}

impl BlobRef {
    fn from_json(v: &Value) -> Result<BlobRef> {
        Ok(BlobRef {
            path: v
                .req("path")?
                .as_str()
                .ok_or_else(|| Error::Manifest("blob path".into()))?
                .to_string(),
            shape: v.req("shape")?.as_usize_vec()?,
        })
    }
}

/// One exported (solver, K) full-solve executable + its measured metrics.
#[derive(Clone, Debug)]
pub struct Variant {
    pub name: String,
    pub solver: String,
    pub k: usize,
    pub hyper: bool,
    pub hlo: String,
    pub nfe: u64,
    /// analytic MACs per sample
    pub macs: u64,
    /// measured terminal MAPE vs dopri5(1e-6) on the eval batch
    pub mape: f64,
    /// accuracy drop vs dopri5 (image tasks only)
    pub acc_drop: Option<f64>,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    /// true when the executable returns (z, nfe) (the dopri5 export)
    pub returns_nfe: bool,
}

impl Variant {
    fn from_json(v: &Value) -> Result<Variant> {
        Ok(Variant {
            name: req_str(v, "name")?,
            solver: req_str(v, "solver")?,
            k: v.req("k")?.as_usize().unwrap_or(0),
            hyper: v.req("hyper")?.as_bool().unwrap_or(false),
            hlo: req_str(v, "hlo")?,
            nfe: v.req("nfe")?.as_i64().unwrap_or(0) as u64,
            macs: v.req("macs")?.as_i64().unwrap_or(0) as u64,
            mape: v.req("mape")?.as_f64().unwrap_or(f64::NAN),
            acc_drop: v.get("acc_drop").and_then(Value::as_f64),
            in_shape: v.req("in_shape")?.as_usize_vec()?,
            out_shape: v.req("out_shape")?.as_usize_vec()?,
            returns_nfe: v.get("outputs").is_some(),
        })
    }
}

/// One task (cnf_<density>, img_<ds>, tracking).
#[derive(Clone, Debug)]
pub struct TaskEntry {
    pub name: String,
    pub kind: String,
    pub state_shape: Vec<usize>,
    pub s_span: (f32, f32),
    pub weights: String,
    pub field_hlo: String,
    pub mac_f: u64,
    pub mac_g: u64,
    /// final residual-fitting loss δ of the hypersolver
    pub delta: f64,
    pub hyper_base: String,
    pub truth_acc: Option<f64>,
    pub variants: Vec<Variant>,
    pub data: BTreeMap<String, BlobRef>,
}

impl TaskEntry {
    pub fn variant(&self, name: &str) -> Option<&Variant> {
        self.variants.iter().find(|v| v.name == name)
    }

    /// The batch size every full-solve executable was exported at.
    pub fn batch(&self) -> usize {
        self.state_shape.first().copied().unwrap_or(1)
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub stamp: String,
    pub quick: bool,
    pub tasks: BTreeMap<String, TaskEntry>,
}

fn req_str(v: &Value, key: &str) -> Result<String> {
    Ok(v.req(key)?
        .as_str()
        .ok_or_else(|| Error::Manifest(format!("{key} must be a string")))?
        .to_string())
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        if !path.exists() {
            return Err(Error::Manifest(format!(
                "{} not found — run `make artifacts` first",
                path.display()
            )));
        }
        let root = json::parse_file(&path)?;
        let mut tasks = BTreeMap::new();
        let tobj = root
            .req("tasks")?
            .as_obj()
            .ok_or_else(|| Error::Manifest("tasks must be an object".into()))?;
        for (name, tv) in tobj {
            let span = tv.req("s_span")?;
            let span = span
                .as_arr()
                .ok_or_else(|| Error::Manifest("s_span".into()))?;
            let variants = tv
                .req("variants")?
                .as_arr()
                .ok_or_else(|| Error::Manifest("variants".into()))?
                .iter()
                .map(Variant::from_json)
                .collect::<Result<Vec<_>>>()?;
            let mut data = BTreeMap::new();
            if let Some(Value::Obj(dm)) = tv.get("data") {
                for (k, v) in dm {
                    data.insert(k.clone(), BlobRef::from_json(v)?);
                }
            }
            let macs = tv.req("macs")?;
            tasks.insert(
                name.clone(),
                TaskEntry {
                    name: name.clone(),
                    kind: req_str(tv, "kind")?,
                    state_shape: tv.req("state")?.req("shape")?.as_usize_vec()?,
                    s_span: (
                        span[0].as_f32().unwrap_or(0.0),
                        span[1].as_f32().unwrap_or(1.0),
                    ),
                    weights: req_str(tv, "weights")?,
                    field_hlo: req_str(tv, "field_hlo")?,
                    mac_f: macs.req("field")?.as_i64().unwrap_or(0) as u64,
                    mac_g: macs.req("hyper")?.as_i64().unwrap_or(0) as u64,
                    delta: tv.req("delta")?.as_f64().unwrap_or(f64::NAN),
                    hyper_base: req_str(tv, "hyper_base")?,
                    truth_acc: tv.get("truth_acc").and_then(Value::as_f64),
                    variants,
                    data,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            stamp: req_str(&root, "stamp").unwrap_or_default(),
            quick: root.get("quick").and_then(Value::as_bool).unwrap_or(false),
            tasks,
        })
    }

    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<Manifest> {
        Self::load(&crate::artifacts_dir())
    }

    pub fn task(&self, name: &str) -> Result<&TaskEntry> {
        self.tasks
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("unknown task {name:?}")))
    }

    pub fn hlo_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    pub fn blob_path(&self, b: &BlobRef) -> PathBuf {
        self.dir.join(&b.path)
    }

    pub fn weights_path(&self, task: &TaskEntry) -> PathBuf {
        self.dir.join(&task.weights)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "stamp": "abc", "seed": 0, "quick": false,
      "tasks": {
        "cnf_rings": {
          "kind": "cnf",
          "state": {"shape": [256, 2]},
          "s_span": [0.0, 1.0],
          "weights": "weights/cnf_rings.json",
          "field_hlo": "cnf_rings_field.hlo.txt",
          "macs": {"field": 8512, "hyper": 4608},
          "delta": 0.03,
          "hyper_base": "heun",
          "variants": [
            {"name": "heun_k1", "solver": "heun", "k": 1, "hyper": false,
             "hlo": "cnf_rings_heun_k1.hlo.txt", "nfe": 2, "macs": 17024,
             "mape": 0.119, "in_shape": [256, 2], "out_shape": [256, 2]},
            {"name": "dopri5", "solver": "dopri5", "k": 0, "hyper": false,
             "hlo": "cnf_rings_dopri5.hlo.txt", "nfe": 28, "macs": 238336,
             "mape": 0.0, "in_shape": [256, 2], "out_shape": [256, 2],
             "outputs": ["z", "nfe"]}
          ],
          "data": {"z0": {"path": "data/cnf_rings_z0.bin", "shape": [256, 2]}}
        }
      }
    }"#;

    fn write_sample() -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "hsolve_manifest_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), SAMPLE).unwrap();
        dir
    }

    #[test]
    fn parses_sample() {
        let dir = write_sample();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.stamp, "abc");
        let t = m.task("cnf_rings").unwrap();
        assert_eq!(t.kind, "cnf");
        assert_eq!(t.batch(), 256);
        assert_eq!(t.mac_f, 8512);
        assert_eq!(t.variants.len(), 2);
        let v = t.variant("heun_k1").unwrap();
        assert_eq!(v.nfe, 2);
        assert!(!v.returns_nfe);
        assert!(t.variant("dopri5").unwrap().returns_nfe);
        assert!(m.task("nope").is_err());
        assert!(t.data.contains_key("z0"));
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
