//! Leveled stderr logger, configured by `HYPERSOLVERS_LOG`
//! (error|warn|info|debug, case-insensitive; default info). An
//! unrecognized value keeps the default but warns once — never a silent
//! fallback.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static INIT: OnceLock<()> = OnceLock::new();

/// Parse one `HYPERSOLVERS_LOG` value (case-insensitive). `None` means
/// the value is not a level name — callers decide the fallback; the
/// parser never silently substitutes one.
pub fn parse_level(v: &str) -> Option<Level> {
    match v.trim().to_ascii_lowercase().as_str() {
        "error" => Some(Level::Error),
        "warn" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        _ => None,
    }
}

fn init() {
    INIT.get_or_init(|| {
        if let Ok(v) = std::env::var("HYPERSOLVERS_LOG") {
            match parse_level(&v) {
                Some(lvl) => LEVEL.store(lvl as u8, Ordering::Relaxed),
                // keep the info default, but say so ONCE — a typo like
                // `trace` or `INFO,foo` must not silently change what
                // gets logged (eprintln! directly: the logger itself is
                // mid-initialization here)
                None => eprintln!(
                    "[WARN ] {}: HYPERSOLVERS_LOG={v:?} is not a level \
                     (error|warn|info|debug, case-insensitive); using info",
                    module_path!()
                ),
            }
        }
    });
}

pub fn set_level(level: Level) {
    init();
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    init();
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {module}: {msg}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing_accepts_any_case_and_rejects_everything_else() {
        assert_eq!(parse_level("debug"), Some(Level::Debug));
        assert_eq!(parse_level("DEBUG"), Some(Level::Debug));
        assert_eq!(parse_level("Warn"), Some(Level::Warn));
        assert_eq!(parse_level(" info "), Some(Level::Info));
        assert_eq!(parse_level("ERROR"), Some(Level::Error));
        // not levels: the historical silent-info cases must be loud now
        for bad in ["trace", "INFO,foo", "2", "", "verbose"] {
            assert_eq!(parse_level(bad), None, "{bad:?}");
        }
    }

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        set_level(Level::Info); // restore default for other tests
    }
}
