//! Leveled stderr logger, configured by `HYPERSOLVERS_LOG`
//! (error|warn|info|debug; default info).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static INIT: OnceLock<()> = OnceLock::new();

fn init() {
    INIT.get_or_init(|| {
        if let Ok(v) = std::env::var("HYPERSOLVERS_LOG") {
            let lvl = match v.to_ascii_lowercase().as_str() {
                "error" => 0,
                "warn" => 1,
                "info" => 2,
                "debug" => 3,
                _ => 2,
            };
            LEVEL.store(lvl, Ordering::Relaxed);
        }
    });
}

pub fn set_level(level: Level) {
    init();
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    init();
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{tag}] {module}: {msg}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Debug);
        assert!(enabled(Level::Debug));
        set_level(Level::Info); // restore default for other tests
    }
}
