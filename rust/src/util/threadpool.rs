//! Fixed-size thread pool (tokio is not available offline).
//!
//! The coordinator's worker pool and the benches' parallel sweeps run on
//! this. Jobs are boxed closures over an mpsc channel guarded by a mutex —
//! at the coordinator's batch granularity (hundreds of µs to ms of work per
//! job) the channel cost is noise.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    sender: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        assert!(n > 0, "thread pool needs at least one worker");
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("hsolve-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            workers,
            sender: Some(tx),
        }
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers alive");
    }

    /// Run `f` over every item of `items` on the pool and collect results in
    /// input order (a barrier — used by the benches' sweeps).
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let _ = tx.send((i, f(item)));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("worker died")).collect()
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.sender.take(); // closes the channel; workers drain & exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join on drop
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<i32>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    #[should_panic]
    fn zero_workers_panics() {
        let _ = ThreadPool::new(0);
    }
}
