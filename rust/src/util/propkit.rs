//! Property-test harness (proptest is not available offline).
//!
//! `check` runs a property over N generated cases; failures report the
//! case's seed so it can be replayed deterministically:
//!
//! ```ignore
//! propkit::check("matmul identity", 100, |rng| {
//!     let t = random_tensor(rng, &[4, 4]);
//!     prop_assert_close(&t.matmul(&Tensor::eye(4)).data, &t.data, 1e-6)
//! });
//! ```

use crate::util::prng::Rng;

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Run `cases` random cases of `prop`. Panics (test failure) on the first
/// failing case, printing the replay seed.
pub fn check<F: FnMut(&mut Rng) -> CaseResult>(name: &str, cases: u64, mut prop: F) {
    let base_seed = env_seed().unwrap_or(0xC0FFEE);
    for case in 0..cases {
        let seed = base_seed ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property {name:?} failed on case {case}/{cases} \
                 (replay with PROPKIT_SEED={base_seed}): {msg}"
            );
        }
    }
}

fn env_seed() -> Option<u64> {
    std::env::var("PROPKIT_SEED").ok()?.parse().ok()
}

/// Assert two float slices are elementwise close.
pub fn prop_assert_close(a: &[f32], b: &[f32], tol: f32) -> CaseResult {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let denom = 1.0f32.max(x.abs()).max(y.abs());
        if (x - y).abs() / denom > tol {
            return Err(format!("element {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Assert a predicate with a formatted message on failure.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Uniform usize in [lo, hi] from the rng (generator helper).
pub fn gen_range(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below((hi - lo + 1) as u64) as usize
}

/// Random f32 vector with entries ~ N(0, scale).
pub fn gen_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32() * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 25, |_rng| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property \"bad\" failed")]
    fn failing_property_panics_with_seed() {
        check("bad", 10, |rng| {
            prop_assert(rng.uniform() < 2.0, "impossible")?;
            Err("always fails".to_string())
        });
    }

    #[test]
    fn assert_close_catches_mismatch() {
        assert!(prop_assert_close(&[1.0], &[1.0 + 1e-8], 1e-6).is_ok());
        assert!(prop_assert_close(&[1.0], &[1.1], 1e-6).is_err());
        assert!(prop_assert_close(&[1.0], &[1.0, 2.0], 1e-6).is_err());
    }

    #[test]
    fn gen_range_bounds() {
        let mut rng = Rng::new(0);
        for _ in 0..100 {
            let v = gen_range(&mut rng, 3, 7);
            assert!((3..=7).contains(&v));
        }
    }
}
