//! Deterministic PRNG: SplitMix64 seeding + xoshiro256** core.
//!
//! Every stochastic component in the crate (datasets, workload traces,
//! property tests) draws from this generator so runs are reproducible from
//! a single `u64` seed — the same discipline the python layer applies with
//! `numpy.random.default_rng(seed)`.

/// xoshiro256** generator (Blackman & Vigna), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from the Box-Muller pair
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (à la jax `fold_in`).
    pub fn fold_in(&self, data: u64) -> Rng {
        let mut sm = self.s[0] ^ data.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire-style unbiased bounded sampling
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box-Muller (pair-cached).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Exponential with given rate (inter-arrival times of a Poisson
    /// process — the workload generator's backbone).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -self.uniform().max(f64::MIN_POSITIVE).ln() / rate
    }

    /// Vector of standard normals.
    pub fn normals_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fold_in_gives_independent_stream() {
        let base = Rng::new(7);
        let mut a = base.fold_in(0);
        let mut b = base.fold_in(1);
        assert_ne!(a.next_u64(), b.next_u64());
        // and is itself deterministic
        let mut a2 = Rng::new(7).fold_in(0);
        assert_eq!(Rng::new(7).fold_in(0).next_u64(), a2.next_u64());
    }

    #[test]
    fn uniform_bounds_and_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let rate = 4.0;
        let mean: f64 = (0..n).map(|_| r.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
