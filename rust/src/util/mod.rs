//! Offline-environment substrates, built from scratch (no crates.io access
//! beyond the vendored `xla` dependency chain — see DESIGN.md §3).

pub mod artifacts;
pub mod benchkit;
pub mod cli;
pub mod cluster;
pub mod fixtures;
pub mod json;
pub mod logging;
pub mod merge;
pub mod prng;
pub mod propkit;
pub mod stats;
pub mod threadpool;
