//! Bench/example support: artifact loading with friendly failure modes.

use crate::data::blobs;
use crate::runtime::Manifest;
use crate::tensor::Tensor;

/// Load the manifest or exit(0) with instructions — benches and examples
/// should be runnable (as a no-op) on a checkout without artifacts.
pub fn require_manifest() -> Manifest {
    match Manifest::load_default() {
        Ok(m) => {
            if m.quick {
                eprintln!(
                    "WARNING: artifacts built with --quick — numbers are NOT \
                     representative; run `make artifacts`"
                );
            }
            m
        }
        Err(e) => {
            eprintln!("artifacts missing ({e}); run `make artifacts` first");
            std::process::exit(0);
        }
    }
}

/// Load a task data blob by key, panicking with context on failure.
pub fn load_blob(m: &Manifest, task: &str, key: &str) -> Tensor {
    let t = m
        .task(task)
        .unwrap_or_else(|e| panic!("task {task}: {e}"));
    let b = t
        .data
        .get(key)
        .unwrap_or_else(|| panic!("task {task} has no blob {key:?}"));
    blobs::load_f32(&m.blob_path(b), &b.shape)
        .unwrap_or_else(|e| panic!("blob {task}/{key}: {e}"))
}

/// Load labels (i32 blob).
pub fn load_labels(m: &Manifest, task: &str, key: &str) -> Vec<i32> {
    let t = m.task(task).unwrap();
    let b = &t.data[key];
    blobs::load_i32(&m.blob_path(b), b.shape.iter().product())
        .unwrap_or_else(|e| panic!("labels {task}/{key}: {e}"))
}
