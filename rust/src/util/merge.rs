//! Cluster summary-merge helpers — fold per-node `cmd: "metrics"` replies
//! into one aggregate reply at the router.
//!
//! Counters merge as **sums**; rate-style gauges (goodput, batch fill)
//! merge as **ratio-of-sums** of their underlying counters, keeping the
//! engine's vacuous-1.0 convention when nothing has been observed; latency
//! percentiles merge as a **responses-weighted mean** of the per-node
//! percentiles. Exact percentile merging would need the raw histograms on
//! the wire, so the merged percentile is an approximation — documented as
//! such in rust/README.md §"Cluster serving" — but it is monotone in every
//! node's value and exact when nodes are identically loaded.

use crate::util::json::{self, Value};

/// Sum `key` across every reply; absent or non-numeric fields contribute
/// nothing (a node predating a field must not poison the merge).
pub fn sum_field(replies: &[Value], key: &str) -> f64 {
    replies
        .iter()
        .filter_map(|r| r.get(key).and_then(Value::as_f64))
        .sum()
}

/// `Σ num / Σ den` with the engine's vacuous convention: a zero
/// denominator (nothing observed anywhere) reads 1.0, matching the
/// per-node `goodput()` / `fill_ratio()` gauges being merged.
pub fn ratio_of_sums(replies: &[Value], num: &str, den: &str) -> f64 {
    let d = sum_field(replies, den);
    if d <= 0.0 {
        1.0
    } else {
        sum_field(replies, num) / d
    }
}

/// Mean of `key` weighted by `weight_key` — the percentile merge rule.
/// Replies missing either field drop out of both sums; zero total weight
/// reads 0.0 (an idle cluster has no latency to report).
pub fn weighted_mean_field(replies: &[Value], key: &str, weight_key: &str) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for r in replies {
        let (Some(v), Some(w)) = (
            r.get(key).and_then(Value::as_f64),
            r.get(weight_key).and_then(Value::as_f64),
        ) else {
            continue;
        };
        num += v * w;
        den += w;
    }
    if den <= 0.0 {
        0.0
    } else {
        num / den
    }
}

/// Merge per-node `cmd: "metrics"` replies into the router's one reply.
/// The output carries the same flat numeric fields a single engine
/// reports (so clients need no cluster-specific parsing), plus `nodes`
/// and `merged: true` so callers can tell an aggregate from a single
/// engine's answer.
pub fn merge_metrics(replies: &[Value]) -> Value {
    const SUMS: &[&str] = &[
        "requests",
        "responses",
        "failures",
        "deadline_met",
        "deadline_misses",
        "rows",
        "padded_slots",
        "shed",
        "overload_rejects",
    ];
    let mut fields: Vec<(&str, Value)> = vec![
        ("ok", Value::Bool(true)),
        ("merged", Value::Bool(true)),
        ("nodes", json::num(replies.len() as f64)),
    ];
    for key in SUMS {
        fields.push((key, json::num(sum_field(replies, key))));
    }
    fields.push((
        "goodput",
        json::num(ratio_of_sums(replies, "deadline_met", "responses")),
    ));
    let rows = sum_field(replies, "rows");
    let padded = sum_field(replies, "padded_slots");
    let fill = if rows + padded <= 0.0 {
        1.0
    } else {
        rows / (rows + padded)
    };
    fields.push(("fill", json::num(fill)));
    fields.push((
        "total_p50_us",
        json::num(weighted_mean_field(replies, "total_p50_us", "responses")),
    ));
    fields.push((
        "total_p99_us",
        json::num(weighted_mean_field(replies, "total_p99_us", "responses")),
    ));
    json::obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node(fields: &[(&str, f64)]) -> Value {
        json::obj(
            fields
                .iter()
                .map(|(k, v)| (*k, json::num(*v)))
                .collect::<Vec<_>>(),
        )
    }

    #[test]
    fn counters_merge_as_sums() {
        let replies = [
            node(&[("requests", 10.0), ("responses", 8.0)]),
            node(&[("requests", 5.0), ("responses", 5.0)]),
            // a node predating the field contributes nothing, not NaN
            node(&[("responses", 1.0)]),
        ];
        assert_eq!(sum_field(&replies, "requests"), 15.0);
        assert_eq!(sum_field(&replies, "responses"), 14.0);
        assert_eq!(sum_field(&replies, "no_such_field"), 0.0);
    }

    #[test]
    fn goodput_is_ratio_of_sums_not_mean_of_ratios() {
        // node A: 9/10 met, node B: 1/2 met — the mean of ratios (0.70)
        // overweights the tiny node; the true cluster goodput is 10/12
        let replies = [
            node(&[("deadline_met", 9.0), ("responses", 10.0)]),
            node(&[("deadline_met", 1.0), ("responses", 2.0)]),
        ];
        let g = ratio_of_sums(&replies, "deadline_met", "responses");
        assert!((g - 10.0 / 12.0).abs() < 1e-12, "{g}");
        // vacuous cluster: nothing observed reads 1.0 like a fresh engine
        assert_eq!(ratio_of_sums(&[], "deadline_met", "responses"), 1.0);
    }

    #[test]
    fn percentiles_merge_weighted_by_responses() {
        let replies = [
            node(&[("total_p99_us", 100.0), ("responses", 1.0)]),
            node(&[("total_p99_us", 400.0), ("responses", 3.0)]),
        ];
        let p = weighted_mean_field(&replies, "total_p99_us", "responses");
        assert!((p - 325.0).abs() < 1e-12, "{p}");
        assert_eq!(weighted_mean_field(&[], "total_p99_us", "responses"), 0.0);
    }

    #[test]
    fn merged_reply_carries_flat_fields_and_node_count() {
        let replies = [
            node(&[
                ("requests", 10.0),
                ("responses", 10.0),
                ("deadline_met", 10.0),
                ("rows", 90.0),
                ("padded_slots", 10.0),
                ("total_p50_us", 50.0),
                ("total_p99_us", 200.0),
            ]),
            node(&[
                ("requests", 30.0),
                ("responses", 30.0),
                ("deadline_met", 15.0),
                ("rows", 60.0),
                ("padded_slots", 40.0),
                ("total_p50_us", 90.0),
                ("total_p99_us", 400.0),
            ]),
        ];
        let m = merge_metrics(&replies);
        assert_eq!(m.get("ok").and_then(Value::as_bool), Some(true));
        assert_eq!(m.get("merged").and_then(Value::as_bool), Some(true));
        assert_eq!(m.get("nodes").and_then(Value::as_f64), Some(2.0));
        assert_eq!(m.get("requests").and_then(Value::as_f64), Some(40.0));
        assert_eq!(m.get("goodput").and_then(Value::as_f64), Some(25.0 / 40.0));
        assert_eq!(m.get("fill").and_then(Value::as_f64), Some(150.0 / 200.0));
        let p50 = m.get("total_p50_us").and_then(Value::as_f64).unwrap();
        assert!((p50 - (50.0 * 10.0 + 90.0 * 30.0) / 40.0).abs() < 1e-9);
    }

    #[test]
    fn empty_cluster_merges_to_the_vacuous_reply() {
        let m = merge_metrics(&[]);
        assert_eq!(m.get("nodes").and_then(Value::as_f64), Some(0.0));
        assert_eq!(m.get("goodput").and_then(Value::as_f64), Some(1.0));
        assert_eq!(m.get("fill").and_then(Value::as_f64), Some(1.0));
        assert_eq!(m.get("requests").and_then(Value::as_f64), Some(0.0));
    }
}
