//! LocalCluster — K in-process engines on loopback ports, the multi-node
//! substrate of the router tests and the cluster serving bench.
//!
//! Each node is a full [`Engine`] behind its own
//! [`server::serve_listener`] accept loop on an ephemeral `127.0.0.1`
//! port, all sharing one synthetic native artifact set
//! ([`fixtures::temp_native_artifacts`]) — tier-1 verifiable: no
//! compiled artifacts, no external processes, no fixed ports. Teardown
//! is the graceful `cmd: "shutdown"` path (drain, answer, exit the
//! accept loop), so killing a node mid-bench is deterministic rather
//! than a process-level kill.

use std::net::TcpListener;
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Duration;

use crate::coordinator::server::{self, Client};
use crate::coordinator::{Engine, EngineConfig, Policy};
use crate::runtime::BackendKind;
use crate::util::fixtures;
use crate::util::json::{self, Value};
use crate::Result;

/// Bound on the shutdown handshake when stopping a node: connect fast,
/// but leave the read enough room for the engine's in-flight drain
/// (the server-side drain timeout is 5 s).
const STOP_CONNECT: Duration = Duration::from_secs(1);
const STOP_READ: Duration = Duration::from_secs(10);

/// One cluster member: a live engine plus the address it serves on.
pub struct ClusterNode {
    /// `127.0.0.1:<ephemeral>` — what a router or client dials.
    pub addr: String,
    /// The node's engine, for in-process assertions (metrics, queues).
    pub engine: Arc<Engine>,
    serve: Option<JoinHandle<()>>,
    stopped: bool,
}

/// K engines on loopback ports. Dropping the cluster stops every node
/// gracefully (best effort).
pub struct LocalCluster {
    pub nodes: Vec<ClusterNode>,
}

impl LocalCluster {
    /// Spawn `k` nodes over one shared synthetic artifact set (native
    /// backend, 2 workers, 1 ms batching window — the test profile).
    /// `tag` disambiguates the temp dir; `tasks` is the fixture task
    /// list, e.g. `&[("cnf_a", 4)]`.
    pub fn spawn(k: usize, tag: &str, tasks: &[(&str, usize)]) -> Result<LocalCluster> {
        let dir = fixtures::temp_native_artifacts(tag, tasks)?;
        LocalCluster::spawn_with(k, |_node| EngineConfig {
            artifacts_dir: dir.clone(),
            max_wait: Duration::from_millis(1),
            policy: Policy::MinMacs,
            backend: BackendKind::Native,
            workers: 2,
            ..Default::default()
        })
    }

    /// Spawn `k` nodes with a caller-supplied config per node index —
    /// the bench uses this to tune batching windows and SLO knobs.
    pub fn spawn_with(
        k: usize,
        config: impl Fn(usize) -> EngineConfig,
    ) -> Result<LocalCluster> {
        let mut nodes = Vec::with_capacity(k);
        for i in 0..k {
            let engine = Arc::new(Engine::new(config(i))?);
            let listener = TcpListener::bind("127.0.0.1:0")?;
            let addr = listener.local_addr()?.to_string();
            let serve = {
                let engine = Arc::clone(&engine);
                thread::spawn(move || {
                    let _ = server::serve_listener(engine, listener);
                })
            };
            nodes.push(ClusterNode {
                addr,
                engine,
                serve: Some(serve),
                stopped: false,
            });
        }
        Ok(LocalCluster { nodes })
    }

    /// The node addresses in spawn order — what a router's `--nodes`
    /// list or a [`Client`] dials.
    pub fn addrs(&self) -> Vec<String> {
        self.nodes.iter().map(|n| n.addr.clone()).collect()
    }

    /// Gracefully stop node `i` via `cmd: "shutdown"`: the engine drains
    /// queued + in-flight work, answers it, and the accept loop exits —
    /// then the serve thread is joined. Returns whether the drain
    /// finished inside the server's timeout. Idempotent: stopping a
    /// stopped node is `Ok(true)`.
    pub fn stop(&mut self, i: usize) -> Result<bool> {
        let node = &mut self.nodes[i];
        if node.stopped {
            return Ok(true);
        }
        let mut c = Client::connect_with(&node.addr, Some(STOP_CONNECT), Some(STOP_READ))?;
        let reply = c.request(&json::obj(vec![("cmd", json::s("shutdown"))]))?;
        let drained = reply.get("drained").and_then(Value::as_bool).unwrap_or(false);
        node.stopped = true;
        if let Some(h) = node.serve.take() {
            let _ = h.join();
        }
        Ok(drained)
    }

    /// [`Self::stop`] every live node, ignoring nodes that already died.
    pub fn stop_all(&mut self) {
        for i in 0..self.nodes.len() {
            let _ = self.stop(i);
        }
    }
}

impl Drop for LocalCluster {
    fn drop(&mut self) {
        self.stop_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::v1::{InferReply, InferRequest};

    #[test]
    fn cluster_spawns_serves_and_stops_gracefully() {
        let mut cluster = LocalCluster::spawn(2, "cluster_unit", &[("cnf_a", 4)]).unwrap();
        let addrs = cluster.addrs();
        assert_eq!(addrs.len(), 2);
        // every node answers a v1 request on its own port
        for addr in &addrs {
            let mut c = Client::connect_with(
                addr,
                Some(Duration::from_secs(1)),
                Some(Duration::from_secs(30)),
            )
            .unwrap();
            let reply = c
                .infer_v1(&InferRequest::single("cnf_a", 0.05, vec![0.1, -0.2]))
                .unwrap();
            assert!(matches!(reply, InferReply::Ok(_)), "{reply:?}");
        }
        // graceful stop: drains, then the port stops accepting
        assert!(cluster.stop(0).unwrap());
        assert!(cluster.stop(0).unwrap(), "stop is idempotent");
        assert!(
            Client::connect_with(&addrs[0], Some(Duration::from_millis(200)), None).is_err(),
            "stopped node must not accept connections"
        );
        // the surviving node still serves
        let mut c = Client::connect_with(
            &addrs[1],
            Some(Duration::from_secs(1)),
            Some(Duration::from_secs(30)),
        )
        .unwrap();
        let reply = c
            .infer_v1(&InferRequest::single("cnf_a", 0.05, vec![0.3, 0.4]))
            .unwrap();
        assert!(matches!(reply, InferReply::Ok(_)), "{reply:?}");
    }
}
