//! Tiny CLI argument parser (clap is not available offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments; generates usage text from registered options — plus the
//! shared comma-list/span value parsers every binary uses.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
struct Opt {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative argument parser.
#[derive(Debug, Default)]
pub struct Cli {
    about: String,
    opts: Vec<Opt>,
    values: BTreeMap<String, String>,
    positionals: Vec<String>,
}

impl Cli {
    pub fn new(about: &str) -> Self {
        Cli {
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// Register `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.into(),
            help: help.into(),
            default: Some(default.into()),
            is_flag: false,
        });
        self
    }

    /// Register a boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self, prog: &str) -> String {
        let mut s = format!("{}\n\nusage: {prog} [options]\n\noptions:\n", self.about);
        for o in &self.opts {
            let tail = if o.is_flag {
                String::new()
            } else {
                format!(" <v> (default {})", o.default.as_deref().unwrap_or(""))
            };
            s.push_str(&format!("  --{}{tail}\n        {}\n", o.name, o.help));
        }
        s.push_str("  --help\n        print this message\n");
        s
    }

    /// Parse; on `--help` prints usage and exits. Errors on unknown options.
    pub fn parse(mut self, args: &[String]) -> Result<Parsed, String> {
        let prog = args.first().map(String::as_str).unwrap_or("prog");
        let mut it = args.iter().skip(1).peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(self.usage(prog));
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.usage(prog)))?
                    .clone();
                let value = if opt.is_flag {
                    inline.unwrap_or_else(|| "true".to_string())
                } else if let Some(v) = inline {
                    v
                } else {
                    it.next()
                        .ok_or_else(|| format!("--{name} needs a value"))?
                        .clone()
                };
                self.values.insert(name, value);
            } else {
                self.positionals.push(a.clone());
            }
        }
        Ok(Parsed {
            opts: self.opts,
            values: self.values,
            positionals: self.positionals,
        })
    }

    /// Parse from `std::env::args()`; print usage and exit on `--help`/error.
    pub fn parse_env(self) -> Parsed {
        let args: Vec<String> = std::env::args().collect();
        match self.parse(&args) {
            Ok(p) => p,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(if msg.starts_with("unknown") { 2 } else { 0 });
            }
        }
    }
}

#[derive(Debug)]
pub struct Parsed {
    opts: Vec<Opt>,
    values: BTreeMap<String, String>,
    positionals: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> String {
        if let Some(v) = self.values.get(name) {
            return v.clone();
        }
        self.opts
            .iter()
            .find(|o| o.name == name)
            .and_then(|o| o.default.clone())
            .unwrap_or_default()
    }

    pub fn get_flag(&self, name: &str) -> bool {
        self.values.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name).parse().unwrap_or_else(|_| {
            panic!("--{name} expects an integer, got {:?}", self.get(name))
        })
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name).parse().unwrap_or_else(|_| {
            panic!("--{name} expects a number, got {:?}", self.get(name))
        })
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

// ---------------------------------------------------------------------------
// Shared value parsers (comma lists, spans)
// ---------------------------------------------------------------------------

/// Comma-separated strings; empty tokens dropped.
pub fn parse_list(s: &str) -> Vec<String> {
    s.split(',')
        .map(|x| x.trim().to_string())
        .filter(|x| !x.is_empty())
        .collect()
}

/// Comma-separated numbers; a fully-empty string means an empty list, but
/// any unparsable or empty *interior* token is an error (`what` names the
/// flag, `noun` the expected kind) — silently dropping a token (e.g. the
/// `16,,8` typo) would run a different config than asked for.
fn parse_num_list<T: std::str::FromStr>(
    what: &str,
    noun: &str,
    s: &str,
) -> crate::Result<Vec<T>> {
    if s.trim().is_empty() {
        return Ok(Vec::new());
    }
    s.split(',')
        .map(|x| {
            x.trim().parse::<T>().map_err(|_| {
                crate::Error::Other(format!(
                    "{what} expects comma-separated {noun}, got {x:?} in {s:?}"
                ))
            })
        })
        .collect()
}

/// Comma-separated integers (see [`parse_num_list`] semantics).
pub fn parse_usize_list(what: &str, s: &str) -> crate::Result<Vec<usize>> {
    parse_num_list(what, "integers", s)
}

/// Comma-separated floats (see [`parse_num_list`] semantics).
pub fn parse_f32_list(what: &str, s: &str) -> crate::Result<Vec<f32>> {
    parse_num_list(what, "numbers", s)
}

/// An `s0,s1` span.
pub fn parse_span(what: &str, s: &str) -> crate::Result<(f32, f32)> {
    let parts: Result<Vec<f32>, _> = s.split(',').map(|x| x.trim().parse::<f32>()).collect();
    match parts.as_deref() {
        Ok([a, b]) => Ok((*a, *b)),
        _ => Err(crate::Error::Other(format!(
            "{what} expects two comma-separated numbers (s0,s1), got {s:?}"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Vec<String> {
        std::iter::once("prog".to_string())
            .chain(s.iter().map(|x| x.to_string()))
            .collect()
    }

    fn cli() -> Cli {
        Cli::new("test")
            .opt("port", "7070", "listen port")
            .opt("task", "cnf_rings", "task name")
            .flag("verbose", "chatty")
    }

    #[test]
    fn defaults() {
        let p = cli().parse(&args(&[])).unwrap();
        assert_eq!(p.get("port"), "7070");
        assert_eq!(p.get_usize("port"), 7070);
        assert!(!p.get_flag("verbose"));
    }

    #[test]
    fn explicit_values_and_flags() {
        let p = cli()
            .parse(&args(&["--port", "9090", "--verbose", "--task=img_smnist"]))
            .unwrap();
        assert_eq!(p.get_usize("port"), 9090);
        assert!(p.get_flag("verbose"));
        assert_eq!(p.get("task"), "img_smnist");
    }

    #[test]
    fn positionals() {
        let p = cli().parse(&args(&["run", "--port", "1", "x"])).unwrap();
        assert_eq!(p.positionals(), &["run".to_string(), "x".to_string()]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cli().parse(&args(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(cli().parse(&args(&["--port"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = cli().parse(&args(&["--help"])).unwrap_err();
        assert!(err.contains("--port"));
        assert!(err.contains("listen port"));
    }

    #[test]
    fn value_parsers() {
        assert_eq!(parse_list("a, b,,c"), vec!["a", "b", "c"]);
        assert!(parse_list("").is_empty());
        assert_eq!(parse_usize_list("--ks", "1, 2,8").unwrap(), vec![1, 2, 8]);
        assert!(parse_usize_list("--ks", "").unwrap().is_empty());
        let err = parse_usize_list("--ks", "1,x").unwrap_err();
        assert!(err.to_string().contains("--ks"));
        // an interior empty token is a typo, not a value to drop
        assert!(parse_usize_list("--ks", "1,,2").is_err());
        assert_eq!(parse_f32_list("--tols", "1e-3,0.5").unwrap(), vec![1e-3, 0.5]);
        assert!(parse_f32_list("--tols", "nope").is_err());
        assert_eq!(parse_span("--span", "0, 1.5").unwrap(), (0.0, 1.5));
        assert!(parse_span("--span", "1").is_err());
        assert!(parse_span("--span", "1,2,3").is_err());
    }
}
