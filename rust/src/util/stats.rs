//! Small statistics helpers: moments, percentiles, online latency histogram.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64)
        .sqrt()
}

/// Percentile via linear interpolation on a sorted copy (q in [0, 100]).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    // total_cmp: a stray NaN (e.g. a failed measurement) sorts to the end
    // instead of panicking the whole report
    v.sort_by(|a, b| a.total_cmp(b));
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Fixed-bucket log-scale latency histogram (µs-granularity, thread-safe via
/// atomics) used by the coordinator's metrics without locking the hot path.
pub struct LatencyHistogram {
    /// bucket i covers [2^i, 2^{i+1}) microseconds
    buckets: Vec<std::sync::atomic::AtomicU64>,
    count: std::sync::atomic::AtomicU64,
    sum_us: std::sync::atomic::AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: (0..40).map(|_| std::sync::atomic::AtomicU64::new(0)).collect(),
            count: std::sync::atomic::AtomicU64::new(0),
            sum_us: std::sync::atomic::AtomicU64::new(0),
        }
    }

    pub fn record(&self, d: std::time::Duration) {
        use std::sync::atomic::Ordering::Relaxed;
        let us = d.as_micros().max(1) as u64;
        let idx = (63 - us.leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[idx].fetch_add(1, Relaxed);
        self.count.fetch_add(1, Relaxed);
        self.sum_us.fetch_add(us, Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(std::sync::atomic::Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(std::sync::atomic::Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate percentile from the log buckets (returns the bucket's
    /// geometric midpoint in µs).
    pub fn percentile_us(&self, q: f64) -> f64 {
        use std::sync::atomic::Ordering::Relaxed;
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((q / 100.0) * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Relaxed);
            if acc >= target {
                let lo = (1u64 << i) as f64;
                return lo * std::f64::consts::SQRT_2;
            }
        }
        (1u64 << (self.buckets.len() - 1)) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935).abs() < 1e-6);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-9);
        assert!((percentile(&xs, 100.0) - 100.0).abs() < 1e-9);
        assert!((percentile(&xs, 99.0) - 99.01).abs() < 0.011);
    }

    #[test]
    fn percentile_survives_nan_entries() {
        // regression: partial_cmp(...).unwrap() panicked on any NaN in the
        // input; total_cmp sorts NaN after every finite value, so low/mid
        // percentiles of real data stay meaningful
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
        assert!(percentile(&xs, 100.0).is_nan());
    }

    #[test]
    fn histogram_basic() {
        let h = LatencyHistogram::new();
        for ms in [1u64, 2, 4, 8] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 4);
        assert!(h.mean_us() >= 1000.0);
        let p50 = h.percentile_us(50.0);
        assert!(p50 >= 1000.0 && p50 <= 4096.0 * 2.0, "{p50}");
    }

    #[test]
    fn histogram_percentile_ordering() {
        let h = LatencyHistogram::new();
        for i in 0..1000u64 {
            h.record(Duration::from_micros(10 + i));
        }
        assert!(h.percentile_us(99.0) >= h.percentile_us(50.0));
    }
}
