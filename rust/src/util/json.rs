//! Minimal JSON codec (serde is not available offline).
//!
//! Parses the artifact manifest and the exported weight files — the heavy
//! case is weight JSON with hundreds of thousands of number literals, so the
//! number path avoids per-token allocation.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A JSON value. Numbers are stored as f64 (all our payloads are f32 weights
/// / small ints, well inside f64's exact range).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest-parsing ergonomics.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing key {key:?}")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_f32(&self) -> Option<f32> {
        self.as_f64().map(|x| x as f32)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Flatten an arbitrarily nested numeric array into (data, shape).
    /// Errors on ragged nesting.
    pub fn as_f32_tensor(&self) -> Result<(Vec<f32>, Vec<usize>)> {
        fn shape_of(v: &Value, shape: &mut Vec<usize>) -> Result<()> {
            if let Value::Arr(a) = v {
                shape.push(a.len());
                if let Some(first) = a.first() {
                    shape_of(first, shape)?;
                }
            }
            Ok(())
        }
        fn fill(v: &Value, shape: &[usize], out: &mut Vec<f32>) -> Result<()> {
            match v {
                Value::Num(x) => {
                    if !shape.is_empty() {
                        return Err(Error::Json("ragged array".into()));
                    }
                    out.push(*x as f32);
                    Ok(())
                }
                Value::Arr(a) => {
                    let (head, rest) = shape
                        .split_first()
                        .ok_or_else(|| Error::Json("ragged array".into()))?;
                    if a.len() != *head {
                        return Err(Error::Json("ragged array".into()));
                    }
                    for x in a {
                        fill(x, rest, out)?;
                    }
                    Ok(())
                }
                _ => Err(Error::Json("non-numeric tensor".into())),
            }
        }
        let mut shape = Vec::new();
        shape_of(self, &mut shape)?;
        let numel: usize = shape.iter().product();
        let mut data = Vec::with_capacity(numel);
        fill(self, &shape, &mut data)?;
        Ok((data, shape))
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()
            .ok_or_else(|| Error::Json("expected array".into()))?
            .iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| Error::Json("expected number".into()))
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

pub fn parse(input: &str) -> Result<Value> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

pub fn parse_file(path: &std::path::Path) -> Result<Value> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| Error::Json(format!("read {}: {e}", path.display())))?;
    parse(&text)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        let line = self.b[..self.i.min(self.b.len())]
            .iter()
            .filter(|&&c| c == b'\n')
            .count()
            + 1;
        Error::Json(format!("{msg} at byte {} (line {line})", self.i))
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek().ok_or_else(|| self.err("unexpected eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = *self
                .b
                .get(self.i)
                .ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = *self
                        .b
                        .get(self.i)
                        .ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // byte-accurate UTF-8 passthrough
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    let end = start + len;
                    let chunk = self
                        .b
                        .get(start..end)
                        .ok_or_else(|| self.err("bad utf8"))?;
                    s.push_str(
                        std::str::from_utf8(chunk)
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, &mut s);
    s
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                let _ = write!(out, "{}", *x as i64);
            } else {
                let _ = write!(out, "{x}");
            }
        }
        Value::Str(s) => write_string(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders for the request protocol.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Value {
    Value::Num(x)
}

pub fn s(x: &str) -> Value {
    Value::Str(x.to_string())
}

pub fn arr_f32(xs: &[f32]) -> Value {
    Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("\"hi\\n\"").unwrap(), Value::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b"),
            Some(&Value::Bool(false))
        );
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("hello").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"w":[[1.5,-2],[0.25,3]],"name":"t\"x","ok":true,"n":null}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&to_string(&v)).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_strings() {
        let v = parse(r#""é café ✓""#).unwrap();
        assert_eq!(v.as_str(), Some("é café ✓"));
        let v2 = parse(&to_string(&v)).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn tensor_extraction() {
        let v = parse("[[1,2,3],[4,5,6]]").unwrap();
        let (data, shape) = v.as_f32_tensor().unwrap();
        assert_eq!(shape, vec![2, 3]);
        assert_eq!(data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn tensor_rejects_ragged() {
        let v = parse("[[1,2],[3]]").unwrap();
        assert!(v.as_f32_tensor().is_err());
    }

    #[test]
    fn deep_nesting() {
        let mut src = String::new();
        for _ in 0..64 {
            src.push('[');
        }
        src.push('1');
        for _ in 0..64 {
            src.push(']');
        }
        let v = parse(&src).unwrap();
        let (data, shape) = v.as_f32_tensor().unwrap();
        assert_eq!(shape.len(), 64);
        assert_eq!(data, vec![1.0]);
    }

    #[test]
    fn req_reports_key() {
        let v = parse("{}").unwrap();
        let err = v.req("weights").unwrap_err();
        assert!(err.to_string().contains("weights"));
    }
}
