//! Synthetic native-only artifact sets — the substrate of the engine test
//! harness that runs anywhere.
//!
//! Writes a `manifest.json` plus weight files that the
//! [`crate::runtime::NativeBackend`] can serve with zero external
//! dependencies: no `make artifacts`, no HLO, no PJRT. The synthetic task
//! is a 2-D CNF-shaped system with a rotation-flavoured linear field
//! (bounded trajectories, so every solver stays finite) and a small linear
//! hypersolver correction, exported in the exact JSON schema
//! `python/compile/aot.py` produces.

use std::path::{Path, PathBuf};

use crate::Result;

/// Field weights: dz0 = z1 + 0.1 s, dz1 = -z0 + 0.1 s (rotation + drift).
const FIELD_JSON: &str = r#"{
    "time_mode": "concat",
    "layers": [
      {"w": [[0.0, -1.0], [1.0, 0.0], [0.1, 0.1]], "b": [0.0, 0.0], "act": "id"}
    ]
  }"#;

/// Hyper net g([z, dz, eps, s]) = 0.05 z — tiny but nonzero, so hypersolved
/// variants are distinguishable from their base solver.
const HYPER_JSON: &str = r#"{
    "layers": [
      {"w": [[0.05, 0.0], [0.0, 0.05], [0.0, 0.0], [0.0, 0.0], [0.0, 0.0], [0.0, 0.0]],
       "b": [0.0, 0.0], "act": "id"}
    ]
  }"#;

/// Training-distribution stamp for the synthetic fixtures: a seeded
/// box-uniform sample over `[-1.5, 1.5]^dims` — the state range every
/// fixture's bounded trajectories live in — serialized the way the real
/// exporters stamp it, so engine tests and benches exercise the audit
/// plane's drift scoring without a training run.
fn train_stats_json(dims: usize) -> String {
    let mut rng = crate::util::prng::Rng::new(0x7A57_57A7 ^ dims as u64);
    let rows: Vec<f32> = (0..256 * dims)
        .map(|_| rng.uniform_in(-1.5, 1.5) as f32)
        .collect();
    let stats = crate::obs::drift::TrainStats::from_rows(&rows, dims)
        .expect("fixture train_stats");
    crate::util::json::to_string(&stats.to_json())
}

fn task_manifest_json(name: &str, batch: usize) -> String {
    format!(
        r#""{name}": {{
      "kind": "cnf",
      "state": {{"shape": [{batch}, 2]}},
      "s_span": [0.0, 1.0],
      "weights": "weights/{name}.json",
      "field_hlo": "{name}_field.hlo.txt",
      "macs": {{"field": 6, "hyper": 12}},
      "delta": 0.01,
      "train_stats": {train_stats},
      "hyper_base": "heun",
      "variants": [
        {{"name": "euler_k2", "solver": "euler", "k": 2, "hyper": false,
          "hlo": "{name}_euler_k2.hlo.txt", "nfe": 2, "macs": 12,
          "mape": 0.25, "in_shape": [{batch}, 2], "out_shape": [{batch}, 2]}},
        {{"name": "heun_k2", "solver": "heun", "k": 2, "hyper": false,
          "hlo": "{name}_heun_k2.hlo.txt", "nfe": 4, "macs": 24,
          "mape": 0.08, "in_shape": [{batch}, 2], "out_shape": [{batch}, 2]}},
        {{"name": "hyperheun_k2", "solver": "heun", "k": 2, "hyper": true,
          "hlo": "{name}_hyperheun_k2.hlo.txt", "nfe": 4, "macs": 40,
          "mape": 0.02, "in_shape": [{batch}, 2], "out_shape": [{batch}, 2]}},
        {{"name": "dopri5", "solver": "dopri5", "k": 0, "hyper": false,
          "hlo": "{name}_dopri5.hlo.txt", "nfe": 28, "macs": 200,
          "mape": 0.0001, "outputs": ["z", "nfe"],
          "in_shape": [{batch}, 2], "out_shape": [{batch}, 2]}}
      ]
    }}"#,
        train_stats = train_stats_json(2),
    )
}

/// Write `manifest.json` + weight files for cnf-style 2-D tasks into `dir`.
/// `tasks` is a list of (task name, exported batch size). Each task gets
/// four variants: euler_k2 / heun_k2 / hyperheun_k2 / dopri5.
pub fn write_native_artifacts(dir: &Path, tasks: &[(&str, usize)]) -> Result<()> {
    std::fs::create_dir_all(dir.join("weights"))?;
    let mut entries = Vec::with_capacity(tasks.len());
    for (name, batch) in tasks {
        entries.push(task_manifest_json(name, *batch));
        let weights = format!(
            r#"{{"kind": "cnf", "field": {FIELD_JSON}, "hyper": {HYPER_JSON}}}"#
        );
        std::fs::write(dir.join("weights").join(format!("{name}.json")), weights)?;
    }
    let manifest = format!(
        r#"{{
  "version": 1, "stamp": "synthetic-native", "seed": 0, "quick": false,
  "tasks": {{
    {}
  }}
}}"#,
        entries.join(",\n    ")
    );
    std::fs::write(dir.join("manifest.json"), manifest)?;
    Ok(())
}

/// Create a fresh temp dir with synthetic artifacts and return its path.
/// Every call gets a unique directory (pid + counter), so concurrent tests
/// in one binary never race on the filesystem; `tag` just aids debugging.
pub fn temp_native_artifacts(tag: &str, tasks: &[(&str, usize)]) -> Result<PathBuf> {
    let dir = fresh_temp_dir(tag)?;
    write_native_artifacts(&dir, tasks)?;
    Ok(dir)
}

fn fresh_temp_dir(tag: &str) -> Result<PathBuf> {
    use std::sync::atomic::{AtomicU64, Ordering};
    static UNIQ: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "hsolve_native_{tag}_{}_{}",
        std::process::id(),
        UNIQ.fetch_add(1, Ordering::Relaxed)
    ));
    if dir.exists() {
        std::fs::remove_dir_all(&dir)?;
    }
    Ok(dir)
}

// ---------------------------------------------------------------------------
// Heavy fixture: a field expensive enough that serving capacity is finite
// ---------------------------------------------------------------------------

/// Hidden width of the heavy fixture's MLP field.
const HEAVY_HIDDEN: usize = 128;

/// Render a dense matrix as a JSON array of `din` rows × `dout` columns —
/// the exact `w` layout `nn::layers` reads back.
fn mat_json(rows: &[Vec<f32>]) -> String {
    let rows: Vec<String> = rows
        .iter()
        .map(|r| {
            let cells: Vec<String> = r.iter().map(|v| format!("{v}")).collect();
            format!("[{}]", cells.join(", "))
        })
        .collect();
    format!("[{}]", rows.join(", "))
}

/// A 2-D field through a 3→H→H→2 MLP (time concat, tanh hidden layers, a
/// small-scaled linear readout so |f| stays O(1) and every solver is
/// finite over the span). Weights come from the seeded in-repo PRNG, so
/// the fixture is deterministic across runs and machines. At H = 128 one
/// field evaluation costs ~17k MACs — three orders of magnitude above the
/// rotation fixture — which gives the serving stack a *finite measurable
/// capacity*: the substrate the overload/shedding bench needs.
fn heavy_field_json(seed: u64) -> String {
    let mut rng = crate::util::prng::Rng::new(seed ^ 0x0EA5_EED);
    let dims = [3usize, HEAVY_HIDDEN, HEAVY_HIDDEN, 2];
    let mut layers = Vec::with_capacity(dims.len() - 1);
    for li in 0..dims.len() - 1 {
        let (din, dout) = (dims[li], dims[li + 1]);
        let last = li == dims.len() - 2;
        let scale = if last { 0.1 } else { 1.0 } / (din as f32).sqrt();
        let w: Vec<Vec<f32>> = (0..din)
            .map(|_| (0..dout).map(|_| rng.normal_f32() * scale).collect())
            .collect();
        let b: Vec<String> = (0..dout).map(|_| "0".to_string()).collect();
        layers.push(format!(
            r#"{{"w": {}, "b": [{}], "act": "{}"}}"#,
            mat_json(&w),
            b.join(", "),
            if last { "id" } else { "tanh" }
        ));
    }
    format!(
        r#"{{"time_mode": "concat", "layers": [{}]}}"#,
        layers.join(", ")
    )
}

/// Write a single heavy cnf task (see [`heavy_field_json`]) into `dir`.
/// Two variants: a cheap `euler_k2` and the adaptive `dopri5` reference —
/// the overload bench pins `dopri5` so every request pays the full
/// adaptive cost.
pub fn write_heavy_native_artifacts(dir: &Path, name: &str, batch: usize) -> Result<()> {
    std::fs::create_dir_all(dir.join("weights"))?;
    // MACs per field eval: 3·H + H·H + H·2 at H = HEAVY_HIDDEN
    let mac_f = 3 * HEAVY_HIDDEN + HEAVY_HIDDEN * HEAVY_HIDDEN + HEAVY_HIDDEN * 2;
    let task = format!(
        r#""{name}": {{
      "kind": "cnf",
      "state": {{"shape": [{batch}, 2]}},
      "s_span": [0.0, 1.0],
      "weights": "weights/{name}.json",
      "field_hlo": "{name}_field.hlo.txt",
      "macs": {{"field": {mac_f}, "hyper": 12}},
      "delta": 0.01,
      "train_stats": {train_stats},
      "hyper_base": "heun",
      "variants": [
        {{"name": "euler_k2", "solver": "euler", "k": 2, "hyper": false,
          "hlo": "{name}_euler_k2.hlo.txt", "nfe": 2, "macs": {m2},
          "mape": 0.3, "in_shape": [{batch}, 2], "out_shape": [{batch}, 2]}},
        {{"name": "dopri5", "solver": "dopri5", "k": 0, "hyper": false,
          "hlo": "{name}_dopri5.hlo.txt", "nfe": 26, "macs": {m26},
          "mape": 0.0001, "outputs": ["z", "nfe"],
          "in_shape": [{batch}, 2], "out_shape": [{batch}, 2]}}
      ]
    }}"#,
        m2 = 2 * mac_f,
        m26 = 26 * mac_f,
        train_stats = train_stats_json(2),
    );
    let weights = format!(
        r#"{{"kind": "cnf", "field": {}, "hyper": {HYPER_JSON}}}"#,
        heavy_field_json(17)
    );
    std::fs::write(dir.join("weights").join(format!("{name}.json")), weights)?;
    let manifest = format!(
        r#"{{
  "version": 1, "stamp": "synthetic-native-heavy", "seed": 0, "quick": false,
  "tasks": {{
    {task}
  }}
}}"#
    );
    std::fs::write(dir.join("manifest.json"), manifest)?;
    Ok(())
}

/// [`temp_native_artifacts`], but with the heavy task set.
pub fn temp_heavy_native_artifacts(tag: &str, name: &str, batch: usize) -> Result<PathBuf> {
    let dir = fresh_temp_dir(tag)?;
    write_heavy_native_artifacts(&dir, name, batch)?;
    Ok(dir)
}

// ---------------------------------------------------------------------------
// Wide fixture: arbitrary state dimension, cheap field — codec-bound serving
// ---------------------------------------------------------------------------

/// Render the wide fixture's linear field: paired rotations on the state
/// plane (z_{2k}, z_{2k+1}) plus a small time drift, generalising the 2-D
/// rotation fixture to any `dims`. Trajectories stay bounded, so every
/// solver is finite, while one field eval costs only (dims+1)·dims MACs —
/// cheap enough that wide-row serving is wire/batching-bound, which is the
/// regime the v2 codec benches need.
fn wide_field_json(dims: usize) -> String {
    // w is (dims + 1) × dims: state rows then the time-concat row
    let mut w = vec![vec![0.0f32; dims]; dims + 1];
    for k in 0..dims / 2 {
        w[2 * k + 1][2 * k] = 1.0; // dz_{2k}   = +z_{2k+1}
        w[2 * k][2 * k + 1] = -1.0; // dz_{2k+1} = -z_{2k}
    }
    for j in 0..dims {
        w[dims][j] = 0.1; // + 0.1 s drift on every coordinate
    }
    let b: Vec<String> = (0..dims).map(|_| "0".to_string()).collect();
    format!(
        r#"{{"time_mode": "concat", "layers": [{{"w": {}, "b": [{}], "act": "id"}}]}}"#,
        mat_json(&w),
        b.join(", ")
    )
}

/// The matching hyper net g([z, dz, eps, s]) = 0.05 z at width `dims`.
fn wide_hyper_json(dims: usize) -> String {
    let mut w = vec![vec![0.0f32; dims]; 2 * dims + 2];
    for j in 0..dims {
        w[j][j] = 0.05;
    }
    let b: Vec<String> = (0..dims).map(|_| "0".to_string()).collect();
    format!(
        r#"{{"layers": [{{"w": {}, "b": [{}], "act": "id"}}]}}"#,
        mat_json(&w),
        b.join(", ")
    )
}

/// Write one cnf task with state shape `[batch, dims]` — the **wide**
/// fixture. A single cheap `euler_k2` variant keeps compute negligible
/// next to request decode + batch assembly, so end-to-end timings at
/// large `dims` (e.g. 512×64) measure the wire path, not the solver.
pub fn write_wide_native_artifacts(
    dir: &Path,
    name: &str,
    batch: usize,
    dims: usize,
) -> Result<()> {
    std::fs::create_dir_all(dir.join("weights"))?;
    let mac_f = (dims + 1) * dims;
    let task = format!(
        r#""{name}": {{
      "kind": "cnf",
      "state": {{"shape": [{batch}, {dims}]}},
      "s_span": [0.0, 1.0],
      "weights": "weights/{name}.json",
      "field_hlo": "{name}_field.hlo.txt",
      "macs": {{"field": {mac_f}, "hyper": {mac_h}}},
      "delta": 0.01,
      "train_stats": {train_stats},
      "hyper_base": "heun",
      "variants": [
        {{"name": "euler_k2", "solver": "euler", "k": 2, "hyper": false,
          "hlo": "{name}_euler_k2.hlo.txt", "nfe": 2, "macs": {m2},
          "mape": 0.25, "in_shape": [{batch}, {dims}], "out_shape": [{batch}, {dims}]}}
      ]
    }}"#,
        mac_h = (2 * dims + 2) * dims,
        m2 = 2 * mac_f,
        train_stats = train_stats_json(dims),
    );
    let weights = format!(
        r#"{{"kind": "cnf", "field": {}, "hyper": {}}}"#,
        wide_field_json(dims),
        wide_hyper_json(dims)
    );
    std::fs::write(dir.join("weights").join(format!("{name}.json")), weights)?;
    let manifest = format!(
        r#"{{
  "version": 1, "stamp": "synthetic-native-wide", "seed": 0, "quick": false,
  "tasks": {{
    {task}
  }}
}}"#
    );
    std::fs::write(dir.join("manifest.json"), manifest)?;
    Ok(())
}

/// [`temp_native_artifacts`], but with one wide `[batch, dims]` task.
pub fn temp_wide_native_artifacts(
    tag: &str,
    name: &str,
    batch: usize,
    dims: usize,
) -> Result<PathBuf> {
    let dir = fresh_temp_dir(tag)?;
    write_wide_native_artifacts(&dir, name, batch, dims)?;
    Ok(dir)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    #[test]
    fn synthetic_manifest_parses_and_models_load() {
        let dir = temp_native_artifacts("fixtures_unit", &[("cnf_a", 4), ("cnf_b", 8)]).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.tasks.len(), 2);
        let a = m.task("cnf_a").unwrap();
        assert_eq!(a.batch(), 4);
        assert_eq!(a.variants.len(), 4);
        assert!(a.variant("dopri5").unwrap().returns_nfe);
        assert!(!a.variant("heun_k2").unwrap().returns_nfe);
        assert!(a.variant("hyperheun_k2").unwrap().hyper);
        // the weight files load as a CnfModel and the field has state dim 2
        let model = crate::nn::CnfModel::load(&m.weights_path(a)).unwrap();
        assert_eq!(model.field.state_dim(), 2);
        // fixtures stamp a training-distribution summary, so engine tests
        // exercise the audit plane's drift scoring
        let ts = a.train_stats.as_ref().expect("fixture train_stats");
        assert_eq!(ts.count, 256);
        assert_eq!(ts.mean.len(), 2);
    }

    #[test]
    fn wide_fixture_parses_loads_and_serves_any_dims() {
        let dir = temp_wide_native_artifacts("fixtures_wide", "cnf_wide", 16, 64).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let t = m.task("cnf_wide").unwrap();
        assert_eq!(t.batch(), 16);
        assert_eq!(t.state_shape, vec![16, 64]);
        assert_eq!(t.variants.len(), 1);
        let v = t.variant("euler_k2").unwrap();
        assert_eq!(v.in_shape, vec![16, 64]);
        let model = crate::nn::CnfModel::load(&m.weights_path(t)).unwrap();
        assert_eq!(model.field.state_dim(), 64);
    }

    #[test]
    fn heavy_fixture_parses_loads_and_is_deterministic() {
        let dir = temp_heavy_native_artifacts("fixtures_heavy", "cnf_heavy", 8).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let t = m.task("cnf_heavy").unwrap();
        assert_eq!(t.batch(), 8);
        assert!(t.variant("dopri5").unwrap().returns_nfe);
        assert!(t.mac_f > 10_000, "heavy field must be expensive: {}", t.mac_f);
        let model = crate::nn::CnfModel::load(&m.weights_path(t)).unwrap();
        assert_eq!(model.field.state_dim(), 2);
        // seeded weights: two independent writes produce identical files
        let dir2 = temp_heavy_native_artifacts("fixtures_heavy", "cnf_heavy", 8).unwrap();
        let w1 = std::fs::read(dir.join("weights/cnf_heavy.json")).unwrap();
        let w2 = std::fs::read(dir2.join("weights/cnf_heavy.json")).unwrap();
        assert_eq!(w1, w2);
    }
}
