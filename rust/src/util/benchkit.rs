//! Micro-benchmark harness (criterion is not available offline).
//!
//! Auto-calibrates iteration counts to a target measurement time, reports
//! mean/median/p95, and renders aligned tables — each paper figure's bench
//! binary prints the same rows/series the paper reports.

use std::time::{Duration, Instant};

use crate::util::stats;

/// One measured quantity.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub std_dev: Duration,
}

impl Measurement {
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }

    pub fn mean_us(&self) -> f64 {
        self.mean.as_secs_f64() * 1e6
    }
}

/// Benchmark runner with a wall-clock budget per measurement.
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    min_iters: u64,
    max_iters: u64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(150),
            measure: Duration::from_millis(600),
            min_iters: 5,
            max_iters: 100_000,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(30),
            measure: Duration::from_millis(150),
            min_iters: 3,
            max_iters: 10_000,
        }
    }

    pub fn with_budget(measure_ms: u64) -> Self {
        Bench {
            measure: Duration::from_millis(measure_ms),
            ..Default::default()
        }
    }

    /// Measure `f`, auto-scaling iteration count. `f` must do one unit of
    /// work per call; keep any setup outside.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        // warmup + rate estimation
        let wstart = Instant::now();
        let mut warm_iters = 0u64;
        while wstart.elapsed() < self.warmup || warm_iters < 2 {
            f();
            warm_iters += 1;
            if warm_iters >= self.max_iters {
                break;
            }
        }
        let per_iter = wstart.elapsed().as_secs_f64() / warm_iters as f64;
        let target = ((self.measure.as_secs_f64() / per_iter.max(1e-9)) as u64)
            .clamp(self.min_iters, self.max_iters);

        // measurement: batch into ~20 samples for percentile stability
        let samples = 20u64.min(target).max(1);
        let batch = (target / samples).max(1);
        let mut times: Vec<f64> = Vec::with_capacity(samples as usize);
        for _ in 0..samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            times.push(t0.elapsed().as_secs_f64() / batch as f64);
        }
        Measurement {
            name: name.to_string(),
            iters: samples * batch,
            mean: Duration::from_secs_f64(stats::mean(&times)),
            median: Duration::from_secs_f64(stats::percentile(&times, 50.0)),
            p95: Duration::from_secs_f64(stats::percentile(&times, 95.0)),
            std_dev: Duration::from_secs_f64(stats::std_dev(&times)),
        }
    }
}

/// Aligned plain-text table writer for bench reports.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(&format!("{:<width$}", c, width = widths[i]));
            }
            out.push('\n');
        };
        line(&self.header, &widths, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            line(row, &widths, &mut out);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// `fmt` helpers used across bench binaries.
pub fn fmt_ms(d: Duration) -> String {
    let ms = d.as_secs_f64() * 1e3;
    if ms >= 100.0 {
        format!("{ms:.0} ms")
    } else if ms >= 1.0 {
        format!("{ms:.2} ms")
    } else {
        format!("{:.1} µs", ms * 1e3)
    }
}

pub fn fmt_sci(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if (0.001..10_000.0).contains(&x.abs()) {
        format!("{x:.4}")
    } else {
        format!("{x:.2e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::quick();
        let m = b.run("spin", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(m.iters >= 3);
        assert!(m.mean > Duration::ZERO);
        assert!(m.p95 >= m.median || m.p95 > Duration::ZERO);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["method", "NFE", "MAPE"]);
        t.row(&["euler".into(), "2".into(), "0.3322".into()]);
        t.row(&["hyperheun".into(), "2".into(), "0.0423".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("method"));
        assert!(lines[2].starts_with("euler"));
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a"]);
        t.row(&["1".into(), "2".into()]);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_sci(0.0), "0");
        assert!(fmt_ms(Duration::from_micros(12)).contains("µs"));
        assert!(fmt_ms(Duration::from_millis(250)).contains("ms"));
        assert!(fmt_sci(1e-9).contains('e'));
    }
}
