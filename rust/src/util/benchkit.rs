//! Micro-benchmark harness (criterion is not available offline).
//!
//! Auto-calibrates iteration counts to a target measurement time, reports
//! mean/median/p95, and renders aligned tables — each paper figure's bench
//! binary prints the same rows/series the paper reports.
//!
//! Besides the measurement/table machinery, this module owns the **shared
//! bench JSON schema**: every `BENCH_*.json` the repo emits
//! (`BENCH_serving.json`, `BENCH_train.json`, `BENCH_pareto.json`, the fig
//! bench exports) goes through [`bench_doc`] + [`write_bench_json`], so
//! they all carry the same `bench`/`schema`/`stamp` envelope, and
//! [`append_trajectory`] accumulates headline numbers per run into one
//! rolling `BENCH_trajectory.json` — the per-PR bench trajectory.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::util::json::{self, Value};
use crate::util::stats;

/// One measured quantity.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub std_dev: Duration,
}

impl Measurement {
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }

    pub fn mean_us(&self) -> f64 {
        self.mean.as_secs_f64() * 1e6
    }
}

/// Benchmark runner with a wall-clock budget per measurement.
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    min_iters: u64,
    max_iters: u64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(150),
            measure: Duration::from_millis(600),
            min_iters: 5,
            max_iters: 100_000,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(30),
            measure: Duration::from_millis(150),
            min_iters: 3,
            max_iters: 10_000,
        }
    }

    pub fn with_budget(measure_ms: u64) -> Self {
        Bench {
            measure: Duration::from_millis(measure_ms),
            ..Default::default()
        }
    }

    /// Measure `f`, auto-scaling iteration count. `f` must do one unit of
    /// work per call; keep any setup outside.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        // warmup + rate estimation
        let wstart = Instant::now();
        let mut warm_iters = 0u64;
        while wstart.elapsed() < self.warmup || warm_iters < 2 {
            f();
            warm_iters += 1;
            if warm_iters >= self.max_iters {
                break;
            }
        }
        let per_iter = wstart.elapsed().as_secs_f64() / warm_iters as f64;
        let target = ((self.measure.as_secs_f64() / per_iter.max(1e-9)) as u64)
            .clamp(self.min_iters, self.max_iters);

        // measurement: batch into ~20 samples for percentile stability
        let samples = 20u64.min(target).max(1);
        let batch = (target / samples).max(1);
        let mut times: Vec<f64> = Vec::with_capacity(samples as usize);
        for _ in 0..samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            times.push(t0.elapsed().as_secs_f64() / batch as f64);
        }
        Measurement {
            name: name.to_string(),
            iters: samples * batch,
            mean: Duration::from_secs_f64(stats::mean(&times)),
            median: Duration::from_secs_f64(stats::percentile(&times, 50.0)),
            p95: Duration::from_secs_f64(stats::percentile(&times, 95.0)),
            std_dev: Duration::from_secs_f64(stats::std_dev(&times)),
        }
    }
}

/// Aligned plain-text table writer for bench reports.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(&format!("{:<width$}", c, width = widths[i]));
            }
            out.push('\n');
        };
        line(&self.header, &widths, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            line(row, &widths, &mut out);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

// ---------------------------------------------------------------------------
// Shared bench JSON schema + the bench trajectory
// ---------------------------------------------------------------------------

/// Version tag stamped into every bench JSON document.
pub const BENCH_SCHEMA: &str = "bench.v1";

/// Wrap bench-specific `fields` in the shared envelope: `bench` (the
/// emitting binary's name), `schema` ([`BENCH_SCHEMA`]), and `stamp` (the
/// `BENCH_STAMP` env var when set — CI stamps the commit here — else
/// `"dev"`). Callers add only their own payload keys.
pub fn bench_doc(bench: &str, fields: Vec<(&str, Value)>) -> Value {
    let stamp = std::env::var("BENCH_STAMP").unwrap_or_else(|_| "dev".into());
    let mut all = vec![
        ("bench", json::s(bench)),
        ("schema", json::s(BENCH_SCHEMA)),
        ("stamp", json::s(&stamp)),
    ];
    all.extend(fields);
    json::obj(all)
}

/// Write a bench document to `default_path`. `BENCH_JSON` overrides the
/// full path — meant for single-bench invocations (the convention the
/// serving/train benches established). `BENCH_DIR` instead redirects the
/// *directory* while keeping each bench's own file name, so a multi-bench
/// sweep (`cargo bench`) cannot collapse several documents onto one path,
/// last writer winning. Returns the path written.
pub fn write_bench_json(default_path: &str, doc: &Value) -> crate::Result<PathBuf> {
    let path = match std::env::var("BENCH_JSON") {
        Ok(p) => PathBuf::from(p),
        Err(_) => match std::env::var("BENCH_DIR") {
            Ok(d) => PathBuf::from(d).join(default_path),
            Err(_) => PathBuf::from(default_path),
        },
    };
    std::fs::write(&path, json::to_string(doc))?;
    Ok(path)
}

/// Append one entry to the rolling bench trajectory
/// (`BENCH_trajectory.json`, overridable with `BENCH_TRAJECTORY`). The
/// file holds a JSON array ordered oldest → newest so successive PRs'
/// headline numbers can be diffed in one place. A missing file starts a
/// new trajectory; a present-but-unparsable file is an error — appending
/// over it would destroy the recorded history.
pub fn append_trajectory(entry: Value) -> crate::Result<PathBuf> {
    let path = PathBuf::from(
        std::env::var("BENCH_TRAJECTORY").unwrap_or_else(|_| "BENCH_trajectory.json".into()),
    );
    append_trajectory_at(&path, entry)?;
    Ok(path)
}

/// [`append_trajectory`] to an explicit path (no env involved) — also what
/// tests use, so they never race on the process-global env var.
pub fn append_trajectory_at(path: &std::path::Path, entry: Value) -> crate::Result<()> {
    let mut entries: Vec<Value> = if path.exists() {
        json::parse_file(&path)?
            .as_arr()
            .ok_or_else(|| {
                crate::Error::Json(format!(
                    "{} is not a JSON array; refusing to append over it",
                    path.display()
                ))
            })?
            .to_vec()
    } else {
        Vec::new()
    };
    entries.push(entry);
    std::fs::write(path, json::to_string(&Value::Arr(entries)))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Trajectory gate: diff the newest entry of each bench stream against the
// previous one and flag regressions (CI restores the prior run's
// trajectory file, so the diff is commit-over-commit)
// ---------------------------------------------------------------------------

/// Outcome of gating a trajectory: human-readable check lines plus the
/// regressions found (empty = gate passes).
#[derive(Debug, Default)]
pub struct GateReport {
    pub checks: Vec<String>,
    pub regressions: Vec<String>,
}

impl GateReport {
    pub fn passed(&self) -> bool {
        self.regressions.is_empty()
    }
}

fn entry_f64(v: &Value, key: &str) -> Option<f64> {
    v.get(key).and_then(Value::as_f64)
}

/// Allowed audit-on / audit-off serving-p50 ratio within one bench entry
/// (the tentpole's "auditing is effectively free" acceptance bound).
pub const AUDIT_OVERHEAD_SLACK: f64 = 1.10;

/// Diff the last two entries of every bench stream in a
/// `BENCH_trajectory.json` array (ordered oldest → newest). Gated today:
///
/// * `serving_throughput.mixed_p50_ms` — newest must stay within
///   `p50_slack ×` of the previous run (wall-clock on shared runners is
///   noisy; pick a generous slack);
/// * `hyperbench_pareto.tasks[*].hyper_on_nfe_front` — NFE-front
///   membership must never flip true → false;
/// * `hyperbench_pareto.tasks[*].serve_speedup_vs_dopri5` — a speedup
///   that was > 1 must not drop to ≤ 1 (the end-to-end win vanishing);
/// * `serving_throughput.overload_goodput` — within the newest entry,
///   shedding-on goodput must strictly exceed the shedding-off baseline
///   (`overload_goodput_baseline`), and run over run the goodput must not
///   drop by more than `goodput_drop` (absolute, goodput is in [0, 1]);
/// * `serving_throughput.stage_*_p50_ms` — within the newest entry, the
///   engine-side queue + pad + exec stage p50s must sum to at most twice
///   the engine-side total p50 (disjoint sub-spans of the same requests;
///   the slack covers log-bucket midpoint error) — a broken span clock
///   cannot ship a plausible-looking breakdown;
/// * `serving_throughput.pipelined_big_v2_p50_ms` — within the newest
///   entry, end-to-end pipelined p50 on the wide workload must be strictly
///   faster over the v2 binary frames than over v1 JSON lines
///   (`pipelined_big_v1_p50_ms`) — the zero-copy wire path must stay a win;
/// * `serving_throughput.audit_on_p50_ms` — within the newest entry,
///   serving p50 with full shadow-audit sampling must stay within
///   [`AUDIT_OVERHEAD_SLACK`] × the audit-off p50 on the same workload
///   (`audit_off_p50_ms`) — the audit plane must never tax dispatch;
/// * `codecbench.v2_decode_mbps` — within the newest entry, v2 request
///   decode throughput must strictly exceed `v1_decode_mbps`.
///
/// Streams with fewer than two entries just record a baseline note (the
/// within-entry checks still apply to a first entry).
pub fn trajectory_gate(entries: &[Value], p50_slack: f64, goodput_drop: f64) -> GateReport {
    let mut report = GateReport::default();
    // group by bench stream, preserving order
    let mut streams: Vec<(String, Vec<&Value>)> = Vec::new();
    for e in entries {
        let name = e
            .get("bench")
            .and_then(Value::as_str)
            .unwrap_or("<unnamed>")
            .to_string();
        match streams.iter_mut().find(|(n, _)| *n == name) {
            Some((_, v)) => v.push(e),
            None => streams.push((name, vec![e])),
        }
    }
    for (name, stream) in &streams {
        // within-entry overload invariant: shedding must *help* — applies
        // to the newest entry even when there is nothing yet to diff
        let latest = *stream.last().expect("streams hold at least one entry");
        if name.as_str() == "serving_throughput" {
            if let (Some(on), Some(off)) = (
                entry_f64(latest, "overload_goodput"),
                entry_f64(latest, "overload_goodput_baseline"),
            ) {
                let line = format!(
                    "[{name}] overload goodput: shed-on {on:.3} vs shed-off {off:.3}"
                );
                if on <= off {
                    report.regressions.push(format!(
                        "{line} — REGRESSED (shedding must strictly beat the baseline)"
                    ));
                } else {
                    report.checks.push(line);
                }
            }
            // within-entry span-accounting invariant: the engine-side
            // stage p50s (queue + pad + exec) cannot meaningfully exceed
            // the engine-side total p50 — stages are disjoint sub-spans of
            // the same requests. The ×2 slack absorbs the pow2-bucket
            // histograms' geometric-midpoint error (each stage p50 can
            // read up to √2 high while the total reads up to √2 low).
            if let (Some(q), Some(pd), Some(ex), Some(tot)) = (
                entry_f64(latest, "stage_queue_p50_ms"),
                entry_f64(latest, "stage_pad_p50_ms"),
                entry_f64(latest, "stage_exec_p50_ms"),
                entry_f64(latest, "stage_total_p50_ms"),
            ) {
                let sum = q + pd + ex;
                let line = format!(
                    "[{name}] stage p50 sum (queue {q:.3} + pad {pd:.3} + exec \
                     {ex:.3} = {sum:.3} ms) vs total p50 {tot:.3} ms"
                );
                if sum > tot * 2.0 {
                    report.regressions.push(format!(
                        "{line} — REGRESSED (stage spans account for more than \
                         the whole request; the span clock is broken)"
                    ));
                } else {
                    report.checks.push(line);
                }
            }
            // within-entry cluster-resilience invariant: with a node
            // killed mid-run, the router's failover retries must strictly
            // beat running with the retry budget off — otherwise the
            // failover path is dead weight (or worse, slowing recovery)
            if let (Some(on), Some(off)) = (
                entry_f64(latest, "cluster_kill_goodput_retries_on"),
                entry_f64(latest, "cluster_kill_goodput_retries_off"),
            ) {
                let line = format!(
                    "[{name}] cluster kill goodput: retries-on {on:.3} vs \
                     retries-off {off:.3}"
                );
                if on <= off {
                    report.regressions.push(format!(
                        "{line} — REGRESSED (failover retries must strictly beat \
                         no retries when a node dies mid-run)"
                    ));
                } else {
                    report.checks.push(line);
                }
            }
            // within-entry wire invariant: the v2 frames must beat the v1
            // lines end to end on the wide pipelined workload
            if let (Some(v1), Some(v2)) = (
                entry_f64(latest, "pipelined_big_v1_p50_ms"),
                entry_f64(latest, "pipelined_big_v2_p50_ms"),
            ) {
                let line = format!(
                    "[{name}] wide pipelined p50: v1 {v1:.3} ms vs v2 {v2:.3} ms"
                );
                if v2 >= v1 {
                    report.regressions.push(format!(
                        "{line} — REGRESSED (v2 frames must strictly beat v1 lines)"
                    ));
                } else {
                    report.checks.push(line);
                }
            }
            // within-entry audit-overhead invariant: shadow auditing at
            // full sampling must stay effectively free on the serve path
            // (the decision is lock-free, the copy bounded, the re-solve
            // off-thread) — audit-on p50 may cost at most 10% over
            // audit-off on the same workload
            if let (Some(off), Some(on)) = (
                entry_f64(latest, "audit_off_p50_ms"),
                entry_f64(latest, "audit_on_p50_ms"),
            ) {
                let line = format!(
                    "[{name}] audit A/B p50: off {off:.3} ms vs on {on:.3} ms \
                     (allowed ≤ {:.3})",
                    off * AUDIT_OVERHEAD_SLACK
                );
                if on > off * AUDIT_OVERHEAD_SLACK {
                    report.regressions.push(format!(
                        "{line} — REGRESSED (auditing must not slow the serve path \
                         by more than 10%)"
                    ));
                } else {
                    report.checks.push(line);
                }
            }
        }
        if name.as_str() == "codecbench" {
            // within-entry codec invariant: binary row blocks must decode
            // strictly faster than the per-float JSON text path
            if let (Some(v1), Some(v2)) = (
                entry_f64(latest, "v1_decode_mbps"),
                entry_f64(latest, "v2_decode_mbps"),
            ) {
                let line =
                    format!("[{name}] request decode: v1 {v1:.1} MB/s vs v2 {v2:.1} MB/s");
                if v2 <= v1 {
                    report.regressions.push(format!(
                        "{line} — REGRESSED (v2 decode must strictly beat v1)"
                    ));
                } else {
                    report.checks.push(line);
                }
            }
        }
        if stream.len() < 2 {
            report
                .checks
                .push(format!("[{name}] first entry recorded; nothing to diff"));
            continue;
        }
        let prev = stream[stream.len() - 2];
        let newest = stream[stream.len() - 1];
        if name.as_str() == "serving_throughput" {
            match (entry_f64(prev, "mixed_p50_ms"), entry_f64(newest, "mixed_p50_ms")) {
                (Some(p), Some(n)) if p > 0.0 => {
                    let line = format!(
                        "[{name}] mixed-budget serving p50: {p:.3} → {n:.3} ms \
                         (allowed ≤ {:.3})",
                        p * p50_slack
                    );
                    if n > p * p50_slack {
                        report.regressions.push(format!("{line} — REGRESSED"));
                    } else {
                        report.checks.push(line);
                    }
                }
                _ => report
                    .checks
                    .push(format!("[{name}] no mixed_p50_ms pair to diff")),
            }
            match (
                entry_f64(prev, "overload_goodput"),
                entry_f64(newest, "overload_goodput"),
            ) {
                (Some(p), Some(n)) => {
                    let floor = p - goodput_drop;
                    let line = format!(
                        "[{name}] overload goodput under shedding: {p:.3} → {n:.3} \
                         (allowed ≥ {floor:.3})"
                    );
                    if n < floor {
                        report.regressions.push(format!("{line} — REGRESSED"));
                    } else {
                        report.checks.push(line);
                    }
                }
                _ => report
                    .checks
                    .push(format!("[{name}] no overload_goodput pair to diff")),
            }
        }
        if name.as_str() == "hyperbench_pareto" {
            let tasks_of = |v: &Value| -> Vec<Value> {
                v.get("tasks")
                    .and_then(Value::as_arr)
                    .map(|a| a.to_vec())
                    .unwrap_or_default()
            };
            for nt in tasks_of(newest) {
                let Some(task) = nt.get("task").and_then(Value::as_str).map(String::from)
                else {
                    continue;
                };
                let pt = tasks_of(prev)
                    .into_iter()
                    .find(|p| p.get("task").and_then(Value::as_str) == Some(task.as_str()));
                let Some(pt) = pt else { continue };
                let front = |v: &Value| v.get("hyper_on_nfe_front").and_then(Value::as_bool);
                if let (Some(was), Some(is)) = (front(&pt), front(&nt)) {
                    let line =
                        format!("[{name}/{task}] hyper on NFE front: {was} → {is}");
                    if was && !is {
                        report.regressions.push(format!("{line} — REGRESSED"));
                    } else {
                        report.checks.push(line);
                    }
                }
                let speed = |v: &Value| entry_f64(v, "serve_speedup_vs_dopri5");
                if let (Some(was), Some(is)) = (speed(&pt), speed(&nt)) {
                    let line = format!(
                        "[{name}/{task}] serve speedup vs tight dopri5: \
                         {was:.2}× → {is:.2}×"
                    );
                    if was > 1.0 && is <= 1.0 {
                        report.regressions.push(format!("{line} — REGRESSED"));
                    } else {
                        report.checks.push(line);
                    }
                }
            }
        }
    }
    report
}

/// `fmt` helpers used across bench binaries.
pub fn fmt_ms(d: Duration) -> String {
    let ms = d.as_secs_f64() * 1e3;
    if ms >= 100.0 {
        format!("{ms:.0} ms")
    } else if ms >= 1.0 {
        format!("{ms:.2} ms")
    } else {
        format!("{:.1} µs", ms * 1e3)
    }
}

pub fn fmt_sci(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if (0.001..10_000.0).contains(&x.abs()) {
        format!("{x:.4}")
    } else {
        format!("{x:.2e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::quick();
        let m = b.run("spin", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(m.iters >= 3);
        assert!(m.mean > Duration::ZERO);
        assert!(m.p95 >= m.median || m.p95 > Duration::ZERO);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["method", "NFE", "MAPE"]);
        t.row(&["euler".into(), "2".into(), "0.3322".into()]);
        t.row(&["hyperheun".into(), "2".into(), "0.0423".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("method"));
        assert!(lines[2].starts_with("euler"));
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a"]);
        t.row(&["1".into(), "2".into()]);
    }

    #[test]
    fn bench_doc_has_envelope() {
        let doc = bench_doc("unit_bench", vec![("answer", json::num(42.0))]);
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("unit_bench"));
        assert_eq!(doc.get("schema").unwrap().as_str(), Some(BENCH_SCHEMA));
        assert!(doc.get("stamp").unwrap().as_str().is_some());
        assert_eq!(doc.get("answer").unwrap().as_f64(), Some(42.0));
        // and it round-trips through the JSON layer
        let back = json::parse(&json::to_string(&doc)).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn trajectory_appends_and_rejects_corrupt() {
        // exercise the append logic on an explicit temp path — no
        // process-global env mutation, so concurrent tests cannot race
        let dir = std::env::temp_dir();
        let path = dir.join(format!("hsolve_traj_{}.json", std::process::id()));
        std::fs::remove_file(&path).ok();
        append_trajectory_at(&path, bench_doc("a", vec![])).unwrap();
        append_trajectory_at(&path, bench_doc("b", vec![])).unwrap();
        let v = json::parse_file(&path).unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("bench").unwrap().as_str(), Some("a"));
        assert_eq!(arr[1].get("bench").unwrap().as_str(), Some("b"));
        // corrupt (non-array) file: refuse, and leave the file untouched
        std::fs::write(&path, "{\"not\": \"an array\"}").unwrap();
        assert!(append_trajectory_at(&path, bench_doc("c", vec![])).is_err());
        assert!(json::parse_file(&path).unwrap().as_obj().is_some());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn trajectory_gate_diffs_last_two_per_stream() {
        let serving = |p50: f64| {
            json::obj(vec![
                ("bench", json::s("serving_throughput")),
                ("mixed_p50_ms", json::num(p50)),
            ])
        };
        let pareto = |front: bool, speedup: f64| {
            json::obj(vec![
                ("bench", json::s("hyperbench_pareto")),
                (
                    "tasks",
                    Value::Arr(vec![json::obj(vec![
                        ("task", json::s("vdp")),
                        ("hyper_on_nfe_front", Value::Bool(front)),
                        ("serve_speedup_vs_dopri5", json::num(speedup)),
                    ])]),
                ),
            ])
        };
        // healthy: p50 within slack, front stays, speedup stays > 1
        let entries = vec![serving(2.0), pareto(true, 5.0), serving(2.2), pareto(true, 4.0)];
        let r = trajectory_gate(&entries, 1.5, 0.15);
        assert!(r.passed(), "{:?}", r.regressions);
        assert!(r.checks.iter().any(|c| c.contains("serving p50")));

        // p50 blows the slack → regression
        let entries = vec![serving(2.0), serving(4.0)];
        let r = trajectory_gate(&entries, 1.5, 0.15);
        assert!(!r.passed());
        assert!(r.regressions[0].contains("REGRESSED"), "{:?}", r.regressions);

        // front membership flipping off → regression, even with p50 fine
        let entries = vec![pareto(true, 5.0), pareto(false, 5.0)];
        assert!(!trajectory_gate(&entries, 1.5, 0.15).passed());
        // speedup collapsing through 1.0 → regression
        let entries = vec![pareto(true, 5.0), pareto(true, 0.8)];
        assert!(!trajectory_gate(&entries, 1.5, 0.15).passed());
        // only the LAST TWO entries of a stream are compared: an ancient
        // regression two runs back does not keep failing the gate once a
        // healthy pair follows (false→true front is a recovery, and a
        // speedup that was ≤ 1 may grow freely)
        let entries = vec![pareto(true, 5.0), pareto(false, 0.5), pareto(true, 3.0)];
        assert!(trajectory_gate(&entries, 1.5, 0.15).passed());

        // single entries per stream: baseline only, passes
        let entries = vec![serving(2.0), pareto(true, 5.0)];
        let r = trajectory_gate(&entries, 1.5, 0.15);
        assert!(r.passed());
        assert!(r.checks.iter().all(|c| c.contains("nothing to diff")));
    }

    #[test]
    fn trajectory_gate_checks_overload_goodput() {
        let overload = |on: f64, off: f64| {
            json::obj(vec![
                ("bench", json::s("serving_throughput")),
                ("mixed_p50_ms", json::num(2.0)),
                ("overload_goodput", json::num(on)),
                ("overload_goodput_baseline", json::num(off)),
            ])
        };
        // healthy: shed-on beats shed-off, and run-over-run drop is small
        let entries = vec![overload(0.40, 0.10), overload(0.35, 0.12)];
        let r = trajectory_gate(&entries, 1.5, 0.15);
        assert!(r.passed(), "{:?}", r.regressions);
        assert!(r.checks.iter().any(|c| c.contains("overload goodput")));

        // within-entry: shedding-on not strictly beating shed-off fails,
        // even on a first entry with nothing to diff against
        let entries = vec![overload(0.10, 0.10)];
        let r = trajectory_gate(&entries, 1.5, 0.15);
        assert!(!r.passed());
        assert!(r.regressions[0].contains("strictly beat"), "{:?}", r.regressions);

        // run-over-run: goodput collapsing past the allowed drop fails
        let entries = vec![overload(0.60, 0.10), overload(0.30, 0.10)];
        let r = trajectory_gate(&entries, 1.5, 0.15);
        assert!(!r.passed());
        assert!(
            r.regressions.iter().any(|c| c.contains("overload goodput")),
            "{:?}",
            r.regressions
        );

        // entries without overload fields gate nothing new
        let plain = json::obj(vec![
            ("bench", json::s("serving_throughput")),
            ("mixed_p50_ms", json::num(2.0)),
        ]);
        let r = trajectory_gate(&[plain.clone(), plain], 1.5, 0.15);
        assert!(r.passed(), "{:?}", r.regressions);
    }

    #[test]
    fn trajectory_gate_checks_v2_wire_wins() {
        // codecbench: v2 decode throughput must strictly beat v1
        let codec = |v1: f64, v2: f64| {
            json::obj(vec![
                ("bench", json::s("codecbench")),
                ("v1_decode_mbps", json::num(v1)),
                ("v2_decode_mbps", json::num(v2)),
            ])
        };
        let r = trajectory_gate(&[codec(120.0, 900.0)], 1.5, 0.15);
        assert!(r.passed(), "{:?}", r.regressions);
        assert!(r.checks.iter().any(|c| c.contains("request decode")));
        // applies within a FIRST entry — no prior run needed to fail it
        let r = trajectory_gate(&[codec(120.0, 120.0)], 1.5, 0.15);
        assert!(!r.passed());
        assert!(
            r.regressions[0].contains("v2 decode must strictly beat v1"),
            "{:?}",
            r.regressions
        );

        // serving: wide pipelined p50 over v2 frames must beat v1 lines
        let serving = |v1: f64, v2: f64| {
            json::obj(vec![
                ("bench", json::s("serving_throughput")),
                ("pipelined_big_v1_p50_ms", json::num(v1)),
                ("pipelined_big_v2_p50_ms", json::num(v2)),
            ])
        };
        let r = trajectory_gate(&[serving(8.0, 3.0)], 1.5, 0.15);
        assert!(r.passed(), "{:?}", r.regressions);
        assert!(r.checks.iter().any(|c| c.contains("wide pipelined p50")));
        let r = trajectory_gate(&[serving(3.0, 3.0)], 1.5, 0.15);
        assert!(!r.passed());
        assert!(
            r.regressions[0].contains("v2 frames must strictly beat v1 lines"),
            "{:?}",
            r.regressions
        );

        // entries without the fields gate nothing new
        let plain = json::obj(vec![("bench", json::s("codecbench"))]);
        assert!(trajectory_gate(&[plain], 1.5, 0.15).passed());
    }

    #[test]
    fn trajectory_gate_checks_cluster_kill_goodput() {
        let cluster = |on: f64, off: f64| {
            json::obj(vec![
                ("bench", json::s("serving_throughput")),
                ("cluster_kill_goodput_retries_on", json::num(on)),
                ("cluster_kill_goodput_retries_off", json::num(off)),
            ])
        };
        // healthy: failover recovers work that retries-off loses
        let r = trajectory_gate(&[cluster(0.95, 0.70)], 1.5, 0.15);
        assert!(r.passed(), "{:?}", r.regressions);
        assert!(r.checks.iter().any(|c| c.contains("cluster kill goodput")));
        // retries not strictly beating retries-off fails, even on a first
        // entry with nothing to diff against
        let r = trajectory_gate(&[cluster(0.70, 0.70)], 1.5, 0.15);
        assert!(!r.passed());
        assert!(
            r.regressions[0].contains("failover retries must strictly beat"),
            "{:?}",
            r.regressions
        );
        // only the newest entry is gated; entries without the pair gate
        // nothing new
        let plain = json::obj(vec![("bench", json::s("serving_throughput"))]);
        let r = trajectory_gate(&[cluster(0.1, 0.9), plain], 1.5, 0.15);
        assert!(r.passed(), "{:?}", r.regressions);
    }

    #[test]
    fn trajectory_gate_checks_audit_overhead() {
        let audited = |off: f64, on: f64| {
            json::obj(vec![
                ("bench", json::s("serving_throughput")),
                ("audit_off_p50_ms", json::num(off)),
                ("audit_on_p50_ms", json::num(on)),
            ])
        };
        // healthy: auditing costs under the 10% bound
        let r = trajectory_gate(&[audited(2.0, 2.1)], 1.5, 0.15);
        assert!(r.passed(), "{:?}", r.regressions);
        assert!(r.checks.iter().any(|c| c.contains("audit A/B p50")));
        // auditing taxing dispatch past the bound fails, even on a first
        // entry with nothing to diff against
        let r = trajectory_gate(&[audited(2.0, 2.5)], 1.5, 0.15);
        assert!(!r.passed());
        assert!(
            r.regressions[0].contains("auditing must not slow"),
            "{:?}",
            r.regressions
        );
        // entries without the A/B fields gate nothing new
        let plain = json::obj(vec![("bench", json::s("serving_throughput"))]);
        assert!(trajectory_gate(&[plain], 1.5, 0.15).passed());
    }

    #[test]
    fn trajectory_gate_checks_stage_accounting() {
        let staged = |q: f64, pd: f64, ex: f64, tot: f64| {
            json::obj(vec![
                ("bench", json::s("serving_throughput")),
                ("stage_queue_p50_ms", json::num(q)),
                ("stage_pad_p50_ms", json::num(pd)),
                ("stage_exec_p50_ms", json::num(ex)),
                ("stage_total_p50_ms", json::num(tot)),
            ])
        };
        // healthy: stages sum under the total (with bucket slack)
        let r = trajectory_gate(&[staged(0.5, 0.1, 1.0, 2.0)], 1.5, 0.15);
        assert!(r.passed(), "{:?}", r.regressions);
        assert!(r.checks.iter().any(|c| c.contains("stage p50 sum")));
        // broken clock: stages account for far more than the whole request
        let r = trajectory_gate(&[staged(3.0, 1.0, 3.0, 1.0)], 1.5, 0.15);
        assert!(!r.passed());
        assert!(
            r.regressions[0].contains("span clock"),
            "{:?}",
            r.regressions
        );
        // applies to the NEWEST entry only; entries without the fields
        // gate nothing new
        let plain = json::obj(vec![("bench", json::s("serving_throughput"))]);
        let r = trajectory_gate(&[staged(9.0, 9.0, 9.0, 1.0), plain], 1.5, 0.15);
        assert!(r.passed(), "{:?}", r.regressions);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_sci(0.0), "0");
        assert!(fmt_ms(Duration::from_micros(12)).contains("µs"));
        assert!(fmt_ms(Duration::from_millis(250)).contains("ms"));
        assert!(fmt_sci(1e-9).contains('e'));
    }
}
