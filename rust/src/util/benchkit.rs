//! Micro-benchmark harness (criterion is not available offline).
//!
//! Auto-calibrates iteration counts to a target measurement time, reports
//! mean/median/p95, and renders aligned tables — each paper figure's bench
//! binary prints the same rows/series the paper reports.
//!
//! Besides the measurement/table machinery, this module owns the **shared
//! bench JSON schema**: every `BENCH_*.json` the repo emits
//! (`BENCH_serving.json`, `BENCH_train.json`, `BENCH_pareto.json`, the fig
//! bench exports) goes through [`bench_doc`] + [`write_bench_json`], so
//! they all carry the same `bench`/`schema`/`stamp` envelope, and
//! [`append_trajectory`] accumulates headline numbers per run into one
//! rolling `BENCH_trajectory.json` — the per-PR bench trajectory.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::util::json::{self, Value};
use crate::util::stats;

/// One measured quantity.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub median: Duration,
    pub p95: Duration,
    pub std_dev: Duration,
}

impl Measurement {
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }

    pub fn mean_us(&self) -> f64 {
        self.mean.as_secs_f64() * 1e6
    }
}

/// Benchmark runner with a wall-clock budget per measurement.
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    min_iters: u64,
    max_iters: u64,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup: Duration::from_millis(150),
            measure: Duration::from_millis(600),
            min_iters: 5,
            max_iters: 100_000,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            warmup: Duration::from_millis(30),
            measure: Duration::from_millis(150),
            min_iters: 3,
            max_iters: 10_000,
        }
    }

    pub fn with_budget(measure_ms: u64) -> Self {
        Bench {
            measure: Duration::from_millis(measure_ms),
            ..Default::default()
        }
    }

    /// Measure `f`, auto-scaling iteration count. `f` must do one unit of
    /// work per call; keep any setup outside.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        // warmup + rate estimation
        let wstart = Instant::now();
        let mut warm_iters = 0u64;
        while wstart.elapsed() < self.warmup || warm_iters < 2 {
            f();
            warm_iters += 1;
            if warm_iters >= self.max_iters {
                break;
            }
        }
        let per_iter = wstart.elapsed().as_secs_f64() / warm_iters as f64;
        let target = ((self.measure.as_secs_f64() / per_iter.max(1e-9)) as u64)
            .clamp(self.min_iters, self.max_iters);

        // measurement: batch into ~20 samples for percentile stability
        let samples = 20u64.min(target).max(1);
        let batch = (target / samples).max(1);
        let mut times: Vec<f64> = Vec::with_capacity(samples as usize);
        for _ in 0..samples {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            times.push(t0.elapsed().as_secs_f64() / batch as f64);
        }
        Measurement {
            name: name.to_string(),
            iters: samples * batch,
            mean: Duration::from_secs_f64(stats::mean(&times)),
            median: Duration::from_secs_f64(stats::percentile(&times, 50.0)),
            p95: Duration::from_secs_f64(stats::percentile(&times, 95.0)),
            std_dev: Duration::from_secs_f64(stats::std_dev(&times)),
        }
    }
}

/// Aligned plain-text table writer for bench reports.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push_str("  ");
                }
                out.push_str(&format!("{:<width$}", c, width = widths[i]));
            }
            out.push('\n');
        };
        line(&self.header, &widths, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            line(row, &widths, &mut out);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

// ---------------------------------------------------------------------------
// Shared bench JSON schema + the bench trajectory
// ---------------------------------------------------------------------------

/// Version tag stamped into every bench JSON document.
pub const BENCH_SCHEMA: &str = "bench.v1";

/// Wrap bench-specific `fields` in the shared envelope: `bench` (the
/// emitting binary's name), `schema` ([`BENCH_SCHEMA`]), and `stamp` (the
/// `BENCH_STAMP` env var when set — CI stamps the commit here — else
/// `"dev"`). Callers add only their own payload keys.
pub fn bench_doc(bench: &str, fields: Vec<(&str, Value)>) -> Value {
    let stamp = std::env::var("BENCH_STAMP").unwrap_or_else(|_| "dev".into());
    let mut all = vec![
        ("bench", json::s(bench)),
        ("schema", json::s(BENCH_SCHEMA)),
        ("stamp", json::s(&stamp)),
    ];
    all.extend(fields);
    json::obj(all)
}

/// Write a bench document to `default_path`. `BENCH_JSON` overrides the
/// full path — meant for single-bench invocations (the convention the
/// serving/train benches established). `BENCH_DIR` instead redirects the
/// *directory* while keeping each bench's own file name, so a multi-bench
/// sweep (`cargo bench`) cannot collapse several documents onto one path,
/// last writer winning. Returns the path written.
pub fn write_bench_json(default_path: &str, doc: &Value) -> crate::Result<PathBuf> {
    let path = match std::env::var("BENCH_JSON") {
        Ok(p) => PathBuf::from(p),
        Err(_) => match std::env::var("BENCH_DIR") {
            Ok(d) => PathBuf::from(d).join(default_path),
            Err(_) => PathBuf::from(default_path),
        },
    };
    std::fs::write(&path, json::to_string(doc))?;
    Ok(path)
}

/// Append one entry to the rolling bench trajectory
/// (`BENCH_trajectory.json`, overridable with `BENCH_TRAJECTORY`). The
/// file holds a JSON array ordered oldest → newest so successive PRs'
/// headline numbers can be diffed in one place. A missing file starts a
/// new trajectory; a present-but-unparsable file is an error — appending
/// over it would destroy the recorded history.
pub fn append_trajectory(entry: Value) -> crate::Result<PathBuf> {
    let path = PathBuf::from(
        std::env::var("BENCH_TRAJECTORY").unwrap_or_else(|_| "BENCH_trajectory.json".into()),
    );
    append_trajectory_at(&path, entry)?;
    Ok(path)
}

/// [`append_trajectory`] to an explicit path (no env involved) — also what
/// tests use, so they never race on the process-global env var.
pub fn append_trajectory_at(path: &std::path::Path, entry: Value) -> crate::Result<()> {
    let mut entries: Vec<Value> = if path.exists() {
        json::parse_file(&path)?
            .as_arr()
            .ok_or_else(|| {
                crate::Error::Json(format!(
                    "{} is not a JSON array; refusing to append over it",
                    path.display()
                ))
            })?
            .to_vec()
    } else {
        Vec::new()
    };
    entries.push(entry);
    std::fs::write(path, json::to_string(&Value::Arr(entries)))?;
    Ok(())
}

/// `fmt` helpers used across bench binaries.
pub fn fmt_ms(d: Duration) -> String {
    let ms = d.as_secs_f64() * 1e3;
    if ms >= 100.0 {
        format!("{ms:.0} ms")
    } else if ms >= 1.0 {
        format!("{ms:.2} ms")
    } else {
        format!("{:.1} µs", ms * 1e3)
    }
}

pub fn fmt_sci(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if (0.001..10_000.0).contains(&x.abs()) {
        format!("{x:.4}")
    } else {
        format!("{x:.2e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::quick();
        let m = b.run("spin", || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(m.iters >= 3);
        assert!(m.mean > Duration::ZERO);
        assert!(m.p95 >= m.median || m.p95 > Duration::ZERO);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["method", "NFE", "MAPE"]);
        t.row(&["euler".into(), "2".into(), "0.3322".into()]);
        t.row(&["hyperheun".into(), "2".into(), "0.0423".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("method"));
        assert!(lines[2].starts_with("euler"));
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a"]);
        t.row(&["1".into(), "2".into()]);
    }

    #[test]
    fn bench_doc_has_envelope() {
        let doc = bench_doc("unit_bench", vec![("answer", json::num(42.0))]);
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("unit_bench"));
        assert_eq!(doc.get("schema").unwrap().as_str(), Some(BENCH_SCHEMA));
        assert!(doc.get("stamp").unwrap().as_str().is_some());
        assert_eq!(doc.get("answer").unwrap().as_f64(), Some(42.0));
        // and it round-trips through the JSON layer
        let back = json::parse(&json::to_string(&doc)).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn trajectory_appends_and_rejects_corrupt() {
        // exercise the append logic on an explicit temp path — no
        // process-global env mutation, so concurrent tests cannot race
        let dir = std::env::temp_dir();
        let path = dir.join(format!("hsolve_traj_{}.json", std::process::id()));
        std::fs::remove_file(&path).ok();
        append_trajectory_at(&path, bench_doc("a", vec![])).unwrap();
        append_trajectory_at(&path, bench_doc("b", vec![])).unwrap();
        let v = json::parse_file(&path).unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("bench").unwrap().as_str(), Some("a"));
        assert_eq!(arr[1].get("bench").unwrap().as_str(), Some("b"));
        // corrupt (non-array) file: refuse, and leave the file untouched
        std::fs::write(&path, "{\"not\": \"an array\"}").unwrap();
        assert!(append_trajectory_at(&path, bench_doc("c", vec![])).is_err());
        assert!(json::parse_file(&path).unwrap().as_obj().is_some());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_sci(0.0), "0");
        assert!(fmt_ms(Duration::from_micros(12)).contains("µs"));
        assert!(fmt_ms(Duration::from_millis(250)).contains("ms"));
        assert!(fmt_sci(1e-9).contains('e'));
    }
}
