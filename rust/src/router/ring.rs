//! Consistent-hash placement ring with virtual nodes.
//!
//! Each engine node contributes `vnodes` points to a 64-bit hash ring;
//! a request's key — the hash of its `(task, variant)` — is placed on
//! the first node clockwise from the key. Virtual nodes smooth the
//! per-node share; the hand-rolled FNV-1a hash keeps placement stable
//! across platforms, releases, and std hasher changes (a router restart
//! must not reshuffle the cluster). Node loss is handled by *skipping*
//! dead nodes along the ring rather than rebuilding it, so only keys
//! owned by the lost node move — the consistent-hashing property the
//! retry path relies on.

/// 64-bit FNV-1a. Tiny, dependency-free, and frozen: these constants are
/// part of the cluster's placement contract.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The placement ring: `(point, node)` pairs sorted by point.
pub struct Ring {
    points: Vec<(u64, usize)>,
    nodes: usize,
}

impl Ring {
    /// Build a ring of `nodes` engines with `vnodes` virtual nodes each.
    /// Point labels are `node{i}#vnode{v}`, hashed with [`fnv1a`] — the
    /// ring for a given (nodes, vnodes) is identical everywhere.
    pub fn new(nodes: usize, vnodes: usize) -> Ring {
        let vnodes = vnodes.max(1);
        let mut points = Vec::with_capacity(nodes * vnodes);
        for n in 0..nodes {
            for v in 0..vnodes {
                points.push((fnv1a(format!("node{n}#vnode{v}").as_bytes()), n));
            }
        }
        points.sort_unstable();
        Ring { points, nodes }
    }

    pub fn len(&self) -> usize {
        self.nodes
    }

    pub fn is_empty(&self) -> bool {
        self.nodes == 0
    }

    /// The placement key of a request: `task` and the pinned `variant`
    /// (requests without a pin hash on the task alone). The NUL
    /// separator keeps `("ab", "c")` and `("a", "bc")` distinct.
    pub fn key(task: &str, variant: Option<&str>) -> u64 {
        let mut bytes = Vec::with_capacity(task.len() + 1 + variant.map_or(0, str::len));
        bytes.extend_from_slice(task.as_bytes());
        bytes.push(0);
        if let Some(v) = variant {
            bytes.extend_from_slice(v.as_bytes());
        }
        fnv1a(&bytes)
    }

    /// Every node once, in ring order starting at `key`'s successor
    /// point — position 0 is the primary, the rest is the failover
    /// sequence. Deterministic for a given ring and key.
    pub fn sequence(&self, key: u64) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.nodes);
        if self.points.is_empty() {
            return out;
        }
        let start = self.points.partition_point(|&(p, _)| p < key);
        let mut seen = vec![false; self.nodes];
        for i in 0..self.points.len() {
            let (_, n) = self.points[(start + i) % self.points.len()];
            if !seen[n] {
                seen[n] = true;
                out.push(n);
                if out.len() == self.nodes {
                    break;
                }
            }
        }
        out
    }

    /// The key's primary owner.
    pub fn primary(&self, key: u64) -> Option<usize> {
        self.sequence(key).first().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_is_the_frozen_fnv1a() {
        // reference vectors for the 64-bit FNV-1a everyone implements
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
    }

    #[test]
    fn sequence_is_deterministic_and_covers_every_node() {
        let ring = Ring::new(5, 64);
        for task in ["cnf_a", "cnf_b", "cnf_wide", "x"] {
            let key = Ring::key(task, None);
            let s1 = ring.sequence(key);
            let s2 = ring.sequence(key);
            assert_eq!(s1, s2);
            let mut sorted = s1.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2, 3, 4], "all nodes appear once");
        }
    }

    #[test]
    fn variant_and_task_both_shape_the_key() {
        assert_ne!(Ring::key("cnf_a", None), Ring::key("cnf_b", None));
        assert_ne!(
            Ring::key("cnf_a", Some("euler_k2")),
            Ring::key("cnf_a", Some("heun_k2"))
        );
        // the NUL separator keeps concatenation ambiguity out
        assert_ne!(Ring::key("ab", Some("c")), Ring::key("a", Some("bc")));
    }

    #[test]
    fn virtual_nodes_spread_primaries_across_the_cluster() {
        let ring = Ring::new(4, 64);
        let mut counts = [0usize; 4];
        for i in 0..1000 {
            let key = Ring::key(&format!("task_{i}"), None);
            counts[ring.primary(key).unwrap()] += 1;
        }
        for (n, &c) in counts.iter().enumerate() {
            assert!(
                c >= 50,
                "node {n} owns only {c}/1000 keys — vnode spread broken: {counts:?}"
            );
        }
    }

    #[test]
    fn skipping_a_dead_node_only_moves_its_own_keys() {
        let ring = Ring::new(4, 64);
        for i in 0..200 {
            let key = Ring::key(&format!("task_{i}"), None);
            let seq = ring.sequence(key);
            let dead = 2usize;
            let survivor = seq.iter().copied().find(|&n| n != dead).unwrap();
            if seq[0] != dead {
                // keys not owned by the dead node keep their primary
                assert_eq!(survivor, seq[0]);
            } else {
                // keys owned by the dead node fail over to its ring successor
                assert_eq!(survivor, seq[1]);
            }
        }
    }
}
