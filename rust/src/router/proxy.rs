//! The cluster router: a v0/v1/v2-speaking proxy over N engine nodes.
//!
//! Placement is the consistent-hash ring ([`super::ring`]) keyed on
//! `(task, variant)`; health is the poller-driven eject/readmit machine
//! ([`super::health`]). Each downstream connection gets its own lazy
//! pool of pipelined upstream connections — one per node actually used —
//! and a **router id** per request: the router rewrites request ids on
//! the way up and maps completions (arriving out of order, from
//! different nodes) back to the client's ids and dialect on the way
//! down. v0 lines keep their strict request→reply order by blocking the
//! downstream reader on the proxied reply; v1 lines and v2 frames
//! pipeline freely.
//!
//! Failure handling: `exec_failed` replies and upstream connection
//! resets re-dispatch the request on the next ring node, remembering
//! every node already tried (a node is never retried twice for one
//! request), bounded by [`RouterConfig::retries`] and by the request's
//! own `deadline_us` — a retry never launches past the deadline. When
//! failover is exhausted the client receives the frozen
//! `upstream_unavailable` error code.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering::SeqCst};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::api::v1::{self, ErrorReply, InferReply, InferRequest};
use crate::api::{v2, ApiError, ErrorCode};
use crate::coordinator::server::Client;
use crate::router::health::{self, HealthTracker};
use crate::router::ring::Ring;
use crate::util::json::{self, Value};
use crate::util::merge;
use crate::{log_debug, log_info, Error, Result};

/// Bound on how long a v0 (strict-order) request may hold its reader
/// thread — a backstop well above any sane engine latency; normal
/// failures resolve much earlier via timeouts and the retry budget.
const V0_SYNC_CAP: Duration = Duration::from_secs(60);

/// Router tuning. `Default` gives the test/bench profile; `hyperrouter`
/// exposes every knob as a flag.
#[derive(Clone)]
pub struct RouterConfig {
    /// Engine node addresses (`host:port`); list order defines ring
    /// node indices.
    pub nodes: Vec<String>,
    /// Virtual nodes per engine on the placement ring.
    pub vnodes: usize,
    /// Consecutive failed health polls before a node is ejected.
    pub eject_after: u32,
    /// Health poll cadence.
    pub poll_interval: Duration,
    /// Max re-dispatch attempts after the first (so a request touches at
    /// most `retries + 1` nodes).
    pub retries: usize,
    /// Upstream TCP connect bound.
    pub connect_timeout: Duration,
    /// Read bound for health polls and one-shot forwarded commands
    /// (persistent pipelined upstreams read unbounded — idle is normal).
    pub probe_read_timeout: Duration,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            nodes: Vec::new(),
            vnodes: 64,
            eject_after: 3,
            poll_interval: Duration::from_millis(500),
            retries: 2,
            connect_timeout: Duration::from_secs(1),
            probe_read_timeout: Duration::from_secs(2),
        }
    }
}

/// Everything the accept loop, connection handlers, and poller share.
struct Shared {
    cfg: RouterConfig,
    ring: Ring,
    health: Arc<HealthTracker>,
    stop: AtomicBool,
    /// The bound listen address once serving — lets `cmd: "shutdown"`
    /// (and [`Router::stop`]) wake the blocked accept loop.
    listen_addr: Mutex<Option<SocketAddr>>,
}

/// The router front end. Construction starts the health poller;
/// [`Self::serve`]/[`Self::serve_listener`] run the accept loop.
pub struct Router {
    shared: Arc<Shared>,
}

impl Router {
    pub fn new(cfg: RouterConfig) -> Router {
        assert!(!cfg.nodes.is_empty(), "router needs at least one node");
        let ring = Ring::new(cfg.nodes.len(), cfg.vnodes);
        let health = Arc::new(HealthTracker::new(cfg.nodes.len(), cfg.eject_after));
        let shared = Arc::new(Shared {
            cfg,
            ring,
            health,
            stop: AtomicBool::new(false),
            listen_addr: Mutex::new(None),
        });
        {
            let s = Arc::clone(&shared);
            let p = Arc::clone(&shared);
            // detached: the poller exits on the stop flag, not on join
            let _ = health::spawn_poller(
                Arc::clone(&shared.health),
                shared.cfg.poll_interval,
                move || s.stop.load(SeqCst),
                move |node| probe_node(&p, node),
            );
        }
        Router { shared }
    }

    /// Route on `addr`. Returns `Ok(())` after a graceful
    /// `cmd: "shutdown"` (loopback-gated, like the engine's).
    pub fn serve(&self, addr: &str) -> Result<()> {
        self.serve_listener(TcpListener::bind(addr)?)
    }

    /// [`Self::serve`] on an already-bound listener (tests bind port 0).
    pub fn serve_listener(&self, listener: TcpListener) -> Result<()> {
        log_info!(
            "router listening on {:?} over {} node(s)",
            listener.local_addr(),
            self.shared.cfg.nodes.len()
        );
        *self.shared.listen_addr.lock().unwrap() = listener.local_addr().ok();
        for stream in listener.incoming() {
            if self.shared.stop.load(SeqCst) {
                break;
            }
            let stream = stream?;
            let shared = Arc::clone(&self.shared);
            thread::spawn(move || {
                if let Err(e) = handle_conn(shared, stream) {
                    log_debug!("router connection closed: {e}");
                }
            });
        }
        log_info!("router accept loop exited");
        Ok(())
    }

    /// Stop the poller and, when serving, the accept loop.
    pub fn stop(&self) {
        self.shared.stop.store(true, SeqCst);
        if let Some(addr) = *self.shared.listen_addr.lock().unwrap() {
            let _ = TcpStream::connect_timeout(&addr, Duration::from_millis(250));
        }
    }

    /// The health view the poller maintains (for tests and diagnostics).
    pub fn health(&self) -> &HealthTracker {
        &self.shared.health
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shared.stop.store(true, SeqCst);
    }
}

/// One health probe: fresh timed-out connection, `cmd: "health"`, any
/// `ok: true` counts (the command answers even with auditing disabled).
fn probe_node(shared: &Shared, node: usize) -> bool {
    let addr = &shared.cfg.nodes[node];
    let mut c = match Client::connect_with(
        addr,
        Some(shared.cfg.connect_timeout),
        Some(shared.cfg.probe_read_timeout),
    ) {
        Ok(c) => c,
        Err(_) => return false,
    };
    matches!(
        c.request(&json::obj(vec![("cmd", json::s("health"))])),
        Ok(v) if v.get("ok").and_then(Value::as_bool) == Some(true)
    )
}

/// One JSON line as wire bytes (trailing newline included).
fn line_bytes(v: &Value) -> Vec<u8> {
    let mut s = json::to_string(v);
    s.push('\n');
    s.into_bytes()
}

/// What the router remembers about one in-flight proxied request.
struct PendingProxy {
    /// The upstream-facing request; `id` is the router-assigned id.
    req: InferRequest,
    /// Downstream dialect (0 | 1 | 2) — replies re-encode into it.
    version: u8,
    /// The client's own id, restored on the way down.
    client_id: Option<u64>,
    trace: Option<u64>,
    /// Node currently holding the request.
    node: usize,
    /// Nodes that already failed this request — never retried twice.
    excluded: Vec<usize>,
    /// Send attempts so far (`attempts > retries` ⇒ budget exhausted).
    attempts: usize,
    /// Absolute retry fence derived from the request's `deadline_us`.
    deadline: Option<Instant>,
    /// Human context for the final `upstream_unavailable` message.
    last_error: Option<String>,
    /// v0 strict-order path: the downstream reader blocks on this.
    v0_reply: Option<mpsc::Sender<Value>>,
}

struct ConnState {
    next_id: u64,
    pending: HashMap<u64, PendingProxy>,
}

/// One pipelined upstream connection (per downstream connection, per
/// node): writes go through `writer`, replies come back on a pump
/// thread ([`pump_upstream`]).
struct Upstream {
    node: usize,
    writer: Mutex<TcpStream>,
    /// Negotiated at connect via `cmd: "protocol"`.
    use_v2: bool,
    dead: AtomicBool,
}

/// Per-downstream-connection proxy state. Upstream pumps deliver
/// completions straight onto the (mutex-serialized) downstream writer.
struct ProxyConn {
    shared: Arc<Shared>,
    down: Mutex<TcpStream>,
    state: Mutex<ConnState>,
    upstreams: Mutex<HashMap<usize, Arc<Upstream>>>,
    closed: AtomicBool,
}

fn handle_conn(shared: Arc<Shared>, stream: TcpStream) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let conn = Arc::new(ProxyConn {
        shared,
        down: Mutex::new(stream.try_clone()?),
        state: Mutex::new(ConnState {
            next_id: 1,
            pending: HashMap::new(),
        }),
        upstreams: Mutex::new(HashMap::new()),
        closed: AtomicBool::new(false),
    });
    let mut reader = BufReader::new(stream);
    loop {
        // same first-byte sniff as the engine server: frame magic →
        // binary v2, anything else → a JSON line (v0/v1)
        let first = match reader.fill_buf() {
            Ok(buf) => match buf.first() {
                Some(b) => *b,
                None => break,
            },
            Err(_) => break,
        };
        if conn.shared.stop.load(SeqCst) {
            break;
        }
        if first == v2::FRAME_MAGIC {
            let frame = match v2::read_frame(&mut reader) {
                Ok(f) => f,
                Err(v2::FrameError::Bad(e)) => {
                    conn.write_down(&v2::encode_error(None, None, &e));
                    break;
                }
                Err(v2::FrameError::Io(_)) => break,
            };
            let client_id = v1::peek_id(&frame.header);
            let client_trace = v1::peek_trace(&frame.header);
            match v2::decode_request(frame) {
                Ok(req) => {
                    let router_id = conn.register(req, 2, None);
                    conn.dispatch(router_id);
                }
                Err(e) => conn.write_down(&v2::encode_error(client_id, client_trace, &e)),
            }
            continue;
        }
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) => {}
            Err(_) => break,
        }
        if line.trim().is_empty() {
            continue;
        }
        handle_line(&conn, &line, peer);
        if conn.shared.stop.load(SeqCst) {
            break; // the line was a shutdown command: reply is out, close
        }
    }
    conn.close();
    log_debug!("router peer {peer:?} disconnected");
    Ok(())
}

/// One downstream JSON line: command, v0 strict-order request, or
/// pipelined v1 request.
fn handle_line(conn: &Arc<ProxyConn>, line: &str, peer: Option<SocketAddr>) {
    let v = match json::parse(line) {
        Ok(v) => v,
        Err(e) => {
            conn.write_down(&line_bytes(&v1::encode_error(
                None,
                None,
                &ApiError::bad_request(format!("invalid JSON: {e}")),
                1,
            )));
            return;
        }
    };
    if v.get("cmd").is_some() {
        let reply = handle_router_cmd(conn, &v, peer);
        conn.write_down(&line_bytes(&reply));
        return;
    }
    let version_guess = v1::wire_version(&v).unwrap_or(1);
    let (req, version) = match v1::decode_request(&v) {
        Ok(x) => x,
        Err(e) => {
            conn.write_down(&line_bytes(&v1::encode_error(
                v1::peek_id(&v),
                v1::peek_trace(&v),
                &e,
                version_guess,
            )));
            return;
        }
    };
    if version == 0 {
        // v0 clients rely on strict request→reply order: the reader
        // thread blocks on the proxied reply (which may still fail over
        // across nodes) before reading the next line
        let (tx, rx) = mpsc::channel();
        let router_id = conn.register(req, 0, Some(tx));
        conn.dispatch(router_id);
        match rx.recv_timeout(V0_SYNC_CAP) {
            Ok(value) => conn.write_down(&line_bytes(&value)),
            Err(_) => {
                conn.state.lock().unwrap().pending.remove(&router_id);
                conn.write_down(&line_bytes(&v1::encode_error(
                    None,
                    None,
                    &ApiError::upstream_unavailable(format!(
                        "no upstream reply within {V0_SYNC_CAP:?}"
                    )),
                    0,
                )));
            }
        }
        return;
    }
    let router_id = conn.register(req, version, None);
    conn.dispatch(router_id);
}

impl ProxyConn {
    /// Assign a router id, remember the client's framing, and park the
    /// request as pending. The router id is what transits upstream.
    fn register(
        &self,
        mut req: InferRequest,
        version: u8,
        v0_reply: Option<mpsc::Sender<Value>>,
    ) -> u64 {
        let deadline = req
            .deadline_us
            .map(|us| Instant::now() + Duration::from_micros(us));
        let client_id = req.id;
        let trace = req.trace;
        let mut st = self.state.lock().unwrap();
        let router_id = st.next_id;
        st.next_id += 1;
        req.id = Some(router_id);
        st.pending.insert(
            router_id,
            PendingProxy {
                req,
                version,
                client_id,
                trace,
                node: usize::MAX,
                excluded: Vec::new(),
                attempts: 0,
                deadline,
                last_error: None,
                v0_reply,
            },
        );
        router_id
    }

    /// Place (or re-place) one pending request on the first healthy,
    /// not-yet-excluded node of its ring sequence and send it. Loops
    /// over send-level failures (connect refused, broken pipe), so a
    /// request always settles: delivered to a node, or failed loudly
    /// with `upstream_unavailable`.
    fn dispatch(self: &Arc<Self>, router_id: u64) {
        loop {
            // phase 1 — under the state lock: pick the next candidate or
            // conclude the request is unroutable
            let step = {
                let mut st = self.state.lock().unwrap();
                let picked = match st.pending.get_mut(&router_id) {
                    None => return, // completed or abandoned meanwhile
                    Some(entry) => next_candidate(&self.shared, entry),
                };
                match picked {
                    Ok(x) => Ok(x),
                    Err(reason) => {
                        let entry = st.pending.remove(&router_id).expect("just seen");
                        Err((entry, reason))
                    }
                }
            };
            let (node, req) = match step {
                Ok(x) => x,
                Err((entry, reason)) => {
                    self.fail_request(&entry, &reason);
                    return;
                }
            };
            // phase 2 — connect or reuse the upstream (no state lock)
            let up = match self.ensure_upstream(node) {
                Ok(up) => up,
                Err(e) => {
                    self.note_failure(
                        router_id,
                        node,
                        format!("connect {}: {e}", self.shared.cfg.nodes[node]),
                    );
                    continue;
                }
            };
            // phase 3 — encode in the upstream's dialect and send
            let bytes = if up.use_v2 {
                v2::encode_request(&req)
            } else {
                line_bytes(&v1::encode_request(&req))
            };
            let sent = {
                let mut w = up.writer.lock().unwrap();
                w.write_all(&bytes)
            };
            match sent {
                Ok(()) => return,
                Err(e) => {
                    self.drop_upstream(node);
                    self.note_failure(
                        router_id,
                        node,
                        format!("send to {}: {e}", self.shared.cfg.nodes[node]),
                    );
                }
            }
        }
    }

    /// Mark a failed attempt on `node` so the next dispatch skips it.
    fn note_failure(&self, router_id: u64, node: usize, err: String) {
        let mut st = self.state.lock().unwrap();
        if let Some(entry) = st.pending.get_mut(&router_id) {
            if !entry.excluded.contains(&node) {
                entry.excluded.push(node);
            }
            entry.last_error = Some(err);
        }
    }

    /// Failover is out of road: tell the client with the frozen
    /// `upstream_unavailable` code and the last upstream error.
    fn fail_request(&self, entry: &PendingProxy, reason: &str) {
        let detail = match &entry.last_error {
            Some(last) => format!(
                "{reason} after {} attempt(s); last error: {last}",
                entry.attempts
            ),
            None => format!("{reason} after {} attempt(s)", entry.attempts),
        };
        self.deliver(
            entry,
            InferReply::Err(ErrorReply {
                id: None,
                error: ApiError::upstream_unavailable(detail),
                trace: None,
            }),
        );
    }

    /// Get the live upstream for `node`, dialling (and negotiating v2,
    /// and starting the reply pump) on first use. The pool lock is held
    /// across the dial — contending dispatches wait rather than racing
    /// duplicate connections.
    fn ensure_upstream(self: &Arc<Self>, node: usize) -> Result<Arc<Upstream>> {
        let mut ups = self.upstreams.lock().unwrap();
        if let Some(up) = ups.get(&node) {
            if !up.dead.load(SeqCst) {
                return Ok(Arc::clone(up));
            }
        }
        ups.remove(&node);
        let addr = &self.shared.cfg.nodes[node];
        let sockaddr = addr.to_socket_addrs()?.next().ok_or_else(|| {
            Error::Coordinator(format!("{addr}: resolved to no socket addresses"))
        })?;
        let stream = TcpStream::connect_timeout(&sockaddr, self.shared.cfg.connect_timeout)?;
        let use_v2 = negotiate_v2(&stream, self.shared.cfg.probe_read_timeout)?;
        let up = Arc::new(Upstream {
            node,
            writer: Mutex::new(stream.try_clone()?),
            use_v2,
            dead: AtomicBool::new(false),
        });
        ups.insert(node, Arc::clone(&up));
        {
            let conn = Arc::clone(self);
            let up = Arc::clone(&up);
            thread::spawn(move || pump_upstream(conn, up, stream));
        }
        Ok(up)
    }

    fn drop_upstream(&self, node: usize) {
        if let Some(up) = self.upstreams.lock().unwrap().remove(&node) {
            up.dead.store(true, SeqCst);
        }
    }

    /// One decoded upstream reply: retire its pending entry, then either
    /// hand it downstream or fail the request over (`exec_failed` means
    /// the batch died on that node — the request itself is re-playable).
    fn complete(self: &Arc<Self>, node: usize, reply: InferReply) {
        let Some(router_id) = reply.id() else {
            return; // id-less error reply — nothing to correlate
        };
        let entry = {
            let mut st = self.state.lock().unwrap();
            match st.pending.remove(&router_id) {
                Some(e) => e,
                None => return, // stale or duplicate completion
            }
        };
        if let InferReply::Err(err) = &reply {
            if err.error.code == ErrorCode::ExecFailed && entry.node == node {
                let mut entry = entry;
                if !entry.excluded.contains(&node) {
                    entry.excluded.push(node);
                }
                entry.last_error =
                    Some(format!("node {}: {}", self.shared.cfg.nodes[node], err.error));
                self.state.lock().unwrap().pending.insert(router_id, entry);
                self.dispatch(router_id);
                return;
            }
        }
        self.deliver(&entry, reply);
    }

    /// Re-encode one settled reply in the client's dialect, with the
    /// client's id restored, and hand it downstream.
    fn deliver(&self, entry: &PendingProxy, mut reply: InferReply) {
        let router_id = entry.req.id.expect("router id assigned at register");
        let down_id = entry.client_id.unwrap_or(router_id);
        match &mut reply {
            InferReply::Ok(r) => {
                r.id = down_id;
                r.trace = entry.trace;
            }
            InferReply::Err(e) => {
                e.id = Some(down_id);
                e.trace = entry.trace;
            }
        }
        if let Some(tx) = &entry.v0_reply {
            // v0: wake the blocked reader thread, which writes in order
            let value = match &reply {
                InferReply::Ok(r) => v1::encode_response(r, 0),
                InferReply::Err(e) => v1::encode_error(e.id, e.trace, &e.error, 0),
            };
            let _ = tx.send(value);
            return;
        }
        let bytes = match (&reply, entry.version) {
            (InferReply::Ok(r), 2) => v2::encode_response(r),
            (InferReply::Err(e), 2) => v2::encode_error(e.id, e.trace, &e.error),
            (InferReply::Ok(r), ver) => line_bytes(&v1::encode_response(r, ver)),
            (InferReply::Err(e), ver) => line_bytes(&v1::encode_error(e.id, e.trace, &e.error, ver)),
        };
        self.write_down(&bytes);
    }

    /// The upstream to `node` died (EOF or reset): every request parked
    /// on it fails over to its next ring node.
    fn fail_node(self: &Arc<Self>, node: usize) {
        self.drop_upstream(node);
        if self.closed.load(SeqCst) {
            return;
        }
        let ids: Vec<u64> = {
            let mut st = self.state.lock().unwrap();
            st.pending
                .iter_mut()
                .filter(|(_, e)| e.node == node)
                .map(|(id, e)| {
                    if !e.excluded.contains(&node) {
                        e.excluded.push(node);
                    }
                    e.last_error =
                        Some(format!("connection to {} reset", self.shared.cfg.nodes[node]));
                    *id
                })
                .collect()
        };
        for id in ids {
            self.dispatch(id);
        }
    }

    /// Serialize one complete downstream message (pump threads and the
    /// reader thread share the socket through this).
    fn write_down(&self, bytes: &[u8]) {
        if self.closed.load(SeqCst) {
            return;
        }
        let mut w = self.down.lock().unwrap();
        if w.write_all(bytes).is_err() {
            self.closed.store(true, SeqCst);
        }
    }

    /// Downstream hung up: stop delivering and unblock every pump.
    fn close(&self) {
        self.closed.store(true, SeqCst);
        let ups: Vec<Arc<Upstream>> = self
            .upstreams
            .lock()
            .unwrap()
            .drain()
            .map(|(_, u)| u)
            .collect();
        for up in ups {
            up.dead.store(true, SeqCst);
            let w = up.writer.lock().unwrap();
            let _ = w.shutdown(std::net::Shutdown::Both);
        }
    }
}

/// Pick the next node for a pending request, enforcing the deadline
/// fence, the retry budget, and the excluded-node memory. `Err` carries
/// the give-up reason.
fn next_candidate(
    shared: &Shared,
    entry: &mut PendingProxy,
) -> std::result::Result<(usize, InferRequest), String> {
    if entry.attempts > 0 {
        // retrying — never past the request's own deadline
        if let Some(d) = entry.deadline {
            if Instant::now() >= d {
                return Err("request deadline elapsed during failover".to_string());
            }
        }
        if entry.attempts > shared.cfg.retries {
            return Err(format!("retry budget ({}) exhausted", shared.cfg.retries));
        }
    }
    let key = Ring::key(&entry.req.task, entry.req.variant.as_deref());
    let node = shared
        .ring
        .sequence(key)
        .into_iter()
        .find(|&n| shared.health.healthy(n) && !entry.excluded.contains(&n))
        .ok_or_else(|| "no healthy un-tried node remains on the ring".to_string())?;
    entry.attempts += 1;
    entry.node = node;
    Ok((node, entry.req.clone()))
}

/// Negotiate the upstream dialect on a fresh connection: `cmd:
/// "protocol"`, prefer v2 when offered. The read is bounded; afterwards
/// the socket reverts to unbounded reads (the pump idles by design).
fn negotiate_v2(stream: &TcpStream, read_timeout: Duration) -> Result<bool> {
    stream.set_read_timeout(Some(read_timeout))?;
    let mut w = stream.try_clone()?;
    w.write_all(&line_bytes(&json::obj(vec![("cmd", json::s("protocol"))])))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    if reader.read_line(&mut line)? == 0 {
        return Err(Error::Coordinator(
            "node closed the connection during protocol negotiation".into(),
        ));
    }
    let v = json::parse(&line)?;
    stream.set_read_timeout(None)?;
    Ok(v.get("ok").and_then(Value::as_bool) == Some(true)
        && v.get("versions")
            .and_then(Value::as_arr)
            .is_some_and(|vs| vs.iter().any(|x| x.as_f64() == Some(2.0))))
}

/// Read replies off one upstream connection and complete them. Exit (EOF
/// or error) means the node connection is gone: fail everything parked
/// there over to the next ring node.
fn pump_upstream(conn: Arc<ProxyConn>, up: Arc<Upstream>, stream: TcpStream) {
    let mut reader = BufReader::new(stream);
    loop {
        let first = match reader.fill_buf() {
            Ok(buf) => match buf.first() {
                Some(b) => *b,
                None => break,
            },
            Err(_) => break,
        };
        let reply = if first == v2::FRAME_MAGIC {
            match v2::read_frame(&mut reader) {
                Ok(f) => v2::decode_reply(f),
                Err(_) => break, // framing lost — no resync, reconnect
            }
        } else {
            let mut line = String::new();
            match reader.read_line(&mut line) {
                Ok(0) => break,
                Ok(_) => {}
                Err(_) => break,
            }
            match json::parse(&line) {
                Ok(v) => v1::decode_reply(&v),
                Err(_) => break,
            }
        };
        match reply {
            Ok(r) => conn.complete(up.node, r),
            Err(e) => log_debug!("undecodable reply from node {}: {e}", up.node),
        }
    }
    up.dead.store(true, SeqCst);
    conn.fail_node(up.node);
}

/// Router-level command handling. `protocol`, `health`, `metrics` and
/// `shutdown` answer at the router; anything else forwards one-shot to
/// the first healthy node.
fn handle_router_cmd(conn: &Arc<ProxyConn>, v: &Value, peer: Option<SocketAddr>) -> Value {
    let shared = &conn.shared;
    let cmd = match v.get("cmd").and_then(Value::as_str) {
        Some(c) => c,
        None => {
            return v1::encode_error(
                None,
                None,
                &ApiError::bad_request("cmd must be a string"),
                1,
            )
        }
    };
    match cmd {
        "protocol" => json::obj(vec![
            ("ok", Value::Bool(true)),
            (
                "versions",
                Value::Arr(vec![json::num(0.0), json::num(1.0), json::num(2.0)]),
            ),
        ]),
        // the router's own placement health view (the engine's audit
        // "health" is reachable by asking a node directly)
        "health" => {
            let nodes: Vec<Value> = shared
                .cfg
                .nodes
                .iter()
                .enumerate()
                .map(|(i, addr)| {
                    json::obj(vec![
                        ("addr", json::s(addr)),
                        ("healthy", Value::Bool(shared.health.healthy(i))),
                    ])
                })
                .collect();
            json::obj(vec![
                ("ok", Value::Bool(true)),
                ("router", Value::Bool(true)),
                ("nodes", Value::Arr(nodes)),
            ])
        }
        "metrics" => cluster_metrics(shared),
        "shutdown" => {
            let loopback = peer.map(|p| p.ip().is_loopback()).unwrap_or(false);
            if !loopback {
                return v1::encode_error(
                    None,
                    None,
                    &ApiError::bad_request(format!(
                        "cmd \"shutdown\" is admin-only: accepted from loopback \
                         peers, denied for {peer:?}"
                    )),
                    1,
                );
            }
            shared.stop.store(true, SeqCst);
            if let Some(addr) = *shared.listen_addr.lock().unwrap() {
                let _ = TcpStream::connect_timeout(&addr, Duration::from_millis(250));
            }
            json::obj(vec![
                ("ok", Value::Bool(true)),
                ("shutdown", Value::Bool(true)),
            ])
        }
        _ => {
            let Some(node) = (0..shared.cfg.nodes.len()).find(|&i| shared.health.healthy(i))
            else {
                return v1::encode_error(
                    None,
                    None,
                    &ApiError::upstream_unavailable(
                        "no healthy node to forward the command to",
                    ),
                    1,
                );
            };
            match forward_cmd(shared, node, v) {
                Ok(reply) => reply,
                Err(e) => v1::encode_error(
                    None,
                    None,
                    &ApiError::upstream_unavailable(format!(
                        "forwarding cmd to {}: {e}",
                        shared.cfg.nodes[node]
                    )),
                    1,
                ),
            }
        }
    }
}

/// One-shot command round trip to a node on a fresh timed-out connection.
fn forward_cmd(shared: &Shared, node: usize, v: &Value) -> Result<Value> {
    let mut c = Client::connect_with(
        &shared.cfg.nodes[node],
        Some(shared.cfg.connect_timeout),
        Some(shared.cfg.probe_read_timeout),
    )?;
    c.request(v)
}

/// Live-poll every node's `cmd: "metrics"` and merge into one reply:
/// counters as sums, goodput/fill as ratio-of-sums, percentiles as a
/// responses-weighted mean (see [`merge`]); a `per_node` array carries
/// each node's health and headline gauges.
fn cluster_metrics(shared: &Shared) -> Value {
    let mut oks: Vec<Value> = Vec::new();
    let mut per_node: Vec<Value> = Vec::new();
    for (i, addr) in shared.cfg.nodes.iter().enumerate() {
        let reply = forward_cmd(shared, i, &json::obj(vec![("cmd", json::s("metrics"))]))
            .ok()
            .filter(|r| r.get("ok").and_then(Value::as_bool) == Some(true));
        let mut fields = vec![
            ("addr", json::s(addr)),
            ("healthy", Value::Bool(shared.health.healthy(i))),
            ("ok", Value::Bool(reply.is_some())),
        ];
        if let Some(r) = &reply {
            for key in ["fill", "goodput", "responses", "total_p50_us", "total_p99_us"] {
                if let Some(x) = r.get(key).and_then(Value::as_f64) {
                    fields.push((key, json::num(x)));
                }
            }
        }
        per_node.push(json::obj(fields));
        if let Some(r) = reply {
            oks.push(r);
        }
    }
    let mut merged = merge::merge_metrics(&oks);
    if let Value::Obj(map) = &mut merged {
        map.insert("per_node".to_string(), Value::Arr(per_node));
    }
    merged
}
