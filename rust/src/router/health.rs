//! Per-node health: the eject/readmit state machine and the poll loop.
//!
//! A node starts healthy. Each failed probe increments a consecutive-
//! failure counter; reaching `eject_after` ejects the node from
//! placement. Any successful probe zeroes the counter and — if the node
//! was ejected — re-admits it immediately (recovery should not wait out
//! a penalty window; the poll cadence already rate-limits flapping).

use std::sync::atomic::{AtomicU32, Ordering::SeqCst};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use crate::log_info;

struct NodeState {
    /// consecutive failed probes since the last success
    fails: u32,
    healthy: bool,
}

/// Health state for every node, shared between the poller and the
/// request path (which only reads [`Self::healthy`]).
pub struct HealthTracker {
    states: Vec<Mutex<NodeState>>,
    eject_after: u32,
    /// total ejections since startup (observability)
    pub ejections: AtomicU32,
}

impl HealthTracker {
    /// All nodes start healthy; `eject_after` consecutive failures eject.
    pub fn new(nodes: usize, eject_after: u32) -> HealthTracker {
        assert!(eject_after > 0, "eject_after must be at least 1");
        HealthTracker {
            states: (0..nodes)
                .map(|_| {
                    Mutex::new(NodeState {
                        fails: 0,
                        healthy: true,
                    })
                })
                .collect(),
            eject_after,
            ejections: AtomicU32::new(0),
        }
    }

    pub fn len(&self) -> usize {
        self.states.len()
    }

    pub fn is_empty(&self) -> bool {
        self.states.is_empty()
    }

    /// Is the node currently in placement?
    pub fn healthy(&self, node: usize) -> bool {
        self.states[node].lock().unwrap().healthy
    }

    pub fn healthy_count(&self) -> usize {
        (0..self.len()).filter(|&i| self.healthy(i)).count()
    }

    /// Record a successful probe. Returns `true` when this re-admits a
    /// previously ejected node.
    pub fn record_success(&self, node: usize) -> bool {
        let mut s = self.states[node].lock().unwrap();
        let readmitted = !s.healthy;
        s.fails = 0;
        s.healthy = true;
        readmitted
    }

    /// Record a failed probe. Returns `true` when this probe crosses the
    /// ejection threshold (exactly once per ejection).
    pub fn record_failure(&self, node: usize) -> bool {
        let mut s = self.states[node].lock().unwrap();
        s.fails = s.fails.saturating_add(1);
        let ejected = s.healthy && s.fails >= self.eject_after;
        if ejected {
            s.healthy = false;
            self.ejections.fetch_add(1, SeqCst);
        }
        ejected
    }
}

/// Run the poll loop on its own thread: probe every node, record the
/// outcome, sleep `interval`, repeat until `stop()` turns true. The
/// probe itself is a closure so the tracker stays transport-agnostic
/// (the router probes `cmd: "health"` over a fresh timed-out
/// connection; tests inject scripted outcomes).
pub fn spawn_poller(
    tracker: Arc<HealthTracker>,
    interval: Duration,
    stop: impl Fn() -> bool + Send + 'static,
    probe: impl Fn(usize) -> bool + Send + 'static,
) -> JoinHandle<()> {
    thread::spawn(move || {
        while !stop() {
            for node in 0..tracker.len() {
                if stop() {
                    return;
                }
                if probe(node) {
                    if tracker.record_success(node) {
                        log_info!("node {node} re-admitted to placement");
                    }
                } else if tracker.record_failure(node) {
                    log_info!("node {node} ejected from placement");
                }
            }
            // sleep in slices so a stop request is honoured promptly
            let mut slept = Duration::ZERO;
            while slept < interval && !stop() {
                let step = (interval - slept).min(Duration::from_millis(20));
                thread::sleep(step);
                slept += step;
            }
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    #[test]
    fn ejects_after_k_consecutive_failures_only() {
        let t = HealthTracker::new(2, 3);
        assert!(t.healthy(0) && t.healthy(1));
        assert!(!t.record_failure(0));
        assert!(!t.record_failure(0));
        assert!(t.healthy(0), "below the threshold stays in placement");
        assert!(t.record_failure(0), "third consecutive failure ejects");
        assert!(!t.healthy(0));
        assert!(!t.record_failure(0), "ejection reports exactly once");
        assert!(t.healthy(1), "other nodes unaffected");
        assert_eq!(t.ejections.load(SeqCst), 1);
    }

    #[test]
    fn a_success_resets_the_streak_and_readmits() {
        let t = HealthTracker::new(1, 2);
        // interleaved success: never ejects
        assert!(!t.record_failure(0));
        assert!(!t.record_success(0), "healthy success is not a readmit");
        assert!(!t.record_failure(0));
        assert!(t.healthy(0));
        // now a real ejection, then recovery on the first good probe
        assert!(t.record_failure(0));
        assert!(!t.healthy(0));
        assert!(t.record_success(0), "first success after ejection readmits");
        assert!(t.healthy(0));
        // the streak restarted from zero
        assert!(!t.record_failure(0));
        assert!(t.healthy(0));
    }

    #[test]
    fn poller_drives_the_state_machine_and_stops() {
        let t = Arc::new(HealthTracker::new(2, 3));
        let stop = Arc::new(AtomicBool::new(false));
        let h = {
            let stop = Arc::clone(&stop);
            // node 0 always fails its probe, node 1 always passes
            spawn_poller(
                Arc::clone(&t),
                Duration::from_millis(1),
                move || stop.load(SeqCst),
                |node| node == 1,
            )
        };
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while t.healthy(0) && std::time::Instant::now() < deadline {
            thread::sleep(Duration::from_millis(2));
        }
        assert!(!t.healthy(0), "persistently failing node must be ejected");
        assert!(t.healthy(1), "passing node stays in placement");
        stop.store(true, SeqCst);
        h.join().unwrap();
    }
}
