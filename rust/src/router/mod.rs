//! The cluster routing layer — one process in front of N engine nodes.
//!
//! * [`ring`] — consistent-hash placement: requests map to nodes by a
//!   stable FNV-1a hash of `(task, variant)` over a virtual-node ring,
//!   so placement survives node loss and every key has a deterministic
//!   failover sequence.
//! * [`health`] — per-node health: a poller probes each node's
//!   `cmd: "health"` on a fixed cadence; a node failing K consecutive
//!   polls is ejected from placement and re-admitted on its first
//!   successful poll.
//! * [`proxy`] — the router itself ([`Router`], the `hyperrouter` bin):
//!   a v0/v1/v2-speaking proxy with per-connection upstream pools,
//!   id-remapping so out-of-order completions from different nodes
//!   multiplex onto one client connection, and health-aware retries
//!   with excluded-node memory, a bounded budget, and a hard
//!   `deadline_us` fence. Exhausted failover surfaces as the frozen
//!   `upstream_unavailable` error code.
//!
//! Every wire dialect transits the router unchanged: replies return in
//! the dialect their request arrived in. See rust/README.md §"Cluster
//! serving" for the placement rule, the eject/readmit state machine and
//! the retry budget semantics.

pub mod health;
pub mod proxy;
pub mod ring;

pub use health::HealthTracker;
pub use proxy::{Router, RouterConfig};
pub use ring::Ring;
