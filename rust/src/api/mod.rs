//! The versioned serving API — the single definition of the wire and
//! in-process contract.
//!
//! * [`error`] — the stable machine-readable [`ErrorCode`] space and the
//!   coded [`ApiError`] every failure path carries.
//! * [`v1`] — the typed [`v1::InferRequest`]/[`v1::InferResponse`] structs
//!   and the JSON-lines codec (v1 lines tagged `"v": 1`; legacy v0 lines
//!   still decoded and answered with a deprecation notice).
//! * [`v2`] — the binary framed codec over the *same* typed structs: a
//!   small JSON header plus raw little-endian f32 row data, zero-copy in
//!   both directions. Routed by a one-byte frame magic, so v0/v1/v2
//!   coexist on one port.
//!
//! The TCP server ([`crate::coordinator::server`]), the pipelined
//! [`Client`](crate::coordinator::server::Client), and the Pareto serve
//! sweep ([`crate::pareto::sweep::serve_sweep`]) all speak through this
//! module — there is no second copy of the protocol anywhere. See
//! rust/README.md §"Serving API v1" for the schema tables.

pub mod error;
pub mod v1;
pub mod v2;

pub use error::{ApiError, ErrorCode};
