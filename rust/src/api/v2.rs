//! Serving API **v2**: a length-prefixed binary framing of the same typed
//! protocol [`crate::api::v1`] speaks — a small JSON header plus raw
//! little-endian `f32` row data, so a `[rows, dims]` payload crosses the
//! wire without a per-float text parse and deserializes straight into the
//! engine's contiguous [`RowBlock`](crate::coordinator::RowBlock).
//!
//! ```text
//! offset  size          field
//! 0       1             magic 0xB2 (never a valid JSON/UTF-8 first byte)
//! 1       1             kind: 1 = request, 2 = response, 3 = error
//! 2       4             header_len  (u32, little-endian)
//! 6       4             payload_len (u32, little-endian, bytes; = 4·rows·dims)
//! 10      header_len    JSON header ({"v":2, ...}; same fields as the v1
//!                       line minus input/output, plus "rows"/"dims")
//! 10+h    payload_len   raw little-endian f32 rows, row-major [rows, dims]
//! ```
//!
//! Request headers carry `task`/`rows`/`dims` plus the optional v1 fields
//! (`id`, `budget`, `policy`, `variant`, `deadline_us`, `priority`,
//! `client`, `trace`) with **identical** strict semantics — both codecs decode the
//! metadata through the same `api::v1` readers, so v2 cannot drift from
//! v1 field by field. Response and error headers mirror the v1 reply
//! shapes (`ok`, `id`, `variant`, `mape`, `nfe`, `latency_us`,
//! `batch_fill`, `code`, `error`); error frames have an empty payload.
//!
//! A server sniffs the first byte of each message to route it: `0xB2`
//! means a v2 frame, anything else is a JSON line (v0/v1) — all three
//! dialects coexist on one port and one connection. Malformed frames
//! (bad magic, truncated header, length overflow, ragged row payload) are
//! answered with a loud `bad_request` error frame, never a panic or a
//! silent truncation; since binary framing cannot be resynchronized after
//! garbage, the server then closes the connection.

use std::io::Read;

use crate::api::error::{ApiError, ErrorCode};
use crate::api::v1::{self, ErrorReply, InferReply, InferRequest, InferResponse};
use crate::util::json::{self, Value};

/// The protocol version this module speaks (the header's `"v"` value).
pub const VERSION: u64 = 2;

/// First byte of every v2 frame. `0xB2` is not `{` (0x7B), not whitespace,
/// and not a valid leading UTF-8 byte — a JSON-lines peer can never emit
/// it as the first byte of a message, so one-byte sniffing is unambiguous.
pub const FRAME_MAGIC: u8 = 0xB2;

/// Frame kinds (byte 1).
pub const KIND_REQUEST: u8 = 1;
pub const KIND_RESPONSE: u8 = 2;
pub const KIND_ERROR: u8 = 3;

/// Fixed prefix: magic + kind + header_len (u32le) + payload_len (u32le).
pub const FRAME_PREFIX_LEN: usize = 10;

/// Hard cap on the JSON header (metadata only — row data never lives
/// here); a bigger claim is a corrupt or hostile frame.
pub const MAX_HEADER_BYTES: usize = 64 * 1024;

/// Hard cap on the row payload (64 MiB ≈ a 65536×256 f32 block, far above
/// any exported batch); a bigger claim is rejected before allocating.
pub const MAX_PAYLOAD_BYTES: usize = 64 * 1024 * 1024;

/// One decoded frame: kind, parsed JSON header, and the payload as `f32`
/// values (empty for error frames).
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub kind: u8,
    pub header: Value,
    pub payload: Vec<f32>,
}

/// Why a frame failed to read: `Io` is a transport failure (including a
/// stream truncated mid-frame); `Bad` is a structurally invalid frame the
/// peer should be told about (`bad_request`) before the connection drops.
#[derive(Debug)]
pub enum FrameError {
    Io(std::io::Error),
    Bad(ApiError),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Io(e) => write!(f, "v2 frame io error: {e}"),
            FrameError::Bad(e) => write!(f, "v2 frame error: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<FrameError> for crate::Error {
    fn from(e: FrameError) -> crate::Error {
        match e {
            FrameError::Io(e) => crate::Error::Io(e),
            FrameError::Bad(e) => e.into(),
        }
    }
}

/// True when the stream was cut mid-frame — the one `Io` case that still
/// deserves a loud `bad_request` ("truncated frame") reply attempt.
pub fn is_truncation(e: &FrameError) -> bool {
    matches!(e, FrameError::Io(io) if io.kind() == std::io::ErrorKind::UnexpectedEof)
}

fn bad(msg: impl Into<String>) -> FrameError {
    FrameError::Bad(ApiError::bad_request(msg))
}

// ---------------------------------------------------------------------------
// Byte-level frame I/O
// ---------------------------------------------------------------------------

/// Append `rows` to `out` as raw little-endian f32 bytes — on
/// little-endian targets a single `extend_from_slice` of the reinterpreted
/// block (the symmetric zero-copy of the decode path).
fn extend_rows_le(out: &mut Vec<u8>, rows: &[f32]) {
    #[cfg(target_endian = "little")]
    {
        // SAFETY: an initialized `[f32]` is plain-old-data; viewing its
        // rows.len() * 4 bytes as `[u8]` (alignment 1 ≤ 4) is always
        // valid, and the view ends before `out` can reallocate or `rows`
        // can move.
        let bytes = unsafe {
            std::slice::from_raw_parts(rows.as_ptr().cast::<u8>(), rows.len() * 4)
        };
        out.extend_from_slice(bytes);
    }
    #[cfg(not(target_endian = "little"))]
    for x in rows {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Read `n` little-endian f32 values, filling the target vec's bytes in
/// place — no intermediate byte buffer, no per-float parse.
fn read_rows_le(r: &mut impl Read, n: usize) -> std::io::Result<Vec<f32>> {
    let mut out = vec![0f32; n];
    {
        // SAFETY: `out` owns n initialized f32s; viewing them as n * 4
        // bytes (alignment 1 ≤ 4) for the duration of the read is valid,
        // and every byte pattern is a valid f32.
        let bytes = unsafe {
            std::slice::from_raw_parts_mut(out.as_mut_ptr().cast::<u8>(), n * 4)
        };
        r.read_exact(bytes)?;
    }
    #[cfg(not(target_endian = "little"))]
    for x in &mut out {
        *x = f32::from_bits(x.to_bits().swap_bytes());
    }
    Ok(out)
}

/// Serialize one frame: prefix + header JSON + payload rows.
fn frame_bytes(kind: u8, header: &Value, payload: &[f32]) -> Vec<u8> {
    let h = json::to_string(header).into_bytes();
    debug_assert!(h.len() <= MAX_HEADER_BYTES, "header exceeds the frame cap");
    let mut out = Vec::with_capacity(FRAME_PREFIX_LEN + h.len() + payload.len() * 4);
    out.push(FRAME_MAGIC);
    out.push(kind);
    out.extend_from_slice(&(h.len() as u32).to_le_bytes());
    out.extend_from_slice(&((payload.len() * 4) as u32).to_le_bytes());
    out.extend_from_slice(&h);
    extend_rows_le(&mut out, payload);
    out
}

/// Read one complete frame (prefix, header, payload) from `r`, applying
/// the hardening limits. The caller has usually sniffed (not consumed)
/// the magic byte; this reads and re-checks it.
pub fn read_frame(r: &mut impl Read) -> Result<Frame, FrameError> {
    let mut prefix = [0u8; FRAME_PREFIX_LEN];
    r.read_exact(&mut prefix).map_err(FrameError::Io)?;
    if prefix[0] != FRAME_MAGIC {
        return Err(bad(format!(
            "bad v2 frame magic 0x{:02x} (want 0x{FRAME_MAGIC:02x})",
            prefix[0]
        )));
    }
    let kind = prefix[1];
    if !matches!(kind, KIND_REQUEST | KIND_RESPONSE | KIND_ERROR) {
        return Err(bad(format!("unknown v2 frame kind {kind}")));
    }
    let header_len = u32::from_le_bytes(prefix[2..6].try_into().expect("4 bytes")) as usize;
    let payload_len = u32::from_le_bytes(prefix[6..10].try_into().expect("4 bytes")) as usize;
    if header_len == 0 {
        return Err(bad("v2 frame declares an empty header"));
    }
    if header_len > MAX_HEADER_BYTES {
        return Err(bad(format!(
            "v2 frame header of {header_len} bytes overflows the {MAX_HEADER_BYTES}-byte cap"
        )));
    }
    if payload_len > MAX_PAYLOAD_BYTES {
        return Err(bad(format!(
            "v2 frame payload of {payload_len} bytes overflows the {MAX_PAYLOAD_BYTES}-byte cap"
        )));
    }
    if payload_len % 4 != 0 {
        return Err(bad(format!(
            "v2 frame payload of {payload_len} bytes is not a whole number of f32 rows"
        )));
    }
    let mut hbuf = vec![0u8; header_len];
    r.read_exact(&mut hbuf).map_err(FrameError::Io)?;
    let htext = std::str::from_utf8(&hbuf)
        .map_err(|_| bad("v2 frame header is not UTF-8"))?;
    let header = json::parse(htext)
        .map_err(|e| bad(format!("v2 frame header is not valid JSON: {e}")))?;
    let payload = read_rows_le(r, payload_len / 4).map_err(FrameError::Io)?;
    Ok(Frame {
        kind,
        header,
        payload,
    })
}

/// Header `"v"` must be exactly this module's version (strict, like v1's
/// line tag — an unknown version must fail loudly, not guess).
fn check_version(header: &Value) -> Result<(), ApiError> {
    if header.as_obj().is_none() {
        return Err(ApiError::bad_request("v2 frame header must be a JSON object"));
    }
    match header.get("v").and_then(Value::as_f64) {
        Some(n) if n == VERSION as f64 => Ok(()),
        other => Err(ApiError::bad_request(format!(
            "v2 frame header carries version {other:?}, want {VERSION}"
        ))),
    }
}

/// Strict read of a required non-negative integer header field.
fn required_u64(header: &Value, key: &str) -> Result<u64, ApiError> {
    v1::field_u64(header, key)?
        .ok_or_else(|| ApiError::bad_request(format!("v2 frame header missing {key}")))
}

/// Check the header's declared `[rows, dims]` against the payload the
/// frame actually carried — a ragged payload is a loud `bad_request`.
fn check_rows_dims(rows: u64, dims: u64, got: usize) -> Result<(usize, usize), ApiError> {
    if rows == 0 || dims == 0 {
        return Err(ApiError::bad_request("v2 frame carries no rows"));
    }
    let want = (rows as usize)
        .checked_mul(dims as usize)
        .filter(|w| *w <= MAX_PAYLOAD_BYTES / 4)
        .ok_or_else(|| {
            ApiError::bad_request(format!("v2 frame declares {rows}×{dims} rows — overflow"))
        })?;
    if want != got {
        return Err(ApiError::bad_request(format!(
            "v2 frame payload carries {got} values but the header declares \
             {rows}×{dims} = {want}"
        )));
    }
    Ok((rows as usize, dims as usize))
}

// ---------------------------------------------------------------------------
// Request codec
// ---------------------------------------------------------------------------

/// Encode a typed request as one v2 frame: the metadata header (same
/// omission conventions as the v1 line) plus the raw row payload.
pub fn encode_request(r: &InferRequest) -> Vec<u8> {
    let mut fields = vec![
        ("v", json::num(VERSION as f64)),
        ("task", json::s(&r.task)),
        ("rows", json::num(r.samples as f64)),
        ("dims", json::num(r.dims as f64)),
    ];
    v1::push_meta_fields(&mut fields, r);
    frame_bytes(KIND_REQUEST, &json::obj(fields), &r.input)
}

/// Decode a request frame into the typed form, moving the payload (the
/// frame's row block becomes the request's input with no copy). Strict:
/// every malformed header field is a [`ErrorCode::BadRequest`].
pub fn decode_request(f: Frame) -> Result<InferRequest, ApiError> {
    if f.kind != KIND_REQUEST {
        return Err(ApiError::bad_request(format!(
            "expected a request frame (kind {KIND_REQUEST}), got kind {}",
            f.kind
        )));
    }
    check_version(&f.header)?;
    let task = v1::field_str(&f.header, "task")?
        .ok_or_else(|| ApiError::bad_request("v2 frame header missing task"))?
        .to_string();
    let rows = required_u64(&f.header, "rows")?;
    let dims = required_u64(&f.header, "dims")?;
    let (samples, dims) = check_rows_dims(rows, dims, f.payload.len())?;
    let budget = v1::decode_budget(&f.header)?;
    let meta = v1::decode_meta(&f.header)?;
    Ok(InferRequest {
        id: meta.id,
        task,
        samples,
        dims,
        input: f.payload,
        budget,
        policy: meta.policy,
        variant: meta.variant,
        deadline_us: meta.deadline_us,
        priority: meta.priority,
        client: meta.client,
        trace: meta.trace,
    })
}

// ---------------------------------------------------------------------------
// Reply codec
// ---------------------------------------------------------------------------

/// Encode a success reply as one v2 frame; the output rows ride as the
/// raw payload.
pub fn encode_response(r: &InferResponse) -> Vec<u8> {
    let mut fields = vec![
        ("v", json::num(VERSION as f64)),
        ("ok", Value::Bool(true)),
        ("id", json::num(r.id as f64)),
        ("variant", json::s(&r.variant)),
        ("mape", json::num(r.mape)),
        ("nfe", json::num(r.nfe as f64)),
        ("latency_us", json::num(r.latency_us as f64)),
        ("batch_fill", json::num(r.batch_fill as f64)),
        ("rows", json::num(r.samples as f64)),
        ("dims", json::num(r.dims as f64)),
    ];
    // same omission convention as the v1 line: pre-trace frames are
    // byte-identical
    if let Some(t) = r.trace {
        fields.push(("trace", json::num(t as f64)));
    }
    frame_bytes(KIND_RESPONSE, &json::obj(fields), &r.output)
}

/// Encode an error reply as one v2 frame (empty payload). Carries the
/// same stable `code` strings as every other dialect, and echoes a
/// client-supplied trace id like the v1 error line.
pub fn encode_error(id: Option<u64>, trace: Option<u64>, e: &ApiError) -> Vec<u8> {
    let mut fields = vec![
        ("v", json::num(VERSION as f64)),
        ("ok", Value::Bool(false)),
    ];
    if let Some(id) = id {
        fields.push(("id", json::num(id as f64)));
    }
    fields.push(("code", json::s(e.code.as_str())));
    fields.push(("error", json::s(&e.message)));
    if let Some(t) = trace {
        fields.push(("trace", json::num(t as f64)));
    }
    frame_bytes(KIND_ERROR, &json::obj(fields), &[])
}

/// Decode one reply frame (client side), moving the payload into the
/// typed response. Mirrors [`v1::decode_reply`]'s leniency: unknown error
/// codes degrade to `internal` with the original string kept.
pub fn decode_reply(f: Frame) -> Result<InferReply, ApiError> {
    match f.kind {
        KIND_ERROR => {
            check_version(&f.header)?;
            if !f.payload.is_empty() {
                return Err(ApiError::bad_request(
                    "v2 error frame carries a non-empty payload",
                ));
            }
            let id = v1::field_u64(&f.header, "id")?;
            let code_s = v1::field_str(&f.header, "code")?.unwrap_or("internal");
            let message = v1::field_str(&f.header, "error")?.unwrap_or("").to_string();
            let error = match ErrorCode::from_wire(code_s) {
                Some(code) => ApiError::new(code, message),
                None => ApiError::internal(format!("unknown error code {code_s:?}: {message}")),
            };
            Ok(InferReply::Err(ErrorReply {
                id,
                error,
                trace: v1::field_u64(&f.header, "trace")?,
            }))
        }
        KIND_RESPONSE => {
            check_version(&f.header)?;
            if f.header.get("ok").and_then(Value::as_bool) != Some(true) {
                return Err(ApiError::bad_request(
                    "v2 response frame must carry ok: true",
                ));
            }
            let id = required_u64(&f.header, "id")?;
            let rows = required_u64(&f.header, "rows")?;
            let dims = required_u64(&f.header, "dims")?;
            let (samples, dims) = check_rows_dims(rows, dims, f.payload.len())?;
            Ok(InferReply::Ok(InferResponse {
                id,
                variant: v1::field_str(&f.header, "variant")?.unwrap_or("").to_string(),
                mape: f.header.get("mape").and_then(Value::as_f64).unwrap_or(f64::NAN),
                nfe: v1::field_u64(&f.header, "nfe")?.unwrap_or(0),
                latency_us: v1::field_u64(&f.header, "latency_us")?.unwrap_or(0),
                batch_fill: v1::field_u64(&f.header, "batch_fill")?.unwrap_or(0) as usize,
                samples,
                dims,
                output: f.payload,
                trace: v1::field_u64(&f.header, "trace")?,
            }))
        }
        other => Err(ApiError::bad_request(format!(
            "expected a reply frame, got kind {other}"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Priority;

    fn read_all(bytes: &[u8]) -> Result<Frame, FrameError> {
        let mut cur = bytes;
        read_frame(&mut cur)
    }

    #[test]
    fn request_frames_round_trip_with_v1_parity() {
        let mut r = InferRequest::batch("cnf_a", 0.25, 2, vec![1.0, 2.0, 3.0, 4.0]);
        r.id = Some(7);
        r.variant = Some("euler_k2".into());
        r.deadline_us = Some(5000);
        r.priority = Priority::High;
        r.client = Some("tenant-a".into());
        let frame = read_all(&encode_request(&r)).unwrap();
        assert_eq!(frame.kind, KIND_REQUEST);
        let back = decode_request(frame).unwrap();
        assert_eq!(back, r);
        // the same request through the v1 line codec decodes identically
        let (via_v1, _) = v1::decode_request(&v1::encode_request(&r)).unwrap();
        assert_eq!(back, via_v1);
    }

    #[test]
    fn response_and_error_frames_round_trip() {
        let resp = InferResponse {
            id: 9,
            variant: "hyperheun_k2".into(),
            mape: 0.02,
            nfe: 4,
            latency_us: 812,
            batch_fill: 4,
            samples: 2,
            dims: 2,
            output: vec![1.0, 2.0, 3.0, 4.0],
            trace: None,
        };
        match decode_reply(read_all(&encode_response(&resp)).unwrap()).unwrap() {
            InferReply::Ok(back) => assert_eq!(back, resp),
            other => panic!("{other:?}"),
        }
        for code in ErrorCode::ALL {
            let e = ApiError::new(code, format!("m-{code}"));
            match decode_reply(read_all(&encode_error(Some(5), None, &e)).unwrap()).unwrap() {
                InferReply::Err(back) => {
                    assert_eq!(back.id, Some(5));
                    assert_eq!(back.error, e);
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn trace_ids_round_trip_both_frame_kinds() {
        // request frames inherit the shared meta codec
        let mut r = InferRequest::single("t", 0.5, vec![1.0]);
        r.trace = Some(314);
        let back = decode_request(read_all(&encode_request(&r)).unwrap()).unwrap();
        assert_eq!(back.trace, Some(314));
        // reply frames echo, error frames echo, absent stays absent
        let resp = InferResponse {
            id: 1,
            variant: "euler_k2".into(),
            mape: 0.0,
            nfe: 2,
            latency_us: 5,
            batch_fill: 1,
            samples: 1,
            dims: 1,
            output: vec![0.5],
            trace: Some(314),
        };
        match decode_reply(read_all(&encode_response(&resp)).unwrap()).unwrap() {
            InferReply::Ok(back) => assert_eq!(back.trace, Some(314)),
            other => panic!("{other:?}"),
        }
        let e = ApiError::new(ErrorCode::Overloaded, "busy");
        match decode_reply(read_all(&encode_error(Some(2), Some(314), &e)).unwrap()).unwrap() {
            InferReply::Err(back) => assert_eq!(back.trace, Some(314)),
            other => panic!("{other:?}"),
        }
        match decode_reply(read_all(&encode_error(Some(2), None, &e)).unwrap()).unwrap() {
            InferReply::Err(back) => assert_eq!(back.trace, None),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn malformed_frames_fail_loudly_not_silently() {
        let good = encode_request(&InferRequest::single("t", 0.5, vec![1.0, 2.0]));
        // bad magic
        let mut b = good.clone();
        b[0] = b'{';
        assert!(matches!(read_all(&b), Err(FrameError::Bad(e)) if e.code == ErrorCode::BadRequest));
        // unknown kind
        let mut b = good.clone();
        b[1] = 9;
        assert!(matches!(read_all(&b), Err(FrameError::Bad(_))));
        // truncated header: cut the stream mid-frame
        let b = &good[..FRAME_PREFIX_LEN + 3];
        let err = read_all(b).unwrap_err();
        assert!(is_truncation(&err), "{err}");
        // truncated prefix
        let err = read_all(&good[..4]).unwrap_err();
        assert!(is_truncation(&err), "{err}");
        // header length overflow
        let mut b = good.clone();
        b[2..6].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(read_all(&b), Err(FrameError::Bad(_))));
        // payload length overflow
        let mut b = good.clone();
        b[6..10].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(read_all(&b), Err(FrameError::Bad(_))));
        // ragged payload: 2 rows × 2 dims declared, 3 values sent
        let mut r = InferRequest::batch("t", 0.5, 2, vec![1.0, 2.0, 3.0, 4.0]);
        r.input.pop();
        let frame = read_all(&encode_request(&r)).unwrap();
        assert_eq!(decode_request(frame).unwrap_err().code, ErrorCode::BadRequest);
        // payload not a multiple of 4 bytes
        let mut b = good.clone();
        let plen = u32::from_le_bytes(b[6..10].try_into().unwrap());
        b[6..10].copy_from_slice(&(plen - 1).to_le_bytes());
        assert!(matches!(read_all(&b), Err(FrameError::Bad(_))));
    }

    #[test]
    fn header_version_is_strict() {
        let good = encode_request(&InferRequest::single("t", 0.5, vec![1.0]));
        let mut frame = read_all(&good).unwrap();
        // rewrite the header's version tag: decode must reject it
        if let Value::Obj(m) = &mut frame.header {
            m.insert("v".into(), json::num(1.0));
        }
        assert_eq!(decode_request(frame).unwrap_err().code, ErrorCode::BadRequest);
    }
}
