//! Stable machine-readable error codes of the serving API.
//!
//! Every error that crosses the API boundary — wire lines, `Engine::submit`
//! rejections, batch-execution failures — carries one of these codes next
//! to its human-readable message, so clients can branch on `code` without
//! parsing prose. The code strings are part of the v1 wire contract:
//! **never rename one**; add new variants instead.

use std::fmt;

/// The closed set of machine-readable error codes (`code` field on every
/// error line). Wire strings are snake_case and frozen.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ErrorCode {
    /// Malformed request: bad JSON, wrong field type, unsupported
    /// protocol version, non-numeric `budget`/`deadline_us`, unknown
    /// `policy` axis.
    BadRequest,
    /// `task` names no manifest entry.
    UnknownTask,
    /// A pinned `variant` names no variant of the task.
    UnknownVariant,
    /// Input shape disagrees with the task's state shape (wrong sample
    /// dim, zero samples, or more samples than the executable batch).
    ShapeMismatch,
    /// The request's `deadline_us` elapsed before its batch dispatched;
    /// the request was dropped without executing (fail-fast).
    DeadlineExceeded,
    /// `cmd` names no server command.
    UnknownCmd,
    /// The execution backend failed the batch.
    ExecFailed,
    /// Server-side invariant violation (manifest drift, short backend
    /// output, dropped channels).
    Internal,
    /// The engine refused or dropped the request under load: admission
    /// control predicted its deadline unmeetable given the queue, the
    /// client's row quota was exhausted, or the request was shed at the
    /// queued-rows high-water mark.
    Overloaded,
    /// The routing layer could not reach any engine node for this
    /// request: every candidate on the placement ring was ejected,
    /// excluded by earlier failed attempts, or the retry budget /
    /// request deadline ran out mid-failover. Raised only by the
    /// cluster router — a single engine never emits it.
    UpstreamUnavailable,
}

impl ErrorCode {
    /// Every code, for exhaustive protocol tests.
    pub const ALL: [ErrorCode; 10] = [
        ErrorCode::BadRequest,
        ErrorCode::UnknownTask,
        ErrorCode::UnknownVariant,
        ErrorCode::ShapeMismatch,
        ErrorCode::DeadlineExceeded,
        ErrorCode::UnknownCmd,
        ErrorCode::ExecFailed,
        ErrorCode::Internal,
        ErrorCode::Overloaded,
        ErrorCode::UpstreamUnavailable,
    ];

    /// The frozen wire string.
    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::BadRequest => "bad_request",
            ErrorCode::UnknownTask => "unknown_task",
            ErrorCode::UnknownVariant => "unknown_variant",
            ErrorCode::ShapeMismatch => "shape_mismatch",
            ErrorCode::DeadlineExceeded => "deadline_exceeded",
            ErrorCode::UnknownCmd => "unknown_cmd",
            ErrorCode::ExecFailed => "exec_failed",
            ErrorCode::Internal => "internal",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::UpstreamUnavailable => "upstream_unavailable",
        }
    }

    pub fn from_wire(s: &str) -> Option<ErrorCode> {
        ErrorCode::ALL.into_iter().find(|c| c.as_str() == s)
    }
}

impl fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// A coded API error: stable `code` + human `message`. This is what the
/// engine's completion channel and every error line carry.
#[derive(Clone, Debug, PartialEq)]
pub struct ApiError {
    pub code: ErrorCode,
    pub message: String,
}

impl ApiError {
    pub fn new(code: ErrorCode, message: impl Into<String>) -> ApiError {
        ApiError {
            code,
            message: message.into(),
        }
    }

    pub fn bad_request(m: impl Into<String>) -> ApiError {
        ApiError::new(ErrorCode::BadRequest, m)
    }

    pub fn unknown_task(m: impl Into<String>) -> ApiError {
        ApiError::new(ErrorCode::UnknownTask, m)
    }

    pub fn unknown_variant(m: impl Into<String>) -> ApiError {
        ApiError::new(ErrorCode::UnknownVariant, m)
    }

    pub fn shape_mismatch(m: impl Into<String>) -> ApiError {
        ApiError::new(ErrorCode::ShapeMismatch, m)
    }

    pub fn deadline_exceeded(m: impl Into<String>) -> ApiError {
        ApiError::new(ErrorCode::DeadlineExceeded, m)
    }

    pub fn unknown_cmd(m: impl Into<String>) -> ApiError {
        ApiError::new(ErrorCode::UnknownCmd, m)
    }

    pub fn exec_failed(m: impl Into<String>) -> ApiError {
        ApiError::new(ErrorCode::ExecFailed, m)
    }

    pub fn internal(m: impl Into<String>) -> ApiError {
        ApiError::new(ErrorCode::Internal, m)
    }

    pub fn overloaded(m: impl Into<String>) -> ApiError {
        ApiError::new(ErrorCode::Overloaded, m)
    }

    pub fn upstream_unavailable(m: impl Into<String>) -> ApiError {
        ApiError::new(ErrorCode::UpstreamUnavailable, m)
    }

    /// Map a crate-level execution error onto the API code space (batch
    /// failures surfaced through the completion channel).
    pub fn from_engine(e: &crate::Error) -> ApiError {
        match e {
            crate::Error::Shape(m) => ApiError::shape_mismatch(m.clone()),
            other => ApiError::exec_failed(other.to_string()),
        }
    }
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.code, self.message)
    }
}

impl std::error::Error for ApiError {}

impl From<ApiError> for crate::Error {
    fn from(e: ApiError) -> crate::Error {
        crate::Error::Coordinator(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_round_trip_their_wire_strings() {
        for c in ErrorCode::ALL {
            assert_eq!(ErrorCode::from_wire(c.as_str()), Some(c));
        }
        assert_eq!(ErrorCode::from_wire("no_such_code"), None);
    }

    #[test]
    fn display_carries_code_and_message() {
        let e = ApiError::deadline_exceeded("waited 5000µs");
        assert_eq!(e.to_string(), "deadline_exceeded: waited 5000µs");
        let ce: crate::Error = e.into();
        assert!(ce.to_string().contains("deadline_exceeded"));
    }

    #[test]
    fn engine_errors_map_onto_codes() {
        let shape = ApiError::from_engine(&crate::Error::Shape("2 vs 3".into()));
        assert_eq!(shape.code, ErrorCode::ShapeMismatch);
        let other = ApiError::from_engine(&crate::Error::Other("boom".into()));
        assert_eq!(other.code, ErrorCode::ExecFailed);
    }
}
