//! Serving API **v1**: the typed request/response structs and the one
//! JSON-lines codec shared by the server, the client, and the Pareto
//! sweeps. Nothing else in the crate encodes or decodes wire lines.
//!
//! One JSON object per line, both directions. A v1 request:
//!
//! ```text
//! {"v": 1, "id": 7, "task": "cnf_rings", "budget": 0.05,
//!  "input": [[0.1, -0.7], [0.3, 0.2]],            // [B, dims]
//!  "policy": "nfe", "variant": "hypereuler_k2", "deadline_us": 5000}
//! ```
//!
//! and its (possibly out-of-order — correlate by `id`) response:
//!
//! ```text
//! {"v": 1, "ok": true, "id": 7, "variant": "hypereuler_k2",
//!  "mape": 0.042, "nfe": 2, "latency_us": 812, "batch_fill": 4,
//!  "output": [[...], [...]]}
//! ```
//!
//! Errors are `{"v": 1, "ok": false, "id": 7, "code": "...", "error":
//! "..."}` with a stable [`ErrorCode`] string. Optional request fields:
//! `id` (client correlation id, echoed; engine-assigned when absent),
//! `budget` (absent = cheapest available), `policy` (`"nfe" | "macs"`,
//! overrides the engine default axis), `variant` (pin an exact variant,
//! bypassing the policy), `deadline_us` (fail fast with
//! `deadline_exceeded` if the request has not *dispatched* within this
//! many µs — an execution already in flight is never cancelled),
//! `priority` (`"low" | "normal" | "high"`, breaks dispatch ties and
//! orders overload shedding; absent = `"normal"`), `client` (caller
//! identity string for per-client row quotas; absent = unattributed,
//! quota-exempt), `trace` (client-chosen trace id for the observability
//! plane, echoed on the reply — success *or* error — and attached to the
//! request's span; absent = server-assigned, visible only via
//! `cmd:"trace"`). An overloaded engine answers with the `overloaded`
//! code *before* queueing work it predicts cannot meet its deadline.
//!
//! **Versioning:** every v1 line carries `"v": 1`. A line without `"v"`
//! is a legacy v0 request (single flat sample, no id/policy/variant/
//! deadline); it is still answered, in the v0 response shape, with an
//! added `deprecation` notice. Any other `"v"` value is rejected with
//! `bad_request`. Parsing is strict in every version: a present field of
//! the wrong type (e.g. `"budget": "0.05"`) is `bad_request`, never a
//! silent default.

use crate::api::error::{ApiError, ErrorCode};
use crate::coordinator::policy::Policy;
use crate::coordinator::request::{Priority, Response};
use crate::util::json::{self, Value};

/// The protocol version this module speaks.
pub const VERSION: u64 = 1;

/// Notice attached to every answered v0 line.
pub const DEPRECATION: &str =
    "v0 single-sample lines are deprecated; send {\"v\": 1, ...} (see rust/README.md, API v1)";

/// A typed inference request — the in-process form of a v1 wire line.
#[derive(Clone, Debug, PartialEq)]
pub struct InferRequest {
    /// Client-chosen correlation id. `None` lets the server assign (and
    /// echo) the engine id.
    pub id: Option<u64>,
    pub task: String,
    /// Rows of the request batch.
    pub samples: usize,
    /// Values per row.
    pub dims: usize,
    /// Row-major `[samples, dims]` payload.
    pub input: Vec<f32>,
    /// Max acceptable terminal MAPE; `f32::INFINITY` = cheapest available.
    pub budget: f32,
    /// Per-request override of the engine's cost axis.
    pub policy: Option<Policy>,
    /// Pin an exact variant, bypassing the budget policy.
    pub variant: Option<String>,
    /// Fail fast with `deadline_exceeded` if not dispatched in time.
    pub deadline_us: Option<u64>,
    /// Priority class ("low"/"normal"/"high" on the wire); ties in EDF
    /// dispatch and shedding order. Defaults to [`Priority::Normal`].
    pub priority: Priority,
    /// Caller identity for per-client row quotas (absent = exempt).
    pub client: Option<String>,
    /// Client-chosen trace id, echoed on replies (success and error) and
    /// attached to the request's stage span. `None` lets the engine assign
    /// one, visible only via `cmd:"trace"`.
    pub trace: Option<u64>,
}

impl InferRequest {
    /// A single-sample request (the common case).
    pub fn single(task: &str, budget: f32, sample: Vec<f32>) -> InferRequest {
        let dims = sample.len();
        InferRequest {
            id: None,
            task: task.to_string(),
            samples: 1,
            dims,
            input: sample,
            budget,
            policy: None,
            variant: None,
            deadline_us: None,
            priority: Priority::default(),
            client: None,
            trace: None,
        }
    }

    /// A multi-sample request over a row-major `[samples, dims]` payload.
    ///
    /// # Panics
    /// If `input.len()` is not a positive multiple of `samples` — silently
    /// truncating a ragged payload would violate the module's
    /// loud-over-lossy contract.
    pub fn batch(task: &str, budget: f32, samples: usize, input: Vec<f32>) -> InferRequest {
        assert!(
            samples > 0 && !input.is_empty() && input.len() % samples == 0,
            "InferRequest::batch: {} values do not split into {samples} equal rows",
            input.len()
        );
        let dims = input.len() / samples;
        InferRequest {
            id: None,
            task: task.to_string(),
            samples,
            dims,
            input,
            budget,
            policy: None,
            variant: None,
            deadline_us: None,
            priority: Priority::default(),
            client: None,
            trace: None,
        }
    }

    /// The engine-level submission options this request carries — the one
    /// mapping from wire fields to
    /// [`SubmitOptions`](crate::coordinator::SubmitOptions), so server
    /// paths cannot drift apart field by field.
    pub fn submit_options(&self) -> crate::coordinator::SubmitOptions {
        crate::coordinator::SubmitOptions {
            policy: self.policy,
            variant: self.variant.clone(),
            deadline: self.deadline_us.map(std::time::Duration::from_micros),
            priority: self.priority,
            client: self.client.clone(),
            trace: self.trace,
        }
    }
}

/// A typed success reply.
#[derive(Clone, Debug, PartialEq)]
pub struct InferResponse {
    /// The correlation id (client-chosen when given, engine id otherwise).
    pub id: u64,
    pub variant: String,
    pub mape: f64,
    pub nfe: u64,
    pub latency_us: u64,
    /// Real rows in the executed batch (how well batching worked).
    pub batch_fill: usize,
    pub samples: usize,
    pub dims: usize,
    /// Row-major `[samples, dims]` output.
    pub output: Vec<f32>,
    /// Echo of the client-supplied trace id; `None` (and omitted on the
    /// wire) when the request carried none — golden replies stay stable.
    pub trace: Option<u64>,
}

/// A typed error reply.
#[derive(Clone, Debug, PartialEq)]
pub struct ErrorReply {
    pub id: Option<u64>,
    pub error: ApiError,
    /// Echo of the client-supplied trace id, when the line that failed
    /// carried a valid one.
    pub trace: Option<u64>,
}

/// One decoded reply line.
#[derive(Clone, Debug, PartialEq)]
pub enum InferReply {
    Ok(InferResponse),
    Err(ErrorReply),
}

impl InferReply {
    pub fn id(&self) -> Option<u64> {
        match self {
            InferReply::Ok(r) => Some(r.id),
            InferReply::Err(e) => e.id,
        }
    }
}

// ---------------------------------------------------------------------------
// Strict field readers
// ---------------------------------------------------------------------------

pub(crate) fn field_u64(v: &Value, key: &str) -> Result<Option<u64>, ApiError> {
    match v.get(key) {
        None => Ok(None),
        Some(x) => {
            let n = x.as_f64().ok_or_else(|| {
                ApiError::bad_request(format!("{key} must be a number"))
            })?;
            if !(n.is_finite() && n >= 0.0 && n.fract() == 0.0 && n <= 2f64.powi(53)) {
                return Err(ApiError::bad_request(format!(
                    "{key} must be a non-negative integer, got {n}"
                )));
            }
            Ok(Some(n as u64))
        }
    }
}

pub(crate) fn field_str(v: &Value, key: &str) -> Result<Option<&str>, ApiError> {
    match v.get(key) {
        None => Ok(None),
        Some(Value::Str(s)) => Ok(Some(s)),
        Some(_) => Err(ApiError::bad_request(format!("{key} must be a string"))),
    }
}

/// Best-effort read of a line's `id` field with the same validation the
/// codec applies — for echoing ids on lines that failed to decode (an
/// invalid id yields `None`, never a second definition of validity).
pub fn peek_id(v: &Value) -> Option<u64> {
    field_u64(v, "id").ok().flatten()
}

/// Best-effort read of a line's `trace` field, same contract as
/// [`peek_id`] — for echoing trace ids on lines that failed to decode.
pub fn peek_trace(v: &Value) -> Option<u64> {
    field_u64(v, "trace").ok().flatten()
}

/// Wire version of a line: `None` "v" key → 0; `1` → 1; else rejected.
pub fn wire_version(v: &Value) -> Result<u8, ApiError> {
    match v.get("v") {
        None => Ok(0),
        Some(x) => match x.as_f64() {
            Some(n) if n == VERSION as f64 => Ok(1),
            _ => Err(ApiError::bad_request(format!(
                "unsupported protocol version {x:?} (JSON lines speak v{VERSION} \
                 or legacy v0; v2 is a binary frame, not a JSON line — see \
                 rust/README.md §\"Wire protocol v2\")"
            ))),
        },
    }
}

// ---------------------------------------------------------------------------
// Request codec
// ---------------------------------------------------------------------------

/// Decode one request line (already parsed to a [`Value`]); returns the
/// typed request plus the wire version it arrived in (0 or 1), so the
/// reply can be encoded in the same dialect. Strict: any present field of
/// the wrong type or value is a [`ErrorCode::BadRequest`].
pub fn decode_request(v: &Value) -> Result<(InferRequest, u8), ApiError> {
    let version = wire_version(v)?;
    if v.as_obj().is_none() {
        return Err(ApiError::bad_request("request must be a JSON object"));
    }
    let task = field_str(v, "task")?
        .ok_or_else(|| ApiError::bad_request("missing task"))?
        .to_string();

    let input_v = v
        .get("input")
        .ok_or_else(|| ApiError::bad_request("missing input"))?;
    let (input, shape) = input_v
        .as_f32_tensor()
        .map_err(|e| ApiError::bad_request(format!("input must be a numeric array: {e}")))?;
    let (samples, dims) = match shape.len() {
        1 => (1, shape[0]),
        2 => (shape[0], shape[1]),
        r => {
            return Err(ApiError::bad_request(format!(
                "input must be [dims] or [samples, dims], got rank {r}"
            )))
        }
    };
    if samples == 0 || dims == 0 {
        return Err(ApiError::bad_request("input has no samples"));
    }
    if version == 0 && shape.len() != 1 {
        return Err(ApiError::bad_request(
            "v0 lines carry one flat sample; send {\"v\": 1, ...} for multi-sample input",
        ));
    }

    let budget = decode_budget(v)?;

    // the v1-only fields: on v0 lines they are ignored entirely, exactly
    // as the pre-v1 server (which read only task/budget/input) did — a
    // legacy client whose lines carry extraneous keys must keep working
    let meta = if version == 1 {
        decode_meta(v)?
    } else {
        WireMeta::default()
    };

    Ok((
        InferRequest {
            id: meta.id,
            task,
            samples,
            dims,
            input,
            budget,
            policy: meta.policy,
            variant: meta.variant,
            deadline_us: meta.deadline_us,
            priority: meta.priority,
            client: meta.client,
            trace: meta.trace,
        },
        version,
    ))
}

/// Strict read of the `budget` field (absent = infinite = cheapest
/// available) — shared by the v1 line codec and the v2 frame header, so
/// the dialects cannot drift on what a malformed budget means.
pub(crate) fn decode_budget(v: &Value) -> Result<f32, ApiError> {
    match v.get("budget") {
        None => Ok(f32::INFINITY),
        Some(b) => {
            let b = b.as_f64().ok_or_else(|| {
                ApiError::bad_request("budget must be a number (e.g. 0.05, not \"0.05\")")
            })?;
            if b.is_nan() {
                return Err(ApiError::bad_request("budget must not be NaN"));
            }
            Ok(b as f32)
        }
    }
}

/// The optional request metadata shared by the v1 line and the v2 frame
/// header: correlation id, policy axis, pinned variant, deadline,
/// priority class, client identity.
#[derive(Debug, Default)]
pub(crate) struct WireMeta {
    pub id: Option<u64>,
    pub policy: Option<Policy>,
    pub variant: Option<String>,
    pub deadline_us: Option<u64>,
    pub priority: Priority,
    pub client: Option<String>,
    pub trace: Option<u64>,
}

/// Strict decode of the [`WireMeta`] fields from a request object — the
/// one mapping both codecs apply, so v2 headers inherit v1's semantics
/// (and its loud rejections) field for field.
pub(crate) fn decode_meta(v: &Value) -> Result<WireMeta, ApiError> {
    let policy = match field_str(v, "policy")? {
        None => None,
        Some("nfe") => Some(Policy::MinNfe),
        Some("macs") => Some(Policy::MinMacs),
        Some(other) => {
            return Err(ApiError::bad_request(format!(
                "policy must be \"nfe\" or \"macs\", got {other:?}"
            )))
        }
    };
    let priority = match field_str(v, "priority")? {
        None => Priority::default(),
        Some(s) => Priority::from_wire(s).ok_or_else(|| {
            ApiError::bad_request(format!(
                "priority must be \"low\", \"normal\" or \"high\", got {s:?}"
            ))
        })?,
    };
    Ok(WireMeta {
        id: field_u64(v, "id")?,
        policy,
        variant: field_str(v, "variant")?.map(str::to_string),
        deadline_us: field_u64(v, "deadline_us")?,
        priority,
        client: field_str(v, "client")?.map(str::to_string),
        trace: field_u64(v, "trace")?,
    })
}

/// Encode a request as a v1 wire line. An infinite budget is omitted
/// (absent = cheapest, the wire convention); input is always nested
/// `[samples, dims]`.
pub fn encode_request(r: &InferRequest) -> Value {
    let mut fields = vec![
        ("v", json::num(VERSION as f64)),
        ("task", json::s(&r.task)),
        ("input", rows_value(&r.input, r.samples, r.dims)),
    ];
    push_meta_fields(&mut fields, r);
    json::obj(fields)
}

/// Append the optional request fields shared by the v1 line and the v2
/// frame header, with the frozen omission conventions (absent id, infinite
/// budget, `normal` priority are all omitted — golden-byte stability).
pub(crate) fn push_meta_fields(fields: &mut Vec<(&'static str, Value)>, r: &InferRequest) {
    if let Some(id) = r.id {
        fields.push(("id", json::num(id as f64)));
    }
    if r.budget.is_finite() {
        fields.push(("budget", json::num(r.budget as f64)));
    }
    if let Some(p) = r.policy {
        let s = match p {
            Policy::MinNfe => "nfe",
            Policy::MinMacs => "macs",
        };
        fields.push(("policy", json::s(s)));
    }
    if let Some(vn) = &r.variant {
        fields.push(("variant", json::s(vn)));
    }
    if let Some(d) = r.deadline_us {
        fields.push(("deadline_us", json::num(d as f64)));
    }
    // the default class is omitted, keeping pre-priority golden lines
    // byte-identical
    if r.priority != Priority::Normal {
        fields.push(("priority", json::s(r.priority.as_str())));
    }
    if let Some(c) = &r.client {
        fields.push(("client", json::s(c)));
    }
    if let Some(t) = r.trace {
        fields.push(("trace", json::num(t as f64)));
    }
}

fn rows_value(data: &[f32], samples: usize, dims: usize) -> Value {
    Value::Arr(
        (0..samples)
            .map(|i| json::arr_f32(&data[i * dims..(i + 1) * dims]))
            .collect(),
    )
}

// ---------------------------------------------------------------------------
// Response codec
// ---------------------------------------------------------------------------

/// Build the typed reply from an engine [`Response`] plus the request
/// metadata the engine does not carry (correlation id, row count). The
/// output row width is derived from the response itself — variants may
/// legitimately have `out_dim != in_dim` (image→logits exports), so the
/// request's input dims must never be used to slice the output.
pub fn response_from_engine(id: u64, samples: usize, r: &Response) -> InferResponse {
    let dims = if samples > 0 {
        r.output.len() / samples
    } else {
        0
    };
    InferResponse {
        id,
        variant: r.variant.clone(),
        mape: r.mape,
        nfe: r.nfe,
        latency_us: r.latency.as_micros() as u64,
        batch_fill: r.batch_fill,
        samples,
        dims,
        output: r.output.clone(),
        trace: None,
    }
}

/// Encode a success reply in the given wire dialect: v1 nests the output
/// as `[samples, dims]`; v0 reproduces the legacy flat shape and adds the
/// `deprecation` notice.
pub fn encode_response(r: &InferResponse, version: u8) -> Value {
    if version == 0 {
        return json::obj(vec![
            ("ok", Value::Bool(true)),
            ("id", json::num(r.id as f64)),
            ("variant", json::s(&r.variant)),
            ("mape", json::num(r.mape)),
            ("nfe", json::num(r.nfe as f64)),
            ("latency_us", json::num(r.latency_us as f64)),
            ("batch_fill", json::num(r.batch_fill as f64)),
            ("output", json::arr_f32(&r.output)),
            ("deprecation", json::s(DEPRECATION)),
        ]);
    }
    let mut fields = vec![
        ("v", json::num(VERSION as f64)),
        ("ok", Value::Bool(true)),
        ("id", json::num(r.id as f64)),
        ("variant", json::s(&r.variant)),
        ("mape", json::num(r.mape)),
        ("nfe", json::num(r.nfe as f64)),
        ("latency_us", json::num(r.latency_us as f64)),
        ("batch_fill", json::num(r.batch_fill as f64)),
        ("output", rows_value(&r.output, r.samples, r.dims)),
    ];
    // echoed only when the request carried one — pre-trace golden replies
    // stay byte-identical
    if let Some(t) = r.trace {
        fields.push(("trace", json::num(t as f64)));
    }
    json::obj(fields)
}

/// Encode an error reply. Both dialects carry `code` + `error`; v1 adds
/// the version tag, echoes the id when one is known, and echoes a
/// client-supplied trace id so rejected requests stay correlatable.
pub fn encode_error(id: Option<u64>, trace: Option<u64>, e: &ApiError, version: u8) -> Value {
    let mut fields = Vec::with_capacity(6);
    if version != 0 {
        fields.push(("v", json::num(VERSION as f64)));
    }
    fields.push(("ok", Value::Bool(false)));
    if let Some(id) = id {
        fields.push(("id", json::num(id as f64)));
    }
    fields.push(("code", json::s(e.code.as_str())));
    fields.push(("error", json::s(&e.message)));
    if let Some(t) = trace {
        fields.push(("trace", json::num(t as f64)));
    }
    json::obj(fields)
}

/// Decode one reply line into the typed form (client side). Unknown
/// `code` strings degrade to [`ErrorCode::Internal`] with the original
/// string kept in the message.
pub fn decode_reply(v: &Value) -> Result<InferReply, ApiError> {
    let ok = v
        .get("ok")
        .and_then(Value::as_bool)
        .ok_or_else(|| ApiError::bad_request("reply missing ok"))?;
    let id = field_u64(v, "id")?;
    let trace = field_u64(v, "trace")?;
    if !ok {
        let code_s = field_str(v, "code")?.unwrap_or("internal");
        let message = field_str(v, "error")?.unwrap_or("").to_string();
        let error = match ErrorCode::from_wire(code_s) {
            Some(code) => ApiError::new(code, message),
            None => ApiError::internal(format!("unknown error code {code_s:?}: {message}")),
        };
        return Ok(InferReply::Err(ErrorReply { id, error, trace }));
    }
    let id = id.ok_or_else(|| ApiError::bad_request("ok reply missing id"))?;
    let (output, shape) = v
        .get("output")
        .ok_or_else(|| ApiError::bad_request("ok reply missing output"))?
        .as_f32_tensor()
        .map_err(|e| ApiError::bad_request(format!("reply output: {e}")))?;
    let (samples, dims) = match shape.len() {
        1 => (1, shape[0]),
        2 => (shape[0], shape[1]),
        r => {
            return Err(ApiError::bad_request(format!(
                "reply output has rank {r}"
            )))
        }
    };
    Ok(InferReply::Ok(InferResponse {
        id,
        variant: field_str(v, "variant")?.unwrap_or("").to_string(),
        mape: v.get("mape").and_then(Value::as_f64).unwrap_or(f64::NAN),
        nfe: field_u64(v, "nfe")?.unwrap_or(0),
        latency_us: field_u64(v, "latency_us")?.unwrap_or(0),
        batch_fill: field_u64(v, "batch_fill")?.unwrap_or(0) as usize,
        samples,
        dims,
        output,
        trace,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v1_request_encodes_to_the_golden_line() {
        // budget/input values are dyadic (exact in f32 AND f64), so the
        // widened f64 prints exactly these digits
        let mut r = InferRequest::batch("cnf_a", 0.25, 2, vec![1.0, 2.0, 3.0, 4.0]);
        r.id = Some(7);
        r.policy = Some(Policy::MinNfe);
        r.variant = Some("euler_k2".into());
        r.deadline_us = Some(5000);
        // BTreeMap ordering makes the wire line deterministic — golden
        assert_eq!(
            json::to_string(&encode_request(&r)),
            r#"{"budget":0.25,"deadline_us":5000,"id":7,"input":[[1,2],[3,4]],"policy":"nfe","task":"cnf_a","v":1,"variant":"euler_k2"}"#
        );
    }

    #[test]
    fn v1_request_round_trips() {
        let mut r = InferRequest::batch("t", 0.1, 3, vec![0.5; 6]);
        r.id = Some(3);
        r.deadline_us = Some(100);
        r.priority = Priority::High;
        r.client = Some("tenant-a".into());
        let (back, version) = decode_request(&encode_request(&r)).unwrap();
        assert_eq!(version, 1);
        assert_eq!(back, r);
        // the normal class is omitted on the wire and restored on decode
        let r = InferRequest::single("t", 0.1, vec![1.0]);
        let enc = encode_request(&r);
        assert!(enc.get("priority").is_none() && enc.get("client").is_none());
        let (back, _) = decode_request(&enc).unwrap();
        assert_eq!(back.priority, Priority::Normal);
        // infinite budget is omitted on the wire and restored on decode
        let r = InferRequest::single("t", f32::INFINITY, vec![1.0]);
        let enc = encode_request(&r);
        assert!(enc.get("budget").is_none());
        let (back, _) = decode_request(&enc).unwrap();
        assert_eq!(back.budget, f32::INFINITY);
    }

    #[test]
    #[should_panic(expected = "do not split")]
    fn ragged_batch_constructor_panics_loudly() {
        // 7 values cannot split into 3 rows — truncating silently would
        // serve a wrong batch with no error anywhere
        let _ = InferRequest::batch("t", 0.1, 3, vec![0.0; 7]);
    }

    #[test]
    fn peek_id_shares_the_codec_validation() {
        let v = json::parse(r#"{"id":7,"task":3}"#).unwrap();
        assert_eq!(peek_id(&v), Some(7));
        // invalid ids yield None under the same rules decode_request uses
        for line in [r#"{"id":-1}"#, r#"{"id":1.5}"#, r#"{"id":"7"}"#, r#"{}"#] {
            assert_eq!(peek_id(&json::parse(line).unwrap()), None, "{line}");
        }
    }

    #[test]
    fn v0_lines_decode_as_version_zero() {
        let v = json::parse(r#"{"task":"cnf_a","budget":0.5,"input":[0.3,-0.2]}"#).unwrap();
        let (r, version) = decode_request(&v).unwrap();
        assert_eq!(version, 0);
        assert_eq!(r.samples, 1);
        assert_eq!(r.dims, 2);
        assert_eq!(r.input, vec![0.3, -0.2]);
        assert!(r.id.is_none() && r.policy.is_none() && r.deadline_us.is_none());
        // v0 cannot carry multi-sample input
        let v = json::parse(r#"{"task":"t","input":[[1,2],[3,4]]}"#).unwrap();
        assert_eq!(decode_request(&v).unwrap_err().code, ErrorCode::BadRequest);
        // v1-only fields on a v0 line are IGNORED (the pre-v1 server read
        // only task/budget/input), never honored and never rejected —
        // even when their values would be invalid in v1
        let v = json::parse(
            r#"{"task":"t","input":[1,2],"policy":"speed","variant":7,
                "deadline_us":-1,"id":"x","priority":"urgent","client":3}"#,
        )
        .unwrap();
        let (r, version) = decode_request(&v).unwrap();
        assert_eq!(version, 0);
        assert!(r.id.is_none() && r.policy.is_none());
        assert!(r.variant.is_none() && r.deadline_us.is_none());
        assert_eq!(r.priority, Priority::Normal);
        assert!(r.client.is_none());
    }

    #[test]
    fn strict_fields_reject_loudly() {
        let bad = [
            // the historical silent footgun: a string budget served the
            // cheapest variant; now it is a loud bad_request
            r#"{"v":1,"task":"t","budget":"0.05","input":[1]}"#,
            r#"{"v":1,"task":"t","budget":null,"input":[1]}"#,
            r#"{"v":1,"task":"t","policy":"speed","input":[1]}"#,
            r#"{"v":1,"task":"t","policy":3,"input":[1]}"#,
            r#"{"v":1,"task":"t","deadline_us":"5","input":[1]}"#,
            r#"{"v":1,"task":"t","deadline_us":-3,"input":[1]}"#,
            r#"{"v":1,"task":"t","deadline_us":1.5,"input":[1]}"#,
            r#"{"v":1,"task":"t","id":-1,"input":[1]}"#,
            r#"{"v":1,"task":"t","variant":7,"input":[1]}"#,
            r#"{"v":1,"task":"t","priority":"urgent","input":[1]}"#,
            r#"{"v":1,"task":"t","priority":2,"input":[1]}"#,
            r#"{"v":1,"task":"t","client":7,"input":[1]}"#,
            r#"{"v":1,"task":"t","input":[[1,2],[3]]}"#,
            r#"{"v":1,"task":"t","input":[[[1]]]}"#,
            r#"{"v":1,"task":"t","input":[]}"#,
            r#"{"v":1,"task":"t","input":["a"]}"#,
            r#"{"v":1,"input":[1]}"#,
            r#"{"v":1,"task":3,"input":[1]}"#,
            r#"{"v":2,"task":"t","input":[1]}"#,
            r#"{"v":"1","task":"t","input":[1]}"#,
        ];
        for line in bad {
            let v = json::parse(line).unwrap();
            let e = decode_request(&v).unwrap_err();
            assert_eq!(e.code, ErrorCode::BadRequest, "{line} -> {e}");
        }
    }

    #[test]
    fn response_round_trips_both_dialects() {
        let r = InferResponse {
            id: 9,
            variant: "hypereuler_k2".into(),
            mape: 0.04,
            nfe: 2,
            latency_us: 812,
            batch_fill: 4,
            samples: 2,
            dims: 2,
            output: vec![1.0, 2.0, 3.0, 4.0],
            trace: None,
        };
        let v1 = encode_response(&r, 1);
        assert_eq!(v1.get("v").and_then(Value::as_f64), Some(1.0));
        match decode_reply(&v1).unwrap() {
            InferReply::Ok(back) => assert_eq!(back, r),
            other => panic!("{other:?}"),
        }
        // v0: flat output + deprecation notice, no version tag
        let v0 = encode_response(&r, 0);
        assert!(v0.get("v").is_none());
        assert_eq!(v0.get("deprecation").and_then(Value::as_str), Some(DEPRECATION));
        let flat = v0.get("output").unwrap().as_arr().unwrap();
        assert_eq!(flat.len(), 4);
        assert!(flat[0].as_f64().is_some());
    }

    #[test]
    fn errors_round_trip_every_code() {
        for code in ErrorCode::ALL {
            let e = ApiError::new(code, format!("details of {code}"));
            for version in [0u8, 1] {
                let enc = encode_error(Some(5), None, &e, version);
                assert_eq!(enc.get("ok").and_then(Value::as_bool), Some(false));
                assert_eq!(enc.get("code").and_then(Value::as_str), Some(code.as_str()));
                match decode_reply(&enc).unwrap() {
                    InferReply::Err(back) => {
                        assert_eq!(back.id, Some(5));
                        assert_eq!(back.error, e);
                    }
                    other => panic!("{other:?}"),
                }
            }
        }
        // unknown code degrades to internal but keeps the string
        let v = json::parse(r#"{"ok":false,"code":"weird","error":"x"}"#).unwrap();
        match decode_reply(&v).unwrap() {
            InferReply::Err(back) => {
                assert_eq!(back.error.code, ErrorCode::Internal);
                assert!(back.error.message.contains("weird"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn trace_ids_ride_the_wire_when_present_and_vanish_when_absent() {
        // requests: trace encodes, round-trips, and is strictly typed
        let mut r = InferRequest::single("t", 0.5, vec![1.0]);
        r.trace = Some(99);
        let enc = encode_request(&r);
        assert_eq!(enc.get("trace").and_then(Value::as_f64), Some(99.0));
        let (back, _) = decode_request(&enc).unwrap();
        assert_eq!(back.trace, Some(99));
        assert_eq!(back.submit_options().trace, Some(99));
        let v = json::parse(r#"{"v":1,"task":"t","trace":"x","input":[1]}"#).unwrap();
        assert_eq!(decode_request(&v).unwrap_err().code, ErrorCode::BadRequest);
        // the untraced request line has no trace key at all
        r.trace = None;
        assert!(encode_request(&r).get("trace").is_none());

        // replies: echoed on success and on errors, omitted when None
        let mut resp = InferResponse {
            id: 1,
            variant: "euler_k2".into(),
            mape: 0.0,
            nfe: 2,
            latency_us: 10,
            batch_fill: 1,
            samples: 1,
            dims: 1,
            output: vec![0.5],
            trace: Some(99),
        };
        let enc = encode_response(&resp, 1);
        assert_eq!(enc.get("trace").and_then(Value::as_f64), Some(99.0));
        match decode_reply(&enc).unwrap() {
            InferReply::Ok(back) => assert_eq!(back.trace, Some(99)),
            other => panic!("{other:?}"),
        }
        resp.trace = None;
        assert!(encode_response(&resp, 1).get("trace").is_none());
        let err = ApiError::new(ErrorCode::Overloaded, "busy");
        let enc = encode_error(Some(5), Some(99), &err, 1);
        match decode_reply(&enc).unwrap() {
            InferReply::Err(back) => assert_eq!(back.trace, Some(99)),
            other => panic!("{other:?}"),
        }
        assert!(encode_error(Some(5), None, &err, 1).get("trace").is_none());
    }
}
