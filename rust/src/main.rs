//! `hypersolverd` — the hypersolver serving daemon.
//!
//! Subcommands:
//!   serve    run the coordinator + TCP JSON-lines server (default)
//!   tasks    list artifact tasks and their variants
//!   infer    one-shot inference from the command line
//!
//! Examples:
//!   hypersolverd serve --addr 127.0.0.1:7878 --max-wait-ms 2
//!   hypersolverd serve --backend native --workers 4
//!   hypersolverd tasks
//!   hypersolverd infer --task cnf_rings --budget 0.05 --input 0.3,-0.7
//!   hypersolverd infer --task cnf_rings --variant dopri5 --input 0.3,-0.7 \
//!       --deadline-us 5000
//!
//! The TCP wire protocol is API v1 (see rust/README.md §"Serving API v1").

use std::sync::Arc;
use std::time::Duration;

use hypersolvers::coordinator::{server, Engine, EngineConfig, Policy, Priority, SubmitOptions};
use hypersolvers::runtime::{BackendKind, Manifest};
use hypersolvers::util::cli::Cli;

fn main() {
    let parsed = Cli::new("hypersolverd — hypersolver model serving daemon")
        .opt("addr", "127.0.0.1:7878", "listen address for `serve`")
        .opt(
            "metrics-addr",
            "",
            "Prometheus exposition listen address for `serve` (empty = off)",
        )
        .opt("artifacts", "", "artifacts directory (default: ./artifacts)")
        .opt("max-wait-ms", "2", "dynamic batching deadline in ms")
        .opt("policy", "macs", "variant cost axis: macs | nfe")
        .opt("backend", "pjrt", "execution backend: pjrt | native")
        .opt("workers", "0", "dispatch workers (0 = auto)")
        .opt(
            "admission",
            "on",
            "SLO admission control: reject at submit when the deadline is predicted unmeetable (on | off)",
        )
        .opt(
            "shed-rows",
            "0",
            "queued-rows high-water mark; overflow sheds lowest-priority work (0 = off)",
        )
        .opt("quota-rows", "0", "per-client queued-row quota (0 = off)")
        .opt(
            "audit-rate",
            "0",
            "shadow-audit sampling fraction of completed requests in [0, 1] (0 = off)",
        )
        .opt(
            "audit-tol",
            "1e-6",
            "dopri5 tolerance for the audit plane's reference re-solves",
        )
        .opt(
            "matmul-threads",
            "0",
            "dedicated row-block matmul pool for large gemms (0 = off)",
        )
        .opt("task", "", "task for `infer`")
        .opt("budget", "0.05", "MAPE budget for `infer`")
        .opt("input", "", "comma-separated f32 sample for `infer`")
        .opt("variant", "", "pin an exact variant for `infer` (bypasses the policy)")
        .opt(
            "deadline-us",
            "0",
            "fail `infer` fast with deadline_exceeded after this many µs (0 = none)",
        )
        .opt("priority", "normal", "`infer` priority class: low | normal | high")
        .opt("client", "", "client id for `infer` (per-client quota accounting)")
        .parse_env();

    let cmd = parsed
        .positionals()
        .first()
        .map(String::as_str)
        .unwrap_or("serve")
        .to_string();

    // Optional dedicated pool for row-block-parallel gemms (bit-identical
    // results; see tensor::set_matmul_pool). Off by default: the small CNF
    // shapes never clear the size threshold, but the image-task convs and
    // hypertrain's wide hidden layers do.
    let matmul_threads = parsed.get_usize("matmul-threads");
    if matmul_threads > 0 {
        hypersolvers::tensor::set_matmul_pool(Arc::new(
            hypersolvers::util::threadpool::ThreadPool::new(matmul_threads),
        ));
        eprintln!("matmul pool: {matmul_threads} workers");
    }

    let backend = match BackendKind::from_name(&parsed.get("backend")) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let mut config = EngineConfig {
        max_wait: Duration::from_millis(parsed.get_usize("max-wait-ms") as u64),
        policy: match parsed.get("policy").as_str() {
            "nfe" => Policy::MinNfe,
            _ => Policy::MinMacs,
        },
        backend,
        workers: parsed.get_usize("workers"),
        ..Default::default()
    };
    if !parsed.get("artifacts").is_empty() {
        config.artifacts_dir = parsed.get("artifacts").into();
    }
    config.slo.admission = match parsed.get("admission").as_str() {
        "on" => true,
        "off" => false,
        other => {
            eprintln!("error: --admission must be \"on\" or \"off\", got {other:?}");
            std::process::exit(2);
        }
    };
    config.slo.shed_high_water_rows = parsed.get_usize("shed-rows");
    config.slo.client_quota_rows = parsed.get_usize("quota-rows");
    config.audit.rate = parsed.get_f64("audit-rate");
    if !(0.0..=1.0).contains(&config.audit.rate) {
        eprintln!(
            "error: --audit-rate must be in [0, 1], got {}",
            config.audit.rate
        );
        std::process::exit(2);
    }
    config.audit.tol = parsed.get_f64("audit-tol") as f32;
    if !(config.audit.tol.is_finite() && config.audit.tol > 0.0) {
        eprintln!(
            "error: --audit-tol must be a positive number, got {}",
            config.audit.tol
        );
        std::process::exit(2);
    }

    let result = match cmd.as_str() {
        "tasks" => cmd_tasks(&config),
        "infer" => cmd_infer(
            config,
            &parsed.get("task"),
            parsed.get_f64("budget") as f32,
            &parsed.get("input"),
            &parsed.get("variant"),
            parsed.get_usize("deadline-us") as u64,
            &parsed.get("priority"),
            &parsed.get("client"),
        ),
        "serve" => cmd_serve(config, &parsed.get("addr"), &parsed.get("metrics-addr")),
        other => {
            eprintln!("unknown command {other:?} (serve | tasks | infer)");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn cmd_tasks(config: &EngineConfig) -> hypersolvers::Result<()> {
    let manifest = Manifest::load(&config.artifacts_dir)?;
    println!("artifacts: {} (stamp {})", manifest.dir.display(), manifest.stamp);
    for (name, task) in &manifest.tasks {
        println!(
            "\n{name} [{}] state {:?}, MAC_f={} MAC_g={} δ={:.4}",
            task.kind, task.state_shape, task.mac_f, task.mac_g, task.delta
        );
        for v in &task.variants {
            println!(
                "  {:<18} nfe={:<4} macs={:<9} mape={:.4}{}",
                v.name,
                v.nfe,
                v.macs,
                v.mape,
                v.acc_drop
                    .map(|d| format!(" acc_drop={d:.4}"))
                    .unwrap_or_default()
            );
        }
    }
    Ok(())
}

fn cmd_infer(
    config: EngineConfig,
    task: &str,
    budget: f32,
    input_csv: &str,
    variant: &str,
    deadline_us: u64,
    priority: &str,
    client: &str,
) -> hypersolvers::Result<()> {
    if task.is_empty() {
        return Err(hypersolvers::Error::Other("--task is required".into()));
    }
    let priority = Priority::from_wire(priority).ok_or_else(|| {
        hypersolvers::Error::Other(format!(
            "--priority must be \"low\", \"normal\" or \"high\", got {priority:?}"
        ))
    })?;
    let input: Vec<f32> = input_csv
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.trim().parse().unwrap_or(0.0))
        .collect();
    let engine = Engine::new(config)?;
    let opts = SubmitOptions {
        policy: None,
        variant: (!variant.is_empty()).then(|| variant.to_string()),
        deadline: (deadline_us > 0).then(|| Duration::from_micros(deadline_us)),
        priority,
        client: (!client.is_empty()).then(|| client.to_string()),
        trace: None,
    };
    let resp = engine
        .submit_opts(task, budget, input, 1, &opts)
        .map_err(|e| hypersolvers::Error::Other(format!("[{}] {}", e.code, e.message)))?
        .wait()
        .map_err(|e| hypersolvers::Error::Other(format!("[{}] {}", e.code, e.message)))?;
    println!(
        "variant={} mape≤{:.4} nfe={} latency={:?}\noutput={:?}",
        resp.variant, resp.mape, resp.nfe, resp.latency, resp.output
    );
    Ok(())
}

fn cmd_serve(config: EngineConfig, addr: &str, metrics_addr: &str) -> hypersolvers::Result<()> {
    let engine = Arc::new(Engine::new(config)?);
    if !metrics_addr.is_empty() {
        let engine = Arc::clone(&engine);
        let metrics_addr = metrics_addr.to_string();
        println!("metrics exposition on {metrics_addr}");
        std::thread::spawn(move || {
            if let Err(e) = server::serve_metrics(engine, &metrics_addr) {
                eprintln!("metrics listener failed: {e}");
            }
        });
    }
    println!("hypersolverd serving on {addr} — ctrl-c to stop");
    server::serve(engine, addr)
}
