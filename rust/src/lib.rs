//! # Hypersolvers — fast continuous-depth model inference
//!
//! Rust + JAX + Pallas reproduction of *"Hypersolvers: Toward Fast
//! Continuous-Depth Models"* (Poli & Massaroli et al., NeurIPS 2020).
//!
//! Architecture (see `DESIGN.md`):
//!
//! * **Layer 1/2 (build time)** — Pallas kernels + JAX Neural ODE models,
//!   trained and AOT-lowered to HLO text by `python/compile/aot.py`.
//!   Python never runs on the request path.
//! * **Layer 3 (this crate)** — the serving coordinator: it loads the AOT
//!   artifacts through PJRT ([`runtime`]), batches inference requests and
//!   picks the cheapest `(solver, K)` variant that satisfies each
//!   request's error budget ([`coordinator`]) — the paper's accuracy/compute
//!   pareto front made operational.
//!
//! The crate also carries a complete *native* inference stack ([`tensor`],
//! [`nn`], [`solvers`], [`ode`]) that evaluates the trained networks from
//! exported weights without PJRT; the benches use it for dense parameter
//! sweeps (every figure of the paper) and the tests use it to cross-validate
//! the PJRT path numerically.
//!
//! The [`util`] module contains substrates this offline environment forced
//! us to build from scratch: PRNG, JSON codec, CLI parsing, thread pool,
//! a bench harness (`benchkit`) and a property-test harness (`propkit`).

pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod nn;
pub mod ode;
pub mod runtime;
pub mod solvers;
pub mod tensor;
pub mod util;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("json error: {0}")]
    Json(String),
    #[error("manifest error: {0}")]
    Manifest(String),
    #[error("xla error: {0}")]
    Xla(String),
    #[error("shape error: {0}")]
    Shape(String),
    #[error("coordinator error: {0}")]
    Coordinator(String),
    #[error("{0}")]
    Other(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Default artifacts directory, overridable via `HYPERSOLVERS_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("HYPERSOLVERS_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| {
            // crate root (works from tests/benches/examples alike)
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}
