//! # Hypersolvers — fast continuous-depth model inference
//!
//! Rust + JAX + Pallas reproduction of *"Hypersolvers: Toward Fast
//! Continuous-Depth Models"* (Poli & Massaroli et al., NeurIPS 2020).
//!
//! Architecture (see `DESIGN.md`):
//!
//! * **Layer 1/2 (build time)** — Pallas kernels + JAX Neural ODE models,
//!   trained and AOT-lowered to HLO text by `python/compile/aot.py`.
//!   Python never runs on the request path.
//! * **Layer 3 (this crate)** — the serving coordinator: it batches
//!   inference requests, picks the cheapest `(solver, K)` variant that
//!   satisfies each request's error budget ([`coordinator`]) — the paper's
//!   accuracy/compute pareto front made operational — and executes batches
//!   on a worker pool against a pluggable execution backend
//!   ([`runtime::ExecBackend`]): PJRT over the AOT artifacts, or the
//!   native solver stack.
//!
//! The crate also carries a complete *native* inference stack ([`tensor`],
//! [`nn`], [`solvers`], [`ode`]) that evaluates the trained networks from
//! exported weights without PJRT; it backs the `native` serving backend
//! (and with it the artifact-free engine test harness), the benches' dense
//! parameter sweeps (every figure of the paper), and the numeric
//! cross-validation of the PJRT path. See `rust/README.md` for the engine
//! architecture and backend selection.
//!
//! The native stack's hot path is **allocation-free**: `_into`/`_inplace`
//! kernels write into [`tensor::Workspace`]-pooled buffers, solvers step on
//! a reusable [`solvers::RkWorkspace`], and the serving runtime holds one
//! workspace per (task, variant) queue — zero steady-state heap traffic in
//! the solver loop (see rust/README.md §"The workspace hot path").
//!
//! The [`train`] module closes the paper's loop *inside* the repo: it fits
//! hypersolver nets by residual regression (hand-rolled reverse-mode
//! gradients + Adam over the same `_ws` kernels that serve), and exports
//! weights the native backend loads unchanged — see the `hypertrain`
//! binary and rust/README.md §"Training hypersolvers in-repo".
//!
//! The [`pareto`] module *measures* the paper's headline claim end to end:
//! the `hyperbench` binary sweeps a (solver × step-count/tolerance × task)
//! grid through the `_ws` kernels and the native serve path, extracts
//! dominance-correct Pareto fronts, and emits `BENCH_pareto.json` plus a
//! rolling `BENCH_trajectory.json` — the repo's permanent bench
//! trajectory (rust/README.md §"Pareto evaluation & the bench
//! trajectory").
//!
//! The [`util`] module contains substrates this offline environment forced
//! us to build from scratch: PRNG, JSON codec, CLI parsing, thread pool,
//! a bench harness (`benchkit`) and a property-test harness (`propkit`).

pub mod api;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod nn;
pub mod obs;
pub mod ode;
pub mod pareto;
pub mod router;
pub mod runtime;
pub mod solvers;
pub mod tensor;
pub mod train;
pub mod util;

/// Crate-wide error type (hand-rolled Display/Error impls — proc-macro
/// crates like `thiserror` are not available offline).
#[derive(Debug)]
pub enum Error {
    Io(std::io::Error),
    Json(String),
    Manifest(String),
    Xla(String),
    Shape(String),
    Coordinator(String),
    Other(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Manifest(m) => write!(f, "manifest error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Other(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Default artifacts directory, overridable via `HYPERSOLVERS_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("HYPERSOLVERS_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| {
            // crate root (works from tests/benches/examples alike)
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}
