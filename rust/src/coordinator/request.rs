//! Request/response types of the serving surface.

use std::sync::mpsc;
use std::time::Instant;

use crate::api::ApiError;

/// Priority class of a request. Higher classes win dispatch ties when two
/// queues are equally urgent, and lower classes are shed first under
/// overload. The wire strings ("low"/"normal"/"high") are frozen.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    Low,
    #[default]
    Normal,
    High,
}

impl Priority {
    /// The frozen wire string.
    pub fn as_str(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }

    pub fn from_wire(s: &str) -> Option<Priority> {
        match s {
            "low" => Some(Priority::Low),
            "normal" => Some(Priority::Normal),
            "high" => Some(Priority::High),
            _ => None,
        }
    }
}

/// One inference request: a batch of `samples` rows for `task`, plus the
/// accuracy budget the caller is willing to tolerate.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// task name, e.g. "cnf_rings"
    pub task: String,
    /// maximum acceptable terminal MAPE vs the dopri5 reference;
    /// `f32::INFINITY` means "cheapest available"
    pub budget: f32,
    /// row-major `[samples, dims]` payload (dims = task state dims
    /// without the batch dim)
    pub input: Vec<f32>,
    /// rows carried by this request (1 for the classic single-sample case)
    pub samples: usize,
    /// enqueue timestamp (set by the engine)
    pub t_submit: Instant,
    /// fail fast with `deadline_exceeded` if the request has not been
    /// dispatched to the backend by this instant (`None` = no deadline)
    pub deadline: Option<Instant>,
    /// priority class: breaks EDF dispatch ties, and lower classes are
    /// shed first under overload
    pub priority: Priority,
    /// client identity for per-client row quotas (`None` = unattributed,
    /// exempt from quotas)
    pub client: Option<String>,
}

impl Request {
    pub fn new(id: u64, task: &str, budget: f32, input: Vec<f32>, samples: usize) -> Request {
        Request {
            id,
            task: task.to_string(),
            budget,
            input,
            samples,
            t_submit: Instant::now(),
            deadline: None,
            priority: Priority::default(),
            client: None,
        }
    }
}

/// The coordinator's answer.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// flattened row-major `[samples, dims]` output
    pub output: Vec<f32>,
    /// which variant served it
    pub variant: String,
    /// that variant's measured MAPE (the bound the policy enforced)
    pub mape: f64,
    /// NFEs spent on this sample's batch (per sample)
    pub nfe: u64,
    /// end-to-end latency
    pub latency: std::time::Duration,
    /// how many real rows shared the executed batch
    pub batch_fill: usize,
}

/// One finished submission, delivered on the completion channel the
/// caller handed to [`Engine::submit_with`](crate::coordinator::Engine::submit_with).
/// `id` is the engine-assigned submission id, so many in-flight requests
/// can share one channel and still be correlated.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub result: Result<Response, ApiError>,
}

/// The channel completions arrive on. One sender clone travels with each
/// queued request; the engine never blocks on it.
pub type CompletionSender = mpsc::Sender<Completion>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_construction() {
        let r = Request::new(7, "cnf_rings", 0.05, vec![1.0, 2.0], 1);
        assert_eq!(r.id, 7);
        assert_eq!(r.task, "cnf_rings");
        assert_eq!(r.samples, 1);
        assert!(r.deadline.is_none());
        assert_eq!(r.priority, Priority::Normal);
        assert!(r.client.is_none());
        assert!(r.t_submit.elapsed().as_secs() < 1);
    }

    #[test]
    fn priority_classes_order_and_round_trip() {
        assert!(Priority::Low < Priority::Normal);
        assert!(Priority::Normal < Priority::High);
        assert_eq!(Priority::default(), Priority::Normal);
        for p in [Priority::Low, Priority::Normal, Priority::High] {
            assert_eq!(Priority::from_wire(p.as_str()), Some(p));
        }
        assert_eq!(Priority::from_wire("urgent"), None);
    }

    #[test]
    fn completions_share_a_channel_by_id() {
        let (tx, rx) = mpsc::channel();
        for id in [3u64, 1, 2] {
            tx.send(Completion {
                id,
                result: Err(ApiError::internal("test")),
            })
            .unwrap();
        }
        let ids: Vec<u64> = rx.try_iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![3, 1, 2]);
    }
}
