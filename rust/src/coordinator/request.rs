//! Request/response types of the serving surface.

use std::sync::mpsc;
use std::time::Instant;

use crate::api::ApiError;

/// A contiguous row-major `[rows, dims]` block of `f32` values — the one
/// payload type both wire codecs decode into and the engine/batcher queue.
/// Binary v2 frames read their raw little-endian row bytes straight into
/// `data`; v1 JSON lines flatten into the same shape. Either way
/// [`Engine::submit_with`](crate::coordinator::Engine::submit_with) and the
/// batcher never see a per-row `Vec<Vec<f32>>` or re-copy the payload.
///
/// The constructors don't validate `rows × dims` against `data.len()` —
/// the engine checks the block against the task's state shape at submit,
/// so a malformed block fails loudly with `shape_mismatch` instead of
/// panicking inside the server.
#[derive(Clone, Debug, PartialEq)]
pub struct RowBlock {
    /// rows carried (1 for the classic single-sample case)
    pub rows: usize,
    /// values per row
    pub dims: usize,
    /// row-major `[rows, dims]` values
    pub data: Vec<f32>,
}

impl RowBlock {
    pub fn new(rows: usize, dims: usize, data: Vec<f32>) -> RowBlock {
        RowBlock { rows, dims, data }
    }

    /// Build from a flat payload and a row count, deriving `dims`
    /// (`rows == 0` keeps the raw length so the mismatch stays visible to
    /// the engine's validation).
    pub fn from_rows(rows: usize, data: Vec<f32>) -> RowBlock {
        let dims = if rows > 0 { data.len() / rows } else { data.len() };
        RowBlock { rows, dims, data }
    }

    /// One row — the classic single-sample surface.
    pub fn single(sample: Vec<f32>) -> RowBlock {
        RowBlock {
            rows: 1,
            dims: sample.len(),
            data: sample,
        }
    }

    /// Total values carried (`rows × dims` when well-formed).
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

/// Priority class of a request. Higher classes win dispatch ties when two
/// queues are equally urgent, and lower classes are shed first under
/// overload. The wire strings ("low"/"normal"/"high") are frozen.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    Low,
    #[default]
    Normal,
    High,
}

impl Priority {
    /// The frozen wire string.
    pub fn as_str(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }

    pub fn from_wire(s: &str) -> Option<Priority> {
        match s {
            "low" => Some(Priority::Low),
            "normal" => Some(Priority::Normal),
            "high" => Some(Priority::High),
            _ => None,
        }
    }
}

/// One inference request: a batch of `samples` rows for `task`, plus the
/// accuracy budget the caller is willing to tolerate.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// task name, e.g. "cnf_rings"
    pub task: String,
    /// maximum acceptable terminal MAPE vs the dopri5 reference;
    /// `f32::INFINITY` means "cheapest available"
    pub budget: f32,
    /// the contiguous `[rows, dims]` payload block (dims = task state
    /// dims without the batch dim)
    pub block: RowBlock,
    /// enqueue timestamp (set by the engine)
    pub t_submit: Instant,
    /// fail fast with `deadline_exceeded` if the request has not been
    /// dispatched to the backend by this instant (`None` = no deadline)
    pub deadline: Option<Instant>,
    /// priority class: breaks EDF dispatch ties, and lower classes are
    /// shed first under overload
    pub priority: Priority,
    /// client identity for per-client row quotas (`None` = unattributed,
    /// exempt from quotas)
    pub client: Option<String>,
    /// trace id: client-supplied via the wire `trace` field, or
    /// server-generated at submit — never 0 once the engine accepts it
    pub trace: u64,
    /// whether `trace` was supplied by the client (echoed on replies
    /// only then, keeping traceless wire lines byte-stable)
    pub trace_client: bool,
    /// per-stage monotonic timestamps, stamped along the pipeline; the
    /// completed record lands in the span ring (`cmd:"trace"`)
    pub stamps: crate::obs::StageStamps,
}

impl Request {
    pub fn new(id: u64, task: &str, budget: f32, input: Vec<f32>, samples: usize) -> Request {
        Request::from_block(id, task, budget, RowBlock::from_rows(samples, input))
    }

    /// Construct from an already-assembled [`RowBlock`] (the codec path —
    /// no reshaping, the block moves in as-is).
    pub fn from_block(id: u64, task: &str, budget: f32, block: RowBlock) -> Request {
        Request {
            id,
            task: task.to_string(),
            budget,
            block,
            t_submit: Instant::now(),
            deadline: None,
            priority: Priority::default(),
            client: None,
            trace: 0,
            trace_client: false,
            stamps: crate::obs::StageStamps::default(),
        }
    }
}

/// The coordinator's answer.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// flattened row-major `[samples, dims]` output
    pub output: Vec<f32>,
    /// which variant served it
    pub variant: String,
    /// that variant's measured MAPE (the bound the policy enforced)
    pub mape: f64,
    /// NFEs spent on this sample's batch (per sample)
    pub nfe: u64,
    /// end-to-end latency
    pub latency: std::time::Duration,
    /// how many real rows shared the executed batch
    pub batch_fill: usize,
}

/// One finished submission, delivered on the completion channel the
/// caller handed to [`Engine::submit_with`](crate::coordinator::Engine::submit_with).
/// `id` is the engine-assigned submission id, so many in-flight requests
/// can share one channel and still be correlated.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub result: Result<Response, ApiError>,
}

/// The channel completions arrive on. One sender clone travels with each
/// queued request; the engine never blocks on it.
pub type CompletionSender = mpsc::Sender<Completion>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_construction() {
        let r = Request::new(7, "cnf_rings", 0.05, vec![1.0, 2.0], 1);
        assert_eq!(r.id, 7);
        assert_eq!(r.task, "cnf_rings");
        assert_eq!(r.block.rows, 1);
        assert_eq!(r.block.dims, 2);
        assert_eq!(r.block.data, vec![1.0, 2.0]);
        assert!(r.deadline.is_none());
        assert_eq!(r.priority, Priority::Normal);
        assert!(r.client.is_none());
        assert!(r.t_submit.elapsed().as_secs() < 1);
    }

    #[test]
    fn row_blocks_carry_shape_without_reshaping() {
        let b = RowBlock::from_rows(2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!((b.rows, b.dims, b.len()), (2, 2, 4));
        assert!(!b.is_empty());
        let s = RowBlock::single(vec![5.0, 6.0, 7.0]);
        assert_eq!((s.rows, s.dims), (1, 3));
        // zero rows keep the raw length visible instead of dividing by 0
        let z = RowBlock::from_rows(0, vec![9.0]);
        assert_eq!((z.rows, z.dims, z.len()), (0, 1, 1));
        // explicit constructor trusts the caller; the engine validates
        let e = RowBlock::new(3, 2, vec![0.0; 5]);
        assert_eq!(e.len(), 5);
    }

    #[test]
    fn priority_classes_order_and_round_trip() {
        assert!(Priority::Low < Priority::Normal);
        assert!(Priority::Normal < Priority::High);
        assert_eq!(Priority::default(), Priority::Normal);
        for p in [Priority::Low, Priority::Normal, Priority::High] {
            assert_eq!(Priority::from_wire(p.as_str()), Some(p));
        }
        assert_eq!(Priority::from_wire("urgent"), None);
    }

    #[test]
    fn completions_share_a_channel_by_id() {
        let (tx, rx) = mpsc::channel();
        for id in [3u64, 1, 2] {
            tx.send(Completion {
                id,
                result: Err(ApiError::internal("test")),
            })
            .unwrap();
        }
        let ids: Vec<u64> = rx.try_iter().map(|c| c.id).collect();
        assert_eq!(ids, vec![3, 1, 2]);
    }
}
