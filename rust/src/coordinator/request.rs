//! Request/response types of the serving surface.

use std::time::Instant;

/// One inference request: a single sample for `task`, plus the accuracy
/// budget the caller is willing to tolerate.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// task name, e.g. "cnf_rings"
    pub task: String,
    /// maximum acceptable terminal MAPE vs the dopri5 reference;
    /// `f32::INFINITY` means "cheapest available"
    pub budget: f32,
    /// one flattened sample (task state dims without the batch dim)
    pub input: Vec<f32>,
    /// enqueue timestamp (set by the engine)
    pub t_submit: Instant,
}

impl Request {
    pub fn new(id: u64, task: &str, budget: f32, input: Vec<f32>) -> Request {
        Request {
            id,
            task: task.to_string(),
            budget,
            input,
            t_submit: Instant::now(),
        }
    }
}

/// The coordinator's answer.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// flattened output sample
    pub output: Vec<f32>,
    /// which variant served it
    pub variant: String,
    /// that variant's measured MAPE (the bound the policy enforced)
    pub mape: f64,
    /// NFEs spent on this sample's batch (per sample)
    pub nfe: u64,
    /// end-to-end latency
    pub latency: std::time::Duration,
    /// how many real samples shared the executed batch
    pub batch_fill: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_construction() {
        let r = Request::new(7, "cnf_rings", 0.05, vec![1.0, 2.0]);
        assert_eq!(r.id, 7);
        assert_eq!(r.task, "cnf_rings");
        assert!(r.t_submit.elapsed().as_secs() < 1);
    }
}
