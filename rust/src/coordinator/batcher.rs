//! Dynamic batching: per-(task, variant) queues flushed on batch-full or
//! deadline.
//!
//! The exported executables have a fixed batch dimension B, so a batch is
//! (a) full when B *rows* are queued (a request may carry several rows —
//! the v1 multi-sample surface), or (b) forced when the oldest queued
//! request has waited `max_wait` — the standard dynamic batching policy of
//! serving systems (vLLM/Triton style), applied at the ODE-solve level. A
//! request carrying its own `deadline` pulls the queue's flush point
//! earlier, so fail-fast deadline checks happen at dispatch time rather
//! than after a full `max_wait`.

use std::collections::{HashMap, HashSet, VecDeque};
use std::time::{Duration, Instant};

use crate::coordinator::request::{CompletionSender, Priority, Request};

/// How far ahead of a request's deadline its queue is flushed, covering
/// the condvar wake-up + pop + batch assembly so dispatch starts before
/// the deadline passes (see [`Batcher::flush_at`]). Generous enough for
/// a loaded scheduler; still small against real serving deadlines.
pub const DEADLINE_FLUSH_MARGIN: Duration = Duration::from_millis(2);

/// Assemble a padded batch input into a reusable buffer: `cap` rows of
/// `dim` values. Each slice contributes `len / dim` consecutive rows (a
/// multi-sample request is one contiguous row block); remaining fill rows
/// are zeroed. This is the dispatch hot path's form — each worker reuses
/// one buffer across batches (the `RkWorkspace` pattern), so steady-state
/// batch staging allocates nothing once the buffer has grown to the
/// largest `cap × dim` it serves.
pub fn pad_batch_into<'a>(
    out: &mut Vec<f32>,
    samples: impl IntoIterator<Item = &'a [f32]>,
    cap: usize,
    dim: usize,
) {
    out.clear();
    out.resize(cap * dim, 0.0);
    let mut off = 0usize;
    for s in samples {
        if off >= out.len() {
            break;
        }
        let n = s.len().min(out.len() - off);
        out[off..off + n].copy_from_slice(&s[..n]);
        off += n;
    }
}

/// [`pad_batch_into`] as a pure function returning a fresh `Vec` —
/// bit-identical output (same clamping, same zero fill), kept for callers
/// and tests that don't hold a reusable buffer.
pub fn pad_batch(samples: &[&[f32]], cap: usize, dim: usize) -> Vec<f32> {
    let mut out = Vec::new();
    pad_batch_into(&mut out, samples.iter().copied(), cap, dim);
    out
}

/// A request waiting in a queue, with its completion channel.
pub struct Pending {
    pub req: Request,
    pub done: CompletionSender,
}

impl std::fmt::Debug for Pending {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pending").field("req", &self.req).finish_non_exhaustive()
    }
}

/// Rows a queued request occupies in every accounting path — push, drain,
/// shed and quota bookkeeping all route through this one definition, so a
/// zero-sample request (which still occupies a batch slot) can never drift
/// `Queue::rows` against the cap/readiness math.
pub fn rows(p: &Pending) -> usize {
    p.req.block.rows.max(1)
}

/// Queue key: (task, variant) — requests routed to the same executable batch
/// together regardless of their exact budgets.
pub type QueueKey = (String, String);

/// A batch ready for execution.
pub struct ReadyBatch {
    pub key: QueueKey,
    pub items: Vec<Pending>,
}

/// Queue depth snapshot for one (task, variant) queue.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QueueDepth {
    pub task: String,
    pub variant: String,
    /// queued requests
    pub requests: usize,
    /// queued rows (a request may carry several)
    pub rows: usize,
}

/// One (task, variant) queue with O(1) readiness bookkeeping: the row
/// count is maintained incrementally, and queues that carry no explicit
/// per-request deadlines (the common case) derive their flush point from
/// the front item alone — the readiness scan under the engine lock stays
/// O(#queues), not O(#queued requests).
struct Queue {
    items: VecDeque<Pending>,
    /// executable batch capacity, in rows
    cap: usize,
    /// total queued rows (maintained on push/pop)
    rows: usize,
    /// queued requests carrying an explicit deadline; only queues with
    /// deadline users pay the O(len) flush-point scan
    deadline_count: usize,
}

/// Per-variant FIFO queues with deadline tracking. Not internally
/// synchronised — the engine wraps it in a mutex and a condvar.
pub struct Batcher {
    queues: HashMap<QueueKey, Queue>,
    max_wait: Duration,
    /// per-client queued-row quota (0 = unlimited)
    quota_rows: usize,
    /// rows currently queued per client identity
    client_rows: HashMap<String, usize>,
}

impl Batcher {
    pub fn new(max_wait: Duration) -> Batcher {
        Batcher {
            queues: HashMap::new(),
            max_wait,
            quota_rows: 0,
            client_rows: HashMap::new(),
        }
    }

    /// Cap the rows any single client may hold queued at once (0 =
    /// unlimited). Requests carrying a `client` identity are rejected at
    /// [`Self::push`] once the quota is reached; unattributed requests
    /// are exempt.
    pub fn with_client_quota(mut self, rows: usize) -> Batcher {
        self.quota_rows = rows;
        self
    }

    /// Register the executable batch size for a queue (first sight).
    pub fn ensure_queue(&mut self, key: &QueueKey, batch_size: usize) {
        self.queues.entry(key.clone()).or_insert_with(|| Queue {
            items: VecDeque::new(),
            cap: batch_size,
            rows: 0,
            deadline_count: 0,
        });
    }

    /// Enqueue a request. `Err` hands the request back untouched when the
    /// client's row quota would be exceeded — the caller owns the refusal
    /// (the engine maps it onto `overloaded`).
    pub fn push(&mut self, key: &QueueKey, p: Pending) -> Result<(), Pending> {
        if self.quota_rows > 0 {
            if let Some(client) = &p.req.client {
                let used = self.client_rows.get(client).copied().unwrap_or(0);
                if used + rows(&p) > self.quota_rows {
                    return Err(p);
                }
            }
        }
        let q = self.queues.get_mut(key).expect("ensure_queue before push");
        if let Some(client) = &p.req.client {
            *self.client_rows.entry(client.clone()).or_insert(0) += rows(&p);
        }
        q.rows += rows(&p);
        q.deadline_count += usize::from(p.req.deadline.is_some());
        q.items.push_back(p);
        Ok(())
    }

    /// Rows currently queued on one (task, variant) queue (0 when absent).
    /// Admission control reads this to predict the wait ahead of a new
    /// request before enqueueing it.
    pub fn queue_rows(&self, key: &QueueKey) -> usize {
        self.queues.get(key).map(|q| q.rows).unwrap_or(0)
    }

    /// Queued requests across all queues.
    pub fn queued(&self) -> usize {
        self.queues.values().map(|q| q.items.len()).sum()
    }

    /// Queued rows across all queues.
    pub fn queued_rows(&self) -> usize {
        self.queues.values().map(|q| q.rows).sum()
    }

    /// Per-queue depth snapshot, sorted by (task, variant) so callers get
    /// a deterministic report (the `cmd:"metrics"` surface).
    pub fn depths(&self) -> Vec<QueueDepth> {
        let mut out: Vec<QueueDepth> = self
            .queues
            .iter()
            .map(|(k, q)| QueueDepth {
                task: k.0.clone(),
                variant: k.1.clone(),
                requests: q.items.len(),
                rows: q.rows,
            })
            .collect();
        out.sort_by(|a, b| (&a.task, &a.variant).cmp(&(&b.task, &b.variant)));
        out
    }

    /// When this request must be flushed: its `max_wait` point, pulled
    /// earlier by an explicit per-request deadline. The deadline pull
    /// lands [`DEADLINE_FLUSH_MARGIN`] *before* the deadline itself, so a
    /// request whose deadline precedes the batching wait still dispatches
    /// in time and executes — the deadline is a usable latency SLO, not
    /// just a failure timer. (A deadline already within the margin of
    /// `t_submit` flushes immediately and fails fast at dispatch.)
    fn flush_at(&self, p: &Pending) -> Instant {
        let wait_dl = p.req.t_submit + self.max_wait;
        match p.req.deadline {
            Some(d) => {
                let early = d
                    .checked_sub(DEADLINE_FLUSH_MARGIN)
                    .map(|e| e.max(p.req.t_submit))
                    .unwrap_or(p.req.t_submit);
                wait_dl.min(early)
            }
            None => wait_dl,
        }
    }

    /// Earliest flush point of a queue (None when empty). O(1) when no
    /// queued request carries a deadline: items arrive in submit order, so
    /// the front holds the earliest `t_submit + max_wait`.
    fn queue_flush_deadline(&self, q: &Queue) -> Option<Instant> {
        if q.deadline_count == 0 {
            return q.items.front().map(|p| p.req.t_submit + self.max_wait);
        }
        q.items.iter().map(|p| self.flush_at(p)).min()
    }

    /// Pop the single most-urgent ready batch (rows full, or a flush
    /// deadline passed) whose key is not in `busy`.
    ///
    /// Dispatch is earliest-deadline-first: among ready queues the one
    /// whose flush/deadline point is earliest wins (for deadline-free
    /// queues that point is `front.t_submit + max_wait`, which reduces to
    /// the old oldest-first order), and the front request's priority class
    /// breaks exact ties — `High` beats `Normal` beats `Low`.
    ///
    /// This is the worker-pool pop: each dispatch worker takes one batch at
    /// a time, and `busy` carries the keys currently executing on other
    /// workers — per-queue affinity, so a queue's batches never run (or
    /// complete) out of order while batches for *distinct* (task, variant)
    /// queues execute concurrently. Requests are never split: the drain
    /// stops before a request whose rows would overflow the cap.
    pub fn pop_ready(&mut self, now: Instant, busy: &HashSet<QueueKey>) -> Option<ReadyBatch> {
        let mut best: Option<((Instant, std::cmp::Reverse<Priority>), QueueKey)> = None;
        for (key, q) in &self.queues {
            if busy.contains(key) {
                continue;
            }
            let front = match q.items.front() {
                Some(p) => p,
                None => continue,
            };
            let urgency = match self.queue_flush_deadline(q) {
                Some(d) => d,
                None => continue,
            };
            let ready = q.rows >= q.cap || now >= urgency;
            if !ready {
                continue;
            }
            let cand = (urgency, std::cmp::Reverse(front.req.priority));
            if best.as_ref().map(|(b, _)| cand < *b).unwrap_or(true) {
                best = Some((cand, key.clone()));
            }
        }
        let (_, key) = best?;
        let q = self.queues.get_mut(&key).expect("queue exists");
        let cap = q.cap;
        let mut items: Vec<Pending> = Vec::new();
        let mut taken = 0usize;
        while let Some(p) = q.items.front() {
            let r = rows(p);
            if !items.is_empty() && taken + r > cap {
                break;
            }
            taken += r;
            let mut p = q.items.pop_front().expect("front exists");
            p.req.stamps.stamp(crate::obs::Stage::Pop);
            q.rows -= rows(&p);
            q.deadline_count -= usize::from(p.req.deadline.is_some());
            if let Some(client) = &p.req.client {
                if let Some(c) = self.client_rows.get_mut(client) {
                    *c = c.saturating_sub(rows(&p));
                    if *c == 0 {
                        self.client_rows.remove(client);
                    }
                }
            }
            items.push(p);
            if taken >= cap {
                break;
            }
        }
        Some(ReadyBatch { key, items })
    }

    /// Shed queued requests until total queued rows drop to `target_rows`,
    /// removing lowest-priority, latest-deadline victims first (a request
    /// without a deadline is "latest" within its class — it promised the
    /// least, so it is sacrificed first). Returns the shed requests so the
    /// engine can fail their completions with `overloaded`; row, deadline
    /// and quota accounting all stay consistent.
    pub fn shed_to(&mut self, target_rows: usize) -> Vec<Pending> {
        let far = Instant::now() + Duration::from_secs(365 * 24 * 3600);
        let mut shed = Vec::new();
        while self.queued_rows() > target_rows {
            let mut victim: Option<((Priority, std::cmp::Reverse<Instant>), QueueKey, usize)> =
                None;
            for (key, q) in &self.queues {
                for (i, p) in q.items.iter().enumerate() {
                    let cand = (
                        p.req.priority,
                        std::cmp::Reverse(p.req.deadline.unwrap_or(far)),
                    );
                    if victim.as_ref().map(|(v, _, _)| cand < *v).unwrap_or(true) {
                        victim = Some((cand, key.clone(), i));
                    }
                }
            }
            let (_, key, i) = match victim {
                Some(v) => v,
                None => break,
            };
            let q = self.queues.get_mut(&key).expect("queue exists");
            let p = q.items.remove(i).expect("victim index exists");
            q.rows -= rows(&p);
            q.deadline_count -= usize::from(p.req.deadline.is_some());
            if let Some(client) = &p.req.client {
                if let Some(c) = self.client_rows.get_mut(client) {
                    *c = c.saturating_sub(rows(&p));
                    if *c == 0 {
                        self.client_rows.remove(client);
                    }
                }
            }
            shed.push(p);
        }
        shed
    }

    /// Earliest flush deadline across all queues (None when idle) —
    /// drives the engine's condvar timeout.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .values()
            .filter_map(|q| self.queue_flush_deadline(q))
            .min()
    }

    /// [`Self::next_deadline`] restricted to queues not in `busy`. Workers
    /// wait on this: a busy queue's (already expired) deadline must not turn
    /// the condvar wait into a spin — its completion `notify_all` is the
    /// wake-up signal for that queue, not a timeout.
    pub fn next_deadline_idle(&self, busy: &HashSet<QueueKey>) -> Option<Instant> {
        self.queues
            .iter()
            .filter(|(k, _)| !busy.contains(*k))
            .filter_map(|(_, q)| self.queue_flush_deadline(q))
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Completion;
    use std::sync::mpsc;

    fn pending(id: u64, at: Instant) -> (Pending, mpsc::Receiver<Completion>) {
        pending_rows(id, at, 1)
    }

    fn pending_rows(
        id: u64,
        at: Instant,
        rows: usize,
    ) -> (Pending, mpsc::Receiver<Completion>) {
        let (tx, rx) = mpsc::channel();
        let mut req = Request::new(id, "t", 0.1, vec![0.0; rows], rows);
        req.t_submit = at;
        (Pending { req, done: tx }, rx)
    }

    fn key() -> QueueKey {
        ("t".to_string(), "v".to_string())
    }

    #[test]
    fn flushes_on_full_batch() {
        let mut b = Batcher::new(Duration::from_secs(10));
        b.ensure_queue(&key(), 3);
        let now = Instant::now();
        for i in 0..7 {
            let (p, _rx) = pending(i, now);
            std::mem::forget(_rx);
            b.push(&key(), p).unwrap();
        }
        // 7 queued, batch 3 → two full batches pop, one item stays queued
        // (not full, deadline far away)
        let busy = HashSet::new();
        assert_eq!(b.pop_ready(now, &busy).unwrap().items.len(), 3);
        assert_eq!(b.pop_ready(now, &busy).unwrap().items.len(), 3);
        assert!(b.pop_ready(now, &busy).is_none());
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn multi_row_requests_fill_by_rows_and_never_split() {
        let mut b = Batcher::new(Duration::from_secs(10));
        b.ensure_queue(&key(), 4);
        let now = Instant::now();
        // rows: 2 + 1 + 2 + 3 = 8; cap 4
        for (i, rows) in [(0u64, 2usize), (1, 1), (2, 2), (3, 3)] {
            let (p, _rx) = pending_rows(i, now, rows);
            std::mem::forget(_rx);
            b.push(&key(), p).unwrap();
        }
        let busy = HashSet::new();
        // first pop: 2 + 1 = 3 rows, then the 2-row request would overflow
        let batch = b.pop_ready(now, &busy).unwrap();
        assert_eq!(
            batch.items.iter().map(|p| p.req.id).collect::<Vec<_>>(),
            vec![0, 1]
        );
        // second pop needs rows: 2 + 3 = 5 ≥ cap, ready; takes the 2-row
        // request alone (3 more would overflow)
        let batch = b.pop_ready(now, &busy).unwrap();
        assert_eq!(
            batch.items.iter().map(|p| p.req.id).collect::<Vec<_>>(),
            vec![2]
        );
        // last request alone: 3 rows < cap 4, deadline far → not ready
        assert!(b.pop_ready(now, &busy).is_none());
        assert_eq!(b.queued(), 1);
        assert_eq!(b.queued_rows(), 3);
    }

    #[test]
    fn request_deadline_pulls_the_flush_earlier() {
        let mut b = Batcher::new(Duration::from_secs(60));
        b.ensure_queue(&key(), 64);
        let now = Instant::now();
        let (mut p, _rx) = pending(0, now);
        std::mem::forget(_rx);
        p.req.deadline = Some(now + Duration::from_millis(5));
        b.push(&key(), p).unwrap();
        // not ready yet; flush point is margin-before-deadline, not max_wait
        assert!(b.pop_ready(now, &HashSet::new()).is_none());
        let dl = b.next_deadline().unwrap();
        assert_eq!(dl, now + Duration::from_millis(5) - DEADLINE_FLUSH_MARGIN);
        // the batch pops BEFORE the deadline passes, so dispatch can start
        // on time (the deadline is an SLO, not just a failure timer)
        let at_flush = dl + Duration::from_micros(1);
        assert!(at_flush < now + Duration::from_millis(5));
        assert_eq!(
            b.pop_ready(at_flush, &HashSet::new()).unwrap().items.len(),
            1
        );
    }

    #[test]
    fn depths_report_per_queue_requests_and_rows() {
        let mut b = Batcher::new(Duration::from_secs(10));
        let ka = ("a".to_string(), "v".to_string());
        let kb = ("b".to_string(), "v".to_string());
        b.ensure_queue(&ka, 8);
        b.ensure_queue(&kb, 8);
        let now = Instant::now();
        for (i, rows) in [(0u64, 2usize), (1, 3)] {
            let (p, _rx) = pending_rows(i, now, rows);
            std::mem::forget(_rx);
            b.push(&ka, p).unwrap();
        }
        let d = b.depths();
        assert_eq!(d.len(), 2);
        assert_eq!((d[0].task.as_str(), d[0].requests, d[0].rows), ("a", 2, 5));
        assert_eq!((d[1].task.as_str(), d[1].requests, d[1].rows), ("b", 0, 0));
    }

    #[test]
    fn flushes_partial_on_deadline() {
        let mut b = Batcher::new(Duration::from_millis(5));
        b.ensure_queue(&key(), 64);
        let old = Instant::now() - Duration::from_millis(50);
        let (p, _rx) = pending(0, old);
        std::mem::forget(_rx);
        b.push(&key(), p).unwrap();
        let batch = b.pop_ready(Instant::now(), &HashSet::new()).unwrap();
        assert_eq!(batch.items.len(), 1);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn no_flush_before_deadline() {
        let mut b = Batcher::new(Duration::from_secs(1));
        b.ensure_queue(&key(), 64);
        let now = Instant::now();
        let (p, _rx) = pending(0, now);
        std::mem::forget(_rx);
        b.push(&key(), p).unwrap();
        assert!(b.pop_ready(now, &HashSet::new()).is_none());
        assert_eq!(b.queued(), 1);
        let dl = b.next_deadline().unwrap();
        assert!(dl > now);
    }

    fn key_n(i: usize) -> QueueKey {
        ("t".to_string(), format!("v{i}"))
    }

    #[test]
    fn pop_ready_takes_one_batch_and_respects_busy() {
        let mut b = Batcher::new(Duration::from_millis(1));
        let now = Instant::now();
        let old = now - Duration::from_secs(1);
        for k in 0..2 {
            b.ensure_queue(&key_n(k), 4);
            for i in 0..4 {
                let (p, _rx) = pending((k * 10 + i) as u64, old);
                std::mem::forget(_rx);
                b.push(&key_n(k), p).unwrap();
            }
        }
        // both queues full; with one busy, pop must return the other
        let mut busy = HashSet::new();
        busy.insert(key_n(0));
        let batch = b.pop_ready(now, &busy).unwrap();
        assert_eq!(batch.key, key_n(1));
        assert_eq!(batch.items.len(), 4);
        // now both keys busy → nothing poppable even though key 0 is full
        busy.insert(key_n(1));
        assert!(b.pop_ready(now, &busy).is_none());
        busy.clear();
        assert_eq!(b.pop_ready(now, &busy).unwrap().key, key_n(0));
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn next_deadline_idle_skips_busy_queues() {
        let mut b = Batcher::new(Duration::from_millis(1));
        let now = Instant::now();
        b.ensure_queue(&key_n(0), 4);
        b.ensure_queue(&key_n(1), 4);
        // key 0: old item (expired deadline), key 1: fresh item
        let (p, _rx) = pending(0, now - Duration::from_secs(1));
        std::mem::forget(_rx);
        b.push(&key_n(0), p).unwrap();
        let (p, _rx) = pending(1, now);
        std::mem::forget(_rx);
        b.push(&key_n(1), p).unwrap();

        let mut busy = HashSet::new();
        busy.insert(key_n(0));
        // with key 0 busy, the wait deadline must come from key 1 (future),
        // not the already-expired key 0 front — no condvar spin
        let idle = b.next_deadline_idle(&busy).unwrap();
        assert!(idle > now);
        assert_eq!(b.next_deadline_idle(&HashSet::new()), b.next_deadline());
        busy.insert(key_n(1));
        assert!(b.next_deadline_idle(&busy).is_none());
    }

    #[test]
    fn batches_never_exceed_cap_property() {
        use crate::util::propkit::{check, gen_range, prop_assert};
        check("pop_ready batch rows ≤ cap", 50, |rng| {
            let cap = gen_range(rng, 1, 6);
            let n = gen_range(rng, 0, 30);
            let mut b = Batcher::new(Duration::from_millis(1));
            b.ensure_queue(&key(), cap);
            let old = Instant::now() - Duration::from_secs(1);
            let mut total_rows = 0usize;
            for i in 0..n {
                // rows within [1, cap] — the engine rejects larger requests
                let rows = gen_range(rng, 1, cap);
                total_rows += rows;
                let (p, _rx) = pending_rows(i as u64, old, rows);
                std::mem::forget(_rx);
                b.push(&key(), p).unwrap();
            }
            let busy = HashSet::new();
            let mut popped = 0usize;
            let mut popped_rows = 0usize;
            while let Some(batch) = b.pop_ready(Instant::now(), &busy) {
                let rows: usize = batch.items.iter().map(|p| p.req.block.rows).sum();
                prop_assert(rows <= cap, format!("batch rows {rows} > cap {cap}"))?;
                prop_assert(!batch.items.is_empty(), "empty batch")?;
                popped += batch.items.len();
                popped_rows += rows;
            }
            prop_assert(popped == n, format!("popped {popped} of {n}"))?;
            prop_assert(
                popped_rows == total_rows,
                format!("rows {popped_rows} of {total_rows}"),
            )
        });
    }

    #[test]
    fn fifo_within_queue_property() {
        use crate::util::propkit::{check, gen_range, prop_assert};
        check("items within a queue stay FIFO", 40, |rng| {
            let keys: Vec<QueueKey> = (0..3).map(key_n).collect();
            let mut b = Batcher::new(Duration::from_millis(1));
            for k in &keys {
                b.ensure_queue(k, gen_range(rng, 1, 5));
            }
            let old = Instant::now() - Duration::from_secs(5);
            let busy = HashSet::new();
            let mut next_id = 0u64;
            let mut drained: Vec<Vec<u64>> = vec![Vec::new(); keys.len()];
            // interleave random pushes with random pops
            for _ in 0..gen_range(rng, 5, 40) {
                if rng.below(3) < 2 {
                    let k = gen_range(rng, 0, keys.len() - 1);
                    // ids are globally increasing, so per-key order is too
                    let (p, _rx) = pending(next_id, old + Duration::from_micros(next_id));
                    std::mem::forget(_rx);
                    next_id += 1;
                    b.push(&keys[k], p).unwrap();
                } else if let Some(batch) = b.pop_ready(Instant::now(), &busy) {
                    let ki = keys.iter().position(|k| *k == batch.key).unwrap();
                    drained[ki].extend(batch.items.iter().map(|p| p.req.id));
                }
            }
            while let Some(batch) = b.pop_ready(Instant::now(), &busy) {
                let ki = keys.iter().position(|k| *k == batch.key).unwrap();
                drained[ki].extend(batch.items.iter().map(|p| p.req.id));
            }
            for (ki, ids) in drained.iter().enumerate() {
                let mut sorted = ids.clone();
                sorted.sort();
                prop_assert(
                    *ids == sorted,
                    format!("queue {ki} drained out of order: {ids:?}"),
                )?;
            }
            prop_assert(b.queued() == 0, "queue should drain")
        });
    }

    #[test]
    fn padding_fill_zeroed_property() {
        use crate::util::propkit::{check, gen_range, gen_vec, prop_assert};
        check("pad_batch zero-fills beyond real rows", 50, |rng| {
            let cap = gen_range(rng, 1, 8);
            let dim = gen_range(rng, 1, 6);
            let real = gen_range(rng, 0, cap);
            let samples: Vec<Vec<f32>> =
                (0..real).map(|_| gen_vec(rng, dim, 1.0)).collect();
            let refs: Vec<&[f32]> = samples.iter().map(Vec::as_slice).collect();
            let out = pad_batch(&refs, cap, dim);
            prop_assert(out.len() == cap * dim, "wrong padded length")?;
            for (i, s) in samples.iter().enumerate() {
                prop_assert(
                    out[i * dim..(i + 1) * dim] == s[..],
                    format!("row {i} corrupted"),
                )?;
            }
            prop_assert(
                out[real * dim..].iter().all(|&x| x == 0.0),
                "padding rows not zeroed",
            )
        });
    }

    #[test]
    fn zero_sample_requests_keep_row_accounting_consistent() {
        // regression: push used to add `samples` (0) while pop drained
        // `samples.max(1)` (1) and decremented raw `samples` (0) — a
        // zero-sample request would leave `q.rows` drifting against the
        // readiness math forever. All paths now route through `rows()`.
        let mut b = Batcher::new(Duration::from_millis(1));
        b.ensure_queue(&key(), 4);
        let old = Instant::now() - Duration::from_secs(1);
        let (p, _rx) = pending_rows(0, old, 0);
        std::mem::forget(_rx);
        b.push(&key(), p).unwrap();
        assert_eq!(b.queued_rows(), 1, "zero-sample request occupies one row");
        let batch = b.pop_ready(Instant::now(), &HashSet::new()).unwrap();
        assert_eq!(batch.items.len(), 1);
        assert_eq!(b.queued(), 0);
        assert_eq!(b.queued_rows(), 0, "accounting balanced after drain");
        assert!(b.pop_ready(Instant::now(), &HashSet::new()).is_none());
    }

    #[test]
    fn client_quota_rejects_push_and_releases_on_pop() {
        let mut b = Batcher::new(Duration::from_millis(1)).with_client_quota(2);
        b.ensure_queue(&key(), 8);
        let old = Instant::now() - Duration::from_secs(1);
        let mk = |id: u64, client: Option<&str>| {
            let (mut p, _rx) = pending(id, old);
            std::mem::forget(_rx);
            p.req.client = client.map(str::to_string);
            p
        };
        b.push(&key(), mk(0, Some("c1"))).unwrap();
        b.push(&key(), mk(1, Some("c1"))).unwrap();
        let rejected = b.push(&key(), mk(2, Some("c1"))).unwrap_err();
        assert_eq!(rejected.req.id, 2, "request handed back untouched");
        // other clients and unattributed requests are unaffected
        b.push(&key(), mk(3, Some("c2"))).unwrap();
        b.push(&key(), mk(4, None)).unwrap();
        // draining the queue releases the quota
        assert_eq!(
            b.pop_ready(Instant::now(), &HashSet::new()).unwrap().items.len(),
            4
        );
        b.push(&key(), mk(5, Some("c1"))).unwrap();
    }

    #[test]
    fn shed_to_removes_lowest_priority_latest_deadline_first() {
        let mut b = Batcher::new(Duration::from_secs(10));
        b.ensure_queue(&key(), 64);
        let now = Instant::now();
        let mk = |id: u64, prio: Priority, dl: Option<Duration>| {
            let (mut p, _rx) = pending(id, now);
            std::mem::forget(_rx);
            p.req.priority = prio;
            p.req.deadline = dl.map(|d| now + d);
            p
        };
        b.push(&key(), mk(0, Priority::High, None)).unwrap();
        b.push(&key(), mk(1, Priority::Low, Some(Duration::from_millis(5))))
            .unwrap();
        b.push(&key(), mk(2, Priority::Low, None)).unwrap();
        b.push(&key(), mk(3, Priority::Normal, None)).unwrap();
        // shed to 2 rows: the no-deadline Low goes first (latest within
        // its class), then the deadlined Low; High and Normal survive
        let shed = b.shed_to(2);
        assert_eq!(
            shed.iter().map(|p| p.req.id).collect::<Vec<_>>(),
            vec![2, 1]
        );
        assert_eq!(b.queued_rows(), 2);
        assert!(b.shed_to(2).is_empty(), "already at the mark");
        // accounting stayed consistent: the survivors still drain
        let batch = b
            .pop_ready(now + Duration::from_secs(60), &HashSet::new())
            .unwrap();
        assert_eq!(
            batch.items.iter().map(|p| p.req.id).collect::<Vec<_>>(),
            vec![0, 3]
        );
    }

    #[test]
    fn pad_batch_packs_multi_row_blocks_contiguously() {
        // a 2-row request followed by a 1-row request, cap 4
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [5.0f32, 6.0];
        let out = pad_batch(&[&a[..], &b[..]], 4, 2);
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 0.0, 0.0]);
    }

    #[test]
    fn pad_batch_into_reuses_a_buffer_bit_identically() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [5.0f32, 6.0];
        let mut buf = Vec::new();
        // the buffered form matches the pure form exactly, batch after
        // batch — including a *smaller* batch reusing a larger buffer,
        // where stale tail values must be re-zeroed, not leak through
        pad_batch_into(&mut buf, [&a[..], &b[..]], 4, 2);
        assert_eq!(buf, pad_batch(&[&a[..], &b[..]], 4, 2));
        pad_batch_into(&mut buf, [&b[..]], 2, 2);
        assert_eq!(buf, pad_batch(&[&b[..]], 2, 2));
        assert_eq!(buf, vec![5.0, 6.0, 0.0, 0.0]);
        // overflowing input clamps exactly like the pure form
        let long = [9.0f32; 8];
        pad_batch_into(&mut buf, [&long[..]], 2, 2);
        assert_eq!(buf, pad_batch(&[&long[..]], 2, 2));
        assert_eq!(buf.len(), 4);
    }

    #[test]
    fn next_deadline_monotone_under_pushes_property() {
        use crate::util::propkit::{check, gen_range, prop_assert};
        check("next_deadline: exact and push-monotone", 40, |rng| {
            let wait = Duration::from_millis(10);
            let keys: Vec<QueueKey> = (0..2).map(key_n).collect();
            let mut b = Batcher::new(wait);
            for k in &keys {
                b.ensure_queue(k, gen_range(rng, 1, 4));
            }
            let base = Instant::now() - Duration::from_secs(60);
            let busy = HashSet::new();
            // mirror of every queue's front submit time
            let mut fronts: Vec<VecDeque<Instant>> = vec![VecDeque::new(); keys.len()];
            let mut t = 0u64;
            for _ in 0..gen_range(rng, 3, 30) {
                let prev = b.next_deadline();
                let push = rng.below(3) < 2;
                if push {
                    let k = gen_range(rng, 0, keys.len() - 1);
                    t += 1 + rng.below(1000);
                    let at = base + Duration::from_micros(t);
                    let (p, _rx) = pending(t, at);
                    std::mem::forget(_rx);
                    b.push(&keys[k], p).unwrap();
                    fronts[k].push_back(at);
                    // pushing can only pull the deadline earlier or leave it
                    if let (Some(prev), Some(now)) = (prev, b.next_deadline()) {
                        prop_assert(now <= prev, "push moved deadline later")?;
                    }
                } else if let Some(batch) = b.pop_ready(base + Duration::from_secs(120), &busy) {
                    let ki = keys.iter().position(|k| *k == batch.key).unwrap();
                    for _ in 0..batch.items.len() {
                        fronts[ki].pop_front();
                    }
                }
                // invariant (no per-request deadlines in this test):
                // deadline == min over fronts + max_wait
                let want = fronts
                    .iter()
                    .filter_map(|q| q.front().copied())
                    .min()
                    .map(|f| f + wait);
                prop_assert(
                    b.next_deadline() == want,
                    "deadline drifted from min-front + max_wait",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn no_request_lost_or_duplicated_property() {
        use crate::util::propkit::{check, gen_range, prop_assert};
        check("conservation of requests", 30, |rng| {
            let batch = gen_range(rng, 1, 8);
            let n = gen_range(rng, 0, 40);
            let mut b = Batcher::new(Duration::from_millis(1));
            b.ensure_queue(&key(), batch);
            let old = Instant::now() - Duration::from_secs(1);
            for i in 0..n {
                let (p, _rx) = pending(i as u64, old);
                std::mem::forget(_rx);
                b.push(&key(), p).unwrap();
            }
            // everything is past deadline → all must flush exactly once
            let busy = HashSet::new();
            let mut ready = Vec::new();
            while let Some(r) = b.pop_ready(Instant::now(), &busy) {
                ready.push(r);
            }
            let mut ids: Vec<u64> = ready
                .iter()
                .flat_map(|r| r.items.iter().map(|p| p.req.id))
                .collect();
            ids.sort();
            prop_assert(
                ids == (0..n as u64).collect::<Vec<_>>(),
                format!("ids {ids:?}"),
            )?;
            prop_assert(b.queued() == 0, "queue should drain")?;
            // batch size bound respected
            prop_assert(
                ready.iter().all(|r| r.items.len() <= batch),
                "oversized batch",
            )
        });
    }
}
