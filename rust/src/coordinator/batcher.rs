//! Dynamic batching: per-(task, variant) queues flushed on batch-full or
//! deadline.
//!
//! The exported executables have a fixed batch dimension B, so a batch is
//! (a) full when B samples are queued, or (b) forced when the oldest queued
//! request has waited `max_wait` — the standard dynamic batching policy of
//! serving systems (vLLM/Triton style), applied at the ODE-solve level.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::coordinator::request::{Request, Response};

/// A request waiting in a queue, with its response channel.
pub struct Pending {
    pub req: Request,
    pub reply: mpsc::Sender<Response>,
}

/// Queue key: (task, variant) — requests routed to the same executable batch
/// together regardless of their exact budgets.
pub type QueueKey = (String, String);

/// A batch ready for execution.
pub struct ReadyBatch {
    pub key: QueueKey,
    pub items: Vec<Pending>,
}

/// Per-variant FIFO queues with deadline tracking. Not internally
/// synchronised — the engine wraps it in a mutex and a condvar.
pub struct Batcher {
    queues: HashMap<QueueKey, VecDeque<Pending>>,
    batch_sizes: HashMap<QueueKey, usize>,
    max_wait: Duration,
}

impl Batcher {
    pub fn new(max_wait: Duration) -> Batcher {
        Batcher {
            queues: HashMap::new(),
            batch_sizes: HashMap::new(),
            max_wait,
        }
    }

    /// Register the executable batch size for a queue (first sight).
    pub fn ensure_queue(&mut self, key: &QueueKey, batch_size: usize) {
        self.batch_sizes.entry(key.clone()).or_insert(batch_size);
        self.queues.entry(key.clone()).or_default();
    }

    pub fn push(&mut self, key: &QueueKey, p: Pending) {
        self.queues
            .get_mut(key)
            .expect("ensure_queue before push")
            .push_back(p);
    }

    pub fn queued(&self) -> usize {
        self.queues.values().map(VecDeque::len).sum()
    }

    /// Pop every batch that is ready now (full, or oldest beyond deadline).
    pub fn ready_batches(&mut self, now: Instant) -> Vec<ReadyBatch> {
        let mut out = Vec::new();
        for (key, q) in self.queues.iter_mut() {
            let b = self.batch_sizes[key];
            loop {
                let flush = if q.len() >= b {
                    true
                } else if let Some(front) = q.front() {
                    now.duration_since(front.req.t_submit) >= self.max_wait
                } else {
                    false
                };
                if !flush {
                    break;
                }
                let take = q.len().min(b);
                let items: Vec<Pending> = q.drain(..take).collect();
                out.push(ReadyBatch {
                    key: key.clone(),
                    items,
                });
            }
        }
        out
    }

    /// Earliest deadline across all queues (None when idle) — drives the
    /// engine's condvar timeout.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .values()
            .filter_map(|q| q.front().map(|p| p.req.t_submit + self.max_wait))
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pending(id: u64, at: Instant) -> (Pending, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        let mut req = Request::new(id, "t", 0.1, vec![0.0]);
        req.t_submit = at;
        (Pending { req, reply: tx }, rx)
    }

    fn key() -> QueueKey {
        ("t".to_string(), "v".to_string())
    }

    #[test]
    fn flushes_on_full_batch() {
        let mut b = Batcher::new(Duration::from_secs(10));
        b.ensure_queue(&key(), 3);
        let now = Instant::now();
        for i in 0..7 {
            let (p, _rx) = pending(i, now);
            std::mem::forget(_rx);
            b.push(&key(), p);
        }
        let ready = b.ready_batches(now);
        // 7 queued, batch 3 → two full batches, one remains queued
        assert_eq!(ready.len(), 2);
        assert!(ready.iter().all(|r| r.items.len() == 3));
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn flushes_partial_on_deadline() {
        let mut b = Batcher::new(Duration::from_millis(5));
        b.ensure_queue(&key(), 64);
        let old = Instant::now() - Duration::from_millis(50);
        let (p, _rx) = pending(0, old);
        std::mem::forget(_rx);
        b.push(&key(), p);
        let ready = b.ready_batches(Instant::now());
        assert_eq!(ready.len(), 1);
        assert_eq!(ready[0].items.len(), 1);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn no_flush_before_deadline() {
        let mut b = Batcher::new(Duration::from_secs(1));
        b.ensure_queue(&key(), 64);
        let now = Instant::now();
        let (p, _rx) = pending(0, now);
        std::mem::forget(_rx);
        b.push(&key(), p);
        assert!(b.ready_batches(now).is_empty());
        assert_eq!(b.queued(), 1);
        let dl = b.next_deadline().unwrap();
        assert!(dl > now);
    }

    #[test]
    fn no_request_lost_or_duplicated_property() {
        use crate::util::propkit::{check, gen_range, prop_assert};
        check("conservation of requests", 30, |rng| {
            let batch = gen_range(rng, 1, 8);
            let n = gen_range(rng, 0, 40);
            let mut b = Batcher::new(Duration::from_millis(1));
            b.ensure_queue(&key(), batch);
            let old = Instant::now() - Duration::from_secs(1);
            for i in 0..n {
                let (p, _rx) = pending(i as u64, old);
                std::mem::forget(_rx);
                b.push(&key(), p);
            }
            // everything is past deadline → all must flush exactly once
            let ready = b.ready_batches(Instant::now());
            let mut ids: Vec<u64> = ready
                .iter()
                .flat_map(|r| r.items.iter().map(|p| p.req.id))
                .collect();
            ids.sort();
            prop_assert(
                ids == (0..n as u64).collect::<Vec<_>>(),
                format!("ids {ids:?}"),
            )?;
            prop_assert(b.queued() == 0, "queue should drain")?;
            // batch size bound respected
            prop_assert(
                ready.iter().all(|r| r.items.len() <= batch),
                "oversized batch",
            )
        });
    }
}
