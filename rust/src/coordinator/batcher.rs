//! Dynamic batching: per-(task, variant) queues flushed on batch-full or
//! deadline.
//!
//! The exported executables have a fixed batch dimension B, so a batch is
//! (a) full when B samples are queued, or (b) forced when the oldest queued
//! request has waited `max_wait` — the standard dynamic batching policy of
//! serving systems (vLLM/Triton style), applied at the ODE-solve level.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::coordinator::request::{Request, Response};

/// Assemble a padded batch input: `cap` rows of `dim` values, real samples
/// first (row-major), remaining fill rows zeroed. Used by the engine right
/// before handing a batch to the execution backend.
pub fn pad_batch(samples: &[&[f32]], cap: usize, dim: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; cap * dim];
    for (i, s) in samples.iter().enumerate().take(cap) {
        let n = s.len().min(dim);
        out[i * dim..i * dim + n].copy_from_slice(&s[..n]);
    }
    out
}

/// A request waiting in a queue, with its response channel.
pub struct Pending {
    pub req: Request,
    pub reply: mpsc::Sender<Response>,
}

/// Queue key: (task, variant) — requests routed to the same executable batch
/// together regardless of their exact budgets.
pub type QueueKey = (String, String);

/// A batch ready for execution.
pub struct ReadyBatch {
    pub key: QueueKey,
    pub items: Vec<Pending>,
}

/// Per-variant FIFO queues with deadline tracking. Not internally
/// synchronised — the engine wraps it in a mutex and a condvar.
pub struct Batcher {
    queues: HashMap<QueueKey, VecDeque<Pending>>,
    batch_sizes: HashMap<QueueKey, usize>,
    max_wait: Duration,
}

impl Batcher {
    pub fn new(max_wait: Duration) -> Batcher {
        Batcher {
            queues: HashMap::new(),
            batch_sizes: HashMap::new(),
            max_wait,
        }
    }

    /// Register the executable batch size for a queue (first sight).
    pub fn ensure_queue(&mut self, key: &QueueKey, batch_size: usize) {
        self.batch_sizes.entry(key.clone()).or_insert(batch_size);
        self.queues.entry(key.clone()).or_default();
    }

    pub fn push(&mut self, key: &QueueKey, p: Pending) {
        self.queues
            .get_mut(key)
            .expect("ensure_queue before push")
            .push_back(p);
    }

    pub fn queued(&self) -> usize {
        self.queues.values().map(VecDeque::len).sum()
    }

    /// Pop the single most-urgent ready batch (full, or oldest beyond
    /// deadline) whose key is not in `busy`.
    ///
    /// This is the worker-pool pop: each dispatch worker takes one batch at
    /// a time, and `busy` carries the keys currently executing on other
    /// workers — per-queue affinity, so a queue's batches never run (or
    /// complete) out of order while batches for *distinct* (task, variant)
    /// queues execute concurrently.
    pub fn pop_ready(&mut self, now: Instant, busy: &HashSet<QueueKey>) -> Option<ReadyBatch> {
        let mut best: Option<(Instant, QueueKey)> = None;
        for (key, q) in &self.queues {
            if busy.contains(key) {
                continue;
            }
            let front = match q.front() {
                Some(p) => p,
                None => continue,
            };
            let cap = self.batch_sizes[key];
            let ready = q.len() >= cap
                || now.duration_since(front.req.t_submit) >= self.max_wait;
            if !ready {
                continue;
            }
            let urgency = front.req.t_submit;
            if best.as_ref().map(|(t, _)| urgency < *t).unwrap_or(true) {
                best = Some((urgency, key.clone()));
            }
        }
        let (_, key) = best?;
        let cap = self.batch_sizes[&key];
        let q = self.queues.get_mut(&key).expect("queue exists");
        let take = q.len().min(cap);
        let items: Vec<Pending> = q.drain(..take).collect();
        Some(ReadyBatch { key, items })
    }

    /// Earliest deadline across all queues (None when idle) — drives the
    /// engine's condvar timeout.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .values()
            .filter_map(|q| q.front().map(|p| p.req.t_submit + self.max_wait))
            .min()
    }

    /// [`Self::next_deadline`] restricted to queues not in `busy`. Workers
    /// wait on this: a busy queue's (already expired) deadline must not turn
    /// the condvar wait into a spin — its completion `notify_all` is the
    /// wake-up signal for that queue, not a timeout.
    pub fn next_deadline_idle(&self, busy: &HashSet<QueueKey>) -> Option<Instant> {
        self.queues
            .iter()
            .filter(|(k, _)| !busy.contains(*k))
            .filter_map(|(_, q)| q.front().map(|p| p.req.t_submit + self.max_wait))
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pending(id: u64, at: Instant) -> (Pending, mpsc::Receiver<Response>) {
        let (tx, rx) = mpsc::channel();
        let mut req = Request::new(id, "t", 0.1, vec![0.0]);
        req.t_submit = at;
        (Pending { req, reply: tx }, rx)
    }

    fn key() -> QueueKey {
        ("t".to_string(), "v".to_string())
    }

    #[test]
    fn flushes_on_full_batch() {
        let mut b = Batcher::new(Duration::from_secs(10));
        b.ensure_queue(&key(), 3);
        let now = Instant::now();
        for i in 0..7 {
            let (p, _rx) = pending(i, now);
            std::mem::forget(_rx);
            b.push(&key(), p);
        }
        // 7 queued, batch 3 → two full batches pop, one item stays queued
        // (not full, deadline far away)
        let busy = HashSet::new();
        assert_eq!(b.pop_ready(now, &busy).unwrap().items.len(), 3);
        assert_eq!(b.pop_ready(now, &busy).unwrap().items.len(), 3);
        assert!(b.pop_ready(now, &busy).is_none());
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn flushes_partial_on_deadline() {
        let mut b = Batcher::new(Duration::from_millis(5));
        b.ensure_queue(&key(), 64);
        let old = Instant::now() - Duration::from_millis(50);
        let (p, _rx) = pending(0, old);
        std::mem::forget(_rx);
        b.push(&key(), p);
        let batch = b.pop_ready(Instant::now(), &HashSet::new()).unwrap();
        assert_eq!(batch.items.len(), 1);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn no_flush_before_deadline() {
        let mut b = Batcher::new(Duration::from_secs(1));
        b.ensure_queue(&key(), 64);
        let now = Instant::now();
        let (p, _rx) = pending(0, now);
        std::mem::forget(_rx);
        b.push(&key(), p);
        assert!(b.pop_ready(now, &HashSet::new()).is_none());
        assert_eq!(b.queued(), 1);
        let dl = b.next_deadline().unwrap();
        assert!(dl > now);
    }

    fn key_n(i: usize) -> QueueKey {
        ("t".to_string(), format!("v{i}"))
    }

    #[test]
    fn pop_ready_takes_one_batch_and_respects_busy() {
        let mut b = Batcher::new(Duration::from_millis(1));
        let now = Instant::now();
        let old = now - Duration::from_secs(1);
        for k in 0..2 {
            b.ensure_queue(&key_n(k), 4);
            for i in 0..4 {
                let (p, _rx) = pending((k * 10 + i) as u64, old);
                std::mem::forget(_rx);
                b.push(&key_n(k), p);
            }
        }
        // both queues full; with one busy, pop must return the other
        let mut busy = HashSet::new();
        busy.insert(key_n(0));
        let batch = b.pop_ready(now, &busy).unwrap();
        assert_eq!(batch.key, key_n(1));
        assert_eq!(batch.items.len(), 4);
        // now both keys busy → nothing poppable even though key 0 is full
        busy.insert(key_n(1));
        assert!(b.pop_ready(now, &busy).is_none());
        busy.clear();
        assert_eq!(b.pop_ready(now, &busy).unwrap().key, key_n(0));
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn next_deadline_idle_skips_busy_queues() {
        let mut b = Batcher::new(Duration::from_millis(1));
        let now = Instant::now();
        b.ensure_queue(&key_n(0), 4);
        b.ensure_queue(&key_n(1), 4);
        // key 0: old item (expired deadline), key 1: fresh item
        let (p, _rx) = pending(0, now - Duration::from_secs(1));
        std::mem::forget(_rx);
        b.push(&key_n(0), p);
        let (p, _rx) = pending(1, now);
        std::mem::forget(_rx);
        b.push(&key_n(1), p);

        let mut busy = HashSet::new();
        busy.insert(key_n(0));
        // with key 0 busy, the wait deadline must come from key 1 (future),
        // not the already-expired key 0 front — no condvar spin
        let idle = b.next_deadline_idle(&busy).unwrap();
        assert!(idle > now);
        assert_eq!(b.next_deadline_idle(&HashSet::new()), b.next_deadline());
        busy.insert(key_n(1));
        assert!(b.next_deadline_idle(&busy).is_none());
    }

    #[test]
    fn batches_never_exceed_cap_property() {
        use crate::util::propkit::{check, gen_range, prop_assert};
        check("pop_ready batch ≤ cap", 50, |rng| {
            let cap = gen_range(rng, 1, 6);
            let n = gen_range(rng, 0, 30);
            let mut b = Batcher::new(Duration::from_millis(1));
            b.ensure_queue(&key(), cap);
            let old = Instant::now() - Duration::from_secs(1);
            for i in 0..n {
                let (p, _rx) = pending(i as u64, old);
                std::mem::forget(_rx);
                b.push(&key(), p);
            }
            let busy = HashSet::new();
            let mut popped = 0usize;
            while let Some(batch) = b.pop_ready(Instant::now(), &busy) {
                prop_assert(
                    batch.items.len() <= cap,
                    format!("batch {} > cap {cap}", batch.items.len()),
                )?;
                prop_assert(!batch.items.is_empty(), "empty batch")?;
                popped += batch.items.len();
            }
            prop_assert(popped == n, format!("popped {popped} of {n}"))
        });
    }

    #[test]
    fn fifo_within_queue_property() {
        use crate::util::propkit::{check, gen_range, prop_assert};
        check("items within a queue stay FIFO", 40, |rng| {
            let keys: Vec<QueueKey> = (0..3).map(key_n).collect();
            let mut b = Batcher::new(Duration::from_millis(1));
            for k in &keys {
                b.ensure_queue(k, gen_range(rng, 1, 5));
            }
            let old = Instant::now() - Duration::from_secs(5);
            let busy = HashSet::new();
            let mut next_id = 0u64;
            let mut drained: Vec<Vec<u64>> = vec![Vec::new(); keys.len()];
            // interleave random pushes with random pops
            for _ in 0..gen_range(rng, 5, 40) {
                if rng.below(3) < 2 {
                    let k = gen_range(rng, 0, keys.len() - 1);
                    // ids are globally increasing, so per-key order is too
                    let (p, _rx) = pending(next_id, old + Duration::from_micros(next_id));
                    std::mem::forget(_rx);
                    next_id += 1;
                    b.push(&keys[k], p);
                } else if let Some(batch) = b.pop_ready(Instant::now(), &busy) {
                    let ki = keys.iter().position(|k| *k == batch.key).unwrap();
                    drained[ki].extend(batch.items.iter().map(|p| p.req.id));
                }
            }
            while let Some(batch) = b.pop_ready(Instant::now(), &busy) {
                let ki = keys.iter().position(|k| *k == batch.key).unwrap();
                drained[ki].extend(batch.items.iter().map(|p| p.req.id));
            }
            for (ki, ids) in drained.iter().enumerate() {
                let mut sorted = ids.clone();
                sorted.sort();
                prop_assert(
                    *ids == sorted,
                    format!("queue {ki} drained out of order: {ids:?}"),
                )?;
            }
            prop_assert(b.queued() == 0, "queue should drain")
        });
    }

    #[test]
    fn padding_fill_zeroed_property() {
        use crate::util::propkit::{check, gen_range, gen_vec, prop_assert};
        check("pad_batch zero-fills beyond real samples", 50, |rng| {
            let cap = gen_range(rng, 1, 8);
            let dim = gen_range(rng, 1, 6);
            let real = gen_range(rng, 0, cap);
            let samples: Vec<Vec<f32>> =
                (0..real).map(|_| gen_vec(rng, dim, 1.0)).collect();
            let refs: Vec<&[f32]> = samples.iter().map(Vec::as_slice).collect();
            let out = pad_batch(&refs, cap, dim);
            prop_assert(out.len() == cap * dim, "wrong padded length")?;
            for (i, s) in samples.iter().enumerate() {
                prop_assert(
                    out[i * dim..(i + 1) * dim] == s[..],
                    format!("row {i} corrupted"),
                )?;
            }
            prop_assert(
                out[real * dim..].iter().all(|&x| x == 0.0),
                "padding rows not zeroed",
            )
        });
    }

    #[test]
    fn next_deadline_monotone_under_pushes_property() {
        use crate::util::propkit::{check, gen_range, prop_assert};
        check("next_deadline: exact and push-monotone", 40, |rng| {
            let wait = Duration::from_millis(10);
            let keys: Vec<QueueKey> = (0..2).map(key_n).collect();
            let mut b = Batcher::new(wait);
            for k in &keys {
                b.ensure_queue(k, gen_range(rng, 1, 4));
            }
            let base = Instant::now() - Duration::from_secs(60);
            let busy = HashSet::new();
            // mirror of every queue's front submit time
            let mut fronts: Vec<VecDeque<Instant>> = vec![VecDeque::new(); keys.len()];
            let mut t = 0u64;
            for _ in 0..gen_range(rng, 3, 30) {
                let prev = b.next_deadline();
                let push = rng.below(3) < 2;
                if push {
                    let k = gen_range(rng, 0, keys.len() - 1);
                    t += 1 + rng.below(1000);
                    let at = base + Duration::from_micros(t);
                    let (p, _rx) = pending(t, at);
                    std::mem::forget(_rx);
                    b.push(&keys[k], p);
                    fronts[k].push_back(at);
                    // pushing can only pull the deadline earlier or leave it
                    if let (Some(prev), Some(now)) = (prev, b.next_deadline()) {
                        prop_assert(now <= prev, "push moved deadline later")?;
                    }
                } else if let Some(batch) = b.pop_ready(base + Duration::from_secs(120), &busy) {
                    let ki = keys.iter().position(|k| *k == batch.key).unwrap();
                    for _ in 0..batch.items.len() {
                        fronts[ki].pop_front();
                    }
                }
                // invariant: deadline == min over fronts + max_wait
                let want = fronts
                    .iter()
                    .filter_map(|q| q.front().copied())
                    .min()
                    .map(|f| f + wait);
                prop_assert(
                    b.next_deadline() == want,
                    "deadline drifted from min-front + max_wait",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn no_request_lost_or_duplicated_property() {
        use crate::util::propkit::{check, gen_range, prop_assert};
        check("conservation of requests", 30, |rng| {
            let batch = gen_range(rng, 1, 8);
            let n = gen_range(rng, 0, 40);
            let mut b = Batcher::new(Duration::from_millis(1));
            b.ensure_queue(&key(), batch);
            let old = Instant::now() - Duration::from_secs(1);
            for i in 0..n {
                let (p, _rx) = pending(i as u64, old);
                std::mem::forget(_rx);
                b.push(&key(), p);
            }
            // everything is past deadline → all must flush exactly once
            let busy = HashSet::new();
            let mut ready = Vec::new();
            while let Some(r) = b.pop_ready(Instant::now(), &busy) {
                ready.push(r);
            }
            let mut ids: Vec<u64> = ready
                .iter()
                .flat_map(|r| r.items.iter().map(|p| p.req.id))
                .collect();
            ids.sort();
            prop_assert(
                ids == (0..n as u64).collect::<Vec<_>>(),
                format!("ids {ids:?}"),
            )?;
            prop_assert(b.queued() == 0, "queue should drain")?;
            // batch size bound respected
            prop_assert(
                ready.iter().all(|r| r.items.len() <= batch),
                "oversized batch",
            )
        });
    }
}
