//! Variant selection: cheapest solver that satisfies the error budget.
//!
//! The manifest carries, for every exported `(solver, K)` variant, the
//! terminal MAPE *measured at export time* against dopri5(1e-6) on a held
//! eval batch. Selection is a lookup over that table — the pareto front the
//! paper plots (Fig. 3) is exactly the lower envelope this policy walks.

use crate::runtime::manifest::{TaskEntry, Variant};

/// Cost axis the policy minimises.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// analytic MACs per sample (the paper's complexity measure, §4.1)
    MinMacs,
    /// vector-field evaluations
    MinNfe,
}

/// Pick the cheapest variant with `mape <= budget`.
///
/// Guarantees (property-tested):
/// * if any variant satisfies the budget, the result satisfies it;
/// * otherwise the most accurate variant is returned (graceful degrade);
/// * the chosen cost is monotone non-increasing in `budget`.
pub fn select_variant<'a>(
    task: &'a TaskEntry,
    budget: f32,
    policy: Policy,
) -> Option<&'a Variant> {
    let cost = |v: &Variant| -> u64 {
        match policy {
            Policy::MinMacs => v.macs,
            Policy::MinNfe => v.nfe,
        }
    };
    let eligible: Vec<&Variant> = task
        .variants
        .iter()
        .filter(|v| v.mape <= budget as f64)
        .collect();
    if eligible.is_empty() {
        // nothing satisfies the budget: return the most accurate variant
        return task.variants.iter().min_by(|a, b| {
            a.mape
                .partial_cmp(&b.mape)
                .unwrap()
                .then_with(|| cost(a).cmp(&cost(b)))
        });
    }
    eligible.into_iter().min_by(|a, b| {
        cost(a)
            .cmp(&cost(b))
            .then(a.mape.partial_cmp(&b.mape).unwrap())
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Variant;
    use crate::util::propkit::{check, gen_range, prop_assert};

    fn variant(name: &str, macs: u64, nfe: u64, mape: f64) -> Variant {
        Variant {
            name: name.into(),
            solver: name.into(),
            k: 1,
            hyper: name.starts_with("hyper"),
            hlo: format!("{name}.hlo.txt"),
            nfe,
            macs,
            mape,
            tol: None,
            acc_drop: None,
            in_shape: vec![4, 2],
            out_shape: vec![4, 2],
            returns_nfe: false,
        }
    }

    fn task(variants: Vec<Variant>) -> TaskEntry {
        TaskEntry {
            name: "t".into(),
            kind: "cnf".into(),
            state_shape: vec![4, 2],
            s_span: (0.0, 1.0),
            weights: "w.json".into(),
            field_hlo: "f.hlo.txt".into(),
            mac_f: 100,
            mac_g: 50,
            delta: 0.01,
            hyper_base: "heun".into(),
            truth_acc: None,
            variants,
            data: Default::default(),
        }
    }

    fn sample_task() -> TaskEntry {
        task(vec![
            variant("euler_k1", 100, 1, 0.30),
            variant("heun_k1", 200, 2, 0.12),
            variant("hyperheun_k1", 250, 2, 0.04),
            variant("rk4_k4", 1600, 16, 0.002),
            variant("dopri5", 2800, 28, 0.0001),
        ])
    }

    #[test]
    fn picks_cheapest_satisfying() {
        let t = sample_task();
        let v = select_variant(&t, 0.5, Policy::MinMacs).unwrap();
        assert_eq!(v.name, "euler_k1"); // everything qualifies → cheapest
        let v = select_variant(&t, 0.05, Policy::MinMacs).unwrap();
        assert_eq!(v.name, "hyperheun_k1"); // the hypersolver wins the mid range
        let v = select_variant(&t, 0.001, Policy::MinMacs).unwrap();
        assert_eq!(v.name, "dopri5");
    }

    #[test]
    fn degrades_to_most_accurate() {
        let t = sample_task();
        let v = select_variant(&t, 1e-9, Policy::MinMacs).unwrap();
        assert_eq!(v.name, "dopri5");
    }

    #[test]
    fn empty_task_gives_none() {
        let t = task(vec![]);
        assert!(select_variant(&t, 0.1, Policy::MinMacs).is_none());
    }

    #[test]
    fn budget_satisfaction_property() {
        check("selected satisfies budget when feasible", 100, |rng| {
            let n = gen_range(rng, 1, 8);
            let vs: Vec<Variant> = (0..n)
                .map(|i| {
                    variant(
                        &format!("v{i}"),
                        gen_range(rng, 1, 1000) as u64,
                        gen_range(rng, 1, 64) as u64,
                        rng.uniform(),
                    )
                })
                .collect();
            let t = task(vs.clone());
            let budget = rng.uniform() as f32;
            let chosen = select_variant(&t, budget, Policy::MinNfe).unwrap();
            let feasible = vs.iter().any(|v| v.mape <= budget as f64);
            if feasible {
                prop_assert(
                    chosen.mape <= budget as f64,
                    format!("chose {} with mape {} > {budget}", chosen.name, chosen.mape),
                )?;
                // and nothing cheaper is feasible
                for v in &vs {
                    if v.mape <= budget as f64 && v.nfe < chosen.nfe {
                        return Err(format!(
                            "{} (nfe {}) was feasible and cheaper",
                            v.name, v.nfe
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn monotone_in_budget_property() {
        check("cost non-increasing in budget", 50, |rng| {
            let t = sample_task();
            let mut b1 = rng.uniform() as f32;
            let mut b2 = rng.uniform() as f32;
            if b1 > b2 {
                std::mem::swap(&mut b1, &mut b2);
            }
            let c1 = select_variant(&t, b1, Policy::MinMacs).unwrap().macs;
            let c2 = select_variant(&t, b2, Policy::MinMacs).unwrap().macs;
            prop_assert(
                c2 <= c1,
                format!("budget {b1}->{c1} macs but {b2}->{c2}"),
            )
        });
    }
}
