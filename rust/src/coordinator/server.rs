//! TCP serving front end — a thin pipelined shell over the typed protocol
//! in [`crate::api`], speaking all three wire dialects on one port.
//!
//! Each connection message is routed by its **first byte**: the v2 frame
//! magic (`0xB2`, see [`crate::api::v2`]) means a binary frame; anything
//! else is a JSON line (v1, or legacy v0 without a `"v"` key). Requests
//! are submitted to the engine **as they arrive** (nothing blocks the
//! reader), and responses are written back as their batches complete —
//! possibly out of order; clients correlate by `id`. A single connection
//! can therefore keep any number of multi-sample requests in flight (see
//! [`Client::infer_pipelined`]), and may freely mix dialects — each reply
//! is encoded in the dialect its request arrived in.
//!
//! ```text
//! → {"v": 1, "id": 7, "task": "cnf_rings", "budget": 0.05,
//!    "input": [[0.1, -0.7], [0.3, 0.2]]}
//! ← {"v": 1, "ok": true, "id": 7, "variant": "hyperheun_k1", ...}
//! → 0xB2 [kind=1][header_len][payload_len]{"v":2,...} <raw f32 rows>
//! ← 0xB2 [kind=2][header_len][payload_len]{"v":2,"ok":true,...} <rows>
//! → {"cmd": "protocol"}
//! ← {"ok": true, "versions": [0, 1, 2]}
//! ```
//!
//! Legacy v0 lines (no `"v"` key, one flat sample) are still answered, in
//! the v0 response shape plus a `deprecation` notice. The full schema,
//! error codes and versioning policy live in rust/README.md §"Serving API
//! v1" and §"Wire protocol v2"; apart from the deliberately-legacy
//! [`Client::infer`] v0 helper, every message this module reads or writes
//! goes through the `api::v1`/`api::v2` codecs — there is no second copy
//! of the protocol.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering::SeqCst};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use crate::api::v1::{self, InferReply, InferRequest};
use crate::api::{v2, ApiError};
use crate::coordinator::engine::Engine;
use crate::coordinator::request::{Completion, RowBlock};
use crate::util::json::{self, Value};
use crate::{log_info, Error, Result};

/// Serve `engine` on `addr` (e.g. "127.0.0.1:7878"). Blocks forever; one
/// thread per connection (connection counts here are test/bench scale).
pub fn serve(engine: Arc<Engine>, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    serve_listener(engine, listener)
}

/// Serve on an already-bound listener (lets tests bind port 0 and read the
/// ephemeral port back before serving). Returns `Ok(())` when a loopback
/// peer requests a graceful stop via `cmd: "shutdown"` (see
/// [`handle_shutdown`]); otherwise blocks forever.
pub fn serve_listener(engine: Arc<Engine>, listener: TcpListener) -> Result<()> {
    log_info!("listening on {:?}", listener.local_addr());
    let ctl = Arc::new(ServeCtl {
        shutdown: AtomicBool::new(false),
        addr: listener.local_addr().ok(),
    });
    for stream in listener.incoming() {
        if ctl.is_shutdown() {
            break;
        }
        let stream = stream?;
        let engine = Arc::clone(&engine);
        let ctl = Arc::clone(&ctl);
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(&engine, stream, &ctl) {
                crate::log_debug!("connection closed: {e}");
            }
        });
    }
    log_info!("accept loop exited after graceful shutdown");
    Ok(())
}

/// Shared control block for one serve loop: lets any connection request a
/// graceful shutdown that the accept loop and every sibling connection
/// observe.
struct ServeCtl {
    shutdown: AtomicBool,
    /// the listener's own address — used to poke the blocked accept loop
    /// awake so it observes the flag instead of waiting for a real peer
    addr: Option<SocketAddr>,
}

impl ServeCtl {
    fn is_shutdown(&self) -> bool {
        self.shutdown.load(SeqCst)
    }

    /// Wake the accept loop with a throwaway connection (best effort —
    /// if the listener address is unknown the loop exits on its next
    /// real accept instead).
    fn wake(&self) {
        if let Some(addr) = self.addr {
            let _ = TcpStream::connect_timeout(&addr, Duration::from_millis(250));
        }
    }
}

/// How long a graceful shutdown waits for queued + in-flight work to
/// finish before replying `drained: false` and exiting anyway.
const DRAIN_TIMEOUT: Duration = Duration::from_secs(5);

/// `cmd: "shutdown"` — admin-gated graceful stop, only honoured for
/// loopback peers (the serving port is otherwise unauthenticated). Flips
/// the serve loop's shutdown flag so no sibling connection accepts new
/// work, waits for the engine to answer everything already queued or
/// in flight ([`Engine::drain`]), wakes the accept loop so it exits, and
/// only then replies — when the caller sees `ok: true` the engine is
/// quiescent. Sibling connections close as soon as their next message
/// arrives; a router reads that as a connection reset and fails over.
fn handle_shutdown(engine: &Engine, peer: Option<SocketAddr>, ctl: &ServeCtl) -> Value {
    let loopback = peer.map(|p| p.ip().is_loopback()).unwrap_or(false);
    if !loopback {
        return v1::encode_error(
            None,
            None,
            &ApiError::bad_request(format!(
                "cmd \"shutdown\" is admin-only: accepted from loopback peers, \
                 denied for {peer:?}"
            )),
            1,
        );
    }
    ctl.shutdown.store(true, SeqCst);
    let drained = engine.drain(DRAIN_TIMEOUT);
    ctl.wake();
    json::obj(vec![
        ("ok", Value::Bool(true)),
        ("shutdown", Value::Bool(true)),
        ("drained", Value::Bool(drained)),
    ])
}

/// Serve the Prometheus exposition on its own plaintext listener (the
/// `--metrics-addr` plane). Each connection gets one scrape: whatever the
/// client sent (an HTTP GET head, or nothing at all) is drained
/// best-effort, then the full exposition is written as a minimal HTTP/1.0
/// response and the connection closes — enough for `curl`, Prometheus,
/// and `nc` alike without an HTTP dependency.
pub fn serve_metrics(engine: Arc<Engine>, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    serve_metrics_listener(engine, listener)
}

/// [`serve_metrics`] on an already-bound listener (tests bind port 0).
pub fn serve_metrics_listener(engine: Arc<Engine>, listener: TcpListener) -> Result<()> {
    serve_metrics_with(listener, move || engine.render_prometheus())
}

/// The exposition accept loop over an arbitrary render closure — lets the
/// serving bench publish metrics for whichever short-lived engine is
/// currently under load, not just one long-lived [`Engine`].
pub fn serve_metrics_with<F>(listener: TcpListener, render: F) -> Result<()>
where
    F: Fn() -> String + Send + Sync + 'static,
{
    log_info!("metrics exposition on {:?}", listener.local_addr());
    let render = Arc::new(render);
    for stream in listener.incoming() {
        let stream = stream?;
        let render = Arc::clone(&render);
        std::thread::spawn(move || {
            if let Err(e) = serve_scrape(render.as_ref(), stream) {
                crate::log_debug!("scrape connection closed: {e}");
            }
        });
    }
    Ok(())
}

/// Hard cap on how many request-head bytes one scrape connection may
/// send before we stop reading and just answer — a peer streaming an
/// endless "request line" cannot grow memory.
const SCRAPE_HEAD_MAX: u64 = 8 * 1024;

/// One scrape connection: drain the request head (bounded by a read
/// timeout so a silent peer cannot pin the thread, and by
/// [`SCRAPE_HEAD_MAX`] so a chatty one cannot grow memory), render,
/// respond, close. Explicit HTTP requests for any path other than
/// `/metrics` get a 404; raw-TCP scrapers that send nothing (`nc`)
/// still get the exposition.
fn serve_scrape(render: &dyn Fn() -> String, mut stream: TcpStream) -> std::io::Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_millis(250)))?;
    let mut reader = BufReader::new(std::io::Read::take(stream.try_clone()?, SCRAPE_HEAD_MAX));
    let mut line = String::new();
    let mut request_line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            // blank line = end of an HTTP request head; EOF, timeout or
            // the head cap = a raw-TCP scraper — answer either way
            Ok(0) => break,
            Ok(_) if line == "\r\n" || line == "\n" => break,
            Ok(_) => {
                if request_line.is_empty() {
                    request_line = line.trim_end().to_string();
                }
            }
            Err(_) => break,
        }
    }
    // "GET /path HTTP/1.x" → route on the path (query string ignored);
    // anything that does not parse as an HTTP request line is treated as
    // a raw scrape and served the exposition
    let mut parts = request_line.split_whitespace();
    let not_found = match (parts.next(), parts.next(), parts.next()) {
        (Some(_method), Some(target), Some(proto)) if proto.starts_with("HTTP/") => {
            target.split('?').next().unwrap_or(target) != "/metrics"
        }
        _ => false,
    };
    if not_found {
        let body = "not found — scrape /metrics\n";
        let head = format!(
            "HTTP/1.0 404 Not Found\r\nContent-Type: text/plain\r\n\
             Content-Length: {}\r\nConnection: close\r\n\r\n",
            body.len()
        );
        stream.write_all(head.as_bytes())?;
        stream.write_all(body.as_bytes())?;
        return stream.flush();
    }
    let body = render();
    let head = format!(
        "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// What the connection remembers about an in-flight submission, keyed by
/// engine id: how to encode its completion.
struct PendingMeta {
    /// wire dialect the request arrived in (0 | 1 | 2)
    version: u8,
    /// client-chosen correlation id (engine id echoed when absent)
    client_id: Option<u64>,
    /// request row count (the output row width comes from the response —
    /// variants may have out_dim != in_dim)
    samples: usize,
    /// client-supplied trace id, echoed on the reply (success or error);
    /// server-assigned ids are never echoed — pre-trace replies stay
    /// byte-identical
    trace: Option<u64>,
}

/// One JSON line as wire bytes (trailing newline included).
fn line_bytes(v: &Value) -> Vec<u8> {
    let mut s = json::to_string(v);
    s.push('\n');
    s.into_bytes()
}

/// Write one complete message and flush — the immediate-reply path
/// (command replies, rejections, the strict-order v0 serve). Completions
/// go through the pump, which coalesces its flushes instead.
fn write_msg(writer: &Mutex<BufWriter<TcpStream>>, bytes: &[u8]) -> std::io::Result<()> {
    let mut w = writer.lock().unwrap();
    w.write_all(bytes)?;
    w.flush()
}

fn handle_conn(engine: &Engine, stream: TcpStream, ctl: &ServeCtl) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let writer = Arc::new(Mutex::new(BufWriter::new(stream.try_clone()?)));
    let pending: Arc<Mutex<HashMap<u64, PendingMeta>>> = Arc::new(Mutex::new(HashMap::new()));
    let (done_tx, done_rx) = mpsc::channel::<Completion>();

    // completion pump: encodes finished submissions (in whatever order the
    // engine completes them) and writes them back; exits once the reader
    // has hung up AND every in-flight request completed (all senders
    // gone). Flushes are coalesced: every completion already finished is
    // written back to back, then the socket is flushed ONCE — under load
    // many replies share one syscall.
    let pump = {
        let writer = Arc::clone(&writer);
        let pending = Arc::clone(&pending);
        std::thread::spawn(move || {
            while let Ok(first) = done_rx.recv() {
                let mut w = writer.lock().unwrap();
                for c in std::iter::once(first).chain(done_rx.try_iter()) {
                    let meta = match pending.lock().unwrap().remove(&c.id) {
                        Some(m) => m,
                        None => continue, // reader vanished mid-registration
                    };
                    if w.write_all(&completion_bytes(&meta, c)).is_err() {
                        return; // peer gone; stop draining
                    }
                }
                if w.flush().is_err() {
                    return;
                }
            }
        })
    };

    let mut reader = BufReader::new(stream);
    let mut read_err: Option<Error> = None;
    loop {
        // one-byte sniff routes each message: frame magic → binary v2,
        // anything else → a JSON line (v0/v1)
        let first = match reader.fill_buf() {
            Ok(buf) => match buf.first() {
                Some(b) => *b,
                None => break, // clean EOF between messages
            },
            Err(e) => {
                read_err = Some(e.into());
                break;
            }
        };
        // a sibling connection triggered graceful shutdown while we were
        // blocked reading: close instead of accepting this message (the
        // engine has already drained — new work would be dropped)
        if ctl.is_shutdown() {
            break;
        }
        if first == v2::FRAME_MAGIC {
            let frame = match v2::read_frame(&mut reader) {
                Ok(f) => f,
                // a malformed or truncated frame loses the framing — reply
                // loudly (best effort), then close; there is no resync
                Err(v2::FrameError::Bad(e)) => {
                    let _ = write_msg(&writer, &v2::encode_error(None, None, &e));
                    break;
                }
                Err(v2::FrameError::Io(e))
                    if e.kind() == std::io::ErrorKind::UnexpectedEof =>
                {
                    let _ = write_msg(
                        &writer,
                        &v2::encode_error(
                            None,
                            None,
                            &ApiError::bad_request("connection truncated mid-frame"),
                        ),
                    );
                    break;
                }
                Err(v2::FrameError::Io(e)) => {
                    read_err = Some(e.into());
                    break;
                }
            };
            if let Some(reply) = handle_frame(engine, frame, &done_tx, &pending) {
                if write_msg(&writer, &reply).is_err() {
                    break;
                }
            }
            continue;
        }
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => break,
            Ok(_) => {}
            Err(e) => {
                read_err = Some(e.into());
                break;
            }
        }
        if line.trim().is_empty() {
            continue;
        }
        if let Some(reply) = handle_pipelined(engine, &line, &done_tx, &pending, peer, ctl) {
            if write_msg(&writer, &line_bytes(&reply)).is_err() {
                break;
            }
        }
        // this very message was the shutdown command: its reply is out,
        // close the connection so the caller's teardown is deterministic
        if ctl.is_shutdown() {
            break;
        }
    }
    drop(done_tx);
    let _ = pump.join();
    crate::log_debug!("peer {peer:?} disconnected");
    match read_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Encode one completion in the dialect its request arrived in.
fn completion_bytes(meta: &PendingMeta, c: Completion) -> Vec<u8> {
    let id = meta.client_id.unwrap_or(c.id);
    if meta.version == 2 {
        return match c.result {
            Ok(resp) => {
                let mut r = v1::response_from_engine(id, meta.samples, &resp);
                r.trace = meta.trace;
                v2::encode_response(&r)
            }
            Err(e) => v2::encode_error(Some(id), meta.trace, &e),
        };
    }
    line_bytes(&match c.result {
        Ok(resp) => {
            let mut r = v1::response_from_engine(id, meta.samples, &resp);
            r.trace = meta.trace;
            v1::encode_response(&r, meta.version)
        }
        Err(e) => v1::encode_error(Some(id), meta.trace, &e, meta.version),
    })
}

/// Process one request line on the pipelined path. Returns an immediate
/// reply for command lines and rejected submissions; accepted submissions
/// return `None` — their reply arrives later via the completion pump.
fn handle_pipelined(
    engine: &Engine,
    line: &str,
    done: &mpsc::Sender<Completion>,
    pending: &Mutex<HashMap<u64, PendingMeta>>,
    peer: Option<SocketAddr>,
    ctl: &ServeCtl,
) -> Option<Value> {
    let v = match json::parse(line) {
        Ok(v) => v,
        Err(e) => {
            return Some(v1::encode_error(
                None,
                None,
                &ApiError::bad_request(format!("invalid JSON: {e}")),
                1,
            ))
        }
    };
    if v.get("cmd").is_some() {
        // shutdown needs the connection's peer (admin gating) and the
        // serve loop's control block, so it is handled here rather than
        // in the socketless handle_cmd
        if v.get("cmd").and_then(Value::as_str) == Some("shutdown") {
            return Some(handle_shutdown(engine, peer, ctl));
        }
        return Some(handle_cmd(engine, &v));
    }
    let version_guess = v1::wire_version(&v).unwrap_or(1);
    let (req, version) = match v1::decode_request(&v) {
        Ok(x) => x,
        Err(e) => {
            // best-effort id + trace echo so pipelined clients can still
            // correlate rejections of malformed lines
            return Some(v1::encode_error(
                v1::peek_id(&v),
                v1::peek_trace(&v),
                &e,
                version_guess,
            ));
        }
    };
    if version == 0 {
        // legacy v0 clients have no client-chosen ids and relied on the
        // old server's strict request→reply order; serve them
        // synchronously on the reader thread so that guarantee holds
        // (only v1 lines and v2 frames pipeline)
        return Some(serve_blocking(engine, req, 0));
    }
    match submit_pipelined(engine, req, version, done, pending) {
        None => None,
        Some((id, trace, e)) => Some(v1::encode_error(id, trace, &e, version)),
    }
}

/// Process one decoded v2 request frame on the pipelined path. Returns an
/// immediate error frame for rejected submissions; accepted submissions
/// return `None` — their reply frame arrives later via the completion
/// pump.
fn handle_frame(
    engine: &Engine,
    frame: v2::Frame,
    done: &mpsc::Sender<Completion>,
    pending: &Mutex<HashMap<u64, PendingMeta>>,
) -> Option<Vec<u8>> {
    // best-effort id + trace echo (same validation as the codec) so
    // pipelined clients can correlate rejections of malformed headers
    let client_id = v1::peek_id(&frame.header);
    let client_trace = v1::peek_trace(&frame.header);
    let req = match v2::decode_request(frame) {
        Ok(r) => r,
        Err(e) => return Some(v2::encode_error(client_id, client_trace, &e)),
    };
    match submit_pipelined(engine, req, 2, done, pending) {
        None => None,
        Some((id, trace, e)) => Some(v2::encode_error(id, trace, &e)),
    }
}

/// Submit one decoded request on the pipelined path, registering its
/// completion meta keyed by engine id. The pending lock is held across
/// `submit_with` so the completion pump cannot observe a finished id
/// before its meta is registered. Returns the rejection (client id +
/// error) when the engine refuses the request.
fn submit_pipelined(
    engine: &Engine,
    req: InferRequest,
    version: u8,
    done: &mpsc::Sender<Completion>,
    pending: &Mutex<HashMap<u64, PendingMeta>>,
) -> Option<(Option<u64>, Option<u64>, ApiError)> {
    let opts = req.submit_options();
    let InferRequest {
        id: client_id,
        task,
        samples,
        dims,
        input,
        budget,
        trace,
        ..
    } = req;
    // the decoded payload moves into the engine as one contiguous block —
    // for v2 frames this is the same allocation the frame was read into
    let block = RowBlock::new(samples, dims, input);
    let mut map = pending.lock().unwrap();
    match engine.submit_with(&task, budget, block, &opts, done.clone()) {
        Ok(engine_id) => {
            map.insert(
                engine_id,
                PendingMeta {
                    version,
                    client_id,
                    samples,
                    trace,
                },
            );
            None
        }
        Err(e) => Some((client_id, trace, e)),
    }
}

/// Submit one decoded request and block for its reply, encoded in
/// `version`'s dialect — the synchronous serve used by [`handle_line`]
/// and by v0 lines on pipelined connections.
fn serve_blocking(engine: &Engine, req: InferRequest, version: u8) -> Value {
    let opts = req.submit_options();
    let InferRequest {
        id: client_id,
        task,
        samples,
        input,
        budget,
        trace,
        ..
    } = req;
    let handle = match engine.submit_opts(&task, budget, input, samples, &opts) {
        Ok(h) => h,
        Err(e) => return v1::encode_error(client_id, trace, &e, version),
    };
    let id = client_id.unwrap_or(handle.id());
    match handle.wait() {
        Ok(resp) => {
            let mut r = v1::response_from_engine(id, samples, &resp);
            r.trace = trace;
            v1::encode_response(&r, version)
        }
        Err(e) => v1::encode_error(Some(id), trace, &e, version),
    }
}

/// One completed span as a JSON object — raw per-stage timestamps (µs
/// since the process clock epoch; 0 = the stage was never reached) plus
/// the solver counters, resolved back to task/variant names.
fn span_value(m: &crate::coordinator::CoordinatorMetrics, s: &crate::obs::Span) -> Value {
    use crate::obs::Stage;
    let (task, variant) = m.key_name(s.key).unwrap_or_default();
    let st = &s.stamps;
    json::obj(vec![
        ("trace", json::num(s.trace as f64)),
        ("id", json::num(s.id as f64)),
        ("task", json::s(&task)),
        ("variant", json::s(&variant)),
        ("rows", json::num(s.rows as f64)),
        ("ok", Value::Bool(s.ok)),
        ("submit_us", json::num(st.get(Stage::Submit) as f64)),
        ("admission_us", json::num(st.get(Stage::Admission) as f64)),
        ("enqueue_us", json::num(st.get(Stage::Enqueue) as f64)),
        ("pop_us", json::num(st.get(Stage::Pop) as f64)),
        ("pad_us", json::num(st.get(Stage::Pad) as f64)),
        ("exec_start_us", json::num(st.get(Stage::ExecStart) as f64)),
        ("exec_end_us", json::num(st.get(Stage::ExecEnd) as f64)),
        ("reply_us", json::num(st.get(Stage::Reply) as f64)),
        ("total_us", json::num(s.total_us() as f64)),
        ("nfe", json::num(st.nfe as f64)),
        ("accepted", json::num(st.accepted as f64)),
        ("rejected", json::num(st.rejected as f64)),
    ])
}

/// Optional strictly-positive count field on a command (`"n"`, `"k"`).
/// Absent → `None`; present must be a positive integer — zero and
/// non-numeric values are client bugs and get a `bad_request`, never a
/// silent default (the PR 6 no-silent-defaults rule).
fn positive_count(req: &Value, key: &str) -> Result<Option<usize>, ApiError> {
    match v1::field_u64(req, key)? {
        None => Ok(None),
        Some(0) => Err(ApiError::bad_request(format!(
            "{key} must be a positive integer, got 0"
        ))),
        Some(n) => Ok(Some(n as usize)),
    }
}

/// Handle a `{"cmd": ...}` line. Every error carries a stable `code`.
pub fn handle_cmd(engine: &Engine, req: &Value) -> Value {
    let cmd = match req.get("cmd").and_then(Value::as_str) {
        Some(c) => c,
        None => {
            return v1::encode_error(
                None,
                None,
                &ApiError::bad_request("cmd must be a string"),
                1,
            )
        }
    };
    match cmd {
        "metrics" => {
            let queues: Vec<Value> = engine
                .queue_depths()
                .into_iter()
                .map(|d| {
                    json::obj(vec![
                        ("task", json::s(&d.task)),
                        ("variant", json::s(&d.variant)),
                        ("requests", json::num(d.requests as f64)),
                        ("rows", json::num(d.rows as f64)),
                    ])
                })
                .collect();
            use std::sync::atomic::Ordering::Relaxed;
            let m = engine.metrics();
            let shed = m.shed.load(Relaxed);
            let rejects = m.overload_rejects.load(Relaxed);
            // the flat numeric counters double as the router's merge
            // inputs (util::merge::merge_metrics) — sums, ratio-of-sums
            // denominators, and responses-weighted percentile means all
            // come from these fields
            json::obj(vec![
                ("ok", Value::Bool(true)),
                ("backend", json::s(engine.backend_name())),
                ("report", json::s(&m.report())),
                ("goodput", json::num(m.goodput())),
                ("fill", json::num(m.fill_ratio())),
                ("shed", json::num(shed as f64)),
                ("overload_rejects", json::num(rejects as f64)),
                ("requests", json::num(m.requests.load(Relaxed) as f64)),
                ("responses", json::num(m.responses.load(Relaxed) as f64)),
                ("failures", json::num(m.failures.load(Relaxed) as f64)),
                ("deadline_met", json::num(m.deadline_met.load(Relaxed) as f64)),
                (
                    "deadline_misses",
                    json::num(m.deadline_misses.load(Relaxed) as f64),
                ),
                ("rows", json::num(m.rows.load(Relaxed) as f64)),
                ("padded_slots", json::num(m.padded_slots.load(Relaxed) as f64)),
                (
                    "total_p50_us",
                    json::num(m.total_latency.percentile_us(50.0)),
                ),
                (
                    "total_p99_us",
                    json::num(m.total_latency.percentile_us(99.0)),
                ),
                ("queues", Value::Arr(queues)),
            ])
        }
        "backend" => json::obj(vec![
            ("ok", Value::Bool(true)),
            ("backend", json::s(engine.backend_name())),
            ("workers", json::num(engine.worker_count() as f64)),
        ]),
        // version negotiation: which wire dialects this server speaks.
        // Clients prefer the highest they know; servers predating this
        // command answer unknown_cmd, which a client reads as "v1 only"
        "protocol" => json::obj(vec![
            ("ok", Value::Bool(true)),
            (
                "versions",
                Value::Arr(vec![json::num(0.0), json::num(1.0), json::num(2.0)]),
            ),
        ]),
        "tasks" => json::obj(vec![
            ("ok", Value::Bool(true)),
            (
                "tasks",
                Value::Arr(
                    engine
                        .manifest()
                        .tasks
                        .keys()
                        .map(|k| json::s(k))
                        .collect(),
                ),
            ),
        ]),
        // the whole Prometheus exposition, inline — for clients already on
        // the serving port; scrapers use the dedicated --metrics-addr
        // listener (see serve_metrics)
        "stats" => json::obj(vec![
            ("ok", Value::Bool(true)),
            ("format", json::s("prometheus")),
            ("text", json::s(&engine.render_prometheus())),
        ]),
        // the last N completed request spans, newest first (optional "n",
        // default 32; present-but-zero or non-numeric is a bad_request —
        // "n": 0 is a client bug, not a request for nothing)
        "trace" => {
            let n = match positive_count(req, "n") {
                Ok(n) => n.unwrap_or(32),
                Err(e) => return v1::encode_error(None, None, &e, 1),
            };
            let m = engine.metrics();
            let mut spans = Vec::new();
            m.spans.snapshot_into(&mut spans, n);
            json::obj(vec![
                ("ok", Value::Bool(true)),
                (
                    "spans",
                    Value::Arr(spans.iter().map(|s| span_value(m, s)).collect()),
                ),
            ])
        }
        // the slowest completed spans since startup, slowest first —
        // exemplars that a capacity-bounded ring would have overwritten
        // (optional "k" caps how many; default all, zero is a bad_request)
        "trace_slow" => {
            let k = match positive_count(req, "k") {
                Ok(k) => k.unwrap_or(usize::MAX),
                Err(e) => return v1::encode_error(None, None, &e, 1),
            };
            let m = engine.metrics();
            let mut spans = Vec::new();
            m.slow.snapshot_into(&mut spans);
            spans.truncate(k);
            json::obj(vec![
                ("ok", Value::Bool(true)),
                (
                    "spans",
                    Value::Arr(spans.iter().map(|s| span_value(m, s)).collect()),
                ),
            ])
        }
        // numerical-health verdicts from the shadow-audit plane: per
        // (task, variant) audited error vs the manifest MAPE budget, plus
        // input-drift scores vs the artifact's train_stats stamp
        "health" => match engine.audit() {
            None => json::obj(vec![
                ("ok", Value::Bool(true)),
                ("audit", Value::Bool(false)),
                (
                    "reason",
                    json::s("auditing disabled — serve with --audit-rate > 0"),
                ),
            ]),
            Some(plane) => {
                use std::sync::atomic::Ordering::Relaxed;
                let keys: Vec<Value> = plane
                    .snapshot()
                    .iter()
                    .map(|k| {
                        json::obj(vec![
                            ("task", json::s(&k.task)),
                            ("variant", json::s(&k.variant)),
                            ("samples", json::num(k.samples as f64)),
                            ("err_p50", json::num(k.err_p50)),
                            ("err_p99", json::num(k.err_p99)),
                            ("err_mean", json::num(k.err_mean)),
                            ("err_ewma", k.ewma.map(json::num).unwrap_or(Value::Null)),
                            ("budget", json::num(k.budget)),
                            ("budget_status", json::s(k.budget_status())),
                            ("breaches", json::num(k.breaches as f64)),
                            // drift is per-task state observed through this
                            // key; "disabled" = the artifact carries no
                            // train_stats stamp to score against
                            (
                                "drift",
                                if k.has_train_stats {
                                    json::obj(vec![
                                        ("rows", json::num(k.drift_rows as f64)),
                                        (
                                            "score",
                                            k.drift_score
                                                .map(json::num)
                                                .unwrap_or(Value::Null),
                                        ),
                                    ])
                                } else {
                                    json::s("disabled")
                                },
                            ),
                        ])
                    })
                    .collect();
                json::obj(vec![
                    ("ok", Value::Bool(true)),
                    ("audit", Value::Bool(true)),
                    ("rate", json::num(plane.config.rate)),
                    ("tol", json::num(plane.config.tol as f64)),
                    ("backlog", json::num(plane.backlog() as f64)),
                    ("enqueued", json::num(plane.enqueued.load(Relaxed) as f64)),
                    ("drops", json::num(plane.drops.load(Relaxed) as f64)),
                    (
                        "unsupported",
                        json::num(plane.unsupported.load(Relaxed) as f64),
                    ),
                    ("keys", Value::Arr(keys)),
                ])
            }
        },
        // graceful stop is a property of a live serve loop (it needs the
        // peer address and the accept loop's control block); the
        // socketless handle_line/handle_cmd path has nothing to stop
        "shutdown" => v1::encode_error(
            None,
            None,
            &ApiError::bad_request(
                "cmd \"shutdown\" is only valid on a live serving connection",
            ),
            1,
        ),
        // command errors use the v1 error shape (the version tag is how
        // clients branch); only v0-dialect *infer* replies omit it
        other => v1::encode_error(
            None,
            None,
            &ApiError::unknown_cmd(format!("unknown cmd {other:?}")),
            1,
        ),
    }
}

/// Process one request line synchronously (exposed for tests and one-shot
/// callers — no socket, no pipelining): decode in whatever dialect the
/// line arrived, submit, wait, encode. The pipelined connection loop is
/// the production path.
pub fn handle_line(engine: &Engine, line: &str) -> Value {
    let v = match json::parse(line) {
        Ok(v) => v,
        Err(e) => {
            return v1::encode_error(
                None,
                None,
                &ApiError::bad_request(format!("invalid JSON: {e}")),
                1,
            )
        }
    };
    if v.get("cmd").is_some() {
        return handle_cmd(engine, &v);
    }
    let version_guess = v1::wire_version(&v).unwrap_or(1);
    let (req, version) = match v1::decode_request(&v) {
        Ok(x) => x,
        Err(e) => return v1::encode_error(v1::peek_id(&v), v1::peek_trace(&v), &e, version_guess),
    };
    serve_blocking(engine, req, version)
}

/// Blocking + pipelined client over the typed protocol — examples,
/// integration tests, and the serving bench's TCP scenarios. Speaks v1
/// JSON lines by default; [`Self::prefer_v2`] negotiates up to binary v2
/// frames when the server supports them (and falls back to v1 when not).
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    next_id: u64,
    /// Encode requests as binary v2 frames (set by [`Self::prefer_v2`]).
    use_v2: bool,
    /// Active read timeout, echoed in timeout errors (`None` = block
    /// forever, the historical behaviour).
    read_timeout: Option<Duration>,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        Client::connect_with(addr, None, None)
    }

    /// [`Self::connect`] with explicit socket timeouts: `connect` bounds
    /// the TCP connect, `read` bounds every blocking read thereafter. On
    /// expiry the pending call returns a loud [`Error::Coordinator`]
    /// instead of hanging forever on a dead or stalled peer — the router
    /// and the cluster fixtures rely on this to bound failover latency.
    pub fn connect_with(
        addr: &str,
        connect: Option<Duration>,
        read: Option<Duration>,
    ) -> Result<Client> {
        let stream = match connect {
            None => TcpStream::connect(addr)?,
            Some(t) => {
                use std::net::ToSocketAddrs;
                let mut last: Option<std::io::Error> = None;
                let mut found = None;
                for a in addr.to_socket_addrs()? {
                    match TcpStream::connect_timeout(&a, t) {
                        Ok(s) => {
                            found = Some(s);
                            break;
                        }
                        Err(e) => last = Some(e),
                    }
                }
                match (found, last) {
                    (Some(s), _) => s,
                    (None, Some(e)) => return Err(e.into()),
                    (None, None) => {
                        return Err(Error::Coordinator(format!(
                            "{addr}: resolved to no socket addresses"
                        )))
                    }
                }
            }
        };
        stream.set_read_timeout(read)?;
        Ok(Client {
            writer: stream.try_clone()?,
            reader: BufReader::new(stream),
            next_id: 1,
            use_v2: false,
            read_timeout: read,
        })
    }

    /// Change the read timeout on the live connection (both halves share
    /// one socket, so it applies to the next blocking read immediately).
    pub fn set_read_timeout(&mut self, read: Option<Duration>) -> Result<()> {
        self.writer.set_read_timeout(read)?;
        self.read_timeout = read;
        Ok(())
    }

    /// Map a socket-level read error: timeout expiry becomes a loud,
    /// actionable message (the whole point of the timeout), everything
    /// else passes through unchanged.
    fn read_error(&self, e: std::io::Error) -> Error {
        use std::io::ErrorKind;
        if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) {
            Error::Coordinator(format!(
                "read timed out after {:?} waiting for a reply — peer dead or stalled",
                self.read_timeout.unwrap_or_default()
            ))
        } else {
            e.into()
        }
    }

    /// Negotiate up to binary v2: ask the server which protocol versions
    /// it speaks (`cmd: "protocol"`) and switch this client to v2 frames
    /// when the answer includes 2. A server predating the command answers
    /// `unknown_cmd` — the client then simply stays on v1 (the fallback
    /// rule). Returns whether v2 is now active.
    pub fn prefer_v2(&mut self) -> Result<bool> {
        let reply = self.request(&json::obj(vec![("cmd", json::s("protocol"))]))?;
        self.use_v2 = reply.get("ok").and_then(Value::as_bool) == Some(true)
            && reply
                .get("versions")
                .and_then(Value::as_arr)
                .is_some_and(|vs| vs.iter().any(|v| v.as_f64() == Some(2.0)));
        Ok(self.use_v2)
    }

    fn write_value(&mut self, v: &Value) -> Result<()> {
        self.writer.write_all(json::to_string(v).as_bytes())?;
        self.writer.write_all(b"\n")?;
        Ok(())
    }

    fn read_value(&mut self) -> Result<Value> {
        let mut line = String::new();
        match self.reader.read_line(&mut line) {
            Ok(0) => Err(Error::Coordinator("server closed the connection".into())),
            Ok(_) => json::parse(&line),
            Err(e) => Err(self.read_error(e)),
        }
    }

    /// Raw line round trip (command lines, protocol experiments).
    pub fn request(&mut self, v: &Value) -> Result<Value> {
        self.write_value(v)?;
        self.read_value()
    }

    /// Legacy **v0** single-sample request — kept for the deprecated-path
    /// tests; new code should use [`Self::infer_v1`].
    pub fn infer(&mut self, task: &str, budget: f32, input: &[f32]) -> Result<Value> {
        self.request(&json::obj(vec![
            ("task", json::s(task)),
            ("budget", json::num(budget as f64)),
            ("input", json::arr_f32(input)),
        ]))
    }

    /// Send one typed request without waiting, in the negotiated dialect
    /// (v1 line, or v2 frame after [`Self::prefer_v2`]). Assigns (and
    /// returns) a connection-unique id when the request doesn't carry one.
    pub fn send(&mut self, req: &InferRequest) -> Result<u64> {
        let id = match req.id {
            Some(i) => {
                self.next_id = self.next_id.max(i + 1);
                i
            }
            None => {
                let i = self.next_id;
                self.next_id += 1;
                i
            }
        };
        let mut r = req.clone();
        r.id = Some(id);
        if self.use_v2 {
            self.writer.write_all(&v2::encode_request(&r))?;
        } else {
            self.write_value(&v1::encode_request(&r))?;
        }
        Ok(id)
    }

    /// Read and decode the next reply (any in-flight id), sniffing the
    /// first byte so v1 lines and v2 frames can interleave on one
    /// connection.
    pub fn recv_reply(&mut self) -> Result<InferReply> {
        let first = match self.reader.fill_buf() {
            Ok(buf) => buf
                .first()
                .copied()
                .ok_or_else(|| Error::Coordinator("server closed the connection".into()))?,
            Err(e) => return Err(self.read_error(e)),
        };
        if first == v2::FRAME_MAGIC {
            let frame = match v2::read_frame(&mut self.reader) {
                Ok(f) => f,
                Err(v2::FrameError::Io(e)) => return Err(self.read_error(e)),
                Err(e) => return Err(e.into()),
            };
            return v2::decode_reply(frame).map_err(Error::from);
        }
        let v = self.read_value()?;
        v1::decode_reply(&v).map_err(Error::from)
    }

    /// Send one v1 request and wait for **its** reply.
    pub fn infer_v1(&mut self, req: &InferRequest) -> Result<InferReply> {
        let id = self.send(req)?;
        let reply = self.recv_reply()?;
        if reply.id() != Some(id) {
            return Err(Error::Coordinator(format!(
                "reply id {:?} does not match request id {id} (other requests \
                 in flight? use infer_pipelined)",
                reply.id()
            )));
        }
        Ok(reply)
    }

    /// The pipelined loop: send **all** requests, then await all replies,
    /// matching out-of-order completions by id. Returns replies in request
    /// order. Requests carrying explicit ids must be unique.
    pub fn infer_pipelined(&mut self, reqs: &[InferRequest]) -> Result<Vec<InferReply>> {
        let mut ids = Vec::with_capacity(reqs.len());
        for r in reqs {
            ids.push(self.send(r)?);
        }
        let mut by_id: HashMap<u64, InferReply> = HashMap::with_capacity(ids.len());
        while by_id.len() < ids.len() {
            let reply = self.recv_reply()?;
            match reply.id() {
                Some(id) if ids.contains(&id) && !by_id.contains_key(&id) => {
                    by_id.insert(id, reply);
                }
                other => {
                    return Err(Error::Coordinator(format!(
                        "unmatched reply id {other:?} on the pipelined connection"
                    )))
                }
            }
        }
        Ok(ids
            .iter()
            .map(|id| by_id.remove(id).expect("collected above"))
            .collect())
    }
}
