//! TCP JSON-lines serving front end.
//!
//! Protocol (one JSON object per line, both directions):
//!
//! ```text
//! → {"task": "cnf_rings", "budget": 0.05, "input": [0.1, -0.7]}
//! ← {"ok": true, "variant": "hyperheun_k1", "mape": 0.042,
//!    "latency_us": 812, "output": [...]}
//! → {"cmd": "metrics"}
//! ← {"ok": true, "report": "..."}
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use crate::coordinator::engine::Engine;
use crate::util::json::{self, Value};
use crate::{log_info, Result};

/// Serve `engine` on `addr` (e.g. "127.0.0.1:7878"). Blocks forever; one
/// thread per connection (connection counts here are test/bench scale).
pub fn serve(engine: Arc<Engine>, addr: &str) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    serve_listener(engine, listener)
}

/// Serve on an already-bound listener (lets tests bind port 0 and read the
/// ephemeral port back before serving).
pub fn serve_listener(engine: Arc<Engine>, listener: TcpListener) -> Result<()> {
    log_info!("listening on {:?}", listener.local_addr());
    for stream in listener.incoming() {
        let stream = stream?;
        let engine = Arc::clone(&engine);
        std::thread::spawn(move || {
            if let Err(e) = handle_conn(&engine, stream) {
                crate::log_debug!("connection closed: {e}");
            }
        });
    }
    Ok(())
}

fn handle_conn(engine: &Engine, stream: TcpStream) -> Result<()> {
    let peer = stream.peer_addr().ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = handle_line(engine, &line);
        writer.write_all(json::to_string(&reply).as_bytes())?;
        writer.write_all(b"\n")?;
    }
    crate::log_debug!("peer {peer:?} disconnected");
    Ok(())
}

/// Process one request line (exposed for tests — no socket needed).
pub fn handle_line(engine: &Engine, line: &str) -> Value {
    match handle_line_inner(engine, line) {
        Ok(v) => v,
        Err(e) => json::obj(vec![
            ("ok", Value::Bool(false)),
            ("error", json::s(&e.to_string())),
        ]),
    }
}

fn handle_line_inner(engine: &Engine, line: &str) -> Result<Value> {
    let req = json::parse(line)?;
    if let Some(cmd) = req.get("cmd").and_then(Value::as_str) {
        return match cmd {
            "metrics" => Ok(json::obj(vec![
                ("ok", Value::Bool(true)),
                ("backend", json::s(engine.backend_name())),
                ("report", json::s(&engine.metrics().report())),
            ])),
            "backend" => Ok(json::obj(vec![
                ("ok", Value::Bool(true)),
                ("backend", json::s(engine.backend_name())),
                ("workers", json::num(engine.worker_count() as f64)),
            ])),
            "tasks" => Ok(Value::Obj(
                [
                    ("ok".to_string(), Value::Bool(true)),
                    (
                        "tasks".to_string(),
                        Value::Arr(
                            engine
                                .manifest()
                                .tasks
                                .keys()
                                .map(|k| json::s(k))
                                .collect(),
                        ),
                    ),
                ]
                .into_iter()
                .collect(),
            )),
            other => Err(crate::Error::Coordinator(format!(
                "unknown cmd {other:?}"
            ))),
        };
    }
    let task = req
        .req("task")?
        .as_str()
        .ok_or_else(|| crate::Error::Coordinator("task must be a string".into()))?
        .to_string();
    let budget = req
        .get("budget")
        .and_then(Value::as_f32)
        .unwrap_or(f32::INFINITY);
    let (input, _) = req.req("input")?.as_f32_tensor()?;
    let resp = engine.infer(&task, budget, input)?;
    Ok(json::obj(vec![
        ("ok", Value::Bool(true)),
        ("id", json::num(resp.id as f64)),
        ("variant", json::s(&resp.variant)),
        ("mape", json::num(resp.mape)),
        ("nfe", json::num(resp.nfe as f64)),
        ("latency_us", json::num(resp.latency.as_micros() as f64)),
        ("batch_fill", json::num(resp.batch_fill as f64)),
        ("output", json::arr_f32(&resp.output)),
    ]))
}

/// Minimal blocking client for examples and integration tests.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        Ok(Client {
            writer: stream.try_clone()?,
            reader: BufReader::new(stream),
        })
    }

    pub fn request(&mut self, v: &Value) -> Result<Value> {
        self.writer
            .write_all(json::to_string(v).as_bytes())?;
        self.writer.write_all(b"\n")?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        json::parse(&line)
    }

    pub fn infer(&mut self, task: &str, budget: f32, input: &[f32]) -> Result<Value> {
        self.request(&json::obj(vec![
            ("task", json::s(task)),
            ("budget", json::num(budget as f64)),
            ("input", json::arr_f32(input)),
        ]))
    }
}
