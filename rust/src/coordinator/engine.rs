//! The coordinator engine: policy → queues → dispatch worker pool →
//! pluggable execution backend.
//!
//! Submission is **non-blocking**: [`Engine::submit`] enqueues a request
//! (single- or multi-sample) and returns a [`SubmitHandle`] immediately;
//! completions are delivered id-correlated on a channel, so one caller can
//! keep many requests in flight ([`Engine::submit_with`] lets any number
//! of submissions share one completion channel — the pipelined server
//! loop). [`Engine::infer`] remains the thin blocking wrapper. Every
//! rejection and failure carries a stable [`ApiError`] code; a request
//! with a deadline fails fast with `deadline_exceeded` when its batch
//! dispatches too late.
//!
//! Dispatch runs on a small pool of workers, each pulling one ready batch
//! at a time from the shared [`Batcher`]. A per-[`QueueKey`] affinity set
//! guarantees that a queue's batches execute (and therefore respond) in
//! FIFO order, while batches for *distinct* (task, variant) queues run
//! concurrently — on the [`NativeBackend`](crate::runtime::NativeBackend)
//! genuinely in parallel, on the PJRT backend pipelined up to the executor
//! thread.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::api::ApiError;
use crate::coordinator::batcher::{
    pad_batch_into, Batcher, Pending, QueueDepth, QueueKey, ReadyBatch,
};
use crate::coordinator::metrics::CoordinatorMetrics;
use crate::coordinator::policy::{select_variant, Policy};
use crate::coordinator::request::{
    Completion, CompletionSender, Priority, Request, Response, RowBlock,
};
use crate::runtime::backend::{BackendKind, ExecBackend};
use crate::runtime::manifest::Manifest;
use crate::{log_debug, log_info, Error, Result};

/// EWMA smoothing factor for the per-(task, variant) measured batch
/// wall-clock that admission control predicts queue waits from.
const WALL_EWMA_ALPHA: f64 = 0.3;

/// Admission-control seed before the first measurement: the manifest's
/// per-sample `nfe` × this µs/NFE guess approximates one batch wall-clock,
/// so a cold queue still rejects obviously-unmeetable deadlines instead of
/// admitting blind until the first batch lands.
const SEED_WALL_US_PER_NFE: f64 = 25.0;

/// SLO-defence knobs: admission control, load shedding, client quotas.
/// All default to "admit everything except provably-late deadlines" —
/// shedding and quotas are opt-in because they refuse work.
#[derive(Clone, Debug)]
pub struct SloConfig {
    /// Reject a deadlined request with `overloaded` *before* enqueue when
    /// the predicted queue wait (per-(task, variant) wall-clock EWMA ×
    /// batches already queued ahead) exceeds its deadline.
    pub admission: bool,
    /// Total queued-rows high-water mark: a push that leaves more rows
    /// queued sheds lowest-priority, latest-deadline requests back down
    /// to the mark (0 = never shed).
    pub shed_high_water_rows: usize,
    /// Per-client queued-row quota enforced at push (0 = unlimited;
    /// unattributed requests are exempt).
    pub client_quota_rows: usize,
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig {
            admission: true,
            shed_high_water_rows: 0,
            client_quota_rows: 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub artifacts_dir: std::path::PathBuf,
    /// dynamic batching deadline
    pub max_wait: Duration,
    pub policy: Policy,
    /// which execution backend serves batches
    pub backend: BackendKind,
    /// dispatch worker count; 0 = auto (one per core, clamped to [2, 8])
    pub workers: usize,
    /// SLO defence: admission control, shedding high-water mark, quotas
    pub slo: SloConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            artifacts_dir: crate::artifacts_dir(),
            max_wait: Duration::from_millis(2),
            policy: Policy::MinMacs,
            backend: BackendKind::Pjrt,
            workers: 0,
            slo: SloConfig::default(),
        }
    }
}

/// Per-request submission options of the v1 surface. `Default` reproduces
/// the classic behavior: engine policy axis, budget-selected variant, no
/// deadline.
#[derive(Clone, Debug, Default)]
pub struct SubmitOptions {
    /// Override the engine's cost axis for this request.
    pub policy: Option<Policy>,
    /// Pin an exact variant, bypassing the budget policy.
    pub variant: Option<String>,
    /// Fail fast with `deadline_exceeded` if the request has not been
    /// dispatched within this duration of submission.
    pub deadline: Option<Duration>,
    /// Priority class: breaks EDF dispatch ties between equally-urgent
    /// queues, and lower classes are shed first under overload.
    pub priority: Priority,
    /// Client identity for per-client row quotas (`None` = unattributed,
    /// exempt from quotas).
    pub client: Option<String>,
}

/// A non-blocking submission: the engine id plus the completion channel.
/// Drop it to ignore the response (the engine never blocks on it).
#[derive(Debug)]
pub struct SubmitHandle {
    id: u64,
    rx: mpsc::Receiver<Completion>,
}

impl SubmitHandle {
    /// The engine-assigned submission id (what [`Completion::id`] carries).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the completion arrives. An engine shut down before
    /// responding surfaces as an `internal` error.
    pub fn wait(&self) -> std::result::Result<Response, ApiError> {
        match self.rx.recv() {
            Ok(c) => c.result,
            Err(_) => Err(ApiError::internal("engine dropped the response channel")),
        }
    }

    /// [`Self::wait`] with a timeout; `None` means the timeout elapsed
    /// (the request is still in flight).
    pub fn wait_timeout(
        &self,
        timeout: Duration,
    ) -> Option<std::result::Result<Response, ApiError>> {
        match self.rx.recv_timeout(timeout) {
            Ok(c) => Some(c.result),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Some(Err(ApiError::internal("engine dropped the response channel")))
            }
        }
    }

    /// The raw completion receiver (tests that assert channel lifecycle).
    pub fn receiver(&self) -> &mpsc::Receiver<Completion> {
        &self.rx
    }
}

fn resolve_workers(configured: usize) -> usize {
    if configured > 0 {
        return configured;
    }
    thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(2)
        .clamp(2, 8)
}

/// Queues + the affinity set, under one lock.
struct DispatchState {
    batcher: Batcher,
    /// keys currently executing on some worker
    inflight: HashSet<QueueKey>,
    /// per-(task, variant) EWMA of measured batch wall-clock (µs),
    /// updated by the workers after each executed batch — what admission
    /// control predicts queue waits from
    wall_ewma: HashMap<QueueKey, f64>,
}

struct Shared {
    state: Mutex<DispatchState>,
    work: Condvar,
    shutdown: AtomicBool,
}

/// The serving engine. `submit` is thread-safe; execution happens on the
/// dispatch worker pool against the configured backend.
pub struct Engine {
    manifest: Arc<Manifest>,
    shared: Arc<Shared>,
    metrics: Arc<CoordinatorMetrics>,
    backend: Arc<dyn ExecBackend>,
    next_id: AtomicU64,
    workers: Vec<thread::JoinHandle<()>>,
    config: EngineConfig,
}

impl Engine {
    pub fn new(config: EngineConfig) -> Result<Engine> {
        let manifest = Arc::new(Manifest::load(&config.artifacts_dir)?);
        let backend: Arc<dyn ExecBackend> = Arc::from(config.backend.create()?);
        let shared = Arc::new(Shared {
            state: Mutex::new(DispatchState {
                batcher: Batcher::new(config.max_wait)
                    .with_client_quota(config.slo.client_quota_rows),
                inflight: HashSet::new(),
                wall_ewma: HashMap::new(),
            }),
            work: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let metrics = Arc::new(CoordinatorMetrics::new());

        let n = resolve_workers(config.workers);
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let spawned = {
                let shared = Arc::clone(&shared);
                let manifest = Arc::clone(&manifest);
                let metrics = Arc::clone(&metrics);
                let backend = Arc::clone(&backend);
                thread::Builder::new()
                    .name(format!("hsolve-dispatch-{i}"))
                    .spawn(move || worker_main(shared, manifest, metrics, backend))
            };
            match spawned {
                Ok(j) => workers.push(j),
                Err(e) => {
                    shared.shutdown.store(true, Relaxed);
                    shared.work.notify_all();
                    for j in workers {
                        let _ = j.join();
                    }
                    return Err(Error::Coordinator(format!("spawn dispatch worker: {e}")));
                }
            }
        }

        log_info!(
            "engine up: {} tasks, backend {}, {} dispatch workers, policy {:?}, max_wait {:?}",
            manifest.tasks.len(),
            backend.name(),
            n,
            config.policy,
            config.max_wait
        );
        Ok(Engine {
            manifest,
            shared,
            metrics,
            backend,
            next_id: AtomicU64::new(1),
            workers,
            config,
        })
    }

    pub fn with_defaults() -> Result<Engine> {
        Self::new(EngineConfig::default())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn metrics(&self) -> &CoordinatorMetrics {
        &self.metrics
    }

    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// The active backend's name ("pjrt" | "native").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Dispatch worker count actually running.
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Snapshot of per-(task, variant) queue depths (the `cmd:"metrics"`
    /// surface).
    pub fn queue_depths(&self) -> Vec<QueueDepth> {
        self.shared.state.lock().unwrap().batcher.depths()
    }

    /// Submit a request whose completion is delivered on `done`, tagged
    /// with the returned engine id — the pipelined path: any number of
    /// in-flight submissions can share one channel. `block` is the
    /// contiguous row-major `[rows, dims]` payload (moved in as-is — the
    /// binary v2 codec hands its decoded frame payload straight here);
    /// validation, policy selection and enqueueing all happen before this
    /// returns, so a returned id is a guarantee that exactly one
    /// [`Completion`] will be attempted for it (success, structured error,
    /// or — only if the engine is dropped first — channel disconnect).
    pub fn submit_with(
        &self,
        task: &str,
        budget: f32,
        block: RowBlock,
        opts: &SubmitOptions,
        done: CompletionSender,
    ) -> std::result::Result<u64, ApiError> {
        let entry = self
            .manifest
            .task(task)
            .map_err(|e| ApiError::unknown_task(e.to_string()))?;
        if entry.state_shape.is_empty() {
            return Err(ApiError::internal(format!(
                "task {task}: manifest state shape is rank 0"
            )));
        }
        let samples = block.rows;
        if samples == 0 {
            return Err(ApiError::shape_mismatch(format!(
                "task {task}: request carries zero samples"
            )));
        }
        let sample_dim: usize = entry.state_shape[1..].iter().product();
        if block.data.len() != samples * sample_dim {
            return Err(ApiError::shape_mismatch(format!(
                "task {task}: {samples} sample(s) × state dim {sample_dim} wants \
                 {} values, got {}",
                samples * sample_dim,
                block.data.len()
            )));
        }
        let b_cap = entry.batch();
        if samples > b_cap {
            return Err(ApiError::shape_mismatch(format!(
                "task {task}: request has {samples} samples but the exported \
                 executables take batches of {b_cap}; split the request"
            )));
        }
        let variant = match &opts.variant {
            Some(name) => entry.variant(name).ok_or_else(|| {
                ApiError::unknown_variant(format!(
                    "task {task} has no variant {name:?}"
                ))
            })?,
            None => {
                let axis = opts.policy.unwrap_or(self.config.policy);
                select_variant(entry, budget, axis).ok_or_else(|| {
                    ApiError::internal(format!("task {task} has no variants"))
                })?
            }
        };
        let key: QueueKey = (task.to_string(), variant.name.clone());
        let id = self.next_id.fetch_add(1, Relaxed);
        let mut req = Request::from_block(id, task, budget, block);
        let t0 = req.t_submit;
        req.deadline = opts.deadline.map(|d| t0 + d);
        req.priority = opts.priority;
        req.client = opts.client.clone();
        let slo = &self.config.slo;
        let shed_victims = {
            let mut s = self.shared.state.lock().unwrap();
            s.batcher.ensure_queue(&key, b_cap);
            // admission control: refuse a deadlined request before it
            // ever queues when the rows already ahead of it predict a
            // wait past its deadline — rejecting late work up front keeps
            // it from poisoning the queue for requests that can still win
            if slo.admission {
                if let Some(deadline) = opts.deadline {
                    let queued = s.batcher.queue_rows(&key);
                    if queued > 0 {
                        let seed = variant.nfe as f64 * SEED_WALL_US_PER_NFE;
                        let wall_us = s.wall_ewma.get(&key).copied().unwrap_or(seed);
                        let batches_ahead = queued.div_ceil(b_cap);
                        // +2: the request's own batch must also run, and a
                        // prior batch of this queue may already be in
                        // flight on its affine worker — admitting work
                        // that can only *just* make it loses to jitter
                        let predicted_us = (batches_ahead + 2) as f64 * wall_us;
                        if predicted_us > deadline.as_micros() as f64 {
                            drop(s);
                            self.metrics.overload_rejects.fetch_add(1, Relaxed);
                            return Err(ApiError::overloaded(format!(
                                "task {task}: {queued} queued rows predict a \
                                 {predicted_us:.0}µs wait, past the {}µs \
                                 deadline",
                                deadline.as_micros()
                            )));
                        }
                    }
                }
            }
            if let Err(p) = s.batcher.push(&key, Pending { req, done }) {
                drop(s);
                self.metrics.overload_rejects.fetch_add(1, Relaxed);
                let client = p.req.client.as_deref().unwrap_or("");
                return Err(ApiError::overloaded(format!(
                    "client {client:?} is at its queued-row quota of {}",
                    slo.client_quota_rows
                )));
            }
            if slo.shed_high_water_rows > 0 && s.batcher.queued_rows() > slo.shed_high_water_rows {
                s.batcher.shed_to(slo.shed_high_water_rows)
            } else {
                Vec::new()
            }
        };
        self.metrics.requests.fetch_add(1, Relaxed);
        for p in shed_victims {
            self.metrics.shed.fetch_add(1, Relaxed);
            complete(
                &self.metrics,
                p,
                Err(ApiError::overloaded(
                    "shed at the queued-rows high-water mark under overload",
                )),
            );
        }
        self.shared.work.notify_one();
        Ok(id)
    }

    /// Non-blocking submit with per-request options; returns a handle
    /// owning its completion channel. `input` is flat row-major
    /// `[samples, dims]` — the convenience wrapper over
    /// [`Self::submit_with`]'s [`RowBlock`] surface.
    pub fn submit_opts(
        &self,
        task: &str,
        budget: f32,
        input: Vec<f32>,
        samples: usize,
        opts: &SubmitOptions,
    ) -> std::result::Result<SubmitHandle, ApiError> {
        let (tx, rx) = mpsc::channel();
        let block = RowBlock::from_rows(samples, input);
        let id = self.submit_with(task, budget, block, opts, tx)?;
        Ok(SubmitHandle { id, rx })
    }

    /// Submit one single-sample request (the classic surface).
    pub fn submit(
        &self,
        task: &str,
        budget: f32,
        input: Vec<f32>,
    ) -> std::result::Result<SubmitHandle, ApiError> {
        self.submit_opts(task, budget, input, 1, &SubmitOptions::default())
    }

    /// Submit and wait — the thin blocking wrapper over [`Self::submit`].
    pub fn infer(&self, task: &str, budget: f32, input: Vec<f32>) -> Result<Response> {
        let handle = self.submit(task, budget, input).map_err(Error::from)?;
        handle.wait().map_err(Error::from)
    }

    /// Prepare every variant of `task` on the backend (PJRT compilation /
    /// native weight loading), so first requests don't pay it.
    pub fn warmup(&self, task: &str) -> Result<()> {
        let entry = self.manifest.task(task)?;
        for v in &entry.variants {
            self.backend.prepare(&self.manifest, entry, v)?;
        }
        Ok(())
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Relaxed);
        self.shared.work.notify_all();
        for j in self.workers.drain(..) {
            let _ = j.join();
        }
    }
}

/// Releases a claimed queue key when the batch finishes — on the normal
/// path *and* on unwind, so a panicking backend can't leave its queue
/// permanently marked in-flight (which would silently starve it).
struct InflightGuard<'a> {
    shared: &'a Shared,
    metrics: &'a CoordinatorMetrics,
    key: QueueKey,
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.metrics.batch_finished();
        match self.shared.state.lock() {
            Ok(mut s) => {
                s.inflight.remove(&self.key);
            }
            // the state lock is only poisoned if another worker died while
            // batching; still release our key so the queue isn't starved
            Err(poisoned) => {
                poisoned.into_inner().inflight.remove(&self.key);
            }
        }
        // releasing the key may make another batch of the same queue
        // poppable; other workers might all be asleep on the condvar
        self.shared.work.notify_all();
    }
}

fn worker_main(
    shared: Arc<Shared>,
    manifest: Arc<Manifest>,
    metrics: Arc<CoordinatorMetrics>,
    backend: Arc<dyn ExecBackend>,
) {
    // per-worker reusable padded-batch buffer: `pad_batch_into` refills it
    // for every batch, so steady-state dispatch does not allocate for
    // batch assembly
    let mut pad_buf: Vec<f32> = Vec::new();
    loop {
        // claim one ready batch under the lock, run it outside
        let batch: ReadyBatch = {
            let mut s = shared.state.lock().unwrap();
            loop {
                if shared.shutdown.load(Relaxed) {
                    return;
                }
                let now = Instant::now();
                let state = &mut *s;
                if let Some(batch) = state.batcher.pop_ready(now, &state.inflight) {
                    state.inflight.insert(batch.key.clone());
                    break batch;
                }
                // wait on non-busy queues only: a busy queue's expired
                // deadline would clamp this to ~0 and spin; its completion
                // notify_all is what wakes us for that queue
                let timeout = state
                    .batcher
                    .next_deadline_idle(&state.inflight)
                    .map(|dl| dl.saturating_duration_since(now))
                    .unwrap_or(Duration::from_millis(50));
                let (guard, _) = shared
                    .work
                    .wait_timeout(s, timeout.max(Duration::from_micros(100)))
                    .unwrap();
                s = guard;
            }
        };

        let key = batch.key.clone();
        let _guard = InflightGuard {
            shared: &*shared,
            metrics: &*metrics,
            key: key.clone(),
        };
        metrics.batch_started();
        if let Some(wall) = run_batch(&manifest, &metrics, backend.as_ref(), batch, &mut pad_buf) {
            // feed the measured wall-clock back into the admission
            // predictor for this (task, variant)
            let wall_us = wall.as_secs_f64() * 1e6;
            let mut s = shared.state.lock().unwrap();
            let e = s.wall_ewma.entry(key).or_insert(wall_us);
            *e = WALL_EWMA_ALPHA * wall_us + (1.0 - WALL_EWMA_ALPHA) * *e;
        }
    }
}

/// Deliver one completion; a closed receiver just means the caller
/// stopped listening.
fn complete(
    metrics: &CoordinatorMetrics,
    p: Pending,
    result: std::result::Result<Response, ApiError>,
) {
    if result.is_err() {
        metrics.failures.fetch_add(1, Relaxed);
    }
    let _ = p.done.send(Completion {
        id: p.req.id,
        result,
    });
}

/// Fail every item of a batch; returns `None` so `run_batch` error paths
/// can `return fail_items(...)` without an executed wall-clock.
fn fail_items(
    metrics: &CoordinatorMetrics,
    key: &QueueKey,
    items: Vec<Pending>,
    err: ApiError,
) -> Option<Duration> {
    crate::log_error!("batch {key:?} failed: {err}");
    for p in items {
        complete(metrics, p, Err(err.clone()));
    }
    None
}

/// Execute one ready batch. Returns the backend wall-clock when the batch
/// actually executed (the admission EWMA observation), `None` otherwise.
fn run_batch(
    manifest: &Manifest,
    metrics: &CoordinatorMetrics,
    backend: &dyn ExecBackend,
    batch: ReadyBatch,
    pad_buf: &mut Vec<f32>,
) -> Option<Duration> {
    let ReadyBatch { key, items } = batch;
    let entry = match manifest.task(&key.0) {
        Ok(e) => e,
        Err(e) => {
            return fail_items(metrics, &key, items, ApiError::unknown_task(e.to_string()))
        }
    };
    let variant = match entry.variant(&key.1) {
        Some(v) => v.clone(),
        None => {
            return fail_items(
                metrics,
                &key,
                items,
                ApiError::internal("variant vanished from the manifest"),
            )
        }
    };
    if variant.in_shape.is_empty() || variant.out_shape.is_empty() {
        return fail_items(
            metrics,
            &key,
            items,
            ApiError::internal("variant has rank-0 in/out shape"),
        );
    }

    let b_cap = entry.batch();
    let sample_dim: usize = variant.in_shape[1..].iter().product();
    let out_dim: usize = variant.out_shape[1..].iter().product();

    // fail-fast deadlines: a request whose deadline passed before this
    // dispatch gets a structured deadline_exceeded error and never
    // executes (an in-flight execute is never cancelled, by contract)
    let now = Instant::now();
    let mut live: Vec<Pending> = Vec::with_capacity(items.len());
    for p in items {
        match p.req.deadline {
            Some(d) if now >= d => {
                metrics.deadline_misses.fetch_add(1, Relaxed);
                let waited = now.duration_since(p.req.t_submit).as_micros();
                let err = ApiError::deadline_exceeded(format!(
                    "request waited {waited}µs, past its deadline, before its \
                     batch dispatched"
                ));
                complete(metrics, p, Err(err));
            }
            _ => live.push(p),
        }
    }
    if live.is_empty() {
        return None;
    }
    let items = live;

    // submit validated against the task's state shape; the variant's
    // executable row dim must agree or padding would silently corrupt
    // (image→logits exports take image-dim rows the state-dim submit
    // surface doesn't produce yet)
    if let Some(p) = items
        .iter()
        .find(|p| p.req.block.data.len() != p.req.block.rows * sample_dim)
    {
        let got = p.req.block.data.len();
        let rows = p.req.block.rows;
        return fail_items(
            metrics,
            &key,
            items,
            ApiError::shape_mismatch(format!(
                "request has {got} values over {rows} row(s) but variant row \
                 dim is {sample_dim}"
            )),
        );
    }

    // assemble the padded batch input into the worker's reusable buffer:
    // each request is one contiguous row block, fill rows zeroed
    let rows: usize = items.iter().map(|p| p.req.block.rows).sum();
    pad_batch_into(
        pad_buf,
        items.iter().map(|p| p.req.block.data.as_slice()),
        b_cap,
        sample_dim,
    );
    let queue_start = Instant::now();
    for p in &items {
        metrics
            .queue_latency
            .record(queue_start.duration_since(p.req.t_submit));
    }

    let t_exec = Instant::now();
    let out = match backend.execute(manifest, entry, &variant, pad_buf.as_slice()) {
        Ok(o) => o,
        Err(e) => return fail_items(metrics, &key, items, ApiError::from_engine(&e)),
    };
    let exec_time = t_exec.elapsed();
    metrics.exec_latency.record(exec_time);

    let nfe = out.nfe.unwrap_or(variant.nfe);
    if out.z.len() < rows * out_dim {
        // validate before recording: a short output produces no responses
        // and must not count as a served batch in fill/NFE accounting
        let got = out.z.len();
        return fail_items(
            metrics,
            &key,
            items,
            ApiError::internal(format!(
                "backend returned {got} values, batch needs {}",
                rows * out_dim
            )),
        );
    }
    metrics.record_batch(rows, b_cap, nfe, variant.macs);
    log_debug!("batch {}/{}: {rows}/{b_cap} rows in {exec_time:?}", key.0, key.1);
    let mut off = 0usize;
    for p in items {
        let n = p.req.block.rows * out_dim;
        let latency = p.req.t_submit.elapsed();
        metrics.total_latency.record(latency);
        metrics.responses.fetch_add(1, Relaxed);
        // goodput accounting: a response with no deadline had no SLO to
        // miss; one delivered past its deadline counts against goodput
        if p.req.deadline.is_none_or(|d| Instant::now() <= d) {
            metrics.deadline_met.fetch_add(1, Relaxed);
        }
        let resp = Response {
            id: p.req.id,
            output: out.z[off..off + n].to_vec(),
            variant: variant.name.clone(),
            mape: variant.mape,
            nfe,
            latency,
            batch_fill: rows,
        };
        off += n;
        complete(metrics, p, Ok(resp));
    }
    Some(exec_time)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_resolution_bounds() {
        assert_eq!(resolve_workers(3), 3);
        let auto = resolve_workers(0);
        assert!((2..=8).contains(&auto), "auto workers {auto}");
    }

    #[test]
    fn default_config_is_pjrt_auto() {
        let c = EngineConfig::default();
        assert_eq!(c.backend, BackendKind::Pjrt);
        assert_eq!(c.workers, 0);
        // SLO defaults: admission on, shedding and quotas off (they
        // refuse work, so they are opt-in)
        assert!(c.slo.admission);
        assert_eq!(c.slo.shed_high_water_rows, 0);
        assert_eq!(c.slo.client_quota_rows, 0);
    }

    #[test]
    fn default_submit_options_are_classic() {
        let o = SubmitOptions::default();
        assert!(o.policy.is_none() && o.variant.is_none() && o.deadline.is_none());
        assert_eq!(o.priority, Priority::Normal);
        assert!(o.client.is_none());
    }
}
