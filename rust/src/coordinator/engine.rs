//! The coordinator engine: policy → queues → dispatch worker pool →
//! pluggable execution backend.
//!
//! Dispatch runs on a small pool of workers, each pulling one ready batch
//! at a time from the shared [`Batcher`]. A per-[`QueueKey`] affinity set
//! guarantees that a queue's batches execute (and therefore respond) in
//! FIFO order, while batches for *distinct* (task, variant) queues run
//! concurrently — on the [`NativeBackend`](crate::runtime::NativeBackend)
//! genuinely in parallel, on the PJRT backend pipelined up to the executor
//! thread.

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{pad_batch, Batcher, Pending, QueueKey, ReadyBatch};
use crate::coordinator::metrics::CoordinatorMetrics;
use crate::coordinator::policy::{select_variant, Policy};
use crate::coordinator::request::{Request, Response};
use crate::runtime::backend::{BackendKind, ExecBackend};
use crate::runtime::manifest::Manifest;
use crate::{log_debug, log_info, Error, Result};

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub artifacts_dir: std::path::PathBuf,
    /// dynamic batching deadline
    pub max_wait: Duration,
    pub policy: Policy,
    /// which execution backend serves batches
    pub backend: BackendKind,
    /// dispatch worker count; 0 = auto (one per core, clamped to [2, 8])
    pub workers: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            artifacts_dir: crate::artifacts_dir(),
            max_wait: Duration::from_millis(2),
            policy: Policy::MinMacs,
            backend: BackendKind::Pjrt,
            workers: 0,
        }
    }
}

fn resolve_workers(configured: usize) -> usize {
    if configured > 0 {
        return configured;
    }
    thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(2)
        .clamp(2, 8)
}

/// Queues + the affinity set, under one lock.
struct DispatchState {
    batcher: Batcher,
    /// keys currently executing on some worker
    inflight: HashSet<QueueKey>,
}

struct Shared {
    state: Mutex<DispatchState>,
    work: Condvar,
    shutdown: AtomicBool,
}

/// The serving engine. `submit` is thread-safe; execution happens on the
/// dispatch worker pool against the configured backend.
pub struct Engine {
    manifest: Arc<Manifest>,
    shared: Arc<Shared>,
    metrics: Arc<CoordinatorMetrics>,
    backend: Arc<dyn ExecBackend>,
    next_id: AtomicU64,
    workers: Vec<thread::JoinHandle<()>>,
    config: EngineConfig,
}

impl Engine {
    pub fn new(config: EngineConfig) -> Result<Engine> {
        let manifest = Arc::new(Manifest::load(&config.artifacts_dir)?);
        let backend: Arc<dyn ExecBackend> = Arc::from(config.backend.create()?);
        let shared = Arc::new(Shared {
            state: Mutex::new(DispatchState {
                batcher: Batcher::new(config.max_wait),
                inflight: HashSet::new(),
            }),
            work: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let metrics = Arc::new(CoordinatorMetrics::new());

        let n = resolve_workers(config.workers);
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let spawned = {
                let shared = Arc::clone(&shared);
                let manifest = Arc::clone(&manifest);
                let metrics = Arc::clone(&metrics);
                let backend = Arc::clone(&backend);
                thread::Builder::new()
                    .name(format!("hsolve-dispatch-{i}"))
                    .spawn(move || worker_main(shared, manifest, metrics, backend))
            };
            match spawned {
                Ok(j) => workers.push(j),
                Err(e) => {
                    shared.shutdown.store(true, Relaxed);
                    shared.work.notify_all();
                    for j in workers {
                        let _ = j.join();
                    }
                    return Err(Error::Coordinator(format!("spawn dispatch worker: {e}")));
                }
            }
        }

        log_info!(
            "engine up: {} tasks, backend {}, {} dispatch workers, policy {:?}, max_wait {:?}",
            manifest.tasks.len(),
            backend.name(),
            n,
            config.policy,
            config.max_wait
        );
        Ok(Engine {
            manifest,
            shared,
            metrics,
            backend,
            next_id: AtomicU64::new(1),
            workers,
            config,
        })
    }

    pub fn with_defaults() -> Result<Engine> {
        Self::new(EngineConfig::default())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn metrics(&self) -> &CoordinatorMetrics {
        &self.metrics
    }

    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// The active backend's name ("pjrt" | "native").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Dispatch worker count actually running.
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Submit one sample; returns the channel the response arrives on.
    pub fn submit(
        &self,
        task: &str,
        budget: f32,
        input: Vec<f32>,
    ) -> Result<mpsc::Receiver<Response>> {
        let entry = self.manifest.task(task)?;
        if entry.state_shape.is_empty() {
            return Err(Error::Coordinator(format!(
                "task {task}: manifest state shape is rank 0"
            )));
        }
        let sample_dim: usize = entry.state_shape[1..].iter().product();
        if input.len() != sample_dim {
            return Err(Error::Coordinator(format!(
                "task {task}: sample has {} values, state wants {sample_dim}",
                input.len()
            )));
        }
        let variant = select_variant(entry, budget, self.config.policy)
            .ok_or_else(|| Error::Coordinator(format!("task {task} has no variants")))?;
        let key: QueueKey = (task.to_string(), variant.name.clone());
        let id = self.next_id.fetch_add(1, Relaxed);
        let (tx, rx) = mpsc::channel();
        {
            let mut s = self.shared.state.lock().unwrap();
            s.batcher.ensure_queue(&key, entry.batch());
            s.batcher.push(
                &key,
                Pending {
                    req: Request::new(id, task, budget, input),
                    reply: tx,
                },
            );
        }
        self.metrics.requests.fetch_add(1, Relaxed);
        self.shared.work.notify_one();
        Ok(rx)
    }

    /// Submit and wait (convenience for examples/benches).
    pub fn infer(&self, task: &str, budget: f32, input: Vec<f32>) -> Result<Response> {
        let rx = self.submit(task, budget, input)?;
        rx.recv()
            .map_err(|_| Error::Coordinator("engine dropped response".into()))
    }

    /// Prepare every variant of `task` on the backend (PJRT compilation /
    /// native weight loading), so first requests don't pay it.
    pub fn warmup(&self, task: &str) -> Result<()> {
        let entry = self.manifest.task(task)?;
        for v in &entry.variants {
            self.backend.prepare(&self.manifest, entry, v)?;
        }
        Ok(())
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Relaxed);
        self.shared.work.notify_all();
        for j in self.workers.drain(..) {
            let _ = j.join();
        }
    }
}

/// Releases a claimed queue key when the batch finishes — on the normal
/// path *and* on unwind, so a panicking backend can't leave its queue
/// permanently marked in-flight (which would silently starve it).
struct InflightGuard<'a> {
    shared: &'a Shared,
    metrics: &'a CoordinatorMetrics,
    key: QueueKey,
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.metrics.batch_finished();
        match self.shared.state.lock() {
            Ok(mut s) => {
                s.inflight.remove(&self.key);
            }
            // the state lock is only poisoned if another worker died while
            // batching; still release our key so the queue isn't starved
            Err(poisoned) => {
                poisoned.into_inner().inflight.remove(&self.key);
            }
        }
        // releasing the key may make another batch of the same queue
        // poppable; other workers might all be asleep on the condvar
        self.shared.work.notify_all();
    }
}

fn worker_main(
    shared: Arc<Shared>,
    manifest: Arc<Manifest>,
    metrics: Arc<CoordinatorMetrics>,
    backend: Arc<dyn ExecBackend>,
) {
    loop {
        // claim one ready batch under the lock, run it outside
        let batch: ReadyBatch = {
            let mut s = shared.state.lock().unwrap();
            loop {
                if shared.shutdown.load(Relaxed) {
                    return;
                }
                let now = Instant::now();
                let state = &mut *s;
                if let Some(batch) = state.batcher.pop_ready(now, &state.inflight) {
                    state.inflight.insert(batch.key.clone());
                    break batch;
                }
                // wait on non-busy queues only: a busy queue's expired
                // deadline would clamp this to ~0 and spin; its completion
                // notify_all is what wakes us for that queue
                let timeout = state
                    .batcher
                    .next_deadline_idle(&state.inflight)
                    .map(|dl| dl.saturating_duration_since(now))
                    .unwrap_or(Duration::from_millis(50));
                let (guard, _) = shared
                    .work
                    .wait_timeout(s, timeout.max(Duration::from_micros(100)))
                    .unwrap();
                s = guard;
            }
        };

        let _guard = InflightGuard {
            shared: &*shared,
            metrics: &*metrics,
            key: batch.key.clone(),
        };
        metrics.batch_started();
        run_batch(&manifest, &metrics, backend.as_ref(), batch);
    }
}

fn run_batch(
    manifest: &Manifest,
    metrics: &CoordinatorMetrics,
    backend: &dyn ExecBackend,
    batch: ReadyBatch,
) {
    let (task_name, variant_name) = &batch.key;
    let entry = match manifest.task(task_name) {
        Ok(e) => e,
        Err(e) => return fail_batch(batch, &e.to_string()),
    };
    let variant = match entry.variant(variant_name) {
        Some(v) => v.clone(),
        None => return fail_batch(batch, "variant vanished"),
    };
    if variant.in_shape.is_empty() || variant.out_shape.is_empty() {
        return fail_batch(batch, "variant has rank-0 in/out shape");
    }

    let b_cap = entry.batch();
    let sample_dim: usize = variant.in_shape[1..].iter().product();
    let out_dim: usize = variant.out_shape[1..].iter().product();
    let real = batch.items.len();

    // submit validated against the task's state shape; the variant's
    // executable row dim must agree or padding would silently corrupt
    // (image→logits exports take image-dim rows the state-dim submit
    // surface doesn't produce yet)
    if let Some(p) = batch.items.iter().find(|p| p.req.input.len() != sample_dim) {
        let got = p.req.input.len();
        return fail_batch(
            batch,
            &format!("sample has {got} values but variant row dim is {sample_dim}"),
        );
    }

    // assemble the padded batch input
    let samples: Vec<&[f32]> = batch
        .items
        .iter()
        .map(|p| p.req.input.as_slice())
        .collect();
    let input = pad_batch(&samples, b_cap, sample_dim);
    let queue_start = Instant::now();
    for p in &batch.items {
        metrics
            .queue_latency
            .record(queue_start.duration_since(p.req.t_submit));
    }

    let t_exec = Instant::now();
    let out = match backend.execute(manifest, entry, &variant, input) {
        Ok(o) => o,
        Err(e) => return fail_batch(batch, &e.to_string()),
    };
    let exec_time = t_exec.elapsed();
    metrics.exec_latency.record(exec_time);

    let nfe = out.nfe.unwrap_or(variant.nfe);
    if out.z.len() < real * out_dim {
        // validate before recording: a short output produces no responses
        // and must not count as a served batch in fill/NFE accounting
        return fail_batch(
            batch,
            &format!(
                "backend returned {} values, batch needs {}",
                out.z.len(),
                real * out_dim
            ),
        );
    }
    metrics.record_batch(real, b_cap, nfe, variant.macs);
    log_debug!("batch {task_name}/{variant_name}: {real}/{b_cap} samples in {exec_time:?}");
    for (i, p) in batch.items.into_iter().enumerate() {
        let latency = p.req.t_submit.elapsed();
        metrics.total_latency.record(latency);
        metrics.responses.fetch_add(1, Relaxed);
        let _ = p.reply.send(Response {
            id: p.req.id,
            output: out.z[i * out_dim..(i + 1) * out_dim].to_vec(),
            variant: variant.name.clone(),
            mape: variant.mape,
            nfe,
            latency,
            batch_fill: real,
        });
    }
}

fn fail_batch(batch: ReadyBatch, msg: &str) {
    crate::log_error!("batch {:?} failed: {msg}", batch.key);
    // drop the reply senders: receivers see a disconnect error
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_resolution_bounds() {
        assert_eq!(resolve_workers(3), 3);
        let auto = resolve_workers(0);
        assert!((2..=8).contains(&auto), "auto workers {auto}");
    }

    #[test]
    fn default_config_is_pjrt_auto() {
        let c = EngineConfig::default();
        assert_eq!(c.backend, BackendKind::Pjrt);
        assert_eq!(c.workers, 0);
    }
}
