//! The coordinator engine: policy → queues → dispatch worker pool →
//! pluggable execution backend.
//!
//! Submission is **non-blocking**: [`Engine::submit`] enqueues a request
//! (single- or multi-sample) and returns a [`SubmitHandle`] immediately;
//! completions are delivered id-correlated on a channel, so one caller can
//! keep many requests in flight ([`Engine::submit_with`] lets any number
//! of submissions share one completion channel — the pipelined server
//! loop). [`Engine::infer`] remains the thin blocking wrapper. Every
//! rejection and failure carries a stable [`ApiError`] code; a request
//! with a deadline fails fast with `deadline_exceeded` when its batch
//! dispatches too late.
//!
//! Dispatch runs on a small pool of workers, each pulling one ready batch
//! at a time from the shared [`Batcher`]. A per-[`QueueKey`] affinity set
//! guarantees that a queue's batches execute (and therefore respond) in
//! FIFO order, while batches for *distinct* (task, variant) queues run
//! concurrently — on the [`NativeBackend`](crate::runtime::NativeBackend)
//! genuinely in parallel, on the PJRT backend pipelined up to the executor
//! thread.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::api::ApiError;
use crate::coordinator::batcher::{
    pad_batch_into, Batcher, Pending, QueueDepth, QueueKey, ReadyBatch,
};
use crate::coordinator::metrics::CoordinatorMetrics;
use crate::coordinator::policy::{select_variant, Policy};
use crate::coordinator::request::{
    Completion, CompletionSender, Priority, Request, Response, RowBlock,
};
use crate::obs::audit::{AuditConfig, AuditPlane, AuditSample};
use crate::obs::{self, Stage};
use crate::runtime::backend::{BackendKind, ExecBackend};
use crate::runtime::manifest::Manifest;
use crate::{log_debug, log_info, Error, Result};

/// EWMA smoothing factor for the per-(task, variant) measured batch
/// wall-clock that admission control predicts queue waits from.
const WALL_EWMA_ALPHA: f64 = 0.3;

/// Admission-control seed before the first measurement: the manifest's
/// per-sample `nfe` × this µs/NFE guess approximates one batch wall-clock,
/// so a cold queue still rejects obviously-unmeetable deadlines instead of
/// admitting blind until the first batch lands.
const SEED_WALL_US_PER_NFE: f64 = 25.0;

/// SLO-defence knobs: admission control, load shedding, client quotas.
/// All default to "admit everything except provably-late deadlines" —
/// shedding and quotas are opt-in because they refuse work.
#[derive(Clone, Debug)]
pub struct SloConfig {
    /// Reject a deadlined request with `overloaded` *before* enqueue when
    /// the predicted queue wait (per-(task, variant) wall-clock EWMA ×
    /// batches already queued ahead) exceeds its deadline.
    pub admission: bool,
    /// Total queued-rows high-water mark: a push that leaves more rows
    /// queued sheds lowest-priority, latest-deadline requests back down
    /// to the mark (0 = never shed).
    pub shed_high_water_rows: usize,
    /// Per-client queued-row quota enforced at push (0 = unlimited;
    /// unattributed requests are exempt).
    pub client_quota_rows: usize,
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig {
            admission: true,
            shed_high_water_rows: 0,
            client_quota_rows: 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub artifacts_dir: std::path::PathBuf,
    /// dynamic batching deadline
    pub max_wait: Duration,
    pub policy: Policy,
    /// which execution backend serves batches
    pub backend: BackendKind,
    /// dispatch worker count; 0 = auto (one per core, clamped to [2, 8])
    pub workers: usize,
    /// SLO defence: admission control, shedding high-water mark, quotas
    pub slo: SloConfig,
    /// shadow-audit plane: sampling rate, reference tolerance, budget
    /// breach thresholds (rate 0.0 = plane disabled, the default)
    pub audit: AuditConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            artifacts_dir: crate::artifacts_dir(),
            max_wait: Duration::from_millis(2),
            policy: Policy::MinMacs,
            backend: BackendKind::Pjrt,
            workers: 0,
            slo: SloConfig::default(),
            audit: AuditConfig::default(),
        }
    }
}

/// Per-request submission options of the v1 surface. `Default` reproduces
/// the classic behavior: engine policy axis, budget-selected variant, no
/// deadline.
#[derive(Clone, Debug, Default)]
pub struct SubmitOptions {
    /// Override the engine's cost axis for this request.
    pub policy: Option<Policy>,
    /// Pin an exact variant, bypassing the budget policy.
    pub variant: Option<String>,
    /// Fail fast with `deadline_exceeded` if the request has not been
    /// dispatched within this duration of submission.
    pub deadline: Option<Duration>,
    /// Priority class: breaks EDF dispatch ties between equally-urgent
    /// queues, and lower classes are shed first under overload.
    pub priority: Priority,
    /// Client identity for per-client row quotas (`None` = unattributed,
    /// exempt from quotas).
    pub client: Option<String>,
    /// Client-supplied trace id for end-to-end correlation; `None` lets
    /// the engine generate one. The id travels with the request's span
    /// (`cmd:"trace"`) and is echoed on wire replies when supplied.
    pub trace: Option<u64>,
}

/// A non-blocking submission: the engine id plus the completion channel.
/// Drop it to ignore the response (the engine never blocks on it).
#[derive(Debug)]
pub struct SubmitHandle {
    id: u64,
    rx: mpsc::Receiver<Completion>,
}

impl SubmitHandle {
    /// The engine-assigned submission id (what [`Completion::id`] carries).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the completion arrives. An engine shut down before
    /// responding surfaces as an `internal` error.
    pub fn wait(&self) -> std::result::Result<Response, ApiError> {
        match self.rx.recv() {
            Ok(c) => c.result,
            Err(_) => Err(ApiError::internal("engine dropped the response channel")),
        }
    }

    /// [`Self::wait`] with a timeout; `None` means the timeout elapsed
    /// (the request is still in flight).
    pub fn wait_timeout(
        &self,
        timeout: Duration,
    ) -> Option<std::result::Result<Response, ApiError>> {
        match self.rx.recv_timeout(timeout) {
            Ok(c) => Some(c.result),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Some(Err(ApiError::internal("engine dropped the response channel")))
            }
        }
    }

    /// The raw completion receiver (tests that assert channel lifecycle).
    pub fn receiver(&self) -> &mpsc::Receiver<Completion> {
        &self.rx
    }
}

fn resolve_workers(configured: usize) -> usize {
    if configured > 0 {
        return configured;
    }
    thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(2)
        .clamp(2, 8)
}

/// Queues + the affinity set, under one lock.
struct DispatchState {
    batcher: Batcher,
    /// keys currently executing on some worker
    inflight: HashSet<QueueKey>,
    /// per-(task, variant) EWMA of measured batch wall-clock (µs),
    /// updated by the workers after each executed batch — what admission
    /// control predicts queue waits from
    wall_ewma: HashMap<QueueKey, f64>,
}

struct Shared {
    state: Mutex<DispatchState>,
    work: Condvar,
    shutdown: AtomicBool,
}

/// The serving engine. `submit` is thread-safe; execution happens on the
/// dispatch worker pool against the configured backend.
pub struct Engine {
    manifest: Arc<Manifest>,
    shared: Arc<Shared>,
    metrics: Arc<CoordinatorMetrics>,
    backend: Arc<dyn ExecBackend>,
    next_id: AtomicU64,
    workers: Vec<thread::JoinHandle<()>>,
    audit: Option<Arc<AuditPlane>>,
    audit_worker: Option<thread::JoinHandle<()>>,
    config: EngineConfig,
}

impl Engine {
    pub fn new(config: EngineConfig) -> Result<Engine> {
        let manifest = Arc::new(Manifest::load(&config.artifacts_dir)?);
        let backend: Arc<dyn ExecBackend> = Arc::from(config.backend.create()?);
        let shared = Arc::new(Shared {
            state: Mutex::new(DispatchState {
                batcher: Batcher::new(config.max_wait)
                    .with_client_quota(config.slo.client_quota_rows),
                inflight: HashSet::new(),
                wall_ewma: HashMap::new(),
            }),
            work: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let metrics = Arc::new(CoordinatorMetrics::new());
        let audit = if config.audit.rate > 0.0 {
            Some(Arc::new(AuditPlane::new(config.audit.clone())))
        } else {
            None
        };

        let n = resolve_workers(config.workers);
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let spawned = {
                let shared = Arc::clone(&shared);
                let manifest = Arc::clone(&manifest);
                let metrics = Arc::clone(&metrics);
                let backend = Arc::clone(&backend);
                let audit = audit.clone();
                thread::Builder::new()
                    .name(format!("hsolve-dispatch-{i}"))
                    .spawn(move || worker_main(shared, manifest, metrics, backend, audit))
            };
            match spawned {
                Ok(j) => workers.push(j),
                Err(e) => {
                    shared.shutdown.store(true, Relaxed);
                    shared.work.notify_all();
                    for j in workers {
                        let _ = j.join();
                    }
                    return Err(Error::Coordinator(format!("spawn dispatch worker: {e}")));
                }
            }
        }

        // the audit worker re-solves sampled requests off the dispatch
        // path; it owns its RkWorkspace (inside the plane), never the
        // dispatch workers'
        let audit_worker = match &audit {
            None => None,
            Some(plane) => {
                let plane = Arc::clone(plane);
                let manifest = Arc::clone(&manifest);
                let metrics = Arc::clone(&metrics);
                let spawned = thread::Builder::new()
                    .name("hsolve-audit".into())
                    .spawn(move || plane.run_worker(&manifest, |k| metrics.key_name(k)));
                match spawned {
                    Ok(j) => Some(j),
                    Err(e) => {
                        shared.shutdown.store(true, Relaxed);
                        shared.work.notify_all();
                        for j in workers {
                            let _ = j.join();
                        }
                        return Err(Error::Coordinator(format!("spawn audit worker: {e}")));
                    }
                }
            }
        };

        log_info!(
            "engine up: {} tasks, backend {}, {} dispatch workers, policy {:?}, max_wait {:?}",
            manifest.tasks.len(),
            backend.name(),
            n,
            config.policy,
            config.max_wait
        );
        Ok(Engine {
            manifest,
            shared,
            metrics,
            backend,
            next_id: AtomicU64::new(1),
            workers,
            audit,
            audit_worker,
            config,
        })
    }

    pub fn with_defaults() -> Result<Engine> {
        Self::new(EngineConfig::default())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn metrics(&self) -> &CoordinatorMetrics {
        &self.metrics
    }

    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// The active backend's name ("pjrt" | "native").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The shadow-audit plane, when `--audit-rate` enabled it.
    pub fn audit(&self) -> Option<&AuditPlane> {
        self.audit.as_deref()
    }

    /// Synchronously drain the audit queue on the caller's thread;
    /// returns how many samples were processed. Tests and benches use
    /// this to observe audit state without racing the worker thread.
    pub fn audit_flush(&self) -> usize {
        match &self.audit {
            None => 0,
            Some(plane) => {
                plane.process_pending(&self.manifest, |k| self.metrics.key_name(k))
            }
        }
    }

    /// Dispatch worker count actually running.
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Snapshot of per-(task, variant) queue depths (the `cmd:"metrics"`
    /// surface).
    pub fn queue_depths(&self) -> Vec<QueueDepth> {
        self.shared.state.lock().unwrap().batcher.depths()
    }

    /// Per-(task, variant) admission-control wall-clock predictions (the
    /// EWMA of measured batch wall µs), sorted by name.
    pub fn wall_predictions(&self) -> Vec<(String, String, f64)> {
        let s = self.shared.state.lock().unwrap();
        let mut out: Vec<(String, String, f64)> = s
            .wall_ewma
            .iter()
            .map(|(k, v)| (k.0.clone(), k.1.clone(), *v))
            .collect();
        drop(s);
        out.sort_by(|a, b| (&a.0, &a.1).cmp(&(&b.0, &b.1)));
        out
    }

    /// Render every counter, gauge and latency histogram in Prometheus
    /// text format — the payload of `cmd:"stats"` and the
    /// `--metrics-addr` listener. Deterministic order (sorted snapshots),
    /// every value finite.
    pub fn render_prometheus(&self) -> String {
        use crate::obs::expo::PromText;
        let m = self.metrics.as_ref();
        let c = |a: &AtomicU64| a.load(Relaxed) as f64;
        let mut p = PromText::new();
        for (name, help, v) in [
            ("requests_total", "Requests accepted at submit", c(&m.requests)),
            ("responses_total", "Successful completions delivered", c(&m.responses)),
            ("failures_total", "Completions delivered as errors", c(&m.failures)),
            (
                "deadline_misses_total",
                "Requests failed fast past their deadline before dispatch",
                c(&m.deadline_misses),
            ),
            (
                "deadline_met_total",
                "Successful completions that met their deadline",
                c(&m.deadline_met),
            ),
            ("shed_total", "Queued requests shed under overload", c(&m.shed)),
            (
                "overload_rejects_total",
                "Requests refused at submit by admission control or quotas",
                c(&m.overload_rejects),
            ),
            ("batches_total", "Batches executed", c(&m.batches)),
            ("rows_total", "Real rows executed", c(&m.rows)),
            (
                "padded_slots_total",
                "Padded (wasted) batch slots executed",
                c(&m.padded_slots),
            ),
            ("nfe_total", "Field evaluations spent", c(&m.nfe_total)),
            ("macs_total", "MACs spent", c(&m.macs_total)),
            (
                "spans_recorded_total",
                "Completed request spans pushed to the trace ring",
                m.spans.pushed() as f64,
            ),
        ] {
            let name = format!("hypersolvers_{name}");
            p.family(&name, "counter", help);
            p.sample(&name, &[], v);
        }
        for (name, help, v) in [
            (
                "inflight_batches",
                "Batches executing right now",
                c(&m.inflight_batches),
            ),
            (
                "inflight_peak",
                "High-water mark of concurrent batches",
                c(&m.inflight_peak),
            ),
            (
                "batch_fill_ratio",
                "Mean real-rows fraction of executed batches",
                m.fill_ratio(),
            ),
            (
                "goodput",
                "Deadline-met fraction of delivered responses",
                m.goodput(),
            ),
        ] {
            let name = format!("hypersolvers_{name}");
            p.family(&name, "gauge", help);
            p.sample(&name, &[], v);
        }

        p.family(
            "hypersolvers_latency_us",
            "summary",
            "Request latency by pipeline stage, all queues",
        );
        for (stage, h) in [
            ("queue", &m.queue_latency),
            ("pad", &m.pad_latency),
            ("exec", &m.exec_latency),
            ("total", &m.total_latency),
        ] {
            p.summary("hypersolvers_latency_us", &[("stage", stage)], h);
        }

        let stages = m.stage_snapshot();
        p.family(
            "hypersolvers_stage_latency_us",
            "summary",
            "Request latency by pipeline stage per (task, variant) queue",
        );
        for (task, variant, h) in &stages {
            for (stage, hist) in [
                ("queue", &h.queue),
                ("pad", &h.pad),
                ("exec", &h.exec),
                ("total", &h.total),
            ] {
                p.summary(
                    "hypersolvers_stage_latency_us",
                    &[
                        ("task", task.as_str()),
                        ("variant", variant.as_str()),
                        ("stage", stage),
                    ],
                    hist,
                );
            }
        }

        let depths = self.queue_depths();
        p.family(
            "hypersolvers_queue_depth_requests",
            "gauge",
            "Queued requests per (task, variant) queue",
        );
        for d in &depths {
            p.sample(
                "hypersolvers_queue_depth_requests",
                &[("task", d.task.as_str()), ("variant", d.variant.as_str())],
                d.requests as f64,
            );
        }
        p.family(
            "hypersolvers_queue_depth_rows",
            "gauge",
            "Queued rows per (task, variant) queue",
        );
        for d in &depths {
            p.sample(
                "hypersolvers_queue_depth_rows",
                &[("task", d.task.as_str()), ("variant", d.variant.as_str())],
                d.rows as f64,
            );
        }

        p.family(
            "hypersolvers_wall_ewma_us",
            "gauge",
            "Admission-control EWMA of measured batch wall-clock",
        );
        for (task, variant, us) in &self.wall_predictions() {
            p.sample(
                "hypersolvers_wall_ewma_us",
                &[("task", task.as_str()), ("variant", variant.as_str())],
                *us,
            );
        }

        // numerical-health families: only rendered when the audit plane is
        // on, so an audit-off scrape is byte-stable against PR 8's shape
        if let Some(plane) = self.audit.as_deref() {
            let snaps = plane.snapshot();
            p.family(
                "hypersolvers_audit_samples_total",
                "counter",
                "Requests shadow-audited against the tight-tolerance reference",
            );
            for s in &snaps {
                p.sample(
                    "hypersolvers_audit_samples_total",
                    &[("task", s.task.as_str()), ("variant", s.variant.as_str())],
                    s.samples as f64,
                );
            }
            p.family(
                "hypersolvers_audit_drops_total",
                "counter",
                "Audit samples lost: bounded-queue/contended drops and unsupported re-solves",
            );
            p.sample(
                "hypersolvers_audit_drops_total",
                &[("reason", "queue")],
                plane.drops.load(Relaxed) as f64,
            );
            p.sample(
                "hypersolvers_audit_drops_total",
                &[("reason", "unsupported")],
                plane.unsupported.load(Relaxed) as f64,
            );
            p.family(
                "hypersolvers_audit_budget_breach_total",
                "counter",
                "Sustained error-budget breaches (EWMA over breach_factor x manifest mape)",
            );
            for s in &snaps {
                p.sample(
                    "hypersolvers_audit_budget_breach_total",
                    &[("task", s.task.as_str()), ("variant", s.variant.as_str())],
                    s.breaches as f64,
                );
            }
            p.family(
                "hypersolvers_audit_error",
                "summary",
                "Measured relative terminal error of served outputs vs the reference solve",
            );
            for s in &snaps {
                for (q, v) in [("0.5", s.err_p50), ("0.99", s.err_p99)] {
                    p.sample(
                        "hypersolvers_audit_error",
                        &[
                            ("task", s.task.as_str()),
                            ("variant", s.variant.as_str()),
                            ("quantile", q),
                        ],
                        v,
                    );
                }
                p.sample(
                    "hypersolvers_audit_error_sum",
                    &[("task", s.task.as_str()), ("variant", s.variant.as_str())],
                    s.err_mean * s.samples as f64,
                );
                p.sample(
                    "hypersolvers_audit_error_count",
                    &[("task", s.task.as_str()), ("variant", s.variant.as_str())],
                    s.samples as f64,
                );
            }
            p.family(
                "hypersolvers_drift_score",
                "gauge",
                "Input drift of audited request states vs the manifest train_stats stamp",
            );
            for s in &snaps {
                if let Some(d) = s.drift_score {
                    p.sample(
                        "hypersolvers_drift_score",
                        &[("task", s.task.as_str()), ("variant", s.variant.as_str())],
                        d,
                    );
                }
            }
        }
        p.finish()
    }

    /// Submit a request whose completion is delivered on `done`, tagged
    /// with the returned engine id — the pipelined path: any number of
    /// in-flight submissions can share one channel. `block` is the
    /// contiguous row-major `[rows, dims]` payload (moved in as-is — the
    /// binary v2 codec hands its decoded frame payload straight here);
    /// validation, policy selection and enqueueing all happen before this
    /// returns, so a returned id is a guarantee that exactly one
    /// [`Completion`] will be attempted for it (success, structured error,
    /// or — only if the engine is dropped first — channel disconnect).
    pub fn submit_with(
        &self,
        task: &str,
        budget: f32,
        block: RowBlock,
        opts: &SubmitOptions,
        done: CompletionSender,
    ) -> std::result::Result<u64, ApiError> {
        let entry = self
            .manifest
            .task(task)
            .map_err(|e| ApiError::unknown_task(e.to_string()))?;
        if entry.state_shape.is_empty() {
            return Err(ApiError::internal(format!(
                "task {task}: manifest state shape is rank 0"
            )));
        }
        let samples = block.rows;
        if samples == 0 {
            return Err(ApiError::shape_mismatch(format!(
                "task {task}: request carries zero samples"
            )));
        }
        let sample_dim: usize = entry.state_shape[1..].iter().product();
        if block.data.len() != samples * sample_dim {
            return Err(ApiError::shape_mismatch(format!(
                "task {task}: {samples} sample(s) × state dim {sample_dim} wants \
                 {} values, got {}",
                samples * sample_dim,
                block.data.len()
            )));
        }
        let b_cap = entry.batch();
        if samples > b_cap {
            return Err(ApiError::shape_mismatch(format!(
                "task {task}: request has {samples} samples but the exported \
                 executables take batches of {b_cap}; split the request"
            )));
        }
        let variant = match &opts.variant {
            Some(name) => entry.variant(name).ok_or_else(|| {
                ApiError::unknown_variant(format!(
                    "task {task} has no variant {name:?}"
                ))
            })?,
            None => {
                let axis = opts.policy.unwrap_or(self.config.policy);
                select_variant(entry, budget, axis).ok_or_else(|| {
                    ApiError::internal(format!("task {task} has no variants"))
                })?
            }
        };
        let key: QueueKey = (task.to_string(), variant.name.clone());
        let id = self.next_id.fetch_add(1, Relaxed);
        let mut req = Request::from_block(id, task, budget, block);
        let t0 = req.t_submit;
        req.deadline = opts.deadline.map(|d| t0 + d);
        req.priority = opts.priority;
        req.client = opts.client.clone();
        req.trace = opts.trace.unwrap_or_else(obs::next_trace_id);
        req.trace_client = opts.trace.is_some();
        req.stamps.stamp(Stage::Submit);
        let slo = &self.config.slo;
        let shed_victims = {
            let mut s = self.shared.state.lock().unwrap();
            s.batcher.ensure_queue(&key, b_cap);
            // admission control: refuse a deadlined request before it
            // ever queues when the rows already ahead of it predict a
            // wait past its deadline — rejecting late work up front keeps
            // it from poisoning the queue for requests that can still win
            if slo.admission {
                if let Some(deadline) = opts.deadline {
                    let queued = s.batcher.queue_rows(&key);
                    if queued > 0 {
                        let seed = variant.nfe as f64 * SEED_WALL_US_PER_NFE;
                        let wall_us = s.wall_ewma.get(&key).copied().unwrap_or(seed);
                        let batches_ahead = queued.div_ceil(b_cap);
                        // +2: the request's own batch must also run, and a
                        // prior batch of this queue may already be in
                        // flight on its affine worker — admitting work
                        // that can only *just* make it loses to jitter
                        let predicted_us = (batches_ahead + 2) as f64 * wall_us;
                        if predicted_us > deadline.as_micros() as f64 {
                            drop(s);
                            self.metrics.overload_rejects.fetch_add(1, Relaxed);
                            return Err(ApiError::overloaded(format!(
                                "task {task}: {queued} queued rows predict a \
                                 {predicted_us:.0}µs wait, past the {}µs \
                                 deadline",
                                deadline.as_micros()
                            )));
                        }
                    }
                }
            }
            // both stamps land here: the admission decision was just made
            // (whether or not the check is enabled), and the push below is
            // the enqueue — a request refused by the quota path simply
            // never reaches the span ring
            req.stamps.stamp(Stage::Admission);
            req.stamps.stamp(Stage::Enqueue);
            if let Err(p) = s.batcher.push(&key, Pending { req, done }) {
                drop(s);
                self.metrics.overload_rejects.fetch_add(1, Relaxed);
                let client = p.req.client.as_deref().unwrap_or("");
                return Err(ApiError::overloaded(format!(
                    "client {client:?} is at its queued-row quota of {}",
                    slo.client_quota_rows
                )));
            }
            if slo.shed_high_water_rows > 0 && s.batcher.queued_rows() > slo.shed_high_water_rows {
                s.batcher.shed_to(slo.shed_high_water_rows)
            } else {
                Vec::new()
            }
        };
        self.metrics.requests.fetch_add(1, Relaxed);
        for p in shed_victims {
            self.metrics.shed.fetch_add(1, Relaxed);
            complete(
                &self.metrics,
                p,
                Err(ApiError::overloaded(
                    "shed at the queued-rows high-water mark under overload",
                )),
            );
        }
        self.shared.work.notify_one();
        Ok(id)
    }

    /// Non-blocking submit with per-request options; returns a handle
    /// owning its completion channel. `input` is flat row-major
    /// `[samples, dims]` — the convenience wrapper over
    /// [`Self::submit_with`]'s [`RowBlock`] surface.
    pub fn submit_opts(
        &self,
        task: &str,
        budget: f32,
        input: Vec<f32>,
        samples: usize,
        opts: &SubmitOptions,
    ) -> std::result::Result<SubmitHandle, ApiError> {
        let (tx, rx) = mpsc::channel();
        let block = RowBlock::from_rows(samples, input);
        let id = self.submit_with(task, budget, block, opts, tx)?;
        Ok(SubmitHandle { id, rx })
    }

    /// Submit one single-sample request (the classic surface).
    pub fn submit(
        &self,
        task: &str,
        budget: f32,
        input: Vec<f32>,
    ) -> std::result::Result<SubmitHandle, ApiError> {
        self.submit_opts(task, budget, input, 1, &SubmitOptions::default())
    }

    /// Submit and wait — the thin blocking wrapper over [`Self::submit`].
    pub fn infer(&self, task: &str, budget: f32, input: Vec<f32>) -> Result<Response> {
        let handle = self.submit(task, budget, input).map_err(Error::from)?;
        handle.wait().map_err(Error::from)
    }

    /// Prepare every variant of `task` on the backend (PJRT compilation /
    /// native weight loading), so first requests don't pay it.
    pub fn warmup(&self, task: &str) -> Result<()> {
        let entry = self.manifest.task(task)?;
        for v in &entry.variants {
            self.backend.prepare(&self.manifest, entry, v)?;
        }
        Ok(())
    }

    /// Block until every queued request has been answered and every
    /// in-flight batch has completed — the graceful-shutdown drain that
    /// `cmd:"shutdown"` runs before the accept loop exits. The dispatch
    /// workers stay up the whole time, so queued requests complete
    /// normally instead of being dropped with their channels. Returns
    /// `false` when the backlog did not clear within `timeout` (callers
    /// shut down anyway; the flag just makes the miss loud).
    pub fn drain(&self, timeout: Duration) -> bool {
        let t0 = Instant::now();
        loop {
            let queued: usize = self.queue_depths().iter().map(|d| d.requests).sum();
            let inflight = self.metrics.inflight_batches.load(Relaxed);
            if queued == 0 && inflight == 0 {
                return true;
            }
            if t0.elapsed() >= timeout {
                return false;
            }
            thread::sleep(Duration::from_millis(1));
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Relaxed);
        self.shared.work.notify_all();
        if let Some(plane) = &self.audit {
            plane.shutdown();
        }
        for j in self.workers.drain(..) {
            let _ = j.join();
        }
        if let Some(j) = self.audit_worker.take() {
            let _ = j.join();
        }
    }
}

/// Releases a claimed queue key when the batch finishes — on the normal
/// path *and* on unwind, so a panicking backend can't leave its queue
/// permanently marked in-flight (which would silently starve it).
struct InflightGuard<'a> {
    shared: &'a Shared,
    metrics: &'a CoordinatorMetrics,
    key: QueueKey,
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.metrics.batch_finished();
        match self.shared.state.lock() {
            Ok(mut s) => {
                s.inflight.remove(&self.key);
            }
            // the state lock is only poisoned if another worker died while
            // batching; still release our key so the queue isn't starved
            Err(poisoned) => {
                poisoned.into_inner().inflight.remove(&self.key);
            }
        }
        // releasing the key may make another batch of the same queue
        // poppable; other workers might all be asleep on the condvar
        self.shared.work.notify_all();
    }
}

fn worker_main(
    shared: Arc<Shared>,
    manifest: Arc<Manifest>,
    metrics: Arc<CoordinatorMetrics>,
    backend: Arc<dyn ExecBackend>,
    audit: Option<Arc<AuditPlane>>,
) {
    // per-worker reusable padded-batch buffer: `pad_batch_into` refills it
    // for every batch, so steady-state dispatch does not allocate for
    // batch assembly
    let mut pad_buf: Vec<f32> = Vec::new();
    loop {
        // claim one ready batch under the lock, run it outside
        let batch: ReadyBatch = {
            let mut s = shared.state.lock().unwrap();
            loop {
                if shared.shutdown.load(Relaxed) {
                    return;
                }
                let now = Instant::now();
                let state = &mut *s;
                if let Some(batch) = state.batcher.pop_ready(now, &state.inflight) {
                    state.inflight.insert(batch.key.clone());
                    break batch;
                }
                // wait on non-busy queues only: a busy queue's expired
                // deadline would clamp this to ~0 and spin; its completion
                // notify_all is what wakes us for that queue
                let timeout = state
                    .batcher
                    .next_deadline_idle(&state.inflight)
                    .map(|dl| dl.saturating_duration_since(now))
                    .unwrap_or(Duration::from_millis(50));
                let (guard, _) = shared
                    .work
                    .wait_timeout(s, timeout.max(Duration::from_micros(100)))
                    .unwrap();
                s = guard;
            }
        };

        let key = batch.key.clone();
        let _guard = InflightGuard {
            shared: &*shared,
            metrics: &*metrics,
            key: key.clone(),
        };
        metrics.batch_started();
        if let Some(wall) = run_batch(
            &manifest,
            &metrics,
            backend.as_ref(),
            batch,
            &mut pad_buf,
            audit.as_deref(),
        ) {
            // feed the measured wall-clock back into the admission
            // predictor for this (task, variant)
            let wall_us = wall.as_secs_f64() * 1e6;
            let mut s = shared.state.lock().unwrap();
            let e = s.wall_ewma.entry(key).or_insert(wall_us);
            *e = WALL_EWMA_ALPHA * wall_us + (1.0 - WALL_EWMA_ALPHA) * *e;
        }
    }
}

/// Deliver one completion; a closed receiver just means the caller
/// stopped listening.
fn complete(
    metrics: &CoordinatorMetrics,
    p: Pending,
    result: std::result::Result<Response, ApiError>,
) {
    if result.is_err() {
        metrics.failures.fetch_add(1, Relaxed);
    }
    let _ = p.done.send(Completion {
        id: p.req.id,
        result,
    });
}

/// Record a finished request's span: ring (for `cmd:"trace"`) and the
/// slow-exemplar table. Pure `Copy` data — no allocation on this path.
fn finish_span(metrics: &CoordinatorMetrics, req: &Request, key_idx: u32, ok: bool) {
    let span = obs::Span {
        trace: req.trace,
        id: req.id,
        key: key_idx,
        rows: req.block.rows as u32,
        ok,
        stamps: req.stamps,
    };
    metrics.spans.push(span);
    metrics.slow.offer(span);
}

/// Fail every item of a batch; returns `None` so `run_batch` error paths
/// can `return fail_items(...)` without an executed wall-clock.
fn fail_items(
    metrics: &CoordinatorMetrics,
    key: &QueueKey,
    key_idx: u32,
    items: Vec<Pending>,
    err: ApiError,
) -> Option<Duration> {
    crate::log_error!("batch {key:?} failed: {err}");
    for mut p in items {
        p.req.stamps.stamp(Stage::Reply);
        finish_span(metrics, &p.req, key_idx, false);
        complete(metrics, p, Err(err.clone()));
    }
    None
}

/// Execute one ready batch. Returns the backend wall-clock when the batch
/// actually executed (the admission EWMA observation), `None` otherwise.
fn run_batch(
    manifest: &Manifest,
    metrics: &CoordinatorMetrics,
    backend: &dyn ExecBackend,
    batch: ReadyBatch,
    pad_buf: &mut Vec<f32>,
    audit: Option<&AuditPlane>,
) -> Option<Duration> {
    let ReadyBatch { key, items } = batch;
    // intern the (task, variant) once per batch: after the first batch of
    // a queue this is a lock + name scan, no allocation — the per-item
    // stage recording below then runs entirely on atomics
    let (key_idx, stage_hists) = metrics.stage_key(&key.0, &key.1);
    let entry = match manifest.task(&key.0) {
        Ok(e) => e,
        Err(e) => {
            return fail_items(
                metrics,
                &key,
                key_idx,
                items,
                ApiError::unknown_task(e.to_string()),
            )
        }
    };
    let variant = match entry.variant(&key.1) {
        Some(v) => v.clone(),
        None => {
            return fail_items(
                metrics,
                &key,
                key_idx,
                items,
                ApiError::internal("variant vanished from the manifest"),
            )
        }
    };
    if variant.in_shape.is_empty() || variant.out_shape.is_empty() {
        return fail_items(
            metrics,
            &key,
            key_idx,
            items,
            ApiError::internal("variant has rank-0 in/out shape"),
        );
    }

    let b_cap = entry.batch();
    let sample_dim: usize = variant.in_shape[1..].iter().product();
    let out_dim: usize = variant.out_shape[1..].iter().product();

    // fail-fast deadlines: a request whose deadline passed before this
    // dispatch gets a structured deadline_exceeded error and never
    // executes (an in-flight execute is never cancelled, by contract)
    let now = Instant::now();
    let mut live: Vec<Pending> = Vec::with_capacity(items.len());
    for mut p in items {
        match p.req.deadline {
            Some(d) if now >= d => {
                metrics.deadline_misses.fetch_add(1, Relaxed);
                let waited = now.duration_since(p.req.t_submit).as_micros();
                let err = ApiError::deadline_exceeded(format!(
                    "request waited {waited}µs, past its deadline, before its \
                     batch dispatched"
                ));
                p.req.stamps.stamp(Stage::Reply);
                finish_span(metrics, &p.req, key_idx, false);
                complete(metrics, p, Err(err));
            }
            _ => live.push(p),
        }
    }
    if live.is_empty() {
        return None;
    }
    let items = live;

    // submit validated against the task's state shape; the variant's
    // executable row dim must agree or padding would silently corrupt
    // (image→logits exports take image-dim rows the state-dim submit
    // surface doesn't produce yet)
    if let Some(p) = items
        .iter()
        .find(|p| p.req.block.data.len() != p.req.block.rows * sample_dim)
    {
        let got = p.req.block.data.len();
        let rows = p.req.block.rows;
        return fail_items(
            metrics,
            &key,
            key_idx,
            items,
            ApiError::shape_mismatch(format!(
                "request has {got} values over {rows} row(s) but variant row \
                 dim is {sample_dim}"
            )),
        );
    }
    let mut items = items;

    // assemble the padded batch input into the worker's reusable buffer:
    // each request is one contiguous row block, fill rows zeroed
    let rows: usize = items.iter().map(|p| p.req.block.rows).sum();
    pad_batch_into(
        pad_buf,
        items.iter().map(|p| p.req.block.data.as_slice()),
        b_cap,
        sample_dim,
    );
    // one clock read per stage, shared by every batch-mate: their stamps
    // stay identical and the stamping cost stays O(1) clock calls
    let padded_us = obs::now_us();
    for p in &mut items {
        p.req.stamps.set(Stage::Pad, padded_us);
    }
    let queue_start = Instant::now();
    for p in &items {
        metrics
            .queue_latency
            .record(queue_start.duration_since(p.req.t_submit));
    }

    let t_exec = Instant::now();
    let exec_start_us = obs::now_us();
    for p in &mut items {
        p.req.stamps.set(Stage::ExecStart, exec_start_us);
    }
    let out = match backend.execute(manifest, entry, &variant, pad_buf.as_slice()) {
        Ok(o) => o,
        Err(e) => return fail_items(metrics, &key, key_idx, items, ApiError::from_engine(&e)),
    };
    let exec_time = t_exec.elapsed();
    metrics.exec_latency.record(exec_time);
    let exec_end_us = obs::now_us();
    // solver-internal counts stamped by the backend on this thread (the
    // native path; a backend executing elsewhere leaves them 0 and the
    // span falls back to the variant's nominal NFE)
    let (solver_nfe, solver_accepted, solver_rejected) = obs::take_solver_stamp();
    for p in &mut items {
        p.req.stamps.set(Stage::ExecEnd, exec_end_us);
    }

    let nfe = out.nfe.unwrap_or(variant.nfe);
    if out.z.len() < rows * out_dim {
        // validate before recording: a short output produces no responses
        // and must not count as a served batch in fill/NFE accounting
        let got = out.z.len();
        return fail_items(
            metrics,
            &key,
            key_idx,
            items,
            ApiError::internal(format!(
                "backend returned {got} values, batch needs {}",
                rows * out_dim
            )),
        );
    }
    metrics.record_batch(rows, b_cap, nfe, variant.macs);
    log_debug!("batch {}/{}: {rows}/{b_cap} rows in {exec_time:?}", key.0, key.1);
    let mut off = 0usize;
    for mut p in items {
        let n = p.req.block.rows * out_dim;
        let latency = p.req.t_submit.elapsed();
        metrics.total_latency.record(latency);
        metrics.responses.fetch_add(1, Relaxed);
        // goodput accounting: a response with no deadline had no SLO to
        // miss; one delivered past its deadline counts against goodput
        if p.req.deadline.is_none_or(|d| Instant::now() <= d) {
            metrics.deadline_met.fetch_add(1, Relaxed);
        }
        // shadow-audit sampling: the decision is a lock-free counter hash
        // (allocation-free, pinned in tests/alloc_free.rs); only a sampled
        // request pays the (input, output) copy, and `offer` never blocks
        // — a full or contended queue costs one drop-counter tick
        if let Some(plane) = audit {
            if plane.sampler.decide() {
                plane.offer(AuditSample {
                    key: key_idx,
                    rows: p.req.block.rows,
                    dims: sample_dim,
                    input: p.req.block.data.clone(),
                    served: out.z[off..off + n].to_vec(),
                });
            }
        }
        let resp = Response {
            id: p.req.id,
            output: out.z[off..off + n].to_vec(),
            variant: variant.name.clone(),
            mape: variant.mape,
            nfe,
            latency,
            batch_fill: rows,
        };
        off += n;
        p.req.stamps.nfe = if solver_nfe > 0 { solver_nfe } else { nfe };
        p.req.stamps.accepted = solver_accepted;
        p.req.stamps.rejected = solver_rejected;
        p.req.stamps.stamp(Stage::Reply);
        let st = &p.req.stamps;
        stage_hists
            .queue
            .record(Duration::from_micros(st.dur_us(Stage::Enqueue, Stage::Pop)));
        stage_hists
            .pad
            .record(Duration::from_micros(st.dur_us(Stage::Pop, Stage::Pad)));
        stage_hists.exec.record(Duration::from_micros(
            st.dur_us(Stage::ExecStart, Stage::ExecEnd),
        ));
        stage_hists
            .total
            .record(Duration::from_micros(st.dur_us(Stage::Submit, Stage::Reply)));
        metrics
            .pad_latency
            .record(Duration::from_micros(st.dur_us(Stage::Pop, Stage::Pad)));
        finish_span(metrics, &p.req, key_idx, true);
        complete(metrics, p, Ok(resp));
    }
    Some(exec_time)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_resolution_bounds() {
        assert_eq!(resolve_workers(3), 3);
        let auto = resolve_workers(0);
        assert!((2..=8).contains(&auto), "auto workers {auto}");
    }

    #[test]
    fn default_config_is_pjrt_auto() {
        let c = EngineConfig::default();
        assert_eq!(c.backend, BackendKind::Pjrt);
        assert_eq!(c.workers, 0);
        // SLO defaults: admission on, shedding and quotas off (they
        // refuse work, so they are opt-in)
        assert!(c.slo.admission);
        assert_eq!(c.slo.shed_high_water_rows, 0);
        assert_eq!(c.slo.client_quota_rows, 0);
        // audit plane defaults off (rate 0) with a tight reference tol
        // and a sustained-breach condition
        assert_eq!(c.audit.rate, 0.0);
        assert!(c.audit.tol <= 1e-5);
        assert!(c.audit.queue_cap > 0);
        assert!(c.audit.breach_factor >= 1.0);
        assert!(c.audit.breach_streak >= 1);
    }

    #[test]
    fn default_submit_options_are_classic() {
        let o = SubmitOptions::default();
        assert!(o.policy.is_none() && o.variant.is_none() && o.deadline.is_none());
        assert_eq!(o.priority, Priority::Normal);
        assert!(o.client.is_none());
    }
}
