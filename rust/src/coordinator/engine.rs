//! The coordinator engine: policy → queues → dispatcher → PJRT executor.

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::coordinator::batcher::{Batcher, Pending, QueueKey, ReadyBatch};
use crate::coordinator::metrics::CoordinatorMetrics;
use crate::coordinator::policy::{select_variant, Policy};
use crate::coordinator::request::{Request, Response};
use crate::runtime::exec::{Executor, ExecutorHandle};
use crate::runtime::manifest::Manifest;
use crate::{log_debug, log_info, Error, Result};

#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub artifacts_dir: std::path::PathBuf,
    /// dynamic batching deadline
    pub max_wait: Duration,
    pub policy: Policy,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            artifacts_dir: crate::artifacts_dir(),
            max_wait: Duration::from_millis(2),
            policy: Policy::MinMacs,
        }
    }
}

struct Shared {
    batcher: Mutex<Batcher>,
    work: Condvar,
    shutdown: AtomicBool,
}

/// The serving engine. `submit` is thread-safe; execution happens on the
/// dispatcher + PJRT executor threads.
pub struct Engine {
    manifest: Arc<Manifest>,
    shared: Arc<Shared>,
    metrics: Arc<CoordinatorMetrics>,
    next_id: AtomicU64,
    dispatcher: Option<thread::JoinHandle<()>>,
    // keep the executor alive (drops last: dispatcher uses its handle)
    _executor: Executor,
    config: EngineConfig,
}

impl Engine {
    pub fn new(config: EngineConfig) -> Result<Engine> {
        let manifest = Arc::new(Manifest::load(&config.artifacts_dir)?);
        let executor = Executor::spawn()?;
        let shared = Arc::new(Shared {
            batcher: Mutex::new(Batcher::new(config.max_wait)),
            work: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let metrics = Arc::new(CoordinatorMetrics::new());

        let dispatcher = {
            let shared = Arc::clone(&shared);
            let manifest = Arc::clone(&manifest);
            let metrics = Arc::clone(&metrics);
            let handle = executor.handle();
            thread::Builder::new()
                .name("hsolve-dispatcher".into())
                .spawn(move || dispatcher_main(shared, manifest, metrics, handle))
                .map_err(|e| Error::Coordinator(format!("spawn dispatcher: {e}")))?
        };

        log_info!(
            "engine up: {} tasks, policy {:?}, max_wait {:?}",
            manifest.tasks.len(),
            config.policy,
            config.max_wait
        );
        Ok(Engine {
            manifest,
            shared,
            metrics,
            next_id: AtomicU64::new(1),
            dispatcher: Some(dispatcher),
            _executor: executor,
            config,
        })
    }

    pub fn with_defaults() -> Result<Engine> {
        Self::new(EngineConfig::default())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn metrics(&self) -> &CoordinatorMetrics {
        &self.metrics
    }

    /// Submit one sample; returns the channel the response arrives on.
    pub fn submit(
        &self,
        task: &str,
        budget: f32,
        input: Vec<f32>,
    ) -> Result<mpsc::Receiver<Response>> {
        let entry = self.manifest.task(task)?;
        let sample_dim: usize = entry.state_shape[1..].iter().product();
        if input.len() != sample_dim {
            return Err(Error::Coordinator(format!(
                "task {task}: sample has {} values, state wants {sample_dim}",
                input.len()
            )));
        }
        let variant = select_variant(entry, budget, self.config.policy)
            .ok_or_else(|| Error::Coordinator(format!("task {task} has no variants")))?;
        let key: QueueKey = (task.to_string(), variant.name.clone());
        let id = self.next_id.fetch_add(1, Relaxed);
        let (tx, rx) = mpsc::channel();
        {
            let mut b = self.shared.batcher.lock().unwrap();
            b.ensure_queue(&key, entry.batch());
            b.push(
                &key,
                Pending {
                    req: Request::new(id, task, budget, input),
                    reply: tx,
                },
            );
        }
        self.metrics.requests.fetch_add(1, Relaxed);
        self.shared.work.notify_one();
        Ok(rx)
    }

    /// Submit and wait (convenience for examples/benches).
    pub fn infer(&self, task: &str, budget: f32, input: Vec<f32>) -> Result<Response> {
        let rx = self.submit(task, budget, input)?;
        rx.recv()
            .map_err(|_| Error::Coordinator("engine dropped response".into()))
    }

    /// Pre-compile the variants the policy can choose for `task`, so first
    /// requests don't pay PJRT compilation.
    pub fn warmup(&self, task: &str) -> Result<()> {
        let entry = self.manifest.task(task)?;
        let handle = self._executor.handle();
        for v in &entry.variants {
            let key = format!("{task}/{}", v.name);
            handle.load(&key, self.manifest.hlo_path(&v.hlo))?;
        }
        Ok(())
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Relaxed);
        self.shared.work.notify_all();
        if let Some(j) = self.dispatcher.take() {
            let _ = j.join();
        }
    }
}

fn dispatcher_main(
    shared: Arc<Shared>,
    manifest: Arc<Manifest>,
    metrics: Arc<CoordinatorMetrics>,
    exec: ExecutorHandle,
) {
    let mut loaded: HashSet<String> = HashSet::new();
    loop {
        // collect ready work under the lock, run it outside
        let batches: Vec<ReadyBatch> = {
            let mut b = shared.batcher.lock().unwrap();
            loop {
                if shared.shutdown.load(Relaxed) {
                    return;
                }
                let now = Instant::now();
                let ready = b.ready_batches(now);
                if !ready.is_empty() {
                    break ready;
                }
                let timeout = b
                    .next_deadline()
                    .map(|dl| dl.saturating_duration_since(now))
                    .unwrap_or(Duration::from_millis(50));
                let (guard, _) = shared
                    .work
                    .wait_timeout(b, timeout.max(Duration::from_micros(100)))
                    .unwrap();
                b = guard;
            }
        };
        for batch in batches {
            run_batch(&manifest, &metrics, &exec, &mut loaded, batch);
        }
    }
}

fn run_batch(
    manifest: &Manifest,
    metrics: &CoordinatorMetrics,
    exec: &ExecutorHandle,
    loaded: &mut HashSet<String>,
    batch: ReadyBatch,
) {
    let (task_name, variant_name) = &batch.key;
    let entry = match manifest.task(task_name) {
        Ok(e) => e,
        Err(e) => return fail_batch(batch, &e.to_string()),
    };
    let variant = match entry.variant(variant_name) {
        Some(v) => v.clone(),
        None => return fail_batch(batch, "variant vanished"),
    };
    let key = format!("{task_name}/{variant_name}");
    if !loaded.contains(&key) {
        let t0 = Instant::now();
        if let Err(e) = exec.load(&key, manifest.hlo_path(&variant.hlo)) {
            return fail_batch(batch, &e.to_string());
        }
        log_info!("compiled {key} in {:?}", t0.elapsed());
        loaded.insert(key.clone());
    }

    let b_cap = entry.batch();
    let sample_dim: usize = variant.in_shape[1..].iter().product();
    let out_dim: usize = variant.out_shape[1..].iter().product();
    let real = batch.items.len();

    // assemble the padded batch input
    let mut input = vec![0.0f32; b_cap * sample_dim];
    for (i, p) in batch.items.iter().enumerate() {
        input[i * sample_dim..(i + 1) * sample_dim].copy_from_slice(&p.req.input);
    }
    let queue_start = Instant::now();
    for p in &batch.items {
        metrics
            .queue_latency
            .record(queue_start.duration_since(p.req.t_submit));
    }

    let t_exec = Instant::now();
    let outputs = match exec.run(&key, input, &variant.in_shape) {
        Ok(o) => o,
        Err(e) => return fail_batch(batch, &e.to_string()),
    };
    let exec_time = t_exec.elapsed();
    metrics.exec_latency.record(exec_time);

    let z = &outputs[0];
    let nfe = if variant.returns_nfe && outputs.len() > 1 {
        outputs[1].first().copied().unwrap_or(0.0) as u64
    } else {
        variant.nfe
    };
    metrics.record_batch(real, b_cap, nfe, variant.macs);
    log_debug!("batch {key}: {real}/{b_cap} samples in {exec_time:?}");

    for (i, p) in batch.items.into_iter().enumerate() {
        let latency = p.req.t_submit.elapsed();
        metrics.total_latency.record(latency);
        metrics.responses.fetch_add(1, Relaxed);
        let _ = p.reply.send(Response {
            id: p.req.id,
            output: z[i * out_dim..(i + 1) * out_dim].to_vec(),
            variant: variant.name.clone(),
            mape: variant.mape,
            nfe,
            latency,
            batch_fill: real,
        });
    }
}

fn fail_batch(batch: ReadyBatch, msg: &str) {
    crate::log_error!("batch {:?} failed: {msg}", batch.key);
    // drop the reply senders: receivers see a disconnect error
}
