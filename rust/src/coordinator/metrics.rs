//! Coordinator observability: request/batch counters, latency histograms,
//! NFE/MAC accounting. All atomics — the hot path never locks to record.
//!
//! With request tracing (see [`crate::obs`]) the metrics also carry the
//! span plane: a lock-free ring of completed spans (`cmd:"trace"`), the
//! slow-request exemplars (`cmd:"trace_slow"`), and per-(task, variant)
//! *stage* histograms — where a queue's requests spend their time, split
//! queue / pad / exec / total. The (task, variant) names are interned to
//! a `u32` key at first sight so the per-request records stay `Copy` and
//! the dispatch hot path stays allocation-free.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

use crate::obs::ring::SpanRing;
use crate::obs::SlowTable;
use crate::util::stats::LatencyHistogram;

/// Per-(task, variant) stage-latency histograms: where time goes inside
/// the pipeline for one queue. All atomics — recording never locks.
#[derive(Default)]
pub struct StageHists {
    /// enqueue → pop (time queued behind the batching policy)
    pub queue: LatencyHistogram,
    /// pop → padded (batch assembly/staging)
    pub pad: LatencyHistogram,
    /// exec start → exec end (backend solve)
    pub exec: LatencyHistogram,
    /// submit → reply (end to end)
    pub total: LatencyHistogram,
}

struct KeyEntry {
    task: String,
    variant: String,
    hists: Arc<StageHists>,
}

#[derive(Default)]
pub struct CoordinatorMetrics {
    /// accepted submissions (a multi-sample request counts once)
    pub requests: AtomicU64,
    /// successful completions delivered
    pub responses: AtomicU64,
    /// completions delivered as structured errors (deadline misses
    /// included — they are also counted separately below)
    pub failures: AtomicU64,
    /// requests failed fast because their deadline passed before dispatch
    pub deadline_misses: AtomicU64,
    /// queued requests shed at the overload high-water mark
    pub shed: AtomicU64,
    /// requests refused before enqueue (admission control predicted the
    /// deadline unmeetable, or the client's row quota was exhausted)
    pub overload_rejects: AtomicU64,
    /// successful completions that met their deadline (no-deadline
    /// responses count as met — they had no SLO to miss)
    pub deadline_met: AtomicU64,
    pub batches: AtomicU64,
    /// real rows executed across all batches
    pub rows: AtomicU64,
    /// padded (wasted) slots across executed batches
    pub padded_slots: AtomicU64,
    /// total NFEs spent (per-sample NFE × real rows)
    pub nfe_total: AtomicU64,
    /// total MACs spent (per-sample × real rows)
    pub macs_total: AtomicU64,
    /// batches executing right now across the dispatch worker pool
    pub inflight_batches: AtomicU64,
    /// high-water mark of concurrent batches; queue affinity means every
    /// concurrent batch belongs to a distinct (task, variant) queue, so a
    /// peak ≥ 2 demonstrates parallel dispatch (true parallel execution on
    /// the native backend; on pjrt, pipelining into the serial executor
    /// thread)
    pub inflight_peak: AtomicU64,
    pub queue_latency: LatencyHistogram,
    pub exec_latency: LatencyHistogram,
    /// batch staging (pop → padded) across all queues
    pub pad_latency: LatencyHistogram,
    pub total_latency: LatencyHistogram,
    /// completed request spans, overwrite-oldest (`cmd:"trace"`)
    pub spans: SpanRing,
    /// top-K slowest spans by end-to-end latency (`cmd:"trace_slow"`)
    pub slow: SlowTable,
    /// interned (task, variant) keys + their stage histograms
    keys: Mutex<Vec<KeyEntry>>,
}

impl CoordinatorMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, real_rows: usize, capacity: usize, nfe: u64, macs: u64) {
        self.batches.fetch_add(1, Relaxed);
        self.rows.fetch_add(real_rows as u64, Relaxed);
        self.padded_slots
            .fetch_add(capacity.saturating_sub(real_rows) as u64, Relaxed);
        self.nfe_total.fetch_add(nfe * real_rows as u64, Relaxed);
        self.macs_total.fetch_add(macs * real_rows as u64, Relaxed);
    }

    /// Mark a batch execution starting; returns the current in-flight count
    /// and maintains the concurrency peak.
    pub fn batch_started(&self) -> u64 {
        let now = self.inflight_batches.fetch_add(1, Relaxed) + 1;
        self.inflight_peak.fetch_max(now, Relaxed);
        now
    }

    /// Mark a batch execution finished.
    pub fn batch_finished(&self) {
        self.inflight_batches.fetch_sub(1, Relaxed);
    }

    /// Mean batch fill ratio over rows (1.0 = always full).
    pub fn fill_ratio(&self) -> f64 {
        let rows = self.rows.load(Relaxed);
        let pad = self.padded_slots.load(Relaxed);
        if rows + pad == 0 {
            return 1.0;
        }
        rows as f64 / (rows + pad) as f64
    }

    /// Fraction of delivered responses that met their deadline (1.0 when
    /// nothing has completed yet). This is the SLO headline: under
    /// overload a server can keep `responses` high while goodput craters.
    pub fn goodput(&self) -> f64 {
        let responses = self.responses.load(Relaxed);
        if responses == 0 {
            return 1.0;
        }
        self.deadline_met.load(Relaxed) as f64 / responses as f64
    }

    fn lock_keys(&self) -> std::sync::MutexGuard<'_, Vec<KeyEntry>> {
        match self.keys.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    /// Intern a (task, variant) key, returning its stable index and the
    /// queue's stage histograms. The scan compares by `&str` so repeat
    /// lookups (once per executed batch) allocate nothing; only the first
    /// sight of a key allocates its entry.
    pub fn stage_key(&self, task: &str, variant: &str) -> (u32, Arc<StageHists>) {
        let mut keys = self.lock_keys();
        for (i, e) in keys.iter().enumerate() {
            if e.task == task && e.variant == variant {
                return (i as u32, Arc::clone(&e.hists));
            }
        }
        let hists = Arc::new(StageHists::default());
        keys.push(KeyEntry {
            task: task.to_string(),
            variant: variant.to_string(),
            hists: Arc::clone(&hists),
        });
        ((keys.len() - 1) as u32, hists)
    }

    /// Resolve an interned key index back to its (task, variant) names.
    pub fn key_name(&self, key: u32) -> Option<(String, String)> {
        self.lock_keys()
            .get(key as usize)
            .map(|e| (e.task.clone(), e.variant.clone()))
    }

    /// Snapshot every interned (task, variant) with its stage histograms,
    /// sorted by name — the exposition iterates this for a deterministic
    /// render order.
    pub fn stage_snapshot(&self) -> Vec<(String, String, Arc<StageHists>)> {
        let mut out: Vec<(String, String, Arc<StageHists>)> = self
            .lock_keys()
            .iter()
            .map(|e| (e.task.clone(), e.variant.clone(), Arc::clone(&e.hists)))
            .collect();
        out.sort_by(|a, b| (&a.0, &a.1).cmp(&(&b.0, &b.1)));
        out
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} responses={} failures={} deadline_misses={} \
             shed={} overload_rejects={} goodput={:.2} batches={} \
             rows={} fill={:.2} inflight_peak={} \
             queue_p50={:.0}µs exec_p50={:.0}µs total_p50={:.0}µs total_p99={:.0}µs \
             nfe_total={} gmacs_total={:.2}",
            self.requests.load(Relaxed),
            self.responses.load(Relaxed),
            self.failures.load(Relaxed),
            self.deadline_misses.load(Relaxed),
            self.shed.load(Relaxed),
            self.overload_rejects.load(Relaxed),
            self.goodput(),
            self.batches.load(Relaxed),
            self.rows.load(Relaxed),
            self.fill_ratio(),
            self.inflight_peak.load(Relaxed),
            self.queue_latency.percentile_us(50.0),
            self.exec_latency.percentile_us(50.0),
            self.total_latency.percentile_us(50.0),
            self.total_latency.percentile_us(99.0),
            self.nfe_total.load(Relaxed),
            self.macs_total.load(Relaxed) as f64 / 1e9,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_accounting() {
        let m = CoordinatorMetrics::new();
        m.record_batch(3, 4, 2, 100);
        m.record_batch(3, 3, 2, 100);
        assert_eq!(m.batches.load(Relaxed), 2);
        assert_eq!(m.rows.load(Relaxed), 6);
        assert_eq!(m.padded_slots.load(Relaxed), 1);
        assert_eq!(m.nfe_total.load(Relaxed), 12);
        assert!((m.fill_ratio() - 6.0 / 7.0).abs() < 1e-9);
        assert!(m.report().contains("batches=2"));
    }

    #[test]
    fn inflight_gauge_tracks_peak() {
        let m = CoordinatorMetrics::new();
        assert_eq!(m.batch_started(), 1);
        assert_eq!(m.batch_started(), 2);
        m.batch_finished();
        assert_eq!(m.batch_started(), 2);
        m.batch_finished();
        m.batch_finished();
        assert_eq!(m.inflight_batches.load(Relaxed), 0);
        assert_eq!(m.inflight_peak.load(Relaxed), 2);
        assert!(m.report().contains("inflight_peak=2"));
    }

    #[test]
    fn empty_metrics_report() {
        let m = CoordinatorMetrics::new();
        assert_eq!(m.fill_ratio(), 1.0);
        assert!(m.report().contains("requests=0"));
        assert!(m.report().contains("deadline_misses=0"));
        assert!(m.report().contains("shed=0"));
        assert!(m.report().contains("overload_rejects=0"));
    }

    #[test]
    fn goodput_tracks_deadline_met_over_responses() {
        let m = CoordinatorMetrics::new();
        assert_eq!(m.goodput(), 1.0, "no responses yet → vacuous 1.0");
        m.responses.fetch_add(4, Relaxed);
        m.deadline_met.fetch_add(3, Relaxed);
        assert!((m.goodput() - 0.75).abs() < 1e-12);
        assert!(m.report().contains("goodput=0.75"), "{}", m.report());
    }

    #[test]
    fn ratios_are_well_defined_before_the_first_response() {
        // division-guard audit: every ratio the wire can report must be a
        // finite, meaningful value at t=0 — never NaN from 0/0
        let m = CoordinatorMetrics::new();
        assert_eq!(m.fill_ratio(), 1.0, "no batches yet → vacuously full");
        assert_eq!(m.goodput(), 1.0, "no responses yet → vacuously good");
        assert!(m.fill_ratio().is_finite());
        assert!(m.goodput().is_finite());
        // histograms: empty percentiles/means are 0, not NaN
        assert_eq!(m.total_latency.percentile_us(50.0), 0.0);
        assert_eq!(m.total_latency.mean_us(), 0.0);
        // pad-only batches (0 real rows) keep fill_ratio finite too
        m.record_batch(0, 4, 1, 1);
        assert_eq!(m.fill_ratio(), 0.0);
        assert!(m.fill_ratio().is_finite());
    }

    #[test]
    fn stage_keys_intern_stably_and_resolve_back() {
        let m = CoordinatorMetrics::new();
        let (k0, h0) = m.stage_key("cnf_a", "euler_k2");
        let (k1, _) = m.stage_key("cnf_b", "euler_k2");
        let (k0b, h0b) = m.stage_key("cnf_a", "euler_k2");
        assert_eq!(k0, k0b, "repeat lookups return the same index");
        assert_ne!(k0, k1);
        assert!(Arc::ptr_eq(&h0, &h0b), "same histograms behind the key");
        assert_eq!(
            m.key_name(k1),
            Some(("cnf_b".to_string(), "euler_k2".to_string()))
        );
        assert_eq!(m.key_name(99), None);
        h0.queue.record(std::time::Duration::from_micros(100));
        let snap = m.stage_snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].0, "cnf_a", "snapshot sorted by name");
        assert_eq!(snap[0].2.queue.count(), 1);
    }
}
