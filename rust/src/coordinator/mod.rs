//! The serving coordinator — the paper's accuracy/compute Pareto front made
//! operational.
//!
//! Callers submit inference requests with an **error budget** (max terminal
//! MAPE vs the dopri5 reference). The [`policy`] picks the cheapest
//! `(solver, K)` variant whose *measured* error satisfies the budget — with
//! hypersolved variants on the front, tight budgets resolve to a fraction of
//! the NFEs classical solvers would need (Fig. 3/4 of the paper, served
//! live). The [`batcher`] coalesces requests per chosen variant up to the
//! exported batch size under a latency deadline, and the [`engine`]'s
//! dispatch worker pool executes batches on a pluggable
//! [`ExecBackend`](crate::runtime::ExecBackend) — PJRT over the AOT
//! artifacts, or the native tensor/solver stack.
//!
//! The caller-facing contract is the versioned API in [`crate::api`]:
//! typed multi-sample requests, non-blocking [`Engine::submit`] with
//! id-correlated completions (many in flight per caller), per-request
//! policy/variant/deadline options, and stable error codes end to end.
//!
//! ```text
//! client ──submit──► Engine ──policy──► per-variant queues (batcher)
//!                                           │ rows full or deadline
//!                                           ▼
//!                          dispatch workers (per-queue affinity)
//!                               │                    │
//!                               ▼                    ▼
//!                        exec backend (pjrt | native) ──► completions (by id)
//! ```

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod policy;
pub mod request;
pub mod server;

pub use engine::{Engine, EngineConfig, SloConfig, SubmitHandle, SubmitOptions};
pub use metrics::{CoordinatorMetrics, StageHists};
pub use policy::{select_variant, Policy};
pub use request::{Completion, CompletionSender, Priority, Request, Response, RowBlock};
