//! The serving coordinator — the paper's accuracy/compute Pareto front made
//! operational.
//!
//! Callers submit inference requests with an **error budget** (max terminal
//! MAPE vs the dopri5 reference). The [`policy`] picks the cheapest
//! `(solver, K)` variant whose *measured* error satisfies the budget — with
//! hypersolved variants on the front, tight budgets resolve to a fraction of
//! the NFEs classical solvers would need (Fig. 3/4 of the paper, served
//! live). The [`batcher`] coalesces requests per chosen variant up to the
//! exported batch size under a latency deadline, and the [`engine`]'s
//! dispatch worker pool executes batches on a pluggable
//! [`ExecBackend`](crate::runtime::ExecBackend) — PJRT over the AOT
//! artifacts, or the native tensor/solver stack.
//!
//! ```text
//! client ──submit──► Engine ──policy──► per-variant queues (batcher)
//!                                           │ full batch or deadline
//!                                           ▼
//!                          dispatch workers (per-queue affinity)
//!                               │                    │
//!                               ▼                    ▼
//!                        exec backend (pjrt | native) ──► responses
//! ```

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod policy;
pub mod request;
pub mod server;

pub use engine::{Engine, EngineConfig};
pub use metrics::CoordinatorMetrics;
pub use policy::{select_variant, Policy};
pub use request::{Request, Response};
