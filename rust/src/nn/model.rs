//! Task-level models assembled from exported weight files.

use std::path::Path;

use crate::nn::field::{ConvField, HyperCnn, HyperMlp, MlpField};
use crate::nn::layers::{Conv2d, Linear};
use crate::ode::{Decay, Rotation, VanDerPol, VectorField};
use crate::tensor::{Tensor, Workspace};
use crate::util::json::{self, Value};
use crate::{Error, Result};

/// An analytic vector field referenced (rather than exported) from a
/// weights file: `{"analytic": {"name": "vdp", "mu": 1.0}}`. The in-Rust
/// trainer (`train`) writes these so a hypersolver fitted against e.g. Van
/// der Pol round-trips through the same weights JSON + manifest the native
/// serving backend loads — no MLP distillation of a closed-form field.
#[derive(Clone, Copy, Debug)]
pub enum AnalyticField {
    VanDerPol { mu: f32 },
    Rotation { omega: f32 },
    Decay { lambda: f32 },
}

impl AnalyticField {
    pub fn from_json(v: &Value) -> Result<AnalyticField> {
        let name = v
            .req("name")?
            .as_str()
            .ok_or_else(|| Error::Json("analytic field name".into()))?;
        let param = |key: &str, default: f32| {
            v.get(key).and_then(Value::as_f32).unwrap_or(default)
        };
        match name {
            "vdp" | "vanderpol" => Ok(AnalyticField::VanDerPol {
                mu: param("mu", 1.0),
            }),
            "rotation" => Ok(AnalyticField::Rotation {
                omega: param("omega", 1.0),
            }),
            "decay" => Ok(AnalyticField::Decay {
                lambda: param("lambda", -1.0),
            }),
            other => Err(Error::Json(format!("unknown analytic field {other:?}"))),
        }
    }

    pub fn to_json(&self) -> Value {
        match *self {
            AnalyticField::VanDerPol { mu } => json::obj(vec![
                ("name", json::s("vdp")),
                ("mu", json::num(mu as f64)),
            ]),
            AnalyticField::Rotation { omega } => json::obj(vec![
                ("name", json::s("rotation")),
                ("omega", json::num(omega as f64)),
            ]),
            AnalyticField::Decay { lambda } => json::obj(vec![
                ("name", json::s("decay")),
                ("lambda", json::num(lambda as f64)),
            ]),
        }
    }

    /// State dimensionality the field integrates (all three are planar —
    /// `Decay` acts elementwise but is exported as a 2-D task).
    pub fn state_dim(&self) -> usize {
        2
    }
}

impl VectorField for AnalyticField {
    fn eval(&self, s: f32, z: &Tensor) -> Tensor {
        match *self {
            AnalyticField::VanDerPol { mu } => VanDerPol { mu }.eval(s, z),
            AnalyticField::Rotation { omega } => Rotation { omega }.eval(s, z),
            AnalyticField::Decay { lambda } => Decay { lambda }.eval(s, z),
        }
    }

    fn eval_into(&self, s: f32, z: &Tensor, out: &mut Tensor, ws: &mut Workspace) {
        match *self {
            AnalyticField::VanDerPol { mu } => VanDerPol { mu }.eval_into(s, z, out, ws),
            AnalyticField::Rotation { omega } => {
                Rotation { omega }.eval_into(s, z, out, ws)
            }
            AnalyticField::Decay { lambda } => Decay { lambda }.eval_into(s, z, out, ws),
        }
    }

    fn macs(&self) -> u64 {
        // a handful of flops per sample; report the dominant term
        4
    }
}

/// A CNF task's field as loaded from the weights file: an exported MLP
/// (the python path) or an analytic reference (the in-Rust trainer's
/// export). Both serve identically through [`VectorField`].
#[derive(Clone, Debug)]
pub enum FieldNet {
    Mlp(MlpField),
    Analytic(AnalyticField),
}

impl FieldNet {
    pub fn from_json(v: &Value) -> Result<FieldNet> {
        if let Some(a) = v.get("analytic") {
            Ok(FieldNet::Analytic(AnalyticField::from_json(a)?))
        } else {
            Ok(FieldNet::Mlp(MlpField::from_json(v)?))
        }
    }

    pub fn to_json(&self) -> Value {
        match self {
            FieldNet::Mlp(f) => f.to_json(),
            FieldNet::Analytic(a) => json::obj(vec![("analytic", a.to_json())]),
        }
    }

    pub fn state_dim(&self) -> usize {
        match self {
            FieldNet::Mlp(f) => f.state_dim(),
            FieldNet::Analytic(a) => a.state_dim(),
        }
    }
}

impl VectorField for FieldNet {
    fn eval(&self, s: f32, z: &Tensor) -> Tensor {
        match self {
            FieldNet::Mlp(f) => f.eval(s, z),
            FieldNet::Analytic(a) => a.eval(s, z),
        }
    }

    fn eval_into(&self, s: f32, z: &Tensor, out: &mut Tensor, ws: &mut Workspace) {
        match self {
            FieldNet::Mlp(f) => f.eval_into(s, z, out, ws),
            FieldNet::Analytic(a) => a.eval_into(s, z, out, ws),
        }
    }

    fn macs(&self) -> u64 {
        match self {
            FieldNet::Mlp(f) => VectorField::macs(f),
            FieldNet::Analytic(a) => VectorField::macs(a),
        }
    }
}

/// CNF model (field + HyperHeun net) — `weights/cnf_<density>.json`.
#[derive(Clone, Debug)]
pub struct CnfModel {
    pub field: FieldNet,
    pub hyper: HyperMlp,
}

impl CnfModel {
    pub fn from_json(v: &Value) -> Result<CnfModel> {
        Ok(CnfModel {
            field: FieldNet::from_json(v.req("field")?)?,
            hyper: HyperMlp::from_json(v.req("hyper")?)?,
        })
    }

    pub fn load(path: &Path) -> Result<CnfModel> {
        Self::from_json(&json::parse_file(path)?)
    }

    /// Export as the full weights file [`load`](Self::load) parses.
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("kind", json::s("cnf")),
            ("field", self.field.to_json()),
            ("hyper", self.hyper.to_json()),
        ])
    }
}

/// Tracking model (Galerkin-flavoured field + trajectory-fitted HyperEuler).
#[derive(Clone, Debug)]
pub struct TrackingModel {
    pub field: MlpField,
    pub hyper: HyperMlp,
}

impl TrackingModel {
    pub fn from_json(v: &Value) -> Result<TrackingModel> {
        Ok(TrackingModel {
            field: MlpField::from_json(v.req("field")?)?,
            hyper: HyperMlp::from_json(v.req("hyper")?)?,
        })
    }

    pub fn load(path: &Path) -> Result<TrackingModel> {
        Self::from_json(&json::parse_file(path)?)
    }
}

/// Image classification model: h_x augmenter, conv field, h_y head, and the
/// HyperEuler (plus optionally HyperMidpoint) correction nets.
#[derive(Clone, Debug)]
pub struct ImageModel {
    pub hw: usize,
    pub in_ch: usize,
    pub aug_ch: usize,
    pub hx: Conv2d,
    pub field: ConvField,
    pub hy_conv: Conv2d,
    pub hy_lin: Linear,
    pub hyper: HyperCnn,
    pub hyper_midpoint: Option<HyperCnn>,
}

impl ImageModel {
    pub fn from_json(v: &Value) -> Result<ImageModel> {
        Ok(ImageModel {
            hw: v.req("hw")?.as_usize().ok_or_else(|| Error::Json("hw".into()))?,
            in_ch: v
                .req("in_ch")?
                .as_usize()
                .ok_or_else(|| Error::Json("in_ch".into()))?,
            aug_ch: v
                .req("aug_ch")?
                .as_usize()
                .ok_or_else(|| Error::Json("aug_ch".into()))?,
            hx: Conv2d::from_json(v.req("hx")?)?,
            field: ConvField::from_json(v.req("field")?)?,
            hy_conv: Conv2d::from_json(v.req("hy_conv")?)?,
            hy_lin: Linear::from_json(v.req("hy_lin")?)?,
            hyper: HyperCnn::from_json(v.req("hyper")?)?,
            hyper_midpoint: match v.get("hyper_midpoint") {
                Some(hm) => Some(HyperCnn::from_json(hm)?),
                None => None,
            },
        })
    }

    pub fn load(path: &Path) -> Result<ImageModel> {
        Self::from_json(&json::parse_file(path)?)
    }

    /// Input augmentation: images (B, in_ch, H, W) → state (B, aug, H, W).
    pub fn hx(&self, x: &Tensor) -> Result<Tensor> {
        self.hx.forward(x)
    }

    /// Readout: terminal state → logits (B, n_classes).
    pub fn hy(&self, z: &Tensor) -> Result<Tensor> {
        let feat = self.hy_conv.forward(z)?;
        let b = feat.shape()[0];
        let flat = feat.reshape(&[b, feat.numel() / b])?;
        self.hy_lin.forward(&flat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn tiny_image_json() -> Value {
        json::parse(
            r#"{
              "kind":"image","hw":2,"in_ch":1,"aug_ch":1,
              "hx":{"w":[[[[1]]]],"b":[0]},
              "field":{
                "c1":{"w":[[[[1]],[[0]]]],"b":[0]},
                "c2":{"w":[[[[1]],[[0]]]],"b":[0]},
                "c3":{"w":[[[[0]]]],"b":[0]}},
              "hy_conv":{"w":[[[[1]]]],"b":[0]},
              "hy_lin":{"w":[[1,0],[0,1],[1,0],[0,1]],"b":[0,0],"act":"id"},
              "hyper":{
                "c1":{"w":[[[[0]],[[0]],[[0]]]],"b":[0]},
                "p1":{"alpha":[0.1]},
                "c2":{"w":[[[[0]]]],"b":[0]}}
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn image_model_loads_and_runs() {
        let m = ImageModel::from_json(&tiny_image_json()).unwrap();
        assert_eq!(m.hw, 2);
        assert!(m.hyper_midpoint.is_none());
        let x = Tensor::full(&[3, 1, 2, 2], 1.0);
        let z0 = m.hx(&x).unwrap();
        assert_eq!(z0.shape(), &[3, 1, 2, 2]);
        let logits = m.hy(&z0).unwrap();
        assert_eq!(logits.shape(), &[3, 2]);
    }

    #[test]
    fn missing_key_reports_name() {
        let v = json::parse(r#"{"kind":"cnf"}"#).unwrap();
        let err = CnfModel::from_json(&v).unwrap_err();
        assert!(err.to_string().contains("field"));
    }

    #[test]
    fn analytic_field_roundtrip_and_eval() {
        let v = json::parse(r#"{"analytic": {"name": "vdp", "mu": 2.5}}"#).unwrap();
        let f = FieldNet::from_json(&v).unwrap();
        assert_eq!(f.state_dim(), 2);
        let z = Tensor::new(&[1, 2], vec![0.5, -1.0]).unwrap();
        let dz = f.eval(0.0, &z);
        // vdp: dx = y, dy = mu (1 - x²) y - x
        assert!((dz.data()[0] - (-1.0)).abs() < 1e-6);
        assert!((dz.data()[1] - (2.5 * 0.75 * -1.0 - 0.5)).abs() < 1e-5);
        // serialization round trip preserves the field exactly
        let back =
            FieldNet::from_json(&json::parse(&json::to_string(&f.to_json())).unwrap())
                .unwrap();
        assert_eq!(back.eval(0.0, &z).data(), dz.data());
        // eval_into agrees with eval
        let mut ws = Workspace::new();
        let mut out = Tensor::full(&[1, 2], f32::NAN);
        f.eval_into(0.0, &z, &mut out, &mut ws);
        assert_eq!(out.data(), dz.data());
    }

    #[test]
    fn unknown_analytic_field_rejected() {
        let v = json::parse(r#"{"analytic": {"name": "lorenz"}}"#).unwrap();
        assert!(FieldNet::from_json(&v).is_err());
    }

    #[test]
    fn mlp_weights_still_parse_as_field_net() {
        let v = json::parse(
            r#"{"time_mode":"concat",
                "layers":[{"w":[[1,0],[0,1],[0,0]],"b":[0,0],"act":"id"}]}"#,
        )
        .unwrap();
        let f = FieldNet::from_json(&v).unwrap();
        assert!(matches!(f, FieldNet::Mlp(_)));
        assert_eq!(f.state_dim(), 2);
    }
}
