//! Task-level models assembled from exported weight files.

use std::path::Path;

use crate::nn::field::{ConvField, HyperCnn, HyperMlp, MlpField};
use crate::nn::layers::{Conv2d, Linear};
use crate::tensor::Tensor;
use crate::util::json::{self, Value};
use crate::{Error, Result};

/// CNF model (field + HyperHeun net) — `weights/cnf_<density>.json`.
#[derive(Clone, Debug)]
pub struct CnfModel {
    pub field: MlpField,
    pub hyper: HyperMlp,
}

impl CnfModel {
    pub fn from_json(v: &Value) -> Result<CnfModel> {
        Ok(CnfModel {
            field: MlpField::from_json(v.req("field")?)?,
            hyper: HyperMlp::from_json(v.req("hyper")?)?,
        })
    }

    pub fn load(path: &Path) -> Result<CnfModel> {
        Self::from_json(&json::parse_file(path)?)
    }
}

/// Tracking model (Galerkin-flavoured field + trajectory-fitted HyperEuler).
#[derive(Clone, Debug)]
pub struct TrackingModel {
    pub field: MlpField,
    pub hyper: HyperMlp,
}

impl TrackingModel {
    pub fn from_json(v: &Value) -> Result<TrackingModel> {
        Ok(TrackingModel {
            field: MlpField::from_json(v.req("field")?)?,
            hyper: HyperMlp::from_json(v.req("hyper")?)?,
        })
    }

    pub fn load(path: &Path) -> Result<TrackingModel> {
        Self::from_json(&json::parse_file(path)?)
    }
}

/// Image classification model: h_x augmenter, conv field, h_y head, and the
/// HyperEuler (plus optionally HyperMidpoint) correction nets.
#[derive(Clone, Debug)]
pub struct ImageModel {
    pub hw: usize,
    pub in_ch: usize,
    pub aug_ch: usize,
    pub hx: Conv2d,
    pub field: ConvField,
    pub hy_conv: Conv2d,
    pub hy_lin: Linear,
    pub hyper: HyperCnn,
    pub hyper_midpoint: Option<HyperCnn>,
}

impl ImageModel {
    pub fn from_json(v: &Value) -> Result<ImageModel> {
        Ok(ImageModel {
            hw: v.req("hw")?.as_usize().ok_or_else(|| Error::Json("hw".into()))?,
            in_ch: v
                .req("in_ch")?
                .as_usize()
                .ok_or_else(|| Error::Json("in_ch".into()))?,
            aug_ch: v
                .req("aug_ch")?
                .as_usize()
                .ok_or_else(|| Error::Json("aug_ch".into()))?,
            hx: Conv2d::from_json(v.req("hx")?)?,
            field: ConvField::from_json(v.req("field")?)?,
            hy_conv: Conv2d::from_json(v.req("hy_conv")?)?,
            hy_lin: Linear::from_json(v.req("hy_lin")?)?,
            hyper: HyperCnn::from_json(v.req("hyper")?)?,
            hyper_midpoint: match v.get("hyper_midpoint") {
                Some(hm) => Some(HyperCnn::from_json(hm)?),
                None => None,
            },
        })
    }

    pub fn load(path: &Path) -> Result<ImageModel> {
        Self::from_json(&json::parse_file(path)?)
    }

    /// Input augmentation: images (B, in_ch, H, W) → state (B, aug, H, W).
    pub fn hx(&self, x: &Tensor) -> Result<Tensor> {
        self.hx.forward(x)
    }

    /// Readout: terminal state → logits (B, n_classes).
    pub fn hy(&self, z: &Tensor) -> Result<Tensor> {
        let feat = self.hy_conv.forward(z)?;
        let b = feat.shape()[0];
        let flat = feat.reshape(&[b, feat.numel() / b])?;
        self.hy_lin.forward(&flat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn tiny_image_json() -> Value {
        json::parse(
            r#"{
              "kind":"image","hw":2,"in_ch":1,"aug_ch":1,
              "hx":{"w":[[[[1]]]],"b":[0]},
              "field":{
                "c1":{"w":[[[[1]],[[0]]]],"b":[0]},
                "c2":{"w":[[[[1]],[[0]]]],"b":[0]},
                "c3":{"w":[[[[0]]]],"b":[0]}},
              "hy_conv":{"w":[[[[1]]]],"b":[0]},
              "hy_lin":{"w":[[1,0],[0,1],[1,0],[0,1]],"b":[0,0],"act":"id"},
              "hyper":{
                "c1":{"w":[[[[0]],[[0]],[[0]]]],"b":[0]},
                "p1":{"alpha":[0.1]},
                "c2":{"w":[[[[0]]]],"b":[0]}}
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn image_model_loads_and_runs() {
        let m = ImageModel::from_json(&tiny_image_json()).unwrap();
        assert_eq!(m.hw, 2);
        assert!(m.hyper_midpoint.is_none());
        let x = Tensor::full(&[3, 1, 2, 2], 1.0);
        let z0 = m.hx(&x).unwrap();
        assert_eq!(z0.shape(), &[3, 1, 2, 2]);
        let logits = m.hy(&z0).unwrap();
        assert_eq!(logits.shape(), &[3, 2]);
    }

    #[test]
    fn missing_key_reports_name() {
        let v = json::parse(r#"{"kind":"cnf"}"#).unwrap();
        let err = CnfModel::from_json(&v).unwrap_err();
        assert!(err.to_string().contains("field"));
    }
}
