//! Vector fields and hypersolver nets reconstructed from exported weights.
//!
//! Mirrors `python/compile/fields.py`: MLP field with time features, DepthCat
//! conv field, hyper MLP (input `[z, dz, eps, s]`) and hyper CNN (input
//! `cat(z, dz) ⊕ DepthCat(s + eps)`).

use crate::nn::layers::{Conv2d, Mlp, PRelu};
use crate::ode::VectorField;
use crate::solvers::HyperNet;
use crate::tensor::Tensor;
use crate::util::json::Value;
use crate::{Error, Result};

/// Depth (time) feature modes — must match `fields.time_features`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimeMode {
    /// raw s appended as one feature
    Concat,
    /// sin/cos(2πks), k = 1..3
    Fourier3,
}

impl TimeMode {
    pub fn from_name(name: &str) -> Result<TimeMode> {
        match name {
            "concat" => Ok(TimeMode::Concat),
            "fourier3" => Ok(TimeMode::Fourier3),
            _ => Err(Error::Json(format!("unknown time mode {name:?}"))),
        }
    }

    pub fn dim(self) -> usize {
        match self {
            TimeMode::Concat => 1,
            TimeMode::Fourier3 => 6,
        }
    }

    pub fn features(self, s: f32) -> Vec<f32> {
        match self {
            TimeMode::Concat => vec![s],
            TimeMode::Fourier3 => {
                let mut out = Vec::with_capacity(6);
                for k in 1..=3 {
                    out.push((2.0 * std::f32::consts::PI * k as f32 * s).sin());
                }
                for k in 1..=3 {
                    out.push((2.0 * std::f32::consts::PI * k as f32 * s).cos());
                }
                out
            }
        }
    }
}

/// f(s, z) = MLP([z, timefeat(s)]) on (B, D) states.
#[derive(Clone, Debug)]
pub struct MlpField {
    pub mlp: Mlp,
    pub time_mode: TimeMode,
}

impl MlpField {
    pub fn from_json(v: &Value) -> Result<MlpField> {
        let time_mode = TimeMode::from_name(
            v.req("time_mode")?
                .as_str()
                .ok_or_else(|| Error::Json("time_mode".into()))?,
        )?;
        Ok(MlpField {
            mlp: Mlp::from_json(v.req("layers")?)?,
            time_mode,
        })
    }

    pub fn state_dim(&self) -> usize {
        self.mlp.layers.last().unwrap().out_dim()
    }
}

impl VectorField for MlpField {
    fn eval(&self, s: f32, z: &Tensor) -> Tensor {
        let b = z.shape()[0];
        let feats = self.time_mode.features(s);
        let fcols = feats.len();
        let ft = Tensor::from_fn(&[b, fcols], |i| feats[i % fcols]);
        let x = Tensor::hcat(&[z, &ft]).expect("hcat");
        self.mlp.forward(&x).expect("mlp forward")
    }

    fn macs(&self) -> u64 {
        self.mlp.macs()
    }
}

/// DepthCat conv field on NCHW states (appendix C.2 shape).
#[derive(Clone, Debug)]
pub struct ConvField {
    pub c1: Conv2d,
    pub c2: Conv2d,
    pub c3: Conv2d,
}

impl ConvField {
    pub fn from_json(v: &Value) -> Result<ConvField> {
        Ok(ConvField {
            c1: Conv2d::from_json(v.req("c1")?)?,
            c2: Conv2d::from_json(v.req("c2")?)?,
            c3: Conv2d::from_json(v.req("c3")?)?,
        })
    }
}

impl VectorField for ConvField {
    fn eval(&self, s: f32, z: &Tensor) -> Tensor {
        let x = z.depth_cat(s).expect("depth_cat");
        let x = self.c1.forward(&x).expect("c1").map(f32::tanh);
        let x = x.depth_cat(s).expect("depth_cat");
        let x = self.c2.forward(&x).expect("c2").map(f32::tanh);
        self.c3.forward(&x).expect("c3")
    }

    fn macs(&self) -> u64 {
        // H from runtime shape is unknown here; expose via macs_hw
        0
    }
}

impl ConvField {
    pub fn macs_hw(&self, hw: usize) -> u64 {
        self.c1.macs(hw) + self.c2.macs(hw) + self.c3.macs(hw)
    }
}

/// g_ω for flat states: MLP over [z, dz, eps, s].
#[derive(Clone, Debug)]
pub struct HyperMlp {
    pub mlp: Mlp,
}

impl HyperMlp {
    pub fn from_json(v: &Value) -> Result<HyperMlp> {
        Ok(HyperMlp {
            mlp: Mlp::from_json(v.req("layers")?)?,
        })
    }
}

impl HyperNet for HyperMlp {
    fn eval(&self, eps: f32, s: f32, z: &Tensor, dz: &Tensor) -> Tensor {
        let b = z.shape()[0];
        let eps_col = Tensor::full(&[b, 1], eps);
        let s_col = Tensor::full(&[b, 1], s);
        let x = Tensor::hcat(&[z, dz, &eps_col, &s_col]).expect("hcat");
        self.mlp.forward(&x).expect("hyper mlp")
    }

    fn macs(&self) -> u64 {
        self.mlp.macs()
    }
}

/// g_ω for conv states: 2-layer PReLU CNN over cat(z, dz) ⊕ DepthCat(s+eps).
#[derive(Clone, Debug)]
pub struct HyperCnn {
    pub c1: Conv2d,
    pub p1: PRelu,
    pub c2: Conv2d,
}

impl HyperCnn {
    pub fn from_json(v: &Value) -> Result<HyperCnn> {
        Ok(HyperCnn {
            c1: Conv2d::from_json(v.req("c1")?)?,
            p1: PRelu::from_json(v.req("p1")?)?,
            c2: Conv2d::from_json(v.req("c2")?)?,
        })
    }

    pub fn macs_hw(&self, hw: usize) -> u64 {
        self.c1.macs(hw) + self.c2.macs(hw)
    }

    /// Channel-concat two NCHW tensors.
    fn cat_channels(a: &Tensor, b: &Tensor) -> Result<Tensor> {
        let (ba, ca, h, w) = match a.shape() {
            [b, c, h, w] => (*b, *c, *h, *w),
            s => return Err(Error::Shape(format!("cat input {s:?}"))),
        };
        let cb = b.shape()[1];
        let plane = h * w;
        let mut out = Vec::with_capacity(ba * (ca + cb) * plane);
        for bi in 0..ba {
            out.extend_from_slice(&a.data()[bi * ca * plane..(bi + 1) * ca * plane]);
            out.extend_from_slice(&b.data()[bi * cb * plane..(bi + 1) * cb * plane]);
        }
        Tensor::new(&[ba, ca + cb, h, w], out)
    }
}

impl HyperNet for HyperCnn {
    fn eval(&self, eps: f32, s: f32, z: &Tensor, dz: &Tensor) -> Tensor {
        let x = Self::cat_channels(z, dz).expect("cat");
        let x = x.depth_cat(s + eps).expect("depth_cat");
        let x = self.p1.forward(&self.c1.forward(&x).expect("c1")).expect("p1");
        self.c2.forward(&x).expect("c2")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn time_modes() {
        assert_eq!(TimeMode::Concat.features(0.3), vec![0.3]);
        let f = TimeMode::Fourier3.features(0.25);
        assert_eq!(f.len(), 6);
        assert!((f[0] - 1.0).abs() < 1e-6); // sin(π/2)
        assert!(TimeMode::from_name("poly").is_err());
    }

    #[test]
    fn mlp_field_time_dependence() {
        let v = json::parse(
            r#"{"type":"mlp_field","time_mode":"concat",
                "layers":[{"w":[[1,0],[0,1],[1,1]],"b":[0,0],"act":"id"}]}"#,
        )
        .unwrap();
        let field = MlpField::from_json(&v).unwrap();
        let z = Tensor::new(&[1, 2], vec![1.0, 2.0]).unwrap();
        // f(s, z) = [z0 + s, z1 + s]
        let out = field.eval(0.5, &z);
        assert_eq!(out.data(), &[1.5, 2.5]);
        let out0 = field.eval(0.0, &z);
        assert_eq!(out0.data(), &[1.0, 2.0]);
    }

    #[test]
    fn hyper_mlp_input_layout() {
        // weight picks out the eps column: g = eps for every output dim
        let v = json::parse(
            r#"{"layers":[{"w":[[0],[0],[0],[0],[1],[0]],"b":[0],"act":"id"}]}"#,
        )
        .unwrap();
        let g = HyperMlp::from_json(&v).unwrap();
        let z = Tensor::new(&[2, 2], vec![9.0; 4]).unwrap();
        let out = g.eval(0.25, 0.7, &z, &z);
        assert_eq!(out.shape(), &[2, 1]);
        assert_eq!(out.data(), &[0.25, 0.25]);
    }

    #[test]
    fn hyper_cnn_shapes() {
        // aug=1: input channels 2*1+1 = 3
        let v = json::parse(
            r#"{"c1":{"w":[[[[1]],[[1]],[[1]]],[[[1]],[[1]],[[1]]]],"b":[0,0]},
                "p1":{"alpha":[0.1,0.1]},
                "c2":{"w":[[[[1]],[[1]]]],"b":[0]}}"#,
        )
        .unwrap();
        let g = HyperCnn::from_json(&v).unwrap();
        let z = Tensor::full(&[1, 1, 2, 2], 1.0);
        let out = g.eval(0.1, 0.2, &z, &z);
        assert_eq!(out.shape(), &[1, 1, 2, 2]);
        // channels: z=1, dz=1, depth=0.3 → c1 out = 2.3 each (two filters),
        // prelu no-op (positive), c2 sums → 4.6
        assert!((out.data()[0] - 4.6).abs() < 1e-5);
    }
}
