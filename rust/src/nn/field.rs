//! Vector fields and hypersolver nets reconstructed from exported weights.
//!
//! Mirrors `python/compile/fields.py`: MLP field with time features, DepthCat
//! conv field, hyper MLP (input `[z, dz, eps, s]`) and hyper CNN (input
//! `cat(z, dz) ⊕ DepthCat(s + eps)`).

use crate::nn::layers::{Conv2d, Mlp, PRelu};
use crate::ode::VectorField;
use crate::solvers::HyperNet;
use crate::tensor::{Tensor, Workspace};
use crate::util::json::{self, Value};
use crate::{Error, Result};

/// Depth (time) feature modes — must match `fields.time_features`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TimeMode {
    /// raw s appended as one feature
    Concat,
    /// sin/cos(2πks), k = 1..3
    Fourier3,
}

impl TimeMode {
    pub fn from_name(name: &str) -> Result<TimeMode> {
        match name {
            "concat" => Ok(TimeMode::Concat),
            "fourier3" => Ok(TimeMode::Fourier3),
            _ => Err(Error::Json(format!("unknown time mode {name:?}"))),
        }
    }

    /// The name [`from_name`](Self::from_name) parses.
    pub fn name(self) -> &'static str {
        match self {
            TimeMode::Concat => "concat",
            TimeMode::Fourier3 => "fourier3",
        }
    }

    pub fn dim(self) -> usize {
        match self {
            TimeMode::Concat => 1,
            TimeMode::Fourier3 => 6,
        }
    }

    pub fn features(self, s: f32) -> Vec<f32> {
        let mut out = vec![0.0; self.dim()];
        self.features_into(s, &mut out);
        out
    }

    /// [`features`](Self::features) into a caller slice of length
    /// [`dim`](Self::dim) — lets the hot path use a stack array.
    pub fn features_into(self, s: f32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.dim());
        match self {
            TimeMode::Concat => out[0] = s,
            TimeMode::Fourier3 => {
                for k in 1..=3usize {
                    out[k - 1] = (2.0 * std::f32::consts::PI * k as f32 * s).sin();
                    out[k + 2] = (2.0 * std::f32::consts::PI * k as f32 * s).cos();
                }
            }
        }
    }
}

/// Assemble the [`MlpField`] input rows `[z, timefeat(s)]` into `x`
/// (B, d + mode.dim()), fully overwritten. The single definition of the
/// field feature layout: `MlpField::eval_into` (serving) and `train::grad`
/// (training) both call this, so the two sides cannot drift apart.
pub fn field_input_into(mode: TimeMode, s: f32, z: &Tensor, x: &mut Tensor) -> Result<()> {
    let (b, d) = match z.shape() {
        [b, d] => (*b, *d),
        sh => return Err(Error::Shape(format!("field input state {sh:?}"))),
    };
    let fdim = mode.dim();
    let w = d + fdim;
    if x.shape() != [b, w] {
        return Err(Error::Shape(format!(
            "field_input_into out shape {:?}, want {:?}",
            x.shape(),
            [b, w]
        )));
    }
    let mut feats = [0.0f32; 6]; // max dim() across modes
    mode.features_into(s, &mut feats[..fdim]);
    let xd = x.data_mut();
    let zd = z.data();
    for i in 0..b {
        xd[i * w..i * w + d].copy_from_slice(&zd[i * d..(i + 1) * d]);
        xd[i * w + d..(i + 1) * w].copy_from_slice(&feats[..fdim]);
    }
    Ok(())
}

/// Assemble the [`HyperMlp`] input rows `[z, dz, eps, s]` into `x`
/// (B, 2d + 2), fully overwritten. Like [`field_input_into`], this is the
/// single definition of the hyper feature layout, shared by
/// `HyperMlp::eval_into` and the trainer.
pub fn hyper_input_into(
    eps: f32,
    s: f32,
    z: &Tensor,
    dz: &Tensor,
    x: &mut Tensor,
) -> Result<()> {
    let (b, d) = match z.shape() {
        [b, d] => (*b, *d),
        sh => return Err(Error::Shape(format!("hyper input state {sh:?}"))),
    };
    if dz.shape() != z.shape() {
        return Err(Error::Shape("hyper input dz shape".into()));
    }
    let w = 2 * d + 2;
    if x.shape() != [b, w] {
        return Err(Error::Shape(format!(
            "hyper_input_into out shape {:?}, want {:?}",
            x.shape(),
            [b, w]
        )));
    }
    let xd = x.data_mut();
    let (zd, dzd) = (z.data(), dz.data());
    for i in 0..b {
        xd[i * w..i * w + d].copy_from_slice(&zd[i * d..(i + 1) * d]);
        xd[i * w + d..i * w + 2 * d].copy_from_slice(&dzd[i * d..(i + 1) * d]);
        xd[i * w + 2 * d] = eps;
        xd[i * w + 2 * d + 1] = s;
    }
    Ok(())
}

/// f(s, z) = MLP([z, timefeat(s)]) on (B, D) states.
#[derive(Clone, Debug)]
pub struct MlpField {
    pub mlp: Mlp,
    pub time_mode: TimeMode,
}

impl MlpField {
    pub fn from_json(v: &Value) -> Result<MlpField> {
        let time_mode = TimeMode::from_name(
            v.req("time_mode")?
                .as_str()
                .ok_or_else(|| Error::Json("time_mode".into()))?,
        )?;
        Ok(MlpField {
            mlp: Mlp::from_json(v.req("layers")?)?,
            time_mode,
        })
    }

    pub fn state_dim(&self) -> usize {
        self.mlp.layers.last().unwrap().out_dim()
    }

    /// Export as the weights-JSON object [`from_json`](Self::from_json)
    /// parses.
    pub fn to_json(&self) -> Value {
        json::obj(vec![
            ("time_mode", json::s(self.time_mode.name())),
            ("layers", self.mlp.to_json()),
        ])
    }
}

impl VectorField for MlpField {
    fn eval(&self, s: f32, z: &Tensor) -> Tensor {
        let b = z.shape()[0];
        let feats = self.time_mode.features(s);
        let fcols = feats.len();
        let ft = Tensor::from_fn(&[b, fcols], |i| feats[i % fcols]);
        let x = Tensor::hcat(&[z, &ft]).expect("hcat");
        self.mlp.forward(&x).expect("mlp forward")
    }

    fn eval_into(&self, s: f32, z: &Tensor, out: &mut Tensor, ws: &mut Workspace) {
        let (b, d) = (z.shape()[0], z.shape()[1]);
        let mut x = ws.take_tensor(&[b, d + self.time_mode.dim()]);
        field_input_into(self.time_mode, s, z, &mut x).expect("field input assembly");
        if self.mlp.forward_into(&x, out, ws).is_err() {
            // misbehaving export (e.g. final out_dim != state dim): hand
            // the pure result through so the solver surfaces Err(Shape),
            // as the pre-workspace path did
            *out = self.mlp.forward(&x).expect("mlp forward");
        }
        ws.give_tensor(x);
    }

    fn macs(&self) -> u64 {
        self.mlp.macs()
    }
}

/// DepthCat conv field on NCHW states (appendix C.2 shape).
#[derive(Clone, Debug)]
pub struct ConvField {
    pub c1: Conv2d,
    pub c2: Conv2d,
    pub c3: Conv2d,
}

impl ConvField {
    pub fn from_json(v: &Value) -> Result<ConvField> {
        Ok(ConvField {
            c1: Conv2d::from_json(v.req("c1")?)?,
            c2: Conv2d::from_json(v.req("c2")?)?,
            c3: Conv2d::from_json(v.req("c3")?)?,
        })
    }
}

impl VectorField for ConvField {
    fn eval(&self, s: f32, z: &Tensor) -> Tensor {
        let x = z.depth_cat(s).expect("depth_cat");
        let x = self.c1.forward(&x).expect("c1").map(f32::tanh);
        let x = x.depth_cat(s).expect("depth_cat");
        let x = self.c2.forward(&x).expect("c2").map(f32::tanh);
        self.c3.forward(&x).expect("c3")
    }

    fn eval_into(&self, s: f32, z: &Tensor, out: &mut Tensor, ws: &mut Workspace) {
        let (b, c, h, w) = match z.shape() {
            [b, c, h, w] => (*b, *c, *h, *w),
            s => panic!("conv field state {s:?}"),
        };
        let c1_out = self.c1.w.shape()[0];
        let c2_out = self.c2.w.shape()[0];

        let mut x0 = ws.take_tensor(&[b, c + 1, h, w]);
        z.depth_cat_into(s, &mut x0).expect("depth_cat");
        let mut a1 = ws.take_tensor(&[b, c1_out, h, w]);
        self.c1.forward_into(&x0, &mut a1, ws).expect("c1");
        a1.map_inplace(f32::tanh);
        ws.give_tensor(x0);

        let mut x1 = ws.take_tensor(&[b, c1_out + 1, h, w]);
        a1.depth_cat_into(s, &mut x1).expect("depth_cat");
        ws.give_tensor(a1);
        let mut a2 = ws.take_tensor(&[b, c2_out, h, w]);
        self.c2.forward_into(&x1, &mut a2, ws).expect("c2");
        a2.map_inplace(f32::tanh);
        ws.give_tensor(x1);

        if self.c3.forward_into(&a2, out, ws).is_err() {
            // wrong c3 output channels: pass the pure result through so
            // the solver reports Err(Shape) instead of panicking a worker
            *out = self.c3.forward(&a2).expect("c3");
        }
        ws.give_tensor(a2);
    }

    fn macs(&self) -> u64 {
        // H from runtime shape is unknown here; expose via macs_hw
        0
    }
}

impl ConvField {
    pub fn macs_hw(&self, hw: usize) -> u64 {
        self.c1.macs(hw) + self.c2.macs(hw) + self.c3.macs(hw)
    }
}

/// g_ω for flat states: MLP over [z, dz, eps, s].
#[derive(Clone, Debug)]
pub struct HyperMlp {
    pub mlp: Mlp,
}

impl HyperMlp {
    pub fn from_json(v: &Value) -> Result<HyperMlp> {
        Ok(HyperMlp {
            mlp: Mlp::from_json(v.req("layers")?)?,
        })
    }

    /// Export as the weights-JSON object [`from_json`](Self::from_json)
    /// parses — what `train::export_trained` writes.
    pub fn to_json(&self) -> Value {
        json::obj(vec![("layers", self.mlp.to_json())])
    }

    /// Total trainable scalars (delegates to the [`Mlp`] flat view).
    pub fn param_count(&self) -> usize {
        self.mlp.param_count()
    }

    /// Append every parameter to `out` in flat-view order.
    pub fn write_params(&self, out: &mut Vec<f32>) {
        self.mlp.write_params(out)
    }

    /// Overwrite all parameters from a flat view; returns scalars consumed.
    pub fn read_params(&mut self, src: &[f32]) -> usize {
        self.mlp.read_params(src)
    }
}

impl HyperNet for HyperMlp {
    fn eval(&self, eps: f32, s: f32, z: &Tensor, dz: &Tensor) -> Tensor {
        let b = z.shape()[0];
        let eps_col = Tensor::full(&[b, 1], eps);
        let s_col = Tensor::full(&[b, 1], s);
        let x = Tensor::hcat(&[z, dz, &eps_col, &s_col]).expect("hcat");
        self.mlp.forward(&x).expect("hyper mlp")
    }

    fn eval_into(
        &self,
        eps: f32,
        s: f32,
        z: &Tensor,
        dz: &Tensor,
        out: &mut Tensor,
        ws: &mut Workspace,
    ) {
        let (b, d) = (z.shape()[0], z.shape()[1]);
        let mut x = ws.take_tensor(&[b, 2 * d + 2]);
        hyper_input_into(eps, s, z, dz, &mut x).expect("hyper input assembly");
        if self.mlp.forward_into(&x, out, ws).is_err() {
            // wrong hyper out_dim: pure result through → solver Err(Shape)
            *out = self.mlp.forward(&x).expect("hyper mlp");
        }
        ws.give_tensor(x);
    }

    fn macs(&self) -> u64 {
        self.mlp.macs()
    }
}

/// g_ω for conv states: 2-layer PReLU CNN over cat(z, dz) ⊕ DepthCat(s+eps).
#[derive(Clone, Debug)]
pub struct HyperCnn {
    pub c1: Conv2d,
    pub p1: PRelu,
    pub c2: Conv2d,
}

impl HyperCnn {
    pub fn from_json(v: &Value) -> Result<HyperCnn> {
        Ok(HyperCnn {
            c1: Conv2d::from_json(v.req("c1")?)?,
            p1: PRelu::from_json(v.req("p1")?)?,
            c2: Conv2d::from_json(v.req("c2")?)?,
        })
    }

    pub fn macs_hw(&self, hw: usize) -> u64 {
        self.c1.macs(hw) + self.c2.macs(hw)
    }

    /// Channel-concat two NCHW tensors.
    fn cat_channels(a: &Tensor, b: &Tensor) -> Result<Tensor> {
        let (ba, ca, h, w) = match a.shape() {
            [b, c, h, w] => (*b, *c, *h, *w),
            s => return Err(Error::Shape(format!("cat input {s:?}"))),
        };
        let cb = b.shape()[1];
        let plane = h * w;
        let mut out = Vec::with_capacity(ba * (ca + cb) * plane);
        for bi in 0..ba {
            out.extend_from_slice(&a.data()[bi * ca * plane..(bi + 1) * ca * plane]);
            out.extend_from_slice(&b.data()[bi * cb * plane..(bi + 1) * cb * plane]);
        }
        Tensor::new(&[ba, ca + cb, h, w], out)
    }
}

impl HyperNet for HyperCnn {
    fn eval(&self, eps: f32, s: f32, z: &Tensor, dz: &Tensor) -> Tensor {
        let x = Self::cat_channels(z, dz).expect("cat");
        let x = x.depth_cat(s + eps).expect("depth_cat");
        let x = self.p1.forward(&self.c1.forward(&x).expect("c1")).expect("p1");
        self.c2.forward(&x).expect("c2")
    }

    fn eval_into(
        &self,
        eps: f32,
        s: f32,
        z: &Tensor,
        dz: &Tensor,
        out: &mut Tensor,
        ws: &mut Workspace,
    ) {
        let (b, c, h, w) = match z.shape() {
            [b, c, h, w] => (*b, *c, *h, *w),
            s => panic!("hyper cnn state {s:?}"),
        };
        let plane = h * w;
        // cat(z, dz) ⊕ DepthCat(s + eps), assembled in one pass
        let mut cat = ws.take_tensor(&[b, 2 * c + 1, h, w]);
        {
            let cd = cat.data_mut();
            let (zd, dzd) = (z.data(), dz.data());
            let stride = (2 * c + 1) * plane;
            for bi in 0..b {
                let base = bi * stride;
                cd[base..base + c * plane]
                    .copy_from_slice(&zd[bi * c * plane..(bi + 1) * c * plane]);
                cd[base + c * plane..base + 2 * c * plane]
                    .copy_from_slice(&dzd[bi * c * plane..(bi + 1) * c * plane]);
                cd[base + 2 * c * plane..base + stride].fill(s + eps);
            }
        }
        let c1_out = self.c1.w.shape()[0];
        let mut a1 = ws.take_tensor(&[b, c1_out, h, w]);
        self.c1.forward_into(&cat, &mut a1, ws).expect("c1");
        ws.give_tensor(cat);
        self.p1.forward_inplace(&mut a1).expect("p1");
        if self.c2.forward_into(&a1, out, ws).is_err() {
            // wrong c2 output channels: pure result through → solver Err
            *out = self.c2.forward(&a1).expect("c2");
        }
        ws.give_tensor(a1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn time_modes() {
        assert_eq!(TimeMode::Concat.features(0.3), vec![0.3]);
        let f = TimeMode::Fourier3.features(0.25);
        assert_eq!(f.len(), 6);
        assert!((f[0] - 1.0).abs() < 1e-6); // sin(π/2)
        assert!(TimeMode::from_name("poly").is_err());
    }

    #[test]
    fn mlp_field_time_dependence() {
        let v = json::parse(
            r#"{"type":"mlp_field","time_mode":"concat",
                "layers":[{"w":[[1,0],[0,1],[1,1]],"b":[0,0],"act":"id"}]}"#,
        )
        .unwrap();
        let field = MlpField::from_json(&v).unwrap();
        let z = Tensor::new(&[1, 2], vec![1.0, 2.0]).unwrap();
        // f(s, z) = [z0 + s, z1 + s]
        let out = field.eval(0.5, &z);
        assert_eq!(out.data(), &[1.5, 2.5]);
        let out0 = field.eval(0.0, &z);
        assert_eq!(out0.data(), &[1.0, 2.0]);
    }

    #[test]
    fn hyper_mlp_input_layout() {
        // weight picks out the eps column: g = eps for every output dim
        let v = json::parse(
            r#"{"layers":[{"w":[[0],[0],[0],[0],[1],[0]],"b":[0],"act":"id"}]}"#,
        )
        .unwrap();
        let g = HyperMlp::from_json(&v).unwrap();
        let z = Tensor::new(&[2, 2], vec![9.0; 4]).unwrap();
        let out = g.eval(0.25, 0.7, &z, &z);
        assert_eq!(out.shape(), &[2, 1]);
        assert_eq!(out.data(), &[0.25, 0.25]);
    }

    #[test]
    fn field_eval_into_matches_eval() {
        let v = json::parse(
            r#"{"type":"mlp_field","time_mode":"fourier3",
                "layers":[{"w":[[0.5,0.1],[0.2,0.3],[0.1,0.0],[0.0,0.1],
                                [0.2,0.2],[0.3,0.1],[0.1,0.3],[0.2,0.0]],
                           "b":[0.05,-0.05],"act":"tanh"}]}"#,
        )
        .unwrap();
        let field = MlpField::from_json(&v).unwrap();
        let z = Tensor::new(&[2, 2], vec![0.4, -0.8, 1.2, 0.1]).unwrap();
        let mut ws = Workspace::new();
        for s in [0.0, 0.31, 0.9] {
            let pure = field.eval(s, &z);
            let mut out = Tensor::full(&[2, 2], f32::NAN);
            field.eval_into(s, &z, &mut out, &mut ws);
            assert_eq!(out.data(), pure.data(), "s={s}");
        }
    }

    #[test]
    fn hyper_mlp_eval_into_matches_eval() {
        let v = json::parse(
            r#"{"layers":[{"w":[[0.1],[0.2],[0.3],[0.4],[0.5],[0.6]],
                           "b":[0.01],"act":"id"}]}"#,
        )
        .unwrap();
        let g = HyperMlp::from_json(&v).unwrap();
        let z = Tensor::new(&[2, 2], vec![1.0, -1.0, 0.5, 2.0]).unwrap();
        let dz = Tensor::new(&[2, 2], vec![0.3, 0.7, -0.2, 0.9]).unwrap();
        let mut ws = Workspace::new();
        let pure = g.eval(0.125, 0.5, &z, &dz);
        let mut out = Tensor::full(&[2, 1], f32::NAN);
        g.eval_into(0.125, 0.5, &z, &dz, &mut out, &mut ws);
        assert_eq!(out.data(), pure.data());
    }

    #[test]
    fn hyper_cnn_shapes() {
        // aug=1: input channels 2*1+1 = 3
        let v = json::parse(
            r#"{"c1":{"w":[[[[1]],[[1]],[[1]]],[[[1]],[[1]],[[1]]]],"b":[0,0]},
                "p1":{"alpha":[0.1,0.1]},
                "c2":{"w":[[[[1]],[[1]]]],"b":[0]}}"#,
        )
        .unwrap();
        let g = HyperCnn::from_json(&v).unwrap();
        let z = Tensor::full(&[1, 1, 2, 2], 1.0);
        let out = g.eval(0.1, 0.2, &z, &z);
        assert_eq!(out.shape(), &[1, 1, 2, 2]);
        // channels: z=1, dz=1, depth=0.3 → c1 out = 2.3 each (two filters),
        // prelu no-op (positive), c2 sums → 4.6
        assert!((out.data()[0] - 4.6).abs() < 1e-5);

        // the workspace path must agree bit-for-bit
        let mut ws = Workspace::new();
        let dz = Tensor::new(&[1, 1, 2, 2], vec![0.5, -0.5, 1.5, -1.5]).unwrap();
        let pure = g.eval(0.1, 0.2, &z, &dz);
        let mut into = Tensor::full(&[1, 1, 2, 2], f32::NAN);
        g.eval_into(0.1, 0.2, &z, &dz, &mut into, &mut ws);
        assert_eq!(into.data(), pure.data());
    }

    #[test]
    fn conv_field_eval_into_matches_eval() {
        // 2-channel state, 3x3 kernels, nontrivial weights
        let mk_w = |cout: usize, cin: usize, seed: f32| -> String {
            let mut s = String::from("[");
            for oc in 0..cout {
                if oc > 0 {
                    s.push(',');
                }
                s.push('[');
                for ic in 0..cin {
                    if ic > 0 {
                        s.push(',');
                    }
                    s.push('[');
                    for ky in 0..3 {
                        if ky > 0 {
                            s.push(',');
                        }
                        s.push('[');
                        for kx in 0..3 {
                            if kx > 0 {
                                s.push(',');
                            }
                            let v = seed
                                * (1.0 + oc as f32 - 0.5 * ic as f32
                                    + 0.25 * ky as f32
                                    - 0.125 * kx as f32);
                            s.push_str(&format!("{v}"));
                        }
                        s.push(']');
                    }
                    s.push(']');
                }
                s.push(']');
            }
            s.push(']');
            s
        };
        let json_text = format!(
            r#"{{"c1":{{"w":{},"b":[0.1,0.2]}},
                "c2":{{"w":{},"b":[-0.1,0.05]}},
                "c3":{{"w":{},"b":[0.0,0.0]}}}}"#,
            mk_w(2, 3, 0.1),
            mk_w(2, 3, -0.07),
            mk_w(2, 2, 0.05),
        );
        let field = ConvField::from_json(&json::parse(&json_text).unwrap()).unwrap();
        let z = Tensor::from_fn(&[2, 2, 4, 4], |i| (i as f32 * 0.37).sin());
        let mut ws = Workspace::new();
        for s in [0.0, 0.45] {
            let pure = field.eval(s, &z);
            let mut out = Tensor::full(&[2, 2, 4, 4], f32::NAN);
            field.eval_into(s, &z, &mut out, &mut ws);
            assert_eq!(out.data(), pure.data(), "s={s}");
        }
    }
}
