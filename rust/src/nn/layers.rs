//! Primitive layers: dense, conv2d (SAME/stride-1), PReLU, activations.
//!
//! Each layer has a `forward_into` / `forward_inplace` twin that writes
//! into caller-provided storage ([`Workspace`]-drawn on the solver hot
//! path); the pure `forward` APIs are thin wrappers, so both paths produce
//! bit-identical values.

use crate::tensor::{Tensor, Workspace};
use crate::util::json::{self, Value};
use crate::{Error, Result};

/// Activation kinds matching `compile/kernels/ref.py::act`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    Id,
    Tanh,
    Relu,
    Softplus,
}

impl Act {
    pub fn from_name(name: &str) -> Result<Act> {
        match name {
            "id" => Ok(Act::Id),
            "tanh" => Ok(Act::Tanh),
            "relu" => Ok(Act::Relu),
            "softplus" => Ok(Act::Softplus),
            _ => Err(Error::Json(format!("unknown activation {name:?}"))),
        }
    }

    /// The name [`from_name`](Self::from_name) parses — the serialization
    /// round trip.
    pub fn name(self) -> &'static str {
        match self {
            Act::Id => "id",
            Act::Tanh => "tanh",
            Act::Relu => "relu",
            Act::Softplus => "softplus",
        }
    }

    /// d act/dx at pre-activation `pre`, with `post = act(pre)` supplied so
    /// tanh can use the cheaper 1 − y² form. Backs the reverse-mode passes
    /// in `train::grad` (finite-difference-checked there).
    pub fn grad_scalar(self, pre: f32, post: f32) -> f32 {
        match self {
            Act::Id => 1.0,
            Act::Tanh => 1.0 - post * post,
            Act::Relu => {
                if pre > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            // σ(x), numerically stable on both tails
            Act::Softplus => {
                if pre >= 0.0 {
                    1.0 / (1.0 + (-pre).exp())
                } else {
                    let e = pre.exp();
                    e / (1.0 + e)
                }
            }
        }
    }

    pub fn apply_scalar(self, x: f32) -> f32 {
        match self {
            Act::Id => x,
            Act::Tanh => x.tanh(),
            Act::Relu => x.max(0.0),
            // log(1 + e^x), numerically stable
            Act::Softplus => {
                if x > 20.0 {
                    x
                } else if x < -20.0 {
                    x.exp()
                } else {
                    x.exp().ln_1p()
                }
            }
        }
    }

    pub fn apply(self, t: &Tensor) -> Tensor {
        match self {
            Act::Id => t.clone(),
            _ => t.map(|x| self.apply_scalar(x)),
        }
    }

    /// In-place [`apply`](Self::apply) (no-op for `Id`).
    pub fn apply_inplace(self, t: &mut Tensor) {
        if self != Act::Id {
            t.map_inplace(|x| self.apply_scalar(x));
        }
    }
}

/// Dense layer y = act(x W + b); weights (in, out) row-major as exported.
#[derive(Clone, Debug)]
pub struct Linear {
    pub w: Tensor,
    pub b: Vec<f32>,
    pub act: Act,
}

impl Linear {
    pub fn from_json(v: &Value) -> Result<Linear> {
        let (wdata, wshape) = v.req("w")?.as_f32_tensor()?;
        if wshape.len() != 2 {
            return Err(Error::Json(format!("linear w shape {wshape:?}")));
        }
        let (b, _) = v.req("b")?.as_f32_tensor()?;
        let act = Act::from_name(v.req("act")?.as_str().unwrap_or("id"))?;
        Ok(Linear {
            w: Tensor::new(&wshape, wdata)?,
            b,
            act,
        })
    }

    pub fn forward(&self, x: &Tensor) -> Result<Tensor> {
        let mut out = Tensor::zeros(&[x.shape()[0], self.out_dim()]);
        self.forward_into(x, &mut out)?;
        Ok(out)
    }

    /// [`forward`](Self::forward) writing into `out` (shape (B, out_dim),
    /// fully overwritten). Needs no scratch: matmul, bias, and activation
    /// all run on `out` directly.
    pub fn forward_into(&self, x: &Tensor, out: &mut Tensor) -> Result<()> {
        x.matmul_into(&self.w, out)?;
        out.add_bias_rows_inplace(&self.b)?;
        self.act.apply_inplace(out);
        Ok(())
    }

    pub fn in_dim(&self) -> usize {
        self.w.shape()[0]
    }

    pub fn out_dim(&self) -> usize {
        self.w.shape()[1]
    }

    /// MACs per sample.
    pub fn macs(&self) -> u64 {
        (self.in_dim() * self.out_dim()) as u64
    }

    // -- trainable-parameter flat view (w row-major, then b) ---------------

    /// Number of trainable scalars.
    pub fn param_count(&self) -> usize {
        self.w.numel() + self.b.len()
    }

    /// Append every parameter to `out` in flat-view order.
    pub fn write_params(&self, out: &mut Vec<f32>) {
        out.extend_from_slice(self.w.data());
        out.extend_from_slice(&self.b);
    }

    /// Overwrite parameters from the head of a flat view; returns the
    /// number of scalars consumed. Panics if `src` is shorter than
    /// [`param_count`](Self::param_count) (the optimizer sizes its buffers
    /// from the same count, so a mismatch is a caller bug).
    pub fn read_params(&mut self, src: &[f32]) -> usize {
        let nw = self.w.numel();
        let nb = self.b.len();
        self.w.data_mut().copy_from_slice(&src[..nw]);
        self.b.copy_from_slice(&src[nw..nw + nb]);
        nw + nb
    }

    /// Export as the weights-JSON object [`from_json`](Self::from_json)
    /// parses (nested `w` rows, `b`, activation name).
    pub fn to_json(&self) -> Value {
        let (din, dout) = (self.in_dim(), self.out_dim());
        let rows = (0..din)
            .map(|i| {
                Value::Arr(
                    (0..dout)
                        .map(|j| Value::Num(self.w.data()[i * dout + j] as f64))
                        .collect(),
                )
            })
            .collect();
        json::obj(vec![
            ("w", Value::Arr(rows)),
            ("b", json::arr_f32(&self.b)),
            ("act", json::s(self.act.name())),
        ])
    }
}

/// 2-D conv, NCHW/OIHW, stride 1, SAME padding (the only conv exported).
#[derive(Clone, Debug)]
pub struct Conv2d {
    pub w: Tensor,
    pub b: Vec<f32>,
}

impl Conv2d {
    pub fn from_json(v: &Value) -> Result<Conv2d> {
        let (wdata, wshape) = v.req("w")?.as_f32_tensor()?;
        if wshape.len() != 4 {
            return Err(Error::Json(format!("conv w shape {wshape:?}")));
        }
        let (b, _) = v.req("b")?.as_f32_tensor()?;
        Ok(Conv2d {
            w: Tensor::new(&wshape, wdata)?,
            b,
        })
    }

    pub fn forward(&self, x: &Tensor) -> Result<Tensor> {
        x.conv2d_same(&self.w, &self.b)
    }

    /// [`forward`](Self::forward) writing into `out` (shape
    /// (B, Cout, H, W)); im2col scratch comes from `ws`.
    pub fn forward_into(&self, x: &Tensor, out: &mut Tensor, ws: &mut Workspace) -> Result<()> {
        x.conv2d_same_into(&self.w, &self.b, out, ws)
    }

    /// MACs per sample for an (H, W) input.
    pub fn macs(&self, hw: usize) -> u64 {
        let s = self.w.shape();
        (s[0] * s[1] * s[2] * s[3] * hw * hw) as u64
    }

    /// Number of trainable scalars (w then b — the flat-view order).
    pub fn param_count(&self) -> usize {
        self.w.numel() + self.b.len()
    }

    /// Append every parameter to `out` in flat-view order.
    pub fn write_params(&self, out: &mut Vec<f32>) {
        out.extend_from_slice(self.w.data());
        out.extend_from_slice(&self.b);
    }

    /// Overwrite parameters from the head of a flat view; returns scalars
    /// consumed (see [`Linear::read_params`] for the length contract).
    pub fn read_params(&mut self, src: &[f32]) -> usize {
        let nw = self.w.numel();
        let nb = self.b.len();
        self.w.data_mut().copy_from_slice(&src[..nw]);
        self.b.copy_from_slice(&src[nw..nw + nb]);
        nw + nb
    }
}

/// Channelwise PReLU on NCHW tensors.
#[derive(Clone, Debug)]
pub struct PRelu {
    pub alpha: Vec<f32>,
}

impl PRelu {
    pub fn from_json(v: &Value) -> Result<PRelu> {
        let (alpha, _) = v.req("alpha")?.as_f32_tensor()?;
        Ok(PRelu { alpha })
    }

    pub fn forward(&self, x: &Tensor) -> Result<Tensor> {
        let mut out = x.clone();
        self.forward_inplace(&mut out)?;
        Ok(out)
    }

    /// In-place [`forward`](Self::forward).
    pub fn forward_inplace(&self, x: &mut Tensor) -> Result<()> {
        let (b, c, h, w) = match x.shape() {
            [b, c, h, w] => (*b, *c, *h, *w),
            s => return Err(Error::Shape(format!("prelu input {s:?}"))),
        };
        if c != self.alpha.len() {
            return Err(Error::Shape("prelu channel mismatch".into()));
        }
        let plane = h * w;
        for bi in 0..b {
            for ci in 0..c {
                let a = self.alpha[ci];
                let base = (bi * c + ci) * plane;
                for v in &mut x.data_mut()[base..base + plane] {
                    if *v < 0.0 {
                        *v *= a;
                    }
                }
            }
        }
        Ok(())
    }

    /// Number of trainable scalars (the per-channel slopes).
    pub fn param_count(&self) -> usize {
        self.alpha.len()
    }

    /// Append every parameter to `out`.
    pub fn write_params(&self, out: &mut Vec<f32>) {
        out.extend_from_slice(&self.alpha);
    }

    /// Overwrite parameters from the head of a flat view; returns scalars
    /// consumed.
    pub fn read_params(&mut self, src: &[f32]) -> usize {
        let n = self.alpha.len();
        self.alpha.copy_from_slice(&src[..n]);
        n
    }
}

/// An MLP as a stack of [`Linear`]s.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub layers: Vec<Linear>,
}

impl Mlp {
    pub fn from_json(v: &Value) -> Result<Mlp> {
        let arr = v
            .as_arr()
            .ok_or_else(|| Error::Json("mlp layers must be array".into()))?;
        Ok(Mlp {
            layers: arr.iter().map(Linear::from_json).collect::<Result<_>>()?,
        })
    }

    pub fn forward(&self, x: &Tensor) -> Result<Tensor> {
        let mut h = x.clone();
        for l in &self.layers {
            h = l.forward(&h)?;
        }
        Ok(h)
    }

    /// [`forward`](Self::forward) writing the last layer's output into
    /// `out` (shape (B, last out_dim)); intermediate activations ping-pong
    /// through `ws`, so a warm workspace makes the whole pass
    /// allocation-free.
    pub fn forward_into(&self, x: &Tensor, out: &mut Tensor, ws: &mut Workspace) -> Result<()> {
        match self.layers.as_slice() {
            [] => {
                if out.shape() != x.shape() {
                    return Err(Error::Shape(format!(
                        "empty mlp out shape {:?} vs input {:?}",
                        out.shape(),
                        x.shape()
                    )));
                }
                out.copy_from(x);
                Ok(())
            }
            [only] => only.forward_into(x, out),
            [first, mid @ .., last] => {
                let b = x.shape()[0];
                let mut cur = ws.take_tensor(&[b, first.out_dim()]);
                first.forward_into(x, &mut cur)?;
                for l in mid {
                    let mut next = ws.take_tensor(&[b, l.out_dim()]);
                    l.forward_into(&cur, &mut next)?;
                    ws.give_tensor(cur);
                    cur = next;
                }
                last.forward_into(&cur, out)?;
                ws.give_tensor(cur);
                Ok(())
            }
        }
    }

    pub fn macs(&self) -> u64 {
        self.layers.iter().map(Linear::macs).sum()
    }

    /// Total trainable scalars across all layers.
    pub fn param_count(&self) -> usize {
        self.layers.iter().map(Linear::param_count).sum()
    }

    /// Append every parameter to `out`, layer by layer (each layer in
    /// [`Linear::write_params`] order) — the canonical flat-view layout the
    /// trainer's optimizer and `train::grad::MlpGrads::write_flat` share.
    pub fn write_params(&self, out: &mut Vec<f32>) {
        for l in &self.layers {
            l.write_params(out);
        }
    }

    /// Overwrite all parameters from a flat view; returns scalars consumed.
    pub fn read_params(&mut self, src: &[f32]) -> usize {
        let mut off = 0;
        for l in &mut self.layers {
            off += l.read_params(&src[off..]);
        }
        off
    }

    /// Export as the weights-JSON array [`from_json`](Self::from_json)
    /// parses.
    pub fn to_json(&self) -> Value {
        Value::Arr(self.layers.iter().map(Linear::to_json).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn act_values() {
        assert_eq!(Act::Relu.apply_scalar(-2.0), 0.0);
        assert_eq!(Act::Relu.apply_scalar(3.0), 3.0);
        assert!((Act::Tanh.apply_scalar(0.5) - 0.5f32.tanh()).abs() < 1e-7);
        assert!((Act::Softplus.apply_scalar(0.0) - (2.0f32).ln()).abs() < 1e-6);
        assert_eq!(Act::Softplus.apply_scalar(30.0), 30.0); // stable branch
        assert!(Act::from_name("gelu").is_err());
    }

    #[test]
    fn linear_from_json_and_forward() {
        let v = json::parse(
            r#"{"kind":"linear","w":[[1,0],[0,2]],"b":[0.5,-0.5],"act":"id"}"#,
        )
        .unwrap();
        let l = Linear::from_json(&v).unwrap();
        let x = Tensor::new(&[1, 2], vec![3.0, 4.0]).unwrap();
        let y = l.forward(&x).unwrap();
        assert_eq!(y.data(), &[3.5, 7.5]);
        assert_eq!(l.macs(), 4);
    }

    #[test]
    fn mlp_chains_activations() {
        let v = json::parse(
            r#"[{"w":[[100]],"b":[0],"act":"tanh"},{"w":[[2]],"b":[1],"act":"id"}]"#,
        )
        .unwrap();
        let mlp = Mlp::from_json(&v).unwrap();
        let y = mlp.forward(&Tensor::new(&[1, 1], vec![5.0]).unwrap()).unwrap();
        assert!((y.data()[0] - 3.0).abs() < 1e-5); // tanh(500)≈1 → 2·1+1
    }

    #[test]
    fn conv_from_json() {
        let v = json::parse(r#"{"kind":"conv2d","w":[[[[1]]]],"b":[2]}"#).unwrap();
        let c = Conv2d::from_json(&v).unwrap();
        let x = Tensor::full(&[1, 1, 2, 2], 3.0);
        let y = c.forward(&x).unwrap();
        assert_eq!(y.data(), &[5.0, 5.0, 5.0, 5.0]);
        assert_eq!(c.macs(16), 256);
    }

    #[test]
    fn prelu_channelwise() {
        let p = PRelu {
            alpha: vec![0.5, 0.0],
        };
        let x = Tensor::new(&[1, 2, 1, 1], vec![-2.0, -2.0]).unwrap();
        let y = p.forward(&x).unwrap();
        assert_eq!(y.data(), &[-1.0, 0.0]);
    }

    #[test]
    fn forward_into_matches_forward() {
        let v = json::parse(
            r#"[{"w":[[1.0,0.5],[0.25,2.0]],"b":[0.1,-0.1],"act":"tanh"},
                {"w":[[1.5],[-0.5]],"b":[0.2],"act":"softplus"},
                {"w":[[2.0,1.0]],"b":[0.0,0.3],"act":"id"}]"#,
        )
        .unwrap();
        let mlp = Mlp::from_json(&v).unwrap();
        let x = Tensor::new(&[3, 2], vec![0.5, -1.0, 2.0, 0.0, -0.25, 1.5]).unwrap();
        let pure = mlp.forward(&x).unwrap();
        let mut ws = Workspace::new();
        let mut out = Tensor::full(&[3, 2], f32::NAN);
        mlp.forward_into(&x, &mut out, &mut ws).unwrap();
        assert_eq!(out.data(), pure.data());
        // second pass on a warm workspace: identical again, pool reused
        let mut out2 = Tensor::full(&[3, 2], f32::NAN);
        mlp.forward_into(&x, &mut out2, &mut ws).unwrap();
        assert_eq!(out2.data(), pure.data());
        assert_eq!(ws.pooled_tensors(), 2, "both intermediates returned");
    }

    #[test]
    fn prelu_inplace_matches_forward() {
        let p = PRelu {
            alpha: vec![0.5, 2.0],
        };
        let x = Tensor::new(&[1, 2, 1, 2], vec![-2.0, 3.0, -1.0, -4.0]).unwrap();
        let pure = p.forward(&x).unwrap();
        let mut ip = x.clone();
        p.forward_inplace(&mut ip).unwrap();
        assert_eq!(ip.data(), pure.data());
    }

    #[test]
    fn act_grad_matches_finite_difference() {
        for act in [Act::Id, Act::Tanh, Act::Relu, Act::Softplus] {
            for &x in &[-3.0f32, -0.7, 0.4, 2.5, 15.0] {
                let h = 1e-3f32;
                let fd =
                    (act.apply_scalar(x + h) - act.apply_scalar(x - h)) / (2.0 * h);
                let an = act.grad_scalar(x, act.apply_scalar(x));
                assert!(
                    (an - fd).abs() < 1e-3,
                    "{:?} at {x}: analytic {an} vs fd {fd}",
                    act
                );
            }
        }
    }

    #[test]
    fn mlp_json_roundtrip_preserves_forward() {
        let v = json::parse(
            r#"[{"w":[[0.5,-1.25],[2.0,0.125]],"b":[0.1,-0.2],"act":"tanh"},
                {"w":[[1.5],[-0.75]],"b":[0.25],"act":"softplus"}]"#,
        )
        .unwrap();
        let mlp = Mlp::from_json(&v).unwrap();
        let back = Mlp::from_json(&json::parse(&json::to_string(&mlp.to_json())).unwrap())
            .unwrap();
        let x = Tensor::new(&[2, 2], vec![0.3, -1.1, 2.0, 0.4]).unwrap();
        assert_eq!(
            mlp.forward(&x).unwrap().data(),
            back.forward(&x).unwrap().data(),
            "serialization round trip must be bit-exact on f32 weights"
        );
    }

    #[test]
    fn flat_param_views_roundtrip() {
        let v = json::parse(
            r#"[{"w":[[1,2],[3,4]],"b":[5,6],"act":"id"},
                {"w":[[7],[8]],"b":[9],"act":"relu"}]"#,
        )
        .unwrap();
        let mut mlp = Mlp::from_json(&v).unwrap();
        assert_eq!(mlp.param_count(), 9);
        let mut flat = Vec::new();
        mlp.write_params(&mut flat);
        assert_eq!(flat, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0]);
        let doubled: Vec<f32> = flat.iter().map(|x| 2.0 * x).collect();
        assert_eq!(mlp.read_params(&doubled), 9);
        let mut back = Vec::new();
        mlp.write_params(&mut back);
        assert_eq!(back, doubled);

        let mut p = PRelu {
            alpha: vec![0.25, 0.5],
        };
        assert_eq!(p.param_count(), 2);
        assert_eq!(p.read_params(&[1.0, 2.0, 99.0]), 2);
        assert_eq!(p.alpha, vec![1.0, 2.0]);
    }

    #[test]
    fn bad_json_rejected() {
        let v = json::parse(r#"{"w":[[1,2],[3]],"b":[0],"act":"id"}"#).unwrap();
        assert!(Linear::from_json(&v).is_err()); // ragged
        let v = json::parse(r#"{"w":[1,2],"b":[0],"act":"id"}"#).unwrap();
        assert!(Linear::from_json(&v).is_err()); // 1-d weights
    }
}
