//! Native inference of the python-trained networks.
//!
//! Loads the weight JSON written by `python/compile/aot.py` and evaluates
//! the exact same architectures (MLP fields with time features, DepthCat
//! conv fields, hypersolver MLP/CNN nets, image h_x/h_y heads) on
//! [`Tensor`]s. Numerics are cross-validated against both the JAX layer
//! (via exported ground-truth blobs) and the PJRT field executables
//! (integration tests).

pub mod field;
pub mod layers;
pub mod model;

pub use field::{
    field_input_into, hyper_input_into, ConvField, HyperCnn, HyperMlp, MlpField, TimeMode,
};
pub use layers::{Act, Conv2d, Linear, Mlp, PRelu};
pub use model::{AnalyticField, CnfModel, FieldNet, ImageModel, TrackingModel};
