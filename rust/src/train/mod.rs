//! In-Rust hypersolver training: residual fitting with hand-rolled
//! reverse-mode gradients, Adam, and weights export.
//!
//! This closes the paper's training loop (§3, eq. 7–8) inside the repo:
//! sample states, compute the base solver's local truncation residual
//! against a fine one-step reference, regress g_ω onto it — all on the
//! crate's own `_ws` solver kernels and [`tensor::Workspace`]-pooled
//! buffers, so training inherits the serving stack's allocation-free
//! discipline and its exact numerics (the net trains against the very
//! kernels that will serve it).
//!
//! * [`grad`] — reverse-mode backward passes for the hypernet forward
//!   stack (Linear/Mlp, activations, PReLU, input-assembly concats),
//!   finite-difference-checked in `tests/train_grad_check.rs`.
//! * [`residual`] — minibatch (s, z, ε) ↦ R(s, z, ε) target generation.
//! * [`optim`] — Adam + cosine LR schedule over flat parameter views.
//! * `loop` — the training loop (loss logging, early stopping) and
//!   [`export_trained`], which writes the weights JSON + manifest the
//!   native serving backend loads unchanged.
//!
//! The `hypertrain` binary wires this to the command line; see
//! rust/README.md §"Training hypersolvers in-repo".
//!
//! [`tensor::Workspace`]: crate::tensor::Workspace

pub mod grad;
pub mod r#loop;
pub mod optim;
pub mod residual;

pub use grad::{
    act_backward_inplace, field_input_backward, field_input_into, hyper_input_backward,
    hyper_input_into, mlp_backward, mlp_forward_cached, mse_loss, mse_loss_grad,
    prelu_backward, MlpCache, MlpGrads,
};
pub use optim::{Adam, AdamCfg, CosineSchedule};
pub use r#loop::{
    base_variant_name, export_trained, hyper_variant_name, init_hyper_mlp, serve_check,
    train_hypersolver, TrainConfig, TrainReport,
};
pub use residual::{one_step_errors, FineRef, ResidualBatch, ResidualGen, StateSampler};
