//! Residual-target minibatches for hypersolver training (paper §3, eq. 7–8).
//!
//! For a base solver ψ of order p, the local truncation residual at
//! (s, z, ε) is
//!
//! ```text
//! R(s, z, ε) = (Φ(s, z, ε) − z − ε ψ(s, z, ε)) / ε^{p+1}
//! ```
//!
//! where Φ is a fine one-step reference flow (RK4 with substeps, or
//! tight-tolerance dopri5). Regressing g_ω onto R is exactly what makes
//! the hypersolved step z + εψ + ε^{p+1} g_ω track Φ to the fit error δ —
//! the paper's residual-fitting objective, and the same residual
//! `solvers::hyper::residual` measures from ground-truth checkpoints.
//!
//! All stepping runs on the `_ws` kernels over generator-held
//! [`RkWorkspace`]s: warm target generation performs no solver-side heap
//! allocation on the RK4 path (dopri5 pays its usual per-solve result
//! clone).

use crate::data::densities;
use crate::ode::VectorField;
use crate::solvers::fixed::{combine_into, rk_stages_core};
use crate::solvers::workspace::RkWorkspace;
use crate::solvers::{
    adaptive_ws, hyper_step, odeint_fixed_traj, odeint_fixed_ws, rk_step, AdaptiveOpts,
    HyperNet, Tableau,
};
use crate::tensor::Tensor;
use crate::util::prng::Rng;
use crate::{Error, Result};

/// Where training states are drawn from.
#[derive(Clone, Debug)]
pub enum StateSampler {
    /// Uniform in `[lo, hi]^dim` — the default for analytic fields, whose
    /// interesting dynamics live in a known box.
    UniformBox { lo: f32, hi: f32, dim: usize },
    /// One of the `data::densities` toy 2-D densities (pinwheel, rings,
    /// checkerboard, circles) — matches the CNF tasks' base distributions.
    Density(String),
    /// States drawn *along base-solver trajectories of the field* — the
    /// paper's CNF setup, matching the distribution the net actually sees
    /// when serving long spans. Initial states are uniform in
    /// `[lo, hi]^dim`, integrated with the named fixed-step tableau in `k`
    /// equal steps over `span`; rows are drawn uniformly (with
    /// replacement) from the pooled mesh states. Deterministic given the
    /// `Rng`; needs the field — use
    /// [`sample_into_for`](Self::sample_into_for).
    Trajectory {
        lo: f32,
        hi: f32,
        dim: usize,
        solver: String,
        k: usize,
        span: (f32, f32),
    },
}

impl StateSampler {
    pub fn dim(&self) -> usize {
        match self {
            StateSampler::UniformBox { dim, .. } => *dim,
            StateSampler::Density(_) => 2,
            StateSampler::Trajectory { dim, .. } => *dim,
        }
    }

    /// Fill `out` (shape (n, dim)) with fresh samples. The box sampler
    /// writes in place; the density sampler draws through
    /// [`densities::sample_density`] (which allocates its result) and
    /// copies. The trajectory sampler needs the field and errors here —
    /// use [`sample_into_for`](Self::sample_into_for).
    pub fn sample_into(&self, out: &mut Tensor, rng: &mut Rng) -> Result<()> {
        let (n, d) = match out.shape() {
            [n, d] => (*n, *d),
            s => return Err(Error::Shape(format!("sample_into out {s:?}"))),
        };
        if d != self.dim() {
            return Err(Error::Shape(format!(
                "sampler dim {} vs out cols {d}",
                self.dim()
            )));
        }
        match self {
            StateSampler::UniformBox { lo, hi, .. } => {
                for v in out.data_mut() {
                    *v = rng.uniform_in(*lo as f64, *hi as f64) as f32;
                }
                Ok(())
            }
            StateSampler::Density(name) => {
                let s = densities::sample_density(name, n, rng)?;
                out.copy_from(&s);
                Ok(())
            }
            StateSampler::Trajectory { .. } => Err(Error::Other(
                "trajectory sampling needs the vector field — call \
                 sample_into_for(f, ...)"
                    .into(),
            )),
        }
    }

    /// [`sample_into`](Self::sample_into) with the field available, which
    /// every variant supports (box/density ignore `f`).
    pub fn sample_into_for<F: VectorField + ?Sized>(
        &self,
        f: &F,
        out: &mut Tensor,
        rng: &mut Rng,
    ) -> Result<()> {
        let (lo, hi, dim, solver, k, span) = match self {
            StateSampler::Trajectory {
                lo,
                hi,
                dim,
                solver,
                k,
                span,
            } => (lo, hi, dim, solver, k, span),
            other => return other.sample_into(out, rng),
        };
        let (n, d) = match out.shape() {
            [n, d] => (*n, *d),
            s => return Err(Error::Shape(format!("sample_into_for out {s:?}"))),
        };
        if d != *dim {
            return Err(Error::Shape(format!("sampler dim {dim} vs out cols {d}")));
        }
        if *k == 0 {
            return Err(Error::Other("trajectory sampler needs k > 0".into()));
        }
        let tab = Tableau::by_name(solver)?;
        // each trajectory yields k+1 mesh states; spread the batch over
        // enough independent trajectories that rows decorrelate
        let n_traj = ((n + k) / (k + 1)).max(1);
        let mut z0 = Tensor::zeros(&[n_traj, d]);
        for v in z0.data_mut() {
            *v = rng.uniform_in(*lo as f64, *hi as f64) as f32;
        }
        let traj = odeint_fixed_traj(f, &z0, *span, *k, &tab)?;
        let od = out.data_mut();
        for i in 0..n {
            let t = rng.below(*k as u64 + 1) as usize;
            let j = rng.below(n_traj as u64) as usize;
            od[i * d..(i + 1) * d].copy_from_slice(&traj[t].data()[j * d..(j + 1) * d]);
        }
        Ok(())
    }

    /// Allocating convenience wrapper over
    /// [`sample_into`](Self::sample_into).
    pub fn sample(&self, n: usize, rng: &mut Rng) -> Result<Tensor> {
        let mut out = Tensor::zeros(&[n, self.dim()]);
        self.sample_into(&mut out, rng)?;
        Ok(out)
    }

    /// Allocating convenience wrapper over
    /// [`sample_into_for`](Self::sample_into_for).
    pub fn sample_for<F: VectorField + ?Sized>(
        &self,
        f: &F,
        n: usize,
        rng: &mut Rng,
    ) -> Result<Tensor> {
        let mut out = Tensor::zeros(&[n, self.dim()]);
        self.sample_into_for(f, &mut out, rng)?;
        Ok(out)
    }
}

/// The fine one-step reference flow Φ.
#[derive(Clone, Copy, Debug)]
pub enum FineRef {
    /// RK4 with this many equal substeps over `[s, s + ε]` — cheap,
    /// deterministic NFE, error O((ε/m)⁴).
    Rk4Substeps(usize),
    /// Adaptive dopri5 at this tolerance — slower but self-validating on
    /// stiff regions.
    Dopri5Tol(f32),
}

/// One regression minibatch. (s, ε) are shared across the batch — the
/// hypernet takes scalar time/step inputs, exactly as it is evaluated
/// inside `hyper_step_core` at serving time.
#[derive(Debug)]
pub struct ResidualBatch {
    pub s: f32,
    pub eps: f32,
    /// States z (B, D).
    pub z: Tensor,
    /// First stage dz = f(s, z) (B, D) — the hypernet's second input block.
    pub dz: Tensor,
    /// Residual targets R (B, D).
    pub target: Tensor,
}

impl ResidualBatch {
    /// An empty batch; buffers are sized on the first
    /// [`ResidualGen::fill`].
    pub fn new() -> ResidualBatch {
        ResidualBatch {
            s: 0.0,
            eps: 0.0,
            z: Tensor::zeros(&[0]),
            dz: Tensor::zeros(&[0]),
            target: Tensor::zeros(&[0]),
        }
    }
}

impl Default for ResidualBatch {
    fn default() -> Self {
        Self::new()
    }
}

/// Residual-batch generator for a (field, base tableau) pair, holding the
/// solver workspaces that make repeated target computation allocation-free.
pub struct ResidualGen<'a, F: VectorField + ?Sized> {
    f: &'a F,
    pub tab: Tableau,
    fine: FineRef,
    rk4: Tableau,
    d5: Tableau,
    base_ws: RkWorkspace,
    fine_ws: RkWorkspace,
}

impl<'a, F: VectorField + ?Sized> ResidualGen<'a, F> {
    pub fn new(f: &'a F, tab: Tableau, fine: FineRef) -> ResidualGen<'a, F> {
        ResidualGen {
            f,
            tab,
            fine,
            rk4: Tableau::rk4(),
            d5: Tableau::dopri5(),
            base_ws: RkWorkspace::new(),
            fine_ws: RkWorkspace::new(),
        }
    }

    /// Sample `n` states, draw s uniformly from `[s_lo, s_hi]`, and fill
    /// `batch` with states, first stages, and residual targets at step
    /// size `eps`. `batch`'s buffers are resized on first use and reused
    /// after.
    pub fn fill(
        &mut self,
        sampler: &StateSampler,
        n: usize,
        s_range: (f32, f32),
        eps: f32,
        rng: &mut Rng,
        batch: &mut ResidualBatch,
    ) -> Result<()> {
        let d = sampler.dim();
        if batch.z.shape() != [n, d] {
            batch.z = Tensor::zeros(&[n, d]);
            batch.dz = Tensor::zeros(&[n, d]);
            batch.target = Tensor::zeros(&[n, d]);
        }
        sampler.sample_into_for(self.f, &mut batch.z, rng)?;
        batch.s = rng.uniform_in(s_range.0 as f64, s_range.1 as f64) as f32;
        batch.eps = eps;
        let (s, eps) = (batch.s, batch.eps);
        self.targets_for(&batch.z, s, eps, &mut batch.dz, &mut batch.target)
    }

    /// Compute dz = f(s, z) and the residual target R for given states,
    /// fully overwriting `dz` and `target` (both (B, D)).
    pub fn targets_for(
        &mut self,
        z: &Tensor,
        s: f32,
        eps: f32,
        dz: &mut Tensor,
        target: &mut Tensor,
    ) -> Result<()> {
        if eps <= 0.0 {
            return Err(Error::Other("residual targets need eps > 0".into()));
        }
        let f = self.f;
        let p = self.tab.stages();
        // base direction ψ (into base_ws.acc) and first stage dz
        self.base_ws.ensure(z.shape(), p);
        self.base_ws.z_cur.copy_from(z);
        rk_stages_core(f, &self.tab, s, eps, &mut self.base_ws)?;
        combine_into(&self.base_ws.stages[..p], &self.tab.b, &mut self.base_ws.acc)?;
        dz.copy_from(&self.base_ws.stages[0]);
        // fine reference Φ(s, z, ε)
        match self.fine {
            FineRef::Rk4Substeps(m) => {
                let zf =
                    odeint_fixed_ws(f, z, (s, s + eps), m.max(1), &self.rk4, &mut self.fine_ws)?;
                target.copy_from(zf);
            }
            FineRef::Dopri5Tol(tol) => {
                let r = adaptive_ws(
                    f,
                    z,
                    (s, s + eps),
                    &self.d5,
                    &AdaptiveOpts::with_tol(tol),
                    &mut self.fine_ws,
                )?;
                target.copy_from(&r.z);
            }
        }
        // R = (Φ − z − ε ψ) / ε^{p+1}, in place
        target.axpy(-1.0, z)?;
        target.axpy(-eps, &self.base_ws.acc)?;
        let scale = 1.0 / eps.powi(self.tab.order as i32 + 1);
        target.map_inplace(|x| x * scale);
        Ok(())
    }
}

/// Mean per-sample L2 one-step errors of the plain base step and the
/// hypersolved step against the fine reference, on states `z` at (s, ε):
/// `(err_base, err_hyper)`. This is the held-out acceptance metric — a
/// trained g_ω should push `err_hyper` well below `err_base`.
pub fn one_step_errors<F: VectorField + ?Sized, G: HyperNet + ?Sized>(
    f: &F,
    g: &G,
    tab: &Tableau,
    fine: FineRef,
    z: &Tensor,
    s: f32,
    eps: f32,
) -> Result<(f32, f32)> {
    let b = z.shape()[0] as f32;
    let zf = match fine {
        FineRef::Rk4Substeps(m) => {
            crate::solvers::odeint_fixed(f, z, (s, s + eps), m.max(1), &Tableau::rk4())?
        }
        FineRef::Dopri5Tol(tol) => {
            crate::solvers::dopri5(f, z, (s, s + eps), &AdaptiveOpts::with_tol(tol))?.z
        }
    };
    let base = rk_step(f, tab, s, z, eps)?;
    let hyp = hyper_step(f, g, tab, s, z, eps)?;
    let err = |a: &Tensor| -> Result<f32> {
        Ok(a.sub(&zf)?.frobenius_norm() / b.sqrt())
    };
    Ok((err(&base)?, err(&hyp)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ode::Rotation;

    #[test]
    fn samplers_produce_finite_states_of_right_shape() {
        let mut rng = Rng::new(5);
        let boxs = StateSampler::UniformBox {
            lo: -2.0,
            hi: 2.0,
            dim: 3,
        };
        let t = boxs.sample(64, &mut rng).unwrap();
        assert_eq!(t.shape(), &[64, 3]);
        assert!(t.data().iter().all(|v| v.is_finite() && v.abs() <= 2.0));
        let den = StateSampler::Density("rings".into());
        let t = den.sample(32, &mut rng).unwrap();
        assert_eq!(t.shape(), &[32, 2]);
        assert!(StateSampler::Density("nope".into()).sample(4, &mut rng).is_err());
    }

    #[test]
    fn trajectory_sampler_draws_mesh_states_deterministically() {
        let f = Rotation { omega: 1.0 };
        let sampler = StateSampler::Trajectory {
            lo: -1.0,
            hi: 1.0,
            dim: 2,
            solver: "euler".into(),
            k: 8,
            span: (0.0, 1.0),
        };
        assert_eq!(sampler.dim(), 2);
        // field-less entry point refuses (it cannot integrate)
        let mut rng = Rng::new(3);
        assert!(sampler.sample(16, &mut rng).is_err());
        // seeded determinism: same seed → identical draw, new seed differs
        let a = sampler.sample_for(&f, 48, &mut Rng::new(42)).unwrap();
        let b = sampler.sample_for(&f, 48, &mut Rng::new(42)).unwrap();
        assert_eq!(a.data(), b.data());
        let c = sampler.sample_for(&f, 48, &mut Rng::new(43)).unwrap();
        assert_ne!(a.data(), c.data());
        // rotation preserves norms exactly and euler inflates them only
        // slightly (factor (1+ε²ω²)^{k/2} ≈ 1.07), so every mesh state
        // stays well inside twice the initial box radius
        assert!(a
            .data()
            .chunks(2)
            .all(|z| (z[0] * z[0] + z[1] * z[1]).sqrt() <= 2.0 * 2.0f32.sqrt()));
        // box samplers keep working through the field-aware entry point
        let boxs = StateSampler::UniformBox {
            lo: -1.0,
            hi: 1.0,
            dim: 2,
        };
        let d = boxs.sample_for(&f, 8, &mut Rng::new(1)).unwrap();
        let e = boxs.sample(8, &mut Rng::new(1)).unwrap();
        assert_eq!(d.data(), e.data());
    }

    #[test]
    fn trajectory_sampler_feeds_residual_generation() {
        // the ResidualGen draws through the field-aware path, so training
        // on trajectory states works end to end
        let f = Rotation { omega: 1.0 };
        let mut gen = ResidualGen::new(&f, Tableau::euler(), FineRef::Rk4Substeps(4));
        let sampler = StateSampler::Trajectory {
            lo: -1.0,
            hi: 1.0,
            dim: 2,
            solver: "euler".into(),
            k: 4,
            span: (0.0, 1.0),
        };
        let mut rng = Rng::new(9);
        let mut batch = ResidualBatch::new();
        gen.fill(&sampler, 16, (0.0, 0.9), 0.1, &mut rng, &mut batch).unwrap();
        assert_eq!(batch.z.shape(), &[16, 2]);
        assert!(batch.target.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn residual_target_matches_solver_residual_definition() {
        // the generator's target must agree with solvers::hyper::residual
        // computed from the same fine checkpoint
        let f = Rotation { omega: 1.0 };
        let tab = Tableau::euler();
        let mut gen = ResidualGen::new(&f, tab.clone(), FineRef::Rk4Substeps(16));
        let z = Tensor::new(&[2, 2], vec![1.0, 0.0, -0.5, 0.75]).unwrap();
        let (s, eps) = (0.2f32, 0.1f32);
        let mut dz = Tensor::zeros(&[2, 2]);
        let mut target = Tensor::zeros(&[2, 2]);
        gen.targets_for(&z, s, eps, &mut dz, &mut target).unwrap();
        let zf = crate::solvers::odeint_fixed(&f, &z, (s, s + eps), 16, &Tableau::rk4())
            .unwrap();
        let want = crate::solvers::residual(&f, &tab, s, &z, &zf, eps).unwrap();
        let diff = target.sub(&want).unwrap().frobenius_norm();
        assert!(diff < 1e-5, "generator target vs residual(): {diff}");
        // dz is the first stage f(s, z)
        let want_dz = f.eval(s, &z);
        assert_eq!(dz.data(), want_dz.data());
    }

    #[test]
    fn euler_residual_on_rotation_approximates_taylor_term() {
        // for ż = Az, R → ½A²z = −½ω²z as ε → 0
        let f = Rotation { omega: 1.0 };
        let mut gen = ResidualGen::new(&f, Tableau::euler(), FineRef::Dopri5Tol(1e-8));
        let z = Tensor::new(&[1, 2], vec![1.0, 0.0]).unwrap();
        let mut dz = Tensor::zeros(&[1, 2]);
        let mut target = Tensor::zeros(&[1, 2]);
        gen.targets_for(&z, 0.0, 0.01, &mut dz, &mut target).unwrap();
        let expected = z.scale(-0.5);
        let err = target.sub(&expected).unwrap().frobenius_norm();
        assert!(err < 0.05, "residual {:?}", target.data());
    }

    #[test]
    fn fill_resizes_once_and_reuses() {
        let f = Rotation { omega: 1.0 };
        let mut gen = ResidualGen::new(&f, Tableau::euler(), FineRef::Rk4Substeps(4));
        let sampler = StateSampler::UniformBox {
            lo: -1.0,
            hi: 1.0,
            dim: 2,
        };
        let mut rng = Rng::new(1);
        let mut batch = ResidualBatch::new();
        gen.fill(&sampler, 8, (0.0, 0.9), 0.1, &mut rng, &mut batch)
            .unwrap();
        assert_eq!(batch.z.shape(), &[8, 2]);
        assert!(batch.s >= 0.0 && batch.s <= 0.9);
        let ptr = batch.target.data().as_ptr();
        gen.fill(&sampler, 8, (0.0, 0.9), 0.1, &mut rng, &mut batch)
            .unwrap();
        assert_eq!(batch.target.data().as_ptr(), ptr, "buffers reused");
        assert!(batch.target.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn one_step_errors_zero_hyper_equals_base() {
        let f = Rotation { omega: 1.0 };
        let g = |_e: f32, _s: f32, z: &Tensor, _dz: &Tensor| Tensor::zeros(z.shape());
        let z = Tensor::new(&[4, 2], vec![1.0, 0.0, 0.0, 1.0, -1.0, 0.5, 0.3, -0.7])
            .unwrap();
        let (eb, eh) = one_step_errors(
            &f,
            &g,
            &Tableau::euler(),
            FineRef::Rk4Substeps(8),
            &z,
            0.0,
            0.125,
        )
        .unwrap();
        assert!((eb - eh).abs() < 1e-7, "{eb} vs {eh}");
        assert!(eb > 0.0);
    }
}
