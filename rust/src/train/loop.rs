//! The residual-fitting training loop: minibatch Adam on g_ω with loss
//! logging, early stopping, and export of the trained weights in the exact
//! JSON + manifest format the native serving backend loads — so a freshly
//! trained hypersolver is immediately servable by `hypersolverd
//! --backend native --artifacts <out>`.

use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::nn::{Act, CnfModel, FieldNet, HyperMlp, Linear, Mlp};
use crate::obs::drift::TrainStats;
use crate::ode::VectorField;
use crate::solvers::{dopri5, odeint_fixed, odeint_hyper, AdaptiveOpts, HyperNet, Tableau};
use crate::tensor::{Tensor, Workspace};
use crate::train::grad::{
    hyper_input_into, mlp_backward, mlp_forward_cached, mse_loss, mse_loss_grad, MlpCache,
    MlpGrads,
};
use crate::train::optim::{Adam, AdamCfg, CosineSchedule};
use crate::train::residual::{
    one_step_errors, FineRef, ResidualBatch, ResidualGen, StateSampler,
};
use crate::util::json::{self, Value};
use crate::util::prng::Rng;
use crate::{Error, Result};

/// Everything the trainer needs to know. Defaults are sized for the
/// analytic 2-D fields (seconds of wall time); the CLI overrides from
/// flags.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Base tableau name ("euler", "heun", "midpoint", ...).
    pub solver: String,
    /// Hidden widths of g_ω (tanh); the output layer is linear.
    pub hidden: Vec<usize>,
    pub steps: usize,
    pub batch: usize,
    pub lr: f32,
    /// Linear LR warmup steps (cosine decay after).
    pub warmup: usize,
    pub seed: u64,
    /// Serving span; ε = (s₁ − s₀) / k.
    pub s_span: (f32, f32),
    /// Serving step count the net is trained for.
    pub k: usize,
    pub fine: FineRef,
    pub sampler: StateSampler,
    /// Validation cadence (steps).
    pub eval_every: usize,
    pub eval_batch: usize,
    /// Early stop after this many evaluations without relative improvement
    /// `min_rel_improve` on the validation loss.
    pub patience: usize,
    pub min_rel_improve: f32,
    /// Stop as soon as the held-out one-step improvement factor reaches
    /// this (0 disables) — bounds training time when the target is a
    /// fixed acceptance bar rather than convergence.
    pub stop_at_improvement: f32,
    /// Print a loss line per evaluation.
    pub log: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            solver: "euler".into(),
            hidden: vec![32, 32],
            steps: 2000,
            batch: 128,
            lr: 3e-3,
            warmup: 50,
            seed: 7,
            s_span: (0.0, 1.0),
            k: 8,
            fine: FineRef::Rk4Substeps(8),
            sampler: StateSampler::UniformBox {
                lo: -2.0,
                hi: 2.0,
                dim: 2,
            },
            eval_every: 100,
            eval_batch: 256,
            patience: 6,
            min_rel_improve: 5e-3,
            stop_at_improvement: 0.0,
            log: false,
        }
    }
}

/// What a training run produced, beyond the net itself.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub steps_run: usize,
    /// Last minibatch loss.
    pub final_loss: f32,
    /// Best validation loss (the exported weights are this checkpoint).
    pub best_val_loss: f32,
    /// Held-out one-step error of the plain base solver / the hypersolved
    /// step — the acceptance criterion's improvement factor.
    pub improvement: f32,
    pub err_base: f32,
    pub err_hyper: f32,
    pub wall_secs: f64,
    pub steps_per_sec: f64,
    /// (step, validation loss) pairs at each evaluation.
    pub history: Vec<(usize, f32)>,
}

/// Initialize g_ω for `state_dim`-dimensional states: input `[z, dz, eps,
/// s]` (2d + 2), tanh hidden layers, linear output scaled small so the
/// hypersolved step starts indistinguishable from the base solver (the
/// correction enters as ε^{p+1} g).
pub fn init_hyper_mlp(state_dim: usize, hidden: &[usize], rng: &mut Rng) -> HyperMlp {
    let mut dims = Vec::with_capacity(hidden.len() + 2);
    dims.push(2 * state_dim + 2);
    dims.extend_from_slice(hidden);
    dims.push(state_dim);
    let mut layers = Vec::with_capacity(dims.len() - 1);
    for li in 0..dims.len() - 1 {
        let (din, dout) = (dims[li], dims[li + 1]);
        let last = li == dims.len() - 2;
        // LeCun normal for hidden layers; the output layer starts ~100×
        // smaller so early steps of Adam refine rather than destabilize
        let scale = if last {
            0.01 / (din as f32).sqrt()
        } else {
            1.0 / (din as f32).sqrt()
        };
        let w = Tensor::new(
            &[din, dout],
            (0..din * dout).map(|_| rng.normal_f32() * scale).collect(),
        )
        .expect("init weight shape");
        layers.push(Linear {
            w,
            b: vec![0.0; dout],
            act: if last { Act::Id } else { Act::Tanh },
        });
    }
    HyperMlp {
        mlp: Mlp { layers },
    }
}

/// Train a [`HyperMlp`] for `f` by residual fitting. Returns the best
/// (early-stopped) checkpoint and a report.
pub fn train_hypersolver<F: VectorField + ?Sized>(
    f: &F,
    cfg: &TrainConfig,
) -> Result<(HyperMlp, TrainReport)> {
    if cfg.k == 0
        || cfg.steps == 0
        || cfg.batch == 0
        || cfg.eval_every == 0
        || cfg.eval_batch == 0
    {
        return Err(Error::Other(
            "train config: k, steps, batch, eval_every, eval_batch must be > 0".into(),
        ));
    }
    let tab = Tableau::by_name(&cfg.solver)?;
    if tab.b_err.is_some() {
        return Err(Error::Other(
            "train the hypersolver for a fixed-step base solver, not an adaptive pair"
                .into(),
        ));
    }
    let d = cfg.sampler.dim();
    let mut rng = Rng::new(cfg.seed);
    let mut g = init_hyper_mlp(d, &cfg.hidden, &mut rng);
    let span = cfg.s_span.1 - cfg.s_span.0;
    if span <= 0.0 {
        return Err(Error::Other("train config: s_span must be increasing".into()));
    }
    let eps = span / cfg.k as f32;
    // train on s values whose reference step stays inside the span
    let s_range = (cfg.s_span.0, (cfg.s_span.1 - eps).max(cfg.s_span.0));
    let mut gen = ResidualGen::new(f, tab.clone(), cfg.fine);

    // fixed validation batch from an independent stream
    let mut vrng = rng.fold_in(0x5EED_DA7A);
    let mut val = ResidualBatch::new();
    gen.fill(&cfg.sampler, cfg.eval_batch, s_range, eps, &mut vrng, &mut val)?;
    let mut val_x = Tensor::zeros(&[cfg.eval_batch, 2 * d + 2]);
    hyper_input_into(val.eps, val.s, &val.z, &val.dz, &mut val_x)?;
    let mut val_cache = MlpCache::new();
    // held-out states for the improvement metric (distinct stream again)
    let mut hrng = rng.fold_in(0xBEEF_CAFE);
    let held_z = cfg.sampler.sample_for(f, cfg.eval_batch, &mut hrng)?;
    let held_s = cfg.s_span.0 + 0.5 * (span - eps).max(0.0);

    let n = g.param_count();
    let mut params = Vec::with_capacity(n);
    g.write_params(&mut params);
    let mut flat_grads: Vec<f32> = Vec::with_capacity(n);
    let mut adam = Adam::new(
        n,
        AdamCfg {
            lr: cfg.lr,
            ..AdamCfg::default()
        },
    );
    let sched = CosineSchedule {
        base_lr: cfg.lr,
        min_lr: cfg.lr * 0.01,
        warmup: cfg.warmup,
        total: cfg.steps,
    };

    let mut batch = ResidualBatch::new();
    let mut x = Tensor::zeros(&[cfg.batch, 2 * d + 2]);
    let mut dy = Tensor::zeros(&[cfg.batch, d]);
    let mut cache = MlpCache::new();
    let mut grads = MlpGrads::new();
    let mut ws = Workspace::new();

    let mut best = f32::INFINITY;
    let mut best_params = params.clone();
    let mut bad_evals = 0usize;
    let mut history = Vec::new();
    let mut final_loss = f32::NAN;
    let mut steps_run = 0usize;
    let t0 = Instant::now();

    for step in 0..cfg.steps {
        steps_run = step + 1;
        gen.fill(&cfg.sampler, cfg.batch, s_range, eps, &mut rng, &mut batch)?;
        hyper_input_into(batch.eps, batch.s, &batch.z, &batch.dz, &mut x)?;
        mlp_forward_cached(&g.mlp, &x, &mut cache)?;
        final_loss = mse_loss_grad(cache.output(), &batch.target, &mut dy)?;
        mlp_backward(&g.mlp, &cache, &dy, &mut grads, None, &mut ws)?;
        flat_grads.clear();
        grads.write_flat(&mut flat_grads);
        adam.step(&mut params, &flat_grads, sched.lr(step));
        g.read_params(&params);

        if (step + 1) % cfg.eval_every == 0 || step + 1 == cfg.steps {
            mlp_forward_cached(&g.mlp, &val_x, &mut val_cache)?;
            let vloss = mse_loss(val_cache.output(), &val.target)?;
            history.push((step + 1, vloss));
            if cfg.log {
                println!(
                    "step {:>6}  train {final_loss:<12.6}  val {vloss:<12.6}  lr {:.5}",
                    step + 1,
                    sched.lr(step)
                );
            }
            if vloss < best * (1.0 - cfg.min_rel_improve) {
                best = vloss;
                best_params.copy_from_slice(&params);
                bad_evals = 0;
            } else {
                bad_evals += 1;
                if bad_evals >= cfg.patience {
                    if cfg.log {
                        println!("early stop: no val improvement for {bad_evals} evals");
                    }
                    break;
                }
            }
            if cfg.stop_at_improvement > 0.0 {
                let (eb, eh) =
                    one_step_errors(f, &g, &tab, cfg.fine, &held_z, held_s, eps)?;
                if eh > 0.0 && eb / eh >= cfg.stop_at_improvement {
                    if cfg.log {
                        println!(
                            "early stop: improvement {:.1}× ≥ target {:.1}×",
                            eb / eh,
                            cfg.stop_at_improvement
                        );
                    }
                    // keep the *current* params (they hit the bar), and
                    // make the reported/exported δ describe those weights
                    best = vloss;
                    best_params.copy_from_slice(&params);
                    break;
                }
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    params.copy_from_slice(&best_params);
    g.read_params(&params);
    let (err_base, err_hyper) = one_step_errors(f, &g, &tab, cfg.fine, &held_z, held_s, eps)?;
    let report = TrainReport {
        steps_run,
        final_loss,
        best_val_loss: best,
        improvement: if err_hyper > 0.0 {
            err_base / err_hyper
        } else {
            f32::INFINITY
        },
        err_base,
        err_hyper,
        wall_secs: wall,
        steps_per_sec: steps_run as f64 / wall.max(1e-9),
        history,
    };
    Ok((g, report))
}

// Exported `mape` fields use `metrics::mape` — the crate-canonical
// (python-identical) measurement — so natively trained manifests route
// through the budget policy on the same scale as python-exported ones.

/// Name of the plain base-solver variant a config exports — the single
/// source of truth shared by [`export_trained`], [`serve_check`], and
/// anything that wants to address the variant by name.
pub fn base_variant_name(cfg: &TrainConfig) -> String {
    format!("{}_k{}", cfg.solver, cfg.k)
}

/// Name of the hypersolved variant a config exports.
pub fn hyper_variant_name(cfg: &TrainConfig) -> String {
    format!("hyper{}_k{}", cfg.solver, cfg.k)
}

/// Write a servable artifact set into `dir`: `manifest.json` plus
/// `weights/<task>.json` holding the field (MLP weights or analytic
/// reference) and the trained hypersolver — the exact schema
/// `runtime::Manifest::load` + `nn::CnfModel::load` parse, so
/// `NativeBackend` serves the result unchanged. Exports three variants:
/// the plain base solver at k, the hypersolved base at k, and dopri5.
/// Returns the weights path.
pub fn export_trained(
    dir: &Path,
    task: &str,
    field: &FieldNet,
    g: &HyperMlp,
    cfg: &TrainConfig,
    report: &TrainReport,
    export_batch: usize,
) -> Result<PathBuf> {
    let tab = Tableau::by_name(&cfg.solver)?;
    let d = field.state_dim();
    // measure terminal MAPE of each exported variant against tight dopri5
    let mut mrng = Rng::new(cfg.seed ^ 0x00AA_00AA);
    let z0 = cfg.sampler.sample_for(field, export_batch, &mut mrng)?;
    let truth = dopri5(field, &z0, cfg.s_span, &AdaptiveOpts::with_tol(1e-6))?.z;
    let plain = odeint_fixed(field, &z0, cfg.s_span, cfg.k, &tab)?;
    let hyped = odeint_hyper(field, g, &z0, cfg.s_span, cfg.k, &tab)?;
    let mape_plain = crate::metrics::mape(&plain, &truth)? as f32;
    let mape_hyper = crate::metrics::mape(&hyped, &truth)? as f32;
    // measure the dopri5 variant at the tolerance NativeBackend actually
    // serves it at (1e-5), against the tighter truth — no fabricated
    // numbers in the manifest, the budget policy routes on these
    let served_d5 = dopri5(field, &z0, cfg.s_span, &AdaptiveOpts::with_tol(1e-5))?;
    let mape_d5 = crate::metrics::mape(&served_d5.z, &truth)? as f32;

    // refuse to export numbers the JSON layer cannot round-trip (inf/NaN
    // from a diverged run would make the artifact set unloadable, failing
    // far away from the real cause) — and diverged weights with them
    for (what, v) in [
        ("validation loss (delta)", report.best_val_loss),
        ("plain-variant mape", mape_plain),
        ("hyper-variant mape", mape_hyper),
        ("dopri5-variant mape", mape_d5),
    ] {
        if !v.is_finite() {
            return Err(Error::Other(format!(
                "export: {what} is {v} — training or evaluation diverged; \
                 refusing to write an unloadable artifact set"
            )));
        }
    }

    let model = CnfModel {
        field: field.clone(),
        hyper: g.clone(),
    };
    std::fs::create_dir_all(dir.join("weights"))?;
    let weights_rel = format!("weights/{task}.json");
    let weights_path = dir.join(&weights_rel);
    std::fs::write(&weights_path, json::to_string(&model.to_json()))?;

    let shape = |b: usize| Value::Arr(vec![json::num(b as f64), json::num(d as f64)]);
    let stages = tab.stages() as u64;
    let mac_f = VectorField::macs(field);
    let mac_g = g.macs();
    let variant = |name: &str, solver: &str, k: usize, hyper: bool, nfe: u64, macs: u64,
                   mape: f32, adaptive: bool| {
        let mut fields = vec![
            ("name", json::s(name)),
            ("solver", json::s(solver)),
            ("k", json::num(k as f64)),
            ("hyper", Value::Bool(hyper)),
            // no HLO exists for natively trained tasks; the native backend
            // never reads it, and the pjrt backend fails loudly on the
            // missing file rather than silently serving the wrong thing
            ("hlo", json::s(&format!("{task}_{name}.hlo.txt"))),
            ("nfe", json::num(nfe as f64)),
            ("macs", json::num(macs as f64)),
            ("mape", json::num(mape as f64)),
            ("in_shape", shape(export_batch)),
            ("out_shape", shape(export_batch)),
        ];
        if adaptive {
            fields.push(("outputs", Value::Arr(vec![json::s("z"), json::s("nfe")])));
        }
        json::obj(fields)
    };
    let base_name = base_variant_name(cfg);
    let hyper_name = hyper_variant_name(cfg);
    let k64 = cfg.k as u64;
    let variants = Value::Arr(vec![
        variant(&base_name, &cfg.solver, cfg.k, false, stages * k64,
                stages * k64 * mac_f, mape_plain, false),
        variant(&hyper_name, &cfg.solver, cfg.k, true, stages * k64,
                k64 * (stages * mac_f + mac_g), mape_hyper, false),
        variant("dopri5", "dopri5", 0, false, served_d5.nfe,
                served_d5.nfe * mac_f, mape_d5, true),
    ]);

    let task_obj = json::obj(vec![
        ("kind", json::s("cnf")),
        (
            "state",
            json::obj(vec![("shape", shape(export_batch))]),
        ),
        (
            "s_span",
            Value::Arr(vec![
                json::num(cfg.s_span.0 as f64),
                json::num(cfg.s_span.1 as f64),
            ]),
        ),
        ("weights", json::s(&weights_rel)),
        ("field_hlo", json::s(&format!("{task}_field.hlo.txt"))),
        (
            "macs",
            json::obj(vec![
                ("field", json::num(mac_f as f64)),
                ("hyper", json::num(mac_g as f64)),
            ]),
        ),
        ("delta", json::num(report.best_val_loss as f64)),
        ("hyper_base", json::s(&cfg.solver)),
        // training-distribution stamp: the serving audit plane scores live
        // input drift against exactly the state distribution the residual
        // loss saw (see obs::drift); sampled fresh and seeded so re-exports
        // are reproducible
        ("train_stats", {
            let mut srng = Rng::new(cfg.seed ^ 0x7A57_57A7);
            let stats_rows = export_batch.max(512);
            let states = cfg.sampler.sample_for(field, stats_rows, &mut srng)?;
            TrainStats::from_rows(states.data(), d)?.to_json()
        }),
        ("variants", variants),
    ]);
    // merge into an existing manifest rather than clobbering it — the
    // shared exporter semantics live in runtime::manifest
    crate::runtime::manifest::merge_task_into_manifest(
        dir,
        task,
        task_obj,
        "hypertrain-native",
        cfg.seed,
    )?;
    Ok(weights_path)
}

/// Verify the train→serialize→serve loop on an exported artifacts dir:
/// reload through [`Manifest::load`], execute every variant of `task`
/// through a fresh [`NativeBackend`] on sampled inputs, check all outputs
/// are finite, and require the hypersolved variant to land closer to the
/// served dopri5 reference than the plain base solver. Returns
/// `(d_hyper, d_plain)` — the L2 distances to the served reference.
///
/// This is the acceptance criterion itself: the `hypertrain` binary and
/// `tests/train_e2e.rs` both call it, so the CLI's self-check cannot
/// drift from what the test pins.
///
/// [`Manifest::load`]: crate::runtime::Manifest::load
/// [`NativeBackend`]: crate::runtime::NativeBackend
pub fn serve_check(
    dir: &Path,
    task: &str,
    cfg: &TrainConfig,
    export_batch: usize,
) -> Result<(f32, f32)> {
    use crate::runtime::{ExecBackend, Manifest, NativeBackend};
    let manifest = Manifest::load(dir)?;
    let entry = manifest.task(task)?;
    let backend = NativeBackend::new();
    let mut rng = Rng::new(cfg.seed ^ 0x5E12_7E57);
    // the sampler may integrate trajectories of the field (paper CNF
    // setup), so reload it from the exported weights — which doubles as a
    // check that the serialized artifact parses back
    let model = crate::nn::CnfModel::load(&manifest.weights_path(entry))?;
    let input = cfg
        .sampler
        .sample_for(&model.field, export_batch, &mut rng)?
        .into_data();
    let mut outputs = std::collections::BTreeMap::new();
    for v in &entry.variants {
        let o = backend.execute(&manifest, entry, v, &input)?;
        if o.z.iter().any(|x| !x.is_finite()) {
            return Err(Error::Other(format!(
                "serve check: variant {} produced non-finite output",
                v.name
            )));
        }
        outputs.insert(v.name.clone(), o.z);
    }
    let dist = |a: &[f32], b: &[f32]| -> f32 {
        a.iter()
            .zip(b)
            .map(|(x, y)| (x - y) * (x - y))
            .sum::<f32>()
            .sqrt()
    };
    fn pick<'a>(
        outputs: &'a std::collections::BTreeMap<String, Vec<f32>>,
        name: &str,
    ) -> Result<&'a Vec<f32>> {
        outputs
            .get(name)
            .ok_or_else(|| Error::Other(format!("serve check: no {name:?} variant served")))
    }
    let truth = pick(&outputs, "dopri5")?;
    let d_hyper = dist(pick(&outputs, &hyper_variant_name(cfg))?, truth);
    let d_plain = dist(pick(&outputs, &base_variant_name(cfg))?, truth);
    if d_hyper >= d_plain {
        return Err(Error::Other(format!(
            "serve check failed: served hypersolver ({d_hyper}) is no better than \
             the plain base solver ({d_plain})"
        )));
    }
    Ok((d_hyper, d_plain))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_shapes_and_small_output_layer() {
        let mut rng = Rng::new(3);
        let g = init_hyper_mlp(2, &[16, 8], &mut rng);
        assert_eq!(g.mlp.layers.len(), 3);
        assert_eq!(g.mlp.layers[0].in_dim(), 6);
        assert_eq!(g.mlp.layers[0].out_dim(), 16);
        assert_eq!(g.mlp.layers[2].out_dim(), 2);
        assert_eq!(g.mlp.layers[0].act, Act::Tanh);
        assert_eq!(g.mlp.layers[2].act, Act::Id);
        // the output layer starts near zero: g ≈ 0 → hyper step ≈ base step
        let norm_last = g.mlp.layers[2].w.frobenius_norm();
        let norm_first = g.mlp.layers[0].w.frobenius_norm();
        assert!(norm_last < norm_first * 0.1, "{norm_last} vs {norm_first}");
    }

    #[test]
    fn bad_configs_rejected() {
        let f = crate::ode::Rotation { omega: 1.0 };
        let mut cfg = TrainConfig {
            steps: 0,
            ..TrainConfig::default()
        };
        assert!(train_hypersolver(&f, &cfg).is_err());
        cfg.steps = 10;
        cfg.solver = "dopri5".into();
        assert!(train_hypersolver(&f, &cfg).is_err(), "adaptive base rejected");
        cfg.solver = "nope".into();
        assert!(train_hypersolver(&f, &cfg).is_err());
    }

    #[test]
    fn short_training_run_reduces_validation_loss() {
        // tiny smoke: a linear-ish field, few steps — loss must drop and
        // the report must be self-consistent. The real quality gate lives
        // in tests/train_e2e.rs.
        let f = crate::ode::Rotation { omega: 1.0 };
        let cfg = TrainConfig {
            steps: 150,
            batch: 32,
            hidden: vec![12],
            eval_every: 25,
            eval_batch: 64,
            fine: FineRef::Rk4Substeps(4),
            sampler: StateSampler::UniformBox {
                lo: -1.5,
                hi: 1.5,
                dim: 2,
            },
            ..TrainConfig::default()
        };
        let (g, report) = train_hypersolver(&f, &cfg).unwrap();
        assert_eq!(g.mlp.layers.last().unwrap().out_dim(), 2);
        assert!(report.steps_run > 0 && report.steps_run <= 150);
        assert!(report.history.len() >= 2);
        let first = report.history.first().unwrap().1;
        let lastv = report.best_val_loss;
        assert!(
            lastv < first,
            "validation loss did not drop: {first} -> {lastv}"
        );
        assert!(report.err_base > 0.0 && report.err_hyper > 0.0);
        assert!(report.steps_per_sec > 0.0);
    }
}
