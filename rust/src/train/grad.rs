//! Hand-rolled reverse-mode backward passes for the hypernet forward stack.
//!
//! Deliberately small: the trainer needs d(loss)/d(params) of an [`Mlp`]
//! (the `HyperMlp` g_ω stack) plus the input-assembly adjoints — the hyper
//! `[z, dz, eps, s]` concat and the [`TimeMode`] feature concat — and the
//! [`PRelu`] channelwise backward for the conv hypernets. No tape, no
//! graph: the forward pass records per-layer activations in a reusable
//! [`MlpCache`], and the backward walks the layers in reverse with three
//! kernels (activation grad, `matmul_tn`, `matmul_nt`).
//!
//! Every kernel writes into caller-held buffers, drawing scratch from a
//! [`Workspace`], so a warm training step performs zero steady-state heap
//! allocations — the same discipline as the solver hot path. Every
//! backward is verified against central finite differences in
//! `tests/train_grad_check.rs`.

use crate::nn::{Act, Linear, Mlp, PRelu, TimeMode};
use crate::tensor::{Tensor, Workspace};
use crate::{Error, Result};

/// Per-layer forward activations recorded for the backward pass: `xs[i]`
/// is layer i's input (`xs[0]` the network input, `xs[L]` the output) and
/// `pres[i]` its pre-activation. Buffers are sized lazily and reused
/// across steps; a warm cache makes [`mlp_forward_cached`] allocation-free.
#[derive(Debug, Default)]
pub struct MlpCache {
    xs: Vec<Tensor>,
    pres: Vec<Tensor>,
}

impl MlpCache {
    pub fn new() -> MlpCache {
        MlpCache::default()
    }

    /// Size the cache for `mlp` at batch `b`. No-op (and allocation-free)
    /// when already sized — the steady-state path.
    fn ensure(&mut self, mlp: &Mlp, b: usize) {
        let l = mlp.layers.len();
        let sized = self.xs.len() == l + 1
            && self.xs[0].shape() == [b, mlp.layers[0].in_dim()]
            && mlp
                .layers
                .iter()
                .enumerate()
                .all(|(i, lr)| self.xs[i + 1].shape() == [b, lr.out_dim()]);
        if sized {
            return;
        }
        self.xs = std::iter::once(mlp.layers[0].in_dim())
            .chain(mlp.layers.iter().map(Linear::out_dim))
            .map(|d| Tensor::zeros(&[b, d]))
            .collect();
        self.pres = mlp
            .layers
            .iter()
            .map(|lr| Tensor::zeros(&[b, lr.out_dim()]))
            .collect();
    }

    /// The cached forward's output (valid after [`mlp_forward_cached`]).
    pub fn output(&self) -> &Tensor {
        self.xs.last().expect("forward before output")
    }
}

/// Parameter gradients mirroring an [`Mlp`]'s layout (per-layer dW + db);
/// [`write_flat`](Self::write_flat) matches `Mlp::write_params` order, so
/// the optimizer's flat views line up by construction.
#[derive(Debug, Default)]
pub struct MlpGrads {
    pub dw: Vec<Tensor>,
    pub db: Vec<Vec<f32>>,
}

impl MlpGrads {
    pub fn new() -> MlpGrads {
        MlpGrads::default()
    }

    fn ensure(&mut self, mlp: &Mlp) {
        let sized = self.dw.len() == mlp.layers.len()
            && mlp
                .layers
                .iter()
                .enumerate()
                .all(|(i, l)| self.dw[i].shape() == l.w.shape());
        if sized {
            return;
        }
        self.dw = mlp
            .layers
            .iter()
            .map(|l| Tensor::zeros(l.w.shape()))
            .collect();
        self.db = mlp.layers.iter().map(|l| vec![0.0; l.b.len()]).collect();
    }

    /// Append every gradient to `out` in `Mlp::write_params` order.
    pub fn write_flat(&self, out: &mut Vec<f32>) {
        for (dw, db) in self.dw.iter().zip(&self.db) {
            out.extend_from_slice(dw.data());
            out.extend_from_slice(db);
        }
    }
}

/// Forward pass recording per-layer activations. Bit-identical to
/// `Mlp::forward` — same matmul/bias/activation kernels in the same order,
/// only the intermediates are kept instead of discarded.
pub fn mlp_forward_cached(mlp: &Mlp, x: &Tensor, cache: &mut MlpCache) -> Result<()> {
    if mlp.layers.is_empty() {
        return Err(Error::Shape("cannot train an empty mlp".into()));
    }
    let b = x.shape()[0];
    if x.shape() != [b, mlp.layers[0].in_dim()] {
        return Err(Error::Shape(format!(
            "mlp_forward_cached input {:?}, layer 0 wants width {}",
            x.shape(),
            mlp.layers[0].in_dim()
        )));
    }
    cache.ensure(mlp, b);
    cache.xs[0].copy_from(x);
    for (i, l) in mlp.layers.iter().enumerate() {
        let (head, tail) = cache.xs.split_at_mut(i + 1);
        let x_in = &head[i];
        let x_out = &mut tail[0];
        let pre = &mut cache.pres[i];
        x_in.matmul_into(&l.w, pre)?;
        pre.add_bias_rows_inplace(&l.b)?;
        x_out.copy_from(pre);
        l.act.apply_inplace(x_out);
    }
    Ok(())
}

/// `du *= act'(pre)` elementwise; `post = act(pre)` is supplied so tanh can
/// use the 1 − y² form without recomputing the forward.
pub fn act_backward_inplace(
    act: Act,
    pre: &Tensor,
    post: &Tensor,
    du: &mut Tensor,
) -> Result<()> {
    if pre.shape() != du.shape() || post.shape() != du.shape() {
        return Err(Error::Shape(format!(
            "act_backward shapes pre {:?} / post {:?} / du {:?}",
            pre.shape(),
            post.shape(),
            du.shape()
        )));
    }
    if act == Act::Id {
        return Ok(());
    }
    let (p, y) = (pre.data(), post.data());
    for (i, d) in du.data_mut().iter_mut().enumerate() {
        *d *= act.grad_scalar(p[i], y[i]);
    }
    Ok(())
}

/// Reverse pass over a cached forward: given `dout = ∂L/∂y` at the output,
/// overwrite `grads` with the parameter gradients and, when `dx` is
/// `Some`, the input adjoint ∂L/∂x. Scratch comes from `ws`; a warm call
/// allocates nothing.
pub fn mlp_backward(
    mlp: &Mlp,
    cache: &MlpCache,
    dout: &Tensor,
    grads: &mut MlpGrads,
    mut dx: Option<&mut Tensor>,
    ws: &mut Workspace,
) -> Result<()> {
    let l = mlp.layers.len();
    if cache.xs.len() != l + 1 {
        return Err(Error::Shape("mlp_backward: cache does not match mlp".into()));
    }
    grads.ensure(mlp);
    let b = cache.xs[0].shape()[0];
    // adjoint of the current layer's output, walked backwards
    let mut dcur = ws.take_tensor(dout.shape());
    dcur.copy_from(dout);
    for (i, layer) in mlp.layers.iter().enumerate().rev() {
        act_backward_inplace(layer.act, &cache.pres[i], &cache.xs[i + 1], &mut dcur)?;
        cache.xs[i].matmul_tn_into(&dcur, &mut grads.dw[i], ws)?;
        dcur.col_sums_into(&mut grads.db[i])?;
        if i > 0 {
            let mut dprev = ws.take_tensor(&[b, layer.in_dim()]);
            dcur.matmul_nt_into(&layer.w, &mut dprev, ws)?;
            ws.give_tensor(dcur);
            dcur = dprev;
        } else if let Some(dx) = dx.as_deref_mut() {
            dcur.matmul_nt_into(&layer.w, dx, ws)?;
        }
    }
    ws.give_tensor(dcur);
    Ok(())
}

/// Channelwise PReLU backward on NCHW tensors: `dy` is rewritten in place
/// to `∂L/∂x = dy ⊙ (x ≥ 0 ? 1 : α_c)` and `dalpha` (length C, fully
/// overwritten) collects `Σ_{x<0} dy · x`. Matches the strict `x < 0.0`
/// branch of `PRelu::forward_inplace`.
pub fn prelu_backward(
    p: &PRelu,
    x: &Tensor,
    dy: &mut Tensor,
    dalpha: &mut [f32],
) -> Result<()> {
    let (b, c, h, w) = match x.shape() {
        [b, c, h, w] => (*b, *c, *h, *w),
        s => return Err(Error::Shape(format!("prelu_backward input {s:?}"))),
    };
    if dy.shape() != x.shape() {
        return Err(Error::Shape("prelu_backward dy shape".into()));
    }
    if c != p.alpha.len() || dalpha.len() != c {
        return Err(Error::Shape("prelu_backward channel mismatch".into()));
    }
    dalpha.fill(0.0);
    let plane = h * w;
    let xd = x.data();
    let dyd = dy.data_mut();
    for bi in 0..b {
        for ci in 0..c {
            let a = p.alpha[ci];
            let base = (bi * c + ci) * plane;
            let mut da = 0.0f32;
            for k in base..base + plane {
                let xv = xd[k];
                if xv < 0.0 {
                    da += dyd[k] * xv;
                    dyd[k] *= a;
                }
            }
            dalpha[ci] += da;
        }
    }
    Ok(())
}

// The input-assembly forward passes live in `nn::field` — ONE definition
// of the feature layouts, called by both `HyperMlp::eval_into` /
// `MlpField::eval_into` (serving) and the trainer, so the two sides cannot
// drift apart. Re-exported here so the adjoints below sit next to their
// forwards.
pub use crate::nn::field::{field_input_into, hyper_input_into};

/// Adjoint of [`hyper_input_into`]: scatter the input-row adjoint `dx`
/// (B, 2d + 2) back into `dz_adj` / `ddz_adj` (B, d, fully overwritten).
/// The eps/s columns are dropped — they are scalars broadcast per batch,
/// data rather than parameters.
pub fn hyper_input_backward(
    dx: &Tensor,
    dz_adj: &mut Tensor,
    ddz_adj: &mut Tensor,
) -> Result<()> {
    let (b, d) = match dz_adj.shape() {
        [b, d] => (*b, *d),
        sh => return Err(Error::Shape(format!("hyper adjoint state {sh:?}"))),
    };
    let w = 2 * d + 2;
    if dx.shape() != [b, w] || ddz_adj.shape() != [b, d] {
        return Err(Error::Shape("hyper_input_backward shapes".into()));
    }
    let xd = dx.data();
    {
        let zd = dz_adj.data_mut();
        for i in 0..b {
            zd[i * d..(i + 1) * d].copy_from_slice(&xd[i * w..i * w + d]);
        }
    }
    let dzd = ddz_adj.data_mut();
    for i in 0..b {
        dzd[i * d..(i + 1) * d].copy_from_slice(&xd[i * w + d..i * w + 2 * d]);
    }
    Ok(())
}

/// Adjoint of the [`TimeMode`] feature concat: copy the leading d columns
/// of `dx` into `dz_adj` (fully overwritten), dropping the time-feature
/// block (s is data, not a parameter).
pub fn field_input_backward(mode: TimeMode, dx: &Tensor, dz_adj: &mut Tensor) -> Result<()> {
    let (b, d) = match dz_adj.shape() {
        [b, d] => (*b, *d),
        sh => return Err(Error::Shape(format!("field adjoint state {sh:?}"))),
    };
    let w = d + mode.dim();
    if dx.shape() != [b, w] {
        return Err(Error::Shape(format!(
            "field_input_backward dx {:?}, want {:?}",
            dx.shape(),
            [b, w]
        )));
    }
    let xd = dx.data();
    let zd = dz_adj.data_mut();
    for i in 0..b {
        zd[i * d..(i + 1) * d].copy_from_slice(&xd[i * w..i * w + d]);
    }
    Ok(())
}

/// Mean-squared-error loss L = mean((y − t)²) over all B·D entries,
/// accumulated in f64; writes `∂L/∂y = 2 (y − t) / (B·D)` into `dy`.
pub fn mse_loss_grad(y: &Tensor, target: &Tensor, dy: &mut Tensor) -> Result<f32> {
    if y.shape() != target.shape() || dy.shape() != y.shape() {
        return Err(Error::Shape(format!(
            "mse shapes y {:?} / target {:?} / dy {:?}",
            y.shape(),
            target.shape(),
            dy.shape()
        )));
    }
    let n = y.numel() as f32;
    let (yd, td) = (y.data(), target.data());
    let dyd = dy.data_mut();
    let mut acc = 0.0f64;
    for i in 0..yd.len() {
        let e = yd[i] - td[i];
        acc += (e as f64) * (e as f64);
        dyd[i] = 2.0 * e / n;
    }
    Ok((acc / n as f64) as f32)
}

/// [`mse_loss_grad`] without the gradient — validation-loss evaluation.
pub fn mse_loss(y: &Tensor, target: &Tensor) -> Result<f32> {
    if y.shape() != target.shape() {
        return Err(Error::Shape(format!(
            "mse shapes y {:?} / target {:?}",
            y.shape(),
            target.shape()
        )));
    }
    let mut acc = 0.0f64;
    for (&a, &b) in y.data().iter().zip(target.data()) {
        let e = (a - b) as f64;
        acc += e * e;
    }
    Ok((acc / y.numel() as f64) as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn tiny_mlp() -> Mlp {
        Mlp::from_json(
            &json::parse(
                r#"[{"w":[[0.5,-0.25],[0.75,1.0]],"b":[0.1,-0.1],"act":"tanh"},
                    {"w":[[1.5],[-0.5]],"b":[0.2],"act":"id"}]"#,
            )
            .unwrap(),
        )
        .unwrap()
    }

    #[test]
    fn cached_forward_matches_plain_forward() {
        let mlp = tiny_mlp();
        let x = Tensor::new(&[3, 2], vec![0.3, -1.0, 2.0, 0.1, -0.4, 0.9]).unwrap();
        let pure = mlp.forward(&x).unwrap();
        let mut cache = MlpCache::new();
        mlp_forward_cached(&mlp, &x, &mut cache).unwrap();
        assert_eq!(cache.output().data(), pure.data());
        // warm second pass: same result, same buffers
        let ptr = cache.output().data().as_ptr();
        mlp_forward_cached(&mlp, &x, &mut cache).unwrap();
        assert_eq!(cache.output().data(), pure.data());
        assert_eq!(cache.output().data().as_ptr(), ptr, "cache reused");
    }

    #[test]
    fn zero_dout_means_zero_grads() {
        let mlp = tiny_mlp();
        let x = Tensor::new(&[2, 2], vec![0.5, -0.5, 1.0, 0.25]).unwrap();
        let mut cache = MlpCache::new();
        mlp_forward_cached(&mlp, &x, &mut cache).unwrap();
        let dout = Tensor::zeros(&[2, 1]);
        let mut grads = MlpGrads::new();
        let mut ws = Workspace::new();
        let mut dx = Tensor::full(&[2, 2], f32::NAN);
        mlp_backward(&mlp, &cache, &dout, &mut grads, Some(&mut dx), &mut ws).unwrap();
        let mut flat = Vec::new();
        grads.write_flat(&mut flat);
        assert_eq!(flat.len(), mlp.param_count());
        assert!(flat.iter().all(|&g| g == 0.0));
        assert!(dx.data().iter().all(|&g| g == 0.0));
    }

    #[test]
    fn hyper_input_assembly_matches_eval_layout() {
        // a weight that picks out each input column in turn shows the
        // assembled layout is [z, dz, eps, s]
        let z = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let dz = Tensor::new(&[2, 2], vec![5.0, 6.0, 7.0, 8.0]).unwrap();
        let mut x = Tensor::full(&[2, 6], f32::NAN);
        hyper_input_into(0.25, 0.75, &z, &dz, &mut x).unwrap();
        assert_eq!(
            x.data(),
            &[1.0, 2.0, 5.0, 6.0, 0.25, 0.75, 3.0, 4.0, 7.0, 8.0, 0.25, 0.75]
        );
        // adjoint scatters the z / dz blocks back and drops eps / s
        let dx = Tensor::from_fn(&[2, 6], |i| i as f32);
        let mut dz_adj = Tensor::zeros(&[2, 2]);
        let mut ddz_adj = Tensor::zeros(&[2, 2]);
        hyper_input_backward(&dx, &mut dz_adj, &mut ddz_adj).unwrap();
        assert_eq!(dz_adj.data(), &[0.0, 1.0, 6.0, 7.0]);
        assert_eq!(ddz_adj.data(), &[2.0, 3.0, 8.0, 9.0]);
    }

    #[test]
    fn field_input_assembly_and_adjoint() {
        let z = Tensor::new(&[1, 2], vec![3.0, -2.0]).unwrap();
        let mut x = Tensor::full(&[1, 3], f32::NAN);
        field_input_into(TimeMode::Concat, 0.5, &z, &mut x).unwrap();
        assert_eq!(x.data(), &[3.0, -2.0, 0.5]);
        let dx = Tensor::new(&[1, 3], vec![10.0, 20.0, 30.0]).unwrap();
        let mut dz = Tensor::zeros(&[1, 2]);
        field_input_backward(TimeMode::Concat, &dx, &mut dz).unwrap();
        assert_eq!(dz.data(), &[10.0, 20.0]);
    }

    #[test]
    fn mse_loss_and_grad_known_values() {
        let y = Tensor::new(&[1, 2], vec![1.0, 3.0]).unwrap();
        let t = Tensor::new(&[1, 2], vec![0.0, 1.0]).unwrap();
        let mut dy = Tensor::zeros(&[1, 2]);
        let loss = mse_loss_grad(&y, &t, &mut dy).unwrap();
        assert!((loss - 2.5).abs() < 1e-6); // (1 + 4) / 2
        assert_eq!(dy.data(), &[1.0, 2.0]); // 2e/n
        assert!((mse_loss(&y, &t).unwrap() - 2.5).abs() < 1e-6);
    }

    #[test]
    fn prelu_backward_known_values() {
        let p = PRelu {
            alpha: vec![0.5, 2.0],
        };
        let x = Tensor::new(&[1, 2, 1, 2], vec![-2.0, 3.0, -1.0, 4.0]).unwrap();
        let mut dy = Tensor::new(&[1, 2, 1, 2], vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let mut dalpha = vec![f32::NAN; 2];
        prelu_backward(&p, &x, &mut dy, &mut dalpha).unwrap();
        // dx: negatives scaled by alpha_c, positives untouched
        assert_eq!(dy.data(), &[0.5, 1.0, 2.0, 1.0]);
        // dalpha: sum of dy·x over negative entries, per channel
        assert_eq!(dalpha, vec![-2.0, -1.0]);
    }
}
