//! Adam and a cosine learning-rate schedule over flat parameter views.
//!
//! The optimizer is deliberately layout-agnostic: it sees one `&mut [f32]`
//! of parameters and one `&[f32]` of gradients in the same order
//! (`Mlp::write_params` / `MlpGrads::write_flat` agree by construction).
//! State (first/second moments) lives in two preallocated vectors, so a
//! step allocates nothing.

/// Adam hyperparameters (Kingma & Ba defaults, plus optional decoupled
/// weight decay).
#[derive(Clone, Copy, Debug)]
pub struct AdamCfg {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamCfg {
    fn default() -> Self {
        AdamCfg {
            lr: 3e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
        }
    }
}

/// Adam state over `n` flat parameters.
#[derive(Debug)]
pub struct Adam {
    pub cfg: AdamCfg,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(n: usize, cfg: AdamCfg) -> Adam {
        Adam {
            cfg,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
        }
    }

    /// Steps taken so far.
    pub fn t(&self) -> u64 {
        self.t
    }

    /// One update with learning rate `lr` (the schedule's output — `cfg.lr`
    /// is only the default passed around in configs). `params` and `grads`
    /// must be the length this state was built for.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), self.m.len(), "adam: params length");
        assert_eq!(grads.len(), self.m.len(), "adam: grads length");
        self.t += 1;
        let (b1, b2) = (self.cfg.beta1, self.cfg.beta2);
        // bias corrections in f64: beta^t underflows f32 late in training
        let bc1 = 1.0 - (b1 as f64).powi(self.t as i32);
        let bc2 = 1.0 - (b2 as f64).powi(self.t as i32);
        let (bc1, bc2) = (bc1 as f32, bc2 as f32);
        let wd = lr * self.cfg.weight_decay;
        for i in 0..params.len() {
            let g = grads[i];
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g;
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g * g;
            let m_hat = self.m[i] / bc1;
            let v_hat = self.v[i] / bc2;
            // decoupled (AdamW) decay: applied outside the moment path so
            // high-gradient weights are not under-regularized
            params[i] -= lr * m_hat / (v_hat.sqrt() + self.cfg.eps) + wd * params[i];
        }
    }
}

/// Cosine decay from `base_lr` to `min_lr` over `total` steps, with linear
/// warmup over the first `warmup` steps.
#[derive(Clone, Copy, Debug)]
pub struct CosineSchedule {
    pub base_lr: f32,
    pub min_lr: f32,
    pub warmup: usize,
    pub total: usize,
}

impl CosineSchedule {
    pub fn lr(&self, step: usize) -> f32 {
        if self.warmup > 0 && step < self.warmup {
            return self.base_lr * (step + 1) as f32 / self.warmup as f32;
        }
        let span = self.total.saturating_sub(self.warmup).max(1);
        let p = ((step - self.warmup.min(step)) as f32 / span as f32).min(1.0);
        self.min_lr
            + 0.5 * (self.base_lr - self.min_lr) * (1.0 + (std::f32::consts::PI * p).cos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_minimizes_a_quadratic() {
        // L(θ) = Σ (θ_i − c_i)², gradient 2(θ − c)
        let c = [3.0f32, -1.5, 0.25];
        let mut theta = [0.0f32; 3];
        let mut adam = Adam::new(3, AdamCfg::default());
        for _ in 0..2000 {
            let grads: Vec<f32> =
                theta.iter().zip(&c).map(|(&t, &ci)| 2.0 * (t - ci)).collect();
            adam.step(&mut theta, &grads, 0.05);
        }
        for (t, ci) in theta.iter().zip(&c) {
            assert!((t - ci).abs() < 1e-2, "{t} vs {ci}");
        }
        assert_eq!(adam.t(), 2000);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // with bias correction, the very first step has magnitude ≈ lr
        let mut theta = [0.0f32];
        let mut adam = Adam::new(1, AdamCfg::default());
        adam.step(&mut theta, &[0.37], 0.01);
        assert!((theta[0].abs() - 0.01).abs() < 1e-4, "{}", theta[0]);
    }

    #[test]
    fn cosine_schedule_endpoints_and_warmup() {
        let s = CosineSchedule {
            base_lr: 1.0,
            min_lr: 0.1,
            warmup: 10,
            total: 110,
        };
        assert!(s.lr(0) < 0.2); // warming up
        assert!((s.lr(9) - 1.0).abs() < 1e-6); // warmup done
        assert!((s.lr(110) - 0.1).abs() < 1e-6); // fully decayed
        assert!((s.lr(10_000) - 0.1).abs() < 1e-6); // clamped past total
        // midpoint sits midway
        let mid = s.lr(10 + 50);
        assert!((mid - 0.55).abs() < 1e-2, "{mid}");
    }
}
