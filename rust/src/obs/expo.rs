//! Prometheus text-format exposition (text/plain, version 0.0.4).
//!
//! [`PromText`] is a tiny deterministic renderer: callers declare a
//! metric family (`# HELP` / `# TYPE` once) and then emit samples under
//! it. Latency histograms render as Prometheus *summaries*
//! (`{quantile="0.5"|"0.99"}` + `_sum` + `_count`) — the repo's
//! [`LatencyHistogram`] is log-bucketed, so quantile midpoints are the
//! honest representation, and summaries keep per-(task, variant) fan-out
//! readable. Every rendered value is forced finite ([`fmt_value`]):
//! a ratio gauge must never expose `NaN` before its first sample (the
//! division-guard contract `CoordinatorMetrics` pins in its tests).
//!
//! [`self_check`] is the consumer-side validator: CI scrapes the
//! `--metrics-addr` listener during the serving bench and runs the scrape
//! through `benchgate --expo-check`, which calls this to assert the
//! exposition is non-empty, parses line by line, carries no non-finite
//! values, and contains the required metric families.

use crate::util::stats::LatencyHistogram;

/// Render a sample value: finite, and integral values print as integers
/// (matching the repo's JSON writer, so goldens stay stable). Non-finite
/// inputs clamp to 0 — exposition is a reporting plane, and a `NaN`
/// poisons every downstream rate()/avg().
pub fn fmt_value(v: f64) -> String {
    let v = if v.is_finite() { v } else { 0.0 };
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// HELP text escaping per the exposition format: `\` → `\\`, newline →
/// `\n` (label values additionally escape `"` — [`escape_label`]). A
/// multi-line help string must not be able to smuggle extra sample lines
/// into the scrape.
fn escape_help(v: &str) -> String {
    v.replace('\\', "\\\\").replace('\n', "\\n")
}

/// Deterministic Prometheus text builder. Families render in call order;
/// samples render in call order under their family — callers iterate
/// sorted snapshots, so repeated renders of the same state are
/// byte-identical (the golden test relies on this).
#[derive(Default)]
pub struct PromText {
    out: String,
}

impl PromText {
    pub fn new() -> PromText {
        PromText { out: String::new() }
    }

    /// Declare a metric family: `kind` is `counter`, `gauge` or
    /// `summary`.
    pub fn family(&mut self, name: &str, kind: &str, help: &str) {
        self.out.push_str("# HELP ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(&escape_help(help));
        self.out.push_str("\n# TYPE ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(kind);
        self.out.push('\n');
    }

    /// Emit one sample line: `name{labels} value`.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                self.out.push_str(k);
                self.out.push_str("=\"");
                self.out.push_str(&escape_label(v));
                self.out.push('"');
            }
            self.out.push('}');
        }
        self.out.push(' ');
        self.out.push_str(&fmt_value(value));
        self.out.push('\n');
    }

    /// Emit a latency histogram as summary samples (p50/p99 quantiles +
    /// `_sum` + `_count`) under an already-declared summary family.
    pub fn summary(&mut self, name: &str, labels: &[(&str, &str)], h: &LatencyHistogram) {
        let mut l: Vec<(&str, &str)> = labels.to_vec();
        l.push(("quantile", "0.5"));
        self.sample(name, &l, h.percentile_us(50.0));
        *l.last_mut().expect("quantile label present") = ("quantile", "0.99");
        self.sample(name, &l, h.percentile_us(99.0));
        let count = h.count();
        self.sample(&format!("{name}_sum"), labels, h.mean_us() * count as f64);
        self.sample(&format!("{name}_count"), labels, count as f64);
    }

    pub fn finish(self) -> String {
        self.out
    }
}

/// Validate one `{k="v",...}` label block: well-formed pairs, label-name
/// charset, and properly escaped values — an unescaped `"` inside a value
/// (a hostile task/variant name leaking through un-escaped) is exactly
/// the corruption this exists to catch before Prometheus does.
fn validate_labels(block: &str) -> Result<(), String> {
    let inner = block
        .strip_prefix('{')
        .and_then(|b| b.strip_suffix('}'))
        .ok_or_else(|| "malformed label block".to_string())?;
    if inner.is_empty() {
        return Ok(());
    }
    let mut chars = inner.chars().peekable();
    loop {
        let mut label = String::new();
        while let Some(&c) = chars.peek() {
            if c.is_ascii_alphanumeric() || c == '_' {
                label.push(c);
                chars.next();
            } else {
                break;
            }
        }
        if label.is_empty() {
            return Err("empty label name".to_string());
        }
        if chars.next() != Some('=') || chars.next() != Some('"') {
            return Err(format!("label {label}: expected =\" after name"));
        }
        loop {
            match chars.next() {
                None => return Err(format!("label {label}: unterminated value")),
                Some('"') => break,
                Some('\\') => match chars.next() {
                    Some('\\' | '"' | 'n') => {}
                    other => {
                        return Err(format!("label {label}: invalid escape {other:?}"))
                    }
                },
                Some(_) => {}
            }
        }
        match chars.next() {
            None => return Ok(()),
            Some(',') => continue,
            Some(c) => return Err(format!("label {label}: junk {c:?} after value")),
        }
    }
}

/// Validate a scraped exposition: non-empty, every sample line parses as
/// `name[{labels}] value` with a well-formed, correctly escaped label
/// block and a finite value, and every family in `required` has at least
/// one sample. Returns the sample count.
pub fn self_check(text: &str, required: &[&str]) -> Result<usize, String> {
    let mut samples = 0usize;
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim_end();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (head, value) = line
            .rsplit_once(' ')
            .ok_or_else(|| format!("line {}: no value separator: {line:?}", ln + 1))?;
        let name = head.split('{').next().unwrap_or("");
        if name.is_empty()
            || !name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
        {
            return Err(format!("line {}: bad metric name: {line:?}", ln + 1));
        }
        if head.len() > name.len() {
            validate_labels(&head[name.len()..])
                .map_err(|e| format!("line {}: {e}: {line:?}", ln + 1))?;
        }
        let v: f64 = value
            .parse()
            .map_err(|_| format!("line {}: unparseable value {value:?}", ln + 1))?;
        if !v.is_finite() {
            return Err(format!("line {}: non-finite value in {line:?}", ln + 1));
        }
        samples += 1;
    }
    if samples == 0 {
        return Err("exposition contains no samples".to_string());
    }
    for fam in required {
        let hit = text.lines().any(|l| {
            !l.starts_with('#')
                && (l.starts_with(&format!("{fam}{{")) || l.starts_with(&format!("{fam} ")))
        });
        if !hit {
            return Err(format!("required metric family missing: {fam}"));
        }
    }
    Ok(samples)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn golden_exposition_bytes() {
        // byte-for-byte golden: the renderer's framing (HELP/TYPE lines,
        // label quoting, value formatting, newlines) is a wire contract —
        // metric VALUES move run to run, but everything around them must
        // not. This fixed snapshot pins the frame exactly.
        let mut p = PromText::new();
        p.family("hypersolvers_requests_total", "counter", "Requests submitted");
        p.sample("hypersolvers_requests_total", &[], 42.0);
        p.family("hypersolvers_goodput", "gauge", "Deadline-met fraction");
        p.sample("hypersolvers_goodput", &[], 0.75);
        p.family(
            "hypersolvers_queue_depth_rows",
            "gauge",
            "Queued rows per (task, variant) queue",
        );
        p.sample(
            "hypersolvers_queue_depth_rows",
            &[("task", "cnf_a"), ("variant", "euler_k2")],
            3.0,
        );
        let got = p.finish();
        let want = "\
# HELP hypersolvers_requests_total Requests submitted
# TYPE hypersolvers_requests_total counter
hypersolvers_requests_total 42
# HELP hypersolvers_goodput Deadline-met fraction
# TYPE hypersolvers_goodput gauge
hypersolvers_goodput 0.75
# HELP hypersolvers_queue_depth_rows Queued rows per (task, variant) queue
# TYPE hypersolvers_queue_depth_rows gauge
hypersolvers_queue_depth_rows{task=\"cnf_a\",variant=\"euler_k2\"} 3
";
        assert_eq!(got, want);
    }

    #[test]
    fn summary_renders_quantiles_sum_count() {
        let h = LatencyHistogram::new();
        for _ in 0..10 {
            h.record(Duration::from_micros(100));
        }
        let mut p = PromText::new();
        p.family("lat_us", "summary", "test latency");
        p.summary("lat_us", &[("stage", "queue")], &h);
        let text = p.finish();
        assert!(text.contains("lat_us{stage=\"queue\",quantile=\"0.5\"}"));
        assert!(text.contains("lat_us{stage=\"queue\",quantile=\"0.99\"}"));
        assert!(text.contains("lat_us_sum{stage=\"queue\"} 1000\n"));
        assert!(text.contains("lat_us_count{stage=\"queue\"} 10\n"));
        assert!(self_check(&text, &["lat_us"]).is_ok());
    }

    #[test]
    fn values_are_always_finite() {
        assert_eq!(fmt_value(f64::NAN), "0");
        assert_eq!(fmt_value(f64::INFINITY), "0");
        assert_eq!(fmt_value(2.0), "2");
        assert_eq!(fmt_value(0.125), "0.125");
    }

    #[test]
    fn labels_are_escaped() {
        let mut p = PromText::new();
        p.sample("m", &[("task", "a\"b\\c\nd")], 1.0);
        assert_eq!(p.finish(), "m{task=\"a\\\"b\\\\c\\nd\"} 1\n");
    }

    #[test]
    fn hostile_variant_names_render_escaped_and_validate() {
        // task/variant names are operator data: quotes, backslashes and
        // newlines must escape on the wire (byte-exact golden) and the
        // escaped form must round-trip the consumer-side validator
        let mut p = PromText::new();
        p.family(
            "hypersolvers_queue_depth_rows",
            "gauge",
            "Queued rows\nper queue \\ per key",
        );
        p.sample(
            "hypersolvers_queue_depth_rows",
            &[("task", "cnf\"quoted\""), ("variant", "euler\\k2\nv2")],
            1.0,
        );
        let got = p.finish();
        let want = "\
# HELP hypersolvers_queue_depth_rows Queued rows\\nper queue \\\\ per key
# TYPE hypersolvers_queue_depth_rows gauge
hypersolvers_queue_depth_rows{task=\"cnf\\\"quoted\\\"\",variant=\"euler\\\\k2\\nv2\"} 1
";
        assert_eq!(got, want);
        assert!(self_check(&got, &["hypersolvers_queue_depth_rows"]).is_ok());
    }

    #[test]
    fn self_check_catches_the_failure_modes() {
        assert!(self_check("", &[]).is_err(), "empty");
        assert!(self_check("# HELP only comments\n", &[]).is_err(), "no samples");
        assert!(self_check("m NaN\n", &[]).is_err(), "NaN value");
        assert!(self_check("m{a=\"b\"} inf\n", &[]).is_err(), "infinite value");
        assert!(self_check("m notanumber\n", &[]).is_err(), "bad value");
        assert!(
            self_check("ok_metric 1\n", &["missing_family"]).is_err(),
            "required family absent"
        );
        let good = "# HELP m help\n# TYPE m counter\nm 3\nm{a=\"b\"} 4\n";
        assert_eq!(self_check(good, &["m"]), Ok(2));
    }

    #[test]
    fn self_check_rejects_unescaped_label_output() {
        // the corruption an un-escaped hostile name would produce
        assert!(self_check("m{task=\"a\"b\"} 1\n", &[]).is_err(), "raw quote");
        assert!(self_check("m{task=\"a\\x\"} 1\n", &[]).is_err(), "bad escape");
        assert!(self_check("m{task=\"open} 1\n", &[]).is_err(), "unterminated");
        assert!(self_check("m{=\"v\"} 1\n", &[]).is_err(), "empty label name");
        assert!(self_check("m{task:\"v\"} 1\n", &[]).is_err(), "no equals");
        assert!(self_check("m{task=\"v\" 1\n", &[]).is_err(), "no close brace");
        assert!(
            self_check("m{task=\"a\\\\b\\nc\\\"d\"} 1\n", &[]).is_ok(),
            "all three legal escapes pass"
        );
    }
}
